"""Shared benchmark harness: evaluate every system (Poplar + 4 baselines)
on a cluster via the analytical device models + BSP simulator.

Strategies (paper §Models and Baselines):
  homog-weak    — baseline 1: only the weaker homogeneous sub-cluster
  homog-strong  — baseline 2: only the stronger homogeneous sub-cluster
  deepspeed     — baseline 3: uniform micro-batches (manually maxed)
  whale         — baseline 4: spec-FLOPs-proportional hetero allocation
  poplar        — ours
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Tuple

from repro.configs import get_config
from repro.core.allocation import (allocate_flops_proportional,
                                   allocate_stage01, allocate_stage23,
                                   allocate_uniform, fit_curve)
from repro.core.cluster import CATALOG, ClusterSpec
from repro.core.planner import make_runners
from repro.core.profiler import profile_cluster
from repro.core.simulator import SimResult, simulate_plan
from repro.core.workload import comm_time_per_microstep, train_flops_per_token

SEQ = 4096


def device_groups(cluster: ClusterSpec) -> Tuple[List[str], List[str]]:
    """(weak names, strong names) by peak spec within the cluster."""
    kinds = {}
    for d in cluster.devices:
        kinds.setdefault(d.name, d)
    ordered = sorted(kinds.values(), key=lambda d: (d.peak_tflops, d.mem_gb))
    weak, strong = ordered[0].name, ordered[-1].name
    weak_names, strong_names = [], []
    counts: Dict[str, int] = {}
    for d in cluster.devices:
        counts[d.name] = counts.get(d.name, 0) + 1
        nm = f"{d.name}#{counts[d.name]}"
        if d.name == weak:
            weak_names.append(nm)
        if d.name == strong:
            strong_names.append(nm)
    return weak_names, strong_names


def evaluate_cluster(cluster: ClusterSpec, arch: str, gbs: int,
                     zero_stage: int, seq: int = SEQ
                     ) -> Dict[str, Optional[SimResult]]:
    cfg = get_config(arch)
    runners = make_runners(cluster, cfg, seq, zero_stage)
    profiles = profile_cluster(runners, zero_stage)
    if any(p.mbs < 1 for p in profiles.values()):
        return {}
    curves = {n: fit_curve(p) for n, p in profiles.items()}
    fps = train_flops_per_token(cfg, seq) * seq
    comm = comm_time_per_microstep(cfg, zero_stage, cluster.n,
                                   cluster.effective_link_gbps(cluster.n))
    weak, strong = device_groups(cluster)
    rating = {n: CATALOG[n.split("#")[0]].peak_tflops for n in curves}

    plans = {}
    if zero_stage <= 1:
        plans["poplar"] = allocate_stage01(curves, gbs)
    else:
        plans["poplar"] = allocate_stage23(curves, gbs, comm, zero_stage)
    plans["deepspeed"] = allocate_uniform(curves, gbs, zero_stage)
    plans["whale"] = allocate_flops_proportional(curves, gbs, zero_stage,
                                                 rating)
    plans["homog-weak"] = allocate_uniform(
        {n: curves[n] for n in weak}, gbs, zero_stage)
    plans["homog-strong"] = allocate_uniform(
        {n: curves[n] for n in strong}, gbs, zero_stage)

    out: Dict[str, Optional[SimResult]] = {}
    for name, p in plans.items():
        p.zero_stage = zero_stage
        sub_cluster = cluster
        out[name] = simulate_plan(p, curves, cfg, seq, sub_cluster, fps)
        out[name].strategy = name
    return out


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.3f},{derived}"
