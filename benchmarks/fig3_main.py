"""Figure 3: main experiment — clusters A/B/C x ZeRO 0-3 x five systems,
0.5B Llama, gbs ~2M tokens. Metric: cluster TFLOPs (higher is better)."""
from __future__ import annotations

from typing import List

from benchmarks.common import csv_row, evaluate_cluster
from repro.core.cluster import PAPER_CLUSTERS

GBS = 512  # x 4096 tokens ~= 2.1M tokens (paper: 2M)


def run(arch: str = "llama-0.5b") -> List[str]:
    rows = []
    summary = []
    for cname, make in PAPER_CLUSTERS.items():
        cluster = make()
        for stage in (0, 1, 2, 3):
            res = evaluate_cluster(cluster, arch, GBS, stage)
            if not res:
                continue
            pop = res["poplar"].cluster_tflops
            for strat, r in res.items():
                rows.append(csv_row(
                    f"fig3/cluster{cname}/zero{stage}/{strat}",
                    r.iter_time * 1e6,
                    f"tflops={r.cluster_tflops:.1f};util={r.utilization:.3f}"))
            ds = res["deepspeed"].cluster_tflops
            wh = res["whale"].cluster_tflops
            summary.append((cname, stage, pop / ds, pop / wh))
    for cname, stage, vs_ds, vs_wh in summary:
        rows.append(csv_row(f"fig3/speedup/cluster{cname}/zero{stage}",
                            0.0, f"vs_deepspeed={vs_ds:.2f}x;vs_whale={vs_wh:.2f}x"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
