"""Figure 4: generality across models — 0.5B Llama, 1.1B Llama, 1.1B BERT
(cluster C, all ZeRO stages; plus the memory-tight cluster-B runs at 1.1B
where the paper's largest DeepSpeed gaps occur)."""
from __future__ import annotations

from typing import List

from benchmarks.common import csv_row, evaluate_cluster
from repro.core.cluster import cluster_B, cluster_C

GBS = 512


def run() -> List[str]:
    rows = []
    cases = ([("C", cluster_C, a) for a in
              ("llama-0.5b", "llama-1.1b", "bert-1.1b")]
             + [("B", cluster_B, a) for a in ("llama-1.1b", "bert-1.1b")])
    for cl_tag, cl_fn, arch in cases:
        for stage in (0, 1, 2, 3):
            tag = f"fig4{cl_tag}/{arch}/zero{stage}"
            res = evaluate_cluster(cl_fn(), arch, GBS, stage)
            if not res:
                rows.append(csv_row(f"{tag}/infeasible",
                                    0.0, "OOM at this stage"))
                continue
            pop = res["poplar"].cluster_tflops
            for strat, r in res.items():
                rows.append(csv_row(
                    f"{tag}/{strat}", r.iter_time * 1e6,
                    f"tflops={r.cluster_tflops:.1f}"))
            rows.append(csv_row(
                f"{tag}/speedup", 0.0,
                f"vs_deepspeed={pop/res['deepspeed'].cluster_tflops:.2f}x;"
                f"vs_whale={pop/res['whale'].cluster_tflops:.2f}x"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
