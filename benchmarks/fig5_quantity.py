"""Figure 5: quantity heterogeneity — A800:V100S ratios 4:1..1:4 plus the
homogeneous anchors (V4, A4), all ZeRO stages, cluster-C device types.

Reproduces the appendix observation that V4A4 can *underperform* V4A3 in
ZeRO-3 (communication growth outweighs added compute)."""
from __future__ import annotations

from typing import List

from benchmarks.common import csv_row, evaluate_cluster
from repro.core.cluster import make_cluster

GBS = 512
COMPOSITIONS = [
    ("V4", [("V100S-32G", 4)]),
    ("A4", [("A800-80G", 4)]),
    ("A4V1", [("A800-80G", 4), ("V100S-32G", 1)]),
    ("A4V2", [("A800-80G", 4), ("V100S-32G", 2)]),
    ("A4V3", [("A800-80G", 4), ("V100S-32G", 3)]),
    ("A4V4", [("A800-80G", 4), ("V100S-32G", 4)]),
    ("A3V4", [("A800-80G", 3), ("V100S-32G", 4)]),
    ("A2V4", [("A800-80G", 2), ("V100S-32G", 4)]),
    ("A1V4", [("A800-80G", 1), ("V100S-32G", 4)]),
]


def run() -> List[str]:
    rows = []
    for stage in (0, 1, 2, 3):
        series = {}
        for tag, comp in COMPOSITIONS:
            cluster = make_cluster(tag, comp, 12.0)
            res = evaluate_cluster(cluster, "llama-0.5b", GBS, stage)
            if not res:
                continue
            r = res["poplar"]
            series[tag] = r.cluster_tflops
            rows.append(csv_row(f"fig5/zero{stage}/{tag}",
                                r.iter_time * 1e6,
                                f"tflops={r.cluster_tflops:.1f};"
                                f"util={r.utilization:.3f}"))
        # monotone growth check + the V4A4-vs-V4A3 anomaly marker
        if "A4V4" in series and "A4V3" in series:
            rows.append(csv_row(
                f"fig5/zero{stage}/A4V4_vs_A4V3", 0.0,
                f"ratio={series['A4V4']/series['A4V3']:.3f}"))
    # appendix regime: the A4V4 < A4V3 inversion appears once the
    # inter-node link is slow enough that ZeRO-3 comm growth outweighs
    # the extra compute (paper appendix, \"V4A4 group has lower cluster
    # utilization than the V4A3 group in ZeRO-3\").
    for link in (12.0, 4.0, 2.0, 1.0):
        series = {}
        for tag, comp in (("A4V3", [("A800-80G", 4), ("V100S-32G", 3)]),
                          ("A4V4", [("A800-80G", 4), ("V100S-32G", 4)])):
            cluster = make_cluster(tag, comp, link)
            res = evaluate_cluster(cluster, "llama-0.5b", GBS, 3)
            if res:
                series[tag] = res["poplar"].cluster_tflops
        if len(series) == 2:
            rows.append(csv_row(
                f"fig5/link_sweep/zero3/link{link:g}GBps", 0.0,
                f"A4V3={series['A4V3']:.1f};A4V4={series['A4V4']:.1f};"
                f"ratio={series['A4V4']/series['A4V3']:.3f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
