"""Figure 6 / appendix: GPU compute capability vs batch size.

Two parts: (a) analytical curves for the paper's GPU types (the saturating
relationship Poplar exploits); (b) a *measured* curve on this host — a real
jitted reduced-Llama train step timed at increasing batch sizes, showing
the same rise-then-plateau shape on actual hardware (CPU here, TPU in
prod)."""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row
from repro.configs import get_config
from repro.core.cluster import CATALOG
from repro.core.planner import make_runners
from repro.core.profiler import MeasuredRunner, profile_device
from repro.core.workload import MemoryModel, train_flops_per_token

BATCHES = [1, 2, 4, 8, 16, 32, 64]


def run(measured: bool = True) -> List[str]:
    rows = []
    cfg = get_config("llama-0.5b")
    fps = train_flops_per_token(cfg, 4096) * 4096
    for dev in ("A100-80G", "V100-16G", "T4-16G", "RTX4090-24G"):
        spec = CATALOG[dev]
        mem = MemoryModel(cfg, 4096, 0, 4)
        from repro.core.profiler import AnalyticalRunner
        r = AnalyticalRunner(spec, mem, fps, 0)
        for b in BATCHES:
            if mem.bytes_at_batch(b) > spec.mem_gb * 1e9:
                break
            t = r.compute_time(b)
            rows.append(csv_row(f"fig6/analytical/{dev}/b{b}", t * 1e6,
                                f"samples_per_s={b/t:.2f}"))
    if measured:
        rows.extend(_measured_curve())
    return rows


def _measured_curve() -> List[str]:
    from repro.core.sharding import MeshRules
    from repro.core.zero import make_train_step, register_axes
    from repro.launch.mesh import make_debug_mesh
    from repro.models import model as mm
    from repro.optim.adamw import adamw_init
    cfg = get_config("llama-0.5b", reduced=True)
    params, axes = mm.init_model(jax.random.PRNGKey(0), cfg)
    rules = MeshRules(make_debug_mesh(1), zero_stage=0)
    register_axes(rules, axes)
    step = jax.jit(make_train_step(cfg, rules))
    opt = adamw_init(params)
    rows = []
    rng = np.random.default_rng(0)
    for b in (1, 2, 4, 8):
        toks = jnp.asarray(rng.integers(3, cfg.vocab_size, (b, 65)), jnp.int32)
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:],
                 "loss_mask": jnp.ones((b, 64), jnp.float32)}
        jax.block_until_ready(step(params, opt, batch))  # compile+warm
        t0 = time.perf_counter()
        for _ in range(3):
            out = step(params, opt, batch)
        jax.block_until_ready(out)
        t = (time.perf_counter() - t0) / 3
        rows.append(csv_row(f"fig6/measured-host/b{b}", t * 1e6,
                            f"samples_per_s={b/t:.2f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
