"""Figure 7: cubic-spline interpolation error vs ground-truth speed data —
'the gap ... is almost zero'. We fit on Poplar's probe points (powers of two
+ binary-search path) and evaluate against every integer batch size."""
from __future__ import annotations

from typing import List

import numpy as np

from benchmarks.common import csv_row
from repro.configs import get_config
from repro.core.allocation import fit_curve
from repro.core.planner import make_runners
from repro.core.cluster import make_cluster
from repro.core.profiler import profile_cluster


def run() -> List[str]:
    rows = []
    cfg = get_config("llama-0.5b")
    cluster = make_cluster("t", [("A800-80G", 1), ("V100-16G", 1),
                                 ("T4-16G", 1)])
    runners = make_runners(cluster, cfg, 4096, 0)
    profs = profile_cluster(runners, 0)
    for name, prof in profs.items():
        curve = fit_curve(prof)
        runner = runners[name]
        bs = np.arange(1, prof.mbs + 1)
        truth = np.array([b / runner.compute_time(int(b)) for b in bs])
        pred = curve.speed(bs.astype(float))
        rel = np.abs(pred - truth) / truth
        rows.append(csv_row(
            f"fig7/spline_error/{name}", 0.0,
            f"mean_rel_err={rel.mean():.5f};max_rel_err={rel.max():.5f};"
            f"knots={len(prof.points)};range=1..{prof.mbs}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
