"""Figure 8: compute-capability measurement — wall-time (Poplar) vs
spec-sheet FLOPs (Whale), normalized to T4. The gap between the two columns
is exactly the misallocation error a FLOPs-only cost model commits."""
from __future__ import annotations

from typing import List

from benchmarks.common import csv_row
from repro.configs import get_config
from repro.core.cluster import CATALOG
from repro.core.profiler import AnalyticalRunner
from repro.core.workload import MemoryModel, train_flops_per_token

DEVICES = ("T4-16G", "V100-16G", "V100S-32G", "RTX4090-24G", "A100-80G",
           "A800-80G")


def run() -> List[str]:
    rows = []
    cfg = get_config("llama-0.5b")
    fps = train_flops_per_token(cfg, 4096) * 4096
    base = None
    meas = {}
    for dev in DEVICES:
        spec = CATALOG[dev]
        r = AnalyticalRunner(spec, MemoryModel(cfg, 4096, 0, 4), fps, 0)
        mbs_like = 16  # measure near-saturation like the paper (at mbs)
        t = r.compute_time(mbs_like)
        meas[dev] = mbs_like / t
    t4 = meas["T4-16G"]
    t4_flops = CATALOG["T4-16G"].peak_tflops
    for dev in DEVICES:
        rel_wall = meas[dev] / t4
        rel_flops = CATALOG[dev].peak_tflops / t4_flops
        err = abs(rel_flops - rel_wall) / rel_wall
        rows.append(csv_row(
            f"fig8/capability/{dev}", 0.0,
            f"walltime_rel={rel_wall:.2f};flops_rel={rel_flops:.2f};"
            f"flops_metric_err={err*100:.1f}%"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
