"""§Perf baseline-vs-variant comparison rows, read from the dry-run
artifacts. One row per (arch, shape, mesh, variant) with the dominant-term
speedup over the same combo's baseline artifact."""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List

from benchmarks.common import csv_row
from benchmarks.roofline import DRYRUN_DIR, roofline_terms


def run() -> List[str]:
    base: Dict = {}
    variants = []
    for fp in sorted(Path(DRYRUN_DIR).glob("*.json")):
        rec = json.loads(fp.read_text())
        t = roofline_terms(rec)
        if t is None:
            continue
        key = (t["arch"], t["shape"], t["mesh"])
        if t["variant"] == "base":
            base[key] = t
        else:
            variants.append((key, t))
    rows = []
    for key, t in variants:
        b = base.get(key)
        if b is None:
            continue
        # report the term the variant moved the most (its actual target),
        # plus the bound (max-term) change — the end-to-end picture
        factors = {}
        for term in ("compute", "memory", "collective"):
            before, after = b[f"{term}_s"], t[f"{term}_s"]
            factors[term] = (before / after) if after > 0 else (
                1.0 if before == 0 else float("inf"))
        target = max(factors, key=factors.get)
        bound_f = (b["bound_s"] / t["bound_s"]) if t["bound_s"] > 0 else 1.0
        rows.append(csv_row(
            f"perf/{t['arch']}/{t['shape']}/{t['mesh']}/{t['variant']}",
            t["bound_s"] * 1e6,
            f"target={target};before_ms={b[f'{target}_s']*1e3:.2f};"
            f"after_ms={t[f'{target}_s']*1e3:.2f};"
            f"factor={factors[target]:.2f}x;bound_factor={bound_f:.2f}x;"
            f"new_dominant={t['dominant']}"))
    if not rows:
        rows.append(csv_row("perf/missing", 0.0,
                            "no variant artifacts; run dryrun --variant"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
