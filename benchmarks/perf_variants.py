"""§Perf baseline-vs-variant comparison rows, read from the dry-run
artifacts, plus a live fwd+bwd attention kernel timing: the jnp reference
(chunked online-softmax) vs the custom-VJP Pallas flash kernels under
``jax.value_and_grad``, with (block_q, block_k) taken from the autotuner
(which persists its sweep to the on-disk cache as a side effect)."""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List

from benchmarks.common import csv_row
from benchmarks.roofline import DRYRUN_DIR, roofline_terms


def attention_fwd_bwd_rows(B: int = 1, H: int = 4, S: int = 256,
                           D: int = 64) -> List[str]:
    """Train-path (value_and_grad) attention timing: reference vs Pallas."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels import autotune
    from repro.kernels.flash_attention import flash_attention_vjp
    from repro.kernels.ops import _interpret_default
    from repro.models.layers import _chunk_attn_flash

    interpret = _interpret_default()
    rng = np.random.default_rng(0)
    q, k, v = (jnp.asarray(rng.standard_normal((B, H, S, D)), jnp.float32)
               for _ in range(3))

    def make_pallas(bq, bk):
        @jax.jit
        def fwd_bwd(q, k, v):
            def loss(q, k, v):
                return flash_attention_vjp(
                    q, k, v, causal=True, block_q=bq, block_k=bk,
                    interpret=interpret).astype(jnp.float32).sum()
            return jax.value_and_grad(loss, argnums=(0, 1, 2))(q, k, v)
        return lambda: fwd_bwd(q, k, v)

    # Tune under the key the training path (ops.flash_attention) reads.
    # Interpret mode never sweeps: timings there measure the traced-Python
    # interpreter, not hardware — the static-table lookup still writes the
    # key through to the on-disk cache.
    kw = dict(S=S, D=D, dtype="float32", causal=True, window=None)
    if interpret:
        bq, bk = autotune.lookup("flash_fwd", interpret=True, **kw)
    else:
        bq, bk = autotune.tune(
            "flash_fwd", make_pallas,
            candidates=((64, 64), (128, 64), (128, 128)), iters=3, **kw)

    @jax.jit
    def ref_fwd_bwd(q, k, v):
        def loss(q, k, v):
            return _chunk_attn_flash(q, k, v, causal=True, window=None
                                     ).astype(jnp.float32).sum()
        return jax.value_and_grad(loss, argnums=(0, 1, 2))(q, k, v)

    ms_ref = autotune.median_ms(lambda: ref_fwd_bwd(q, k, v))
    ms_pal = autotune.median_ms(make_pallas(bq, bk))
    mode = "interpret" if interpret else "compiled"
    shape = f"B{B}H{H}S{S}D{D}"
    return [
        csv_row(f"perf/kernels/attn_fwd_bwd/{shape}/reference",
                ms_ref * 1e3, f"mode=jnp-chunked;ms={ms_ref:.3f}"),
        csv_row(f"perf/kernels/attn_fwd_bwd/{shape}/pallas",
                ms_pal * 1e3,
                f"mode={mode};blocks=({bq},{bk});ms={ms_pal:.3f};"
                f"speedup={ms_ref / ms_pal:.2f}x;"
                f"autotune_cache={autotune.cache_path()}"),
    ]


def run() -> List[str]:
    base: Dict = {}
    variants = []
    for fp in sorted(Path(DRYRUN_DIR).glob("*.json")):
        rec = json.loads(fp.read_text())
        t = roofline_terms(rec)
        if t is None:
            continue
        key = (t["arch"], t["shape"], t["mesh"])
        if t["variant"] == "base":
            base[key] = t
        else:
            variants.append((key, t))
    rows = []
    for key, t in variants:
        b = base.get(key)
        if b is None:
            continue
        # report the term the variant moved the most (its actual target),
        # plus the bound (max-term) change — the end-to-end picture
        factors = {}
        for term in ("compute", "memory", "collective"):
            before, after = b[f"{term}_s"], t[f"{term}_s"]
            factors[term] = (before / after) if after > 0 else (
                1.0 if before == 0 else float("inf"))
        target = max(factors, key=factors.get)
        bound_f = (b["bound_s"] / t["bound_s"]) if t["bound_s"] > 0 else 1.0
        rows.append(csv_row(
            f"perf/{t['arch']}/{t['shape']}/{t['mesh']}/{t['variant']}",
            t["bound_s"] * 1e6,
            f"target={target};before_ms={b[f'{target}_s']*1e3:.2f};"
            f"after_ms={t[f'{target}_s']*1e3:.2f};"
            f"factor={factors[target]:.2f}x;bound_factor={bound_f:.2f}x;"
            f"new_dominant={t['dominant']}"))
    if not rows:
        rows.append(csv_row("perf/missing", 0.0,
                            "no variant artifacts; run dryrun --variant"))
    try:
        rows.extend(attention_fwd_bwd_rows())
    except Exception as e:  # noqa: BLE001 — live timing is best-effort
        rows.append(csv_row("perf/kernels/attn_fwd_bwd/error", 0.0,
                            f"{type(e).__name__}: {e}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
