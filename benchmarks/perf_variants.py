"""§Perf baseline-vs-variant comparison rows, read from the dry-run
artifacts, plus live attention kernel timings: the jnp reference
(chunked online-softmax) vs the custom-VJP Pallas flash kernels under
``jax.value_and_grad``, with (block_q, block_k) taken from the autotuner
(which persists its sweep to the on-disk cache as a side effect).

GQA rows compare the legacy hq-expanded reference against the GQA-native
Pallas kernels at group sizes hq/hkv in {1, 6, 8} (fwd+bwd) plus a
decode-latency row, reporting the K/V bytes the un-expanded layout saves
per step.

ZeRO-3 overlap rows time the XLA-auto stage-3 step against the scheduled
shard_map step (core/overlap.py) on an 8-device CPU mesh (subprocess),
reporting step time, tokens/sec and the analytic exposed-comm bytes of
each schedule.

Session rows pin the facade contract: a `repro.api.Session`-built step
must cost the same per step as the hand-wired ceremony it replaced
(build cost reported separately)."""
from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path
from typing import Dict, List

from benchmarks.common import csv_row
from benchmarks.roofline import DRYRUN_DIR, roofline_terms


def _time_attn_fwd_bwd(q, k, v, *, G: int, interpret: bool, expand_ref: bool):
    """Shared fwd+bwd (value_and_grad) timing harness for the attention
    rows: resolve (block_q, block_k) through the autotuner (lookup-only in
    interpret mode — timings there measure the traced-Python interpreter,
    not hardware; the static-table lookup still writes the key through to
    the on-disk cache), then time the Pallas custom-VJP kernels against
    the jnp reference. ``expand_ref`` times the legacy hq-expanded
    reference (the GQA comparison); otherwise the GQA-native chunked one.
    Returns (ms_ref, ms_pallas, (bq, bk))."""
    import jax
    import jax.numpy as jnp

    from repro.kernels import autotune, ref
    from repro.kernels.flash_attention import flash_attention_vjp
    from repro.models.layers import _chunk_attn_flash

    def make_pallas(bq, bk):
        @jax.jit
        def fwd_bwd(q, k, v):
            def loss(q, k, v):
                return flash_attention_vjp(
                    q, k, v, causal=True, block_q=bq, block_k=bk,
                    interpret=interpret).astype(jnp.float32).sum()
            return jax.value_and_grad(loss, argnums=(0, 1, 2))(q, k, v)
        return lambda: fwd_bwd(q, k, v)

    # tune under the key the training path (ops.flash_attention) reads
    kw = dict(S=q.shape[2], D=q.shape[3], dtype="float32", causal=True,
              window=None, G=G)
    if interpret:
        bq, bk = autotune.lookup("flash_fwd", interpret=True, **kw)
    else:
        bq, bk = autotune.tune(
            "flash_fwd", make_pallas,
            candidates=((64, 64), (128, 64), (128, 128)), iters=3, **kw)

    @jax.jit
    def ref_fwd_bwd(q, k, v):
        def loss(q, k, v):
            ke = ref.expand_kv(k, G, 1) if expand_ref else k
            ve = ref.expand_kv(v, G, 1) if expand_ref else v
            return _chunk_attn_flash(q, ke, ve, causal=True, window=None
                                     ).astype(jnp.float32).sum()
        return jax.value_and_grad(loss, argnums=(0, 1, 2))(q, k, v)

    ms_ref = autotune.median_ms(lambda: ref_fwd_bwd(q, k, v))
    ms_pal = autotune.median_ms(make_pallas(bq, bk))
    return ms_ref, ms_pal, (bq, bk)


def attention_fwd_bwd_rows(B: int = 1, H: int = 4, S: int = 256,
                           D: int = 64) -> List[str]:
    """Train-path (value_and_grad) attention timing: reference vs Pallas."""
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels import autotune
    from repro.kernels.ops import _interpret_default

    interpret = _interpret_default()
    rng = np.random.default_rng(0)
    q, k, v = (jnp.asarray(rng.standard_normal((B, H, S, D)), jnp.float32)
               for _ in range(3))
    ms_ref, ms_pal, (bq, bk) = _time_attn_fwd_bwd(
        q, k, v, G=1, interpret=interpret, expand_ref=False)
    mode = "interpret" if interpret else "compiled"
    shape = f"B{B}H{H}S{S}D{D}"
    return [
        csv_row(f"perf/kernels/attn_fwd_bwd/{shape}/reference",
                ms_ref * 1e3, f"mode=jnp-chunked;ms={ms_ref:.3f}"),
        csv_row(f"perf/kernels/attn_fwd_bwd/{shape}/pallas",
                ms_pal * 1e3,
                f"mode={mode};blocks=({bq},{bk});ms={ms_pal:.3f};"
                f"speedup={ms_ref / ms_pal:.2f}x;"
                f"autotune_cache={autotune.cache_path()}"),
    ]


def gqa_attention_rows(B: int = 1, Hkv: int = 1,
                       groups=(1, 6, 8)) -> List[str]:
    """GQA fwd+bwd: legacy expanded reference vs the GQA-native Pallas
    kernels at hq/hkv group sizes ``groups``, plus K/V bytes saved."""
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels.ops import _interpret_default

    interpret = _interpret_default()
    # interpret mode (CI smoke) runs the kernel body as traced Python:
    # keep shapes small there, realistic when compiled for hardware
    S, D = (128, 64) if interpret else (1024, 128)
    rng = np.random.default_rng(1)
    rows = []
    for G in groups:
        Hq = G * Hkv
        q = jnp.asarray(rng.standard_normal((B, Hq, S, D)), jnp.float32)
        k, v = (jnp.asarray(rng.standard_normal((B, Hkv, S, D)), jnp.float32)
                for _ in range(2))
        ms_ref, ms_pal, (bq, bk) = _time_attn_fwd_bwd(
            q, k, v, G=G, interpret=interpret, expand_ref=True)
        itemsize = q.dtype.itemsize
        kv_native = 2 * B * Hkv * S * D * itemsize
        kv_expanded = 2 * B * Hq * S * D * itemsize
        mode = "interpret" if interpret else "compiled"
        rows.append(csv_row(
            f"perf/kernels/gqa_attn_fwd_bwd/g{G}/B{B}Hq{Hq}Hkv{Hkv}S{S}D{D}",
            ms_pal * 1e3,
            f"mode={mode};blocks=({bq},{bk});ms_pallas={ms_pal:.3f};"
            f"ms_ref_expanded={ms_ref:.3f};"
            f"speedup={ms_ref / ms_pal:.2f}x;"
            f"kv_bytes_native={kv_native};kv_bytes_expanded={kv_expanded};"
            f"kv_bytes_saved_per_step={kv_expanded - kv_native}"))
    return rows


def gqa_decode_row(B: int = 1, Hkv: int = 2, G: int = 8) -> List[str]:
    """Decode latency: GQA-native flash-decode (one cache read serves the
    query group) vs the expanded jnp reference over a long cache."""
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels import autotune, ref
    from repro.kernels.flash_decode import flash_decode_pallas
    from repro.kernels.ops import _interpret_default
    import functools
    import jax

    interpret = _interpret_default()
    S, D = (512, 64) if interpret else (8192, 128)
    Hq = G * Hkv
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.standard_normal((B, Hq, 1, D)), jnp.float32)
    # cache in its stored (B, S, Hkv, D) layout — what the kernel reads
    kc, vc = (jnp.asarray(rng.standard_normal((B, S, Hkv, D)), jnp.float32)
              for _ in range(2))
    filled = jnp.int32(S - 3)

    pal = jax.jit(functools.partial(flash_decode_pallas, block_k=256,
                                    interpret=interpret))
    ref_fn = jax.jit(lambda q, k, v, f: ref.gqa_decode_attention_reference(
        q, k.swapaxes(1, 2), v.swapaxes(1, 2), f))
    ms_pal = autotune.median_ms(lambda: pal(q, kc, vc, filled))
    ms_ref = autotune.median_ms(lambda: ref_fn(q, kc, vc, filled))
    itemsize = q.dtype.itemsize
    kv_native = 2 * B * Hkv * S * D * itemsize
    kv_expanded = 2 * B * Hq * S * D * itemsize
    mode = "interpret" if interpret else "compiled"
    return [csv_row(
        f"perf/kernels/gqa_decode/g{G}/B{B}Hq{Hq}Hkv{Hkv}S{S}D{D}",
        ms_pal * 1e3,
        f"mode={mode};ms_pallas={ms_pal:.3f};ms_ref_expanded={ms_ref:.3f};"
        f"speedup={ms_ref / ms_pal:.2f}x;"
        f"kv_bytes_saved_per_step={kv_expanded - kv_native}")]


def _run_subproc_json(script: str, marker: str, timeout: int = 900) -> Dict:
    """Run an inline benchmark script in a subprocess (needed whenever the
    bench wants placeholder XLA devices — the parent keeps its real single
    device) and parse the one ``<marker> <json>`` line it prints."""
    env = dict(os.environ)
    env["PYTHONPATH"] = (str(Path(__file__).resolve().parents[1] / "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=timeout)
    line = next((l for l in proc.stdout.splitlines()
                 if l.startswith(marker + " ")), None)
    if line is None:
        raise RuntimeError(f"{marker} subprocess failed: "
                           f"{proc.stdout[-500:]}{proc.stderr[-500:]}")
    return json.loads(line[len(marker) + 1:])


_OVERLAP_SUBPROC = r"""
import os, json, time
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
import numpy as np
from repro.api import Session
from repro.configs import get_config
from repro.core import overlap

cfg = get_config("llama-0.5b", reduced=True)
mesh = jax.make_mesh((8,), ("data",))
rng = np.random.default_rng(0)
B, S = 16, 64
toks = jnp.asarray(rng.integers(3, cfg.vocab_size, (B, S)), jnp.int32)
batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1),
         "loss_mask": jnp.ones((B, S), jnp.float32)}
out = {}
for mode in ("xla", "scheduled"):
    sess = Session.build(cfg, None, gbs=B, seq=S, zero=3, overlap=mode,
                         impl="reference", lr=1e-3, mesh=mesh)
    met = sess.step(batch)   # compile + warm up
    jax.block_until_ready(met["loss"])
    times = []
    for _ in range(5):
        t0 = time.perf_counter()
        met = sess.step(batch)
        jax.block_until_ready(met["loss"])
        times.append(time.perf_counter() - t0)
    plan = overlap.plan_comm(sess.rules, sess.state.params, sess.state.axes,
                             batch)
    rep = (overlap.comm_report(plan, sess.state.params, remat=cfg.remat)
           if not isinstance(plan, str) else {})
    ms = sorted(times)[len(times) // 2] * 1e3
    out[mode] = {"ms": ms, "tokens_per_sec": B * S / (ms / 1e3),
                 "report": rep}
print("OVERLAP_JSON " + json.dumps(out))
"""


def session_overhead_rows(B: int = 8, S: int = 64) -> List[str]:
    """Session-vs-hand-wired train step on the local device: the facade
    must add no per-step cost (the jitted computation is identical; the
    wrapper adds one dict conversion + the step-counter increment).
    Build cost is reported separately — it includes the one-time planner
    /init/device_put work the hand-wired path also pays piecemeal."""
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.api import Session
    from repro.configs import get_config
    from repro.core.sharding import MeshRules
    from repro.core.zero import make_train_step, model_shardings, register_axes
    from repro.launch.mesh import make_debug_mesh
    from repro.models import model as mm
    from repro.optim.adamw import adamw_init

    def min_ms(fn, iters: int = 7):
        """Best-of-N wall clock: robust to scheduler noise on shared CI
        runners (a systematic facade overhead would still show in the
        minimum; a one-off noisy interleaving does not)."""
        fn()                                     # warm-up
        best = float("inf")
        for _ in range(iters):
            t0 = time.perf_counter()
            fn()
            best = min(best, time.perf_counter() - t0)
        return best * 1e3

    cfg = get_config("llama-0.5b", reduced=True)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(3, cfg.vocab_size, (B, S)), jnp.int32)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1),
             "loss_mask": jnp.ones((B, S), jnp.float32)}

    # hand-wired ceremony (the pre-Session path, via the deprecation shims)
    t0 = time.perf_counter()
    mesh = make_debug_mesh(jax.device_count())
    rules = MeshRules(mesh, zero_stage=0)
    params, axes = mm.init_model(jax.random.PRNGKey(0), cfg)
    register_axes(rules, axes)
    p_specs, o_specs, _ = model_shardings(rules, params, axes)
    opt = adamw_init(params)
    with mesh:
        params = jax.device_put(params, jax.tree.map(rules.sharding, p_specs))
        opt = jax.device_put(opt, jax.tree.map(rules.sharding, o_specs))
        step = jax.jit(make_train_step(cfg, rules, lr=1e-3,
                                       impl="reference"))
        p, o, met = step(params, opt, batch)
        jax.block_until_ready(met["loss"])
    build_hand = time.perf_counter() - t0

    def hand_step():
        nonlocal params, opt
        with mesh:
            params, opt, met = step(params, opt, batch)
            jax.block_until_ready(met["loss"])

    ms_hand = min_ms(hand_step)

    # the same configuration through the Session facade
    t0 = time.perf_counter()
    sess = Session.build(cfg, None, gbs=B, seq=S, zero=0, impl="reference",
                         lr=1e-3)
    met = sess.step(batch)
    jax.block_until_ready(met["loss"])
    build_sess = time.perf_counter() - t0

    def sess_step():
        jax.block_until_ready(sess.step(batch)["loss"])

    ms_sess = min_ms(sess_step)

    ratio = ms_sess / ms_hand
    return [csv_row(
        "perf/session_api/step_overhead/8x64_reduced_llama", ms_sess * 1e3,
        f"ms_session={ms_sess:.3f};ms_handwired={ms_hand:.3f};"
        f"overhead={ratio:.3f}x;"
        f"build_s_session={build_sess:.2f};build_s_handwired={build_hand:.2f};"
        f"overhead_ok={ratio < 1.25}")]


def zero3_overlap_rows() -> List[str]:
    """Auto-vs-scheduled ZeRO-3 rows: wall time per train step on an
    8-placeholder-device CPU mesh (subprocess — the bench process keeps
    its single device) plus each schedule's exposed-comm bytes."""
    data = _run_subproc_json(_OVERLAP_SUBPROC, "OVERLAP_JSON")
    rep = data["scheduled"]["report"]
    exposed_auto = rep["exposed_bytes_auto"]
    exposed_sched = rep["exposed_bytes_scheduled"]
    ms_a, ms_s = data["xla"]["ms"], data["scheduled"]["ms"]
    return [
        csv_row("perf/zero3_overlap/8dev_cpu/auto", ms_a * 1e3,
                f"ms={ms_a:.2f};"
                f"tokens_per_sec={data['xla']['tokens_per_sec']:.0f};"
                f"exposed_comm_bytes={int(exposed_auto)}"),
        csv_row("perf/zero3_overlap/8dev_cpu/scheduled", ms_s * 1e3,
                f"ms={ms_s:.2f};"
                f"tokens_per_sec={data['scheduled']['tokens_per_sec']:.0f};"
                f"speedup={ms_a / ms_s:.2f}x;"
                f"exposed_comm_bytes={int(exposed_sched)};"
                f"hidden_comm_bytes={int(rep['hidden_bytes_scheduled'])};"
                f"exposed_lower_than_auto={exposed_sched < exposed_auto}"),
    ]


_RAGGED_SUBPROC = r"""
import os, json, time
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import numpy as np
from repro.api import Session
from repro.configs import get_config
from repro.data.pipeline import HeteroDataLoader, MixedLengthDocs

cfg = get_config("llama-0.5b", reduced=True)
B, S = 16, 64
# one mixed-length corpus, two views: zero-padded one-doc-per-row rows
# (the padded baseline) vs FFD-packed rows with segment ids — identical
# document stream, so the comparison isolates the packing
src = MixedLengthDocs(cfg.vocab_size, S, min_len=8, seed=0)
out = {}
for mode in ("padded", "packed"):
    packing = mode == "packed"
    sess = Session.build(cfg, None, gbs=B, seq=S, zero=3,
                         impl="reference", lr=1e-3, packing=packing)
    loader = HeteroDataLoader(src, sess.layout, S, packing=packing)
    met = sess.step(loader.next_batch())        # compile + warm up
    jax.block_until_ready(met["loss"])
    times, tokens = [], 0.0
    for _ in range(5):
        batch = loader.next_batch()
        t0 = time.perf_counter()
        met = sess.step(batch)
        jax.block_until_ready(met["loss"])
        times.append(time.perf_counter() - t0)
        tokens += float(met["tokens"])
    ms = sorted(times)[len(times) // 2] * 1e3
    tps = tokens / sum(times)
    pad_frac = 1.0 - tokens / (5.0 * B * S)
    out[mode] = {"ms": ms, "tokens_per_sec": tps, "pad_fraction": pad_frac,
                 "loss_finite": bool(np.isfinite(float(met["loss"])))}
print("RAGGED_JSON " + json.dumps(out))
"""


def ragged_packing_rows() -> List[str]:
    """Sequence packing end to end: padded one-doc-per-row vs FFD-packed
    batches of the *same* mixed-length document stream (8-placeholder-
    device CPU mesh, subprocess). Wall time per step barely moves — the
    tensor shapes are identical — but the packed rows carry ~2x the real
    tokens, so non-pad tokens/sec (the only throughput that matters) is
    where packing pays."""
    d = _run_subproc_json(_RAGGED_SUBPROC, "RAGGED_JSON")
    pk, pd = d["packed"], d["padded"]
    beats = pk["tokens_per_sec"] > pd["tokens_per_sec"]
    return [csv_row(
        "perf/ragged/packed_throughput/8dev_cpu", pk["ms"] * 1e3,
        f"ms_packed={pk['ms']:.2f};ms_padded={pd['ms']:.2f};"
        f"packed_tokens_per_sec={pk['tokens_per_sec']:.0f};"
        f"padded_tokens_per_sec={pd['tokens_per_sec']:.0f};"
        f"speedup={pk['tokens_per_sec'] / max(pd['tokens_per_sec'], 1e-9):.2f}x;"
        f"pad_fraction_packed={pk['pad_fraction']:.3f};"
        f"pad_fraction_padded={pd['pad_fraction']:.3f};"
        f"loss_finite={pk['loss_finite'] and pd['loss_finite']};"
        f"packed_beats_padded={beats}")]


_ELASTIC_SUBPROC = r"""
import os, json, time
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import numpy as np
from repro.api import Session
from repro.configs import get_config
from repro.core.cluster import make_cluster

cfg = get_config("llama-0.5b", reduced=True)
sess = Session.build(cfg, make_cluster("c8", [("V100-16G", 4),
                                              ("T4-16G", 4)], 12.0),
                     gbs=16, seq=64, zero=3, impl="reference", lr=1e-3)
sess.step()                               # compile + warm up
times = []
for _ in range(5):
    t0 = time.perf_counter()
    sess.step()
    times.append(time.perf_counter() - t0)
step_s = sorted(times)[len(times) // 2]

# drop two devices mid-run: re-plan + live cross-mesh reshard
rep = sess.replan(cluster=make_cluster("c6", [("V100-16G", 4),
                                              ("T4-16G", 2)], 12.0))
losses = [float(sess.step()["loss"]) for _ in range(2)]
out = {"step_ms": step_s * 1e3, "plan_ms": rep.plan_seconds * 1e3,
       "reshard_ms": rep.reshard_seconds * 1e3,
       "replan_ms": rep.total_seconds * 1e3,
       "old_devices": rep.old_devices, "new_devices": rep.new_devices,
       "loss_finite": bool(np.all(np.isfinite(losses)))}
print("ELASTIC_JSON " + json.dumps(out))
"""


def elastic_replan_rows() -> List[str]:
    """Elastic-runtime overhead: a mid-run ``session.replan()`` after two
    of eight devices drop (subprocess, 8-placeholder-device CPU mesh) —
    plan + live cross-mesh reshard wall time, compared against one train
    step so the break-even horizon is explicit."""
    d = _run_subproc_json(_ELASTIC_SUBPROC, "ELASTIC_JSON")
    ratio = d["replan_ms"] / max(d["step_ms"], 1e-9)
    return [csv_row(
        "perf/elastic/replan_overhead/8to6dev_cpu", d["replan_ms"] * 1e3,
        f"replan_ms={d['replan_ms']:.2f};plan_ms={d['plan_ms']:.2f};"
        f"reshard_ms={d['reshard_ms']:.2f};step_ms={d['step_ms']:.2f};"
        f"steps_equivalent={ratio:.2f};"
        f"devices={d['old_devices']}to{d['new_devices']};"
        f"loss_finite={d['loss_finite']}")]


_ROBUST_SUBPROC = r"""
import os, json, time, tempfile
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import numpy as np
from repro.api import FaultPolicy, FaultSchedule, Session, Supervisor
from repro.checkpoint import committed_steps
from repro.configs import get_config
from repro.core.cluster import make_cluster

cfg = get_config("llama-0.5b", reduced=True)
sess = Session.build(cfg, make_cluster("c8", [("V100-16G", 4),
                                              ("T4-16G", 4)], 12.0),
                     gbs=16, seq=64, zero=3, impl="reference", lr=1e-3)
sess.step()                               # compile + warm up
times = []
for _ in range(5):
    t0 = time.perf_counter()
    m = sess.step()
    jax.block_until_ready(m["loss"])
    times.append(time.perf_counter() - t0)
step_s = sorted(times)[len(times) // 2]

# checkpoint stall: how long does save() hold the training loop? The
# blocking path pays gather + serialize + write + fsync + rename; the
# async path pays only the device->host gather (the rest commits on the
# background thread).
ckpt = tempfile.mkdtemp()
t0 = time.perf_counter()
sess.save(ckpt)
blocking_stall = time.perf_counter() - t0
t0 = time.perf_counter()
pend = sess.save(ckpt, async_=True)
async_stall = time.perf_counter() - t0
pend.result(120)                          # the write itself still lands

# recovery cost: lose two devices mid-step under the supervisor and
# time the absorb (drain + re-plan + reshard onto the six survivors)
sched = FaultSchedule().lose(int(sess.state.step), "T4-16G#3", "T4-16G#4")
sup = Supervisor(sess, FaultPolicy(min_devices=4), sched, ckpt_path=ckpt)
t0 = time.perf_counter()
m = sup.step()
recovery_s = time.perf_counter() - t0
ev = {e.kind: e.seconds for e in sup.events}
out = {"step_ms": step_s * 1e3,
       "blocking_stall_ms": blocking_stall * 1e3,
       "async_stall_ms": async_stall * 1e3,
       "recovery_ms": recovery_s * 1e3,
       "replan_recovery_ms": ev.get("replan_recovered", 0.0) * 1e3,
       "new_devices": sup.session.cluster.n,
       "committed": committed_steps(ckpt),
       "loss_finite": bool(np.isfinite(float(m["loss"])))}
print("ROBUST_JSON " + json.dumps(out))
"""


def robustness_async_ckpt_rows() -> List[str]:
    """Fault-tolerance overhead rows (subprocess, 8-placeholder-device
    CPU mesh): the training-loop stall of an async save vs the blocking
    commit protocol, and the wall cost of absorbing a two-device loss
    through the supervised step loop (drain + re-plan + reshard),
    expressed in train-step equivalents."""
    d = _run_subproc_json(_ROBUST_SUBPROC, "ROBUST_JSON")
    step_ms = max(d["step_ms"], 1e-9)
    return [csv_row(
        "perf/robustness/async_ckpt/8dev_cpu", d["async_stall_ms"] * 1e3,
        f"async_stall_ms={d['async_stall_ms']:.2f};"
        f"blocking_stall_ms={d['blocking_stall_ms']:.2f};"
        f"stall_ratio={d['async_stall_ms'] / max(d['blocking_stall_ms'], 1e-9):.3f};"
        f"async_stall_lt_blocking="
        f"{d['async_stall_ms'] < d['blocking_stall_ms']};"
        f"step_ms={d['step_ms']:.2f};"
        f"recovery_ms={d['recovery_ms']:.2f};"
        f"recovery_steps_equivalent={d['recovery_ms'] / step_ms:.2f};"
        f"survivors={d['new_devices']};"
        f"loss_finite={d['loss_finite']}")]


_ARBITER_SUBPROC = r"""
import os, json, time
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import numpy as np
from repro.api import ClusterArbiter, FaultSchedule, Session
from repro.configs import get_config
from repro.core.cluster import make_cluster

cfg = get_config("llama-0.5b", reduced=True)
arb = ClusterArbiter(make_cluster("c8", [("V100-16G", 4),
                                         ("T4-16G", 4)], 12.0))
arb.register_train("train", cfg, gbs=16, seq=64, zero=3, priority=1,
                   min_devices=4)
arb.register_serve("serve", cfg, requests=16, cache_len=32, priority=0,
                   min_devices=1)
t0 = time.perf_counter()
rep = arb.arbitrate(trigger="initial")
initial_s = time.perf_counter() - t0
# the naive heterogeneity-blind baseline: every kind split evenly
even = arb.evaluate_partition(arb.even_partition())

sess = Session.build(cfg, arb.leases["train"], gbs=16, seq=64, zero=3,
                     impl="reference", lr=1e-3)
sup = arb.attach("train", sess)
sess.step()                               # compile + warm up
times = []
for _ in range(5):
    t0 = time.perf_counter()
    m = sess.step()
    jax.block_until_ready(m["loss"])
    times.append(time.perf_counter() - t0)
step_s = sorted(times)[len(times) // 2]

# re-arbitration cost: lose two devices mid-step; the supervised step
# absorbs it through ONE global re-arbitration (candidate search over
# both tenants' planners + replan of the train session onto its new
# lease). The search itself is reported separately from the full absorb.
sess.attach_faults(FaultSchedule().lose(int(sess.state.step),
                                        "T4-16G#3", "T4-16G#4"))
t0 = time.perf_counter()
m = sup.step()
rearb_s = time.perf_counter() - t0
out = {"step_ms": step_s * 1e3,
       "initial_arbitration_ms": initial_s * 1e3,
       "rearbitration_ms": rearb_s * 1e3,
       "arbitration_search_ms": arb.last_report.seconds * 1e3,
       "utility_arbiter": rep.utility,
       "utility_even": even if even is not None else 0.0,
       "candidates": rep.candidates,
       "arbitrations": arb.arbitrations,
       "survivors": len(arb.healthy),
       "loss_finite": bool(np.isfinite(float(m["loss"])))}
print("ARBITER_JSON " + json.dumps(out))
"""


def arbitration_rows() -> List[str]:
    """Multi-tenant arbitration rows (subprocess, 8-placeholder-device
    CPU mesh): the quality gap between the arbiter's Algorithm-1-priced
    partition and a naive even split on the skewed fixture, and the wall
    cost of absorbing a two-device loss through one global
    re-arbitration, in train-step equivalents."""
    d = _run_subproc_json(_ARBITER_SUBPROC, "ARBITER_JSON")
    step_ms = max(d["step_ms"], 1e-9)
    even = max(d["utility_even"], 1e-9)
    return [csv_row(
        "perf/robustness/arbitration/8dev_cpu", d["rearbitration_ms"] * 1e3,
        f"rearbitration_ms={d['rearbitration_ms']:.2f};"
        f"arbitration_search_ms={d['arbitration_search_ms']:.2f};"
        f"initial_arbitration_ms={d['initial_arbitration_ms']:.2f};"
        f"step_ms={d['step_ms']:.2f};"
        f"arbitration_steps_equivalent={d['rearbitration_ms'] / step_ms:.2f};"
        f"utility_arbiter={d['utility_arbiter']:.1f};"
        f"utility_even={d['utility_even']:.1f};"
        f"utility_delta={d['utility_arbiter'] / even:.3f}x;"
        f"arbiter_beats_even={d['utility_arbiter'] > d['utility_even']};"
        f"candidates={d['candidates']};"
        f"survivors={d['survivors']};"
        f"loss_finite={d['loss_finite']}")]


_SERVE_SUBPROC = r"""
import os, json, time
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
from dataclasses import replace
import numpy as np
import jax.numpy as jnp
from repro.api import Session
from repro.configs import get_config
from repro.core.cluster import make_cluster
from repro.launch.serve import run_engine_wave, run_wave

cfg = replace(get_config("llama-0.5b", reduced=True),
              dtype="float32", param_dtype="float32")
cl = make_cluster("c8", [("V100-16G", 4), ("T4-16G", 4)], 12.0)
sess = Session.build(cfg, cl, mode="serve", impl="reference")

# skewed mixed-length traffic (mostly short chats + two long documents):
# the fixed wave pads every request to the longest prompt AND horizon
rng = np.random.default_rng(0)
plens = [int(n) for n in rng.integers(4, 9, 8)] + [56, 48]
gens = [int(g) for g in rng.integers(2, 5, 8)] + [40, 48]
prompts = [rng.integers(3, cfg.vocab_size, n).tolist() for n in plens]
useful = sum(gens)
pmax, gmax = max(plens), max(gens)

kw = dict(num_pages=256, page_size=8, chunk=32)
run_engine_wave(sess, prompts, gens, **kw)         # compile + warm up
best = None
for _ in range(2):
    _, s, eng = run_engine_wave(sess, prompts, gens, **kw)
    if best is None or s < best[0]:
        best = (s, eng)
engine_s, eng = best
snap = eng.telemetry.snapshot()

wave = jnp.asarray(np.stack([
    np.pad(p, (0, pmax - len(p)), constant_values=3) for p in prompts]),
    jnp.int32)
run_wave(sess, wave, gmax)                         # warmup
wave_s = []
for _ in range(2):
    t0 = time.time()
    run_wave(sess, wave, gmax)
    wave_s.append(time.time() - t0)
wave_s = min(wave_s)

out = {"engine_s": engine_s, "wave_s": wave_s, "useful_tokens": useful,
       "padded_tokens": len(prompts) * (pmax + gmax),
       "requests": len(prompts), "steps": eng.steps,
       "preemptions": eng.preemptions,
       "split": eng.split.describe() if eng.split else "none",
       "ttft_p50_s": snap["ttft_p50_s"], "ttft_p95_s": snap["ttft_p95_s"],
       "tok_p50_s": snap["tok_p50_s"], "tok_p95_s": snap["tok_p95_s"]}
print("SERVE_JSON " + json.dumps(out))
"""


def serving_engine_rows() -> List[str]:
    """Serving-engine row (subprocess, 8-placeholder-device CPU mesh):
    continuous batching + paged KV vs the fixed-wave baseline on skewed
    mixed-length traffic, in *useful* tokens/sec (both paths credited
    only the tokens requests asked for), plus the engine's TTFT and
    per-token latency percentiles. ``engine_beats_fixed_wave`` is the
    CI gate — the whole subsystem exists to win this row."""
    d = _run_subproc_json(_SERVE_SUBPROC, "SERVE_JSON")
    useful = d["useful_tokens"]
    engine_tps = useful / d["engine_s"]
    wave_tps = useful / d["wave_s"]
    pad_waste = 1.0 - useful / d["padded_tokens"]
    return [csv_row(
        "perf/serving/engine_vs_wave/8dev_cpu", d["engine_s"] * 1e6,
        f"engine_tokens_per_sec={engine_tps:.1f};"
        f"wave_tokens_per_sec={wave_tps:.1f};"
        f"speedup={engine_tps / wave_tps:.2f}x;"
        f"engine_beats_fixed_wave={engine_tps > wave_tps};"
        f"requests={d['requests']};useful_tokens={useful};"
        f"wave_pad_waste={pad_waste:.3f};"
        f"ttft_p50_ms={d['ttft_p50_s'] * 1e3:.1f};"
        f"ttft_p95_ms={d['ttft_p95_s'] * 1e3:.1f};"
        f"tok_p50_ms={d['tok_p50_s'] * 1e3:.2f};"
        f"tok_p95_ms={d['tok_p95_s'] * 1e3:.2f};"
        f"decode_steps={d['steps']};preemptions={d['preemptions']}")]


_PACKED_PREFILL_SUBPROC = r"""
import os, json, time
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
from dataclasses import replace
import numpy as np
from repro.api import Session
from repro.configs import get_config
from repro.core.cluster import make_cluster
from repro.launch.serve import run_engine_wave

cfg = replace(get_config("llama-0.5b", reduced=True),
              dtype="float32", param_dtype="float32")
cl = make_cluster("c8", [("V100-16G", 4), ("T4-16G", 4)], 12.0)
sess = Session.build(cfg, cl, mode="serve", impl="reference")

# same skewed traffic as perf/serving/engine_vs_wave: mostly short
# chats plus two long documents — the workload where sequential B=1
# chunked prefill burns one model call per request per tick
rng = np.random.default_rng(0)
plens = [int(n) for n in rng.integers(4, 9, 8)] + [56, 48]
gens = [int(g) for g in rng.integers(2, 5, 8)] + [40, 48]
prompts = [rng.integers(3, cfg.vocab_size, n).tolist() for n in plens]
useful = sum(gens)

out = {"useful_tokens": useful, "requests": len(prompts)}
res = {}
for name, packed in (("packed", True), ("chunked", False)):
    kw = dict(num_pages=256, page_size=8, chunk=32,
              packed_prefill=packed, prefix_cache=False)
    run_engine_wave(sess, prompts, gens, **kw)        # compile + warm up
    best = None
    for _ in range(2):
        r, s, eng = run_engine_wave(sess, prompts, gens, **kw)
        if best is None or s < best[0]:
            best = (s, eng, r)
    s, eng, r = best
    res[name] = r
    snap = eng.telemetry.snapshot()
    out[name] = {"wall_s": s, "prefill_calls": snap["prefill_calls"],
                 "prefill_tokens": snap["prefill_tokens"],
                 "fill_frac": snap["prefill_fill_frac"],
                 "ttft_p50_s": snap["ttft_p50_s"]}
out["token_parity"] = res["packed"] == res["chunked"]

# ---- prefix-heavy staggered drill: every request shares a 48-token
# system prompt; arrivals are spread over ticks so later requests can
# adopt pages earlier ones registered (bulk submits all admit on the
# same tick, before any pages exist to share) ----
sys_prompt = rng.integers(3, cfg.vocab_size, 48).tolist()
tails = [rng.integers(3, cfg.vocab_size, int(t)).tolist()
         for t in rng.integers(4, 9, 8)]

def staggered(prefix_cache):
    eng = sess.engine(num_pages=256, page_size=8, chunk=32,
                      requests=len(tails), cache_len=128,
                      packed_prefill=True, prefix_cache=prefix_cache)
    for tail in tails:
        eng.submit(sys_prompt + tail, 6)
        eng.step(); eng.step()
    return eng.run(), eng

staggered(True); staggered(False)                      # compile + warm up
res_on, eng_on = staggered(True)
res_off, eng_off = staggered(False)
snap_on = eng_on.telemetry.snapshot()
snap_off = eng_off.telemetry.snapshot()
out["prefix"] = {
    "token_parity": res_on == res_off,
    "submitted_tokens": sum(len(sys_prompt) + len(t) for t in tails),
    "prefill_tokens_on": snap_on["prefill_tokens"],
    "prefill_tokens_off": snap_off["prefill_tokens"],
    "prefix_hit_tokens": snap_on["prefix_hit_tokens"],
    "ttft_p50_on_s": snap_on["ttft_p50_s"],
    "ttft_p50_off_s": snap_off["ttft_p50_s"]}
print("PACKED_JSON " + json.dumps(out))
"""


def packed_prefill_rows() -> List[str]:
    """Packed segment-masked prefill vs the PR-9 sequential chunked
    baseline (same engine, ``packed_prefill=False``) on the skewed
    8-device workload, plus a prefix-heavy staggered drill for the
    refcounted prefix cache. Two CI gates ride in the derived blobs:
    ``packed_prefill_beats_chunked`` (strictly fewer model calls AND
    higher useful tok/s AND greedy-token parity) and
    ``prefix_cache_saves_prefill`` (bit-identical tokens while
    computing strictly fewer prefill tokens than were submitted)."""
    d = _run_subproc_json(_PACKED_PREFILL_SUBPROC, "PACKED_JSON")
    useful = d["useful_tokens"]
    pk, ch = d["packed"], d["chunked"]
    packed_tps = useful / pk["wall_s"]
    chunked_tps = useful / ch["wall_s"]
    beats = (pk["prefill_calls"] < ch["prefill_calls"]
             and packed_tps > chunked_tps and d["token_parity"])
    px = d["prefix"]
    saves = (px["token_parity"]
             and px["prefill_tokens_on"] < px["submitted_tokens"]
             and px["prefill_tokens_on"] < px["prefill_tokens_off"])
    return [
        csv_row(
            "perf/serving/packed_prefill/8dev_cpu", pk["wall_s"] * 1e6,
            f"packed_tokens_per_sec={packed_tps:.1f};"
            f"chunked_tokens_per_sec={chunked_tps:.1f};"
            f"speedup={packed_tps / chunked_tps:.2f}x;"
            f"prefill_calls_packed={pk['prefill_calls']};"
            f"prefill_calls_chunked={ch['prefill_calls']};"
            f"pack_fill_frac={pk['fill_frac']:.3f};"
            f"token_parity={d['token_parity']};"
            f"packed_prefill_beats_chunked={beats};"
            f"requests={d['requests']};useful_tokens={useful};"
            f"ttft_p50_ms={pk['ttft_p50_s'] * 1e3:.1f};"
            f"ttft_p50_chunked_ms={ch['ttft_p50_s'] * 1e3:.1f}"),
        csv_row(
            "perf/serving/prefix_cache/8dev_cpu",
            px["prefill_tokens_on"],
            f"submitted_tokens={px['submitted_tokens']};"
            f"prefill_tokens_on={px['prefill_tokens_on']};"
            f"prefill_tokens_off={px['prefill_tokens_off']};"
            f"prefix_hit_tokens={px['prefix_hit_tokens']};"
            f"token_parity={px['token_parity']};"
            f"prefix_cache_saves_prefill={saves};"
            f"ttft_p50_ms={px['ttft_p50_on_s'] * 1e3:.1f};"
            f"ttft_p50_nocache_ms={px['ttft_p50_off_s'] * 1e3:.1f}")]


def run() -> List[str]:
    base: Dict = {}
    variants = []
    for fp in sorted(Path(DRYRUN_DIR).glob("*.json")):
        rec = json.loads(fp.read_text())
        t = roofline_terms(rec)
        if t is None:
            continue
        key = (t["arch"], t["shape"], t["mesh"])
        if t["variant"] == "base":
            base[key] = t
        else:
            variants.append((key, t))
    rows = []
    for key, t in variants:
        b = base.get(key)
        if b is None:
            continue
        # report the term the variant moved the most (its actual target),
        # plus the bound (max-term) change — the end-to-end picture
        factors = {}
        for term in ("compute", "memory", "collective"):
            before, after = b[f"{term}_s"], t[f"{term}_s"]
            factors[term] = (before / after) if after > 0 else (
                1.0 if before == 0 else float("inf"))
        target = max(factors, key=factors.get)
        bound_f = (b["bound_s"] / t["bound_s"]) if t["bound_s"] > 0 else 1.0
        rows.append(csv_row(
            f"perf/{t['arch']}/{t['shape']}/{t['mesh']}/{t['variant']}",
            t["bound_s"] * 1e6,
            f"target={target};before_ms={b[f'{target}_s']*1e3:.2f};"
            f"after_ms={t[f'{target}_s']*1e3:.2f};"
            f"factor={factors[target]:.2f}x;bound_factor={bound_f:.2f}x;"
            f"new_dominant={t['dominant']}"))
    if not rows:
        rows.append(csv_row("perf/missing", 0.0,
                            "no variant artifacts; run dryrun --variant"))
    try:
        rows.extend(attention_fwd_bwd_rows())
    except Exception as e:  # noqa: BLE001 — live timing is best-effort
        rows.append(csv_row("perf/kernels/attn_fwd_bwd/error", 0.0,
                            f"{type(e).__name__}: {e}"))
    try:
        rows.extend(gqa_attention_rows())
        rows.extend(gqa_decode_row())
    except Exception as e:  # noqa: BLE001 — live timing is best-effort
        rows.append(csv_row("perf/kernels/gqa/error", 0.0,
                            f"{type(e).__name__}: {e}"))
    try:
        rows.extend(zero3_overlap_rows())
    except Exception as e:  # noqa: BLE001 — live timing is best-effort
        rows.append(csv_row("perf/zero3_overlap/error", 0.0,
                            f"{type(e).__name__}: {e}"))
    try:
        rows.extend(session_overhead_rows())
    except Exception as e:  # noqa: BLE001 — live timing is best-effort
        rows.append(csv_row("perf/session_api/error", 0.0,
                            f"{type(e).__name__}: {e}"))
    try:
        rows.extend(elastic_replan_rows())
    except Exception as e:  # noqa: BLE001 — live timing is best-effort
        rows.append(csv_row("perf/elastic/error", 0.0,
                            f"{type(e).__name__}: {e}"))
    try:
        rows.extend(ragged_packing_rows())
    except Exception as e:  # noqa: BLE001 — live timing is best-effort
        rows.append(csv_row("perf/ragged/error", 0.0,
                            f"{type(e).__name__}: {e}"))
    try:
        rows.extend(robustness_async_ckpt_rows())
    except Exception as e:  # noqa: BLE001 — live timing is best-effort
        rows.append(csv_row("perf/robustness/error", 0.0,
                            f"{type(e).__name__}: {e}"))
    try:
        rows.extend(arbitration_rows())
    except Exception as e:  # noqa: BLE001 — live timing is best-effort
        rows.append(csv_row("perf/robustness/error", 0.0,
                            f"{type(e).__name__}: {e}"))
    try:
        rows.extend(serving_engine_rows())
    except Exception as e:  # noqa: BLE001 — live timing is best-effort
        rows.append(csv_row("perf/serving/error", 0.0,
                            f"{type(e).__name__}: {e}"))
    try:
        rows.extend(packed_prefill_rows())
    except Exception as e:  # noqa: BLE001 — live timing is best-effort
        rows.append(csv_row("perf/serving/packed_prefill/error", 0.0,
                            f"{type(e).__name__}: {e}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
