"""Roofline analysis from the dry-run artifacts (deliverable g).

Per (arch x shape x mesh):
  compute term    = HLO_FLOPs_global / (chips x 197e12 bf16 FLOP/s)
  memory term     = HLO_bytes_global / (chips x 819e9 B/s HBM)
  collective term = collective_bytes_per_device / 50e9 B/s ICI link

HLO FLOPs/bytes come from the dry-run's *unrolled* single-device cost
pass (``flops_unrolled`` / ``bytes_unrolled``) — global algorithmic
numbers with scan bodies fully counted — and are divided by chip count.
(The compiled SPMD module's own cost_analysis() counts while-loop bodies
once, under-reporting by ~n_layers; it is kept in the artifacts as
``flops_per_device_compiled`` for reference only.) Collective bytes are
parsed from the partitioned HLO and are already per-participant.
Also reports MODEL_FLOPS = 6*N(_active)*D vs HLO_FLOPs (useful-compute
ratio: catches remat/dispatch/rectangle-attention waste).
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Optional

from benchmarks.common import csv_row
from repro.configs import get_config, get_shape

PEAK_FLOPS = 197e12     # v5e bf16
HBM_BW = 819e9          # B/s
ICI_BW = 50e9           # B/s per link

DRYRUN_DIR = Path("experiments/dryrun")


def roofline_terms(rec: Dict) -> Optional[Dict]:
    if "error" in rec or "skipped" in rec:
        return None
    arch, shape_name = rec["arch"], rec["shape"]
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    chips = 1
    for s in rec["mesh"]:
        chips *= s
    flops = rec.get("flops_unrolled")     # global algorithmic FLOPs
    bytes_acc = rec.get("bytes_unrolled")
    if flops is None or bytes_acc is None:
        return None                        # stale artifact — re-run dryrun
    coll = sum(rec["collective_bytes"].values())
    t_compute = flops / (chips * PEAK_FLOPS)
    t_memory = bytes_acc / (chips * HBM_BW)
    t_coll = coll / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    # MODEL_FLOPS: useful flops for the whole step, divided over chips
    n = cfg.active_params
    if shape.mode == "train":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6.0 * n * tokens
    elif shape.mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2.0 * n * tokens
    else:  # decode: one token per sequence
        model_flops = 2.0 * n * shape.global_batch
    ratio = model_flops / flops if flops else 0.0   # global / global
    return {
        "arch": arch, "shape": shape_name,
        "mesh": "x".join(map(str, rec["mesh"])),
        "chips": chips,
        "compute_s": t_compute, "memory_s": t_memory,
        "collective_s": t_coll, "dominant": dominant,
        "bound_s": max(terms.values()),
        "model_flops_ratio": ratio,
        "hbm_bytes_per_dev": rec.get("argument_size_in_bytes", 0)
        + rec.get("temp_size_in_bytes", 0),
        "zero_stage": rec.get("zero_stage"),
        "variant": rec.get("variant") or (
            "hpz" if rec.get("hierarchical_params") else "base"),
    }


def load_all(dryrun_dir: Path = DRYRUN_DIR) -> List[Dict]:
    out = []
    for fp in sorted(dryrun_dir.glob("*.json")):
        rec = json.loads(fp.read_text())
        t = roofline_terms(rec)
        if t is not None:
            out.append(t)
    return out


def run() -> List[str]:
    rows = []
    for t in load_all():
        name = (f"roofline/{t['arch']}/{t['shape']}/{t['mesh']}"
                + (f"/{t['variant']}" if t["variant"] != "base" else ""))
        rows.append(csv_row(
            name, t["bound_s"] * 1e6,
            f"compute={t['compute_s']*1e3:.2f}ms;"
            f"memory={t['memory_s']*1e3:.2f}ms;"
            f"collective={t['collective_s']*1e3:.2f}ms;"
            f"dominant={t['dominant']};"
            f"useful_flops_ratio={t['model_flops_ratio']:.3f}"))
    if not rows:
        rows.append(csv_row("roofline/missing", 0.0,
                            "run `python -m repro.launch.dryrun --all --both-meshes` first"))
    return rows


def markdown_table(terms: List[Dict]) -> str:
    hdr = ("| arch | shape | mesh | compute (ms) | memory (ms) | "
           "collective (ms) | dominant | 6ND/HLO |\n"
           "|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for t in terms:
        lines.append(
            f"| {t['arch']} | {t['shape']} | {t['mesh']} "
            f"| {t['compute_s']*1e3:.2f} | {t['memory_s']*1e3:.2f} "
            f"| {t['collective_s']*1e3:.2f} | **{t['dominant']}** "
            f"| {t['model_flops_ratio']:.3f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    print("\n".join(run()))
