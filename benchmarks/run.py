"""Benchmark driver — one section per paper table/figure + the roofline.

Prints ``name,us_per_call,derived`` CSV rows (see benchmarks/common.py).
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks import (fig3_main, fig4_models, fig5_quantity,
                            fig6_curves, fig7_spline, fig8_capability,
                            perf_variants, roofline, table2_overhead)
    sections = [
        ("fig3 (main: 3 clusters x ZeRO x 5 systems)", fig3_main.run),
        ("fig4 (models: llama 0.5B/1.1B, bert 1.1B)", fig4_models.run),
        ("fig5 (quantity heterogeneity)", fig5_quantity.run),
        ("fig6 (speed vs batch curves)", fig6_curves.run),
        ("fig7 (spline interpolation error)", fig7_spline.run),
        ("fig8 (walltime vs FLOPs capability)", fig8_capability.run),
        ("table2 (profiling overhead)", table2_overhead.run),
        ("roofline (dry-run derived)", roofline.run),
        ("perf (baseline vs optimized variants)", perf_variants.run),
    ]
    print("name,us_per_call,derived")
    for title, fn in sections:
        t0 = time.time()
        try:
            rows = fn()
        except Exception as e:  # noqa: BLE001
            print(f"bench/{title.split()[0]}/ERROR,0.0,{type(e).__name__}:{e}",
                  file=sys.stdout)
            continue
        for r in rows:
            print(r)
        print(f"# {title}: {len(rows)} rows in {time.time()-t0:.1f}s",
              file=sys.stderr)


if __name__ == "__main__":
    main()
