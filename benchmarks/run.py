"""Benchmark driver — one section per paper table/figure + the roofline.

Prints ``name,us_per_call,derived`` CSV rows (see benchmarks/common.py)
and writes a ``BENCH_<n>.json`` perf-trajectory artifact at the repo root
(next index after the existing artifacts), so successive PRs have a
machine-readable baseline: every perf row's step time plus the parsed
tokens/sec and exposed-comm bytes where a row reports them.
"""
from __future__ import annotations

import json
import re
import sys
import time
from datetime import datetime, timezone
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parents[1]


def _parse_derived(derived: str) -> dict:
    """Pull the trajectory-relevant numeric fields out of a row's derived
    k=v;k=v blob (best effort — rows are free-form)."""
    out = {}
    for key in ("ms", "tokens_per_sec", "exposed_comm_bytes",
                "hidden_comm_bytes", "kv_bytes_saved_per_step", "speedup",
                "replan_ms", "step_ms", "steps_equivalent",
                "packed_tokens_per_sec", "padded_tokens_per_sec",
                "pad_fraction_packed", "pad_fraction_padded",
                "async_stall_ms", "blocking_stall_ms", "recovery_ms",
                "recovery_steps_equivalent", "rearbitration_ms",
                "arbitration_search_ms", "arbitration_steps_equivalent",
                "utility_arbiter", "utility_even", "utility_delta",
                "engine_tokens_per_sec", "wave_tokens_per_sec",
                "ttft_p50_ms", "ttft_p95_ms", "tok_p50_ms", "tok_p95_ms",
                "wave_pad_waste", "preemptions",
                "chunked_tokens_per_sec",
                "prefill_calls_packed", "prefill_calls_chunked",
                "pack_fill_frac", "prefix_hit_tokens",
                "prefill_tokens_on", "prefill_tokens_off",
                "submitted_tokens", "ttft_p50_nocache_ms"):
        # anchor on a field boundary: the bare "ms" key must not match
        # inside "replan_ms=…" / "step_ms=…"
        m = re.search(rf"(?:^|;){key}=([-0-9.eE]+)x?(?:;|$)", derived)
        if m:
            try:
                out[key] = float(m.group(1))
            except ValueError:
                pass
    return out


def write_bench_artifact(rows_by_section: dict) -> Path:
    """Persist the perf rows as BENCH_<n>.json (n = next free index)."""
    taken = []
    for fp in REPO_ROOT.glob("BENCH_*.json"):
        m = re.fullmatch(r"BENCH_(\d+)\.json", fp.name)
        if m:
            taken.append(int(m.group(1)))
    n = max(taken) + 1 if taken else 0
    entries = []
    for section, rows in rows_by_section.items():
        for r in rows:
            name, us, derived = r.split(",", 2)
            entries.append({"section": section, "name": name,
                            "us_per_call": float(us), "derived": derived,
                            **_parse_derived(derived)})
    artifact = {
        "bench_index": n,
        "created": datetime.now(timezone.utc).isoformat(),
        "schema": "name/us_per_call/derived + parsed ms, tokens_per_sec, "
                  "exposed_comm_bytes, hidden_comm_bytes, "
                  "kv_bytes_saved_per_step, speedup",
        "rows": entries,
    }
    fp = REPO_ROOT / f"BENCH_{n}.json"
    fp.write_text(json.dumps(artifact, indent=1))
    return fp


def main() -> None:
    from benchmarks import (fig3_main, fig4_models, fig5_quantity,
                            fig6_curves, fig7_spline, fig8_capability,
                            perf_variants, roofline, table2_overhead)
    sections = [
        ("fig3 (main: 3 clusters x ZeRO x 5 systems)", fig3_main.run),
        ("fig4 (models: llama 0.5B/1.1B, bert 1.1B)", fig4_models.run),
        ("fig5 (quantity heterogeneity)", fig5_quantity.run),
        ("fig6 (speed vs batch curves)", fig6_curves.run),
        ("fig7 (spline interpolation error)", fig7_spline.run),
        ("fig8 (walltime vs FLOPs capability)", fig8_capability.run),
        ("table2 (profiling overhead)", table2_overhead.run),
        ("roofline (dry-run derived)", roofline.run),
        ("perf (baseline vs optimized variants)", perf_variants.run),
    ]
    print("name,us_per_call,derived")
    artifact_sections = {}
    for title, fn in sections:
        t0 = time.time()
        try:
            rows = fn()
        except Exception as e:  # noqa: BLE001
            print(f"bench/{title.split()[0]}/ERROR,0.0,{type(e).__name__}:{e}",
                  file=sys.stdout)
            continue
        for r in rows:
            print(r)
        if title.startswith(("perf", "roofline")):
            artifact_sections[title.split()[0]] = rows
        print(f"# {title}: {len(rows)} rows in {time.time()-t0:.1f}s",
              file=sys.stderr)
    if artifact_sections:
        fp = write_bench_artifact(artifact_sections)
        print(f"# perf-trajectory artifact: {fp}", file=sys.stderr)


if __name__ == "__main__":
    main()
