"""Table 2: Poplar's one-time profiling overhead — per device type x ZeRO
stage: number of model executions (Alg. 1 probes) and the simulated
wall-clock seconds those probes cost."""
from __future__ import annotations

from typing import List

from benchmarks.common import csv_row
from repro.configs import get_config
from repro.core.cluster import CATALOG
from repro.core.profiler import AnalyticalRunner, profile_device
from repro.core.workload import MemoryModel, train_flops_per_token


def run() -> List[str]:
    rows = []
    cfg = get_config("llama-0.5b")
    fps = train_flops_per_token(cfg, 4096) * 4096
    for dev in ("T4-16G", "V100-16G", "A800-80G"):
        for stage in (0, 1, 2, 3):
            spec = CATALOG[dev]
            mem = MemoryModel(cfg, 4096, stage, 8)
            r = AnalyticalRunner(spec, mem, fps, stage)
            prof = profile_device(r, dev, stage)
            probe_seconds = sum(r.compute_time(b) for b in prof.points)
            rows.append(csv_row(
                f"table2/{dev}/zero{stage}", probe_seconds * 1e6,
                f"probes={prof.probes};mbs={prof.mbs};"
                f"profile_s={probe_seconds:.1f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
