"""End-to-end driver: train a (reduced) model for a few hundred steps with
Poplar's heterogeneous batch allocation actually feeding the train loop.

This is the e2e deliverable: plan -> padded hetero layout -> masked
gradient-accumulation train steps -> checkpoint -> resume, all through
the Session API. The planner sees the same config that trains.

Run:  PYTHONPATH=src python examples/hetero_train.py [--steps 300]
"""
import argparse
import sys
import time
from dataclasses import replace
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.api import Session
from repro.configs import get_config
from repro.core.cluster import cluster_B


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--gbs", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    args = ap.parse_args()

    # ~100M-class config: the reduced llama with a few more layers
    cfg = replace(get_config("llama-0.5b", reduced=True),
                  n_layers=4, d_model=512, n_heads=8, n_kv_heads=8,
                  d_ff=1408, vocab_size=2048)
    print(f"params ~{cfg.total_params/1e6:.0f}M")

    sess = Session.build(cfg, cluster_B(), gbs=args.gbs, seq=args.seq,
                         zero=1, lr=1e-3)
    d = sess.describe()
    print("poplar allocation:",
          {n: a["gmbs"] for n, a in d["plan"]["assignments"].items()})

    t0 = time.time()
    first = last = None
    for step in range(args.steps):
        loss = float(sess.step()["loss"])
        if first is None:
            first = loss
        last = loss
        if step % 25 == 0:
            print(f"step {step:4d} loss {loss:.4f}")
    print(f"loss {first:.3f} -> {last:.3f} over {args.steps} steps "
          f"({time.time()-t0:.0f}s)")
    fn = sess.save(args.ckpt)
    print("checkpoint:", fn)
    # custom cfg is not in the registry -> pass it explicitly on restore
    resumed = Session.restore(args.ckpt, cfg=cfg)
    print(f"restored step {int(resumed.state.step)} OK")


if __name__ == "__main__":
    main()
