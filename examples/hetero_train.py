"""End-to-end driver: train a (reduced) model for a few hundred steps with
Poplar's heterogeneous batch allocation actually feeding the train loop.

This is the e2e deliverable: plan -> padded hetero layout -> masked
gradient-accumulation train steps -> checkpoint. Uses the real ZeRO train
step (pjit + sharding rules) on the locally available devices.

Run:  PYTHONPATH=src python examples/hetero_train.py [--steps 300]
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.core.cluster import cluster_B
from repro.core.hetero import layout_from_plan
from repro.core.planner import plan as poplar_plan
from repro.core.sharding import MeshRules
from repro.core.zero import make_train_step, model_shardings, register_axes
from repro.data.pipeline import HeteroDataLoader, SyntheticTokens
from repro.launch.mesh import data_axis_size, make_debug_mesh
from repro.models import model as mm
from repro.optim.adamw import adamw_init


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--gbs", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    args = ap.parse_args()

    # ~100M-class config: the reduced llama with a few more layers
    from dataclasses import replace
    cfg = replace(get_config("llama-0.5b", reduced=True),
                  n_layers=4, d_model=512, n_heads=8, n_kv_heads=8,
                  d_ff=1408, vocab_size=2048)
    print(f"params ~{cfg.total_params/1e6:.0f}M")

    pplan = poplar_plan(cluster_B(), get_config("llama-0.5b"), args.gbs,
                        seq_len=4096, zero_stage=1)
    print("poplar allocation:",
          {n: a.gmbs for n, a in pplan.allocation.assignments.items()})

    mesh = make_debug_mesh(jax.device_count())
    layout = layout_from_plan(pplan.allocation,
                              group_multiple=data_axis_size(mesh))
    loader = HeteroDataLoader(SyntheticTokens(cfg.vocab_size, args.seq, 1),
                              layout, args.seq)
    rules = MeshRules(mesh, zero_stage=1)
    params, axes = mm.init_model(jax.random.PRNGKey(0), cfg)
    register_axes(rules, axes)
    p_specs, o_specs, _ = model_shardings(rules, params, axes)
    opt = adamw_init(params)
    with mesh:
        params = jax.device_put(params, jax.tree.map(rules.sharding, p_specs))
        opt = jax.device_put(opt, jax.tree.map(rules.sharding, o_specs))
        step_fn = jax.jit(make_train_step(cfg, rules, lr=1e-3,
                                          accum_steps=layout.gas))
        t0 = time.time()
        first = last = None
        for step in range(args.steps):
            batch = {k: jnp.asarray(v) for k, v in loader.next_batch().items()}
            if layout.gas == 1:
                batch = {k: v[0] for k, v in batch.items()}
            params, opt, met = step_fn(params, opt, batch)
            loss = float(met["loss"])
            if first is None:
                first = loss
            last = loss
            if step % 25 == 0:
                print(f"step {step:4d} loss {loss:.4f}")
        print(f"loss {first:.3f} -> {last:.3f} over {args.steps} steps "
              f"({time.time()-t0:.0f}s)")
    fn = save_checkpoint(args.ckpt, args.steps, params, opt)
    print("checkpoint:", fn)
    step, p2, o2 = restore_checkpoint(args.ckpt, None, params, opt)
    print(f"restored step {step} OK")


if __name__ == "__main__":
    main()
