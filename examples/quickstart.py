"""Quickstart: Poplar's fully-automated parallelism in five lines.

One `Session.build` call runs the whole paper pipeline — online
profiling (Alg. 1), spline fitting + batch allocation (Alg. 2), ZeRO
stage selection, mesh + sharding rules, hetero data layout — and hands
back a jitted train step. `describe()` is the plan; `step()` trains.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.api import Session
from repro.configs import get_config
from repro.core.cluster import cluster_B


def main():
    # --- the whole pipeline, one call -----------------------------------
    sess = Session.build(get_config("llama-0.5b", reduced=True), cluster_B(),
                         gbs=8, seq=32, lr=1e-3)
    for _ in range(3):
        metrics = sess.step()
    # --------------------------------------------------------------------

    d = sess.describe()
    print(f"model: {sess.cfg.name} ({sess.cfg.total_params/1e6:.1f}M params) "
          f"cluster: B  gbs={d['gbs']} x seq={d['seq']}")
    print(f"plan: ZeRO-{d['zero_stage']} "
          f"probes={d['plan']['profiling_probes']} "
          f"predicted util={d['plan']['predicted']['utilization']:.3f} "
          f"({d['plan']['plan_seconds']:.2f}s planning, "
          f"{d['build_seconds']:.2f}s build)")
    for name, a in d["plan"]["assignments"].items():
        print(f"  {name:12s} gmbs={a['gmbs']:3d} micro={a['micro_batch']:3d} "
              f"gas={a['gas']} lbs={a['lbs']}")
    print(f"after 3 steps: loss={float(metrics['loss']):.4f} "
          f"step={int(sess.state.step)}")
    assert int(sess.state.step) == 3
    print("QUICKSTART_OK")


if __name__ == "__main__":
    main()
