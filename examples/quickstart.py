"""Quickstart: Poplar's fully-automated heterogeneous training config.

Runs the whole paper pipeline in one page:
  1. describe a heterogeneous cluster (2x V100 + 2x T4 — the paper's
     cluster B);
  2. online profiling (Algorithm 1): per-device max batch size + speed
     curves, zero manual tuning;
  3. offline analysis (Algorithm 2): spline fit + optimal batch allocation;
  4. compare against DeepSpeed-uniform and Whale-FLOPs baselines in the
     BSP simulator.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.configs import get_config
from repro.core.allocation import (allocate_flops_proportional,
                                   allocate_uniform)
from repro.core.cluster import CATALOG, cluster_B
from repro.core.planner import plan
from repro.core.simulator import simulate_plan
from repro.core.workload import train_flops_per_token


def main():
    cfg = get_config("llama-0.5b")
    cluster = cluster_B()
    gbs, seq = 512, 4096

    print(f"model: {cfg.name} ({cfg.total_params/1e9:.2f}B params)")
    print(f"cluster: {cluster.counts()}  gbs={gbs} x seq={seq}")
    print()

    for stage in (0, 3):
        p = plan(cluster, cfg, gbs, seq, zero_stage=stage)
        print(f"=== ZeRO-{stage} ===")
        print(f"profiling probes: {p.profiling_probes} "
              f"(Alg.1: exponential + binary mbs search per device)")
        for name, a in p.allocation.assignments.items():
            curve = p.curves[name]
            print(f"  {name:12s} mbs={curve.mbs:4d} "
                  f"peak@b={curve.peak_batch:6.1f} -> "
                  f"gmbs={a.gmbs:4d} micro={a.micro_batch:3d} "
                  f"gas={a.gas} lbs={a.lbs}")
        fps = train_flops_per_token(cfg, seq) * seq
        base_u = allocate_uniform(p.curves, gbs, stage)
        rating = {n: CATALOG[n.split("#")[0]].peak_tflops for n in p.curves}
        base_w = allocate_flops_proportional(p.curves, gbs, stage, rating)
        for label, alloc in [("poplar", p.allocation),
                             ("deepspeed-uniform", base_u),
                             ("whale-flops", base_w)]:
            alloc.zero_stage = stage
            r = simulate_plan(alloc, p.curves, cfg, seq, cluster, fps)
            print(f"  {label:18s} {r.cluster_tflops:7.1f} TFLOPs  "
                  f"util={r.utilization:.3f}  iter={r.iter_time:.2f}s")
        print()


if __name__ == "__main__":
    main()
