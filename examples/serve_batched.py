"""Batched serving example: prefill a batch of prompts, then decode tokens
with the KV-cache/recurrent-state serve path — on a dense GQA model and on
the attention-free xLSTM (same API, constant-size state), through a
serve-mode Session (jitted decode step, no hand wiring).

Run:  PYTHONPATH=src python examples/serve_batched.py
"""
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import Session
from repro.configs import get_config


def serve(arch: str, batch: int = 4, prompt_len: int = 16,
          gen_tokens: int = 24):
    cfg = get_config(arch, reduced=True)
    sess = Session.build(cfg, mode="serve")
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(3, cfg.vocab_size, (batch, prompt_len)), jnp.int32)

    state = sess.init_decode_state(batch, prompt_len + gen_tokens)

    # prefill by stepping the prompt through the decode path (populates the
    # KV cache / recurrent state token by token)
    t0 = time.time()
    logits = None
    for t in range(prompt_len):
        logits, state = sess.decode(prompts[:, t:t + 1], state)
    prefill_s = time.time() - t0

    # greedy decode
    out_tokens = []
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    t0 = time.time()
    for _ in range(gen_tokens):
        out_tokens.append(np.asarray(tok)[:, 0])
        logits, state = sess.decode(tok, state)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    jax.block_until_ready(logits)
    decode_s = time.time() - t0

    gen = np.stack(out_tokens, axis=1)
    print(f"{arch:24s} batch={batch} prompt={prompt_len} gen={gen_tokens} "
          f"prefill {prefill_s*1e3:6.1f}ms  decode "
          f"{decode_s/gen_tokens*1e3:6.2f}ms/tok  "
          f"first tokens: {gen[0][:8].tolist()}")


def main():
    for arch in ("starcoder2-15b", "granite-moe-1b-a400m", "xlstm-1.3b",
                 "zamba2-2.7b"):
        serve(arch)


if __name__ == "__main__":
    main()
