"""One-off: inject unrolled cost-pass numbers into existing dry-run artifacts.

The pod1/pod2 artifacts were generated before the unrolled cost pass
landed; their collective/memory numbers are still valid (model default
path unchanged — verified by re-running one combo), but `flops` came from
the compiled SPMD cost_analysis() which counts scan bodies once. This
script recomputes global algorithmic FLOPs/bytes per (arch, shape) and
rewrites every artifact with the new field layout.
"""
import json, sys, time
from pathlib import Path
from repro.launch.dryrun import cost_pass

DRY = Path("experiments/dryrun")
combos = {}
for fp in sorted(DRY.glob("*.json")):
    rec = json.loads(fp.read_text())
    if "skipped" in rec or "error" in rec:
        continue
    combos.setdefault((rec["arch"], rec["shape"]), []).append(fp)

for (arch, shape), fps in combos.items():
    t0 = time.time()
    try:
        out = cost_pass(arch, shape)
    except Exception as e:
        print(f"FAIL {arch}/{shape}: {type(e).__name__}: {e}", flush=True)
        continue
    for fp in fps:
        rec = json.loads(fp.read_text())
        rec["flops_unrolled"] = out["flops_unrolled"]
        rec["bytes_unrolled"] = out["bytes_unrolled"]
        if "flops" in rec:
            rec["flops_per_device_compiled"] = rec.pop("flops")
        if "bytes_accessed" in rec:
            rec["bytes_per_device_compiled"] = rec.pop("bytes_accessed")
        fp.write_text(json.dumps(rec, indent=2, default=str))
    print(f"{arch}/{shape}: flops={out['flops_unrolled']:.3e} "
          f"bytes={out['bytes_unrolled']:.3e} ({time.time()-t0:.1f}s) "
          f"-> {len(fps)} files", flush=True)
print("done")
