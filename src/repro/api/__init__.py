"""Public facade: one-call automated parallelism (see README.md here).

    from repro.api import Session
    sess = Session.build(cfg, cluster, gbs=64, seq=128)
    metrics = sess.step()

`Session.build` subsumes the historical plan → mesh → layout → rules →
init → register_axes → shardings → device_put → make_*_step → jit
ceremony; `build_step` is the unified step constructor underneath it and
`TrainState` the state pytree that carries the logical axes in-state.

The fault-tolerance surface rides on the same facade: `Session.save`
grows async + retention modes, `Supervisor` wraps the step loop with
retry / re-plan / restore recovery, and `FaultSchedule` scripts
deterministic fault plans for tests and benchmarks.

Multi-tenancy sits one layer above: `ClusterArbiter` owns the physical
cluster and leases disjoint device subsets to registered tenants (each a
Session + FaultPolicy + priority floor), re-arbitrating globally on
fault/drift via `TenantSupervisor` (see README.md §multi-tenant).
"""
from repro.api.session import Session
from repro.core.arbiter import (ClusterArbiter, Tenant, TenantSupervisor,
                                TenantSuspended)
from repro.api.state import (StaticAxes, TrainState, host_train_state,
                             new_train_state)
from repro.api.steps import ProbeHarness, build_step, step_io
from repro.checkpoint import AsyncCheckpointWriter, PendingSave, SimulatedCrash
from repro.core.faults import (DeviceLossError, FaultPolicy, FaultSchedule,
                               FaultToleranceExhausted, Supervisor,
                               TransientStepError, classify_fault,
                               drop_devices)
from repro.core.telemetry import (ArbitrationReport, DeviceTimers,
                                  DriftConfig, DriftReport, EMAWindow,
                                  EventLog, FaultEvent, ReplanReport)

__all__ = ["Session", "TrainState", "StaticAxes", "new_train_state",
           "host_train_state", "build_step", "step_io", "ProbeHarness",
           "DriftConfig", "DriftReport", "EMAWindow", "ReplanReport",
           "DeviceTimers", "EventLog", "FaultEvent",
           "FaultSchedule", "FaultPolicy", "Supervisor", "classify_fault",
           "drop_devices", "DeviceLossError", "TransientStepError",
           "FaultToleranceExhausted",
           "AsyncCheckpointWriter", "PendingSave", "SimulatedCrash",
           "ClusterArbiter", "Tenant", "TenantSupervisor",
           "TenantSuspended", "ArbitrationReport"]
