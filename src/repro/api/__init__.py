"""Public facade: one-call automated parallelism (see README.md here).

    from repro.api import Session
    sess = Session.build(cfg, cluster, gbs=64, seq=128)
    metrics = sess.step()

`Session.build` subsumes the historical plan → mesh → layout → rules →
init → register_axes → shardings → device_put → make_*_step → jit
ceremony; `build_step` is the unified step constructor underneath it and
`TrainState` the state pytree that carries the logical axes in-state.
"""
from repro.api.session import Session
from repro.api.state import StaticAxes, TrainState, new_train_state
from repro.api.steps import build_step, step_io

__all__ = ["Session", "TrainState", "StaticAxes", "new_train_state",
           "build_step", "step_io"]
