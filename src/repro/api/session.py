"""Session — one-call automated parallelism, plan to jitted step.

Poplar's claim is that the user supplies a model and a cluster and the
system finds the configuration. :class:`Session` is that claim as an
API: ``Session.build(cfg, cluster, gbs=..., seq=...)`` runs the Poplar
planner (profiling → spline fit → batch allocation → stage selection),
constructs the mesh + :class:`MeshRules`, initializes and shards a
:class:`TrainState` (axes carried in-state — no ``register_axes`` side
channel), and jits the unified step. Everything the old ten-step
ceremony hand-wired is one constructor:

    sess = Session.build(get_config("llama-0.5b"), cluster_B(),
                         gbs=64, seq=128)
    for _ in range(steps):
        metrics = sess.step()            # loader-fed hetero batch
    sess.save("/tmp/ckpt")               # ... later:
    sess = Session.restore("/tmp/ckpt")  # resumes params/opt/step

``cluster=None`` skips the planner for callers that pin their own mesh
and stage (tests, benchmarks): a uniform single-group batch layout
replaces the hetero allocation.

Modes: ``"train"`` (loader/step/save/restore), ``"serve"`` (jitted
prefill/decode over the shared state), ``"dryrun"`` (abstract
eval_shape state; ``session.lower()`` for memory/cost analysis without
allocating a byte).

The lifecycle is *elastic* — plan → execute → observe → re-plan:

- ``profile="measured"`` feeds the allocation search real jitted-step
  wall times (Algorithm 1 over :class:`ProbeHarness` +
  ``MeasuredRunner``) instead of analytical ``DeviceSpec`` curves;
- every ``step()`` records wall time into a telemetry EMA;
  ``session.drift()`` compares it against ``plan.predicted`` and
  ``session.maybe_replan()`` re-plans when reality left the band;
- ``session.replan(cluster=...)`` handles membership changes (device
  added/removed): it re-runs the planner, rebuilds mesh + rules +
  layout, and *reshards the live TrainState onto the new mesh* without
  restarting the process (the loader re-splits in place);
- ``Session.restore(path, cluster=...)`` reshards a checkpoint across
  meshes — an 8-device stage-3 checkpoint restores onto a 4-device
  layout bit-identically (checkpoints store gathered full arrays).
"""
from __future__ import annotations

import json
import math
import time
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.api import steps as _steps
from repro.api.state import TrainState, host_train_state, new_train_state
from repro.checkpoint import (AsyncCheckpointWriter, restore_checkpoint,
                              save_checkpoint)
from repro.configs.base import ModelConfig, get_config
from repro.core import cluster as CL
from repro.core.hetero import HeteroBatchLayout, layout_from_plan
from repro.core.sharding import MeshRules
from repro.core.telemetry import (DeviceTimers, DriftConfig, DriftReport,
                                  EMAWindow, EventLog, ReplanReport,
                                  detect_drift)
from repro.core.zero import model_shardings
from repro.launch.mesh import data_axis_size, make_debug_mesh
from repro.models import model as mm
from repro.optim.adamw import AdamWConfig, adamw_init

MODES = ("train", "serve", "dryrun")
PROFILES = ("analytical", "measured")

# exponential-probe ceiling for measured profiling: every probed batch
# size costs a real jit compile, so the default search is bounded (the
# analytical runners keep the uncapped search)
MEASURED_PROBE_CAP = 16


def _uniform_layout(gbs: int, accum: int, group_multiple: int
                    ) -> HeteroBatchLayout:
    """Single-group layout for unplanned (cluster=None) sessions: ``gbs``
    real rows per micro-step, padded to the data-axis multiple."""
    pad = max(int(math.ceil(gbs / max(group_multiple, 1))) * group_multiple,
              group_multiple, 1)
    return HeteroBatchLayout(["local"], [gbs], pad, max(accum, 1), [gbs])


def _cluster_meta(cluster) -> Optional[Dict]:
    if cluster is None:
        return None
    comp = []
    for d in cluster.devices:
        if comp and comp[-1][0] == d.name:
            comp[-1][1] += 1
        else:
            comp.append([d.name, 1])
    return {"name": cluster.name, "composition": comp,
            "inter_link_gbps": cluster.inter_link_gbps,
            "shared_bus": cluster.shared_bus}


def _cluster_from_meta(meta: Optional[Dict]):
    if meta is None:
        return None
    return CL.make_cluster(meta["name"],
                           [tuple(c) for c in meta["composition"]],
                           meta["inter_link_gbps"],
                           shared_bus=meta.get("shared_bus", True))


class Session:
    """Facade over planner + mesh + shardings + state + jitted step.

    Construct with :meth:`build` (or :meth:`restore`); the plain
    constructor is internal.
    """

    def __init__(self):
        self.cfg: ModelConfig = None
        self.cluster = None
        self.mode = "train"
        self.mesh = None
        self.rules: MeshRules = None
        self.plan = None                  # PoplarPlan | None
        self.layout: HeteroBatchLayout = None
        self.state: TrainState = None
        self.impl = "reference"           # resolved
        self.accum_steps = 1
        self.lr = 3e-4
        self.adamw_cfg = AdamWConfig()
        self.window = None
        self.gbs = 0
        self.seq = 0
        self.seed = 0
        self.data = None
        self.profile = "analytical"
        self.probe_cap = None
        self.packing = False
        # effective-token statistics of the packed stream (a
        # workload.PackedWorkload), priced by the planner; None = padded
        self._packed = None
        # measured DeviceProfiles keyed by runner.cache_key — persists
        # across replans so an unchanged workload skips Algorithm 1
        self._profile_cache: Dict[Any, Any] = {}
        # calibrated scheduled-overlap factor (one-shot measured probe;
        # None = not yet probed, falls back to the analytical default)
        self._overlap_factor: Optional[float] = None
        self.build_seconds = 0.0
        self.plan_seconds = 0.0
        self.telemetry = EMAWindow()
        self.drift_config = DriftConfig()
        # per-device step-time EMAs feeding DriftReport.observed_imbalance
        # (fed by _device_step_times — a proxy under single-process SPMD)
        self.device_timers = DeviceTimers()
        # pluggable per-device time source: fn(session, wall_dt) -> {dev: s}.
        # None = the predicted-busy-share proxy. A multi-host deployment
        # would install real per-host wall times here.
        self.device_time_provider = None
        # fault/recovery/checkpoint transition log, shared with the
        # Supervisor and any AsyncCheckpointWriter this session creates
        self.events = EventLog()
        # deterministic fault plan (core.faults.FaultSchedule) — None
        # means no injection anywhere on the hot path
        self._fault_schedule = None
        # one async writer per checkpoint directory, created lazily
        self._writers: Dict[str, AsyncCheckpointWriter] = {}
        self.replans = 0
        self.last_replan: Optional[ReplanReport] = None
        # substrate calibration for drift detection: observed/predicted
        # ratio recorded as nominal once enough steps are in (None until
        # then; reset by replan — a new plan gets a new baseline)
        self._drift_baseline: Optional[float] = None
        self._observe_tick = 0
        self._zero_request: Optional[int] = None
        self._plan_seq: Optional[int] = None
        self._jit_step = None
        self._jit_step_raw = None         # the jitted fn before injection
        self._prefill = None
        self._decode = None
        self._prefill_raw = None          # serve fns before fault injection
        self._decode_raw = None
        # serve-mode fault-schedule clock: decode calls have no state.step,
        # so each decode consumes one tick for FaultSchedule matching
        self._serve_tick = 0
        # multi-tenant surface (core/arbiter.py): the lease this session
        # currently runs under, and whether the arbiter suspended it
        self.lease = None
        self._suspended = False
        self._loader = None
        self._p_shardings = None
        self._o_shardings = None
        self._meta: Dict[str, Any] = {}

    # ------------------------------------------------------------ build --
    @classmethod
    def build(cls, cfg, cluster=None, *, gbs: int = 32, seq: int = 128,
              mode: str = "train", zero: Optional[int] = None,
              impl: str = "auto", overlap: str = "auto",
              comm_dtype: Optional[str] = None, lr: float = 3e-4,
              adamw_cfg: Optional[AdamWConfig] = None,
              window: Optional[int] = None,
              accum_steps: Optional[int] = None,
              mesh=None, seed: int = 0, data: Optional[str] = None,
              overlap_prefetch: bool = True,
              plan_seq: Optional[int] = None,
              profile: str = "analytical",
              probe_cap: Optional[int] = None,
              packing: bool = False,
              drift: Optional[DriftConfig] = None) -> "Session":
        """One call from (model, cluster) to a jitted, sharded step.

        ``cfg`` — a ModelConfig or a registered arch name. ``cluster`` —
        a ClusterSpec to plan against, or None to skip the planner (then
        ``zero`` defaults to 3 and ``accum_steps`` to 1). The planner is
        fed *this* cfg and sequence length — the configuration that
        trains is the configuration that plans (``plan_seq`` overrides
        the planning seq_len only, for CPU demos that train short).

        ``profile`` — where Algorithm 1's timings come from:
        ``"analytical"`` simulates the cluster's published DeviceSpec
        curves; ``"measured"`` times the *real* jitted step per device
        kind (exponential+binary probing over a ProbeHarness with the
        compile-time memory_analysis OOM oracle) so the allocation search
        runs on observed TimeConsumedDuringStep. ``probe_cap`` bounds the
        measured probe's batch sweep (default MEASURED_PROBE_CAP; each
        probed batch size costs one jit compile).

        ``packing=True`` makes the whole hot path padding-free: the
        loader packs mixed-length documents first-fit-decreasing into
        the batch rows (``segment_ids``/``positions``/token-level loss
        masks ride through the hetero layout), the segment-aware
        attention kernels skip cross-segment blocks, the loss normalizer
        counts real tokens only, and the planner prices the *effective*
        (non-pad) workload — one flag, end to end. Requires a document
        source; without ``data=`` a synthetic
        :class:`~repro.data.pipeline.MixedLengthDocs` stream is used.
        """
        if mode not in MODES:
            raise ValueError(f"mode={mode!r}; expected one of {MODES}")
        if profile not in PROFILES:
            raise ValueError(
                f"profile={profile!r}; expected one of {PROFILES}")
        t0 = time.time()
        self = cls()
        if isinstance(cfg, str):
            cfg = get_config(cfg)
        self.cluster = cluster
        self.mode = mode
        self.lr = lr
        self.adamw_cfg = AdamWConfig() if adamw_cfg is None else adamw_cfg
        self.window = window
        self.gbs, self.seq, self.seed, self.data = gbs, seq, seed, data
        self.profile, self.probe_cap = profile, probe_cap
        self.packing = bool(packing)
        self._zero_request, self._plan_seq = zero, plan_seq
        if drift is not None:
            self.drift_config = drift
        # recipe fingerprint of the cfg *as handed in* — a data= corpus may
        # widen the vocab below, and restore() must be able to match the
        # registry config before re-deriving that widening
        input_arch, input_params = cfg.name, int(cfg.total_params)

        # data source first: a text corpus can widen the vocab, and the
        # planner must see the cfg that actually trains
        self._source = None
        if mode == "train":
            from dataclasses import replace
            from repro.data.pipeline import (HeteroDataLoader,
                                             MixedLengthDocs,
                                             SyntheticTokens, TextFileTokens,
                                             pack_documents)
            if self.packing:
                if data:
                    raise ValueError(
                        "packing=True needs a document source; data= "
                        "corpora are contiguous byte streams with no "
                        "document boundaries")
                src = MixedLengthDocs(cfg.vocab_size, seq, seed=seed)
                # pre-pack one probe batch: its PackingStats describe the
                # stream (pad fraction, mean segment length) for the
                # planner's effective-token pricing
                from repro.core.workload import PackedWorkload
                rows = max(gbs, 1)
                budget = max(1, int(round(
                    rows * seq * HeteroDataLoader.PACK_OVERDRAW
                    / src.mean_doc_len)))
                _, stats = pack_documents(src.documents(budget, 0), rows, seq)
                self._packed = PackedWorkload.from_stats(stats)
            elif data:
                src = TextFileTokens(data, seq, seed=seed)
                cfg = replace(cfg, vocab_size=max(cfg.vocab_size,
                                                  src.vocab_size))
            else:
                src = SyntheticTokens(cfg.vocab_size, seq, seed=seed)
            self._source = src
        self.cfg = cfg

        self.impl = _steps.resolve_impl(impl)

        # ---- Poplar: fully automated configuration ----
        if cluster is not None and mode != "serve":
            tp = time.time()
            self.plan = self._run_planner(cluster, overlap)
            self.plan_seconds = time.time() - tp
            stage = self.plan.zero_stage
        else:
            stage = (0 if mode == "serve" else 3) if zero is None else zero

        self.mesh = mesh if mesh is not None else self._default_mesh(cluster)
        if self.plan is not None:
            self.layout = layout_from_plan(
                self.plan.allocation, group_multiple=data_axis_size(self.mesh))
            self.accum_steps = self.layout.gas
        else:
            self.accum_steps = accum_steps or 1
            self.layout = _uniform_layout(gbs, self.accum_steps,
                                          data_axis_size(self.mesh))
        self.rules = MeshRules(self.mesh, zero_stage=stage, overlap=overlap,
                               comm_dtype=comm_dtype,
                               overlap_prefetch=overlap_prefetch)

        # ---- state: init, shard, wrap (axes ride in the pytree) ----
        if mode == "dryrun":
            box = {}

            def init_values(key):
                p, a = mm.init_model(key, cfg)
                box["axes"] = a
                return p

            p_tree = jax.eval_shape(init_values, jax.random.PRNGKey(seed))
            axes = box["axes"]
            opt = jax.eval_shape(adamw_init, p_tree)
            self.state = TrainState(p_tree, opt,
                                    jax.ShapeDtypeStruct((), jnp.int32), axes)
            self._derive_shardings()
        else:
            params, axes = mm.init_model(jax.random.PRNGKey(seed), cfg)
            opt = adamw_init(params) if mode == "train" else None
            self.state = new_train_state(params, axes, opt)
            self._derive_shardings()
            with self.mesh:
                self.state = jax.device_put(self.state,
                                            self._state_shardings())
            self._build_step_fns()

        from dataclasses import asdict
        self._meta = {
            "arch": input_arch, "total_params": input_params,
            "cluster": _cluster_meta(cluster), "gbs": gbs, "seq": seq,
            "mode": mode, "zero": stage, "impl": impl, "overlap": overlap,
            "comm_dtype": comm_dtype, "lr": lr, "window": window,
            "adamw": asdict(self.adamw_cfg),
            "accum_steps": accum_steps, "seed": seed, "data": data,
            "overlap_prefetch": overlap_prefetch, "plan_seq": plan_seq,
            "profile": profile, "probe_cap": probe_cap,
            "packing": self.packing,
        }
        self.build_seconds = time.time() - t0
        return self

    # ------------------------------------------------ planner substrate --
    def _default_mesh(self, cluster):
        """The local simulation mesh: one mesh slot per planned device,
        bounded by what the host actually has (on a real fleet the mesh
        spans the cluster; on this container XLA host devices stand in)."""
        n = jax.device_count()
        if cluster is not None:
            n = min(cluster.n, n)
        return make_debug_mesh(n)

    def _run_planner(self, cluster, overlap: str, *,
                     gbs: Optional[int] = None,
                     profile: Optional[str] = None):
        """One planner invocation honouring the session's profile mode —
        shared by :meth:`build` and :meth:`replan` (which passes its
        tentative overrides explicitly so nothing is committed to the
        session until the plan exists)."""
        from repro.core.overlap import SCHEDULED_OVERLAP_FACTOR
        from repro.core.planner import plan as poplar_plan
        gbs = self.gbs if gbs is None else gbs
        profile = self.profile if profile is None else profile
        factory = None
        probe_cap = self.probe_cap
        if profile == "measured":
            factory = self._measured_runner_factory(cluster)
            probe_cap = probe_cap or MEASURED_PROBE_CAP
        overlap_factor = 0.0
        if overlap != "xla":
            # measured sessions calibrate the hidden-comm fraction from a
            # one-shot auto-vs-scheduled probe; otherwise the analytical
            # default (core/overlap.py) applies
            overlap_factor = (self._calibrated_overlap(cluster)
                              if profile == "measured"
                              else SCHEDULED_OVERLAP_FACTOR)
        return poplar_plan(cluster, self.cfg, gbs,
                           seq_len=self._plan_seq or self.seq,
                           zero_stage=self._zero_request,
                           overlap_factor=overlap_factor,
                           runner_factory=factory,
                           probe_cap=probe_cap,
                           packed=self._packed,
                           profile_cache=self._profile_cache)

    def _measured_runner_factory(self, cluster):
        """Per-stage MeasuredRunner constructor for ``planner.plan``'s
        ``runner_factory`` hook: all devices of a stage share one
        :class:`ProbeHarness` (this host is the measurement substrate —
        the real jitted step is what gets timed), each device kind keeps
        its own memory capacity, and ``dedupe_key`` collapses Algorithm 1
        to one run per (spec, stage)."""
        from repro.core.profiler import MeasuredRunner

        # persistent workload identity for the cross-replan profile
        # cache: same (cfg, seq, impl, packing) on the same device kind
        # and stage times out identically, so the cached curve is valid
        wl = (self.cfg.name, int(self.cfg.total_params),
              self._plan_seq or self.seq, self.impl,
              self.window, bool(self._packed))

        def factory(stage: int):
            harness = _steps.ProbeHarness(
                self.cfg, seq_len=self._plan_seq or self.seq,
                zero_stage=stage, n_workers=cluster.n, impl=self.impl,
                window=self.window, lr=self.lr, adamw_cfg=self.adamw_cfg,
                seed=self.seed, packed=self._packed)
            runners, counts = {}, {}
            for spec in cluster.devices:
                counts[spec.name] = counts.get(spec.name, 0) + 1
                name = f"{spec.name}#{counts[spec.name]}"
                runners[name] = MeasuredRunner(
                    step_fn=harness.step,
                    memory_bytes_fn=harness.memory_bytes,
                    capacity_bytes=spec.mem_gb * 1e9,
                    dedupe_key=(spec.name, stage),
                    cache_key=wl + (stage, spec.name))
            return runners
        return factory

    def _calibrated_overlap(self, cluster) -> float:
        """Hidden-comm fraction for the allocation sweep, measured once
        per session: time one XLA-auto step and one scheduled step (same
        stage-3 workload, one row per device) plus a single-device step
        as the comm-free compute reference, then solve
        ``f = (t_auto - t_sched) / (t_auto - t_compute)``. Falls back to
        the analytical default on single-device meshes or when the probe
        is degenerate (core/overlap.calibrate_overlap_factor)."""
        from repro.core.overlap import (SCHEDULED_OVERLAP_FACTOR,
                                        calibrate_overlap_factor)
        if self._overlap_factor is not None:
            return self._overlap_factor
        factor = SCHEDULED_OVERLAP_FACTOR
        try:
            mesh = self._default_mesh(cluster)
            n = int(mesh.devices.size)
            if n > 1:
                t_auto = self._overlap_probe_time(mesh, "xla", n)
                t_sched = self._overlap_probe_time(mesh, "scheduled", n)
                t_comp = self._overlap_probe_time(make_debug_mesh(1),
                                                  "xla", 1)
                factor = calibrate_overlap_factor(t_auto, t_sched,
                                                  t_auto - t_comp)
        except Exception:  # noqa: BLE001 — probe failure must not block planning
            factor = SCHEDULED_OVERLAP_FACTOR
        self._overlap_factor = factor
        return factor

    def _overlap_probe_time(self, mesh, overlap_mode: str,
                            rows: int) -> float:
        """Median wall time of one jitted stage-3 train step (``rows``
        one per mesh device) under the given overlap mode."""
        import numpy as np
        rules = MeshRules(mesh, zero_stage=3, overlap=overlap_mode)
        params, axes = mm.init_model(jax.random.PRNGKey(self.seed), self.cfg)
        opt = adamw_init(params)
        fn = _steps.build_step(self.cfg, rules, axes, kind="train",
                               adamw_cfg=self.adamw_cfg, lr=self.lr,
                               window=self.window, impl=self.impl)
        S = self._plan_seq or self.seq
        rng = np.random.default_rng(self.seed)
        toks = jnp.asarray(rng.integers(3, self.cfg.vocab_size, (rows, S)),
                           jnp.int32)
        batch = {"tokens": toks, "labels": toks,
                 "loss_mask": jnp.ones((rows, S), jnp.float32)}
        with mesh:
            step = jax.jit(fn)
            jax.block_until_ready(step(params, opt, batch))  # compile
            ts = []
            for _ in range(2):
                t0 = time.perf_counter()
                jax.block_until_ready(step(params, opt, batch))
                ts.append(time.perf_counter() - t0)
        return float(np.median(ts))

    def _derive_shardings(self):
        p_specs, o_specs, _ = model_shardings(self.rules, self.state.params,
                                              self.state.axes)
        self._p_shardings = jax.tree.map(self.rules.sharding, p_specs)
        self._o_shardings = (jax.tree.map(self.rules.sharding, o_specs)
                             if self.state.opt is not None else None)

    def _state_shardings(self) -> TrainState:
        from jax.sharding import PartitionSpec as P
        return TrainState(self._p_shardings, self._o_shardings,
                          self.rules.sharding(P()), self.state.axes)

    def _build_step_fns(self):
        cfg, rules = self.cfg, self.rules

        if self.mode == "train":
            def state_step(state: TrainState, batch):
                raw = _steps.build_step(
                    cfg, rules, state.axes, kind="train",
                    adamw_cfg=self.adamw_cfg, lr=self.lr,
                    window=self.window, impl=self.impl,
                    accum_steps=self.accum_steps)
                p, o, metrics = raw(state.params, state.opt, batch)
                return state.replace(params=p, opt=o,
                                     step=state.step + 1), metrics

            self._jit_step_raw = jax.jit(state_step)
            self._apply_fault_wrapper()
        else:  # serve
            self._prefill_raw = jax.jit(_steps.build_step(
                cfg, rules, kind="prefill", window=self.window,
                impl=self.impl))
            self._decode_raw = jax.jit(_steps.build_step(
                cfg, rules, kind="decode", window=self.window,
                impl=self.impl))
            self._apply_fault_wrapper()

    def _bump_serve_tick(self) -> int:
        tick = self._serve_tick
        self._serve_tick += 1
        return tick

    def _apply_fault_wrapper(self):
        """(Re)derive the dispatched step fns from the raw jitted fns:
        plain when no fault schedule is attached, wrapped with
        step-boundary injection otherwise. Kept separate from
        ``_build_step_fns`` so attaching a schedule does not force a
        re-jit. Serve sessions have no ``state.step`` clock — each decode
        call consumes one ``_serve_tick`` for schedule matching (prefill
        reads the tick without consuming it)."""
        fn = self._jit_step_raw
        if fn is not None and self._fault_schedule is not None:
            fn = _steps.with_fault_injection(
                fn, self._fault_schedule, lambda: int(self.state.step))
        self._jit_step = fn
        pf, dc = self._prefill_raw, self._decode_raw
        if self._fault_schedule is not None:
            if pf is not None:
                pf = _steps.with_fault_injection(
                    pf, self._fault_schedule, lambda: self._serve_tick)
            if dc is not None:
                dc = _steps.with_fault_injection(
                    dc, self._fault_schedule, self._bump_serve_tick)
        self._prefill, self._decode = pf, dc

    # ---------------------------------------------------------- faults --
    def attach_faults(self, schedule) -> "Session":
        """Arm a deterministic :class:`~repro.core.faults.FaultSchedule`
        on this session. Step-boundary faults (device loss, transient
        step failures) and straggler slowdowns inject through the step
        wrapper; checkpoint IO faults inject through the save path's
        ``io_hook``. This is the testing/benchmark surface — a real
        deployment raises :class:`DeviceLossError` from its own health
        monitoring instead."""
        self._fault_schedule = schedule
        self._apply_fault_wrapper()
        for w in self._writers.values():
            w.io_hook = self._ckpt_io_hook
        return self

    def _ckpt_io_hook(self, event: str, step: int) -> None:
        """Checkpoint IO choke point: every write/rename in the commit
        protocol announces itself here, and an attached schedule may
        answer with OSError (retryable) or SimulatedCrash (fatal)."""
        if self._fault_schedule is not None:
            self._fault_schedule.checkpoint_io(event, step)

    def drain(self) -> "Session":
        """Discard in-flight work after a fault and restore the invariant
        that the loader's position matches the last *applied* step.

        Gradient accumulation runs inside one jitted step (a lax.scan),
        and ``state.step`` advances only when that step returns — so a
        step that failed mid-flight applied nothing: no partial
        accumulator can leak. Draining therefore means (a) blocking on
        whatever was dispatched so poisoned buffers surface now rather
        than at the next use, and (b) rewinding the loader to
        ``state.step`` so the interrupted batch replays in full — no
        micro-step of it is lost or double-counted."""
        try:
            jax.block_until_ready(self.state)
        except Exception:  # noqa: BLE001 — the fault that got us here may re-raise
            pass
        if self._loader is not None:
            self._loader.seek(int(self.state.step))
        return self

    def _device_step_times(self, dt: float) -> Dict[str, float]:
        """Best-available per-device step times for one observed step.

        Single-process SPMD has no per-device clock: ``dt`` is the wall
        time of the *whole* step, i.e. the max over devices. The proxy
        distributes it over the plan's predicted per-device busy shares
        (the planner's own imbalance model), scaled by any injected
        straggler factor — so a ``FaultSchedule.slow()`` host shows up in
        ``DriftReport.observed_imbalance`` exactly as a real straggler
        would on a fleet with real timers. ``device_time_provider``
        replaces the whole proxy when a better source exists."""
        if self.device_time_provider is not None:
            return self.device_time_provider(self, dt)
        if self.plan is None or self.plan.predicted is None:
            return {}
        busy = getattr(self.plan.predicted, "device_busy", None) or {}
        mx = max(busy.values(), default=0.0)
        if mx <= 0:
            return {}
        step_idx = max(int(self.state.step) - 1, 0)
        times = {}
        for dev, b in busy.items():
            factor = (self._fault_schedule.slow_factor(step_idx, device=dev)
                      if self._fault_schedule is not None else 1.0)
            times[dev] = dt * (b / mx) * factor
        return times

    # ------------------------------------------------------- execution --
    def step(self, batch=None, *args):
        """Advance one step.

        train: ``step(batch=None)`` — None pulls the next hetero batch
        from :meth:`loader`; returns the metrics dict and updates
        ``self.state``. serve: ``step(tokens, decode_state)`` aliases
        :meth:`decode`.
        """
        if self._suspended:
            raise RuntimeError(
                "session is suspended (its lease was revoked); resume() "
                "must run before stepping")
        if self.mode == "serve":
            return self.decode(batch, *args)
        if self.mode != "train":
            raise RuntimeError(f"step() not available in mode={self.mode!r}")
        if batch is None:
            batch = self.loader().next_batch()
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        if self.accum_steps == 1 and batch["tokens"].ndim == 3:
            # loader batches carry a (gas, B, S) lead; with gas=1 the step
            # consumes the plain (B, S) form
            if batch["tokens"].shape[0] != 1:
                raise ValueError(
                    f"batch has a {batch['tokens'].shape[0]}-deep "
                    "accumulation axis but the session was built with "
                    "accum_steps=1 — rebuild with accum_steps= or pass "
                    "unstacked (B, S) arrays")
            batch = {k: v[0] for k, v in batch.items()}
        # observe only when there is a prediction to compare against and
        # this step is a telemetry sample: the block makes step()
        # synchronous (per-step latency is what the plan predicted, not
        # dispatch time), so unplanned sessions — whose EMA could never
        # be judged — and the steps between sparse samples
        # (DriftConfig.sample_every) keep JAX's async dispatch
        tick = self._observe_tick
        self._observe_tick += 1
        observe = (self.plan is not None and self.plan.predicted is not None
                   and self.plan.predicted.iter_time > 0
                   and tick % max(self.drift_config.sample_every, 1) == 0)
        t0 = time.perf_counter() if observe else 0.0
        with self.mesh:
            self.state, metrics = self._jit_step(self.state, batch)
        if observe:
            jax.block_until_ready(metrics)
            # tokens is the loss-mask sum — *non-pad* tokens, so the
            # tokens/sec EMA measures useful throughput (packed and
            # padded runs are comparable on it; wall time alone is not)
            dt = time.perf_counter() - t0
            self.telemetry.record(dt, tokens=float(metrics["tokens"]))
            per_dev = self._device_step_times(dt)
            if per_dev:
                self.device_timers.record(per_dev)
            if (self._drift_baseline is None
                    and self.telemetry.count
                    >= self.drift_config.min_samples):
                # calibrate as soon as the window is judgeable: these
                # early steps ran under the plan's own conditions, so
                # their ratio to the prediction is the substrate
                # constant, not drift
                self._drift_baseline = (self.telemetry.value
                                        / self.plan.predicted.iter_time)
        return metrics

    def loader(self):
        """The hetero data loader matching the plan's batch layout,
        positioned at the current step (restore-safe)."""
        if self.mode != "train":
            raise RuntimeError("loader() is train-mode only")
        if self._loader is None:
            from repro.data.pipeline import HeteroDataLoader
            self._loader = HeteroDataLoader(self._source, self.layout,
                                            self.seq, packing=self.packing)
            self._loader.seek(int(self.state.step))
        return self._loader

    # --------------------------------------------- observe / re-plan ----
    def drift(self, config: Optional[DriftConfig] = None
              ) -> Optional[DriftReport]:
        """Compare the observed step-time EMA against the plan's
        prediction. None while unjudgeable (unplanned session, or fewer
        than ``min_samples`` post-warmup steps recorded).

        The first judgeable observation *calibrates*: its
        observed/predicted ratio becomes the nominal baseline (the
        simulator's clock is not this host's clock — on the CPU
        container they differ by orders of magnitude), so drift reports
        how reality moved since the plan was made."""
        predicted = busy = None
        if self.plan is not None and self.plan.predicted is not None:
            predicted = self.plan.predicted.iter_time
            busy = self.plan.predicted.device_busy
        # calibration persists on the session, so it is gated by the
        # session's own min_samples — an ad-hoc probe config with
        # min_samples=1 may judge however it likes but must not pin a
        # one-noisy-step baseline for every later call
        if (self._drift_baseline is None and predicted is not None
                and predicted > 0 and self.telemetry.value is not None
                and self.telemetry.count >= self.drift_config.min_samples):
            self._drift_baseline = self.telemetry.value / predicted
        return detect_drift(self.telemetry, predicted,
                            config or self.drift_config, busy,
                            baseline=self._drift_baseline or 1.0,
                            device_timers=self.device_timers)

    def maybe_replan(self, config: Optional[DriftConfig] = None,
                     profile: str = "measured") -> Optional[ReplanReport]:
        """Re-plan iff the drift detector says observed step time left
        the band around the plan's prediction. The periodic check behind
        ``launch/train.py --replan-every``.

        A drift-triggered re-plan consumes *live measurements* by default
        (``profile="measured"``) regardless of how the session was built:
        drift is proof the timings the current plan was computed from no
        longer describe reality, so re-running the same analytical curves
        would reproduce the same plan and merely recalibrate the drift
        baseline to the degraded state — adapting requires re-measuring.
        The session's profile switches accordingly (pass
        ``profile="analytical"`` to opt out)."""
        report = self.drift(config)
        if report is None or not report.drifted:
            return None
        return self.replan(trigger="drift", drift_report=report,
                           profile=profile)

    def replan(self, cluster=None, *, gbs: Optional[int] = None,
               profile: Optional[str] = None, mesh=None,
               trigger: str = "explicit",
               drift_report: Optional[DriftReport] = None) -> ReplanReport:
        """Re-run the planner and migrate the *live* session onto the new
        configuration — no process restart, no parameter loss.

        ``cluster=`` declares a membership change (device added/removed/
        replaced); omitted, the current cluster is re-planned from fresh
        measurements (``profile="measured"`` re-times the real step — the
        paper's 'react to observed throughput' loop). The sequence is:

        1. plan: profiling → spline fit → batch allocation on the (new)
           cluster, same cfg/seq/zero request as the original build;
        2. rebuild mesh + MeshRules + hetero batch layout from the plan;
        3. reshard: gather the TrainState to host (full arrays are
           mesh-independent), re-derive shardings from the logical-axis
           tree it carries, device_put onto the new mesh, re-jit;
        4. re-split the data stream onto the new layout at the current
           step. Under a *deterministic* profile ("analytical") an
           unchanged cluster reproduces the same plan, layout and
           batches — the training trajectory is bit-identical to an
           unperturbed run. ``profile="measured"`` re-times the real
           step, so noisy wall clocks may legitimately re-balance the
           allocation (that adaptivity is the point); the state itself
           is always carried over exactly.

        Returns a :class:`ReplanReport` (plan + reshard wall seconds —
        the elastic overhead the benchmarks compare to one train step).
        """
        if self.mode not in ("train", "serve"):
            raise RuntimeError("replan() is train/serve-mode only")
        if profile is not None and profile not in PROFILES:
            raise ValueError(
                f"profile={profile!r}; expected one of {PROFILES}")
        new_profile = profile if profile is not None else self.profile
        new_gbs = gbs if gbs is not None else self.gbs
        new_cluster = cluster if cluster is not None else self.cluster
        old_devices = self.cluster.n if self.cluster is not None else (
            int(self.mesh.devices.size))

        # plan first, commit after: a planner failure (e.g. SimOOM on a
        # shrunken cluster) must leave the live session untouched
        tp = time.time()
        new_plan = None
        stage = self.rules.zero_stage
        if new_cluster is not None and self.mode == "train":
            new_plan = self._run_planner(new_cluster, self.rules.overlap,
                                         gbs=new_gbs, profile=new_profile)
            stage = new_plan.zero_stage
        plan_seconds = time.time() - tp

        tr = time.time()
        # gather the live state to host BEFORE touching any configuration:
        # full arrays are mesh-independent, so from here the migration can
        # always be rolled back onto the old shardings
        host = host_train_state(self.state)
        rollback = (self.mesh, self.cluster, self.plan, self.layout,
                    self.rules, self.accum_steps, self.profile, self.gbs,
                    self._p_shardings, self._o_shardings, self._jit_step,
                    self._jit_step_raw, self._prefill, self._decode,
                    self._prefill_raw, self._decode_raw, self.state)
        try:
            self.profile, self.gbs = new_profile, new_gbs
            if new_cluster is not None and self.mode == "train":
                self.plan = new_plan
            if mesh is not None:
                self.mesh = mesh
            elif cluster is not None:
                self.mesh = self._default_mesh(new_cluster)
            self.cluster = new_cluster
            if self.plan is not None:
                self.layout = layout_from_plan(
                    self.plan.allocation,
                    group_multiple=data_axis_size(self.mesh))
                self.accum_steps = self.layout.gas
            else:
                self.layout = _uniform_layout(self.gbs, self.accum_steps,
                                              data_axis_size(self.mesh))
            self.rules = MeshRules(
                self.mesh, zero_stage=stage, overlap=self.rules.overlap,
                comm_dtype=self.rules.comm_dtype,
                overlap_prefetch=self.rules.overlap_prefetch)

            # reshard the live state: host gather -> new-mesh placement
            self.state = host
            self._derive_shardings()
            with self.mesh:
                self.state = jax.device_put(host, self._state_shardings())
            self._jit_step = None
            self._build_step_fns()
            if self._loader is not None:
                self._loader.relayout(self.layout,
                                      seek=int(self.state.step))
        except BaseException:
            # half-migrated is worse than failed: restore the old
            # configuration and re-place the gathered state on it
            (self.mesh, self.cluster, self.plan, self.layout, self.rules,
             self.accum_steps, self.profile, self.gbs, self._p_shardings,
             self._o_shardings, self._jit_step, self._jit_step_raw,
             self._prefill, self._decode, self._prefill_raw,
             self._decode_raw, self.state) = rollback
            with self.mesh:
                self.state = jax.device_put(host, self._state_shardings())
            if self._loader is not None:
                self._loader.relayout(self.layout,
                                      seek=int(self.state.step))
            # drop the telemetry that triggered this attempt: keeping the
            # drifted EMA and the stale baseline would make maybe_replan
            # re-fire immediately — a failed-replan loop with no new
            # evidence. Fresh samples must re-establish drift first.
            self.telemetry.reset()
            self.device_timers.reset()
            self._drift_baseline = None
            raise
        reshard_seconds = time.time() - tr

        self.plan_seconds = plan_seconds
        self.telemetry.reset()
        self.device_timers.reset()
        self._drift_baseline = None          # new plan, new calibration
        self.replans += 1
        self._meta.update({
            "cluster": _cluster_meta(new_cluster), "gbs": self.gbs,
            "zero": stage, "profile": self.profile})
        self.last_replan = ReplanReport(
            # an explicit cluster= with the default trigger is a
            # membership change; callers that name their trigger (the
            # Supervisor's "fault", maybe_replan's "drift") keep it
            trigger=("cluster" if cluster is not None
                     and trigger == "explicit" else trigger),
            plan_seconds=plan_seconds, reshard_seconds=reshard_seconds,
            old_devices=old_devices,
            new_devices=(new_cluster.n if new_cluster is not None
                         else int(self.mesh.devices.size)),
            zero_stage=stage,
            profile_source=(self.plan.profile_source
                            if self.plan is not None else "none"),
            step=int(self.state.step), drift=drift_report)
        return self.last_replan

    # serve-mode surface
    def prefill(self, batch):
        if self._prefill is None:
            raise RuntimeError("prefill() is serve-mode only")
        with self.mesh:
            return self._prefill(self.state.params, batch)

    def decode(self, tokens, decode_state):
        if self._decode is None:
            raise RuntimeError("decode() is serve-mode only")
        if self._suspended:
            raise RuntimeError(
                "session is suspended (its lease was revoked); resume() "
                "must run before decoding")
        with self.mesh:
            return self._decode(self.state.params, tokens, decode_state)

    def init_decode_state(self, batch: int, max_len: int, enc_out=None):
        return mm.init_decode_state(self.cfg, batch, max_len,
                                    enc_out=enc_out)

    def engine(self, *, requests: Optional[int] = None,
               cache_len: Optional[int] = None, num_pages: int = 256,
               page_size: int = 16, chunk: int = 32, max_batch: int = 64,
               split=None, **kw):
        """A continuous-batching :class:`~repro.serve.engine.Engine`
        bound to this serve session's parameters.

        When the session rides a cluster (or an arbiter lease) and the
        caller names the workload (``requests``/``cache_len``), the
        engine is built with a hetero traffic split priced off that
        cluster; otherwise it runs split-less (uniform admission).

        An attached FaultSchedule threads through as the engine's
        ``tick_hook``: every decode tick consumes one serve tick, so
        scheduled faults fire inside ``Supervisor.call`` exactly as they
        do on the ``decode()`` path — recovery rebuilds the session, and
        callers rebuild the engine from the recovered session.
        """
        if self.mode != "serve":
            raise RuntimeError("engine() is serve-mode only")
        from repro.serve.engine import Engine
        from repro.serve.split import plan_traffic_split
        if (split is None and self.cluster is not None
                and requests and cache_len):
            split = plan_traffic_split(self.cluster, self.cfg,
                                       requests=requests,
                                       cache_len=cache_len,
                                       page_size=page_size)
        tick_hook = None
        if self._fault_schedule is not None:
            sched = self._fault_schedule

            def tick_hook():
                sched.check_step(self._bump_serve_tick())
        impl = self.impl if self.impl in ("reference", "pallas") else "reference"
        return Engine(self.state.params, self.cfg, num_pages=num_pages,
                      page_size=page_size, chunk=chunk, max_batch=max_batch,
                      impl=impl, split=split, cluster=self.cluster,
                      tick_hook=tick_hook, **kw)

    # dryrun-mode surface
    def lower(self):
        """Lower (not compile) the train step against ShapeDtypeStructs —
        the dry-run entry: memory_analysis/cost_analysis without
        allocating."""
        from repro.launch import specs as SP
        batch = {}
        lead = (self.accum_steps,) if self.accum_steps > 1 else ()
        B, S = self.layout.padded_global_batch, self.seq
        fields = [("tokens", jnp.int32), ("labels", jnp.int32),
                  ("loss_mask", jnp.float32)]
        if self.packing:
            fields += [("segment_ids", jnp.int32), ("positions", jnp.int32)]
        for k, dt in fields:
            batch[k] = SP.SDS(lead + (B, S), dt)
        b_specs = SP.batch_spec_tree(
            self.rules, batch,
            accum=self.accum_steps if self.accum_steps > 1 else 0)
        fn = _steps.build_step(self.cfg, self.rules, self.state.axes,
                               kind="train", adamw_cfg=self.adamw_cfg,
                               lr=self.lr, window=self.window,
                               impl=self.impl,
                               accum_steps=self.accum_steps)
        in_sh = (self._p_shardings, self._o_shardings,
                 jax.tree.map(self.rules.sharding, b_specs))
        with self.mesh:
            return jax.jit(fn, in_shardings=in_sh).lower(
                self.state.params, self.state.opt, batch)

    # -------------------------------------------------------- describe --
    def describe(self) -> Dict[str, Any]:
        """Plan, predicted utilization, memory model, and the scheduled-
        overlap comm report — the whole configuration, one dict."""
        from repro.core.workload import MemoryModel
        out: Dict[str, Any] = {
            "mode": self.mode, "impl": self.impl,
            "zero_stage": self.rules.zero_stage,
            "overlap": self.rules.overlap,
            "comm_dtype": self.rules.comm_dtype,
            "mesh": {"shape": list(self.mesh.devices.shape),
                     "axes": list(self.mesh.axis_names)},
            "gbs": self.gbs, "seq": self.seq,
            "accum_steps": self.accum_steps,
            "build_seconds": round(self.build_seconds, 3),
            "profile": self.profile,
            "replans": self.replans,
        }
        if self.mode == "train":
            out["telemetry"] = {"ema_step_s": self.telemetry.value,
                                "tokens_per_sec": self.telemetry.tokens_per_sec,
                                "samples": self.telemetry.count}
            rep = self.drift()
            if rep is not None:
                out["drift"] = {"ratio": round(rep.ratio, 3),
                                "drifted": rep.drifted,
                                "reason": rep.reason}
        if self.plan is not None:
            p = self.plan
            out["plan"] = {
                "zero_stage": p.zero_stage,
                "profiling_probes": p.profiling_probes,
                "profiling_probes_saved": p.profiling_probes_saved,
                "profile_source": p.profile_source,
                "plan_seconds": round(self.plan_seconds, 3),
                "assignments": {
                    n: {"gmbs": a.gmbs, "micro_batch": a.micro_batch,
                        "gas": a.gas, "lbs": a.lbs}
                    for n, a in p.allocation.assignments.items()},
            }
            if p.predicted is not None:
                out["plan"]["predicted"] = {
                    "cluster_tflops": p.predicted.cluster_tflops,
                    "utilization": p.predicted.utilization,
                    "iter_time_s": p.predicted.iter_time,
                }
        if self.layout is not None:
            out["layout"] = {
                "groups": list(self.layout.group_names),
                "padded_group_batch": self.layout.padded_group_batch,
                "gas": self.layout.gas,
            }
        n_dev = self.cluster.n if self.cluster is not None else max(
            int(jax.device_count()), 1)
        memm = MemoryModel(self.cfg, self.seq, self.rules.zero_stage, n_dev,
                           self.cfg.remat)
        out["memory"] = {
            "model_state_gb": memm.model_state_bytes() / 1e9,
            "activation_gb_per_sample":
                memm.activation_bytes_per_sample() / 1e9,
        }
        out["overlap_report"] = self._overlap_report()
        return out

    def _overlap_report(self):
        """comm_report for the scheduled plan, or the reason it does not
        apply (a string)."""
        from repro.core import overlap
        if self.mode != "train" or self.state is None:
            return "train-mode only"
        lead = ((self.accum_steps,) if self.accum_steps > 1 else ())
        shape = lead + (self.layout.padded_global_batch, self.seq)
        batch = {"tokens": jax.ShapeDtypeStruct(shape, jnp.int32)}
        plan = overlap.plan_comm(self.rules, self.state.params,
                                 self.state.axes, batch, self.accum_steps)
        if isinstance(plan, str):
            return plan
        return overlap.comm_report(plan, self.state.params,
                                   remat=self.cfg.remat)

    # ---------------------------------------------------- save/restore --
    def save(self, path: str, *, async_: bool = False,
             keep_last: Optional[int] = None, incremental: bool = True):
        """Checkpoint params/opt/step plus the session recipe; restore
        with :meth:`Session.restore`.

        ``async_=False`` (default) blocks through the whole atomic commit
        protocol and returns the payload path. ``async_=True`` pays only
        for the device→host snapshot on the critical path — serialization,
        write, fsync, rename and retention run on a background thread —
        and returns a :class:`~repro.checkpoint.PendingSave` (``.result()``
        to join one save, :meth:`flush_saves` to join them all).
        ``keep_last=N`` prunes all but the newest N committed checkpoints
        after each successful commit. ``incremental=True`` (default)
        skips re-writing arrays whose crc32 digest is unchanged from the
        previous committed step — their manifest entries point at the
        prior payload file instead (restore/verify follow the
        indirection). Serve sessions save too (params-only, opt=None) —
        the arbiter's suspend path needs a durable snapshot regardless of
        mode."""
        if self.mode not in ("train", "serve"):
            raise RuntimeError(f"save() not available in mode={self.mode!r}")
        meta = {"session": self._meta}
        if not async_:
            out = save_checkpoint(path, int(self.state.step),
                                  self.state.params, self.state.opt,
                                  metadata=meta, keep_last=keep_last,
                                  io_hook=self._ckpt_io_hook,
                                  incremental=incremental)
            self.events.emit("ckpt_committed", step=int(self.state.step),
                             detail="blocking")
            return out
        writer = self._writer_for(path, keep_last, incremental)
        # the snapshot is the only part that must see live state: gather
        # to host numpy, after which training may keep mutating devices
        host = host_train_state(self.state)
        pending = writer.submit(int(host.step), host.params, host.opt,
                                metadata=meta)
        self.events.emit("save_async", step=pending.step)
        return pending

    def _writer_for(self, path: str, keep_last: Optional[int],
                    incremental: bool = False) -> AsyncCheckpointWriter:
        key = str(path)
        w = self._writers.get(key)
        if w is None:
            w = AsyncCheckpointWriter(path, keep_last=keep_last,
                                      io_hook=self._ckpt_io_hook,
                                      on_event=self.events.emit)
            self._writers[key] = w
        if keep_last is not None:
            w.keep_last = keep_last
        w.incremental = incremental
        return w

    def flush_saves(self, timeout: Optional[float] = None) -> list:
        """Block until every in-flight async save has committed or
        failed; returns the accumulated writer errors (empty = all
        committed)."""
        for w in self._writers.values():
            w.wait(timeout)
        return [e for w in self._writers.values() for e in w.errors]

    # ------------------------------------------------- suspend / resume --
    def suspend(self, ckpt_path: Optional[str] = None, *,
                reason: str = "") -> "Session":
        """Yield this session's devices: drain in-flight work, flush
        pending async saves, commit a blocking checkpoint (when
        ``ckpt_path`` is given — the state is durable *before* the lease
        is handed away), and refuse further step/decode calls until
        :meth:`resume`. Idempotent. The arbiter's graceful-degradation
        path: the lowest-priority tenant suspends here rather than
        crashing anyone."""
        if self._suspended:
            return self
        self.drain()
        self.flush_saves()
        if ckpt_path is not None:
            self.save(ckpt_path)          # blocking — committed now
        self._suspended = True
        self.events.emit("suspended", step=int(self.state.step),
                         detail=reason)
        return self

    def resume(self, cluster=None, *, ckpt_path: Optional[str] = None,
               mesh=None, trigger: str = "resume") -> "Session":
        """Undo :meth:`suspend`: re-admit step/decode calls, optionally
        migrate onto a new lease (``cluster=`` goes through
        :meth:`replan`) and reload the suspend-time checkpoint
        (``ckpt_path=`` — the suspend/resume round trip goes through the
        committed state, so a suspended tenant's devices can be reused
        freely in between)."""
        self._suspended = False
        if cluster is not None or mesh is not None:
            self.replan(cluster=cluster, mesh=mesh, trigger=trigger)
        if ckpt_path is not None:
            from repro.checkpoint import latest_verified_step
            step = latest_verified_step(ckpt_path)
            if step is not None:
                self.load(ckpt_path, step)
        self.events.emit("resumed", step=int(self.state.step),
                         detail=f"devices={self.cluster.n}"
                                if self.cluster is not None else "")
        return self

    def load(self, path: str, step: Optional[int] = None) -> "Session":
        """Load a checkpoint into this (already built) session.

        The checkpoint's mesh does not have to match this session's:
        stored arrays are full (gathered at save time), so placement onto
        this session's shardings re-slices them for whatever mesh the
        session was built with (cross-mesh restore)."""
        with self.mesh:
            step, params, opt = restore_checkpoint(
                path, step, self.state.params, self.state.opt,
                shardings=(self._p_shardings, self._o_shardings))
            self.state = TrainState(params, opt,
                                    jnp.asarray(step, jnp.int32),
                                    self.state.axes)
        if self._loader is not None:
            self._loader.seek(int(step))
        return self

    @classmethod
    def restore(cls, path: str, cfg=None, cluster=None,
                step: Optional[int] = None, mesh=None,
                **overrides) -> "Session":
        """Rebuild the session from the checkpoint's recorded recipe and
        load params/opt/step. ``cfg``/``cluster``/other kwargs override
        the recorded values (required when the original cfg was a custom
        dataclass not in the registry).

        ``cluster=`` may name a *different* cluster than the one the
        checkpoint recorded — cross-mesh restore: the session re-plans
        against the new cluster (new mesh, layout and shardings; the
        recorded ZeRO stage is kept) and the stored full arrays are
        re-sliced onto it. An 8-device stage-3 checkpoint resumes on a
        4-device layout with bit-identical params/opt after gather."""
        d = Path(path)
        if step is None:
            # newest checkpoint that is both committed (in the manifest)
            # and verifies against its recorded digests — a crash mid-save
            # or a corrupted payload falls back to the previous good one
            from repro.checkpoint import latest_step, latest_verified_step
            step = latest_verified_step(path)
            if step is None:
                step = latest_step(path)
            if step is None:
                raise FileNotFoundError(f"no checkpoints under {path}")
        meta = json.loads((d / f"ckpt_{step:08d}.json").read_text())
        skw = dict(meta.get("session", {}))
        arch = skw.pop("arch", None)
        fingerprint = skw.pop("total_params", None)
        cluster_meta = skw.pop("cluster", None)
        adamw = skw.pop("adamw", None)
        if adamw is not None and "adamw_cfg" not in overrides:
            skw["adamw_cfg"] = AdamWConfig(**adamw)
        skw.pop("step", None)
        skw.update(overrides)
        if cfg is None:
            if arch is None:
                raise ValueError("checkpoint has no session metadata; "
                                 "pass cfg= explicitly")
            cfg = get_config(arch)
            if fingerprint is not None and int(cfg.total_params) != fingerprint:
                cfg = get_config(arch, reduced=True)
                if int(cfg.total_params) != fingerprint:
                    raise ValueError(
                        f"checkpoint was built from a customized {arch!r} "
                        "config; pass cfg= explicitly")
        if cluster is None:
            cluster = _cluster_from_meta(cluster_meta)
        sess = cls.build(cfg, cluster, mesh=mesh, **skw)
        return sess.load(path, step)
