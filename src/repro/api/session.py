"""Session — one-call automated parallelism, plan to jitted step.

Poplar's claim is that the user supplies a model and a cluster and the
system finds the configuration. :class:`Session` is that claim as an
API: ``Session.build(cfg, cluster, gbs=..., seq=...)`` runs the Poplar
planner (profiling → spline fit → batch allocation → stage selection),
constructs the mesh + :class:`MeshRules`, initializes and shards a
:class:`TrainState` (axes carried in-state — no ``register_axes`` side
channel), and jits the unified step. Everything the old ten-step
ceremony hand-wired is one constructor:

    sess = Session.build(get_config("llama-0.5b"), cluster_B(),
                         gbs=64, seq=128)
    for _ in range(steps):
        metrics = sess.step()            # loader-fed hetero batch
    sess.save("/tmp/ckpt")               # ... later:
    sess = Session.restore("/tmp/ckpt")  # resumes params/opt/step

``cluster=None`` skips the planner for callers that pin their own mesh
and stage (tests, benchmarks): a uniform single-group batch layout
replaces the hetero allocation.

Modes: ``"train"`` (loader/step/save/restore), ``"serve"`` (jitted
prefill/decode over the shared state), ``"dryrun"`` (abstract
eval_shape state; ``session.lower()`` for memory/cost analysis without
allocating a byte).
"""
from __future__ import annotations

import json
import math
import time
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.api import steps as _steps
from repro.api.state import TrainState, new_train_state
from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.configs.base import ModelConfig, get_config
from repro.core import cluster as CL
from repro.core.hetero import HeteroBatchLayout, layout_from_plan
from repro.core.sharding import MeshRules
from repro.core.zero import model_shardings
from repro.launch.mesh import data_axis_size, make_debug_mesh
from repro.models import model as mm
from repro.optim.adamw import AdamWConfig, adamw_init

MODES = ("train", "serve", "dryrun")


def _uniform_layout(gbs: int, accum: int, group_multiple: int
                    ) -> HeteroBatchLayout:
    """Single-group layout for unplanned (cluster=None) sessions: ``gbs``
    real rows per micro-step, padded to the data-axis multiple."""
    pad = max(int(math.ceil(gbs / max(group_multiple, 1))) * group_multiple,
              group_multiple, 1)
    return HeteroBatchLayout(["local"], [gbs], pad, max(accum, 1), [gbs])


def _cluster_meta(cluster) -> Optional[Dict]:
    if cluster is None:
        return None
    comp = []
    for d in cluster.devices:
        if comp and comp[-1][0] == d.name:
            comp[-1][1] += 1
        else:
            comp.append([d.name, 1])
    return {"name": cluster.name, "composition": comp,
            "inter_link_gbps": cluster.inter_link_gbps,
            "shared_bus": cluster.shared_bus}


def _cluster_from_meta(meta: Optional[Dict]):
    if meta is None:
        return None
    return CL.make_cluster(meta["name"],
                           [tuple(c) for c in meta["composition"]],
                           meta["inter_link_gbps"],
                           shared_bus=meta.get("shared_bus", True))


class Session:
    """Facade over planner + mesh + shardings + state + jitted step.

    Construct with :meth:`build` (or :meth:`restore`); the plain
    constructor is internal.
    """

    def __init__(self):
        self.cfg: ModelConfig = None
        self.cluster = None
        self.mode = "train"
        self.mesh = None
        self.rules: MeshRules = None
        self.plan = None                  # PoplarPlan | None
        self.layout: HeteroBatchLayout = None
        self.state: TrainState = None
        self.impl = "reference"           # resolved
        self.accum_steps = 1
        self.lr = 3e-4
        self.adamw_cfg = AdamWConfig()
        self.window = None
        self.gbs = 0
        self.seq = 0
        self.seed = 0
        self.data = None
        self.build_seconds = 0.0
        self.plan_seconds = 0.0
        self._jit_step = None
        self._prefill = None
        self._decode = None
        self._loader = None
        self._p_shardings = None
        self._o_shardings = None
        self._meta: Dict[str, Any] = {}

    # ------------------------------------------------------------ build --
    @classmethod
    def build(cls, cfg, cluster=None, *, gbs: int = 32, seq: int = 128,
              mode: str = "train", zero: Optional[int] = None,
              impl: str = "auto", overlap: str = "auto",
              comm_dtype: Optional[str] = None, lr: float = 3e-4,
              adamw_cfg: Optional[AdamWConfig] = None,
              window: Optional[int] = None,
              accum_steps: Optional[int] = None,
              mesh=None, seed: int = 0, data: Optional[str] = None,
              overlap_prefetch: bool = True,
              plan_seq: Optional[int] = None) -> "Session":
        """One call from (model, cluster) to a jitted, sharded step.

        ``cfg`` — a ModelConfig or a registered arch name. ``cluster`` —
        a ClusterSpec to plan against, or None to skip the planner (then
        ``zero`` defaults to 3 and ``accum_steps`` to 1). The planner is
        fed *this* cfg and sequence length — the configuration that
        trains is the configuration that plans (``plan_seq`` overrides
        the planning seq_len only, for CPU demos that train short).
        """
        if mode not in MODES:
            raise ValueError(f"mode={mode!r}; expected one of {MODES}")
        t0 = time.time()
        self = cls()
        if isinstance(cfg, str):
            cfg = get_config(cfg)
        self.cluster = cluster
        self.mode = mode
        self.lr = lr
        self.adamw_cfg = AdamWConfig() if adamw_cfg is None else adamw_cfg
        self.window = window
        self.gbs, self.seq, self.seed, self.data = gbs, seq, seed, data
        # recipe fingerprint of the cfg *as handed in* — a data= corpus may
        # widen the vocab below, and restore() must be able to match the
        # registry config before re-deriving that widening
        input_arch, input_params = cfg.name, int(cfg.total_params)

        # data source first: a text corpus can widen the vocab, and the
        # planner must see the cfg that actually trains
        self._source = None
        if mode == "train":
            from dataclasses import replace
            from repro.data.pipeline import SyntheticTokens, TextFileTokens
            if data:
                src = TextFileTokens(data, seq, seed=seed)
                cfg = replace(cfg, vocab_size=max(cfg.vocab_size,
                                                  src.vocab_size))
            else:
                src = SyntheticTokens(cfg.vocab_size, seq, seed=seed)
            self._source = src
        self.cfg = cfg

        # ---- Poplar: fully automated configuration ----
        if cluster is not None and mode != "serve":
            from repro.core.overlap import SCHEDULED_OVERLAP_FACTOR
            from repro.core.planner import plan as poplar_plan
            overlap_factor = (SCHEDULED_OVERLAP_FACTOR if overlap != "xla"
                              else 0.0)
            tp = time.time()
            self.plan = poplar_plan(cluster, cfg, gbs,
                                    seq_len=plan_seq or seq,
                                    zero_stage=zero,
                                    overlap_factor=overlap_factor)
            self.plan_seconds = time.time() - tp
            stage = self.plan.zero_stage
        else:
            stage = (0 if mode == "serve" else 3) if zero is None else zero

        self.mesh = mesh if mesh is not None else make_debug_mesh(
            jax.device_count())
        if self.plan is not None:
            self.layout = layout_from_plan(
                self.plan.allocation, group_multiple=data_axis_size(self.mesh))
            self.accum_steps = self.layout.gas
        else:
            self.accum_steps = accum_steps or 1
            self.layout = _uniform_layout(gbs, self.accum_steps,
                                          data_axis_size(self.mesh))
        self.rules = MeshRules(self.mesh, zero_stage=stage, overlap=overlap,
                               comm_dtype=comm_dtype,
                               overlap_prefetch=overlap_prefetch)
        self.impl = _steps.resolve_impl(impl)

        # ---- state: init, shard, wrap (axes ride in the pytree) ----
        if mode == "dryrun":
            box = {}

            def init_values(key):
                p, a = mm.init_model(key, cfg)
                box["axes"] = a
                return p

            p_tree = jax.eval_shape(init_values, jax.random.PRNGKey(seed))
            axes = box["axes"]
            opt = jax.eval_shape(adamw_init, p_tree)
            self.state = TrainState(p_tree, opt,
                                    jax.ShapeDtypeStruct((), jnp.int32), axes)
            self._derive_shardings()
        else:
            params, axes = mm.init_model(jax.random.PRNGKey(seed), cfg)
            opt = adamw_init(params) if mode == "train" else None
            self.state = new_train_state(params, axes, opt)
            self._derive_shardings()
            with self.mesh:
                self.state = jax.device_put(self.state,
                                            self._state_shardings())
            self._build_step_fns()

        from dataclasses import asdict
        self._meta = {
            "arch": input_arch, "total_params": input_params,
            "cluster": _cluster_meta(cluster), "gbs": gbs, "seq": seq,
            "mode": mode, "zero": stage, "impl": impl, "overlap": overlap,
            "comm_dtype": comm_dtype, "lr": lr, "window": window,
            "adamw": asdict(self.adamw_cfg),
            "accum_steps": accum_steps, "seed": seed, "data": data,
            "overlap_prefetch": overlap_prefetch, "plan_seq": plan_seq,
        }
        self.build_seconds = time.time() - t0
        return self

    def _derive_shardings(self):
        p_specs, o_specs, _ = model_shardings(self.rules, self.state.params,
                                              self.state.axes)
        self._p_shardings = jax.tree.map(self.rules.sharding, p_specs)
        self._o_shardings = (jax.tree.map(self.rules.sharding, o_specs)
                             if self.state.opt is not None else None)

    def _state_shardings(self) -> TrainState:
        from jax.sharding import PartitionSpec as P
        return TrainState(self._p_shardings, self._o_shardings,
                          self.rules.sharding(P()), self.state.axes)

    def _build_step_fns(self):
        cfg, rules = self.cfg, self.rules

        if self.mode == "train":
            def state_step(state: TrainState, batch):
                raw = _steps.build_step(
                    cfg, rules, state.axes, kind="train",
                    adamw_cfg=self.adamw_cfg, lr=self.lr,
                    window=self.window, impl=self.impl,
                    accum_steps=self.accum_steps)
                p, o, metrics = raw(state.params, state.opt, batch)
                return state.replace(params=p, opt=o,
                                     step=state.step + 1), metrics

            self._jit_step = jax.jit(state_step)
        else:  # serve
            self._prefill = jax.jit(_steps.build_step(
                cfg, rules, kind="prefill", window=self.window,
                impl=self.impl))
            self._decode = jax.jit(_steps.build_step(
                cfg, rules, kind="decode", window=self.window,
                impl=self.impl))

    # ------------------------------------------------------- execution --
    def step(self, batch=None, *args):
        """Advance one step.

        train: ``step(batch=None)`` — None pulls the next hetero batch
        from :meth:`loader`; returns the metrics dict and updates
        ``self.state``. serve: ``step(tokens, decode_state)`` aliases
        :meth:`decode`.
        """
        if self.mode == "serve":
            return self.decode(batch, *args)
        if self.mode != "train":
            raise RuntimeError(f"step() not available in mode={self.mode!r}")
        if batch is None:
            batch = self.loader().next_batch()
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        if self.accum_steps == 1 and batch["tokens"].ndim == 3:
            # loader batches carry a (gas, B, S) lead; with gas=1 the step
            # consumes the plain (B, S) form
            if batch["tokens"].shape[0] != 1:
                raise ValueError(
                    f"batch has a {batch['tokens'].shape[0]}-deep "
                    "accumulation axis but the session was built with "
                    "accum_steps=1 — rebuild with accum_steps= or pass "
                    "unstacked (B, S) arrays")
            batch = {k: v[0] for k, v in batch.items()}
        with self.mesh:
            self.state, metrics = self._jit_step(self.state, batch)
        return metrics

    def loader(self):
        """The hetero data loader matching the plan's batch layout,
        positioned at the current step (restore-safe)."""
        if self.mode != "train":
            raise RuntimeError("loader() is train-mode only")
        if self._loader is None:
            from repro.data.pipeline import HeteroDataLoader
            self._loader = HeteroDataLoader(self._source, self.layout,
                                            self.seq)
            self._loader.seek(int(self.state.step))
        return self._loader

    # serve-mode surface
    def prefill(self, batch):
        if self._prefill is None:
            raise RuntimeError("prefill() is serve-mode only")
        with self.mesh:
            return self._prefill(self.state.params, batch)

    def decode(self, tokens, decode_state):
        if self._decode is None:
            raise RuntimeError("decode() is serve-mode only")
        with self.mesh:
            return self._decode(self.state.params, tokens, decode_state)

    def init_decode_state(self, batch: int, max_len: int, enc_out=None):
        return mm.init_decode_state(self.cfg, batch, max_len,
                                    enc_out=enc_out)

    # dryrun-mode surface
    def lower(self):
        """Lower (not compile) the train step against ShapeDtypeStructs —
        the dry-run entry: memory_analysis/cost_analysis without
        allocating."""
        from repro.launch import specs as SP
        batch = {}
        lead = (self.accum_steps,) if self.accum_steps > 1 else ()
        B, S = self.layout.padded_global_batch, self.seq
        for k, dt in (("tokens", jnp.int32), ("labels", jnp.int32),
                      ("loss_mask", jnp.float32)):
            batch[k] = SP.SDS(lead + (B, S), dt)
        b_specs = SP.batch_spec_tree(
            self.rules, batch,
            accum=self.accum_steps if self.accum_steps > 1 else 0)
        fn = _steps.build_step(self.cfg, self.rules, self.state.axes,
                               kind="train", adamw_cfg=self.adamw_cfg,
                               lr=self.lr, window=self.window,
                               impl=self.impl,
                               accum_steps=self.accum_steps)
        in_sh = (self._p_shardings, self._o_shardings,
                 jax.tree.map(self.rules.sharding, b_specs))
        with self.mesh:
            return jax.jit(fn, in_shardings=in_sh).lower(
                self.state.params, self.state.opt, batch)

    # -------------------------------------------------------- describe --
    def describe(self) -> Dict[str, Any]:
        """Plan, predicted utilization, memory model, and the scheduled-
        overlap comm report — the whole configuration, one dict."""
        from repro.core.workload import MemoryModel
        out: Dict[str, Any] = {
            "mode": self.mode, "impl": self.impl,
            "zero_stage": self.rules.zero_stage,
            "overlap": self.rules.overlap,
            "comm_dtype": self.rules.comm_dtype,
            "mesh": {"shape": list(self.mesh.devices.shape),
                     "axes": list(self.mesh.axis_names)},
            "gbs": self.gbs, "seq": self.seq,
            "accum_steps": self.accum_steps,
            "build_seconds": round(self.build_seconds, 3),
        }
        if self.plan is not None:
            p = self.plan
            out["plan"] = {
                "zero_stage": p.zero_stage,
                "profiling_probes": p.profiling_probes,
                "plan_seconds": round(self.plan_seconds, 3),
                "assignments": {
                    n: {"gmbs": a.gmbs, "micro_batch": a.micro_batch,
                        "gas": a.gas, "lbs": a.lbs}
                    for n, a in p.allocation.assignments.items()},
            }
            if p.predicted is not None:
                out["plan"]["predicted"] = {
                    "cluster_tflops": p.predicted.cluster_tflops,
                    "utilization": p.predicted.utilization,
                    "iter_time_s": p.predicted.iter_time,
                }
        if self.layout is not None:
            out["layout"] = {
                "groups": list(self.layout.group_names),
                "padded_group_batch": self.layout.padded_group_batch,
                "gas": self.layout.gas,
            }
        n_dev = self.cluster.n if self.cluster is not None else max(
            int(jax.device_count()), 1)
        memm = MemoryModel(self.cfg, self.seq, self.rules.zero_stage, n_dev,
                           self.cfg.remat)
        out["memory"] = {
            "model_state_gb": memm.model_state_bytes() / 1e9,
            "activation_gb_per_sample":
                memm.activation_bytes_per_sample() / 1e9,
        }
        out["overlap_report"] = self._overlap_report()
        return out

    def _overlap_report(self):
        """comm_report for the scheduled plan, or the reason it does not
        apply (a string)."""
        from repro.core import overlap
        if self.mode != "train" or self.state is None:
            return "train-mode only"
        lead = ((self.accum_steps,) if self.accum_steps > 1 else ())
        shape = lead + (self.layout.padded_global_batch, self.seq)
        batch = {"tokens": jax.ShapeDtypeStruct(shape, jnp.int32)}
        plan = overlap.plan_comm(self.rules, self.state.params,
                                 self.state.axes, batch, self.accum_steps)
        if isinstance(plan, str):
            return plan
        return overlap.comm_report(plan, self.state.params,
                                   remat=self.cfg.remat)

    # ---------------------------------------------------- save/restore --
    def save(self, path: str) -> str:
        """Checkpoint params/opt/step plus the session recipe; restore
        with :meth:`Session.restore`."""
        if self.mode != "train":
            raise RuntimeError("save() is train-mode only")
        return save_checkpoint(path, int(self.state.step), self.state.params,
                               self.state.opt,
                               metadata={"session": self._meta})

    def load(self, path: str, step: Optional[int] = None) -> "Session":
        """Load a checkpoint into this (already built) session."""
        step, params, opt = restore_checkpoint(path, step, self.state.params,
                                               self.state.opt)
        with self.mesh:
            params = jax.device_put(params, self._p_shardings)
            if opt is not None:
                opt = jax.device_put(opt, self._o_shardings)
        self.state = TrainState(params, opt, jnp.asarray(step, jnp.int32),
                                self.state.axes)
        if self._loader is not None:
            self._loader.seek(int(step))
        return self

    @classmethod
    def restore(cls, path: str, cfg=None, cluster=None,
                step: Optional[int] = None, mesh=None,
                **overrides) -> "Session":
        """Rebuild the session from the checkpoint's recorded recipe and
        load params/opt/step. ``cfg``/``cluster``/other kwargs override
        the recorded values (required when the original cfg was a custom
        dataclass not in the registry)."""
        d = Path(path)
        if step is None:
            from repro.checkpoint import latest_step
            step = latest_step(path)
            if step is None:
                raise FileNotFoundError(f"no checkpoints under {path}")
        meta = json.loads((d / f"ckpt_{step:08d}.json").read_text())
        skw = dict(meta.get("session", {}))
        arch = skw.pop("arch", None)
        fingerprint = skw.pop("total_params", None)
        cluster_meta = skw.pop("cluster", None)
        adamw = skw.pop("adamw", None)
        if adamw is not None and "adamw_cfg" not in overrides:
            skw["adamw_cfg"] = AdamWConfig(**adamw)
        skw.pop("step", None)
        skw.update(overrides)
        if cfg is None:
            if arch is None:
                raise ValueError("checkpoint has no session metadata; "
                                 "pass cfg= explicitly")
            cfg = get_config(arch)
            if fingerprint is not None and int(cfg.total_params) != fingerprint:
                cfg = get_config(arch, reduced=True)
                if int(cfg.total_params) != fingerprint:
                    raise ValueError(
                        f"checkpoint was built from a customized {arch!r} "
                        "config; pass cfg= explicitly")
        if cluster is None:
            cluster = _cluster_from_meta(cluster_meta)
        sess = cls.build(cfg, cluster, mesh=mesh, **skw)
        return sess.load(path, step)
