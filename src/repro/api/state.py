"""First-class training state: one pytree that carries everything a step
needs — parameters, optimizer state, the step counter, and the model's
*logical-axis tree* as static pytree metadata.

The axes tree used to travel through a side channel: callers had to
`zero.register_axes(rules, axes)` before tracing so the step builder
could look it up at trace time (a mutable attribute smuggled onto the
MeshRules instance). Carrying the axes as :class:`TrainState` aux data
kills that ceremony: any function jitted over a TrainState sees the axes
as ordinary static Python data (`state.axes`) during tracing, and the
state round-trips through `jax.jit` / `jax.device_put` / checkpointing
with the axes attached.

`params`/`opt` are regular pytrees; `step` is a () int32 array so the
counter lives on-device and survives donation. `opt` may be ``None`` for
inference-only sessions (None is an empty subtree to JAX).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp


def _freeze(tree) -> Any:
    """Canonical hashable form of an axes tree (dicts ordered by key)."""
    if isinstance(tree, dict):
        return ("__dict__",) + tuple(
            (k, _freeze(v)) for k, v in sorted(tree.items()))
    if isinstance(tree, (list, tuple)):
        tag = "__list__" if isinstance(tree, list) else "__tuple__"
        return (tag,) + tuple(_freeze(v) for v in tree)
    return tree


class StaticAxes:
    """Hashable wrapper making an axes tree usable as pytree aux data
    (jit's tracing cache keys aux data by __hash__/__eq__)."""

    __slots__ = ("tree", "_key")

    def __init__(self, tree):
        self.tree = tree
        self._key = _freeze(tree)

    def __eq__(self, other):
        return isinstance(other, StaticAxes) and self._key == other._key

    def __hash__(self):
        return hash(self._key)

    def __repr__(self):
        return f"StaticAxes({self.tree!r})"


@dataclass
class TrainState:
    """(params, opt, step) pytree with the logical-axis tree as static
    aux data. Build fresh states with :func:`new_train_state`; inside jit
    read ``state.axes`` freely — it is Python data, not a tracer."""
    params: Any
    opt: Optional[Any]
    step: Any
    axes: Any

    def replace(self, **kw) -> "TrainState":
        d = {"params": self.params, "opt": self.opt, "step": self.step,
             "axes": self.axes}
        d.update(kw)
        return TrainState(**d)


def _ts_flatten_with_keys(ts: TrainState):
    G = jax.tree_util.GetAttrKey
    children = ((G("params"), ts.params), (G("opt"), ts.opt),
                (G("step"), ts.step))
    return children, StaticAxes(ts.axes)


def _ts_flatten(ts: TrainState):
    return (ts.params, ts.opt, ts.step), StaticAxes(ts.axes)


def _ts_unflatten(aux: StaticAxes, children):
    params, opt, step = children
    return TrainState(params, opt, step, aux.tree)


jax.tree_util.register_pytree_with_keys(
    TrainState, _ts_flatten_with_keys, _ts_unflatten, _ts_flatten)


def new_train_state(params, axes, opt=None) -> TrainState:
    return TrainState(params, opt, jnp.zeros((), jnp.int32), axes)


def host_train_state(state: TrainState) -> TrainState:
    """Gather every leaf to host memory (numpy) — the mesh-independent
    form used for cross-mesh resharding: a state gathered here can be
    ``device_put`` onto any mesh's shardings, because full arrays carry no
    trace of the layout they were sharded with. The logical-axis tree
    rides along as aux data, so the new mesh's specs can be re-derived
    from the result alone."""
    import numpy as np

    def gather(x):
        return np.asarray(x)

    return TrainState(jax.tree.map(gather, state.params),
                      (jax.tree.map(gather, state.opt)
                       if state.opt is not None else None),
                      np.asarray(state.step), state.axes)
