"""Unified step construction: one builder for every execution kind.

``build_step(cfg, rules, axes, kind=...)`` subsumes the three historical
builders (``zero.make_train_step`` / ``make_prefill_step`` /
``make_decode_step``, now thin deprecation shims over this module). The
logical-axis tree is an explicit argument — there is no registration
side channel; Session passes ``state.axes`` and the shims pass whatever
``register_axes`` pinned on the rules instance.

Returned signatures (unjitted; callers jit):

  kind="train"    step(params, opt_state, batch) -> (params, opt, metrics)
  kind="prefill"  step(params, batch)            -> last-token logits
  kind="decode"   step(params, tokens, state)    -> (logits, state)

``step_io(cfg, rules, shape, ...)`` pairs a step with ShapeDtypeStruct
example args and input shardings for lowering-only consumers (the
multi-pod dry-run) — no device allocation happens there.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.sharding import MeshRules, use_rules
from repro.core.zero import model_shardings
from repro.models import model as mm
from repro.optim.adamw import AdamWConfig, adamw_update

STEP_KINDS = ("train", "prefill", "decode")


def resolve_impl(impl: str) -> str:
    """``"auto"`` -> the backend-recommended kernel implementation."""
    if impl == "auto":
        from repro.kernels.ops import recommended_impl
        return recommended_impl()
    return impl


def build_step(cfg: ModelConfig, rules: MeshRules, axes=None, *,
               kind: str = "train",
               adamw_cfg: AdamWConfig = AdamWConfig(),
               lr: float = 3e-4, window: Optional[int] = None,
               impl: str = "reference", accum_steps: int = 1) -> Callable:
    """Build the (unjitted) step function for ``kind``.

    ``axes`` — the logical-axis tree from ``init_model`` — is required
    for ``kind="train"`` (ZeRO>=2 gradient specs and the scheduled-
    overlap comm plan are derived from it); inference kinds ignore it.

    Training semantics are unchanged from the pre-Session builders:
    ``accum_steps > 1`` consumes (gas, B, S) stacked micro-batches with
    per-microbatch loss masks (Poplar's gmbs/lbs schedule as masked
    rows); ``rules.overlap`` routes stage 3 through the explicit
    shard_map schedule in core/overlap.py ("scheduled" raises when the
    mesh/batch combination cannot support it, "auto" falls back).
    """
    if kind not in STEP_KINDS:
        raise ValueError(f"kind={kind!r}; expected one of {STEP_KINDS}")
    impl = resolve_impl(impl)
    if kind == "prefill":
        def prefill_step(params, batch):
            with use_rules(rules):
                return mm.prefill(params, cfg, batch, window=window,
                                  impl=impl)
        return prefill_step
    if kind == "decode":
        def decode_step(params, tokens, state):
            with use_rules(rules):
                return mm.decode_step(params, cfg, tokens, state,
                                      window=window, impl=impl)
        return decode_step
    if axes is None:
        raise ValueError("kind='train' needs the logical-axis tree "
                         "(pass axes=, e.g. TrainState.axes)")
    return _train_step(cfg, rules, axes, adamw_cfg, lr, window, impl,
                       accum_steps)


def _train_step(cfg: ModelConfig, rules: MeshRules, axes,
                adamw_cfg: AdamWConfig, lr: float, window: Optional[int],
                impl: str, accum_steps: int) -> Callable:
    stage = rules.zero_stage

    def loss_of(params, batch):
        return mm.loss_fn(params, cfg, batch, window=window, impl=impl)

    def train_step(params, opt_state, batch):
        mode = getattr(rules, "overlap", "xla")
        if mode in ("scheduled", "auto"):
            from repro.core import overlap
            plan = overlap.plan_comm(rules, params, axes, batch, accum_steps)
            if isinstance(plan, str):
                if mode == "scheduled":
                    raise ValueError(
                        f"rules.overlap='scheduled' unsupported: {plan}")
            elif mode == "scheduled" or plan.n_dp > 1:
                return overlap.scheduled_train_step(
                    plan, cfg, adamw_cfg, lr, window, impl, accum_steps,
                    params, opt_state, batch)
        with use_rules(rules):
            if accum_steps == 1:
                (loss, metrics), grads = jax.value_and_grad(
                    loss_of, has_aux=True)(params, batch)
                tokens = metrics["tokens"]
            else:
                def micro(carry, mb):
                    g_acc, l_acc, t_acc = carry
                    (l, met), g = jax.value_and_grad(
                        loss_of, has_aux=True)(params, mb)
                    w = met["tokens"]
                    g_acc = jax.tree.map(
                        lambda a, b: a + b.astype(jnp.float32) * w, g_acc, g)
                    return (g_acc, l_acc + l * w, t_acc + w), None

                g0 = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                (grads, lsum, tokens), _ = jax.lax.scan(
                    micro, (g0, jnp.zeros(()), jnp.zeros(())), batch)
                denom = jnp.maximum(tokens, 1.0)
                grads = jax.tree.map(lambda g: g / denom, grads)
                loss = lsum / denom
                metrics = {"loss": loss, "aux": jnp.zeros(()),
                           "tokens": tokens}
            if stage >= 2:
                # reduce-scatter semantics: keep grads partitioned
                _, _, g_specs = model_shardings(rules, params, axes)
                grads = jax.tree.map(
                    lambda g, s: jax.lax.with_sharding_constraint(
                        g, rules.sharding(s)), grads, g_specs)
            new_params, new_opt, om = adamw_update(grads, opt_state, params,
                                                   lr, adamw_cfg)
            metrics = dict(metrics)
            metrics.update(om)
            return new_params, new_opt, metrics

    return train_step


def with_fault_injection(step_fn: Callable, schedule,
                         current_step: Callable[[], int]) -> Callable:
    """Wrap a (jitted) step callable so a ``core.faults.FaultSchedule``
    can inject failures at the step boundary — one choke point whether
    the caller goes through ``Session.step()`` or drives the raw step.

    Before dispatch, ``schedule.check_step(step)`` may raise a scheduled
    :class:`~repro.core.faults.DeviceLossError` or
    :class:`~repro.core.faults.TransientStepError`. After dispatch, a
    scheduled straggler (``schedule.slow_factor > 1``) blocks on the
    result and sleeps the extra ``(factor - 1)`` fraction of the step's
    real wall time — the whole step is as slow as its slowest host, which
    is exactly what the drift telemetry should observe."""
    import time as _time

    def injected(*args, **kwargs):
        step = current_step()
        schedule.check_step(step)
        factor = schedule.slow_factor(step)
        if factor <= 1.0:
            return step_fn(*args, **kwargs)
        t0 = _time.perf_counter()
        out = step_fn(*args, **kwargs)
        jax.block_until_ready(out)
        _time.sleep((factor - 1.0) * (_time.perf_counter() - t0))
        return out

    return injected


# ---------------------------------------------------------------------------
# measured profiling substrate (Session.build(profile="measured"))
# ---------------------------------------------------------------------------

class ProbeHarness:
    """A real jitted train step, parameterized by batch size, for
    :class:`repro.core.profiler.MeasuredRunner`.

    Algorithm 1 probes ``step(b)`` at exponentially growing ``b``; each
    batch size is AOT-lowered once (``jax.jit(...).lower(...).compile()``)
    on a single local device and the compiled executable is cached, so a
    probe costs one compile + the requested executions. ``memory_bytes(b)``
    is the OOM oracle, linear in batch (Algorithm 1's own assumption):
    the *slope* (activation bytes per sample — a per-device quantity
    regardless of sharding) comes from the compile-time
    ``memory_analysis`` difference between b=1 and b=2, falling back to
    the analytical estimate on backends that report none; the *base*
    (model-state bytes) always comes from the stage-aware analytical
    :class:`MemoryModel`, because the probe compiles an **unsharded**
    single-device step — its resident params/opt would overcount a
    ZeRO-sharded deployment by ~``n_workers``x and reject configurations
    that actually fit.

    ``packed`` (a ``core.workload.PackedWorkload``) switches the probe
    batches to the packed layout: rows carry synthetic ``segment_ids`` /
    ``positions`` with contiguous segments of the stream's mean segment
    length and its pad fraction, so the measured timings include the
    segment-aware kernels' block skipping — the profile prices what
    packed steps actually cost, not the full-attention workload.
    """

    def __init__(self, cfg: ModelConfig, *, seq_len: int, zero_stage: int,
                 n_workers: int = 1, impl: str = "reference",
                 window: Optional[int] = None, lr: float = 1e-3,
                 adamw_cfg: AdamWConfig = AdamWConfig(), seed: int = 0,
                 packed=None):
        import numpy as np

        from repro.core.workload import MemoryModel
        from repro.launch.mesh import make_debug_mesh
        from repro.optim.adamw import adamw_init

        self.cfg, self.seq_len = cfg, seq_len
        self._rules = MeshRules(make_debug_mesh(1), zero_stage=zero_stage)
        self._params, self._axes = mm.init_model(jax.random.PRNGKey(seed),
                                                 cfg)
        self._opt = adamw_init(self._params)
        self._fn = build_step(cfg, self._rules, self._axes, kind="train",
                              adamw_cfg=adamw_cfg, lr=lr, window=window,
                              impl=resolve_impl(impl))
        self._np_rng = np.random.default_rng(seed)
        self._packed = packed
        self._compiled: Dict[int, Tuple[Callable, Dict]] = {}
        self._analytic = MemoryModel(cfg, seq_len, zero_stage, n_workers,
                                     cfg.remat)
        self._mem_linear: Optional[Tuple[float, float]] = None
        self.compiles = 0

    def _batch(self, b: int) -> Dict:
        import numpy as np

        S = self.seq_len
        toks = self._np_rng.integers(3, self.cfg.vocab_size, (b, S))
        batch = {"tokens": jnp.asarray(toks, jnp.int32),
                 "labels": jnp.asarray(toks, jnp.int32),
                 "loss_mask": jnp.ones((b, S), jnp.float32)}
        if self._packed is None:
            return batch
        # synthetic packed row mirroring the stream's statistics:
        # contiguous segments of ~mean length filling (1 - pad_fraction)
        # of the slots, then pad (segment 0, loss 0)
        span = int(round(self._packed.mean_segment_len or S))
        span = max(1, min(span, S))
        real = int(round(S * max(0.0, min(1.0, self._packed.token_fraction))))
        seg_row = np.zeros(S, np.int32)
        pos_row = np.zeros(S, np.int32)
        off, sid = 0, 0
        while off < real:
            L = min(span, real - off)
            sid += 1
            seg_row[off:off + L] = sid
            pos_row[off:off + L] = np.arange(L)
            off += L
        batch["segment_ids"] = jnp.asarray(np.tile(seg_row, (b, 1)))
        batch["positions"] = jnp.asarray(np.tile(pos_row, (b, 1)))
        batch["loss_mask"] = jnp.asarray(
            np.tile((seg_row > 0).astype(np.float32), (b, 1)))
        return batch

    def _get(self, b: int) -> Tuple[Callable, Dict]:
        if b not in self._compiled:
            batch = self._batch(b)
            lowered = jax.jit(self._fn).lower(self._params, self._opt, batch)
            self._compiled[b] = (lowered.compile(), batch)
            self.compiles += 1
        return self._compiled[b]

    def _compiled_bytes(self, b: int) -> Optional[float]:
        compiled, _ = self._get(b)
        try:
            ma = compiled.memory_analysis()
        except Exception:  # noqa: BLE001 — backend-dependent surface
            return None
        if ma is None:
            return None
        total = 0.0
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes"):
            total += float(getattr(ma, attr, 0) or 0)
        return total if total > 0 else None

    def step(self, b: int) -> None:
        """One full training step at batch ``b``, blocking on completion."""
        compiled, batch = self._get(b)
        jax.block_until_ready(compiled(self._params, self._opt, batch))

    def memory_bytes(self, b: int) -> float:
        if self._mem_linear is None:
            base = self._analytic.bytes_at_batch(0)   # stage-sharded state
            m1, m2 = self._compiled_bytes(1), self._compiled_bytes(2)
            if m1 is not None and m2 is not None and m2 > m1:
                per = m2 - m1                         # measured activations
            else:
                per = self._analytic.activation_bytes_per_sample()
            self._mem_linear = (base, per)
        base, per = self._mem_linear
        return base + per * max(b, 0)


# ---------------------------------------------------------------------------
# lowering-only step assembly (the multi-pod dry-run path)
# ---------------------------------------------------------------------------

def step_io(cfg: ModelConfig, rules: MeshRules, shape,
            impl: str = "reference") -> Tuple[Callable, tuple, tuple]:
    """(fn, ShapeDtypeStruct example args, in_shardings) for an InputShape.

    Everything comes from ``jax.eval_shape`` — safe to lower/compile on
    placeholder meshes with no real allocation.
    """
    from repro.launch import specs as SP

    window = SP.effective_window(cfg, shape)
    if shape.mode == "train":
        p_shapes, axes, p_specs, o_shapes, opt_specs, _ = (
            SP.params_and_shardings(cfg, rules, with_opt=True))
        batch = SP.batch_specs(cfg, shape)
        b_specs = SP.batch_spec_tree(rules, batch)
        fn = build_step(cfg, rules, axes, kind="train", window=window,
                        impl=impl)
        args = (p_shapes, o_shapes, batch)
        in_sh = (jax.tree.map(rules.sharding, p_specs),
                 jax.tree.map(rules.sharding, opt_specs),
                 jax.tree.map(rules.sharding, b_specs))
        return fn, args, in_sh
    if shape.mode == "prefill":
        p_shapes, axes, p_specs, *_ = SP.params_and_shardings(
            cfg, rules, with_opt=False)
        batch = SP.batch_specs(cfg, shape)
        b_specs = SP.batch_spec_tree(rules, batch)
        fn = build_step(cfg, rules, kind="prefill", window=window, impl=impl)
        args = (p_shapes, batch)
        in_sh = (jax.tree.map(rules.sharding, p_specs),
                 jax.tree.map(rules.sharding, b_specs))
        return fn, args, in_sh
    # decode
    p_shapes, axes, p_specs, *_ = SP.params_and_shardings(
        cfg, rules, with_opt=False)
    state_shapes, state_specs = SP.decode_state_specs(cfg, rules, shape)
    tokens = SP.SDS((shape.global_batch, 1), jnp.int32)
    tok_spec = rules.activation_spec(("batch", None), tokens.shape)
    fn = build_step(cfg, rules, kind="decode", window=window, impl=impl)
    args = (p_shapes, tokens, state_shapes)
    in_sh = (jax.tree.map(rules.sharding, p_specs),
             rules.sharding(tok_spec),
             jax.tree.map(rules.sharding, state_specs))
    return fn, args, in_sh
