from repro.checkpoint.async_writer import (AsyncCheckpointWriter,
                                           PendingSave, SimulatedCrash)
from repro.checkpoint.checkpoint import (committed_steps, latest_step,
                                         latest_verified_step,
                                         restore_checkpoint, save_checkpoint,
                                         sweep_retention, verify_checkpoint)

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "committed_steps", "latest_verified_step", "verify_checkpoint",
           "sweep_retention", "AsyncCheckpointWriter", "PendingSave",
           "SimulatedCrash"]
