"""Async checkpointing: the step loop pays only for the snapshot.

A blocking ``Session.save()`` holds the training loop for the full
device→host gather *plus* serialization, write, and fsync — seconds on a
real model, paid every few minutes on a preemptible fleet.
:class:`AsyncCheckpointWriter` splits the save at the only point that
must see live state: the snapshot (device→host gather into numpy — the
arrays are then immutable host memory, untouched by further training
steps). Everything after the snapshot — npz serialization, the
write-to-temp → fsync → atomic-rename commit protocol, the manifest
update, the ``keep_last`` retention sweep — runs on one background
thread, in submission order.

Failure semantics:

- transient IO errors (``OSError``) are retried with exponential
  backoff, ``max_retries`` times, before the save is marked failed;
- a failed or crashed save can never corrupt the directory: the commit
  point is the manifest rename (see ``checkpoint.py``), so readers only
  ever observe fully committed checkpoints;
- errors surface on the returned :class:`PendingSave` (``result()``
  re-raises) and on ``writer.errors``; they never propagate into the
  training thread asynchronously.

``io_hook(event, step)`` threads the deterministic fault-injection
harness into the background write (see ``core/faults.FaultSchedule
.checkpoint_io_hook``): the hook may raise ``OSError`` to exercise the
retry path or ``SimulatedCrash`` to abort mid-protocol (e.g. between
temp-write and rename) the way SIGKILL would.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Callable, Dict, List, Optional

from repro.checkpoint.checkpoint import (commit_payload, prepare_payload,
                                         sweep_retention)


class SimulatedCrash(BaseException):
    """Raised by a fault-injection hook to model the process dying at an
    exact point in the write protocol. Deliberately *not* an
    ``Exception``/``OSError``: the retry loop must not swallow it — a
    crash kills the write where it stands, leaving whatever torn on-disk
    state the protocol allows at that point (which recovery must then
    survive)."""


class PendingSave:
    """Handle for one enqueued save: ``result()`` blocks until the
    background commit finishes and returns the payload path (re-raising
    the writer's error if the save failed); ``done``/``error``/``path``
    for non-blocking inspection."""

    def __init__(self, step: int, target: str):
        self.step = step
        self.target = target          # directory the checkpoint commits into
        self.path: Optional[str] = None
        self.error: Optional[BaseException] = None
        self.retries = 0              # IO retries this save needed
        self._done = threading.Event()

    @property
    def done(self) -> bool:
        return self._done.is_set()

    def result(self, timeout: Optional[float] = None) -> str:
        if not self._done.wait(timeout):
            raise TimeoutError(
                f"async save of step {self.step} still in flight")
        if self.error is not None:
            raise self.error
        return self.path


class AsyncCheckpointWriter:
    """One background thread draining a queue of snapshotted saves.

    ``submit()`` is called with *already gathered* host arrays (the
    caller's critical path did the snapshot); it enqueues and returns a
    :class:`PendingSave` immediately. Saves commit in submission order —
    a newer step can never land before an older one, so ``keep_last``
    retention and ``latest_step`` stay monotonic.
    """

    def __init__(self, path: str, *, keep_last: Optional[int] = None,
                 max_retries: int = 3, backoff_s: float = 0.05,
                 backoff_factor: float = 2.0,
                 io_hook: Optional[Callable[[str, int], None]] = None,
                 on_event: Optional[Callable[..., None]] = None,
                 incremental: bool = False):
        self.path = str(path)
        self.keep_last = keep_last
        # skip re-writing arrays unchanged since the previous committed
        # step (manifest-level indirection; see checkpoint.commit_payload)
        self.incremental = incremental
        self.max_retries = max_retries
        self.backoff_s = backoff_s
        self.backoff_factor = backoff_factor
        self.io_hook = io_hook
        # on_event(kind, step=, detail=) — telemetry sink (EventLog.emit)
        self.on_event = on_event or (lambda *a, **k: None)
        self.committed: List[int] = []
        self.errors: List[BaseException] = []
        self._q: "queue.Queue" = queue.Queue()
        self._thread: Optional[threading.Thread] = None
        self._closed = False

    # ------------------------------------------------------------ submit --
    def submit(self, step: int, params, opt_state=None,
               metadata: Optional[Dict] = None) -> PendingSave:
        """Serialize-and-commit ``step`` in the background. ``params`` /
        ``opt_state`` must be host arrays (numpy) or otherwise immutable
        — the training loop is free to keep stepping the live state."""
        if self._closed:
            raise RuntimeError("writer is closed")
        # flattening/encoding is cheap (no copies for numpy inputs) but
        # runs here so digest computation sees exactly what was submitted
        arrays, meta, digests = prepare_payload(step, params, opt_state,
                                                metadata)
        pending = PendingSave(step, self.path)
        self._q.put((pending, arrays, meta, digests))
        self._ensure_thread()
        return pending

    # ----------------------------------------------------------- control --
    def wait(self, timeout: Optional[float] = None) -> None:
        """Block until every enqueued save has committed or failed."""
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            with self._q.all_tasks_done:
                if self._q.unfinished_tasks == 0:
                    return
                remaining = (None if deadline is None
                             else deadline - time.monotonic())
                if remaining is not None and remaining <= 0:
                    raise TimeoutError("async saves still in flight")
                self._q.all_tasks_done.wait(remaining)

    def close(self, timeout: Optional[float] = None) -> None:
        """Drain and stop the background thread (idempotent)."""
        if self._closed:
            return
        self.wait(timeout)
        self._closed = True
        if self._thread is not None:
            self._q.put(None)
            self._thread.join(timeout)
            self._thread = None

    # --------------------------------------------------------- internals --
    def _ensure_thread(self) -> None:
        if self._thread is None or not self._thread.is_alive():
            self._thread = threading.Thread(
                target=self._run, name="ckpt-writer", daemon=True)
            self._thread.start()

    def _run(self) -> None:
        while True:
            item = self._q.get()
            if item is None:
                self._q.task_done()
                return
            pending, arrays, meta, digests = item
            try:
                self._commit_with_retry(pending, arrays, meta, digests)
            finally:
                self._q.task_done()
                pending._done.set()

    def _commit_with_retry(self, pending: PendingSave, arrays, meta,
                           digests) -> None:
        delay = self.backoff_s
        for attempt in range(self.max_retries + 1):
            try:
                pending.path = commit_payload(
                    self.path, pending.step, arrays, meta, digests,
                    io_hook=self.io_hook, incremental=self.incremental)
                self.committed.append(pending.step)
                self.on_event("ckpt_committed", step=pending.step,
                              detail=f"retries={pending.retries}")
                if self.keep_last is not None:
                    sweep_retention(self.path, self.keep_last)
                return
            except OSError as e:
                pending.retries = attempt + 1
                if attempt >= self.max_retries:
                    pending.error = e
                    self.errors.append(e)
                    self.on_event("ckpt_failed", step=pending.step,
                                  detail=f"{type(e).__name__}: {e}")
                    return
                self.on_event("ckpt_io_retry", step=pending.step,
                              detail=f"attempt={attempt + 1} "
                                     f"backoff={delay:.3f}s: {e}")
                time.sleep(delay)
                delay *= self.backoff_factor
            except SimulatedCrash as e:
                # the injected process death: no retry, no cleanup — the
                # on-disk state is whatever the protocol left behind
                pending.error = e
                self.errors.append(e)
                self.on_event("ckpt_crashed", step=pending.step,
                              detail=str(e))
                return
