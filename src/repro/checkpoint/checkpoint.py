"""Sharding-aware npz checkpointing.

Parameters/optimizer state are flattened with stable path-derived keys and
written as one npz per host. On restore, arrays are re-placed with the
current mesh's shardings (fully-addressable single-host in this container;
the path keys are host-independent so multi-host restore shards by key).
"""
from __future__ import annotations

import json

import jax.numpy as jnp
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = leaf
    return flat


def _encode(arr: np.ndarray) -> Tuple[np.ndarray, str]:
    """npz has no bfloat16: store as uint16 bits + dtype tag."""
    dt = str(arr.dtype)
    if dt == "bfloat16":
        return arr.view(np.uint16), "bfloat16"
    return arr, dt


def save_checkpoint(path: str, step: int, params, opt_state=None,
                    metadata: Optional[Dict] = None) -> str:
    d = Path(path)
    d.mkdir(parents=True, exist_ok=True)
    out = {}
    dtypes = {}
    for k, v in _flatten_with_paths(params).items():
        out[f"params/{k}"], dtypes[f"params/{k}"] = _encode(np.asarray(v))
    if opt_state is not None:
        for k, v in _flatten_with_paths(opt_state).items():
            out[f"opt/{k}"], dtypes[f"opt/{k}"] = _encode(np.asarray(v))
    fn = d / f"ckpt_{step:08d}.npz"
    np.savez(fn, **out)
    meta = {"step": step, "dtypes": dtypes, **(metadata or {})}
    (d / f"ckpt_{step:08d}.json").write_text(json.dumps(meta))
    return str(fn)


def latest_step(path: str) -> Optional[int]:
    d = Path(path)
    if not d.exists():
        return None
    steps = sorted(int(f.stem.split("_")[1]) for f in d.glob("ckpt_*.npz"))
    return steps[-1] if steps else None


def restore_checkpoint(path: str, step: Optional[int], params_template,
                       opt_template=None, shardings=None
                       ) -> Tuple[int, Any, Any]:
    d = Path(path)
    if step is None:
        step = latest_step(path)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {path}")
    data = np.load(d / f"ckpt_{step:08d}.npz")
    meta = json.loads((d / f"ckpt_{step:08d}.json").read_text())
    dtypes = meta.get("dtypes", {})

    def rebuild(template, prefix, spec_tree=None):
        flat = _flatten_with_paths(template)
        keys = list(flat)
        restored = {}
        for k in keys:
            arr = data[f"{prefix}/{k}"]
            if dtypes.get(f"{prefix}/{k}") == "bfloat16":
                arr = arr.view(jnp.bfloat16.dtype)
            restored[k] = jax.device_put(arr)
        leaves, treedef = jax.tree_util.tree_flatten(template)
        paths = [
            "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in pth)
            for pth, _ in jax.tree_util.tree_flatten_with_path(template)[0]]
        new_leaves = [restored[p] for p in paths]
        return jax.tree_util.tree_unflatten(treedef, new_leaves)

    new_params = rebuild(params_template, "params")
    new_opt = rebuild(opt_template, "opt") if opt_template is not None else None
    return step, new_params, new_opt
