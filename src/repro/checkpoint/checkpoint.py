"""Sharding-aware npz checkpointing.

Parameters/optimizer state are flattened with stable path-derived keys and
written as one npz per host. On restore, arrays are re-placed with the
current mesh's shardings (fully-addressable single-host in this container;
the path keys are host-independent so multi-host restore shards by key).
"""
from __future__ import annotations

import json

import jax.numpy as jnp
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = leaf
    return flat


def _encode(arr: np.ndarray) -> Tuple[np.ndarray, str]:
    """npz has no bfloat16: store as uint16 bits + dtype tag."""
    dt = str(arr.dtype)
    if dt == "bfloat16":
        return arr.view(np.uint16), "bfloat16"
    return arr, dt


def save_checkpoint(path: str, step: int, params, opt_state=None,
                    metadata: Optional[Dict] = None) -> str:
    d = Path(path)
    d.mkdir(parents=True, exist_ok=True)
    out = {}
    dtypes = {}
    for k, v in _flatten_with_paths(params).items():
        out[f"params/{k}"], dtypes[f"params/{k}"] = _encode(np.asarray(v))
    if opt_state is not None:
        for k, v in _flatten_with_paths(opt_state).items():
            out[f"opt/{k}"], dtypes[f"opt/{k}"] = _encode(np.asarray(v))
    fn = d / f"ckpt_{step:08d}.npz"
    np.savez(fn, **out)
    meta = {"step": step, "dtypes": dtypes, **(metadata or {})}
    (d / f"ckpt_{step:08d}.json").write_text(json.dumps(meta))
    return str(fn)


def latest_step(path: str) -> Optional[int]:
    d = Path(path)
    if not d.exists():
        return None
    steps = sorted(int(f.stem.split("_")[1]) for f in d.glob("ckpt_*.npz"))
    return steps[-1] if steps else None


def restore_checkpoint(path: str, step: Optional[int], params_template,
                       opt_template=None, shardings=None
                       ) -> Tuple[int, Any, Any]:
    """Load params/opt for ``step`` (latest when ``None``).

    ``shardings`` — an optional ``(param_shardings, opt_shardings)`` pair
    of sharding trees matching the templates — places each restored array
    directly onto its target sharding. Checkpoints store *full* arrays
    (``np.asarray`` gathers sharded leaves at save time), so the target
    mesh does not have to be the mesh the checkpoint was written from:
    restoring an 8-device stage-3 checkpoint onto a 4-device layout just
    re-slices the gathered arrays (cross-mesh resharding on restore).
    """
    d = Path(path)
    if step is None:
        step = latest_step(path)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {path}")
    data = np.load(d / f"ckpt_{step:08d}.npz")
    meta = json.loads((d / f"ckpt_{step:08d}.json").read_text())
    dtypes = meta.get("dtypes", {})

    def rebuild(template, prefix, sharding_tree=None):
        # leaves come back in tree_flatten order, which is also the order
        # tree_flatten_with_path (and the sharding tree's leaves) iterate
        with_path = jax.tree_util.tree_flatten_with_path(template)[0]
        sh_leaves = (jax.tree_util.tree_leaves(
            sharding_tree, is_leaf=lambda x: x is None)
            if sharding_tree is not None else [None] * len(with_path))
        new_leaves = []
        for (pth, _), sh in zip(with_path, sh_leaves):
            k = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                         for p in pth)
            arr = data[f"{prefix}/{k}"]
            if dtypes.get(f"{prefix}/{k}") == "bfloat16":
                arr = arr.view(jnp.bfloat16.dtype)
            new_leaves.append(jax.device_put(arr, sh) if sh is not None
                              else jax.device_put(arr))
        _, treedef = jax.tree_util.tree_flatten(template)
        return jax.tree_util.tree_unflatten(treedef, new_leaves)

    p_sh, o_sh = shardings if shardings is not None else (None, None)
    new_params = rebuild(params_template, "params", p_sh)
    new_opt = (rebuild(opt_template, "opt", o_sh)
               if opt_template is not None else None)
    return step, new_params, new_opt
