"""Sharding-aware npz checkpointing with a crash-consistent commit
protocol.

Parameters/optimizer state are flattened with stable path-derived keys
and written as one npz per host. On restore, arrays are re-placed with
the current mesh's shardings (fully-addressable single-host in this
container; the path keys are host-independent so multi-host restore
shards by key).

Durability contract (the fault-tolerance layer builds on this):

- every file lands via *write-to-temp → fsync → atomic rename*
  (``os.replace``), so a crash mid-write leaves only a ``.tmp.*`` orphan,
  never a half-written ``ckpt_*.npz``;
- a checkpoint exists only once it is recorded in the directory-level
  ``MANIFEST.json`` (itself atomically replaced), which carries a
  per-array crc32 digest table and the recorded session recipe — the
  manifest update is the *commit point*: payload and metadata renamed
  but manifest not yet updated means the checkpoint is torn and is
  ignored by :func:`latest_step`;
- :func:`restore_checkpoint` verifies the digests and, when asked for
  the latest step, silently falls back to the newest checkpoint that
  *does* verify (a torn or bit-rotted newest step must not take down
  recovery — it is exactly the situation checkpoints exist for).

``io_hook(event, step)`` threads the deterministic fault-injection
harness (:mod:`repro.core.faults`) into the write path: the hook runs
immediately before each named IO action ("payload_write",
"payload_rename", "meta_write", "manifest_write") and may raise to
simulate IO errors or a crash at that exact point.
"""
from __future__ import annotations

import json
import os
import zlib

import jax.numpy as jnp
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

MANIFEST_NAME = "MANIFEST.json"
IoHook = Optional[Callable[[str, int], None]]


def _flatten_with_paths(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = leaf
    return flat


def _encode(arr: np.ndarray) -> Tuple[np.ndarray, str]:
    """npz has no bfloat16: store as uint16 bits + dtype tag."""
    dt = str(arr.dtype)
    if dt == "bfloat16":
        return arr.view(np.uint16), "bfloat16"
    return arr, dt


def _digest(arr: np.ndarray) -> str:
    """crc32 over the raw bytes plus the shape/dtype header — cheap
    enough to verify on every restore, strong enough to catch torn or
    bit-rotted payloads."""
    h = zlib.crc32(repr((arr.shape, str(arr.dtype))).encode())
    h = zlib.crc32(np.ascontiguousarray(arr).tobytes(), h)
    return f"{h:08x}"


def _atomic_write(target: Path, data: bytes, *, fsync: bool = True) -> None:
    """write-to-temp → fsync → os.replace: the file either has its old
    content (or is absent) or has the complete new content — never a
    prefix."""
    tmp = target.with_name(target.name + f".tmp.{os.getpid()}")
    try:
        with open(tmp, "wb") as f:
            f.write(data)
            if fsync:
                f.flush()
                os.fsync(f.fileno())
        os.replace(tmp, target)
    finally:
        if tmp.exists():            # crash/injection between write and rename
            try:
                tmp.unlink()
            except OSError:
                pass


def _load_manifest(d: Path) -> Dict:
    fp = d / MANIFEST_NAME
    if not fp.exists():
        return {"version": 1, "steps": {}}
    try:
        m = json.loads(fp.read_text())
    except (OSError, json.JSONDecodeError):
        return {"version": 1, "steps": {}}
    m.setdefault("steps", {})
    return m


def _payload_name(step: int) -> str:
    return f"ckpt_{step:08d}.npz"


def _meta_name(step: int) -> str:
    return f"ckpt_{step:08d}.json"


def prepare_payload(step: int, params, opt_state=None,
                    metadata: Optional[Dict] = None
                    ) -> Tuple[Dict[str, np.ndarray], Dict, Dict[str, str]]:
    """Gather + encode the state into host arrays: ``(arrays, meta,
    digests)``. This is the only part of a save that must happen while
    the state is live — everything after it operates on the snapshot
    (the async writer runs it on the critical path and ships the rest to
    its background thread)."""
    out: Dict[str, np.ndarray] = {}
    dtypes: Dict[str, str] = {}
    for k, v in _flatten_with_paths(params).items():
        out[f"params/{k}"], dtypes[f"params/{k}"] = _encode(np.asarray(v))
    if opt_state is not None:
        for k, v in _flatten_with_paths(opt_state).items():
            out[f"opt/{k}"], dtypes[f"opt/{k}"] = _encode(np.asarray(v))
    digests = {k: _digest(v) for k, v in out.items()}
    meta = {"step": step, "dtypes": dtypes, **(metadata or {})}
    return out, meta, digests


def _incremental_sources(d: Path, step: int,
                         digests: Dict[str, str]) -> Dict[str, str]:
    """Map each array key whose digest is unchanged from the previous
    committed step to the payload *file* that already holds its bytes
    (following the previous entry's own indirection, so chains collapse
    to the origin file). Keys absent from the map must be written."""
    manifest = _load_manifest(d)
    prev_steps = [int(s) for s in manifest["steps"] if int(s) < step]
    if not prev_steps:
        return {}
    prev = manifest["steps"][str(max(prev_steps))]
    prev_sources = prev.get("sources", {})
    sources: Dict[str, str] = {}
    for key, want in digests.items():
        if prev.get("digests", {}).get(key) != want:
            continue
        src = prev_sources.get(key, prev["file"])
        if (d / src).exists():
            sources[key] = src
    return sources


def commit_payload(path: str, step: int, arrays: Dict[str, np.ndarray],
                   meta: Dict, digests: Dict[str, str], *,
                   io_hook: IoHook = None, fsync: bool = True,
                   incremental: bool = False) -> str:
    """Write one checkpoint with the crash-consistent commit protocol:
    payload (tmp→rename), metadata (tmp→rename), then the manifest
    update (tmp→rename) as the commit point. A crash at any earlier
    point leaves the previous committed step authoritative.

    ``incremental=True`` compares ``digests`` against the previous
    committed manifest entry and skips re-writing unchanged arrays: the
    new entry's ``sources`` table points each skipped key at the prior
    step's payload file, and the digest table stays complete, so
    verify/restore follow the indirection transparently.
    :func:`sweep_retention` keeps any payload file a surviving manifest
    entry still references."""
    import io

    d = Path(path)
    d.mkdir(parents=True, exist_ok=True)
    hook = io_hook or (lambda event, s: None)

    sources = _incremental_sources(d, step, digests) if incremental else {}
    written = {k: v for k, v in arrays.items() if k not in sources}

    buf = io.BytesIO()
    np.savez(buf, **written)
    hook("payload_write", step)
    target = d / _payload_name(step)
    tmp = target.with_name(target.name + f".tmp.{os.getpid()}")
    with open(tmp, "wb") as f:
        f.write(buf.getvalue())
        if fsync:
            f.flush()
            os.fsync(f.fileno())
    hook("payload_rename", step)
    os.replace(tmp, target)

    hook("meta_write", step)
    _atomic_write(d / _meta_name(step), json.dumps(meta).encode(),
                  fsync=fsync)

    hook("manifest_write", step)
    manifest = _load_manifest(d)
    entry = {
        "file": _payload_name(step), "meta": _meta_name(step),
        "digests": digests,
        "recipe": meta.get("session"),
    }
    if sources:
        entry["sources"] = sources
    manifest["steps"][str(step)] = entry
    _atomic_write(d / MANIFEST_NAME, json.dumps(manifest).encode(),
                  fsync=fsync)
    return str(target)


def save_checkpoint(path: str, step: int, params, opt_state=None,
                    metadata: Optional[Dict] = None, *,
                    keep_last: Optional[int] = None,
                    io_hook: IoHook = None,
                    incremental: bool = False) -> str:
    """Blocking save: snapshot + commit protocol in the caller's thread
    (``repro.checkpoint.async_writer`` moves everything after the
    snapshot off the critical path). ``keep_last=N`` sweeps older
    committed checkpoints after the commit; ``incremental=True`` skips
    re-writing arrays unchanged since the previous committed step (their
    manifest entries point at the prior payload file)."""
    arrays, meta, digests = prepare_payload(step, params, opt_state, metadata)
    fn = commit_payload(path, step, arrays, meta, digests, io_hook=io_hook,
                        incremental=incremental)
    if keep_last is not None:
        sweep_retention(path, keep_last)
    return fn


def committed_steps(path: str) -> List[int]:
    """Steps recorded in the manifest whose payload actually exists —
    the only checkpoints that count. Directories written before the
    manifest protocol fall back to a glob that ignores ``.tmp`` orphans
    (half-written files never land under the final name either way)."""
    d = Path(path)
    if not d.exists():
        return []
    manifest = _load_manifest(d)
    if manifest["steps"]:
        return sorted(int(s) for s, rec in manifest["steps"].items()
                      if (d / rec["file"]).exists())
    # legacy layout: no manifest was ever written here (the glob cannot
    # match in-flight ``*.npz.tmp.<pid>`` orphans — they end in the pid)
    return sorted(int(f.stem.split("_")[1]) for f in d.glob("ckpt_*.npz"))


def latest_step(path: str) -> Optional[int]:
    steps = committed_steps(path)
    return steps[-1] if steps else None


def verify_checkpoint(path: str, step: int) -> bool:
    """Recompute the per-array digests of a committed checkpoint and
    compare against the manifest. False on any mismatch, missing file,
    unreadable payload, or missing manifest entry (legacy checkpoints
    without digests verify True — there is nothing to compare)."""
    d = Path(path)
    manifest = _load_manifest(d)
    rec = manifest["steps"].get(str(step))
    if rec is None:
        # legacy checkpoint: loadable npz+json is the best check we have
        try:
            np.load(d / _payload_name(step))
            json.loads((d / _meta_name(step)).read_text())
            return True
        except Exception:  # noqa: BLE001 — any unreadable form is torn
            return False
    try:
        sources = rec.get("sources", {})
        cache: Dict[str, Any] = {}

        def _arr(key: str):
            fname = sources.get(key, rec["file"])
            if fname not in cache:
                cache[fname] = np.load(d / fname)
            return cache[fname][key]

        for key, want in rec["digests"].items():
            if _digest(_arr(key)) != want:
                return False
        json.loads((d / rec["meta"]).read_text())
        return True
    except Exception:  # noqa: BLE001 — any unreadable form is torn
        return False


def latest_verified_step(path: str) -> Optional[int]:
    """Newest committed step whose digests verify — what restore falls
    back through when the newest checkpoint is torn."""
    for step in reversed(committed_steps(path)):
        if verify_checkpoint(path, step):
            return step
    return None


def sweep_retention(path: str, keep_last: int) -> List[int]:
    """Drop all but the newest ``keep_last`` committed checkpoints:
    manifest entries removed first (atomically — a crash mid-sweep must
    not orphan entries pointing at deleted files... it can only orphan
    *files*, which are harmless), then payload/metadata files and any
    stale ``.tmp`` orphans. Returns the dropped steps."""
    d = Path(path)
    manifest = _load_manifest(d)
    steps = sorted(int(s) for s in manifest["steps"])
    drop = steps[:-keep_last] if keep_last > 0 else steps
    if drop:
        records = {s: manifest["steps"].pop(str(s)) for s in drop}
        _atomic_write(d / MANIFEST_NAME, json.dumps(manifest).encode())
        # an incremental entry's sources point into *older* payload
        # files: any file a surviving entry still references must not be
        # unlinked, or the newer checkpoint would silently lose leaves
        referenced = set()
        for rec in manifest["steps"].values():
            referenced.add(rec["file"])
            referenced.update(rec.get("sources", {}).values())
        for s, rec in records.items():
            names = [rec["meta"]]
            if rec["file"] not in referenced:
                names.append(rec["file"])
            for name in names:
                try:
                    (d / name).unlink()
                except OSError:
                    pass
    # stale .tmp orphans (crash between temp-write and rename) are swept
    # even when retention keeps every step — they are dead weight either way
    for orphan in d.glob("*.tmp.*"):
        try:
            orphan.unlink()
        except OSError:
            pass
    return drop


def read_metadata(path: str, step: int) -> Dict:
    d = Path(path)
    return json.loads((d / _meta_name(step)).read_text())


def restore_checkpoint(path: str, step: Optional[int], params_template,
                       opt_template=None, shardings=None, *,
                       verify: bool = True
                       ) -> Tuple[int, Any, Any]:
    """Load params/opt for ``step`` (newest *verified* committed step
    when ``None`` — torn or digest-mismatched checkpoints are skipped
    and the previous committed one loads instead; an explicitly
    requested step that fails verification raises).

    ``shardings`` — an optional ``(param_shardings, opt_shardings)`` pair
    of sharding trees matching the templates — places each restored array
    directly onto its target sharding. Checkpoints store *full* arrays
    (``np.asarray`` gathers sharded leaves at save time), so the target
    mesh does not have to be the mesh the checkpoint was written from:
    restoring an 8-device stage-3 checkpoint onto a 4-device layout just
    re-slices the gathered arrays (cross-mesh resharding on restore).
    """
    d = Path(path)
    if step is None:
        step = (latest_verified_step(path) if verify
                else latest_step(path))
        if step is None:
            raise FileNotFoundError(f"no committed checkpoints under {path}")
    elif verify and not verify_checkpoint(path, step):
        raise ValueError(
            f"checkpoint step {step} under {path} is torn or corrupt "
            f"(digest mismatch); newest verified step is "
            f"{latest_verified_step(path)}")
    rec = _load_manifest(d)["steps"].get(str(step), {})
    sources = rec.get("sources", {})
    payloads = {None: np.load(d / rec.get("file", _payload_name(step)))}

    def _read(key: str):
        # incremental entries source unchanged leaves from a prior
        # step's payload file; everything else lives in this step's own
        fname = sources.get(key)
        if fname not in payloads:
            payloads[fname] = np.load(d / fname)
        return payloads[fname][key]

    meta = json.loads((d / _meta_name(step)).read_text())
    dtypes = meta.get("dtypes", {})

    def rebuild(template, prefix, sharding_tree=None):
        # leaves come back in tree_flatten order, which is also the order
        # tree_flatten_with_path (and the sharding tree's leaves) iterate
        with_path = jax.tree_util.tree_flatten_with_path(template)[0]
        sh_leaves = (jax.tree_util.tree_leaves(
            sharding_tree, is_leaf=lambda x: x is None)
            if sharding_tree is not None else [None] * len(with_path))
        new_leaves = []
        for (pth, _), sh in zip(with_path, sh_leaves):
            k = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                         for p in pth)
            arr = _read(f"{prefix}/{k}")
            if dtypes.get(f"{prefix}/{k}") == "bfloat16":
                arr = arr.view(jnp.bfloat16.dtype)
            new_leaves.append(jax.device_put(arr, sh) if sh is not None
                              else jax.device_put(arr))
        _, treedef = jax.tree_util.tree_flatten(template)
        return jax.tree_util.tree_unflatten(treedef, new_leaves)

    p_sh, o_sh = shardings if shardings is not None else (None, None)
    new_params = rebuild(params_template, "params", p_sh)
    new_opt = (rebuild(opt_template, "opt", o_sh)
               if opt_template is not None else None)
    return step, new_params, new_opt
