"""Config registry. Importing this package registers every architecture."""
from repro.configs import (granite_moe_1b_a400m, internlm2_20b, llava_next_34b,
                           minitron_4b, moonshot_v1_16b_a3b, paper_models,
                           phi3p5_moe_42b_a6p6b, seamless_m4t_medium,
                           starcoder2_15b, xlstm_1p3b, zamba2_2p7b)
from repro.configs.base import (ModelConfig, MoEConfig, SSMConfig, get_config,
                                list_archs, reduce_config)
from repro.configs.shapes import SHAPES, InputShape, applicable, get_shape

# The ten architectures assigned to this paper (public pool).
ASSIGNED_ARCHS = (
    "granite-moe-1b-a400m",
    "moonshot-v1-16b-a3b",
    "xlstm-1.3b",
    "phi3.5-moe-42b-a6.6b",
    "seamless-m4t-medium",
    "llava-next-34b",
    "starcoder2-15b",
    "internlm2-20b",
    "minitron-4b",
    "zamba2-2.7b",
)

__all__ = [
    "ModelConfig", "MoEConfig", "SSMConfig", "get_config", "list_archs",
    "reduce_config", "SHAPES", "InputShape", "applicable", "get_shape",
    "ASSIGNED_ARCHS",
]
