"""Config system for repro.

Every architecture is described by a :class:`ModelConfig`. Configs are
registered by id in a global registry; ``get_config("<id>")`` returns the
full-size published config and ``get_config("<id>", reduced=True)`` returns
the 2-layer smoke-test variant of the same family (d_model<=512, <=4
experts) used by CPU tests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Callable, Dict, List, Optional, Tuple

# ---------------------------------------------------------------------------
# Block descriptors
# ---------------------------------------------------------------------------

# Block kinds understood by models/model.py
BLOCK_ATTN = "attn"          # GQA attention + MLP (dense transformer block)
BLOCK_MOE = "moe"            # GQA attention + MoE FFN
BLOCK_MLSTM = "mlstm"        # xLSTM matrix-memory block
BLOCK_SLSTM = "slstm"        # xLSTM scalar-memory block
BLOCK_MAMBA2 = "mamba2"      # Mamba2 SSM block
BLOCK_SHARED_ATTN = "shared_attn"  # zamba2 shared transformer block marker


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    # d_ff of each expert (for MoE archs the table's d_ff is per-expert)
    d_expert: int
    # load-balance auxiliary loss weight
    aux_loss_weight: float = 0.01
    # capacity factor for expert-parallel dispatch buffers
    capacity_factor: float = 1.25
    # routing-group length (tokens). None = paper-faithful per-sequence
    # capacity; setting it bounds the (tokens, E, C) dispatch tensors to
    # C = ceil(group*K/E*cf) per group instead of C ~ S*K/E — the
    # §Perf/P1 optimization (GShard/MaxText grouped routing).
    group_size: Optional[int] = None
    # dispatch implementation: "gshard" (capacity one-hot einsums, MXU
    # friendly, token-dropping) or "ragged" (sorted dropless dispatch via
    # lax.ragged_dot — §Perf/P1 iteration 2).
    impl: str = "gshard"
    # dtype of the combine (gate-weighted) one-hot tensor; float32 is the
    # GShard default, bfloat16 halves its footprint (§Perf/P1 iter 3).
    combine_dtype: str = "float32"


@dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 64          # N (mamba2 state size per head)
    head_dim: int = 64           # P (channels per SSM head)
    expand: int = 2              # d_inner = expand * d_model
    conv_width: int = 4
    chunk_size: int = 256        # chunked-scan block length


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    source: str = ""             # citation per assignment table

    # --- attention ---
    causal: bool = True                 # False => bidirectional encoder (BERT)
    head_dim: Optional[int] = None      # default d_model // n_heads
    rope_theta: float = 10000.0
    sliding_window: Optional[int] = None  # None = full causal attention
    # whether a sliding-window variant exists for long-context decode
    long_context_variant_window: Optional[int] = 8192

    # --- mixture of experts ---
    moe: Optional[MoEConfig] = None
    # MoE applied every `moe_every` layers (1 = all layers)
    moe_every: int = 1

    # --- ssm / hybrid ---
    ssm: Optional[SSMConfig] = None
    # layer pattern for hybrid/xLSTM archs; None = homogeneous `family` stack
    block_pattern: Optional[Tuple[str, ...]] = None
    # zamba2-style shared block period (shared attn block every k blocks)
    shared_block_period: Optional[int] = None

    # --- enc-dec (audio) ---
    encoder_layers: int = 0              # >0 => encoder-decoder model
    # ratio of encoder frames to decoder tokens (frontend downsampling)
    encoder_frame_ratio: int = 4

    # --- multimodal stubs ---
    # vlm: number of image-patch tokens prepended & frontend embedding dim
    num_image_tokens: int = 0
    frontend_dim: int = 0

    # --- serving ---
    # KV cache storage dtype (None = follow `dtype`). "float8_e4m3fn"
    # halves decode cache reads (§Perf/P2 follow-up); values are upcast
    # at the attention einsum.
    kv_cache_dtype: Optional[str] = None

    # --- xLSTM ---
    # chunk length of the chunkwise-parallel mLSTM scan. The dominant
    # intermediates are (B, Q, Q, H) f32, so bytes scale ~ S*Q (§Perf/P3).
    mlstm_chunk: int = 256

    # --- training ---
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    tie_embeddings: bool = False
    zero_stage: int = 3
    remat: bool = True

    # shapes this arch cannot run (see DESIGN.md shape/skip matrix)
    skip_shapes: Tuple[str, ...] = ()

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def total_params(self) -> int:
        """Approximate parameter count (used for 6ND model-flops checks)."""
        return _count_params(self)

    @property
    def active_params(self) -> int:
        """Params active per token (MoE: top_k of num_experts)."""
        return _count_params(self, active_only=True)

    def blocks(self) -> Tuple[str, ...]:
        """Resolved per-layer block kinds for the decoder stack."""
        if self.block_pattern is not None:
            pat = self.block_pattern
            reps = (self.n_layers + len(pat) - 1) // len(pat)
            return tuple((pat * reps)[: self.n_layers])
        if self.family == "moe" or self.moe is not None:
            kinds = []
            for i in range(self.n_layers):
                kinds.append(BLOCK_MOE if (i % self.moe_every == 0) else BLOCK_ATTN)
            return tuple(kinds)
        return tuple([BLOCK_ATTN] * self.n_layers)


def _attn_params(cfg: ModelConfig) -> int:
    hd = cfg.resolved_head_dim
    q = cfg.d_model * cfg.n_heads * hd
    kv = 2 * cfg.d_model * cfg.n_kv_heads * hd
    o = cfg.n_heads * hd * cfg.d_model
    return q + kv + o


def _mlp_params(cfg: ModelConfig, d_ff: int) -> int:
    # SwiGLU: gate + up + down
    return 3 * cfg.d_model * d_ff


def _count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    emb = cfg.vocab_size * cfg.d_model
    head = 0 if cfg.tie_embeddings else cfg.vocab_size * cfg.d_model
    total = emb + head
    kinds = cfg.blocks()
    d_inner = (cfg.ssm.expand * cfg.d_model) if cfg.ssm else 0
    for kind in kinds:
        if kind == BLOCK_ATTN:
            total += _attn_params(cfg) + _mlp_params(cfg, cfg.d_ff) + 2 * cfg.d_model
        elif kind == BLOCK_MOE:
            assert cfg.moe is not None
            n_e = cfg.moe.top_k if active_only else cfg.moe.num_experts
            total += _attn_params(cfg)
            total += n_e * _mlp_params(cfg, cfg.moe.d_expert)
            total += cfg.d_model * cfg.moe.num_experts  # router
            total += 2 * cfg.d_model
        elif kind == BLOCK_MLSTM:
            # qkv + out + gates (approximate published block)
            total += 4 * cfg.d_model * 2 * cfg.d_model + 3 * cfg.d_model + cfg.d_model
        elif kind == BLOCK_SLSTM:
            total += 4 * cfg.d_model * cfg.d_model * 2 + 4 * cfg.d_model
        elif kind == BLOCK_MAMBA2:
            assert cfg.ssm is not None
            n_h = d_inner // cfg.ssm.head_dim
            total += cfg.d_model * (2 * d_inner + 2 * n_h * cfg.ssm.state_dim + n_h)
            total += d_inner * cfg.d_model  # out proj
            total += cfg.ssm.conv_width * d_inner
        elif kind == BLOCK_SHARED_ATTN:
            # weights shared across occurrences: counted once below
            pass
        total += 2 * cfg.d_model  # norms
    if BLOCK_SHARED_ATTN in kinds:
        total += _attn_params(cfg) + _mlp_params(cfg, cfg.d_ff) + 2 * cfg.d_model
    if cfg.encoder_layers:
        per = _attn_params(cfg) + _mlp_params(cfg, cfg.d_ff) + 4 * cfg.d_model
        # decoder cross-attention
        total += cfg.encoder_layers * per + cfg.n_layers * _attn_params(cfg)
    if cfg.num_image_tokens:
        total += cfg.frontend_dim * cfg.d_model  # projector
    return total


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}
_REDUCED: Dict[str, Callable[[], ModelConfig]] = {}


def register(arch_id: str, full: Callable[[], ModelConfig], reduced: Callable[[], ModelConfig]):
    _REGISTRY[arch_id] = full
    _REDUCED[arch_id] = reduced


def get_config(arch_id: str, reduced: bool = False) -> ModelConfig:
    # importing repro.configs triggers registration of all known archs
    import repro.configs  # noqa: F401
    table = _REDUCED if reduced else _REGISTRY
    if arch_id not in table:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_REGISTRY)}")
    return table[arch_id]()


def list_archs() -> List[str]:
    import repro.configs  # noqa: F401
    return sorted(_REGISTRY)


def reduce_config(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Default reduced variant: 2 layers, d_model<=512, <=4 experts."""
    small: Dict = dict(
        n_layers=2,
        d_model=min(cfg.d_model, 256),
        n_heads=min(cfg.n_heads, 4),
        n_kv_heads=min(cfg.n_kv_heads, 2),
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 1024),
        encoder_layers=2 if cfg.encoder_layers else 0,
        num_image_tokens=min(cfg.num_image_tokens, 16) if cfg.num_image_tokens else 0,
        frontend_dim=min(cfg.frontend_dim, 64) if cfg.frontend_dim else 0,
        remat=False,
    )
    if cfg.moe is not None:
        small["moe"] = replace(
            cfg.moe,
            num_experts=min(cfg.moe.num_experts, 4),
            top_k=min(cfg.moe.top_k, 2),
            d_expert=min(cfg.moe.d_expert, 256),
        )
    if cfg.ssm is not None:
        small["ssm"] = replace(cfg.ssm, state_dim=min(cfg.ssm.state_dim, 16),
                               head_dim=min(cfg.ssm.head_dim, 32), chunk_size=32)
    if cfg.block_pattern is not None:
        small["block_pattern"] = cfg.block_pattern[:2]
    small.update(overrides)
    return replace(cfg, **small)
