"""granite-moe-1b-a400m — 24L d1024 16H (GQA kv=8) vocab 49155, MoE 32e top-8.

[hf:ibm-granite/granite-3.0-1b-a400m-base]
"""
from repro.configs.base import ModelConfig, MoEConfig, reduce_config, register

ARCH_ID = "granite-moe-1b-a400m"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="moe",
        n_layers=24,
        d_model=1024,
        n_heads=16,
        n_kv_heads=8,
        d_ff=512,
        vocab_size=49155,
        moe=MoEConfig(num_experts=32, top_k=8, d_expert=512),
        tie_embeddings=True,
        source="hf:ibm-granite/granite-3.0-1b-a400m-base",
    )


def reduced() -> ModelConfig:
    return reduce_config(full())


register(ARCH_ID, full, reduced)
