"""internlm2-20b — 48L d6144 48H (GQA kv=8) d_ff=16384 vocab 92544.

[arXiv:2403.17297] — dense GQA decoder. long_500k runs via the
sliding-window variant (window 8192) per DESIGN.md.
"""
from repro.configs.base import ModelConfig, reduce_config, register

ARCH_ID = "internlm2-20b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        n_layers=48,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        d_ff=16384,
        vocab_size=92544,
        source="arXiv:2403.17297",
    )


def reduced() -> ModelConfig:
    return reduce_config(full())


register(ARCH_ID, full, reduced)
