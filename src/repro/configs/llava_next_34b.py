"""llava-next-34b — 60L d7168 56H (GQA kv=8) d_ff=20480 vocab 64000, anyres.

[hf:llava-hf/llava-v1.6-mistral-7b-hf] — VLM. The vision tower (ViT) is a
STUB per the assignment carve-out: ``input_specs()`` provides precomputed
patch embeddings (batch, num_image_tokens, frontend_dim); the multimodal
projector and language decoder are real. AnyRes tiling => base tile + 4
crops = 5 x 576 = 2880 image tokens.

long_500k is skipped: pure full-attention VLM with no sub-quadratic variant
in the source model family.
"""
from repro.configs.base import ModelConfig, reduce_config, register

ARCH_ID = "llava-next-34b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="vlm",
        n_layers=60,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        d_ff=20480,
        vocab_size=64000,
        num_image_tokens=2880,   # anyres: (1 base + 4 crops) * 576
        frontend_dim=1024,       # CLIP ViT-L/336 hidden size
        long_context_variant_window=None,
        skip_shapes=("long_500k",),
        source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
    )


def reduced() -> ModelConfig:
    return reduce_config(full())


register(ARCH_ID, full, reduced)
