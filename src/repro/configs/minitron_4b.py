"""minitron-4b — 32L d3072 24H (GQA kv=8) d_ff=9216 vocab 256000.

[arXiv:2407.14679] — pruned nemotron. Dense GQA decoder; long_500k via the
sliding-window variant (window 8192).
"""
from repro.configs.base import ModelConfig, reduce_config, register

ARCH_ID = "minitron-4b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        n_layers=32,
        d_model=3072,
        n_heads=24,
        n_kv_heads=8,
        d_ff=9216,
        vocab_size=256000,
        source="arXiv:2407.14679",
    )


def reduced() -> ModelConfig:
    return reduce_config(full())


register(ARCH_ID, full, reduced)
