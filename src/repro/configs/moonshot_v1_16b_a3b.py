"""moonshot-v1-16b-a3b — 48L d2048 16H (GQA kv=16) d_ff=1408 vocab 163840,
MoE 64 experts top-6 (kimi/moonlight-style DeepSeek-V3 MoE).

[hf:moonshotai/Moonlight-16B-A3B] — assignment tags it [dense] but specifies
"MoE 64e top-6"; Moonlight is a fine-grained MoE, we follow the explicit
expert spec.
"""
from repro.configs.base import ModelConfig, MoEConfig, reduce_config, register

ARCH_ID = "moonshot-v1-16b-a3b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="moe",
        n_layers=48,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=1408,
        vocab_size=163840,
        moe=MoEConfig(num_experts=64, top_k=6, d_expert=1408),
        source="hf:moonshotai/Moonlight-16B-A3B",
    )


def reduced() -> ModelConfig:
    return reduce_config(full())


register(ARCH_ID, full, reduced)
