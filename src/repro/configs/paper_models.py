"""The paper's own evaluation models: Llama 0.5B / 1.1B and BERT 1.1B.

Poplar's experiments (Fig. 3–5) train a 0.5B Llama; Fig. 4 adds a 1.1B
Llama and a 1.1B BERT. Sizes follow common published configs of those
parameter counts (the paper does not list exact dims).
"""
from repro.configs.base import ModelConfig, reduce_config, register


def llama_0p5b() -> ModelConfig:
    return ModelConfig(
        name="llama-0.5b",
        family="dense",
        n_layers=24,
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        d_ff=2816,
        vocab_size=32000,
        source="paper main experiments (Touvron et al. 2023 family)",
    )


def llama_1p1b() -> ModelConfig:
    # TinyLlama-1.1B dims
    return ModelConfig(
        name="llama-1.1b",
        family="dense",
        n_layers=22,
        d_model=2048,
        n_heads=32,
        n_kv_heads=4,
        d_ff=5632,
        vocab_size=32000,
        source="paper Fig.4 (1.1B Llama)",
    )


def bert_1p1b() -> ModelConfig:
    # BERT-style bidirectional encoder scaled to ~1.1B
    return ModelConfig(
        name="bert-1.1b",
        family="dense",
        causal=False,
        n_layers=24,
        d_model=1792,
        n_heads=28,
        n_kv_heads=28,
        d_ff=7168,
        vocab_size=30522,
        long_context_variant_window=None,
        skip_shapes=("decode_32k", "long_500k"),  # encoder-only: no decode
        source="paper Fig.4 (1.1B BERT; Devlin et al. 2019)",
    )


register("llama-0.5b", llama_0p5b, lambda: reduce_config(llama_0p5b()))
register("llama-1.1b", llama_1p1b, lambda: reduce_config(llama_1p1b()))
register("bert-1.1b", bert_1p1b, lambda: reduce_config(bert_1p1b()))
