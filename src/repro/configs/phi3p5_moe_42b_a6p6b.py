"""phi3.5-moe-42b-a6.6b — 32L d4096 32H (GQA kv=8) d_ff=6400 vocab 32064,
MoE 16 experts top-2.

[hf:microsoft/Phi-3.5-MoE-instruct]
"""
from repro.configs.base import ModelConfig, MoEConfig, reduce_config, register

ARCH_ID = "phi3.5-moe-42b-a6.6b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="moe",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=6400,
        vocab_size=32064,
        moe=MoEConfig(num_experts=16, top_k=2, d_expert=6400),
        source="hf:microsoft/Phi-3.5-MoE-instruct",
    )


def reduced() -> ModelConfig:
    return reduce_config(full())


register(ARCH_ID, full, reduced)
