"""seamless-m4t-medium — enc-dec, 12L d1024 16H d_ff=4096 vocab 256206.

[arXiv:2308.11596] — multimodal speech/text translation. The modality
frontend (mel-spectrogram + conv feature extractor) is a STUB per the
assignment carve-out: ``input_specs()`` provides precomputed frame
embeddings of shape (batch, seq/encoder_frame_ratio, d_model).

long_500k is skipped (enc-dec speech translation has no meaningful 500k-token
decode operating point, and the decoder is pure full attention).
"""
from repro.configs.base import ModelConfig, reduce_config, register

ARCH_ID = "seamless-m4t-medium"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="audio",
        n_layers=12,            # decoder layers
        encoder_layers=12,      # speech/text encoder layers
        encoder_frame_ratio=4,
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        d_ff=4096,
        vocab_size=256206,
        long_context_variant_window=None,
        skip_shapes=("long_500k",),
        source="arXiv:2308.11596",
    )


def reduced() -> ModelConfig:
    return reduce_config(full())


register(ARCH_ID, full, reduced)
