"""The four assigned input shapes and the shape/arch skip matrix."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Tuple


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    mode: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def get_shape(name: str) -> InputShape:
    return SHAPES[name]


def applicable(arch_cfg, shape_name: str) -> Tuple[bool, str]:
    """Returns (runs?, reason). Skip matrix per DESIGN.md §4."""
    if shape_name in arch_cfg.skip_shapes:
        if shape_name == "long_500k":
            return False, (
                "long_500k skipped: pure full-attention arch with no "
                "sub-quadratic variant (see DESIGN.md shape/skip matrix)")
        return False, f"{shape_name} skipped per config"
    return True, ""


def matrix(arch_ids: List[str]) -> List[Tuple[str, str, bool, str]]:
    from repro.configs.base import get_config
    rows = []
    for a in arch_ids:
        cfg = get_config(a)
        for s in SHAPES:
            ok, why = applicable(cfg, s)
            rows.append((a, s, ok, why))
    return rows
