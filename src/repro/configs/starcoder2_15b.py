"""starcoder2-15b — 40L d6144 48H (GQA kv=4) d_ff=24576 vocab 49152.

[arXiv:2402.19173] — GQA + RoPE. The published model uses a 4096-token
sliding window; we keep full attention for train/prefill/decode_32k (matching
the assignment's dense tag) and use the model's own 4096 window for the
long_500k sub-quadratic variant.
"""
from repro.configs.base import ModelConfig, reduce_config, register

ARCH_ID = "starcoder2-15b"


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        n_layers=40,
        d_model=6144,
        n_heads=48,
        n_kv_heads=4,
        d_ff=24576,
        vocab_size=49152,
        long_context_variant_window=4096,  # the model's own window size
        source="arXiv:2402.19173",
    )


def reduced() -> ModelConfig:
    return reduce_config(full())


register(ARCH_ID, full, reduced)
