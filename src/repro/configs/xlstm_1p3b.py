"""xlstm-1.3b — 48L d2048 4H vocab 50304, sLSTM + mLSTM blocks (xLSTM[7:1]).

[arXiv:2405.04517] — attention-free recurrent arch; d_ff=0 (projections live
inside the blocks). Runs long_500k natively (constant-size recurrent state).
"""
from repro.configs.base import (BLOCK_MLSTM, BLOCK_SLSTM, ModelConfig,
                                reduce_config, register)

ARCH_ID = "xlstm-1.3b"

# xLSTM[7:1]: one sLSTM block per 8 layers, rest mLSTM.
_PATTERN = (BLOCK_MLSTM,) * 7 + (BLOCK_SLSTM,)


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="ssm",
        n_layers=48,
        d_model=2048,
        n_heads=4,
        n_kv_heads=4,
        d_ff=0,
        vocab_size=50304,
        block_pattern=_PATTERN,
        long_context_variant_window=None,  # no attention at all
        source="arXiv:2405.04517",
    )


def reduced() -> ModelConfig:
    return reduce_config(full(), block_pattern=(BLOCK_MLSTM, BLOCK_SLSTM))


register(ARCH_ID, full, reduced)
