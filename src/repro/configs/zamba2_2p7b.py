"""zamba2-2.7b — 54L d2560 32H (GQA kv=32) d_ff=10240 ssm_state=64, hybrid.

[arXiv:2411.15242] — Mamba2 backbone with a weight-shared attention+MLP
block applied every 6 layers (Zamba2 shares two alternating blocks; we model
one shared block and note the simplification in DESIGN.md). Runs long_500k
natively: SSM state is constant-size and the shared attention block uses the
long-context sliding window.
"""
from repro.configs.base import (BLOCK_MAMBA2, BLOCK_SHARED_ATTN, ModelConfig,
                                SSMConfig, reduce_config, register)

ARCH_ID = "zamba2-2.7b"

# 5 mamba2 blocks then one shared attention block, repeated.
_PATTERN = (BLOCK_MAMBA2,) * 5 + (BLOCK_SHARED_ATTN,)


def full() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="hybrid",
        n_layers=54,
        d_model=2560,
        n_heads=32,
        n_kv_heads=32,
        d_ff=10240,
        vocab_size=32000,
        ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, chunk_size=256),
        block_pattern=_PATTERN,
        shared_block_period=6,
        source="arXiv:2411.15242",
    )


def reduced() -> ModelConfig:
    return reduce_config(full(), block_pattern=(BLOCK_MAMBA2, BLOCK_SHARED_ATTN))


register(ARCH_ID, full, reduced)
