"""Offline analysis — Algorithm 2 (Optimal Batch Size Searching) + the
baseline allocation strategies Poplar is compared against.

From each device's profile (probe points of TimeConsumedDuringStep), we fit
speed(b) = b / t(b) with a natural cubic spline, then:

- ZeRO-0/1: allocate gbs proportionally to peak speeds, then hand out the
  integer remainder to the device with the most headroom (u_i = δt_i·p_i);
  each device consumes its share `gmbs_i` by gradient accumulation at its
  peak-speed micro-batch with a final partial `lbs_i` step.
- ZeRO-2/3: sweep the per-microstep time budget t; `find(g_i,t)` inverts
  each device's time curve to the largest batch finishing within t;
  minimize (t + t_comm)·gas over the sweep (load balance vs collective
  count trade-off).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.profiler import DeviceProfile
from repro.core.spline import CubicSpline, fit_natural_cubic, max_of_spline


# ---------------------------------------------------------------------------
# performance curves
# ---------------------------------------------------------------------------

@dataclass
class PerfCurve:
    """speed(b) spline + derived helpers for one device."""
    name: str
    mbs: int
    speed: CubicSpline            # samples/sec as a function of batch
    peak_batch: float             # argmax of speed on [1, mbs]
    peak_speed: float             # samples/sec at peak_batch

    def time_of_batch(self, b: float) -> float:
        if b <= 0:
            return 0.0
        s = max(self.speed(min(b, self.mbs)), 1e-9)
        return b / s

    def find_batch_within(self, t: float) -> int:
        """Largest integer batch with time(b) <= t (paper's `find`)."""
        if t <= 0 or self.mbs < 1:
            return 0
        lo, hi = 0, self.mbs
        while lo < hi:
            mid = (lo + hi + 1) // 2
            if self.time_of_batch(mid) <= t:
                lo = mid
            else:
                hi = mid - 1
        return lo


def fit_curve(profile: DeviceProfile) -> PerfCurve:
    bs, sp = profile.speed_points()
    if len(bs) == 1:
        bs = np.array([bs[0], bs[0] + 1.0])
        sp = np.array([sp[0], sp[0]])
    spline = fit_natural_cubic(bs, sp)
    pb, ps = max_of_spline(spline, 1.0, float(profile.mbs))
    return PerfCurve(profile.name, profile.mbs, spline, pb, ps)


# ---------------------------------------------------------------------------
# allocation plans
# ---------------------------------------------------------------------------

@dataclass
class DeviceAssignment:
    name: str
    gmbs: int          # samples this device processes per iteration
    micro_batch: int   # steady-state micro-batch (gradient accumulation)
    gas: int           # accumulation steps (incl. final partial)
    lbs: int           # last (partial) batch size; 0 = all steps full
    predicted_time: float = 0.0


@dataclass
class AllocationPlan:
    strategy: str
    zero_stage: int
    assignments: Dict[str, DeviceAssignment]
    predicted_iter_time: float = 0.0
    # for stage>=2 plans: the swept per-microstep budget chosen
    micro_time_budget: Optional[float] = None
    global_gas: Optional[int] = None

    @property
    def total_batch(self) -> int:
        return sum(a.gmbs for a in self.assignments.values())


def _accum_schedule(gmbs: int, micro: int) -> Tuple[int, int, int]:
    """(micro_batch, gas, lbs) to cover gmbs by accumulation."""
    if gmbs <= 0:
        return 0, 0, 0
    micro = max(1, min(micro, gmbs))
    full, rem = divmod(gmbs, micro)
    gas = full + (1 if rem else 0)
    return micro, gas, rem


def _device_iter_time(curve: PerfCurve, a: DeviceAssignment) -> float:
    if a.gmbs <= 0:
        return 0.0
    t = (a.gas - (1 if a.lbs else 0)) * curve.time_of_batch(a.micro_batch)
    if a.lbs:
        t += curve.time_of_batch(a.lbs)
    return t


# ----------------------------- ZeRO-0/1 -----------------------------------

def allocate_stage01(curves: Dict[str, PerfCurve], gbs: int) -> AllocationPlan:
    names = list(curves)
    speeds = {n: curves[n].peak_speed for n in names}
    total_speed = sum(speeds.values())
    time_opt = gbs / max(total_speed, 1e-9)
    gmbs = {n: int(math.floor(time_opt * speeds[n])) for n in names}
    # integer remainder: repeatedly give one sample to the device with the
    # largest headroom u_i = δt_i · p_i (most under-utilized).
    remain = gbs - sum(gmbs.values())
    while remain > 0:
        times = {n: gmbs[n] / max(speeds[n], 1e-9) for n in names}
        T = max(times.values())
        u = {n: (T - times[n]) * speeds[n] for n in names}
        target = max(names, key=lambda n: (u[n], speeds[n]))
        gmbs[target] += 1
        remain -= 1
    assigns = {}
    for n in names:
        micro = max(1, min(int(round(curves[n].peak_batch)), curves[n].mbs))
        m, gas, lbs = _accum_schedule(gmbs[n], micro)
        a = DeviceAssignment(n, gmbs[n], m, gas, lbs)
        a.predicted_time = _device_iter_time(curves[n], a)
        assigns[n] = a
    plan = AllocationPlan("poplar", 1, assigns)
    plan.predicted_iter_time = max((a.predicted_time for a in assigns.values()),
                                   default=0.0)
    return plan


# ----------------------------- ZeRO-2/3 -----------------------------------

def allocate_stage23(curves: Dict[str, PerfCurve], gbs: int,
                     comm_time_per_step: float, zero_stage: int,
                     sweep_points: int = 200,
                     overlap_factor: float = 0.0) -> AllocationPlan:
    """Algorithm 2's per-microstep time-budget sweep. ``overlap_factor``
    models the scheduled ZeRO path: only the *exposed* part of the
    per-step collective extends the wall time, which shifts the sweep's
    load-balance vs. collective-count trade-off (hiding comm under
    compute makes extra accumulation steps cheaper, so shorter budgets /
    more micro-steps can win)."""
    from repro.core.workload import exposed_comm_time
    names = list(curves)
    t_min = min(curves[n].time_of_batch(1) for n in names)
    t_max = max(curves[n].time_of_batch(curves[n].mbs) for n in names)
    best = None
    for t in np.linspace(t_min, t_max, sweep_points):
        bs = {n: curves[n].find_batch_within(float(t)) for n in names}
        msbs = sum(bs.values())
        if msbs <= 0:
            continue
        gas = math.ceil(gbs / msbs)
        # actual per-microstep time is the max over devices of their chosen b
        t_step = max(curves[n].time_of_batch(bs[n]) for n in names)
        comm_exposed = exposed_comm_time(comm_time_per_step, t_step,
                                         overlap_factor)
        wall = (t_step + comm_exposed) * gas
        if best is None or wall < best[0]:
            best = (wall, dict(bs), gas, float(t))
    assert best is not None, "no feasible allocation"
    wall, bs, gas, t_budget = best
    assigns = {}
    for n in names:
        gmbs_n = bs[n] * gas
        m, g, lbs = _accum_schedule(gmbs_n, bs[n])
        a = DeviceAssignment(n, gmbs_n, m, g, lbs)
        a.predicted_time = _device_iter_time(curves[n], a)
        assigns[n] = a
    # trim overshoot (Σ b_i·gas >= gbs): shave the final partial steps of the
    # fastest devices so Σ gmbs == gbs exactly.
    over = sum(a.gmbs for a in assigns.values()) - gbs
    order = sorted(names, key=lambda n: -curves[n].peak_speed)
    i = 0
    while over > 0 and any(a.gmbs > 0 for a in assigns.values()):
        n = order[i % len(order)]
        a = assigns[n]
        take = min(over, a.micro_batch if a.gmbs >= a.micro_batch else a.gmbs)
        take = min(take, a.gmbs)
        if take > 0:
            a.gmbs -= take
            m, g, lbs = _accum_schedule(a.gmbs, a.micro_batch or 1)
            a.micro_batch, a.gas, a.lbs = m, g, lbs
            a.predicted_time = _device_iter_time(curves[n], a)
            over -= take
        i += 1
    plan = AllocationPlan("poplar", zero_stage, assigns,
                          micro_time_budget=t_budget, global_gas=gas)
    plan.predicted_iter_time = wall
    return plan


# ---------------------------------------------------------------------------
# baselines
# ---------------------------------------------------------------------------

def allocate_uniform(curves: Dict[str, PerfCurve], gbs: int,
                     zero_stage: int) -> AllocationPlan:
    """DeepSpeed-style: identical micro-batch everywhere, bounded by the
    weakest device's mbs (manually 'tuned' to the max feasible)."""
    names = list(curves)
    n = len(names)
    micro = max(1, min(c.mbs for c in curves.values()))
    per_dev = gbs // n
    rem = gbs - per_dev * n
    assigns = {}
    for i, name in enumerate(names):
        gmbs = per_dev + (1 if i < rem else 0)
        m, gas, lbs = _accum_schedule(gmbs, micro)
        a = DeviceAssignment(name, gmbs, m, gas, lbs)
        a.predicted_time = _device_iter_time(curves[name], a)
        assigns[name] = a
    plan = AllocationPlan("deepspeed-uniform", zero_stage, assigns)
    plan.predicted_iter_time = max(a.predicted_time for a in assigns.values())
    return plan


def allocate_flops_proportional(curves: Dict[str, PerfCurve], gbs: int,
                                zero_stage: int,
                                flops_rating: Dict[str, float]) -> AllocationPlan:
    """Whale-style: split by *spec-sheet FLOPs* rating (the paper's point:
    FLOPs alone mispredicts real heterogeneous performance)."""
    names = list(curves)
    total = sum(flops_rating[n] for n in names)
    assigns = {}
    given = 0
    for name in names:
        share = int(round(gbs * flops_rating[name] / total))
        share = min(share, gbs - given)
        given += share
        micro = max(1, min(int(round(curves[name].peak_batch)), curves[name].mbs))
        m, gas, lbs = _accum_schedule(share, micro)
        a = DeviceAssignment(name, share, m, gas, lbs)
        a.predicted_time = _device_iter_time(curves[name], a)
        assigns[name] = a
    # dump any rounding remainder on the highest-rated device
    if given < gbs:
        top = max(names, key=lambda n: flops_rating[n])
        a = assigns[top]
        a.gmbs += gbs - given
        m, gas, lbs = _accum_schedule(a.gmbs, a.micro_batch or 1)
        a.micro_batch, a.gas, a.lbs = m, gas, lbs
        a.predicted_time = _device_iter_time(curves[top], a)
    plan = AllocationPlan("whale-flops", zero_stage, assigns)
    plan.predicted_iter_time = max(a.predicted_time for a in assigns.values())
    return plan


def allocate_homogeneous(curves: Dict[str, PerfCurve], gbs: int,
                         zero_stage: int, keep: List[str]) -> AllocationPlan:
    """Baselines 1/2: use only the weak (or strong) homogeneous sub-cluster."""
    sub = {n: curves[n] for n in keep}
    plan = allocate_uniform(sub, gbs, zero_stage)
    plan.strategy = "homogeneous"
    return plan
