"""Multi-tenant cluster arbitration: N Sessions, one physical cluster.

A production cluster is never owned by one job — the realistic
heavy-traffic shape is a train Session and a serve Session co-located on
the same devices. PR 7's Supervisor recovers each Session in isolation
(``replan(cluster=survivors)``), which is locally correct and globally
naive: tenant A's loss should be able to shrink A, make B donate, or —
when A is higher priority — be absorbed entirely by B.

:class:`ClusterArbiter` owns the physical :class:`ClusterSpec` and
leases disjoint device subsets to registered tenants. Arbitration is
Algorithm-1-native: for each candidate partition of the healthy devices
it runs every tenant's *own* planner constrained to its tentative lease
— the train tenant's Poplar plan (measured profiles flow through the
session's shared ``profile_cache``, so candidate sweeps cost no new
probes) and the serve tenant's decode-wave plan — and picks the
partition maximizing summed weighted utility subject to every tenant's
``min_devices`` floor. When no partition satisfies all floors, the
arbiter degrades gracefully in priority order: the lowest-priority
tenant is suspended behind a drained, committed checkpoint
(EventLog-recorded) and auto-resumes when devices return.

:class:`TenantSupervisor` is the PR-7 Supervisor with its
membership-change recovery routed through the arbiter: a
``DeviceLossError`` in any tenant triggers *one* global re-arbitration
(simultaneous reports of the same physical loss converge — no replan
storm), after which every surviving tenant runs on its new lease.
"""
from __future__ import annotations

import itertools
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.cluster import ClusterSpec, make_cluster
from repro.core.faults import (FaultPolicy, FaultToleranceExhausted,
                               Supervisor)
from repro.core.profiler import SimOOM
from repro.core.telemetry import (ArbitrationReport, DriftConfig, EMAWindow,
                                  EventLog, detect_drift)


class TenantSuspended(RuntimeError):
    """Raised to a tenant's driver when arbitration left *this* tenant
    without a lease (it was the lowest-priority floor that had to give).
    The tenant's state is committed — it auto-resumes on
    :meth:`ClusterArbiter.restore_devices`."""


@dataclass
class Tenant:
    """One registered workload plus its runtime bindings."""
    name: str
    kind: str                          # "train" | "serve"
    cfg: object
    priority: int = 0                  # higher = kept longer under pressure
    min_devices: int = 1               # lease floor (else: suspend)
    weight: float = 1.0                # utility scale in the global objective
    policy: FaultPolicy = field(default_factory=FaultPolicy)
    # train workload
    gbs: int = 0
    seq: int = 0
    zero: Optional[int] = None
    # serve workload
    requests: int = 0
    cache_len: int = 0
    ckpt_path: Optional[str] = None
    # runtime bindings
    session: object = None
    supervisor: Optional[Supervisor] = None
    suspended: bool = False
    lease: Optional[ClusterSpec] = None
    lease_devices: Tuple[str, ...] = ()
    last_plan: object = None
    predicted_utility: float = 0.0
    # serve-side drift: observed wave latencies vs last_plan.wave_latency
    observed: EMAWindow = field(default_factory=lambda: EMAWindow(warmup=0))
    _drift_baseline: Optional[float] = None
    _order: int = 0                    # registration order (tiebreak)


class ClusterArbiter:
    """Owns the physical cluster; leases disjoint, exhaustive device
    subsets to tenants and re-arbitrates on fault, drift, or device
    return. See the module docstring for the algorithm."""

    def __init__(self, cluster: ClusterSpec, *,
                 events: Optional[EventLog] = None,
                 drift: Optional[DriftConfig] = None,
                 max_candidates: int = 4096):
        self.cluster = cluster
        self.events = events if events is not None else EventLog()
        self.drift_config = drift or DriftConfig()
        self.max_candidates = max_candidates
        # instance names in cluster device order, profiling's per-kind
        # numbering ("V100-16G#1", ...)
        counts: Dict[str, int] = {}
        self.instances: List[str] = []
        self._kind_order: List[str] = []
        for spec in cluster.devices:
            if spec.name not in counts:
                self._kind_order.append(spec.name)
            counts[spec.name] = counts.get(spec.name, 0) + 1
            self.instances.append(f"{spec.name}#{counts[spec.name]}")
        self.healthy = set(self.instances)
        self.lost: set = set()
        self.tenants: Dict[str, Tenant] = {}
        # measured DeviceProfiles shared across every tenant's planner —
        # re-arbitration candidate sweeps reuse cached probes
        self.probe_cache: Dict = {}
        # per-(tenant, composition) predicted utility; cleared when the
        # workload changes (drift re-arbitration, serve load update)
        self._utility_cache: Dict[Tuple, Optional[float]] = {}
        self.arbitrations = 0
        self.last_report: Optional[ArbitrationReport] = None
        self._next_order = 0

    # ------------------------------------------------------ registration --
    def register_train(self, name: str, cfg, *, gbs: int, seq: int,
                       zero: Optional[int] = None, priority: int = 0,
                       min_devices: int = 1, weight: float = 1.0,
                       policy: Optional[FaultPolicy] = None,
                       ckpt_path: Optional[str] = None) -> Tenant:
        return self._register(Tenant(
            name, "train", cfg, priority=priority, min_devices=min_devices,
            weight=weight, policy=policy or FaultPolicy(), gbs=gbs, seq=seq,
            zero=zero, ckpt_path=ckpt_path))

    def register_serve(self, name: str, cfg, *, requests: int,
                       cache_len: int, priority: int = 0,
                       min_devices: int = 1, weight: float = 1.0,
                       policy: Optional[FaultPolicy] = None,
                       ckpt_path: Optional[str] = None) -> Tenant:
        return self._register(Tenant(
            name, "serve", cfg, priority=priority, min_devices=min_devices,
            weight=weight, policy=policy or FaultPolicy(),
            requests=requests, cache_len=cache_len, ckpt_path=ckpt_path))

    def _register(self, t: Tenant) -> Tenant:
        if t.name in self.tenants:
            raise ValueError(f"tenant {t.name!r} already registered")
        if t.min_devices < 1:
            raise ValueError("min_devices must be >= 1")
        t._order = self._next_order
        self._next_order += 1
        self.tenants[t.name] = t
        return t

    def attach(self, name: str, session, *, schedule=None,
               save_every: int = 0, async_save: bool = True,
               keep_last: Optional[int] = None,
               supervised: bool = True) -> Optional[Supervisor]:
        """Bind a built Session (on the tenant's current lease) to its
        tenant: shared probe cache, shared event log, and — by default —
        a :class:`TenantSupervisor` routing membership faults here."""
        t = self.tenants[name]
        t.session = session
        session.lease = t.lease
        # probes the session already paid for join the shared pool
        self.probe_cache.update(session._profile_cache)
        session._profile_cache = self.probe_cache
        # one continuous multi-tenant log: merge what the session already
        # recorded (tagged), then share
        for ev in session.events:
            if not ev.tenant:
                ev.tenant = name
            self.events.events.append(ev)
        session.events = self.events
        if supervised:
            t.supervisor = TenantSupervisor(
                self, name, session, schedule=schedule,
                ckpt_path=t.ckpt_path, save_every=save_every,
                async_save=async_save, keep_last=keep_last)
        elif schedule is not None:
            session.attach_faults(schedule)
        return t.supervisor

    # ---------------------------------------------------------- leases ----
    @property
    def leases(self) -> Dict[str, Optional[ClusterSpec]]:
        return {n: t.lease for n, t in self.tenants.items()}

    def _composition(self, comp: Dict[str, int]) -> List[Tuple[str, int]]:
        return [(k, comp[k]) for k in self._kind_order if comp.get(k, 0) > 0]

    def _lease_cluster(self, name: str, comp: Dict[str, int]) -> ClusterSpec:
        return make_cluster(f"{self.cluster.name}/{name}",
                            self._composition(comp),
                            self.cluster.inter_link_gbps,
                            shared_bus=self.cluster.shared_bus)

    def _healthy_counts(self) -> Dict[str, int]:
        counts = {k: 0 for k in self._kind_order}
        for inst in self.healthy:
            counts[inst.split("#")[0]] += 1
        return counts

    def _assign_instances(self, partition: Dict[str, Dict[str, int]]
                          ) -> Dict[str, Tuple[str, ...]]:
        """Concrete instances per tenant: per-kind healthy pools in
        instance order, tenants take from the front in priority order —
        disjoint and exhaustive over the healthy set by construction."""
        pools = {k: [i for i in self.instances
                     if i in self.healthy and i.split("#")[0] == k]
                 for k in self._kind_order}
        out: Dict[str, Tuple[str, ...]] = {}
        for t in self._ranked():
            if t.name not in partition:
                continue
            grab: List[str] = []
            for k, c in partition[t.name].items():
                grab.extend(pools[k][:c])
                pools[k] = pools[k][c:]
            out[t.name] = tuple(grab)
        return out

    def _ranked(self) -> List[Tenant]:
        return sorted(self.tenants.values(),
                      key=lambda t: (-t.priority, t._order))

    # --------------------------------------------------------- utility ----
    def _tenant_utility(self, t: Tenant, comp: Dict[str, int]
                        ) -> Optional[Tuple[float, object]]:
        """Weighted predicted utility of ``t`` on a lease of composition
        ``comp`` (None = infeasible there). Train: Poplar plan tokens/sec.
        Serve: decode-wave requests/sec (1 / predicted wave latency,
        scaled by wave size)."""
        lease = self._lease_cluster(t.name, comp)
        try:
            if t.kind == "train":
                if t.session is not None and not t.suspended:
                    plan = t.session._run_planner(
                        lease, t.session.rules.overlap)
                else:
                    from repro.core.planner import plan as poplar_plan
                    plan = poplar_plan(lease, t.cfg, t.gbs, seq_len=t.seq,
                                       zero_stage=t.zero,
                                       profile_cache=self.probe_cache)
                tput = plan.predicted.tokens_per_sec if plan.predicted \
                    else 0.0
                return t.weight * tput, plan
            from repro.core.planner import plan_serve
            plan = plan_serve(lease, t.cfg, t.requests, t.cache_len,
                              profile_cache=self.probe_cache)
            return t.weight * plan.requests_per_sec, plan
        except SimOOM:
            return None

    def _cached_utility(self, t: Tenant, comp: Dict[str, int]
                        ) -> Optional[Tuple[float, object]]:
        key = (t.name, tuple(sorted(comp.items())))
        if key not in self._utility_cache:
            self._utility_cache[key] = self._tenant_utility(t, comp)
        return self._utility_cache[key]

    def evaluate_partition(self, partition: Dict[str, Dict[str, int]]
                           ) -> Optional[float]:
        """Summed weighted utility of an explicit partition (None when
        any tenant is infeasible on its share) — the benchmark surface
        for comparing the arbiter's pick against a naive split."""
        total = 0.0
        for name, comp in partition.items():
            got = self._cached_utility(self.tenants[name], comp)
            if got is None:
                return None
            total += got[0]
        return total

    def even_partition(self, names: Optional[List[str]] = None
                       ) -> Dict[str, Dict[str, int]]:
        """The naive baseline: each device kind split evenly across
        tenants, remainders to earlier (higher-priority) tenants —
        heterogeneity-blind by design."""
        keep = [t.name for t in self._ranked()] if names is None else names
        counts = self._healthy_counts()
        out: Dict[str, Dict[str, int]] = {n: {} for n in keep}
        for k, total in counts.items():
            base, rem = divmod(total, len(keep))
            for i, n in enumerate(keep):
                c = base + (1 if i < rem else 0)
                if c:
                    out[n][k] = c
        return out

    # ------------------------------------------------------- candidates ---
    @staticmethod
    def _splits(total: int, n: int):
        """All n-tuples of non-negative ints summing to total."""
        if n == 1:
            yield (total,)
            return
        for first in range(total + 1):
            for rest in ClusterArbiter._splits(total - first, n - 1):
                yield (first,) + rest

    def _candidates(self, keep: List[Tenant]):
        counts = self._healthy_counts()
        kinds = [k for k in self._kind_order if counts[k] > 0]
        per_kind = [list(self._splits(counts[k], len(keep))) for k in kinds]
        emitted = 0
        for combo in itertools.product(*per_kind):
            partition = {}
            ok = True
            for i, t in enumerate(keep):
                comp = {k: combo[j][i] for j, k in enumerate(kinds)
                        if combo[j][i] > 0}
                if sum(comp.values()) < t.min_devices:
                    ok = False
                    break
                partition[t.name] = comp
            if not ok:
                continue
            yield partition
            emitted += 1
            if emitted >= self.max_candidates:
                return

    # ------------------------------------------------------ arbitration ---
    def arbitrate(self, trigger: str = "explicit") -> ArbitrationReport:
        """One global arbitration round: search candidate partitions of
        the healthy devices over the largest feasible top-priority tenant
        subset, apply the winner (suspend the dropped, replan/resume the
        kept), and report."""
        t0 = time.monotonic()
        if trigger in ("drift", "return"):
            # the workload (or the measurement substrate) changed — stale
            # predicted utilities must not decide the new partition
            self._utility_cache.clear()
        ranked = self._ranked()
        evaluated = 0
        best = None
        kept: List[Tenant] = []
        for n_keep in range(len(ranked), 0, -1):
            keep = ranked[:n_keep]
            floor = sum(t.min_devices for t in keep)
            if floor > len(self.healthy):
                continue
            for partition in self._candidates(keep):
                evaluated += 1
                utils = {}
                plans = {}
                total = 0.0
                feasible = True
                for t in keep:
                    got = self._cached_utility(t, partition[t.name])
                    if got is None:
                        feasible = False
                        break
                    utils[t.name], plans[t.name] = got
                    total += got[0]
                if feasible and (best is None or total > best[0]):
                    best = (total, partition, utils, plans)
            if best is not None:
                kept = keep
                break
        if best is None:
            self.events.emit("gave_up", detail=(
                f"no feasible partition of {len(self.healthy)} healthy "
                f"devices for any tenant subset"))
            raise FaultToleranceExhausted(
                f"no feasible partition of {len(self.healthy)} healthy "
                f"devices satisfies any tenant's floor")
        total, partition, utils, plans = best
        devices = self._assign_instances(partition)
        dropped = [t for t in ranked if t.name not in partition]

        # suspend the dropped first — their devices are in the new leases
        for t in dropped:
            self._suspend_tenant(t)
        for t in kept:
            self._apply_lease(t, partition[t.name], devices[t.name],
                              plans[t.name], utils[t.name], trigger)

        self.arbitrations += 1
        report = ArbitrationReport(
            trigger=trigger, partition=partition, devices=devices,
            suspended=[t.name for t in dropped], utility=total,
            per_tenant_utility=utils, candidates=evaluated,
            healthy=len(self.healthy), seconds=time.monotonic() - t0)
        self.last_report = report
        self.events.emit(
            "arbitrated",
            detail=(f"trigger={trigger} "
                    + " ".join(f"{n}={sum(c.values())}dev"
                               for n, c in partition.items())
                    + (f" suspended={'+'.join(report.suspended)}"
                       if report.suspended else "")
                    + f" utility={total:.1f} candidates={evaluated}"),
            seconds=report.seconds)
        return report

    def _suspend_tenant(self, t: Tenant) -> None:
        already = t.suspended
        t.suspended = True
        t.lease, t.lease_devices = None, ()
        t.predicted_utility = 0.0
        if t.session is not None:
            t.session.lease = None
            if not already:
                t.session.suspend(t.ckpt_path,
                                  reason=f"lease revoked ({t.name})")
        if not already:
            self.events.emit("tenant_suspended", tenant=t.name,
                             detail=f"priority={t.priority} "
                                    f"min_devices={t.min_devices}"
                                    + (" ckpt committed"
                                       if t.ckpt_path else ""))

    def _apply_lease(self, t: Tenant, comp: Dict[str, int],
                     instances: Tuple[str, ...], plan, utility: float,
                     trigger: str) -> None:
        lease = self._lease_cluster(t.name, comp)
        unchanged = (not t.suspended
                     and t.lease_devices == instances
                     and t.lease is not None)
        t.last_plan = plan
        t.predicted_utility = utility
        was_suspended = t.suspended
        t.suspended = False
        t.lease, t.lease_devices = lease, instances
        if t.session is None:
            return
        t.session.lease = lease
        if was_suspended:
            t.session.resume(cluster=lease, ckpt_path=t.ckpt_path,
                             trigger=trigger)
            t.observed.reset()
            t._drift_baseline = None
            self.events.emit("tenant_resumed", tenant=t.name,
                             detail=f"{lease.n} devices")
        elif not unchanged:
            t.session.replan(cluster=lease, trigger=trigger)
            t.observed.reset()
            t._drift_baseline = None
        # unchanged lease: no-op — this is what keeps simultaneous fault
        # reports from cascading into a replan storm

    # ----------------------------------------------------------- faults ---
    def _resolve_lost(self, names: List[str]) -> List[str]:
        """Map reported losses to concrete instances: ``kind#N`` passes
        through; a bare kind loses its highest-numbered healthy instance
        not already claimed by this report — ``["V100", "V100"]`` must
        resolve to two distinct instances, matching ``drop_devices``'s
        per-name counting — (or a sentinel when none remain:
        already-handled loss)."""
        out: List[str] = []
        taken: set = set()
        for name in names:
            if "#" in name:
                out.append(name)
                taken.add(name)
                continue
            pool = sorted((i for i in self.healthy
                           if i.split("#")[0] == name and i not in taken),
                          key=lambda i: int(i.split("#")[1]))
            pick = pool[-1] if pool else f"{name}#?"
            out.append(pick)
            taken.add(pick)
        return out

    def handle_fault(self, tenant_name: str, exc,
                     step_idx: int = 0) -> Optional[ArbitrationReport]:
        """Route one tenant's DeviceLossError through global
        re-arbitration. Losses already absorbed by a previous round (the
        co-tenant reporting the same physical devices) converge to a
        no-op — exactly one re-arbitration per physical event."""
        lost = self._resolve_lost(list(getattr(exc, "lost", [])))
        fresh = [i for i in lost if i in self.healthy]
        if not fresh:
            self.events.emit("fault_converged", step=step_idx,
                             tenant=tenant_name,
                             detail="+".join(lost) + " already arbitrated")
            return None
        for i in fresh:
            self.healthy.discard(i)
            self.lost.add(i)
        self.events.emit("device_loss", step=step_idx, tenant=tenant_name,
                         detail="+".join(fresh))
        return self.arbitrate(trigger="fault")

    def restore_devices(self, *names: str) -> Optional[ArbitrationReport]:
        """Devices came back: re-arbitrate (suspended tenants auto-resume
        when the new partition has room for their floor)."""
        returned = [n for n in names if n in self.lost]
        if not returned:
            return None
        for n in returned:
            self.lost.discard(n)
            self.healthy.add(n)
        self.events.emit("device_return", detail="+".join(returned))
        return self.arbitrate(trigger="return")

    # ------------------------------------------------------------ drift ---
    def observe_wave(self, name: str, seconds: float) -> None:
        """Record one serve wave's per-decode-token latency for the
        tenant's drift window (train tenants observe through their own
        Session telemetry)."""
        self.tenants[name].observed.record(seconds)

    def update_serve_load(self, name: str, *, requests: Optional[int] = None,
                          cache_len: Optional[int] = None,
                          weight: Optional[float] = None) -> None:
        """Declare a serve load shift (bigger waves, longer contexts,
        higher priority weight). Clears the utility cache so the next
        arbitration re-prices every candidate — how the serve tenant
        claims devices from train under load."""
        t = self.tenants[name]
        if requests is not None:
            t.requests = requests
        if cache_len is not None:
            t.cache_len = cache_len
        if weight is not None:
            t.weight = weight
        self._utility_cache.clear()

    def _tenant_drift(self, t: Tenant):
        if t.suspended or t.session is None:
            return None
        if t.kind == "train":
            return t.session.drift(self.drift_config)
        predicted = getattr(t.last_plan, "wave_latency", None)
        if t.observed.value is not None and predicted and \
                t._drift_baseline is None and \
                t.observed.count >= self.drift_config.min_samples:
            t._drift_baseline = t.observed.value / predicted
        return detect_drift(t.observed, predicted, self.drift_config,
                            baseline=t._drift_baseline or 1.0)

    def maybe_rearbitrate(self) -> Optional[ArbitrationReport]:
        """Check every tenant's drift detector; any drifted tenant
        triggers one global re-arbitration (per-tenant drift feeds the
        cluster-level decision, not a tenant-local replan)."""
        for t in self._ranked():
            rep = self._tenant_drift(t)
            if rep is not None and rep.drifted:
                self.events.emit("drift", tenant=t.name, detail=rep.reason)
                return self.arbitrate(trigger="drift")
        return None


class TenantSupervisor(Supervisor):
    """PR-7 Supervisor whose membership recovery goes through the
    arbiter: a device loss in this tenant re-arbitrates globally instead
    of replanning session-locally. If the re-arbitration suspends *this*
    tenant (it was the floor that had to give), the supervised call
    raises :class:`TenantSuspended` — the driver parks the tenant until
    :meth:`ClusterArbiter.restore_devices` brings it back."""

    def __init__(self, arbiter: ClusterArbiter, tenant_name: str, session,
                 schedule=None, **kwargs):
        self.arbiter = arbiter
        self.tenant_name = tenant_name
        t = arbiter.tenants[tenant_name]
        kwargs.setdefault("ckpt_path", t.ckpt_path)
        super().__init__(session, t.policy, schedule,
                         membership_hook=self._route_to_arbiter, **kwargs)

    def _route_to_arbiter(self, sup: Supervisor, exc, step_idx: int) -> None:
        self.arbiter.handle_fault(self.tenant_name, exc, step_idx)
        t = self.arbiter.tenants[self.tenant_name]
        if t.suspended:
            raise TenantSuspended(
                f"tenant {self.tenant_name!r} suspended by arbitration "
                f"(state committed"
                + (f" under {t.ckpt_path}" if t.ckpt_path else "")
                + ")") from exc
