"""Power-of-two shape bucketing — the one place the rounding rules live.

Dynamic-shape workloads (continuous batching, autotuned kernel tiles)
must map an unbounded family of runtime sizes onto a small set of
compiled shapes. Two dual rules cover every use in the repo:

- :func:`next_pow2` rounds a *required* size UP to the next power of two
  — batch sizes, page-table widths and packed-prefill token buckets pad
  up so the jit cache stays O(log) in each axis (serve engine/runtime);
- :func:`pow2_floor` rounds an *available* size DOWN to the previous
  power of two — kernel block sizes shrink to what divides the problem
  (kernels/autotune).

Both used to exist as private copies (``serve/runtime.next_pow2`` and
``kernels/autotune._pow2_floor``); the serve engine's table-width
padding grew a third call site, so the rules moved here with boundary
tests (``tests/test_packed_prefill.py``) pinning the edges.
"""
from __future__ import annotations


def next_pow2(n: int) -> int:
    """Smallest power of two >= n; 1 for n <= 1 (a bucket is never
    empty — padding a zero-sized axis still compiles a real shape)."""
    return 1 << max(int(n) - 1, 0).bit_length()


def pow2_floor(n: int) -> int:
    """Largest power of two <= n (n >= 1)."""
    if n < 1:
        raise ValueError(f"pow2_floor needs n >= 1, got {n}")
    return 1 << (int(n).bit_length() - 1)
