"""Device and cluster descriptions.

The paper's heterogeneity unit is one GPU; ours (on TPU) is a mesh group —
but the planner/simulator operate on abstract `DeviceSpec`s either way.
Published chip specs seed the analytical performance model used when real
measurement is impossible (simulating the paper's six GPU types on a CPU
container, or planning for a heterogeneous TPU fleet).
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# device catalog
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class DeviceSpec:
    name: str
    peak_tflops: float          # dense fp16/bf16 tensor throughput
    mem_gb: float
    hbm_gbps: float
    link_gbps: float            # per-device interconnect bandwidth
    # analytical curve parameters: time(b) = overhead + b / eff_rate(b),
    # samples/s rate saturates like b/(b+half_batch). `mfu` is the plateau
    # fraction of peak actually achieved in training.
    mfu: float = 0.45
    half_batch: float = 2.0     # batch at which half the plateau is reached
    overhead_s: float = 0.004   # per-microstep launch/overhead seconds


# GPUs from the paper's three clusters (+ appendix consumer cards)
GPU_CATALOG: Dict[str, DeviceSpec] = {
    "A100-80G": DeviceSpec("A100-80G", 312.0, 80.0, 2039.0, 600.0, 0.48, 2.0),
    "A100-40G": DeviceSpec("A100-40G", 312.0, 40.0, 1555.0, 64.0, 0.48, 2.0),
    "A800-80G": DeviceSpec("A800-80G", 312.0, 80.0, 2039.0, 400.0, 0.48, 2.0),
    "V100-16G": DeviceSpec("V100-16G", 125.0, 16.0, 900.0, 32.0, 0.42, 1.5),
    "V100S-32G": DeviceSpec("V100S-32G", 130.0, 32.0, 1134.0, 32.0, 0.42, 1.5),
    "T4-16G": DeviceSpec("T4-16G", 65.0, 16.0, 300.0, 32.0, 0.35, 1.0),
    "RTX4090-24G": DeviceSpec("RTX4090-24G", 165.0, 24.0, 1008.0, 32.0, 0.40, 1.5),
    "RTX3060-12G": DeviceSpec("RTX3060-12G", 51.0, 12.0, 360.0, 16.0, 0.33, 1.0),
}

# TPU generations — the heterogeneity axis for pod-level Poplar on TPU
TPU_CATALOG: Dict[str, DeviceSpec] = {
    "v5e": DeviceSpec("v5e", 197.0, 16.0, 819.0, 50.0, 0.55, 2.0, 0.002),
    "v4": DeviceSpec("v4", 275.0, 32.0, 1228.0, 50.0, 0.55, 2.0, 0.002),
    "v5p": DeviceSpec("v5p", 459.0, 95.0, 2765.0, 100.0, 0.55, 2.0, 0.002),
}

CATALOG: Dict[str, DeviceSpec] = {**GPU_CATALOG, **TPU_CATALOG}


# ---------------------------------------------------------------------------
# clusters
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ClusterSpec:
    name: str
    devices: Tuple[DeviceSpec, ...]
    # slowest inter-device link bandwidth (GB/s) — the collective bottleneck
    inter_link_gbps: float = 25.0
    # PCIe/socket-style shared fabric: effective per-collective bandwidth
    # divides across participants (the paper's clusters are PCIe-linked)
    shared_bus: bool = True

    def effective_link_gbps(self, n_active: int) -> float:
        if self.shared_bus:
            return self.inter_link_gbps / max(n_active / 2.0, 1.0)
        return self.inter_link_gbps

    @property
    def n(self) -> int:
        return len(self.devices)

    def counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for d in self.devices:
            out[d.name] = out.get(d.name, 0) + 1
        return out


def make_cluster(name: str, composition: Sequence[Tuple[str, int]],
                 inter_link_gbps: float = 25.0,
                 shared_bus: bool = True) -> ClusterSpec:
    devs: List[DeviceSpec] = []
    for dev_name, count in composition:
        devs.extend([CATALOG[dev_name]] * count)
    return ClusterSpec(name, tuple(devs), inter_link_gbps, shared_bus)


# the paper's three experimental clusters (Table 1)
def cluster_A() -> ClusterSpec:
    # 4x A100-80G (NVLink) + 4x A100-40G (PCIe): same compute, different mem
    return make_cluster("A", [("A100-80G", 4), ("A100-40G", 4)], 25.0)


def cluster_B() -> ClusterSpec:
    # 2x V100-16G + 2x T4-16G: same memory, different compute
    return make_cluster("B", [("V100-16G", 2), ("T4-16G", 2)], 12.0)


def cluster_C() -> ClusterSpec:
    # 4x A800-80G + 4x V100S-32G: both differ
    return make_cluster("C", [("A800-80G", 4), ("V100S-32G", 4)], 12.0)


PAPER_CLUSTERS = {"A": cluster_A, "B": cluster_B, "C": cluster_C}


def hetero_tpu_fleet() -> ClusterSpec:
    """A heterogeneous TPU fleet: one v5e pod-slice group + one v4 group.

    This is the pod-granular heterogeneity unit used by the multi-pod
    launcher: each entry represents a 256-chip pod, speeds scaled
    accordingly by the planner."""
    return make_cluster("tpu-v5e+v4", [("v5e", 1), ("v4", 1)], 40.0,
                        shared_bus=False)  # ICI point-to-point
