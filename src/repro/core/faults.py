"""Fault model for the elastic Session runtime: a deterministic
fault-injection harness, fault classification, and the supervised
recovery loop.

Poplar's pitch is a *large number of heterogeneous devices* — in
practice the fleet that gets preempted, loses nodes, and stalls on slow
hosts. PR 5 built plan → execute → observe → re-plan; this module makes
that loop survive hostile schedules:

- :class:`FaultSchedule` — scripted, seed-free fault plans ("lose device
  T4-16G#3 at step 40", "fail checkpoint IO twice from step 25", "slow
  host 2x for steps 10-20") injectable into the Session step boundary
  and the checkpoint writer, so every recovery path is testable in CI on
  the 8-device CPU mesh. Entirely deterministic: entries fire at exact
  step counts and are consumed — two runs of the same schedule observe
  the same faults.
- :func:`classify_fault` — transient (retry with backoff) vs membership
  change (devices gone: re-plan over survivors) vs fatal (programming
  errors: never retry).
- :class:`Supervisor` — wraps a Session's step loop: catches failures,
  drains in-flight gradient-accumulation state (the loader rewinds to
  the last *applied* step, so the interrupted accumulation batch replays
  in full — no micro-step is lost or double-applied), then recovers:
  transient faults retry with exponential backoff; device loss re-plans
  over the survivors via the existing ``replan(cluster=)`` rollback
  machinery (degrading gracefully to fewer devices); if resharding
  itself fails, falls back to restoring a fresh Session from the last
  *committed* checkpoint. Every transition is reported through
  ``core.telemetry.EventLog``.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.checkpoint.async_writer import SimulatedCrash


class DeviceLossError(RuntimeError):
    """A device (or several) left the cluster mid-run. ``lost`` names
    device instances (``"T4-16G#3"`` — profiling's per-kind numbering —
    or a bare kind name meaning one instance of it); ``survivors`` may
    carry the already-computed surviving ClusterSpec (otherwise the
    supervisor derives it from the session's cluster minus ``lost``)."""

    def __init__(self, lost, survivors=None):
        self.lost = list(lost)
        self.survivors = survivors
        super().__init__(f"device loss: {', '.join(self.lost)}")


class TransientStepError(RuntimeError):
    """An injected (or real) one-off step failure — retryable."""


class FaultToleranceExhausted(RuntimeError):
    """The supervisor ran out of recovery options (retry budget spent,
    or fewer survivors than ``FaultPolicy.min_devices``)."""


_FATAL = (ValueError, TypeError, KeyError, AttributeError,
          NotImplementedError)


def classify_fault(exc: BaseException) -> str:
    """``"membership"`` (devices gone — re-plan over survivors),
    ``"transient"`` (worth a retry with backoff), or ``"fatal"``
    (programming errors — retrying reruns the same bug)."""
    if isinstance(exc, DeviceLossError):
        return "membership"
    if isinstance(exc, _FATAL):
        return "fatal"
    return "transient"


@dataclass
class FaultPolicy:
    """How hard the supervisor fights before giving up.

    ``max_retries`` bounds recovery attempts *per training step* —
    transient retries and membership recoveries both draw from it.
    ``backoff_s`` * ``backoff_factor**attempt`` sleeps between transient
    retries (device loss recovers immediately — waiting does not bring
    the device back). ``min_devices``: a membership change leaving fewer
    survivors is unrecoverable (raise instead of limping on a cluster
    the plan space cannot serve). ``restore_on_failure``: when the
    re-plan/reshard path itself fails, rebuild a fresh Session from the
    last committed checkpoint instead of propagating."""
    max_retries: int = 2
    backoff_s: float = 0.05
    backoff_factor: float = 2.0
    min_devices: int = 1
    restore_on_failure: bool = True


# --------------------------------------------------------------------------
# deterministic fault schedules
# --------------------------------------------------------------------------

@dataclass
class _Entry:
    kind: str                     # lose | step_fail | ckpt_io | ckpt_crash | slow
    step: int                     # first step (or save-step) it applies to
    until: int                    # last step inclusive (slow ranges)
    devices: List[str] = field(default_factory=list)
    count: int = 1                # remaining firings (consumed per fire)
    factor: float = 2.0           # slow multiplier
    at: str = "payload_write"     # ckpt_crash / ckpt_io injection point


class FaultSchedule:
    """A scripted fault plan. Build programmatically::

        FaultSchedule().lose(40, "T4-16G#3", "T4-16G#4") \
                       .fail_ckpt_io(25, times=2) \
                       .slow(10, 20, 2.0, device="T4-16G#2")

    or parse the CLI spec grammar (comma-separated on the command line)::

        lose:<step>:<dev>[+<dev>...]      device loss raised before <step>
        step_fail:<step>[:<times>]        transient step failure(s)
        ckpt_io:<step>[:<times>]          checkpoint IO error (retryable)
        ckpt_crash:<step>[:<point>]       crash mid-save at <point>
                                          (payload_write|payload_rename|
                                           meta_write|manifest_write)
        slow:<a>-<b>:<dev|*>:<factor>     straggler host for steps a..b

    Hooks are consumed deterministically: :meth:`check_step` fires at
    the Session step boundary (raising :class:`DeviceLossError` /
    :class:`TransientStepError`), :meth:`checkpoint_io` inside the
    checkpoint write protocol (raising ``OSError`` or
    :class:`SimulatedCrash`), :meth:`slow_factor` scales step wall time
    (and the per-device telemetry proxy, so the drift EMA sees the
    injected imbalance)."""

    def __init__(self):
        self.entries: List[_Entry] = []
        self.fired: List[str] = []    # human-readable log of what fired

    # ------------------------------------------------------- construction --
    def lose(self, step: int, *devices: str) -> "FaultSchedule":
        self.entries.append(_Entry("lose", step, step,
                                   devices=list(devices)))
        return self

    def fail_step(self, step: int, times: int = 1) -> "FaultSchedule":
        self.entries.append(_Entry("step_fail", step, step, count=times))
        return self

    def fail_ckpt_io(self, step: int, times: int = 1,
                     at: str = "payload_write") -> "FaultSchedule":
        self.entries.append(_Entry("ckpt_io", step, step, count=times,
                                   at=at))
        return self

    def crash_ckpt(self, step: int,
                   at: str = "payload_rename") -> "FaultSchedule":
        self.entries.append(_Entry("ckpt_crash", step, step, at=at))
        return self

    def slow(self, start: int, stop: int, factor: float,
             device: Optional[str] = None) -> "FaultSchedule":
        self.entries.append(_Entry(
            "slow", start, stop, factor=factor,
            devices=[device] if device and device != "*" else []))
        return self

    @classmethod
    def parse(cls, specs) -> "FaultSchedule":
        """Parse the CLI grammar (a list of spec strings, or one
        comma-separated string)."""
        if isinstance(specs, str):
            specs = [s for s in specs.split(",") if s]
        sched = cls()
        for spec in specs:
            parts = spec.split(":")
            kind = parts[0]
            if kind == "lose":
                sched.lose(int(parts[1]), *parts[2].split("+"))
            elif kind == "step_fail":
                sched.fail_step(int(parts[1]),
                                int(parts[2]) if len(parts) > 2 else 1)
            elif kind == "ckpt_io":
                sched.fail_ckpt_io(int(parts[1]),
                                   int(parts[2]) if len(parts) > 2 else 1)
            elif kind == "ckpt_crash":
                sched.crash_ckpt(int(parts[1]),
                                 parts[2] if len(parts) > 2
                                 else "payload_rename")
            elif kind == "slow":
                a, b = (int(x) for x in parts[1].split("-"))
                sched.slow(a, b, float(parts[3]),
                           device=parts[2] if parts[2] != "*" else None)
            else:
                raise ValueError(f"unknown fault spec {spec!r}")
        return sched

    # ------------------------------------------------------------- hooks --
    def check_step(self, step: int) -> None:
        """Fire step-boundary faults scheduled at ``step`` (device loss
        first — a lost device fails the step before any retryable
        hiccup would)."""
        for e in self.entries:
            if e.kind == "lose" and e.count > 0 and step >= e.step:
                e.count -= 1
                self.fired.append(f"lose@{step}:{'+'.join(e.devices)}")
                raise DeviceLossError(e.devices)
        for e in self.entries:
            if e.kind == "step_fail" and e.count > 0 and step >= e.step:
                e.count -= 1
                self.fired.append(f"step_fail@{step}")
                raise TransientStepError(
                    f"injected step failure at step {step}")

    def slow_factor(self, step: int, device: Optional[str] = None) -> float:
        """Wall-time multiplier for ``step``. ``device=None`` asks for
        the whole-host factor (the max over active entries — the step
        is as slow as its slowest participant); naming a device returns
        that device's factor (1.0 when the entry targets others)."""
        factor = 1.0
        for e in self.entries:
            if e.kind != "slow" or not (e.step <= step <= e.until):
                continue
            if device is None or not e.devices or device in e.devices:
                factor = max(factor, e.factor)
        return factor

    def checkpoint_io(self, event: str, step: int) -> None:
        """The writer-side hook (``io_hook(event, step)`` in
        ``checkpoint.commit_payload``): raise ``OSError`` while an
        injected IO-failure budget remains, or :class:`SimulatedCrash`
        at the scripted crash point."""
        for e in self.entries:
            if (e.kind == "ckpt_crash" and e.count > 0 and step >= e.step
                    and event == e.at):
                e.count -= 1
                self.fired.append(f"ckpt_crash@{step}:{event}")
                raise SimulatedCrash(
                    f"injected crash during {event} of step {step}")
        for e in self.entries:
            if (e.kind == "ckpt_io" and e.count > 0 and step >= e.step
                    and event == e.at):
                e.count -= 1
                self.fired.append(f"ckpt_io@{step}:{event}")
                raise OSError(f"injected IO error during {event} "
                              f"of step {step}")


def drop_devices(cluster, lost: List[str]):
    """The surviving ClusterSpec after ``lost`` leave. Instance ids use
    profiling's per-kind numbering (``"T4-16G#3"``); a bare kind name
    drops one instance of that kind."""
    from repro.core.cluster import make_cluster

    remaining: Dict[str, int] = {}
    order: List[str] = []
    for d in cluster.devices:
        if d.name not in remaining:
            order.append(d.name)
        remaining[d.name] = remaining.get(d.name, 0) + 1
    for name in lost:
        kind = name.split("#")[0]
        if kind not in remaining or remaining[kind] <= 0:
            raise ValueError(f"cannot lose {name!r}: no {kind!r} left in "
                             f"cluster {cluster.name!r}")
        remaining[kind] -= 1
    composition = [(k, remaining[k]) for k in order if remaining[k] > 0]
    if not composition:
        raise ValueError("device loss leaves an empty cluster")
    return make_cluster(f"{cluster.name}-{cluster.n - len(lost)}",
                        composition, cluster.inter_link_gbps,
                        shared_bus=cluster.shared_bus)


# --------------------------------------------------------------------------
# the supervised step loop
# --------------------------------------------------------------------------

class Supervisor:
    """Fault-tolerant wrapper around a Session's step loop.

    ``sup.step()`` runs one training step, absorbing faults per the
    :class:`FaultPolicy`; ``sup.session`` is the live session (re-bound
    when recovery had to restore from a checkpoint — callers must read
    it through the supervisor). ``ckpt_path`` enables periodic durable
    saves (``save_every``, async by default) and the restore-fallback
    recovery path.
    """

    def __init__(self, session, policy: Optional[FaultPolicy] = None,
                 schedule: Optional[FaultSchedule] = None, *,
                 ckpt_path: Optional[str] = None, save_every: int = 0,
                 async_save: bool = True, keep_last: Optional[int] = None,
                 membership_hook=None):
        self.session = session
        self.policy = policy or FaultPolicy()
        self.schedule = schedule
        self.ckpt_path = ckpt_path
        self.save_every = save_every
        self.async_save = async_save
        self.keep_last = keep_last
        # membership_hook(supervisor, exc, step_idx): when set, device-loss
        # recovery is delegated (e.g. to a ClusterArbiter's global
        # re-arbitration) instead of the session-local replan-over-survivors
        self.membership_hook = membership_hook
        self.events = session.events
        self.recoveries = 0
        if schedule is not None:
            session.attach_faults(schedule)

    # ---------------------------------------------------------------- API --
    def step(self):
        """One supervised training step: returns the metrics dict, or
        raises :class:`FaultToleranceExhausted` (or the fatal original)
        when the policy's budget cannot absorb the failure."""
        metrics = self.call(lambda: self.session.step())
        if self.session.mode == "train":
            self._maybe_autosave(int(self.session.state.step))
        return metrics

    def call(self, fn):
        """Run any session-touching callable under the supervised
        fault/recovery loop (serve waves use this: the callable must read
        ``sup.session`` each invocation, since recovery may rebind it)."""
        policy = self.policy
        delay = policy.backoff_s
        last_exc: Optional[BaseException] = None
        for attempt in range(policy.max_retries + 1):
            sess = self.session
            step_idx = int(sess.state.step)
            try:
                return fn()
            except DeviceLossError as e:
                last_exc = e
                self.events.emit("device_loss", step=step_idx,
                                 detail="+".join(e.lost))
                self._recover_membership(e, step_idx)
            except SimulatedCrash:
                raise
            except Exception as e:  # noqa: BLE001 — classified below
                last_exc = e
                kind = classify_fault(e)
                if kind == "fatal":
                    self.events.emit("fatal", step=step_idx,
                                     detail=f"{type(e).__name__}: {e}")
                    raise
                self.events.emit("transient", step=step_idx,
                                 detail=f"{type(e).__name__}: {e} "
                                        f"(attempt {attempt + 1})")
                sess.drain()
                if attempt < policy.max_retries:
                    time.sleep(delay)
                    delay *= policy.backoff_factor
        self.events.emit("gave_up", step=int(self.session.state.step),
                         detail=f"after {policy.max_retries + 1} attempts")
        raise FaultToleranceExhausted(
            f"step failed {policy.max_retries + 1} times; last: "
            f"{last_exc!r}") from last_exc

    def run(self, n_steps: int):
        """Drive ``n_steps`` supervised steps; returns the last metrics."""
        metrics = None
        for _ in range(n_steps):
            metrics = self.step()
        self.flush()
        return metrics

    def flush(self) -> None:
        """Wait for in-flight async checkpoint writes."""
        self.session.flush_saves()

    # ----------------------------------------------------------- recovery --
    def _recover_membership(self, e: DeviceLossError, step_idx: int) -> None:
        sess, policy = self.session, self.policy
        # A background commit of the pre-fault state must land (or fail)
        # before any membership change: replan/re-arbitration re-shards the
        # live state, and racing the writer could interleave a gather of
        # half-resharded arrays into the "pre-fault" checkpoint.
        sess.flush_saves()
        sess.drain()     # replay the interrupted accum batch after recovery
        if self.membership_hook is not None:
            t0 = time.monotonic()
            self.membership_hook(self, e, step_idx)
            self.recoveries += 1
            self.events.emit("arbiter_recovered", step=step_idx,
                             detail="+".join(e.lost),
                             seconds=time.monotonic() - t0)
            return
        survivors = e.survivors
        if survivors is None:
            if sess.cluster is None:
                raise FaultToleranceExhausted(
                    "device loss on an unplanned session — no cluster to "
                    "re-plan over") from e
            survivors = drop_devices(sess.cluster, e.lost)
        if survivors.n < policy.min_devices:
            self.events.emit("gave_up", step=step_idx,
                             detail=f"{survivors.n} survivors < "
                                    f"min_devices={policy.min_devices}")
            raise FaultToleranceExhausted(
                f"{survivors.n} surviving devices, policy requires "
                f">= {policy.min_devices}") from e
        t0 = time.monotonic()
        try:
            rep = sess.replan(cluster=survivors, trigger="fault")
            self.recoveries += 1
            self.events.emit("replan_recovered", step=step_idx,
                             detail=f"{rep.old_devices}->{rep.new_devices} "
                                    f"stage={rep.zero_stage}",
                             seconds=time.monotonic() - t0)
        except Exception as replan_err:  # noqa: BLE001 — fall back to restore
            self.events.emit("replan_failed", step=step_idx,
                            detail=f"{type(replan_err).__name__}: "
                                   f"{replan_err}")
            if not (policy.restore_on_failure and self.ckpt_path):
                raise
            self._recover_restore(survivors, step_idx, replan_err)

    def _recover_restore(self, survivors, step_idx: int,
                         cause: BaseException) -> None:
        """Last resort: abandon the live state and rebuild a fresh
        Session from the newest *committed, digest-verified* checkpoint
        on the surviving cluster."""
        from repro.checkpoint import latest_verified_step

        step = latest_verified_step(self.ckpt_path)
        if step is None:
            raise FaultToleranceExhausted(
                f"reshard failed and no committed checkpoint under "
                f"{self.ckpt_path}") from cause
        t0 = time.monotonic()
        from repro.api.session import Session
        new_sess = Session.restore(self.ckpt_path, cfg=self.session.cfg,
                                   cluster=survivors, step=step)
        new_sess.events = self.events          # keep one continuous log
        if self.schedule is not None:
            new_sess.attach_faults(self.schedule)
        self.session = new_sess
        self.recoveries += 1
        self.events.emit("restore_recovered", step=step_idx,
                         detail=f"rolled back to committed step {step} on "
                                f"{survivors.n} devices",
                         seconds=time.monotonic() - t0)

    # ---------------------------------------------------------- autosave --
    def _maybe_autosave(self, applied_step: int) -> None:
        if not (self.ckpt_path and self.save_every
                and applied_step % self.save_every == 0):
            return
        self.session.save(self.ckpt_path, async_=self.async_save,
                          keep_last=self.keep_last)
