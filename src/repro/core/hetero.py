"""Heterogeneous batch execution under SPMD — the TPU-native realization of
Poplar's uneven batch assignment (DESIGN.md §2).

The paper's MPMD freedom (each GPU running its own batch size) becomes:

1. the *batch layout*: the global batch dimension is laid out as
   ``n_groups × padded_group_batch`` where group g holds ``b_g`` real
   samples (Poplar's allocation) plus padding rows; a loss mask zeroes the
   padding so gradients are exact;
2. the *accumulation layout*: every group runs the same number of
   micro-steps ``gas = max_g gas_g``; groups that finish their share early
   get fully-masked micro-batches (their last real step is Poplar's `lbs`).

BSP synchronization points (the psums XLA inserts) then see identical
program shapes everywhere while per-group useful work follows the plan.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.allocation import AllocationPlan


@dataclass
class HeteroBatchLayout:
    """Static description of the padded global batch."""
    group_names: List[str]           # one entry per mesh group (e.g. pod)
    real_per_group: List[int]        # Poplar's b_g per accumulation step
    padded_group_batch: int          # uniform padded rows per group
    gas: int                         # accumulation steps (global max)
    last_real_per_group: List[int]   # real rows in the final micro-step

    @property
    def padded_global_batch(self) -> int:
        return self.padded_group_batch * len(self.group_names)

    def total_real(self) -> int:
        full = sum(r * (self.gas - 1) for r in self.real_per_group)
        return full + sum(self.last_real_per_group)


def layout_from_plan(plan: AllocationPlan, group_multiple: int = 1
                     ) -> HeteroBatchLayout:
    """Derive the padded SPMD layout from an AllocationPlan.

    ``group_multiple``: padded per-group batch must divide the data-axis
    size inside each group (e.g. 16 for a (16, 16) pod mesh).
    """
    names = [n for n in plan.assignments]
    gas = max((a.gas for a in plan.assignments.values()), default=1)
    gas = max(gas, 1)
    micro = [plan.assignments[n].micro_batch or 0 for n in names]
    pad = max(micro) if micro else 1
    pad = max(pad, 1)
    pad = int(math.ceil(pad / group_multiple) * group_multiple)
    last = []
    for n in names:
        a = plan.assignments[n]
        if a.gmbs == 0:
            last.append(0)
        elif a.lbs:
            last.append(a.lbs)
        else:
            last.append(a.micro_batch if a.gas == gas else 0)
    return HeteroBatchLayout(names, micro, pad, gas, last)


def build_masks(layout: HeteroBatchLayout) -> np.ndarray:
    """(gas, padded_global_batch) float mask of real rows."""
    G = len(layout.group_names)
    m = np.zeros((layout.gas, G, layout.padded_group_batch), np.float32)
    for gi in range(G):
        a_gas_full = layout.gas - 1
        for s in range(layout.gas):
            if s < a_gas_full:
                # device may have fewer steps than global gas: steps beyond
                # its own schedule stay masked
                real = layout.real_per_group[gi] if s < _dev_steps(layout, gi) - 1 else (
                    layout.last_real_per_group[gi] if s == _dev_steps(layout, gi) - 1 else 0)
            else:
                real = layout.last_real_per_group[gi]
            real = min(real, layout.padded_group_batch)
            m[s, gi, :real] = 1.0
    return m.reshape(layout.gas, G * layout.padded_group_batch)


def _dev_steps(layout: HeteroBatchLayout, gi: int) -> int:
    # number of micro-steps in which group gi has any real work
    r, l = layout.real_per_group[gi], layout.last_real_per_group[gi]
    if r == 0 and l == 0:
        return 0
    return layout.gas


def pack_batch(tokens: Optional[np.ndarray], layout: HeteroBatchLayout,
               seq_len: int, *,
               packed_fields: Optional[Dict[str, np.ndarray]] = None
               ) -> Dict[str, np.ndarray]:
    """Scatter a stream of row data into the padded layout.

    Two modes:

    * ``tokens`` — a (N, seq+1) array of token rows; tokens/labels are the
      usual shift. Per-token loss validity additionally zeroes positions
      whose input or label is PAD (id 0), so zero-padded variable-length
      rows train on exactly their real tokens (full-length rows are
      unaffected: no real token id is 0).
    * ``packed_fields`` — pre-packed per-row arrays from the sequence
      packer (``data.pipeline.pack_documents``): ``tokens``/``labels``/
      ``segment_ids``/``positions`` (N, seq) plus a token-level
      ``loss_mask`` (N, seq). Each field is scattered alongside the row
      mask so packed metadata rides through the hetero layout untouched.

    Returns arrays shaped (gas, padded_global_batch, seq) + the combined
    loss mask. Rows are consumed group-major per micro-step; unfilled
    rows are zero + masked.
    """
    masks = build_masks(layout)                   # (gas, B_pad)
    gas, B_pad = masks.shape
    if packed_fields is not None:
        n_rows = len(packed_fields["tokens"])
        out = {k: np.zeros((gas, B_pad) + v.shape[1:], v.dtype)
               for k, v in packed_fields.items() if k != "loss_mask"}
        tok_mask = np.zeros((gas, B_pad, seq_len), np.float32)
        cursor = 0
        for s in range(gas):
            for b in range(B_pad):
                if masks[s, b] > 0:
                    if cursor >= n_rows:
                        masks[s, b] = 0.0
                        continue
                    for name in out:
                        out[name][s, b] = packed_fields[name][cursor]
                    tok_mask[s, b] = packed_fields["loss_mask"][cursor]
                    cursor += 1
        out["loss_mask"] = masks[:, :, None] * tok_mask
        return out
    toks = np.zeros((gas, B_pad, seq_len), tokens.dtype)
    labs = np.zeros((gas, B_pad, seq_len), tokens.dtype)
    tok_mask = np.zeros((gas, B_pad, seq_len), np.float32)
    cursor = 0
    for s in range(gas):
        for b in range(B_pad):
            if masks[s, b] > 0:
                if cursor >= len(tokens):
                    masks[s, b] = 0.0
                    continue
                row = tokens[cursor]
                cursor += 1
                toks[s, b] = row[:seq_len]
                labs[s, b] = row[1:seq_len + 1]
                tok_mask[s, b] = ((row[:seq_len] != 0)
                                  & (row[1:seq_len + 1] != 0))
    loss_mask = masks[:, :, None] * tok_mask
    return {"tokens": toks, "labels": labs, "loss_mask": loss_mask}
