"""Explicitly scheduled ZeRO-3: double-buffered parameter prefetch +
per-layer gradient reduce-scatter, as a `shard_map` train step.

The XLA-auto stage-3 path (``rules.overlap="xla"``, the parity oracle)
leaves every collective to SPMD: one all-gather per parameter *use*,
serialized against the compute that needs it, and a grad tree that is
materialized in full before the optimizer's sharding constraint turns it
back into shards. Poplar's premise is that heterogeneous clusters live
or die by exactly these per-stage collectives, so this module makes them
explicit and schedulable:

- parameters enter the step as their ZeRO-3 shards (`shard_map` over the
  mesh, in_specs = the stage-3 param specs);
- non-stacked leaves (embeddings, final norm, ...) are all-gathered once
  at step start;
- the scanned layer stack is *streamed*: while layer ``l`` computes, the
  all-gather for layer ``l+1``'s shard is already in flight (a two-deep
  software pipeline carried through the scan — `models/model._run_stack`
  consumes it via a :class:`LayerStream`);
- the backward of each gather is a *reduce-scatter* (`gather_params` is a
  ``jax.custom_vjp``), so each layer's gradient is scattered back to
  shards inside the backward sweep — the full gradient tree never exists,
  and gradient accumulation (`accum_steps>1`) accumulates shards;
- with ``rules.comm_dtype="int8"`` the sharded collectives ride
  `core/qcomm`'s quantized wire format (ZeRO++ qwZ/qgZ style).

Scheduling note (prefetch vs. remat): with ``rules.overlap_prefetch``
(default) the gathered unit params live in the scan carry, so the
backward consumes the saved gather (one AG per layer total) at the cost
of holding gathered layers in the fwd residuals. ``overlap_prefetch=
False`` moves the gather inside the remat region instead: residuals stay
sharded and the backward re-gathers (AG fwd + AG bwd + RS — the classic
ZeRO-3 schedule, and what `workload.comm_time_per_microstep` models).

The scheduled path is the pure ZeRO/data-parallel regime — exactly
Poplar's setting. Tensor-parallel parameter sharding (a ``model`` axis
outside ``dp_only``) is not schedulable here and falls back to the XLA
path under ``rules.overlap="auto"``.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import qcomm
from repro.core.sharding import MeshRules, shard_map_compat, use_rules

# layer-scan comm hidden under compute: the fraction of per-microstep
# collective time the prefetch pipeline can hide. 0.7 is the analytical
# fallback for the planner/simulator overlap term (first-layer fill +
# last-layer drain + the non-stacked leaves stay exposed); sessions built
# with profile="measured" replace it via `calibrate_overlap_factor` from
# a one-shot auto-vs-scheduled probe.
SCHEDULED_OVERLAP_FACTOR = 0.7


def calibrate_overlap_factor(t_auto_s: float, t_scheduled_s: float,
                             comm_s: float,
                             fallback: float = SCHEDULED_OVERLAP_FACTOR
                             ) -> float:
    """Infer the hidden-comm fraction from one measured probe pair.

    The serial (XLA-auto) model costs ``t_auto ≈ compute + comm``; the
    scheduled step hides ``f·comm`` of that under compute, so
    ``t_auto − t_scheduled ≈ f·comm`` and ``f`` falls straight out given
    the planner's per-microstep collective estimate ``comm_s``. Clamped
    to [0, 0.95] (the fill/drain floor can never hide everything);
    degenerate probes — non-positive timings, comm indistinguishable
    from timer noise, or a scheduled step *slower* than auto — return
    ``fallback`` instead of a garbage factor.
    """
    if not (t_auto_s > 0.0 and t_scheduled_s > 0.0 and comm_s > 1e-12):
        return fallback
    hidden = t_auto_s - t_scheduled_s
    if hidden <= 0.0:
        return fallback
    return min(hidden / comm_s, 0.95)

# subtrees of the param dict that are stacked over the layer scan and
# therefore streamed layer-by-layer instead of gathered up front
STREAM_KEYS = ("stack", "cross")


# ---------------------------------------------------------------------------
# per-leaf communication metadata
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class LeafComm:
    """How one param leaf moves between its shard and its full form.

    ``shard_dim``: the dimension sharded over the ZeRO axes (None = the
    leaf is replicated — no divisible dim); ``shard_axes``: the mesh axes
    on that dim; ``psum_axes``: data-parallel axes the leaf is *not*
    sharded over (its gradient must be psum'd across them — e.g. the
    ``pod`` axis under hierarchical ZeRO); ``nshard``: product of the
    shard axis sizes; ``comm_dtype``: "int8" routes the sharded
    collectives through qcomm.
    """
    shard_dim: Optional[int]
    shard_axes: Tuple[str, ...] = ()
    psum_axes: Tuple[str, ...] = ()
    nshard: int = 1
    comm_dtype: Optional[str] = None

    def slice_comm(self) -> "LeafComm":
        """Comm meta for a layer slice of a stacked leaf (drops dim 0)."""
        sd = None if self.shard_dim is None else self.shard_dim - 1
        return LeafComm(sd, self.shard_axes, self.psum_axes, self.nshard,
                        self.comm_dtype)


def _spec_names(entry) -> Tuple[str, ...]:
    if entry is None:
        return ()
    if isinstance(entry, (tuple, list)):
        return tuple(entry)
    return (entry,)


# ---------------------------------------------------------------------------
# gather (fwd) / reduce-scatter (bwd) — the scheduled collective pair
# ---------------------------------------------------------------------------

def _q_all_gather(shard: jnp.ndarray, comm: LeafComm) -> jnp.ndarray:
    axis = comm.shard_axes[0]
    moved = jnp.moveaxis(shard, comm.shard_dim, 0)
    full = qcomm.quantized_all_gather(moved.reshape(-1), axis)
    full = full.reshape((comm.nshard * moved.shape[0],) + moved.shape[1:])
    return jnp.moveaxis(full, 0, comm.shard_dim).astype(shard.dtype)


def _q_reduce_scatter(g: jnp.ndarray, comm: LeafComm) -> jnp.ndarray:
    axis = comm.shard_axes[0]
    moved = jnp.moveaxis(g, comm.shard_dim, 0)
    loc_shape = (moved.shape[0] // comm.nshard,) + moved.shape[1:]
    part = qcomm.quantized_reduce_scatter(
        moved.astype(jnp.float32).reshape(-1), axis)
    n_loc = 1
    for d in loc_shape:
        n_loc *= d
    part = part[:n_loc].reshape(loc_shape)
    return jnp.moveaxis(part, 0, comm.shard_dim).astype(g.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def gather_params(shard, comm: LeafComm):
    """shard -> full parameter. VJP: full-grad -> reduce-scattered shard
    grad (plus a psum over the data axes the leaf is replicated across).
    The custom VJP is what puts the reduce-scatter *inside* the backward
    layer sweep instead of after it."""
    return _gather_impl(shard, comm)


def _gather_impl(shard, comm: LeafComm):
    if comm.shard_dim is None:
        return shard
    if comm.comm_dtype == "int8":
        return _q_all_gather(shard, comm)
    return jax.lax.all_gather(shard, comm.shard_axes, axis=comm.shard_dim,
                              tiled=True)


def _gather_fwd(shard, comm: LeafComm):
    return _gather_impl(shard, comm), None


def _gather_bwd(comm: LeafComm, _, g):
    if comm.shard_dim is not None:
        if comm.comm_dtype == "int8":
            g = _q_reduce_scatter(g, comm)
        else:
            g = jax.lax.psum_scatter(g, comm.shard_axes,
                                     scatter_dimension=comm.shard_dim,
                                     tiled=True)
    if comm.psum_axes:
        g = jax.lax.psum(g, comm.psum_axes)
    return (g,)


gather_params.defvjp(_gather_fwd, _gather_bwd)


def gather_tree(shards, comm_tree):
    return jax.tree.map(gather_params, shards, comm_tree)


@dataclass
class LayerStream:
    """Handed to `models/model._run_stack`: ``gather`` maps one layer's
    sharded slice tree to its full form; ``prefetch`` selects the
    double-buffered carry pipeline (vs. gather-inside-remat)."""
    gather: Callable[[Any], Any]
    prefetch: bool = True


# ---------------------------------------------------------------------------
# planning: specs + comm metadata for one (rules, params, batch) triple
# ---------------------------------------------------------------------------

@dataclass
class CommPlan:
    rules: MeshRules
    p_specs: Any
    o_specs: Any
    b_specs: Any
    comm: Any                       # tree of LeafComm, same structure as params
    stream_keys: Tuple[str, ...]
    dp_axes: Tuple[str, ...]
    n_dp: int


class _Unsupported(Exception):
    pass


def plan_comm(rules: MeshRules, params, axes, batch,
              accum_steps: int = 1):
    """Build the CommPlan for the scheduled step, or return a ``str``
    reason why this (mesh, rules, batch) combination is not schedulable.
    """
    try:
        return _plan_comm(rules, params, axes, batch, accum_steps)
    except _Unsupported as e:
        return str(e)


def _plan_comm(rules, params, axes, batch, accum_steps):
    from repro.core import zero

    if rules.zero_stage != 3:
        raise _Unsupported(
            f"scheduled overlap targets ZeRO-3 (stage={rules.zero_stage})")
    mesh = rules.mesh
    zaxes = rules._zero_axes()

    bdim = 1 if accum_steps > 1 else 0
    tokens = batch["tokens"]
    if tokens.ndim < bdim + 1:
        raise _Unsupported("batch rank does not match accum_steps")
    bsz = tokens.shape[bdim]
    bentry = rules.activation_spec(("batch",), (bsz,))[0]
    dp_axes = _spec_names(bentry)
    n_dp = 1
    for a in dp_axes:
        n_dp *= mesh.shape[a]
    for a in zaxes:
        if mesh.shape.get(a, 1) > 1 and a not in dp_axes:
            raise _Unsupported(
                f"batch of {bsz} does not divide across zero axis {a!r}")

    p_specs, o_specs, _ = zero.model_shardings(rules, params, axes)

    def leaf_comm(spec: P):
        shard_dim, shard_axes = None, ()
        for i, entry in enumerate(spec):
            # size-1 mesh axes are sharding no-ops (e.g. the debug mesh's
            # model axis): nothing to gather or reduce over them
            names = tuple(n for n in _spec_names(entry)
                          if mesh.shape.get(n, 1) > 1)
            if not names:
                continue
            non_zero = [n for n in names if n not in zaxes]
            if non_zero:
                raise _Unsupported(
                    f"tensor-parallel param axes {non_zero} — the scheduled "
                    "path is ZeRO/data-parallel only")
            shard_dim, shard_axes = i, names
        for a in shard_axes:
            if a not in dp_axes:
                raise _Unsupported(
                    f"param sharded over {a!r} but batch is not")
        nshard = 1
        for a in shard_axes:
            nshard *= mesh.shape[a]
        cd = rules.comm_dtype
        if cd == "int8" and len(shard_axes) != 1:
            cd = None  # quantized path rides a single axis; fall back
        psum_axes = tuple(a for a in dp_axes if a not in shard_axes)
        return LeafComm(shard_dim, tuple(shard_axes), psum_axes, nshard, cd)

    comm = jax.tree.map(leaf_comm, p_specs,
                        is_leaf=lambda x: isinstance(x, P))

    def bspec(v):
        parts = [None] * v.ndim
        parts[bdim] = bentry
        return P(*parts)

    b_specs = jax.tree.map(bspec, batch)
    stream_keys = tuple(k for k in STREAM_KEYS if k in params)
    return CommPlan(rules, p_specs, o_specs, b_specs, comm,
                    stream_keys, dp_axes, n_dp)


# ---------------------------------------------------------------------------
# the scheduled train step
# ---------------------------------------------------------------------------

def _psum(x, axes):
    return jax.lax.psum(x, axes) if axes else x


def _global_grad_sq(grads, comm_tree):
    """Global sum of squared gradients over sharded + replicated leaves
    (grouped by shard axes so each axis set is psum'd once)."""
    flat_g = jax.tree.leaves(grads)
    flat_c = jax.tree.leaves(
        comm_tree, is_leaf=lambda x: isinstance(x, LeafComm))
    groups: Dict[Tuple[str, ...], Any] = {}
    for g, c in zip(flat_g, flat_c):
        axes = c.shard_axes if c.shard_dim is not None else ()
        sq = jnp.sum(jnp.square(g.astype(jnp.float32)))
        groups[axes] = groups.get(axes, 0.0) + sq
    total = jnp.zeros((), jnp.float32)
    for axes, s in groups.items():
        total = total + (jax.lax.psum(s, axes) if axes else s)
    return total


def scheduled_train_step(plan: CommPlan, cfg, adamw_cfg, lr: float,
                         window, impl: str, accum_steps: int,
                         params, opt_state, batch):
    """Run one explicitly scheduled ZeRO-3 step (call under jit)."""
    from repro.models import model as mm
    from repro.optim.adamw import adamw_update

    rules = plan.rules
    dp = plan.dp_axes
    prefetch = getattr(rules, "overlap_prefetch", True)
    stream_comm = (
        jax.tree.map(lambda c: c.slice_comm(), plan.comm["stack"],
                     is_leaf=lambda x: isinstance(x, LeafComm)),
        (jax.tree.map(lambda c: c.slice_comm(), plan.comm["cross"],
                      is_leaf=lambda x: isinstance(x, LeafComm))
         if "cross" in plan.stream_keys else None),
    )
    rest_comm = {k: v for k, v in plan.comm.items()
                 if k not in plan.stream_keys}

    def gather_slice(slice_tree):
        return jax.tree.map(gather_params, slice_tree, stream_comm)

    stream = LayerStream(gather=gather_slice, prefetch=prefetch)

    def objective(p_loc, mb):
        streamed = {k: p_loc[k] for k in plan.stream_keys}
        rest = {k: v for k, v in p_loc.items() if k not in plan.stream_keys}
        full = dict(gather_tree(rest, rest_comm), **streamed)
        with use_rules(None):   # local compute: no SPMD constraints inside
            terms = mm.loss_terms(full, cfg, mb, window=window, impl=impl,
                                  stream=stream)
        # psum'd token count is constant wrt params, so no cotangent flows
        # through it — the *local* objective's gradients sum to the global
        # gradient exactly via the reduce-scatters (psum itself must stay
        # out of the differentiated path: its shard_map transpose would
        # scale cotangents by n_dp).
        tok_g = jnp.maximum(_psum(terms["tokens"], dp), 1.0)
        obj = terms["nll"] / tok_g + terms["aux"] / plan.n_dp
        return obj, terms

    def body(p_loc, opt_loc, b_loc):
        if accum_steps == 1:
            (obj, terms), grads = jax.value_and_grad(
                objective, has_aux=True)(p_loc, b_loc)
            tokens = _psum(terms["tokens"], dp)
            loss_tok = _psum(terms["nll"], dp) / jnp.maximum(tokens, 1.0)
            metrics = {"loss": loss_tok,
                       "aux": _psum(terms["aux"], dp) / plan.n_dp,
                       "tokens": tokens}
        else:
            def micro(carry, mb):
                g_acc, l_acc, t_acc = carry
                (obj, terms), g = jax.value_and_grad(
                    objective, has_aux=True)(p_loc, mb)
                w = _psum(terms["tokens"], dp)
                l_g = _psum(obj, dp)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32) * w, g_acc, g)
                return (g_acc, l_acc + l_g * w, t_acc + w), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), p_loc)
            (grads, lsum, tokens), _ = jax.lax.scan(
                micro, (g0, jnp.zeros(()), jnp.zeros(())), b_loc)
            denom = jnp.maximum(tokens, 1.0)
            grads = jax.tree.map(lambda g: g / denom, grads)
            metrics = {"loss": lsum / denom, "aux": jnp.zeros(()),
                       "tokens": tokens}
        gnorm = jnp.sqrt(_global_grad_sq(grads, plan.comm))
        new_params, new_opt, om = adamw_update(grads, opt_loc, p_loc, lr,
                                               adamw_cfg, gnorm=gnorm)
        metrics = dict(metrics)
        metrics.update(om)
        return new_params, new_opt, metrics

    metric_specs = {"loss": P(), "aux": P(), "tokens": P(), "grad_norm": P()}
    step = shard_map_compat(
        body, mesh=rules.mesh,
        in_specs=(plan.p_specs, plan.o_specs, plan.b_specs),
        out_specs=(plan.p_specs, plan.o_specs, metric_specs))
    return step(params, opt_state, batch)


# ---------------------------------------------------------------------------
# analytic wire/exposed-byte accounting (drives benchmarks + the planner)
# ---------------------------------------------------------------------------

def _leaf_wire_bytes(shape, dtype, comm: LeafComm) -> float:
    """Bytes one device receives for a full gather of this leaf (== bytes
    it contributes to the leaf's reduce-scatter)."""
    if comm.shard_dim is None:
        return 0.0
    n_elems = 1
    for d in shape:
        n_elems *= int(d)
    if comm.comm_dtype == "int8":
        q, _ = qcomm.wire_bytes(n_elems)
        full = float(q)
    else:
        full = float(n_elems * jnp.dtype(dtype).itemsize)
    return full * (comm.nshard - 1) / comm.nshard


def comm_report(plan: CommPlan, params, *, remat: bool = True
                ) -> Dict[str, float]:
    """Analytic per-device wire bytes for one micro-step, XLA-auto vs.
    scheduled, and the *exposed* (not hidden under compute) bytes.

    auto: every collective serializes at its use site — all wire bytes
    are exposed. scheduled: streamed layers hide behind the prefetch
    pipeline except the fill (first layer's AG), the drain (last layer's
    RS, plus the first re-gather when ``overlap_prefetch=False``), and
    the non-stacked leaves gathered at step start.
    """
    prefetch = getattr(plan.rules, "overlap_prefetch", True)
    regather = remat and not prefetch

    stream_ag = stream_rs = stream_ag_first = stream_rs_last = 0.0
    rest_ag = rest_rs = 0.0
    for key in params:
        leaves_v = jax.tree.leaves(params[key])
        leaves_c = jax.tree.leaves(
            plan.comm[key], is_leaf=lambda x: isinstance(x, LeafComm))
        streamed = key in plan.stream_keys
        for v, c in zip(leaves_v, leaves_c):
            b = _leaf_wire_bytes(v.shape, v.dtype, c)
            if streamed:
                n_scan = int(v.shape[0])
                stream_ag += b
                stream_rs += b
                stream_ag_first += b / max(n_scan, 1)
                stream_rs_last += b / max(n_scan, 1)
            else:
                rest_ag += b
                rest_rs += b

    # the bwd re-gather only applies to the *streamed* leaves: the rest
    # tree is gathered once outside any remat region, so its full form is
    # a saved residual and backward reuses it in every schedule variant
    ag_passes = 2.0 if regather else 1.0   # stream fwd (+ bwd re-gather)
    wire = stream_ag * ag_passes + rest_ag + stream_rs + rest_rs
    # XLA-auto always re-gathers in backward under remat'd scans
    wire_auto = (stream_ag * (2.0 if remat else 1.0) + rest_ag
                 + stream_rs + rest_rs)
    exposed_sched = (rest_ag + rest_rs
                     + stream_ag_first * ag_passes + stream_rs_last)
    return {
        "wire_bytes_auto": wire_auto,
        "wire_bytes_scheduled": wire,
        "exposed_bytes_auto": wire_auto,
        "exposed_bytes_scheduled": exposed_sched,
        "hidden_bytes_scheduled": wire - exposed_sched,
    }
