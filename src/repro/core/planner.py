"""End-to-end Poplar planner: the "fully automated parallelism" pipeline of
Figure 2 — online profiling -> spline fitting -> batch-allocation search ->
training configuration. One call, no manual batch-size tuning.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from repro.configs.base import ModelConfig
from repro.core.allocation import (AllocationPlan, PerfCurve, allocate_stage01,
                                   allocate_stage23, fit_curve)
from repro.core.cluster import ClusterSpec, DeviceSpec
from repro.core.profiler import (AnalyticalRunner, DeviceProfile, DeviceRunner,
                                 SimOOM, decode_profiles, probes_saved,
                                 profile_cluster)
from repro.core.simulator import SimResult, simulate_plan
from repro.core.workload import (MemoryModel, PackedWorkload,
                                 comm_time_per_microstep,
                                 train_flops_per_row)


@dataclass
class PoplarPlan:
    zero_stage: int
    allocation: AllocationPlan
    curves: Dict[str, PerfCurve]
    profiles: Dict[str, DeviceProfile]
    predicted: Optional[SimResult] = None
    profiling_probes: int = 0
    # model executions avoided by sharing one profile across identical
    # devices (profiler.profile_cluster dedupe)
    profiling_probes_saved: int = 0
    # provenance of the timings the allocation search consumed:
    # "analytical" (DeviceSpec curves), "measured" (real jitted-step wall
    # time), or "mixed"
    profile_source: str = "analytical"


@dataclass
class ServePlan:
    """Poplar Algorithm 1 applied to the *serving* wave: per-device decode
    speed profiles -> spline curves -> a stage-0/1 allocation of the wave's
    requests so every group finishes its decode step together."""
    allocation: AllocationPlan
    curves: Dict[str, PerfCurve]
    profiles: Dict[str, DeviceProfile]
    requests: int
    cache_len: int
    # predicted per-decode-token wave latency (slowest group's step time)
    wave_latency: float = 0.0
    profiling_probes: int = 0
    profiling_probes_saved: int = 0

    @property
    def requests_per_sec(self) -> float:
        """Decode throughput the plan predicts: one token for each of the
        wave's requests per ``wave_latency`` seconds."""
        if self.wave_latency <= 0:
            return 0.0
        return self.requests / self.wave_latency


def plan_serve(cluster: ClusterSpec, cfg: ModelConfig, requests: int,
               cache_len: int,
               profile_cache: Optional[Dict] = None) -> ServePlan:
    """Plan one serve wave over ``cluster``: decode profiles (HBM-bound
    analytical model, shared across identical devices and across calls via
    ``profile_cache``), spline fit, and the stage-0/1 allocator (decode has
    no gradient sync, so finish-together is the whole objective)."""
    if requests < 1:
        raise ValueError("plan_serve needs at least one request")
    profiles = decode_profiles(cluster, cfg, cache_len, cache=profile_cache)
    curves = {n: fit_curve(p) for n, p in profiles.items()}
    alloc = allocate_stage01(curves, requests)
    return ServePlan(alloc, curves, profiles, requests, cache_len,
                     wave_latency=alloc.predicted_iter_time,
                     profiling_probes=sum(p.probes for p in profiles.values()),
                     profiling_probes_saved=probes_saved(profiles))


def make_runners(cluster: ClusterSpec, cfg: ModelConfig, seq_len: int,
                 zero_stage: int, remat: bool = True, noise: float = 0.0,
                 packed: Optional[PackedWorkload] = None,
                 ) -> Dict[str, DeviceRunner]:
    """Analytical runners — one per device — for the given workload/stage.

    ``packed`` prices the effective (non-pad) workload of a packed batch
    stream: the attention term shrinks to the mean segment length and pad
    slots are discounted (see workload.train_flops_per_row).
    """
    fps = train_flops_per_row(cfg, seq_len, packed)
    runners: Dict[str, DeviceRunner] = {}
    counts: Dict[str, int] = {}
    for spec in cluster.devices:
        counts[spec.name] = counts.get(spec.name, 0) + 1
        name = f"{spec.name}#{counts[spec.name]}"
        mem = MemoryModel(cfg, seq_len, zero_stage, cluster.n, remat)
        runners[name] = AnalyticalRunner(spec, mem, fps, zero_stage,
                                         noise=noise)
    return runners


def plan(cluster: ClusterSpec, cfg: ModelConfig, gbs: int, seq_len: int,
         zero_stage: Optional[int] = None, remat: bool = True,
         runner_factory: Optional[Callable[[int], Dict[str, DeviceRunner]]] = None,
         overlap_factor: float = 0.0,
         probe_cap: Optional[int] = None,
         packed: Optional[PackedWorkload] = None,
         profile_cache: Optional[Dict] = None,
         ) -> PoplarPlan:
    """Run the full Poplar pipeline.

    ``zero_stage=None`` enables automatic stage escalation (paper: start at
    ZeRO-0; if any device cannot fit one sample, escalate).

    ``probe_cap`` bounds Algorithm 1's exponential probing (measured
    runners pay a real jit compile per probed batch size; analytical
    runners are free and default to the uncapped search).

    ``overlap_factor`` feeds the scheduled-ZeRO overlap term into the
    batch-allocation sweep and the simulator replay (0 = the serial
    XLA-auto model; see core/overlap.SCHEDULED_OVERLAP_FACTOR for the
    scheduled path's calibration default) — hetero allocations then
    account for comm hidden under compute. The scheduled execution path
    only exists at stage 3, so the factor is zeroed for any other stage
    the escalation settles on (crediting hiding the runtime can't
    deliver would inflate predictions and skew the sweep).

    ``packed`` (a workload.PackedWorkload, e.g. derived from the
    loader's PackingStats) prices analytical profiles and the simulator
    replay at the *effective* packed workload — attention spans the mean
    segment length, pad slots are discounted — so the compute/comm
    balance the allocation sweep optimizes matches what packed rows
    actually cost. Measured runners (runner_factory) see the effect for
    free by probing real packed batches.

    ``profile_cache`` (a caller-owned dict) lets repeated plans over an
    unchanged workload reuse measured profiles instead of re-running
    Algorithm 1 — see profiler.profile_cluster.
    """
    stages = [zero_stage] if zero_stage is not None else [0, 1, 2, 3]
    last_err: Optional[Exception] = None
    for stage in stages:
        stage_overlap = overlap_factor if stage == 3 else 0.0
        runners = (runner_factory(stage) if runner_factory
                   else make_runners(cluster, cfg, seq_len, stage, remat,
                                     packed=packed))
        profiles = profile_cluster(runners, stage,
                                   max_probe_cap=probe_cap or (1 << 16),
                                   cache=profile_cache)
        if any(p.mbs < 1 for p in profiles.values()):
            last_err = SimOOM(f"stage {stage}: some device cannot fit batch 1")
            continue
        curves = {n: fit_curve(p) for n, p in profiles.items()}
        if stage <= 1:
            alloc = allocate_stage01(curves, gbs)
        else:
            comm = comm_time_per_microstep(cfg, stage, cluster.n,
                                           cluster.effective_link_gbps(cluster.n))
            alloc = allocate_stage23(curves, gbs, comm, stage,
                                     overlap_factor=stage_overlap)
        alloc.zero_stage = stage
        fps = train_flops_per_row(cfg, seq_len, packed)
        predicted = simulate_plan(alloc, curves, cfg, seq_len, cluster, fps,
                                  overlap_factor=stage_overlap)
        sources = {p.source for p in profiles.values()}
        return PoplarPlan(stage, alloc, curves, profiles, predicted,
                          profiling_probes=sum(p.probes for p in profiles.values()),
                          profiling_probes_saved=probes_saved(profiles),
                          profile_source=(sources.pop() if len(sources) == 1
                                          else "mixed"))
    raise last_err or SimOOM("no feasible stage")
