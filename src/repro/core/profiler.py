"""Online profiling — Algorithm 1 of the paper.

Per device, fully automatically:
  phase 1: linear memory-scaling estimate of the theoretical max batch size
           (one forward at batch 1, extrapolate to device capacity);
  phase 2: exponential probing (1,2,4,...) followed by binary search for the
           exact OOM-free ``mbs``, recording step wall-time at every probe.

Per-stage *TimeConsumedDuringStep* (paper §Online Profiling): collective
time is subtracted so only heterogeneous compute is compared —
  ZeRO-0/1: fwd + bwd;
  ZeRO-2:   fwd + (bwd − reduce-scatter);
  ZeRO-3:   total − AG_fwd − AG_bwd − reduce-scatter.

Runners implement the measurement substrate: `AnalyticalRunner` simulates a
published `DeviceSpec` (used for the paper's GPU clusters on this CPU box);
`MeasuredRunner` really executes and times a jitted step (used in tests and
the CPU examples) with a compile-time `memory_analysis()` OOM oracle — we
never risk a real OOM (DESIGN.md §2).
"""
from __future__ import annotations

import math
import time
import zlib
from dataclasses import dataclass, field, replace
from typing import Callable, Dict, List, Optional, Protocol, Tuple

import numpy as np

from repro.core.cluster import DeviceSpec
from repro.core.workload import MemoryModel


class SimOOM(Exception):
    """Raised by a runner when a batch does not fit device memory."""


@dataclass
class StepSegments:
    """Wall-time segments of one training step (seconds)."""
    fwd: float
    bwd: float
    optim: float = 0.0
    ag_fwd: float = 0.0      # all-gather during forward (ZeRO-3)
    ag_bwd: float = 0.0      # all-gather during backward (ZeRO-3)
    rs_bwd: float = 0.0      # reduce-scatter during backward (ZeRO-2/3)

    @property
    def total(self) -> float:
        return (self.fwd + self.bwd + self.optim
                + self.ag_fwd + self.ag_bwd + self.rs_bwd)


def time_consumed_during_step(seg: StepSegments, zero_stage: int) -> float:
    """The paper's per-stage compute-time extraction."""
    if zero_stage in (0, 1):
        return seg.fwd + seg.bwd
    if zero_stage == 2:
        return seg.fwd + seg.bwd  # bwd here is already compute-only …
    # ZeRO-3: subtract both all-gathers and the reduce-scatter
    return seg.total - seg.ag_fwd - seg.ag_bwd - seg.rs_bwd - seg.optim


class DeviceRunner(Protocol):
    def memory_bytes_at(self, batch: int) -> float: ...
    def memory_capacity_bytes(self) -> float: ...
    def run_step(self, batch: int) -> StepSegments: ...

    # provenance tag recorded on the resulting DeviceProfile ("analytical"
    # or "measured") — how the planner proves where its timings came from
    source: str
    # hashable identity of the (device kind, workload) this runner measures;
    # `profile_cluster` profiles one representative per key and shares the
    # result across identical devices. None = never share.
    dedupe_key: Optional[Tuple]


@dataclass
class AnalyticalRunner:
    """Simulates one device of the given spec running the given workload."""
    spec: DeviceSpec
    memory: MemoryModel
    flops_per_sample: float          # train fwd+bwd flops for one sample
    zero_stage: int = 0
    seed: int = 0
    noise: float = 0.0               # relative timing jitter
    source: str = field(default="analytical", init=False, repr=False)
    _rng: np.random.Generator = field(init=False, repr=False)

    def __post_init__(self):
        # stable per-spec seed: crc32 is process-independent, unlike
        # hash(str) which varies with PYTHONHASHSEED — noisy profiles must
        # reproduce across processes
        self._rng = np.random.default_rng(
            self.seed + zlib.crc32(self.spec.name.encode()) % 1000)

    @property
    def dedupe_key(self) -> Tuple:
        # identical (spec, stage, seed, noise) devices draw identical noise
        # (the rng is seeded from the spec name), so one profile serves all
        return (self.spec.name, self.zero_stage, self.seed, self.noise)

    def memory_capacity_bytes(self) -> float:
        return self.spec.mem_gb * 1e9

    def memory_bytes_at(self, batch: int) -> float:
        return self.memory.bytes_at_batch(batch)

    def compute_time(self, batch: int) -> float:
        """Saturating-throughput curve: rate(b) = peak·mfu·b/(b+h)."""
        if batch <= 0:
            return self.spec.overhead_s
        eff = self.spec.peak_tflops * 1e12 * self.spec.mfu
        sat = batch / (batch + self.spec.half_batch)
        t = self.spec.overhead_s + batch * self.flops_per_sample / (eff * sat)
        if self.noise:
            t *= float(1.0 + self.noise * self._rng.standard_normal())
        return t

    def run_step(self, batch: int) -> StepSegments:
        if self.memory_bytes_at(batch) > self.memory_capacity_bytes():
            raise SimOOM(f"{self.spec.name}: batch {batch} OOM")
        t = self.compute_time(batch)
        # fwd:bwd ~ 1:2; collective segments are filled by the simulator
        return StepSegments(fwd=t / 3.0, bwd=2.0 * t / 3.0)


@dataclass
class MeasuredRunner:
    """Times a real jitted train step (CPU in this container, TPU on prod).

    ``step_fn(batch_size)`` must run one full training step for that batch
    size and block until complete. The OOM oracle is the compile-time
    memory analysis (bytes) against ``capacity_bytes``.
    """
    step_fn: Callable[[int], None]
    memory_bytes_fn: Callable[[int], float]
    capacity_bytes: float
    warmup: int = 1
    repeats: int = 2
    # measured runners over a shared step harness are identical per device
    # kind: give them the same dedupe_key so profiling runs once per kind
    dedupe_key: Optional[Tuple] = None
    # persistent identity of the (workload, device kind) this runner times
    # — e.g. (cfg fingerprint, seq_len, stage, spec name). Runners sharing
    # a cache_key produce the same profile across *calls*, so a re-plan on
    # an unchanged workload can skip Algorithm 1 entirely (see
    # profile_cluster's ``cache``). None = never cache.
    cache_key: Optional[Tuple] = None
    source: str = field(default="measured", init=False, repr=False)

    def memory_capacity_bytes(self) -> float:
        return self.capacity_bytes

    def memory_bytes_at(self, batch: int) -> float:
        return self.memory_bytes_fn(batch)

    def run_step(self, batch: int) -> StepSegments:
        if self.memory_bytes_at(batch) > self.capacity_bytes:
            raise SimOOM(f"batch {batch} predicted OOM")
        for _ in range(self.warmup):
            self.step_fn(batch)
        ts = []
        for _ in range(self.repeats):
            t0 = time.perf_counter()
            self.step_fn(batch)
            ts.append(time.perf_counter() - t0)
        t = float(np.median(ts))
        return StepSegments(fwd=t / 3.0, bwd=2 * t / 3.0)


# ---------------------------------------------------------------------------
# Algorithm 1
# ---------------------------------------------------------------------------

@dataclass
class DeviceProfile:
    name: str
    mbs: int                          # exact max OOM-free batch size
    points: Dict[int, float]          # batch -> TimeConsumedDuringStep (s)
    probes: int = 0                   # number of model executions (overhead)
    source: str = "analytical"        # provenance: which runner timed this
    shared_from: Optional[str] = None  # representative device, if deduped

    def speed_points(self) -> Tuple[np.ndarray, np.ndarray]:
        bs = np.array(sorted(self.points), dtype=np.float64)
        sp = np.array([bs_i / self.points[int(bs_i)] for bs_i in bs])
        return bs, sp


def profile_device(runner: DeviceRunner, name: str, zero_stage: int,
                   max_probe_cap: int = 1 << 16) -> DeviceProfile:
    """Algorithm 1, both loops: linear estimate -> exponential -> binary."""
    points: Dict[int, float] = {}
    probes = 0
    source = getattr(runner, "source", "analytical")

    def try_step(b: int) -> Optional[float]:
        nonlocal probes
        probes += 1
        try:
            seg = runner.run_step(b)
        except SimOOM:
            return None
        t = time_consumed_during_step(seg, zero_stage)
        points[b] = t
        return t

    # ---- phase 1: linear estimate from a single batch ----
    if try_step(1) is None:
        # cannot even run one sample at this stage (caller escalates stage)
        return DeviceProfile(name, 0, {}, probes, source)
    base = runner.memory_bytes_at(0)
    one = runner.memory_bytes_at(1)
    cap = runner.memory_capacity_bytes()
    per_sample = max(one - base, 1.0)
    mbs_est = int(min((cap - base) / per_sample, max_probe_cap))
    mbs_est = max(mbs_est, 1)

    # ---- phase 2a: exponential probing up to the estimate ----
    b = 1
    last_ok = 1
    while b < mbs_est:
        b = min(b * 2, mbs_est)
        if try_step(b) is None:
            mbs_est = b - 1
            break
        last_ok = b

    # ---- phase 2b: binary search in (last_ok, mbs_est] ----
    low, high = last_ok, mbs_est
    while low < high:
        mid = (low + high + 1) // 2
        if mid == last_ok:
            break
        if try_step(mid) is None:
            high = mid - 1
        else:
            low = mid
    mbs = low
    return DeviceProfile(name, mbs, points, probes, source)


def profile_cluster(runners: Dict[str, DeviceRunner], zero_stage: int,
                    max_probe_cap: int = 1 << 16, dedupe: bool = True,
                    cache: Optional[Dict[Tuple, DeviceProfile]] = None,
                    ) -> Dict[str, DeviceProfile]:
    """Profile every device (the paper runs them in parallel; order is
    irrelevant to the result).

    ``dedupe`` profiles one *representative* per ``runner.dedupe_key`` and
    shares its curve with the other devices of the same kind — N identical
    devices cost one Algorithm-1 run, not N. Shared copies carry
    ``probes=0`` and ``shared_from=<representative>``, so summing
    ``probes`` over the profiles still counts real model executions and
    :func:`probes_saved` reports what deduplication avoided.

    ``cache`` extends the same idea *across calls*: a mutable dict the
    caller owns, keyed by ``runner.cache_key`` (a persistent workload
    identity — measured runners pay a real jit compile per probe, so an
    elastic re-plan over an unchanged (cfg, seq, stage, device kind)
    should not re-run Algorithm 1). Hits are served with ``probes=0`` and
    keep their original ``source``; misses are profiled then stored.
    """
    profiles: Dict[str, DeviceProfile] = {}
    reps: Dict[Tuple, str] = {}
    for name, r in runners.items():
        key = getattr(r, "dedupe_key", None) if dedupe else None
        if key is not None and key in reps:
            rep = profiles[reps[key]]
            profiles[name] = replace(rep, name=name, probes=0,
                                     shared_from=rep.name)
            continue
        ckey = (getattr(r, "cache_key", None)
                if cache is not None else None)
        if ckey is not None and ckey in cache:
            # shared_from=None: the representative lives in a previous
            # call's profile set, not this one
            profiles[name] = replace(cache[ckey], name=name, probes=0,
                                     shared_from=None)
        else:
            profiles[name] = profile_device(r, name, zero_stage,
                                            max_probe_cap)
            if ckey is not None:
                cache[ckey] = profiles[name]
        if key is not None:
            reps[key] = name
    return profiles


def decode_profiles(cluster, cfg, cache_len: int,
                    cache: Optional[Dict[Tuple, DeviceProfile]] = None,
                    ) -> Dict[str, DeviceProfile]:
    """Analytical decode-speed profiles per device: one decode step is
    HBM-bound — it reads every active parameter once plus ``b`` KV-cache
    rows of ``cache_len`` tokens — so step time at batch ``b`` is
    ``(param_bytes + b * cache_tok * cache_len) / hbm_bw``.

    Mirrors :func:`profile_cluster`'s economics on the serve path: one
    profile per device *kind* per call (identical devices share, with
    ``probes=0`` / ``shared_from``), and a caller-owned ``cache`` serves
    repeated plans over an unchanged (cfg, cache_len, kind) workload with
    ``probes=0`` — what makes arbiter candidate sweeps cheap.
    """
    param_bytes = cfg.active_params * 2
    cache_tok = (2 * cfg.n_kv_heads * cfg.resolved_head_dim * 2
                 * max(len([k for k in cfg.blocks()
                            if k in ("attn", "moe", "shared_attn")]), 1))
    profiles: Dict[str, DeviceProfile] = {}
    reps: Dict[Tuple, str] = {}
    counts: Dict[str, int] = {}
    for dev in cluster.devices:
        counts[dev.name] = counts.get(dev.name, 0) + 1
        name = f"{dev.name}#{counts[dev.name]}"
        key = ("decode", dev.name, cfg.name, cfg.active_params, cache_len)
        if key in reps:
            rep = profiles[reps[key]]
            profiles[name] = replace(rep, name=name, probes=0,
                                     shared_from=rep.name)
            continue
        reps[key] = name
        if cache is not None and key in cache:
            profiles[name] = replace(cache[key], name=name, probes=0,
                                     shared_from=None)
            continue
        bw = dev.hbm_gbps * 1e9
        mbs = max(int(dev.mem_gb * 1e9 * 0.6
                      // max(cache_tok * cache_len, 1)), 1)
        points, b = {}, 1
        while b <= mbs:
            points[b] = (param_bytes + b * cache_tok * cache_len) / bw
            b *= 2
        profiles[name] = DeviceProfile(name=name, mbs=mbs, points=points,
                                       probes=len(points))
        if cache is not None:
            cache[key] = profiles[name]
    return profiles


def probes_saved(profiles: Dict[str, DeviceProfile]) -> int:
    """Model executions deduplication avoided (vs profiling every device)."""
    return sum(profiles[p.shared_from].probes
               for p in profiles.values() if p.shared_from)


def auto_stage(runners: Dict[str, DeviceRunner], start_stage: int = 0,
               make_runner: Optional[Callable[[str, int], DeviceRunner]] = None
               ) -> Tuple[int, Dict[str, DeviceProfile]]:
    """Paper: 'starting from ZeRO-0, if the current stage cannot even run a
    single batch, automatically increase the ZeRO stage.'"""
    stage = start_stage
    while stage <= 3:
        rs = runners if make_runner is None else {
            n: make_runner(n, stage) for n in runners}
        profs = profile_cluster(rs, stage)
        if all(p.mbs >= 1 for p in profs.values()):
            return stage, profs
        stage += 1
    raise SimOOM("model does not fit at any ZeRO stage")
