"""Quantized collectives (ZeRO++ qwZ/qgZ-style) — int8-on-the-wire
reduce-scatter and all-gather, built from explicit shard_map collectives.

The paper's appendix names "Quantized Weight Communication" and
"Quantized Gradient Communication" as the ZeRO optimizations it defers
to future work; these are the building blocks. Payloads cross the
interconnect as int8 with per-block float32 scales (block = a contiguous
chunk of the flattened tensor), cutting wire bytes ~2x vs bf16 / ~4x vs
f32 at a bounded quantization error (tests pin the bound).

``quantized_reduce_scatter`` follows the qgZ schedule: quantize ->
all_to_all -> dequantize -> local sum, so the reduction itself happens
in f32 (int8 psum would overflow and compound error).

Integration note: the scheduled ZeRO-3 train path (core/overlap.py,
``rules.overlap="scheduled"`` + ``rules.comm_dtype="int8"``) rides these
as its wire format — parameter all-gathers go out quantized and each
layer's backward reduce-scatter follows the qgZ schedule. The XLA-auto
train path still lets SPMD insert its own (unquantized) reductions.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

INT8_MAX = 127.0


def axis_size(axis_name) -> int:
    """Mapped-axis size across JAX versions: ``jax.lax.axis_size`` only
    exists on newer releases; ``psum(1, axis)`` is the portable spelling
    (special-cased to a static int)."""
    fn = getattr(jax.lax, "axis_size", None)
    if fn is not None:
        return fn(axis_name)
    return jax.lax.psum(1, axis_name)


def _pad_to(x: jnp.ndarray, multiple: int) -> Tuple[jnp.ndarray, int]:
    pad = (-x.shape[0]) % multiple
    if pad:
        x = jnp.pad(x, ((0, pad),))
    return x, pad


def quantize_blocks(x: jnp.ndarray, block: int = 256
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Flat f32/bf16 -> (int8 payload, per-block f32 scales)."""
    xf, _ = _pad_to(x.reshape(-1).astype(jnp.float32), block)
    xb = xf.reshape(-1, block)
    amax = jnp.max(jnp.abs(xb), axis=1, keepdims=True)
    scale = jnp.where(amax > 0, amax / INT8_MAX, 1.0)
    q = jnp.clip(jnp.round(xb / scale), -INT8_MAX, INT8_MAX).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_blocks(q: jnp.ndarray, scale: jnp.ndarray, n: int,
                      dtype=jnp.float32) -> jnp.ndarray:
    xb = q.astype(jnp.float32) * scale
    return xb.reshape(-1)[:n].astype(dtype)


def quantized_reduce_scatter(x: jnp.ndarray, axis_name: str,
                             block: int = 256) -> jnp.ndarray:
    """Inside shard_map: reduce a replicated-shape per-device tensor over
    ``axis_name`` and return this device's 1/n partition (flattened).

    Partitions are *shard-aligned*: the flat tensor is padded to a
    multiple of n (not n*block) before splitting, so partition i is
    exactly elements [i*ceil(len/n), (i+1)*ceil(len/n)) of the reduced
    tensor — composable with a tiled all-gather of ZeRO shards. Block
    padding for quantization happens per-partition inside
    ``quantize_blocks`` (and is trimmed by ``dequantize_blocks``).

    Wire traffic per participant: n-1 int8 partitions + scales
    (vs n-1 f32 partitions for an unquantized reduce-scatter).
    """
    n = axis_size(axis_name)
    flat = x.reshape(-1).astype(jnp.float32)
    flat, _ = _pad_to(flat, n)
    part = flat.reshape(n, -1)                       # (n, per)
    per = part.shape[1]
    q, scale = jax.vmap(lambda p: quantize_blocks(p, block))(part)
    # exchange: device i keeps the pieces destined to partition i
    q = jax.lax.all_to_all(q, axis_name, split_axis=0, concat_axis=0)
    scale = jax.lax.all_to_all(scale, axis_name, split_axis=0, concat_axis=0)
    deq = jax.vmap(lambda qq, ss: dequantize_blocks(qq, ss, per))(q, scale)
    return deq.sum(axis=0)                           # (per,) f32


def quantized_all_gather(x: jnp.ndarray, axis_name: str,
                         block: int = 256) -> jnp.ndarray:
    """Inside shard_map: gather each device's flat partition as int8 +
    scales; returns the concatenated f32 tensor (n * len(x),)."""
    q, scale = quantize_blocks(x.reshape(-1).astype(jnp.float32), block)
    nloc = x.size
    qg = jax.lax.all_gather(q, axis_name)            # (n, blocks, block)
    sg = jax.lax.all_gather(scale, axis_name)
    deq = jax.vmap(lambda qq, ss: dequantize_blocks(qq, ss, nloc))(qg, sg)
    return deq.reshape(-1)


def wire_bytes(n_elems: int, block: int = 256,
               unquantized_dtype=jnp.float32) -> Tuple[int, int]:
    """(quantized, unquantized) wire bytes for an n_elems exchange."""
    blocks = -(-n_elems // block)
    qbytes = n_elems * 1 + blocks * 4
    ubytes = n_elems * jnp.dtype(unquantized_dtype).itemsize
    return qbytes, ubytes
