"""Logical-axis sharding rules (MaxText-style) + ZeRO param/opt sharding.

Models annotate parameters and activations with *logical* axis names
("batch", "heads", "ffn", "experts", "vocab", ...). A :class:`MeshRules`
instance maps logical names onto physical mesh axes, checks divisibility,
and layers the ZeRO stage on top:

- tensor parallelism: logical axes that map to the ``model`` axis;
- ZeRO-3 (FSDP): every parameter is additionally sharded along its largest
  still-unsharded, divisible dimension over the ``data`` (and, unless
  hierarchical-ZeRO is enabled, ``pod``) axes;
- ZeRO-1/2: the same data-axis sharding is applied to optimizer state /
  gradients only, while parameters stay replicated.

A thread-local "current rules" pointer lets pure-jnp model code call
:func:`constrain` without threading a mesh object everywhere; outside a
rules context it is a no-op (CPU unit tests).
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> preferred mesh axes, in priority order. Tuple entries are
# compound (all used together).
DEFAULT_LOGICAL_RULES: Dict[str, Tuple] = {
    "batch": (("pod", "data"), ("data",)),
    "heads": (("model",),),
    "kv_heads": (("model",),),
    "ffn": (("model",),),
    "experts": (("model",),),
    "expert_capacity": (),
    "vocab": (("model",),),
    "embed": (),            # activations' d_model stays unsharded (TP on heads/ffn)
    "seq": (),              # overridden for long-context decode layouts
    "kv_seq": (("model",),),  # KV-cache sequence sharding for decode
    "layers": (),
    "ssm_heads": (("model",),),
    "state": (),
    "conv": (),
}


def shard_map_compat(f, *, mesh, in_specs, out_specs):
    """shard_map across JAX versions: the function moved from
    jax.experimental.shard_map to jax.shard_map, and the replication-check
    kwarg was renamed check_rep -> check_vma. Always disables the check
    (our local_fns mix replicated and sharded outputs)."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=False)
    from jax.experimental.shard_map import shard_map as sm
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              check_rep=False)


def _axis_size(mesh: Mesh, axes) -> int:
    size = 1
    for a in (axes if isinstance(axes, (tuple, list)) else (axes,)):
        size *= mesh.shape[a]
    return size


@dataclass
class MeshRules:
    mesh: Mesh
    zero_stage: int = 3
    # hierarchical ZeRO (ZeRO++ hpZ-style): params shard over 'data' only,
    # never across 'pod'; cross-pod traffic is gradient reduction only.
    hierarchical_params: bool = False
    rules: Dict[str, Tuple] = field(default_factory=lambda: dict(DEFAULT_LOGICAL_RULES))
    # shard KV caches along sequence over 'model' when kv_heads don't divide
    kv_seq_shard: bool = True
    # pure data parallelism (§Perf/P3): disable tensor parallelism, map
    # 'batch' over (data, model) jointly and let ZeRO shard params over
    # the model axis too. The right regime for attention-free archs whose
    # head count can't use the model axis (e.g. xLSTM H=4 on a 16-way TP
    # axis) — TP buys nothing there but forces per-scan-chunk resharding.
    dp_only: bool = False
    # ZeRO-3 collective scheduling (core/overlap.py): "xla" leaves every
    # all-gather/reduce-scatter to auto-SPMD (the parity oracle);
    # "scheduled" runs the explicit shard_map step with double-buffered
    # layer prefetch + per-layer grad reduce-scatter; "auto" picks
    # scheduled whenever the (mesh, stage, batch) combination supports it.
    overlap: str = "xla"
    # wire format of the scheduled path's sharded collectives: None keeps
    # the param dtype; "int8" rides qcomm's block-quantized AG/RS.
    comm_dtype: Optional[str] = None
    # scheduled path only: True = two-deep prefetch pipeline (layer l+1's
    # all-gather in flight under layer l's compute; backward reuses the
    # saved gather); False = gather inside the remat region (backward
    # re-gathers; lowest memory, the classic ZeRO-3 schedule).
    overlap_prefetch: bool = True

    def __post_init__(self):
        if self.dp_only:
            rules = dict(self.rules)
            rules["batch"] = (("pod", "data", "model"), ("data", "model"),
                              ("data",))
            for ax in ("heads", "kv_heads", "ffn", "experts", "vocab",
                       "kv_seq", "ssm_heads"):
                rules[ax] = ()
            self.rules = rules

    # ---------------- logical -> physical -----------------
    def _resolve(self, logical: Optional[str], dim: int, taken: set) -> Optional[Tuple[str, ...]]:
        if logical is None:
            return None
        for cand in self.rules.get(logical, ()):  # priority order
            axes = tuple(cand) if isinstance(cand, (tuple, list)) else (cand,)
            if any(a not in self.mesh.shape or a in taken for a in axes):
                continue
            if dim % _axis_size(self.mesh, axes) == 0:
                return axes
        return None

    def activation_spec(self, logical_axes: Sequence[Optional[str]],
                        shape: Optional[Sequence[int]] = None) -> P:
        taken: set = set()
        parts = []
        for i, name in enumerate(logical_axes):
            dim = shape[i] if shape is not None else 0
            axes = None
            if name is not None:
                for cand in self.rules.get(name, ()):
                    cand_t = tuple(cand) if isinstance(cand, (tuple, list)) else (cand,)
                    if any(a not in self.mesh.shape or a in taken for a in cand_t):
                        continue
                    if shape is None or dim % _axis_size(self.mesh, cand_t) == 0:
                        axes = cand_t
                        break
            if axes is None:
                parts.append(None)
            else:
                taken.update(axes)
                parts.append(axes if len(axes) > 1 else axes[0])
        return P(*parts)

    # data axes used for ZeRO param/opt sharding
    def _zero_axes(self) -> Tuple[str, ...]:
        axes = []
        if "pod" in self.mesh.shape and not self.hierarchical_params:
            axes.append("pod")
        if "data" in self.mesh.shape:
            axes.append("data")
        if self.dp_only and "model" in self.mesh.shape:
            axes.append("model")     # model axis is free of TP in dp_only
        return tuple(axes)

    def param_spec(self, shape: Sequence[int],
                   logical_axes: Sequence[Optional[str]],
                   zero_sharded: Optional[bool] = None) -> P:
        """Physical spec for a parameter (or same-shaped opt state).

        ``zero_sharded``: whether to additionally shard over the data/pod
        axes. Defaults by stage: params are data-sharded only at stage 3;
        optimizer state at stages >= 1 (callers pass the right flag).
        """
        if zero_sharded is None:
            zero_sharded = self.zero_stage >= 3
        taken: set = set()
        parts: list = [None] * len(shape)
        # 1) tensor parallel axes from logical names
        for i, name in enumerate(logical_axes):
            axes = self._resolve(name, shape[i], taken)
            if axes is not None:
                parts[i] = axes if len(axes) > 1 else axes[0]
                taken.update(axes)
        # 2) ZeRO data-axis sharding on the largest free divisible dim
        if zero_sharded:
            zaxes = tuple(a for a in self._zero_axes() if a not in taken)
            if zaxes:
                zsize = _axis_size(self.mesh, zaxes)
                best = -1
                # prefer later (non-layer-stack) dims on ties: iterate all,
                # pick largest divisible dim not already sharded; skip dim 0
                # when it is a scan-stacked 'layers' axis.
                for i, d in enumerate(shape):
                    if parts[i] is not None:
                        continue
                    if logical_axes[i] == "layers":
                        continue
                    eff = d  # remaining size on this dim
                    if eff % zsize == 0 and (best < 0 or shape[i] > shape[best]):
                        best = i
                if best >= 0:
                    existing = parts[best]
                    assert existing is None
                    parts[best] = zaxes if len(zaxes) > 1 else zaxes[0]
        return P(*parts)

    def sharding(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)


# ---------------------------------------------------------------------------
# thread-local current rules + constrain()
# ---------------------------------------------------------------------------

_TLS = threading.local()


def current_rules() -> Optional[MeshRules]:
    return getattr(_TLS, "rules", None)


@contextlib.contextmanager
def use_rules(rules: Optional[MeshRules]):
    prev = getattr(_TLS, "rules", None)
    _TLS.rules = rules
    try:
        yield rules
    finally:
        _TLS.rules = prev


def constrain(x: jax.Array, logical_axes: Sequence[Optional[str]]) -> jax.Array:
    """Apply a logical-axis sharding constraint if rules are active."""
    rules = current_rules()
    if rules is None:
        return x
    spec = rules.activation_spec(logical_axes, x.shape)
    return jax.lax.with_sharding_constraint(x, rules.sharding(spec))
