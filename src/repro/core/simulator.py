"""BSP cluster simulator: evaluates an AllocationPlan against device
performance curves, reproducing the paper's throughput metric
(cluster TFLOPs = model FLOPs per iteration / iteration wall time / 1e12).

The simulator is deliberately *independent* of the search in
allocation.py — the search optimizes its own prediction, the simulator
replays the full BSP schedule (accumulation micro-steps, per-stage
synchronization points, collective costs) so a bad plan shows up as idle
time exactly like Figure 1 of the paper.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.core.allocation import AllocationPlan, PerfCurve
from repro.core.cluster import ClusterSpec
from repro.core.workload import comm_time_per_microstep, exposed_comm_time


@dataclass
class SimResult:
    strategy: str
    zero_stage: int
    iter_time: float                     # seconds per iteration
    device_busy: Dict[str, float]        # compute seconds per device
    device_idle: Dict[str, float]        # idle (sync wait) seconds
    comm_time: float                     # *exposed* collective seconds
    samples: int
    cluster_tflops: float = 0.0
    tokens_per_sec: float = 0.0
    comm_hidden: float = 0.0             # collective seconds overlapped away

    @property
    def utilization(self) -> float:
        busy = sum(self.device_busy.values())
        total = (sum(self.device_busy.values())
                 + sum(self.device_idle.values()) + 1e-12)
        return busy / total


def simulate_plan(plan: AllocationPlan, curves: Dict[str, PerfCurve],
                  cfg, seq_len: int, cluster: ClusterSpec,
                  flops_per_sample: float,
                  overlap_factor: float = 0.0) -> SimResult:
    """Replay one BSP iteration of `plan` on the cluster.

    ``overlap_factor > 0`` models the scheduled ZeRO execution path:
    per-sync collective time hides under the concurrent compute up to
    ``overlap_factor * compute`` (bounded by the schedulable comm
    fraction — see workload.exposed_comm_time); only the exposed
    remainder extends the iteration.
    """
    stage = plan.zero_stage
    names = [n for n, a in plan.assignments.items() if a.gmbs > 0]
    n_active = max(len(names), 1)
    comm_step = comm_time_per_microstep(cfg, stage, n_active,
                                        cluster.effective_link_gbps(n_active))
    busy: Dict[str, float] = {}
    per_dev_time: Dict[str, float] = {}
    total_comm = 0.0
    hidden_comm = 0.0

    if stage <= 1:
        # single sync point at iteration end: one all-reduce (stage 0) or
        # RS+AG around the sharded update (stage 1) — same ring volume.
        for n in names:
            a = plan.assignments[n]
            t = 0.0
            full_steps = a.gas - (1 if a.lbs else 0)
            t += full_steps * curves[n].time_of_batch(a.micro_batch)
            if a.lbs:
                t += curves[n].time_of_batch(a.lbs)
            per_dev_time[n] = t
            busy[n] = t
        compute_wall = max(per_dev_time.values(), default=0.0)
        total_comm = exposed_comm_time(comm_step, compute_wall,
                                       overlap_factor)
        hidden_comm = comm_step - total_comm
        iter_time = compute_wall + total_comm
    else:
        # every accumulation micro-step ends in a collective sync (RS for
        # stage 2; AG-fwd + AG-bwd + RS for stage 3) — all devices step in
        # lockstep `gas` times.
        gas = max((plan.assignments[n].gas for n in names), default=1)
        iter_time = 0.0
        busy = {n: 0.0 for n in names}
        for s in range(gas):
            step_times = {}
            for n in names:
                a = plan.assignments[n]
                if s < a.gas - (1 if a.lbs else 0):
                    b = a.micro_batch
                elif s < a.gas:
                    b = a.lbs or a.micro_batch
                else:
                    b = 0
                step_times[n] = curves[n].time_of_batch(b) if b else 0.0
                busy[n] += step_times[n]
            step_wall = max(step_times.values(), default=0.0)
            comm_exposed = exposed_comm_time(comm_step, step_wall,
                                             overlap_factor)
            iter_time += step_wall + comm_exposed
            total_comm += comm_exposed
            hidden_comm += comm_step - comm_exposed
        per_dev_time = dict(busy)

    idle = {n: iter_time - total_comm - busy.get(n, 0.0) for n in names}
    samples = plan.total_batch
    model_flops = samples * flops_per_sample
    result = SimResult(
        strategy=plan.strategy, zero_stage=stage, iter_time=iter_time,
        device_busy=busy, device_idle=idle, comm_time=total_comm,
        samples=samples,
        cluster_tflops=model_flops / max(iter_time, 1e-12) / 1e12,
        tokens_per_sec=samples * seq_len / max(iter_time, 1e-12),
        comm_hidden=hidden_comm,
    )
    return result
