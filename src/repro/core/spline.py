"""Natural cubic spline interpolation (paper appendix, McKinley & Levine).

Poplar fits each device's speed(batch) curve with a natural cubic spline
over the probe points collected during online profiling. Implemented from
scratch (tridiagonal solve) in numpy; no scipy dependency.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import numpy as np


@dataclass
class CubicSpline:
    """Natural cubic spline through (x_i, y_i); C2-continuous piecewise cubic."""
    x: np.ndarray       # knots, strictly increasing (n,)
    a: np.ndarray       # y values (n,)
    b: np.ndarray       # (n-1,)
    c: np.ndarray       # (n,)
    d: np.ndarray       # (n-1,)

    def __call__(self, xq):
        xq = np.asarray(xq, dtype=np.float64)
        scalar = xq.ndim == 0
        xq = np.atleast_1d(xq)
        # clamp extrapolation to the boundary segments
        idx = np.clip(np.searchsorted(self.x, xq, side="right") - 1, 0,
                      len(self.x) - 2)
        dx = xq - self.x[idx]
        y = (self.a[idx] + self.b[idx] * dx + self.c[idx] * dx ** 2
             + self.d[idx] * dx ** 3)
        return float(y[0]) if scalar else y

    def derivative(self, xq):
        xq = np.asarray(xq, dtype=np.float64)
        scalar = xq.ndim == 0
        xq = np.atleast_1d(xq)
        idx = np.clip(np.searchsorted(self.x, xq, side="right") - 1, 0,
                      len(self.x) - 2)
        dx = xq - self.x[idx]
        y = self.b[idx] + 2 * self.c[idx] * dx + 3 * self.d[idx] * dx ** 2
        return float(y[0]) if scalar else y


def fit_natural_cubic(xs: Sequence[float], ys: Sequence[float]) -> CubicSpline:
    x = np.asarray(xs, dtype=np.float64)
    y = np.asarray(ys, dtype=np.float64)
    assert x.ndim == 1 and x.shape == y.shape and len(x) >= 2
    assert np.all(np.diff(x) > 0), "knots must be strictly increasing"
    n = len(x)
    if n == 2:  # degenerate: linear segment
        b = np.array([(y[1] - y[0]) / (x[1] - x[0])])
        return CubicSpline(x, y, b, np.zeros(2), np.zeros(1))
    h = np.diff(x)                                   # (n-1,)
    # tridiagonal system for second-derivative coefficients c (natural BCs)
    alpha = np.zeros(n)
    alpha[1:-1] = (3.0 / h[1:] * (y[2:] - y[1:-1])
                   - 3.0 / h[:-1] * (y[1:-1] - y[:-2]))
    l = np.ones(n)
    mu = np.zeros(n)
    z = np.zeros(n)
    for i in range(1, n - 1):
        l[i] = 2.0 * (x[i + 1] - x[i - 1]) - h[i - 1] * mu[i - 1]
        mu[i] = h[i] / l[i]
        z[i] = (alpha[i] - h[i - 1] * z[i - 1]) / l[i]
    c = np.zeros(n)
    b = np.zeros(n - 1)
    d = np.zeros(n - 1)
    for j in range(n - 2, -1, -1):
        c[j] = z[j] - mu[j] * c[j + 1]
        b[j] = ((y[j + 1] - y[j]) / h[j]
                - h[j] * (c[j + 1] + 2.0 * c[j]) / 3.0)
        d[j] = (c[j + 1] - c[j]) / (3.0 * h[j])
    return CubicSpline(x, y.copy(), b, c, d)


def max_of_spline(sp: CubicSpline, lo: float, hi: float, samples: int = 512):
    """(argmax, max) of the spline on [lo, hi] by dense sampling + knots."""
    grid = np.linspace(lo, hi, samples)
    grid = np.concatenate([grid, sp.x[(sp.x >= lo) & (sp.x <= hi)]])
    vals = sp(grid)
    i = int(np.argmax(vals))
    return float(grid[i]), float(vals[i])
