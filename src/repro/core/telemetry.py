"""Step-time telemetry and drift detection — the *observe* leg of the
elastic Session lifecycle (plan → execute → observe → re-plan).

A plan is a prediction: the batch allocation came from profiled (or
analytical) per-device curves, and ``PoplarPlan.predicted.iter_time`` is
what the simulator expects one iteration to cost. The runtime records
what iterations *actually* cost into an :class:`EMAWindow`;
:func:`detect_drift` compares the smoothed observation against the
prediction and flags when the cluster has drifted far enough from the
plan that re-running the allocation search is worth its overhead (Zorse
/ Nie et al.: adapting allocation to observed throughput is where
heterogeneous clusters recover 20-40%).

The detector is deliberately mechanism-only: *when* to act on a
``DriftReport`` belongs to the caller (``Session.maybe_replan`` /
``launch/train.py --replan-every``).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple


@dataclass
class EMAWindow:
    """Exponential moving average of per-step wall time.

    The first ``warmup`` samples are discarded — they time jit
    compilation, not the steady-state step the plan predicted.

    Passing ``tokens`` (the step's *non-pad* token count, e.g. the loss
    mask sum) additionally maintains a ``tokens_per_sec`` EMA — the
    throughput metric that makes packed and padded runs comparable:
    wall-clock alone rewards computing pad garbage faster.
    """
    alpha: float = 0.3
    warmup: int = 1
    value: Optional[float] = None
    count: int = 0                    # samples folded into the EMA
    skipped: int = 0                  # warmup samples discarded
    last: Optional[float] = None
    tokens_per_sec: Optional[float] = None  # EMA of non-pad tokens / s

    def record(self, dt: float, tokens: Optional[float] = None) -> None:
        if self.skipped < self.warmup:
            self.skipped += 1
            return
        self.last = float(dt)
        self.value = (self.last if self.value is None
                      else self.alpha * self.last
                      + (1.0 - self.alpha) * self.value)
        self.count += 1
        if tokens is not None and self.last > 0:
            tps = float(tokens) / self.last
            self.tokens_per_sec = (tps if self.tokens_per_sec is None
                                   else self.alpha * tps
                                   + (1.0 - self.alpha) * self.tokens_per_sec)

    def reset(self) -> None:
        self.value, self.last = None, None
        self.count, self.skipped = 0, 0
        self.tokens_per_sec = None


class DeviceTimers:
    """Per-device step-time EMAs under SPMD.

    The global step EMA only sees ``max`` over devices — a straggler is
    invisible until it dominates. This window keeps one
    :class:`EMAWindow` per device so the drift detector can report
    *imbalance* (max/min of the per-device EMAs) next to the global
    ratio.

    What feeds it is substrate-dependent: a multi-host fleet records
    real per-host wall times; a single-process SPMD session has no
    per-device clock, so the Session feeds the best available proxy —
    observed wall time distributed over the plan's predicted per-device
    busy shares, scaled by any injected straggler factors (see
    ``Session._device_step_times``). The mechanism is the same either
    way; only the provider differs.
    """

    def __init__(self, alpha: float = 0.3, warmup: int = 1):
        self.alpha, self.warmup = alpha, warmup
        self.windows: Dict[str, EMAWindow] = {}

    def record(self, times: Dict[str, float]) -> None:
        for dev, dt in times.items():
            w = self.windows.get(dev)
            if w is None:
                w = self.windows[dev] = EMAWindow(alpha=self.alpha,
                                                 warmup=self.warmup)
            w.record(dt)

    def values(self) -> Dict[str, float]:
        return {d: w.value for d, w in self.windows.items()
                if w.value is not None}

    def imbalance(self) -> float:
        """max/min of the per-device EMAs (1.0 = balanced or unjudged)."""
        vals = [v for v in self.values().values() if v > 0]
        if len(vals) < 2:
            return 1.0
        return max(vals) / max(min(vals), 1e-12)

    def slowest(self) -> Optional[str]:
        vals = self.values()
        return max(vals, key=vals.get) if vals else None

    def reset(self) -> None:
        self.windows.clear()


@dataclass
class DriftConfig:
    """When does observed reality contradict the plan?

    ``threshold``: relative deviation of the observed EMA step time from
    the predicted iteration time beyond which drift is declared (0.5 =
    steps running >1.5x slower or <1/1.5x faster than planned).
    ``min_samples``: EMA samples required before judging — one noisy step
    must not trigger a re-plan.
    ``sample_every``: observe every k-th step only. Timing a step forces
    a host-device sync (``block_until_ready``), which forfeits JAX async
    dispatch for that step — on real accelerators, sample sparsely
    (e.g. 10) so the hot path keeps overlapping host work with device
    compute; drift moves slowly enough that sparse samples suffice.
    """
    threshold: float = 0.5
    min_samples: int = 3
    sample_every: int = 1


@dataclass
class DriftReport:
    observed_s: float                 # EMA of measured step wall time
    predicted_s: float                # plan.predicted.iter_time
    ratio: float                      # (observed / predicted) / baseline
    drifted: bool
    reason: str
    # substrate calibration in effect: the observed/predicted ratio taken
    # as nominal right after planning (see detect_drift)
    baseline: float = 1.0
    # predicted per-device compute imbalance of the *current* plan
    # (max busy / min busy over active devices) — context for deciding
    # whether a re-plan can plausibly rebalance anything
    predicted_imbalance: float = 1.0
    # *observed* per-device imbalance (max/min of the DeviceTimers EMAs;
    # 1.0 when unjudged). predicted says what the plan accepted; observed
    # says what the cluster is doing — observed >> predicted means a
    # straggler the plan did not price in
    observed_imbalance: float = 1.0
    # the device behind observed_imbalance, when one stands out
    slowest_device: Optional[str] = None


def predicted_imbalance(device_busy: Dict[str, float]) -> float:
    """max/min predicted busy seconds over active devices (1.0 = balanced)."""
    busy = [t for t in device_busy.values() if t > 0]
    if len(busy) < 2:
        return 1.0
    return max(busy) / max(min(busy), 1e-12)


def detect_drift(window: EMAWindow, predicted_s: Optional[float],
                 config: DriftConfig = DriftConfig(),
                 device_busy: Optional[Dict[str, float]] = None,
                 baseline: float = 1.0,
                 device_timers: Optional[DeviceTimers] = None
                 ) -> Optional[DriftReport]:
    """Compare the observed step-time EMA against the plan's prediction.

    Returns ``None`` while there is nothing to judge (no prediction — the
    session was built unplanned — or fewer than ``min_samples`` post-
    warmup observations); otherwise a :class:`DriftReport` whose
    ``drifted`` flag says whether observed wall time left the
    ``[1/(1+threshold), 1+threshold]`` band around the prediction.

    ``baseline`` is the substrate calibration: the simulator predicts
    *cluster* iteration time while the EMA measures *this host's* wall
    clock, and the two differ by a structural constant (on the CPU
    container, by orders of magnitude). The caller records the
    observed/predicted ratio right after planning as nominal and passes
    it here, so drift means "reality changed since the plan was made",
    not "the simulator's clock is not this host's clock". 1.0 = trust
    the prediction absolutely.
    """
    if predicted_s is None or predicted_s <= 0:
        return None
    if window.value is None or window.count < config.min_samples:
        return None
    ratio = window.value / predicted_s / max(baseline, 1e-12)
    hi = 1.0 + config.threshold
    lo = 1.0 / hi
    drifted = ratio > hi or ratio < lo
    if ratio > hi:
        reason = (f"steps {ratio:.2f}x slower than planned "
                  f"(>{hi:.2f}x band)")
    elif ratio < lo:
        reason = (f"steps {ratio:.2f}x of planned time "
                  f"(<{lo:.2f}x band) — plan underuses the cluster")
    else:
        reason = f"within band ({ratio:.2f}x of prediction)"
    obs_imb = device_timers.imbalance() if device_timers is not None else 1.0
    return DriftReport(
        observed_s=window.value, predicted_s=predicted_s, ratio=ratio,
        drifted=drifted, reason=reason, baseline=baseline,
        predicted_imbalance=predicted_imbalance(device_busy or {}),
        observed_imbalance=obs_imb,
        slowest_device=(device_timers.slowest()
                        if device_timers is not None and obs_imb > 1.0
                        else None))


class LatencyHistogram:
    """Log-spaced latency histogram (serving-side percentiles).

    Serving latency is judged by tail quantiles, and the engine sees
    thousands of per-token samples per second — storing them all is out,
    and an EMA hides the tail entirely. Geometric buckets (default 10
    per decade from 1µs to 1000s) give ~12% worst-case relative error on
    any percentile at a fixed 271-int footprint. ``percentile`` returns
    the geometric midpoint of the bucket holding the q-th sample.
    """

    def __init__(self, lo: float = 1e-6, hi: float = 1e3,
                 buckets_per_decade: int = 10):
        import math
        self.lo, self.hi = lo, hi
        decades = math.log10(hi / lo)
        n = max(int(round(decades * buckets_per_decade)), 1)
        self.ratio = (hi / lo) ** (1.0 / n)
        # bucket i covers [lo * ratio^i, lo * ratio^(i+1)); +2 for the
        # underflow/overflow catch-alls at the ends
        self.counts = [0] * (n + 2)
        self.total = 0
        self.sum = 0.0
        self.max = 0.0

    def _bucket(self, seconds: float) -> int:
        import math
        if seconds < self.lo:
            return 0
        if seconds >= self.hi:
            return len(self.counts) - 1
        return 1 + int(math.log(seconds / self.lo) / math.log(self.ratio))

    def record(self, seconds: float) -> None:
        s = max(float(seconds), 0.0)
        self.counts[min(self._bucket(s), len(self.counts) - 1)] += 1
        self.total += 1
        self.sum += s
        self.max = max(self.max, s)

    def percentile(self, q: float) -> Optional[float]:
        """q in [0, 100]. None until a sample lands."""
        if self.total == 0:
            return None
        target = q / 100.0 * self.total
        seen = 0
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= target and c > 0:
                if i == 0:
                    return self.lo
                if i == len(self.counts) - 1:
                    return self.max
                lo_edge = self.lo * self.ratio ** (i - 1)
                return lo_edge * self.ratio ** 0.5
        return self.max

    @property
    def mean(self) -> Optional[float]:
        return self.sum / self.total if self.total else None

    def reset(self) -> None:
        self.counts = [0] * len(self.counts)
        self.total, self.sum, self.max = 0, 0.0, 0.0


@dataclass
class ServeTelemetry:
    """Per-request serving metrics: TTFT and per-token latency histograms
    plus a generated-tokens/sec EMA — what the engine's describe/log line
    surfaces and what the hetero re-split loop watches for drift.

    TTFT (time-to-first-token) is recorded once per request when its
    prefill produces the first logits; per-token latency once per decode
    step per *live* request in the batch (padded bucket slots don't
    count). ``throughput`` smooths generated tokens per wall-second over
    decode steps — comparable to ``ServePlan.requests_per_sec *
    gen_tokens`` when judging plan drift.

    Prefill efficiency counters (the packed-prefill PR's scoreboard):
    ``prefill_calls`` counts model invocations (the packed path's whole
    point is fewer of them); ``prefill_fill_frac`` is valid tokens over
    bucket slots across those calls — how much of each padded buffer was
    real work; ``prefix_hit_tokens`` counts context tokens *not*
    computed because admission adopted shared prefix pages (so
    ``prefill_tokens`` < tokens submitted on prefix-heavy workloads).
    """
    ttft: LatencyHistogram = field(default_factory=LatencyHistogram)
    per_token: LatencyHistogram = field(default_factory=LatencyHistogram)
    throughput: EMAWindow = field(
        default_factory=lambda: EMAWindow(warmup=1))
    requests_done: int = 0
    tokens_generated: int = 0
    prefill_tokens: int = 0
    prefill_calls: int = 0
    prefill_pack_tokens: int = 0      # valid tokens across prefill buffers
    prefill_pack_slots: int = 0       # bucket slots across prefill buffers
    prefix_hit_tokens: int = 0

    def record_ttft(self, seconds: float) -> None:
        self.ttft.record(seconds)

    def record_decode(self, dt: float, live: int) -> None:
        """One decode step of ``live`` requests taking ``dt`` seconds."""
        if live <= 0:
            return
        self.per_token.record(dt)
        self.throughput.record(dt, tokens=live)
        self.tokens_generated += live

    def record_prefill(self, tokens: int) -> None:
        self.prefill_tokens += int(tokens)

    def record_prefill_call(self, valid: int, bucket: int) -> None:
        """One prefill model invocation whose buffer held ``valid`` real
        tokens in a ``bucket``-slot padded shape."""
        self.prefill_calls += 1
        self.prefill_pack_tokens += int(valid)
        self.prefill_pack_slots += int(bucket)

    def record_prefix_hit(self, tokens: int) -> None:
        self.prefix_hit_tokens += int(tokens)

    @property
    def prefill_fill_frac(self) -> Optional[float]:
        if self.prefill_pack_slots <= 0:
            return None
        return self.prefill_pack_tokens / self.prefill_pack_slots

    def record_finished(self, n: int = 1) -> None:
        self.requests_done += n

    def describe(self) -> str:
        def ms(v):
            return f"{v * 1e3:.1f}ms" if v is not None else "-"
        tps = self.throughput.tokens_per_sec
        rate = f"{tps:.1f} tok/s" if tps is not None else "warming"
        return (f"serve: {self.requests_done} done · "
                f"{self.tokens_generated} tok ({self.prefill_tokens} prefill) · "
                f"ttft p50 {ms(self.ttft.percentile(50))} "
                f"p95 {ms(self.ttft.percentile(95))} · "
                f"tok p50 {ms(self.per_token.percentile(50))} "
                f"p95 {ms(self.per_token.percentile(95))} · {rate}")

    def snapshot(self) -> Dict[str, Optional[float]]:
        return {
            "requests_done": self.requests_done,
            "tokens_generated": self.tokens_generated,
            "prefill_tokens": self.prefill_tokens,
            "prefill_calls": self.prefill_calls,
            "prefill_fill_frac": self.prefill_fill_frac,
            "prefix_hit_tokens": self.prefix_hit_tokens,
            "ttft_p50_s": self.ttft.percentile(50),
            "ttft_p95_s": self.ttft.percentile(95),
            "tok_p50_s": self.per_token.percentile(50),
            "tok_p95_s": self.per_token.percentile(95),
            "tokens_per_sec": self.throughput.tokens_per_sec,
        }


@dataclass
class FaultEvent:
    """One runtime transition: a fault observed, a recovery taken, a
    checkpoint committed. ``kind`` vocabulary (core/faults.py and the
    checkpoint writer emit these): ``device_loss``, ``transient``,
    ``fatal``, ``replan_recovered``, ``replan_failed``,
    ``restore_recovered``, ``gave_up``, ``save_async``,
    ``ckpt_committed``, ``ckpt_io_retry``, ``ckpt_failed``,
    ``ckpt_crashed``."""
    kind: str
    step: int = 0
    detail: str = ""
    seconds: float = 0.0              # how long the transition took
    wall: float = 0.0                 # time.time() at emission
    tenant: str = ""                  # owning tenant ("" = single-tenant)


@dataclass
class EventLog:
    """Append-only log of fault/recovery/checkpoint transitions — the
    reporting channel the supervised step loop and the async checkpoint
    writer share. ``verbose=True`` additionally prints each event (the
    ``[fault]`` lines of ``launch/train.py``)."""
    events: List[FaultEvent] = field(default_factory=list)
    verbose: bool = False

    def emit(self, kind: str, step: int = 0, detail: str = "",
             seconds: float = 0.0, tenant: str = "") -> FaultEvent:
        ev = FaultEvent(kind, step, detail, seconds, wall=time.time(),
                        tenant=tenant)
        self.events.append(ev)
        if self.verbose:
            extra = f" ({seconds:.2f}s)" if seconds else ""
            who = f"[{tenant}] " if tenant else ""
            print(f"[fault] {who}step {step}: {kind}"
                  + (f" — {detail}" if detail else "") + extra)
        return ev

    def counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for ev in self.events:
            out[ev.kind] = out.get(ev.kind, 0) + 1
        return out

    def __iter__(self):
        return iter(self.events)

    def __len__(self):
        return len(self.events)


@dataclass
class ArbitrationReport:
    """What one ``ClusterArbiter.arbitrate()`` decided, and what it cost.

    ``partition`` maps tenant name -> per-kind device composition of its
    new lease; ``devices`` maps tenant name -> the concrete instance
    names leased. ``suspended`` lists tenants left without a lease this
    round (degraded in priority order, checkpointed before yielding
    their devices)."""
    trigger: str                      # "initial" | "fault" | "drift" | "return" | "explicit"
    partition: Dict[str, Dict[str, int]]
    devices: Dict[str, Tuple[str, ...]]
    suspended: List[str]
    utility: float                    # summed weighted utility of the pick
    per_tenant_utility: Dict[str, float]
    candidates: int                   # partitions evaluated this round
    healthy: int                      # healthy device count arbitrated over
    seconds: float = 0.0


@dataclass
class ReplanReport:
    """What one ``Session.replan()`` did, and what it cost."""
    trigger: str                      # "explicit" | "drift" | "cluster" | "fault"
    plan_seconds: float               # planner (re-profile + search) time
    reshard_seconds: float            # state gather + re-place + re-jit
    old_devices: int
    new_devices: int
    zero_stage: int
    profile_source: str
    step: int                         # training step at which replan ran
    drift: Optional[DriftReport] = None

    @property
    def total_seconds(self) -> float:
        return self.plan_seconds + self.reshard_seconds
