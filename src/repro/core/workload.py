"""Analytical workload model: FLOPs and memory per sample for a ModelConfig.

Used by (a) the analytical device runner that simulates the paper's GPU
clusters, (b) Algorithm 1's linear memory estimation step, and (c) the
MODEL_FLOPS = 6·N·D sanity term of the roofline analysis.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from repro.configs.base import ModelConfig

BF16 = 2
F32 = 4


def train_flops_per_token(cfg: ModelConfig, seq_len: int) -> float:
    """6·N_active per token + quadratic attention term (fwd+bwd)."""
    base = 6.0 * cfg.active_params
    # attention scores+values: fwd 2 * 2 * S * hd per head-token, x3 for bwd
    n_attn_layers = sum(1 for k in cfg.blocks()
                        if k in ("attn", "moe", "shared_attn"))
    hd = cfg.resolved_head_dim
    attn = 12.0 * n_attn_layers * cfg.n_heads * hd * seq_len
    return base + attn


def fwd_flops_per_token(cfg: ModelConfig, seq_len: int) -> float:
    return train_flops_per_token(cfg, seq_len) / 3.0


@dataclass(frozen=True)
class PackedWorkload:
    """Effective (non-pad) token statistics of a packed batch stream.

    ``token_fraction``: real tokens per (row, seq_len) slot — one minus
    the pad fraction. ``mean_segment_len``: average packed document
    length — the span each token's attention actually covers once the
    segment-aware kernels skip cross-segment blocks.
    """
    token_fraction: float = 1.0
    mean_segment_len: Optional[float] = None

    @staticmethod
    def from_stats(stats) -> "PackedWorkload":
        """From a ``data.pipeline.PackingStats`` (duck-typed)."""
        return PackedWorkload(
            token_fraction=max(0.0, min(1.0, 1.0 - stats.pad_fraction)),
            mean_segment_len=float(stats.mean_segment_len) or None)


def train_flops_per_row(cfg: ModelConfig, seq_len: int,
                        packed: Optional[PackedWorkload] = None) -> float:
    """FLOPs one (row, seq_len) training sample actually costs the device.

    Unpacked: ``train_flops_per_token * seq_len``. Packed rows keep the
    dense cost on (1 - pad_fraction) of the slots while the segment-aware
    attention kernels skip cross-segment blocks, so the quadratic term
    sees the mean *segment* length rather than the full sequence — this
    is the number measured/analytical profiles and the allocation sweep
    must price, or Algorithm 1 optimizes a partly-garbage workload.
    """
    if packed is None:
        return train_flops_per_token(cfg, seq_len) * seq_len
    span = packed.mean_segment_len or seq_len
    return (train_flops_per_token(cfg, int(round(span)))
            * seq_len * packed.token_fraction)


@dataclass
class MemoryModel:
    """ZeRO-stage-aware per-device memory model (DeepSpeed mixed precision).

    model-state bytes: params 2P, grads 2P, optimizer 12P (fp32 master +
    Adam mu/nu); partitioned per stage over the n data-parallel workers.
    Activation bytes scale linearly in batch — exactly the linearity that
    Algorithm 1's first phase exploits.
    """
    cfg: ModelConfig
    seq_len: int
    zero_stage: int = 0
    n_workers: int = 1
    remat: bool = True
    framework_overhead_gb: float = 0.9  # CUDA/XLA context etc.
    # GQA-native attention kernels hold K/V at n_kv_heads (the Pallas path
    # never builds the (B, n_heads, S, D) expansion); set False to model
    # the legacy expanded layout.
    gqa_native_attn: bool = True

    def model_state_bytes(self) -> float:
        P = float(self.cfg.total_params)
        n = max(self.n_workers, 1)
        params, grads, opt = 2 * P, 2 * P, 12 * P
        if self.zero_stage >= 1:
            opt /= n
        if self.zero_stage >= 2:
            grads /= n
        if self.zero_stage >= 3:
            params /= n
        return params + grads + opt

    def activation_bytes_per_sample(self) -> float:
        c = self.cfg
        # per-layer resident activations; remat keeps ~2 tensors per layer,
        # otherwise ~14 (qkv, scores stats, mlp hidden, ...)
        per_layer = (2 if self.remat else 14) * self.seq_len * c.d_model * BF16
        act = per_layer * c.n_layers
        # attention K/V working set: the GQA-native kernels allocate
        # n_kv_heads-wide K/V (what the mbs probe / OOM oracle must see);
        # the expanded layout costs the full n_heads.
        n_attn = sum(1 for kind in c.blocks()
                     if kind in ("attn", "moe", "shared_attn"))
        if n_attn:
            hd = c.resolved_head_dim
            if self.remat:
                # remat saves only the ~2 layer inputs above; K/V of the
                # layer being (re)computed are transient but bound the
                # peak (x2: forward pass + backward recompute). Counted
                # explicitly because the kv-head width is exactly what
                # the GQA-native layout changes.
                kv_heads = (c.n_kv_heads if self.gqa_native_attn
                            else c.n_heads)
                act += 2 * self.seq_len * kv_heads * hd * BF16 * 2
            elif self.gqa_native_attn:
                # without remat the 14x catch-all above already charges
                # saved K/V at full n_heads width (d_model per tensor);
                # credit back the expansion the GQA-native layout avoids
                # so the legacy estimate stays byte-identical to before
                act -= (2 * self.seq_len * (c.n_heads - c.n_kv_heads)
                        * hd * BF16 * n_attn)
        if c.moe is not None:
            # dispatched expert buffers ~ top_k/capacity overhead
            act += (2 * self.seq_len * c.d_model * BF16
                    * c.moe.top_k * (1.25 if self.remat else 3.0))
        # logits + CE in fp32 for one microbatch
        act += self.seq_len * c.vocab_size * (BF16 + F32) * 0.25  # chunked CE
        return act

    def bytes_at_batch(self, batch: int) -> float:
        return (self.model_state_bytes()
                + batch * self.activation_bytes_per_sample()
                + self.framework_overhead_gb * 1e9)

    def max_batch(self, mem_gb: float) -> int:
        free = mem_gb * 1e9 - self.model_state_bytes() - self.framework_overhead_gb * 1e9
        if free <= 0:
            return 0
        return int(free // self.activation_bytes_per_sample())


def comm_time_per_microstep(cfg: ModelConfig, zero_stage: int, n: int,
                            link_gbps: float,
                            alpha_s: float = 25e-6) -> float:
    """Collective seconds per micro-step (the `time_communication` of
    Algorithm 2): alpha-beta model — ring bandwidth term
    2(n-1)/n * bytes / bw plus per-hop latency alpha * (n-1) per collective
    *per layer* (ZeRO-3 launches one all-gather per layer, paper appendix).
    The latency term is what makes adding devices eventually unprofitable
    (the paper's V4A4 < V4A3 observation in ZeRO-3).

    stage 0/1: one all-reduce of bf16 grads per *iteration* (amortized by
    the caller over accumulation steps); stage 2: reduce-scatter per
    micro-step backward; stage 3: 2x all-gather + reduce-scatter per
    micro-step.
    """
    P = float(cfg.total_params)
    bw = link_gbps * 1e9
    ring = 2.0 * (n - 1) / max(n, 1)
    hop_lat = alpha_s * (n - 1)
    allreduce = ring * (2 * P) / bw + hop_lat  # = RS + AG of bf16 grads
    gather = ring / 2.0 * (2 * P) / bw         # one AG (or RS) of bf16 params
    if zero_stage <= 1:
        return allreduce                       # per iteration
    if zero_stage == 2:
        # RS per micro-step: layer-wise launches during backward
        return gather + hop_lat * cfg.n_layers
    # AG fwd + AG bwd + RS grads, each launched per layer
    return 3.0 * (gather + hop_lat * cfg.n_layers)


# fraction of a sync period's collective time that can never hide under
# compute: the prefetch pipeline's fill (first layer's all-gather) and
# drain (last reduce-scatter) plus the non-stacked leaves at step start.
EXPOSED_COMM_FLOOR = 0.1


def exposed_comm_time(comm_s: float, compute_s: float,
                      overlap_factor: float,
                      exposed_floor: float = EXPOSED_COMM_FLOOR) -> float:
    """Collective seconds left *exposed* (serialized with compute) when a
    schedule can hide comm under compute.

    ``overlap_factor`` is the fraction of concurrent compute time usable
    for hiding collectives (0 = the XLA-auto serial model; the scheduled
    ZeRO-3 path's calibration default lives in core/overlap.py). Hiding
    is bounded both by the available compute (factor * compute_s) and by
    the schedulable fraction of the comm itself (1 - exposed_floor).
    """
    if overlap_factor <= 0.0 or comm_s <= 0.0:
        return comm_s
    hidden = min(overlap_factor * compute_s, (1.0 - exposed_floor) * comm_s)
    return comm_s - hidden
