"""ZeRO stages 0–3 as JAX shardings + train/serve step builders.

Mapping (DESIGN.md §3):
  stage 0 — params & optimizer replicated over data axes; grads all-reduced.
  stage 1 — optimizer state sharded over data axes; params replicated;
            the post-update parameter cast re-gathers (AG) the params.
  stage 2 — stage 1 + gradients reduce-scattered (sharding constraint on
            the grad tree keeps them partitioned through the update).
  stage 3 — parameters themselves sharded (FSDP); XLA SPMD inserts the
            per-use all-gathers in forward and backward.

All of it composes with tensor parallelism on the `model` axis and the
hierarchical-ZeRO (`hierarchical_params`) pod-local variant via MeshRules.

Stage 3 additionally supports the *explicitly scheduled* execution path
(`rules.overlap="scheduled"|"auto"`, core/overlap.py): a shard_map step
that double-buffers the next layer's parameter all-gather under the
current layer's compute and reduce-scatters each layer's gradient inside
the backward sweep. The XLA-auto path here remains the parity oracle.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.sharding import MeshRules, use_rules
from repro.models import model as mm
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update


# ---------------------------------------------------------------------------
# sharding trees
# ---------------------------------------------------------------------------

def specs_for(rules: MeshRules, values_tree, axes_tree, *, zero_sharded: bool):
    def leaf(v, ax):
        return rules.param_spec(v.shape, ax, zero_sharded=zero_sharded)
    return jax.tree.map(leaf, values_tree, axes_tree,
                        is_leaf=lambda x: isinstance(x, tuple))


def model_shardings(rules: MeshRules, params, axes
                    ) -> Tuple[Any, Any, Any]:
    """(param_specs, opt_specs, grad_specs) for the rules' ZeRO stage."""
    stage = rules.zero_stage
    p_specs = specs_for(rules, params, axes, zero_sharded=stage >= 3)
    o_leaf = specs_for(rules, params, axes, zero_sharded=stage >= 1)
    opt_specs = {"mu": o_leaf, "nu": o_leaf, "master": o_leaf, "count": P()}
    g_specs = specs_for(rules, params, axes, zero_sharded=stage >= 2)
    return p_specs, opt_specs, g_specs


def batch_spec(rules: MeshRules, batch_shapes: Dict[str, Tuple[int, ...]]
               ) -> Dict[str, P]:
    out = {}
    for k, shp in batch_shapes.items():
        out[k] = rules.activation_spec(
            ("batch",) + (None,) * (len(shp) - 1), shp)
    return out


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, rules: MeshRules,
                    adamw_cfg: AdamWConfig = AdamWConfig(),
                    lr: float = 3e-4, window: Optional[int] = None,
                    impl: str = "reference",
                    accum_steps: int = 1) -> Callable:
    """Build the (unjitted) train step; callers jit with the spec trees
    from `model_shardings`.

    ``accum_steps > 1``: batch arrives as (gas, B, S) stacked micro-batches
    with per-microbatch loss masks — the SPMD realization of Poplar's
    gmbs/lbs schedule (uneven per-device accumulation becomes masked rows;
    see core/hetero.py).

    ``impl="auto"`` resolves to the Pallas kernel path on backends where
    it compiles natively and to the jnp reference elsewhere (see
    ``repro.kernels.ops.recommended_impl``); ``"pallas"`` forces the
    custom-VJP kernels (interpret mode included).

    ``rules.overlap``: "scheduled" routes stage 3 through the explicit
    shard_map schedule in core/overlap.py (raising if the mesh/batch
    combination cannot support it); "auto" does so only when supported
    *and* there is more than one data-parallel device; "xla" (default)
    keeps the auto-SPMD path below.
    """
    stage = rules.zero_stage
    impl = _resolve_impl(impl)

    def loss_of(params, batch):
        return mm.loss_fn(params, cfg, batch, window=window, impl=impl)

    def train_step(params, opt_state, batch):
        mode = getattr(rules, "overlap", "xla")
        if mode in ("scheduled", "auto"):
            from repro.core import overlap
            plan = overlap.plan_comm(rules, params, _axes_of(params, rules),
                                     batch, accum_steps)
            if isinstance(plan, str):
                if mode == "scheduled":
                    raise ValueError(
                        f"rules.overlap='scheduled' unsupported: {plan}")
            elif mode == "scheduled" or plan.n_dp > 1:
                return overlap.scheduled_train_step(
                    plan, cfg, adamw_cfg, lr, window, impl, accum_steps,
                    params, opt_state, batch)
        with use_rules(rules):
            if accum_steps == 1:
                (loss, metrics), grads = jax.value_and_grad(
                    loss_of, has_aux=True)(params, batch)
                tokens = metrics["tokens"]
            else:
                def micro(carry, mb):
                    g_acc, l_acc, t_acc = carry
                    (l, met), g = jax.value_and_grad(
                        loss_of, has_aux=True)(params, mb)
                    w = met["tokens"]
                    g_acc = jax.tree.map(
                        lambda a, b: a + b.astype(jnp.float32) * w, g_acc, g)
                    return (g_acc, l_acc + l * w, t_acc + w), None

                g0 = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                (grads, lsum, tokens), _ = jax.lax.scan(
                    micro, (g0, jnp.zeros(()), jnp.zeros(())), batch)
                denom = jnp.maximum(tokens, 1.0)
                grads = jax.tree.map(lambda g: g / denom, grads)
                loss = lsum / denom
                metrics = {"loss": loss, "aux": jnp.zeros(()),
                           "tokens": tokens}
            if stage >= 2:
                # reduce-scatter semantics: keep grads partitioned
                _, _, g_specs = model_shardings(rules, params,
                                                _axes_of(params, rules))
                grads = jax.tree.map(
                    lambda g, s: jax.lax.with_sharding_constraint(
                        g, rules.sharding(s)), grads, g_specs)
            new_params, new_opt, om = adamw_update(grads, opt_state, params,
                                                   lr, adamw_cfg)
            metrics = dict(metrics)
            metrics.update(om)
            return new_params, new_opt, metrics

    return train_step


# grads sharding needs the axes tree; registration pins it on the rules
# instance itself. (A module-level dict keyed on id(rules) is a use-after-
# free hazard: once a MeshRules is garbage-collected CPython can hand its
# id to a brand-new instance, silently serving the *old* rules' axes tree.
# Instance storage has exactly the lifetime of the key.)
_AXES_ATTR = "_registered_axes_tree"


def _axes_of(params, rules):
    axes = getattr(rules, _AXES_ATTR, None)
    if axes is None:
        raise RuntimeError("call register_axes(rules, axes) before tracing")
    return axes


def register_axes(rules: MeshRules, axes) -> None:
    object.__setattr__(rules, _AXES_ATTR, axes)


def _resolve_impl(impl: str) -> str:
    if impl == "auto":
        from repro.kernels.ops import recommended_impl
        return recommended_impl()
    return impl


def make_prefill_step(cfg: ModelConfig, rules: MeshRules,
                      window: Optional[int] = None, impl: str = "reference"
                      ) -> Callable:
    impl = _resolve_impl(impl)

    def prefill_step(params, batch):
        with use_rules(rules):
            return mm.prefill(params, cfg, batch, window=window, impl=impl)
    return prefill_step


def make_decode_step(cfg: ModelConfig, rules: MeshRules,
                     window: Optional[int] = None, impl: str = "reference"
                     ) -> Callable:
    impl = _resolve_impl(impl)

    def serve_step(params, tokens, state):
        with use_rules(rules):
            return mm.decode_step(params, cfg, tokens, state, window=window,
                                  impl=impl)
    return serve_step
