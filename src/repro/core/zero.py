"""ZeRO stages 0–3 as JAX shardings + deprecated step-builder shims.

Mapping (DESIGN.md §3):
  stage 0 — params & optimizer replicated over data axes; grads all-reduced.
  stage 1 — optimizer state sharded over data axes; params replicated;
            the post-update parameter cast re-gathers (AG) the params.
  stage 2 — stage 1 + gradients reduce-scattered (sharding constraint on
            the grad tree keeps them partitioned through the update).
  stage 3 — parameters themselves sharded (FSDP); XLA SPMD inserts the
            per-use all-gathers in forward and backward.

All of it composes with tensor parallelism on the `model` axis and the
hierarchical-ZeRO (`hierarchical_params`) pod-local variant via MeshRules.

The step builders themselves moved to ``repro.api.steps.build_step``
(one builder for train/prefill/decode, logical axes passed explicitly)
behind the ``repro.api.Session`` facade. ``make_train_step`` /
``make_prefill_step`` / ``make_decode_step`` / ``register_axes`` remain
here as thin deprecation shims with the historical semantics: register
the axes tree on the rules instance, then build a step that looks them
up at trace time. New code should not use them — a ``TrainState``
carries its axes in-state (see repro/api/README.md for the old→new map).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.sharding import MeshRules

# ---------------------------------------------------------------------------
# sharding trees
# ---------------------------------------------------------------------------

def specs_for(rules: MeshRules, values_tree, axes_tree, *, zero_sharded: bool):
    def leaf(v, ax):
        return rules.param_spec(v.shape, ax, zero_sharded=zero_sharded)
    return jax.tree.map(leaf, values_tree, axes_tree,
                        is_leaf=lambda x: isinstance(x, tuple))


def model_shardings(rules: MeshRules, params, axes
                    ) -> Tuple[Any, Any, Any]:
    """(param_specs, opt_specs, grad_specs) for the rules' ZeRO stage."""
    stage = rules.zero_stage
    p_specs = specs_for(rules, params, axes, zero_sharded=stage >= 3)
    o_leaf = specs_for(rules, params, axes, zero_sharded=stage >= 1)
    opt_specs = {"mu": o_leaf, "nu": o_leaf, "master": o_leaf, "count": P()}
    g_specs = specs_for(rules, params, axes, zero_sharded=stage >= 2)
    return p_specs, opt_specs, g_specs


def batch_spec(rules: MeshRules, batch_shapes: Dict[str, Tuple[int, ...]]
               ) -> Dict[str, P]:
    out = {}
    for k, shp in batch_shapes.items():
        out[k] = rules.activation_spec(
            ("batch",) + (None,) * (len(shp) - 1), shp)
    return out


# ---------------------------------------------------------------------------
# deprecated step-builder shims (use repro.api.Session / api.steps instead)
# ---------------------------------------------------------------------------

# The historical axes side channel: registration pins the axes tree on the
# rules instance itself. (A module-level dict keyed on id(rules) is a use-
# after-free hazard: once a MeshRules is garbage-collected CPython can hand
# its id to a brand-new instance, silently serving the *old* rules' axes
# tree. Instance storage has exactly the lifetime of the key.) Kept only
# for the shims below — Session-built steps read TrainState.axes instead.
_AXES_ATTR = "_registered_axes_tree"


def _axes_of(params, rules):
    axes = getattr(rules, _AXES_ATTR, None)
    if axes is None:
        raise RuntimeError("call register_axes(rules, axes) before tracing "
                           "(deprecated — prefer repro.api.Session, which "
                           "carries axes in TrainState)")
    return axes


def register_axes(rules: MeshRules, axes) -> None:
    """Deprecated: pin the logical-axis tree on a MeshRules instance for
    the step-builder shims below. New code passes axes explicitly
    (``api.steps.build_step(cfg, rules, axes, ...)``) or lets Session
    carry them in-state."""
    object.__setattr__(rules, _AXES_ATTR, axes)


def make_train_step(cfg: ModelConfig, rules: MeshRules,
                    adamw_cfg=None, lr: float = 3e-4,
                    window: Optional[int] = None,
                    impl: str = "reference",
                    accum_steps: int = 1) -> Callable:
    """Deprecated shim over ``repro.api.steps.build_step(kind="train")``.

    Axes come from ``register_axes`` at trace time (the historical side
    channel); semantics — accum stacking, impl="auto" resolution, the
    rules.overlap routing — are unchanged and live in api/steps.py.
    """
    from repro.api import steps as _steps
    from repro.optim.adamw import AdamWConfig
    adamw_cfg = AdamWConfig() if adamw_cfg is None else adamw_cfg

    def train_step(params, opt_state, batch):
        inner = _steps.build_step(
            cfg, rules, _axes_of(params, rules), kind="train",
            adamw_cfg=adamw_cfg, lr=lr, window=window, impl=impl,
            accum_steps=accum_steps)
        return inner(params, opt_state, batch)

    return train_step


def make_prefill_step(cfg: ModelConfig, rules: MeshRules,
                      window: Optional[int] = None, impl: str = "reference"
                      ) -> Callable:
    """Deprecated shim over ``api.steps.build_step(kind="prefill")``."""
    from repro.api import steps as _steps
    return _steps.build_step(cfg, rules, kind="prefill", window=window,
                             impl=impl)


def make_decode_step(cfg: ModelConfig, rules: MeshRules,
                     window: Optional[int] = None, impl: str = "reference"
                     ) -> Callable:
    """Deprecated shim over ``api.steps.build_step(kind="decode")``."""
    from repro.api import steps as _steps
    return _steps.build_step(cfg, rules, kind="decode", window=window,
                             impl=impl)
