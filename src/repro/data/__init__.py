from repro.data.pipeline import (ByteTokenizer, HeteroDataLoader,
                                 SyntheticTokens, TextFileTokens)

__all__ = ["ByteTokenizer", "HeteroDataLoader", "SyntheticTokens",
           "TextFileTokens"]
