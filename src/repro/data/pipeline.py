"""Data pipeline: token sources, the greedy sequence packer, and the
heterogeneous dynamic-batch loader.

The paper modifies the data loader to honour per-device ``gmbs``/``lbs``
(dynamic micro-batch sizes with a partial last accumulation step). Our
:class:`HeteroDataLoader` does exactly that on top of any token source: it
emits padded (gas, B_pad, seq) micro-batch stacks whose loss masks encode
Poplar's allocation (see core/hetero.py for the SPMD layout rationale).

**Packed layout** (``HeteroDataLoader(..., packing=True)``): real corpora
are mixed-length, and padding every document to ``seq_len`` burns 40–60%
of the FLOPs the planner allocates on pad tokens. Document sources (any
source with a ``.documents(n, epoch)`` method, e.g.
:class:`MixedLengthDocs`) are instead packed first-fit-decreasing by
:func:`pack_documents` into the layout's ``(rows, seq_len)`` slots; each
row then carries

* ``segment_ids`` (int32, 0 = pad) — document ids 1..n in contiguous
  runs, consumed by the segment-aware attention kernels so documents
  sharing a row never attend to each other;
* ``positions`` (int32) — RoPE positions restarting at 0 per document;
* a token-level ``loss_mask`` counting exactly the real predict
  positions (the loss normalizer sees non-pad tokens only).

Both modes are pure in ``epoch``: ``seek``/``relayout`` reproduce the
exact stream, packed or not. Per-batch packing efficiency is recorded in
``loader.last_pack_stats`` (:class:`PackingStats`), which the planner
uses to price the effective (non-pad) workload.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.hetero import HeteroBatchLayout, pack_batch


class ByteTokenizer:
    """Deterministic byte-level tokenizer (vocab 256 + specials)."""
    PAD, BOS, EOS = 0, 1, 2
    OFFSET = 3

    @property
    def vocab_size(self) -> int:
        return 256 + self.OFFSET

    def encode(self, text: str) -> np.ndarray:
        b = text.encode("utf-8", errors="replace")
        return np.frombuffer(b, dtype=np.uint8).astype(np.int32) + self.OFFSET

    def decode(self, ids) -> str:
        ids = np.asarray(ids)
        ids = ids[ids >= self.OFFSET] - self.OFFSET
        return bytes(ids.astype(np.uint8)).decode("utf-8", errors="replace")


@dataclass
class SyntheticTokens:
    """Reproducible synthetic token rows (seq+1 for input/label shift)."""
    vocab_size: int
    seq_len: int
    seed: int = 0

    def rows(self, n: int, epoch: int = 0) -> np.ndarray:
        rng = np.random.default_rng(self.seed + epoch * 1_000_003)
        return rng.integers(3, self.vocab_size, (n, self.seq_len + 1),
                            dtype=np.int32)

    def stream(self, batch_rows: int) -> Iterator[np.ndarray]:
        epoch = 0
        while True:
            yield self.rows(batch_rows, epoch)
            epoch += 1


@dataclass
class TextFileTokens:
    """Token rows from a text file via the byte tokenizer (wikitext-style
    contiguous-chunk language modelling)."""
    path: str
    seq_len: int
    seed: int = 0

    def __post_init__(self):
        self._tok = ByteTokenizer()
        text = Path(self.path).read_text(encoding="utf-8", errors="replace")
        self._ids = self._tok.encode(text)

    @property
    def vocab_size(self) -> int:
        return self._tok.vocab_size

    def rows(self, n: int, epoch: int = 0) -> np.ndarray:
        rng = np.random.default_rng(self.seed + epoch)
        L = self.seq_len + 1
        max_start = max(len(self._ids) - L, 1)
        starts = rng.integers(0, max_start, n)
        return np.stack([self._ids[s:s + L] if s + L <= len(self._ids)
                         else np.pad(self._ids[s:], (0, s + L - len(self._ids)))
                         for s in starts]).astype(np.int32)

    def stream(self, batch_rows: int) -> Iterator[np.ndarray]:
        epoch = 0
        while True:
            yield self.rows(batch_rows, epoch)
            epoch += 1


@dataclass
class MixedLengthDocs:
    """Reproducible mixed-length synthetic documents.

    ``documents(n, epoch)`` yields variable-length docs (uniform predict
    length in [min_len, max_len]); ``rows(n, epoch)`` is the *padded
    baseline* view of the same docs — one zero-padded row per document —
    so padded-vs-packed comparisons train on identical data.
    """
    vocab_size: int
    seq_len: int
    min_len: int = 8
    max_len: Optional[int] = None
    seed: int = 0

    def __post_init__(self):
        if self.max_len is None:
            self.max_len = self.seq_len
        self.max_len = min(self.max_len, self.seq_len)
        if not (1 <= self.min_len <= self.max_len):
            raise ValueError(
                f"need 1 <= min_len <= max_len <= seq_len, got "
                f"[{self.min_len}, {self.max_len}] for seq {self.seq_len}")

    @property
    def mean_doc_len(self) -> float:
        """Expected predict positions per document."""
        return 0.5 * (self.min_len + self.max_len)

    def documents(self, n: int, epoch: int = 0) -> List[np.ndarray]:
        """n docs, each (L+1,) int32 for L predict positions; pure in
        (n, epoch) and prefix-consistent: lengths are drawn first, so
        documents(m, e)[:n] == documents(n, e) for m >= n."""
        rng = np.random.default_rng(self.seed + epoch * 1_000_003)
        lens = rng.integers(self.min_len, self.max_len + 1, n)
        return [rng.integers(3, self.vocab_size, (int(L) + 1,),
                             dtype=np.int32) for L in lens]

    def rows(self, n: int, epoch: int = 0) -> np.ndarray:
        out = np.zeros((n, self.seq_len + 1), np.int32)
        for i, d in enumerate(self.documents(n, epoch)):
            d = d[:self.seq_len + 1]
            out[i, :len(d)] = d
        return out

    def stream(self, batch_rows: int) -> Iterator[np.ndarray]:
        epoch = 0
        while True:
            yield self.rows(batch_rows, epoch)
            epoch += 1


@dataclass
class PackingStats:
    """Per-batch packing efficiency (fed to the planner's effective-token
    workload model and the throughput telemetry)."""
    n_docs: int        # documents offered to the packer
    n_packed: int      # documents placed into rows
    n_dropped: int     # documents that fit no remaining slot (discarded)
    real_tokens: int   # predict positions actually packed
    slot_tokens: int   # rows * seq_len capacity

    @property
    def pad_fraction(self) -> float:
        return 1.0 - self.real_tokens / max(self.slot_tokens, 1)

    @property
    def mean_segment_len(self) -> float:
        return self.real_tokens / max(self.n_packed, 1)


def pack_documents(docs: Sequence[np.ndarray], n_rows: int, seq_len: int
                   ) -> Tuple[Dict[str, np.ndarray], PackingStats]:
    """Greedy first-fit-decreasing sequence packing.

    Each doc ``d`` ((L+1,) int32) occupies ``L = len(d)-1`` slots of one
    row: ``tokens=d[:-1]``, ``labels=d[1:]``, a fresh segment id
    (1..n per row, contiguous), positions restarting at 0 and loss mask
    1. Docs are sorted longest-first and placed in the first row with
    capacity (the classic FFD bin-packing heuristic — within ~2% of
    optimal fill in practice); docs fitting no remaining slot are
    dropped (counted in the stats). Over-long docs are truncated to
    ``seq_len`` predict positions.

    Returns per-row (n_rows, seq_len) fields + :class:`PackingStats`.
    """
    sizes = np.array([min(max(len(d) - 1, 0), seq_len) for d in docs],
                     np.int64)
    order = np.argsort(-sizes, kind="stable")
    remaining = np.full(n_rows, seq_len, np.int64)
    placement: List[List[int]] = [[] for _ in range(n_rows)]
    dropped = 0
    for i in order:
        sz = int(sizes[i])
        if sz <= 0:
            dropped += 1
            continue
        for r in range(n_rows):
            if remaining[r] >= sz:
                placement[r].append(int(i))
                remaining[r] -= sz
                break
        else:
            dropped += 1
    tokens = np.zeros((n_rows, seq_len), np.int32)
    labels = np.zeros((n_rows, seq_len), np.int32)
    segment_ids = np.zeros((n_rows, seq_len), np.int32)
    positions = np.zeros((n_rows, seq_len), np.int32)
    loss_mask = np.zeros((n_rows, seq_len), np.float32)
    packed = real = 0
    for r, idxs in enumerate(placement):
        off = 0
        for sid, i in enumerate(idxs, start=1):
            d = docs[i][:seq_len + 1]
            L = len(d) - 1
            tokens[r, off:off + L] = d[:-1]
            labels[r, off:off + L] = d[1:]
            segment_ids[r, off:off + L] = sid
            positions[r, off:off + L] = np.arange(L)
            loss_mask[r, off:off + L] = 1.0
            off += L
            packed += 1
            real += L
    fields = {"tokens": tokens, "labels": labels,
              "segment_ids": segment_ids, "positions": positions,
              "loss_mask": loss_mask}
    return fields, PackingStats(len(docs), packed, dropped, real,
                                n_rows * seq_len)


class HeteroDataLoader:
    """Feeds a Poplar HeteroBatchLayout from a token source.

    ``packing=True`` switches to the packed layout: the source must
    expose ``documents(n, epoch)`` (and ``mean_doc_len``); each batch
    draws a document budget sized to ~1.25x the slot capacity, packs it
    FFD, and scatters per-token ``segment_ids``/``positions``/loss masks
    through ``pack_batch`` alongside the row masks.
    """

    # overdraw factor: offering slightly more docs than capacity lets FFD
    # fill rows to single-digit pad fractions; the overflow is dropped.
    PACK_OVERDRAW = 1.25

    def __init__(self, source, layout: HeteroBatchLayout, seq_len: int,
                 packing: bool = False):
        if packing and not hasattr(source, "documents"):
            raise ValueError(
                f"packing=True needs a document source (.documents); "
                f"{type(source).__name__} has none")
        self.source = source
        self.layout = layout
        self.seq_len = seq_len
        self.packing = bool(packing)
        self.last_pack_stats: Optional[PackingStats] = None
        self._epoch = 0

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next_batch()

    def seek(self, epoch: int) -> None:
        """Position the stream as if ``epoch`` batches were already drawn
        (checkpoint resume: epoch == the restored step count)."""
        self._epoch = int(epoch)

    def relayout(self, layout: HeteroBatchLayout,
                 seek: Optional[int] = None) -> None:
        """Re-split the stream onto a new batch layout (elastic re-plan:
        the allocation changed, the data source and stream position did
        not). Subsequent batches pack rows into the new layout; ``seek``
        optionally repositions at the same time (pass the current training
        step so an unchanged layout replays the exact same batches)."""
        self.layout = layout
        if seek is not None:
            self.seek(seek)

    def next_batch(self) -> Dict[str, np.ndarray]:
        n = self.layout.total_real()
        if self.packing:
            mean_len = float(getattr(self.source, "mean_doc_len", 0.0)) or (
                self.seq_len / 2.0)
            budget = max(1, int(round(
                n * self.seq_len * self.PACK_OVERDRAW / mean_len)))
            docs = self.source.documents(budget, self._epoch)
            fields, stats = pack_documents(docs, n, self.seq_len)
            self.last_pack_stats = stats
            self._epoch += 1
            return pack_batch(None, self.layout, self.seq_len,
                              packed_fields=fields)
        rows = self.source.rows(n, self._epoch)
        self._epoch += 1
        return pack_batch(rows, self.layout, self.seq_len)
