"""Data pipeline: token sources + the heterogeneous dynamic-batch loader.

The paper modifies the data loader to honour per-device ``gmbs``/``lbs``
(dynamic micro-batch sizes with a partial last accumulation step). Our
:class:`HeteroDataLoader` does exactly that on top of any token source: it
emits padded (gas, B_pad, seq) micro-batch stacks whose loss masks encode
Poplar's allocation (see core/hetero.py for the SPMD layout rationale).
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, Iterator, Optional

import numpy as np

from repro.core.hetero import HeteroBatchLayout, pack_batch


class ByteTokenizer:
    """Deterministic byte-level tokenizer (vocab 256 + specials)."""
    PAD, BOS, EOS = 0, 1, 2
    OFFSET = 3

    @property
    def vocab_size(self) -> int:
        return 256 + self.OFFSET

    def encode(self, text: str) -> np.ndarray:
        b = text.encode("utf-8", errors="replace")
        return np.frombuffer(b, dtype=np.uint8).astype(np.int32) + self.OFFSET

    def decode(self, ids) -> str:
        ids = np.asarray(ids)
        ids = ids[ids >= self.OFFSET] - self.OFFSET
        return bytes(ids.astype(np.uint8)).decode("utf-8", errors="replace")


@dataclass
class SyntheticTokens:
    """Reproducible synthetic token rows (seq+1 for input/label shift)."""
    vocab_size: int
    seq_len: int
    seed: int = 0

    def rows(self, n: int, epoch: int = 0) -> np.ndarray:
        rng = np.random.default_rng(self.seed + epoch * 1_000_003)
        return rng.integers(3, self.vocab_size, (n, self.seq_len + 1),
                            dtype=np.int32)

    def stream(self, batch_rows: int) -> Iterator[np.ndarray]:
        epoch = 0
        while True:
            yield self.rows(batch_rows, epoch)
            epoch += 1


@dataclass
class TextFileTokens:
    """Token rows from a text file via the byte tokenizer (wikitext-style
    contiguous-chunk language modelling)."""
    path: str
    seq_len: int
    seed: int = 0

    def __post_init__(self):
        self._tok = ByteTokenizer()
        text = Path(self.path).read_text(encoding="utf-8", errors="replace")
        self._ids = self._tok.encode(text)

    @property
    def vocab_size(self) -> int:
        return self._tok.vocab_size

    def rows(self, n: int, epoch: int = 0) -> np.ndarray:
        rng = np.random.default_rng(self.seed + epoch)
        L = self.seq_len + 1
        max_start = max(len(self._ids) - L, 1)
        starts = rng.integers(0, max_start, n)
        return np.stack([self._ids[s:s + L] if s + L <= len(self._ids)
                         else np.pad(self._ids[s:], (0, s + L - len(self._ids)))
                         for s in starts]).astype(np.int32)

    def stream(self, batch_rows: int) -> Iterator[np.ndarray]:
        epoch = 0
        while True:
            yield self.rows(batch_rows, epoch)
            epoch += 1


class HeteroDataLoader:
    """Feeds a Poplar HeteroBatchLayout from a token source."""

    def __init__(self, source, layout: HeteroBatchLayout, seq_len: int):
        self.source = source
        self.layout = layout
        self.seq_len = seq_len
        self._epoch = 0

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next_batch()

    def seek(self, epoch: int) -> None:
        """Position the stream as if ``epoch`` batches were already drawn
        (checkpoint resume: epoch == the restored step count)."""
        self._epoch = int(epoch)

    def relayout(self, layout: HeteroBatchLayout,
                 seek: Optional[int] = None) -> None:
        """Re-split the stream onto a new batch layout (elastic re-plan:
        the allocation changed, the data source and stream position did
        not). Subsequent batches pack rows into the new layout; ``seek``
        optionally repositions at the same time (pass the current training
        step so an unchanged layout replays the exact same batches)."""
        self.layout = layout
        if seek is not None:
            self.seek(seek)

    def next_batch(self) -> Dict[str, np.ndarray]:
        n = self.layout.total_real()
        rows = self.source.rows(n, self._epoch)
        self._epoch += 1
        return pack_batch(rows, self.layout, self.seq_len)
