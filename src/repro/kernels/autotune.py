"""Block-size autotuner for the Pallas kernels.

HARP-style automated per-device tuning: the best ``(block_q, block_k)``
tile for flash attention depends on sequence length, head dim, dtype and
masking pattern, and differs across accelerator generations. Rather than
hard-coding 128x128 everywhere, the tuner

  1. answers lookups from an in-process cache,
  2. then from a JSON disk cache (``~/.cache/repro/autotune.json``,
     override with ``REPRO_AUTOTUNE_CACHE``) so the sweep cost is paid
     once per machine,
  3. and otherwise falls back to a deterministic static table — always
     used in interpret mode, where timing the traced-Python kernel body
     would tune for the interpreter, not the hardware.

``tune(...)`` runs the actual candidate sweep (compile + median-of-k
timing) and writes the winner through both caches. The train step never
sweeps implicitly: lookups inside a traced function only read the cache
or the static table, keeping tracing deterministic.

Cache file format — one JSON object per key (``g`` is the GQA group
size ``n_heads // n_kv_heads`` — the best tile for a 6-way grouped
kernel differs from the MHA one, so the keys must not alias)::

  {"flash_fwd|S512|D128|bfloat16|c1|w0|g6":
     {"blocks": [128, 128], "ms": 0.41, "source": "measured"}}
"""
from __future__ import annotations

import json
import os
import time
from pathlib import Path
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.bucketing import pow2_floor as _pow2_floor

__all__ = ["cache_path", "key_of", "lookup", "median_ms", "record",
           "static_blocks", "tune", "clear_memory_cache", "CANDIDATES"]

# (block_q, block_k) sweep grid; pruned per shape to blocks <= padded S
CANDIDATES: Tuple[Tuple[int, int], ...] = (
    (64, 64), (64, 128), (128, 64), (128, 128),
    (128, 256), (256, 128), (256, 256), (512, 128),
)

_MEM_CACHE: Dict[str, Tuple[int, int]] = {}


def cache_path() -> Path:
    env = os.environ.get("REPRO_AUTOTUNE_CACHE")
    if env:
        return Path(env)
    return Path.home() / ".cache" / "repro" / "autotune.json"


def clear_memory_cache() -> None:
    _MEM_CACHE.clear()


def key_of(kind: str, *, S: int, D: int, dtype: str, causal: bool,
           window: Optional[int], G: int = 1) -> str:
    """``G`` is the GQA group size (n_heads // n_kv_heads); tuned tiles
    for grouped and MHA shapes must not alias."""
    return f"{kind}|S{S}|D{D}|{dtype}|c{int(causal)}|w{window or 0}|g{G}"


def static_blocks(*, S: int, D: int, dtype: str = "float32",
                  causal: bool = True,
                  window: Optional[int] = None) -> Tuple[int, int]:
    """Deterministic fallback: MXU-aligned 128 tiles, shrunk for short
    sequences (and for sliding windows narrower than a 128 tile, where a
    big block wastes its area on masked keys)."""
    blk = min(128, _pow2_floor(max(S, 8)))
    bk = blk
    if window is not None:
        bk = min(bk, max(32, _pow2_floor(window)))
    return blk, bk


def _read_disk() -> Dict[str, dict]:
    fp = cache_path()
    try:
        return json.loads(fp.read_text())
    except (OSError, ValueError):
        return {}


def _write_disk(entries: Dict[str, dict]) -> None:
    fp = cache_path()
    try:
        fp.parent.mkdir(parents=True, exist_ok=True)
        merged = _read_disk()
        merged.update(entries)
        tmp = fp.with_suffix(".json.tmp")
        tmp.write_text(json.dumps(merged, indent=1, sort_keys=True))
        tmp.replace(fp)
    except OSError:  # read-only FS etc. — the in-process cache still works
        pass


def record(key: str, blocks: Tuple[int, int], *, ms: Optional[float] = None,
           source: str = "measured") -> None:
    _MEM_CACHE[key] = tuple(blocks)
    entry = {"blocks": list(blocks), "source": source}
    if ms is not None:
        entry["ms"] = round(ms, 5)
    _write_disk({key: entry})


def lookup(kind: str, *, S: int, D: int, dtype: str, causal: bool = True,
           window: Optional[int] = None, G: int = 1,
           interpret: bool = False) -> Tuple[int, int]:
    """Cached (block_q, block_k) for a kernel-shape key; never sweeps."""
    key = key_of(kind, S=S, D=D, dtype=dtype, causal=causal, window=window,
                 G=G)
    hit = _MEM_CACHE.get(key)
    if hit is not None:
        return hit
    disk = _read_disk().get(key)
    if disk and "blocks" in disk and len(disk["blocks"]) == 2:
        blocks = (int(disk["blocks"][0]), int(disk["blocks"][1]))
        _MEM_CACHE[key] = blocks
        return blocks
    blocks = static_blocks(S=S, D=D, dtype=dtype, causal=causal,
                           window=window)
    # record the static choice so the cache file documents every key the
    # run touched (interpret-mode runs produce a fully static table)
    record(key, blocks, source="static" if interpret else "static-default")
    return blocks


def median_ms(fn: Callable[[], object], iters: int = 3) -> float:
    """Median wall-clock of ``fn()`` after one warm-up (compile) call."""
    import jax
    jax.block_until_ready(fn())          # compile / first-call overheads
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        times.append((time.perf_counter() - t0) * 1e3)
    times.sort()
    return times[len(times) // 2]


def tune(kind: str, make_fn: Callable[[int, int], Callable[[], object]], *,
         S: int, D: int, dtype: str, causal: bool = True,
         window: Optional[int] = None, G: int = 1,
         candidates: Optional[Sequence[Tuple[int, int]]] = None,
         iters: int = 3, verbose: bool = False) -> Tuple[int, int]:
    """Sweep candidates and cache the fastest.

    ``make_fn(block_q, block_k)`` returns a zero-arg callable running the
    kernel at that tile size (typically a jit closure over live inputs).
    Candidates larger than the sequence collapse after the kernels'
    ``min(block, S)`` clamp and are deduplicated before timing.
    """
    key = key_of(kind, S=S, D=D, dtype=dtype, causal=causal, window=window,
                 G=G)
    hit = _MEM_CACHE.get(key)
    if hit is not None:
        return hit
    cand: List[Tuple[int, int]] = []
    cap = _pow2_floor(max(S, 8))  # pow2 clamp keeps lcm(bq, bk) == max
    for bq, bk in (candidates or CANDIDATES):
        c = (min(bq, cap), min(bk, cap))
        if c not in cand:
            cand.append(c)
    best, best_ms = None, float("inf")
    for bq, bk in cand:
        try:
            ms = median_ms(make_fn(bq, bk), iters)
        except Exception:  # candidate doesn't lower on this backend
            continue
        if verbose:
            print(f"[autotune] {key} ({bq},{bk}) {ms:.3f} ms")
        if ms < best_ms:
            best, best_ms = (bq, bk), ms
    if best is None:
        best = static_blocks(S=S, D=D, dtype=dtype, causal=causal,
                             window=window)
        record(key, best, source="static-fallback")
        return best
    record(key, best, ms=best_ms, source="measured")
    return best
