"""Blockwise causal flash attention — Pallas TPU kernel.

TPU-native adaptation (DESIGN.md §6): online-softmax over KV blocks staged
through VMEM, MXU-aligned tiles (block_q x D and block_k x D, multiples of
128 at full size), float32 running statistics in VMEM scratch. Grid =
(batch*heads, num_q_blocks, num_kv_blocks); the innermost (kv) grid dim
iterates sequentially on TPU so scratch carries (m, l, acc) across KV
blocks; fully-masked causal/window blocks are skipped via ``pl.when`` —
the block-skipping the pure-jnp reference cannot do.

Heads arrive GQA-expanded from the wrapper, matching
``repro.models.layers._chunk_attn_flash`` (the oracle lives in ref.py).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU memory spaces; absent on CPU-only installs (interpret mode)
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    _VMEM = None

NEG_INF = -1e30


def _scratch_shapes(block_q: int, d: int):
    if _VMEM is not None:
        return [_VMEM((block_q,), jnp.float32),
                _VMEM((block_q,), jnp.float32),
                _VMEM((block_q, d), jnp.float32)]
    return [jax.ShapeDtypeStruct((block_q,), jnp.float32),
            jax.ShapeDtypeStruct((block_q,), jnp.float32),
            jax.ShapeDtypeStruct((block_q, d), jnp.float32)]


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  block_q: int, block_k: int, seq_len: int, causal: bool,
                  window: Optional[int], scale: float, num_kv: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = ki * block_k

    needed = jnp.asarray(True)
    if causal:  # block fully above the diagonal -> skip
        needed = jnp.logical_and(needed, k_start <= q_start + block_q - 1)
    if window is not None:  # block fully left of the window -> skip
        needed = jnp.logical_and(
            needed, k_start + block_k - 1 >= q_start - window + 1)

    @pl.when(needed)
    def _compute():
        q = q_ref[0].astype(jnp.float32)               # (block_q, D)
        k = k_ref[0].astype(jnp.float32)               # (block_k, D)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < seq_len                           # unpadded keys only
        if causal:
            mask = jnp.logical_and(mask, qpos >= kpos)
        if window is not None:
            mask = jnp.logical_and(mask, qpos - kpos < window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        acc_ref[...] = (acc_ref[...] * corr[:, None]
                        + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                              preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(ki == num_kv - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-20)
        o_ref[0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           window: Optional[int] = None,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = False):
    """q,k,v: (B, H, S, D), H already GQA-expanded. Returns (B, H, S, D)."""
    B, H, S, D = q.shape
    assert k.shape == v.shape == (B, H, S, D)
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    blk = max(block_q, block_k)
    pad = (-S) % blk
    if pad:
        padcfg = ((0, 0), (0, 0), (0, pad), (0, 0))
        q = jnp.pad(q, padcfg)
        k = jnp.pad(k, padcfg)
        v = jnp.pad(v, padcfg)
    Sp = q.shape[2]
    nq, nkv = Sp // block_q, Sp // block_k
    qf = q.reshape(B * H, Sp, D)
    kf = k.reshape(B * H, Sp, D)
    vf = v.reshape(B * H, Sp, D)
    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k, seq_len=S,
        causal=causal, window=window, scale=1.0 / (D ** 0.5), num_kv=nkv)
    out = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, D), lambda bh, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((1, block_k, D), lambda bh, qi, ki: (bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sp, D), q.dtype),
        scratch_shapes=_scratch_shapes(block_q, D),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, Sp, D)[:, :, :S]
