"""Blockwise causal flash attention — Pallas TPU kernel, fwd + custom VJP.

TPU-native adaptation (DESIGN.md §6): online-softmax over KV blocks staged
through VMEM, MXU-aligned tiles (block_q x D and block_k x D, multiples of
128 at full size), float32 running statistics in VMEM scratch. Grid =
(batch*heads, num_q_blocks, num_kv_blocks); the innermost (kv) grid dim
iterates sequentially on TPU so scratch carries (m, l, acc) across KV
blocks; fully-masked causal/window blocks are skipped via ``pl.when`` —
the block-skipping the pure-jnp reference cannot do.

Training path: the forward kernel additionally emits the per-row
logsumexp ``L = m + log l`` so the backward pass (the recomputation
scheme in ``flash_attention_bwd.py``) can rebuild ``p = exp(s - L)``
block-by-block without materializing the S x S score matrix.
``flash_attention_vjp`` wraps forward + backward in ``jax.custom_vjp``,
which is what makes ``impl="pallas"`` usable under ``jax.value_and_grad``
— a bare ``pallas_call`` has no autodiff rule.

GQA-native: K/V arrive with ``Hkv <= Hq`` heads and are NEVER expanded
to ``(B, Hq, S, D)``. Each Q-head grid row reads the KV head of its
group directly through the BlockSpec ``index_map`` (``h // group_size``),
so HBM holds exactly one copy of the cache-sized tensors. The expansion
survives only in the jnp parity oracle (``ref.py``).

Ragged (packed) sequences: an optional ``segment_ids`` input of shape
``(B, S)`` — 0 marks padding, packed documents carry ids 1..n in
contiguous runs — adds a per-element ``q_seg == k_seg != 0`` mask AND a
block-level skip: because ids are contiguous per row, a (q-block,
k-block) pair whose nonzero id ranges do not intersect cannot contain a
matching pair, so the same ``pl.when`` machinery that skips
above-diagonal causal blocks skips cross-segment blocks entirely. For a
row packed with ``n`` equal documents that removes ~``(n-1)/n`` of the
off-diagonal work on top of the causal skip.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU memory spaces; absent on CPU-only installs (interpret mode)
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    _VMEM = None

NEG_INF = -1e30


def _lcm(a: int, b: int) -> int:
    x, y = a, b
    while y:
        x, y = y, x % y
    return a * b // x


def _pad_len(S: int, block_q: int, block_k: int) -> int:
    """Padded length divisible by BOTH blocks (unequal blocks included:
    padding to max() alone truncates the grid for the smaller block)."""
    m = _lcm(block_q, block_k)
    return S + (-S) % m


def _group_sizes(q_shape, kv_shape):
    """(Hq, Hkv, group_size) with divisibility checked."""
    Hq, Hkv = q_shape[1], kv_shape[1]
    if Hq % Hkv:
        raise ValueError(
            f"GQA head counts must divide: n_heads={Hq}, n_kv_heads={Hkv}")
    return Hq, Hkv, Hq // Hkv


def _kv_head_map(Hq: int, Hkv: int):
    """Flattened-(b*h) index of the KV head serving flattened q head
    ``bh``: q head ``h`` reads KV head ``h // group_size``. Identity for
    MHA so the index_map stays a plain passthrough there."""
    if Hq == Hkv:
        return lambda bh: bh
    group = Hq // Hkv
    return lambda bh: (bh // Hq) * Hkv + (bh % Hq) // group


_SEG_BIG = 1 << 30  # sentinel above any real segment id


def _segments_may_overlap(qseg, kseg):
    """True iff some (q, k) pair in the block pair can share a nonzero
    segment id. Segment ids are contiguous runs per row (0 = padding), so
    the nonzero [min, max] ranges intersect iff any pair matches — an
    exact skip test, not just a conservative one."""
    q_lo = jnp.min(jnp.where(qseg > 0, qseg, _SEG_BIG))
    q_hi = jnp.max(qseg)
    k_lo = jnp.min(jnp.where(kseg > 0, kseg, _SEG_BIG))
    k_hi = jnp.max(kseg)
    return jnp.logical_and(k_lo <= q_hi, k_hi >= q_lo)


def _scratch_shapes(block_q: int, d: int):
    if _VMEM is not None:
        return [_VMEM((block_q,), jnp.float32),
                _VMEM((block_q,), jnp.float32),
                _VMEM((block_q, d), jnp.float32)]
    return [jax.ShapeDtypeStruct((block_q,), jnp.float32),
            jax.ShapeDtypeStruct((block_q,), jnp.float32),
            jax.ShapeDtypeStruct((block_q, d), jnp.float32)]


def _flash_kernel(q_ref, k_ref, v_ref, *refs, block_q: int, block_k: int,
                  seq_len: int, causal: bool, window: Optional[int],
                  scale: float, num_kv: int, segmented: bool = False):
    if segmented:
        qseg_ref, kseg_ref, o_ref, lse_ref, m_ref, l_ref, acc_ref = refs
    else:
        qseg_ref = kseg_ref = None
        o_ref, lse_ref, m_ref, l_ref, acc_ref = refs
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = ki * block_k

    needed = jnp.asarray(True)
    if causal:  # block fully above the diagonal -> skip
        needed = jnp.logical_and(needed, k_start <= q_start + block_q - 1)
    if window is not None:  # block fully left of the window -> skip
        needed = jnp.logical_and(
            needed, k_start + block_k - 1 >= q_start - window + 1)
    if segmented:  # disjoint segment-id ranges -> skip (packed sequences)
        qseg = qseg_ref[0]                              # (block_q,) int32
        kseg = kseg_ref[0]                              # (block_k,) int32
        needed = jnp.logical_and(needed, _segments_may_overlap(qseg, kseg))

    @pl.when(needed)
    def _compute():
        q = q_ref[0].astype(jnp.float32)               # (block_q, D)
        k = k_ref[0].astype(jnp.float32)               # (block_k, D)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < seq_len                           # unpadded keys only
        if causal:
            mask = jnp.logical_and(mask, qpos >= kpos)
        if window is not None:
            mask = jnp.logical_and(mask, qpos - kpos < window)
        if segmented:  # attend within the same nonzero segment only
            mask = jnp.logical_and(mask, qseg[:, None] == kseg[None, :])
            mask = jnp.logical_and(mask, kseg[None, :] > 0)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        acc_ref[...] = (acc_ref[...] * corr[:, None]
                        + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                              preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(ki == num_kv - 1)
    def _finalize():
        l = l_ref[...]
        denom = jnp.maximum(l, 1e-20)
        o_ref[0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)
        # L = m + log l; fully-masked (padded) rows get 0 so the backward
        # recomputation exp(NEG_INF - 0) underflows to exactly 0.
        lse_ref[0] = jnp.where(l > 0, m_ref[...] + jnp.log(denom), 0.0)


def flash_attention_fwd_pallas(q, k, v, segment_ids=None, *,
                               causal: bool = True,
                               window: Optional[int] = None,
                               block_q: int = 128, block_k: int = 128,
                               interpret: bool = False):
    """Forward with residual logsumexp.

    q: (B, Hq, S, D); k,v: (B, Hkv, S, D) un-expanded — Hq == Hkv is
    plain MHA, otherwise each group of Hq/Hkv query heads reads its KV
    head through the grid index_map (no replication in HBM).
    ``segment_ids``: optional (B, S) int32 packed-document ids (0 = pad);
    attention is confined within equal nonzero ids and cross-segment
    block pairs are skipped.
    Returns (out (B,Hq,S,D), lse (B,Hq,S) float32).
    """
    B, _, S, D = q.shape
    Hq, Hkv, _ = _group_sizes(q.shape, k.shape)
    assert k.shape == v.shape == (B, Hkv, S, D)
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    pad = _pad_len(S, block_q, block_k) - S
    if pad:
        padcfg = ((0, 0), (0, 0), (0, pad), (0, 0))
        q = jnp.pad(q, padcfg)
        k = jnp.pad(k, padcfg)
        v = jnp.pad(v, padcfg)
    Sp = q.shape[2]
    nq, nkv = Sp // block_q, Sp // block_k
    qf = q.reshape(B * Hq, Sp, D)
    kf = k.reshape(B * Hkv, Sp, D)
    vf = v.reshape(B * Hkv, Sp, D)
    kvmap = _kv_head_map(Hq, Hkv)
    segmented = segment_ids is not None
    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k, seq_len=S,
        causal=causal, window=window, scale=1.0 / (D ** 0.5), num_kv=nkv,
        segmented=segmented)
    in_specs = [
        pl.BlockSpec((1, block_q, D), lambda bh, qi, ki: (bh, qi, 0)),
        pl.BlockSpec((1, block_k, D),
                     lambda bh, qi, ki: (kvmap(bh), ki, 0)),
        pl.BlockSpec((1, block_k, D),
                     lambda bh, qi, ki: (kvmap(bh), ki, 0)),
    ]
    args = [qf, kf, vf]
    if segmented:
        seg = jnp.asarray(segment_ids, jnp.int32)
        if pad:
            seg = jnp.pad(seg, ((0, 0), (0, pad)))      # pads get id 0
        in_specs += [
            pl.BlockSpec((1, block_q), lambda bh, qi, ki: (bh // Hq, qi)),
            pl.BlockSpec((1, block_k), lambda bh, qi, ki: (bh // Hq, ki)),
        ]
        args += [seg, seg]
    out, lse = pl.pallas_call(
        kernel,
        grid=(B * Hq, nq, nkv),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, block_q, D), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_q), lambda bh, qi, ki: (bh, qi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * Hq, Sp, D), q.dtype),
            jax.ShapeDtypeStruct((B * Hq, Sp), jnp.float32),
        ],
        scratch_shapes=_scratch_shapes(block_q, D),
        interpret=interpret,
    )(*args)
    return (out.reshape(B, Hq, Sp, D)[:, :, :S],
            lse.reshape(B, Hq, Sp)[:, :, :S])


def flash_attention_pallas(q, k, v, segment_ids=None, *, causal: bool = True,
                           window: Optional[int] = None,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = False):
    """Inference-path forward. q: (B,Hq,S,D); k,v: (B,Hkv,S,D).
    Returns (B,Hq,S,D)."""
    out, _ = flash_attention_fwd_pallas(
        q, k, v, segment_ids, causal=causal, window=window, block_q=block_q,
        block_k=block_k, interpret=interpret)
    return out


# ---------------------------------------------------------------------------
# custom VJP (training path)
# ---------------------------------------------------------------------------

class AttnConfig(NamedTuple):
    """Hashable static configuration threaded through the custom_vjp."""
    causal: bool
    window: Optional[int]
    block_q: int
    block_k: int
    interpret: bool


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _flash_attention(cfg: AttnConfig, q, k, v, segment_ids):
    out, _ = flash_attention_fwd_pallas(
        q, k, v, segment_ids, causal=cfg.causal, window=cfg.window,
        block_q=cfg.block_q, block_k=cfg.block_k, interpret=cfg.interpret)
    return out


def _flash_attention_fwd(cfg: AttnConfig, q, k, v, segment_ids):
    out, lse = flash_attention_fwd_pallas(
        q, k, v, segment_ids, causal=cfg.causal, window=cfg.window,
        block_q=cfg.block_q, block_k=cfg.block_k, interpret=cfg.interpret)
    return out, (q, k, v, segment_ids, out, lse)


def _flash_attention_bwd(cfg: AttnConfig, residuals, do):
    from repro.kernels.flash_attention_bwd import flash_attention_bwd_pallas
    q, k, v, segment_ids, out, lse = residuals
    dq, dk, dv = flash_attention_bwd_pallas(
        q, k, v, out, lse, do, segment_ids, causal=cfg.causal,
        window=cfg.window, block_q=cfg.block_q, block_k=cfg.block_k,
        interpret=cfg.interpret)
    # integer segment ids take a symbolic-zero (float0) cotangent
    dseg = (None if segment_ids is None
            else jnp.zeros(segment_ids.shape, jax.dtypes.float0))
    return dq, dk, dv, dseg


_flash_attention.defvjp(_flash_attention_fwd, _flash_attention_bwd)


def flash_attention_vjp(q, k, v, segment_ids=None, *, causal: bool = True,
                        window: Optional[int] = None,
                        block_q: int = 128, block_k: int = 128,
                        interpret: bool = False):
    """Differentiable flash attention (training entry point)."""
    cfg = AttnConfig(causal=causal, window=window,
                     block_q=min(block_q, q.shape[2]),
                     block_k=min(block_k, q.shape[2]),
                     interpret=interpret)
    return _flash_attention(cfg, q, k, v, segment_ids)
