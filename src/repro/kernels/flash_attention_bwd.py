"""Flash-attention backward — Pallas dQ and dKV kernels (recomputation).

Standard FlashAttention-2 backward: nothing O(S^2) is ever materialized.
The forward saved only the per-row logsumexp ``L = m + log l``; each block
of the backward recomputes ``s = qk^T * scale`` and ``p = exp(s - L)``,
forms ``ds = p * (dp - delta)`` with ``dp = dO v^T`` and
``delta = rowsum(dO * O)``, and accumulates

  dQ  = sum_k (ds * scale) @ K      (grid: kv innermost, dQ in scratch)
  dK  = sum_q (ds * scale)^T @ Q    (grid: q innermost, dK/dV in scratch)
  dV  = sum_q p^T @ dO

Both kernels reuse the forward's causal/window block-skipping (``pl.when``
on the block coordinates), so the backward enjoys the same ~2x causal /
O(window) sparsity win as the forward. ``delta`` is a cheap O(S*D)
elementwise reduction done in plain jnp before the kernels launch.

GQA-native: K/V (and therefore dK/dV) carry ``Hkv`` heads. The dQ grid
maps each Q head onto its KV head (``h // group_size`` index_map); the
dKV grid runs one program row per *KV* head and fuses ``group_size x
num_q_blocks`` into its innermost sequential dimension, so dK/dV
accumulate across every Q head of the group in VMEM scratch — the
``(B, Hq, S, D)`` expanded gradient is never materialized either.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.flash_attention import (NEG_INF, _VMEM, _group_sizes,
                                           _kv_head_map, _pad_len,
                                           _segments_may_overlap)

__all__ = ["flash_attention_bwd_pallas"]


def _scratch(shape):
    if _VMEM is not None:
        return _VMEM(shape, jnp.float32)
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def _block_needed(q_start, k_start, block_q, block_k, causal, window):
    """False iff every (q, k) pair in the block is masked out."""
    needed = jnp.asarray(True)
    if causal:  # block fully above the diagonal
        needed = jnp.logical_and(needed, k_start <= q_start + block_q - 1)
    if window is not None:  # block fully left of the sliding window
        needed = jnp.logical_and(
            needed, k_start + block_k - 1 >= q_start - window + 1)
    return needed


def _recompute_p(q, k, lse, q_start, k_start, *, seq_len, causal, window,
                 scale, qseg=None, kseg=None):
    """Rebuild the probability block p = exp(s - L) and its mask."""
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = jnp.logical_and(kpos < seq_len, qpos < seq_len)
    if causal:
        mask = jnp.logical_and(mask, qpos >= kpos)
    if window is not None:
        mask = jnp.logical_and(mask, qpos - kpos < window)
    if qseg is not None:  # packed rows: within the same nonzero segment
        mask = jnp.logical_and(mask, qseg[:, None] == kseg[None, :])
        mask = jnp.logical_and(mask, kseg[None, :] > 0)
    s = jnp.where(mask, s, NEG_INF)
    p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)
    return p


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, *refs,
               block_q: int, block_k: int, seq_len: int,
               causal: bool, window: Optional[int], scale: float,
               num_kv: int, segmented: bool = False):
    if segmented:
        qseg_ref, kseg_ref, dq_ref, dq_acc = refs
    else:
        qseg_ref = kseg_ref = None
        dq_ref, dq_acc = refs
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        dq_acc[...] = jnp.zeros_like(dq_acc)

    q_start = pl.program_id(1) * block_q
    k_start = ki * block_k

    needed = _block_needed(q_start, k_start, block_q, block_k, causal, window)
    qseg = kseg = None
    if segmented:
        qseg = qseg_ref[0]
        kseg = kseg_ref[0]
        needed = jnp.logical_and(needed, _segments_may_overlap(qseg, kseg))

    @pl.when(needed)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                 # (block_q, D)
        k = k_ref[0].astype(jnp.float32)                 # (block_k, D)
        v = v_ref[0].astype(jnp.float32)
        do = do_ref[0].astype(jnp.float32)               # (block_q, D)
        p = _recompute_p(q, k, lse_ref[0], q_start, k_start,
                         seq_len=seq_len, causal=causal, window=window,
                         scale=scale, qseg=qseg, kseg=kseg)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta_ref[0][:, None]) * scale    # (block_q, block_k)
        dq_acc[...] += jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ki == num_kv - 1)
    def _finalize():
        dq_ref[0] = dq_acc[...].astype(dq_ref.dtype)


def _dkv_kernel(k_ref, v_ref, q_ref, do_ref, lse_ref, delta_ref, *refs,
                block_q: int, block_k: int, seq_len: int, causal: bool,
                window: Optional[int], scale: float, num_q: int,
                num_inner: int, segmented: bool = False):
    if segmented:
        qseg_ref, kseg_ref, dk_ref, dv_ref, dk_acc, dv_acc = refs
    else:
        qseg_ref = kseg_ref = None
        dk_ref, dv_ref, dk_acc, dv_acc = refs
    # innermost dim fuses (group member, q block): t = g * num_q + qi.
    # dK/dV scratch therefore accumulates across ALL Q heads sharing
    # this KV head before the single writeback.
    t = pl.program_id(2)
    qi = t % num_q

    @pl.when(t == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    k_start = pl.program_id(1) * block_k
    q_start = qi * block_q

    needed = _block_needed(q_start, k_start, block_q, block_k, causal, window)
    qseg = kseg = None
    if segmented:
        qseg = qseg_ref[0]
        kseg = kseg_ref[0]
        needed = jnp.logical_and(needed, _segments_may_overlap(qseg, kseg))

    @pl.when(needed)
    def _compute():
        k = k_ref[0].astype(jnp.float32)                 # (block_k, D)
        v = v_ref[0].astype(jnp.float32)
        q = q_ref[0].astype(jnp.float32)                 # (block_q, D)
        do = do_ref[0].astype(jnp.float32)
        p = _recompute_p(q, k, lse_ref[0], q_start, k_start,
                         seq_len=seq_len, causal=causal, window=window,
                         scale=scale, qseg=qseg, kseg=kseg)
        dv_acc[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())),             # p^T @ dO
            preferred_element_type=jnp.float32)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta_ref[0][:, None]) * scale
        dk_acc[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())),             # ds^T @ Q
            preferred_element_type=jnp.float32)

    @pl.when(t == num_inner - 1)
    def _finalize():
        dk_ref[0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_acc[...].astype(dv_ref.dtype)


def flash_attention_bwd_pallas(q, k, v, out, lse, do, segment_ids=None, *,
                               causal: bool = True,
                               window: Optional[int] = None,
                               block_q: int = 128, block_k: int = 128,
                               interpret: bool = False
                               ) -> Tuple[jnp.ndarray, jnp.ndarray,
                                          jnp.ndarray]:
    """dQ/dK/dV for ``flash_attention_fwd_pallas``.

    q,out,do: (B,Hq,S,D); k,v: (B,Hkv,S,D); lse: (B,Hq,S) float32;
    ``segment_ids``: optional (B, S) int32 packed-document ids (0 = pad) —
    both kernels then apply the segment mask and skip cross-segment
    block pairs, mirroring the forward.
    Returns grads with the *primal* shapes/dtypes — dK/dV come back with
    ``Hkv`` heads, already summed over each KV head's query group
    (accumulated in float32 inside the kernels).
    """
    B, _, S, D = q.shape
    Hq, Hkv, group = _group_sizes(q.shape, k.shape)
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    pad = _pad_len(S, block_q, block_k) - S
    # delta = rowsum(dO * O) — the softmax-jacobian correction term
    delta = jnp.sum(do.astype(jnp.float32) * out.astype(jnp.float32), axis=-1)
    segmented = segment_ids is not None
    seg = None
    if segmented:
        seg = jnp.asarray(segment_ids, jnp.int32)
    if pad:
        padcfg = ((0, 0), (0, 0), (0, pad), (0, 0))
        q = jnp.pad(q, padcfg)
        k = jnp.pad(k, padcfg)
        v = jnp.pad(v, padcfg)
        do = jnp.pad(do, padcfg)
        lse = jnp.pad(lse, ((0, 0), (0, 0), (0, pad)))
        delta = jnp.pad(delta, ((0, 0), (0, 0), (0, pad)))
        if segmented:
            seg = jnp.pad(seg, ((0, 0), (0, pad)))       # pads get id 0
    Sp = q.shape[2]
    nq, nkv = Sp // block_q, Sp // block_k
    qf = q.reshape(B * Hq, Sp, D)
    kf = k.reshape(B * Hkv, Sp, D)
    vf = v.reshape(B * Hkv, Sp, D)
    dof = do.reshape(B * Hq, Sp, D)
    lsef = lse.reshape(B * Hq, Sp)
    deltaf = delta.reshape(B * Hq, Sp)
    scale = 1.0 / (D ** 0.5)
    kvmap = _kv_head_map(Hq, Hkv)

    qspec = pl.BlockSpec((1, block_q, D), lambda bh, qi, ki: (bh, qi, 0))
    kspec = pl.BlockSpec((1, block_k, D),
                         lambda bh, qi, ki: (kvmap(bh), ki, 0))
    rowspec = pl.BlockSpec((1, block_q), lambda bh, qi, ki: (bh, qi))

    dq_in_specs = [qspec, kspec, kspec, qspec, rowspec, rowspec]
    dq_args = [qf, kf, vf, dof, lsef, deltaf]
    if segmented:
        dq_in_specs += [
            pl.BlockSpec((1, block_q), lambda bh, qi, ki: (bh // Hq, qi)),
            pl.BlockSpec((1, block_k), lambda bh, qi, ki: (bh // Hq, ki)),
        ]
        dq_args += [seg, seg]
    dq = pl.pallas_call(
        functools.partial(_dq_kernel, block_q=block_q, block_k=block_k,
                          seq_len=S, causal=causal, window=window,
                          scale=scale, num_kv=nkv, segmented=segmented),
        grid=(B * Hq, nq, nkv),
        in_specs=dq_in_specs,
        out_specs=qspec,
        out_shape=jax.ShapeDtypeStruct((B * Hq, Sp, D), q.dtype),
        scratch_shapes=[_scratch((block_q, D))],
        interpret=interpret,
    )(*dq_args)

    # dKV grid: one program row per KV head; kv blocks in the middle;
    # innermost (sequential on TPU) fuses group x q-blocks (t = g*nq + qi)
    # so scratch accumulates the whole query group for one kv block.
    def qmap(bhk, t):
        # flattened q head: batch (bhk // Hkv), kv head (bhk % Hkv),
        # group member (t // nq)
        return (bhk // Hkv) * Hq + (bhk % Hkv) * group + t // nq

    kspec2 = pl.BlockSpec((1, block_k, D), lambda bh, ki, t: (bh, ki, 0))
    qspec2 = pl.BlockSpec((1, block_q, D),
                          lambda bh, ki, t: (qmap(bh, t), t % nq, 0))
    rowspec2 = pl.BlockSpec((1, block_q),
                            lambda bh, ki, t: (qmap(bh, t), t % nq))
    dkv_in_specs = [kspec2, kspec2, qspec2, qspec2, rowspec2, rowspec2]
    dkv_args = [kf, vf, qf, dof, lsef, deltaf]
    if segmented:
        dkv_in_specs += [
            pl.BlockSpec((1, block_q), lambda bh, ki, t: (bh // Hkv, t % nq)),
            pl.BlockSpec((1, block_k), lambda bh, ki, t: (bh // Hkv, ki)),
        ]
        dkv_args += [seg, seg]
    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, block_q=block_q, block_k=block_k,
                          seq_len=S, causal=causal, window=window,
                          scale=scale, num_q=nq, num_inner=group * nq,
                          segmented=segmented),
        grid=(B * Hkv, nkv, group * nq),
        in_specs=dkv_in_specs,
        out_specs=[kspec2, kspec2],
        out_shape=[jax.ShapeDtypeStruct((B * Hkv, Sp, D), k.dtype),
                   jax.ShapeDtypeStruct((B * Hkv, Sp, D), v.dtype)],
        scratch_shapes=[_scratch((block_k, D)), _scratch((block_k, D))],
        interpret=interpret,
    )(*dkv_args)

    def unpad(a, H):
        return a.reshape(B, H, Sp, D)[:, :, :S]
    return unpad(dq, Hq), unpad(dk, Hkv), unpad(dv, Hkv)
