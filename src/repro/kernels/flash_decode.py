"""Single-token flash-decode — Pallas TPU kernel (§Perf/P2's hot loop).

One query token attends over a long KV cache: the cache streams through
VMEM in ``block_k`` tiles with online-softmax running statistics in
scratch, so HBM traffic is exactly one read of the valid cache prefix.
Blocks entirely past ``filled`` (the number of valid cache slots) are
skipped via ``pl.when`` — for a ring buffer that's a no-op (all slots
valid), for a growing cache it prunes the tail without re-compiling.

Grid = (batch*heads, num_kv_blocks); the kv dim iterates sequentially on
TPU so scratch carries (m, l, acc). Heads arrive GQA-expanded from the
wrapper (ops.flash_decode), matching the model's decode path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU memory spaces; absent on CPU-only installs (interpret mode)
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    _VMEM = None

NEG_INF = -1e30


def _scratch(d: int):
    if _VMEM is not None:
        return [_VMEM((1,), jnp.float32), _VMEM((1,), jnp.float32),
                _VMEM((1, d), jnp.float32)]
    return [jax.ShapeDtypeStruct((1,), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.float32),
            jax.ShapeDtypeStruct((1, d), jnp.float32)]


def _decode_kernel(filled_ref, q_ref, k_ref, v_ref, o_ref,
                   m_ref, l_ref, acc_ref, *,
                   block_k: int, scale: float, num_kv: int):
    ki = pl.program_id(1)
    filled = filled_ref[0, 0]

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    k_start = ki * block_k

    @pl.when(k_start < filled)
    def _block():
        q = q_ref[...].astype(jnp.float32)                 # (1, D)
        k = k_ref[...].astype(jnp.float32)                 # (block_k, D)
        v = v_ref[...].astype(jnp.float32)
        s = (q @ k.T) * scale                              # (1, block_k)
        pos = k_start + jax.lax.iota(jnp.int32, block_k)
        s = jnp.where((pos < filled)[None, :], s, NEG_INF)
        m_prev = m_ref[0]
        m_new = jnp.maximum(m_prev, s.max())
        p = jnp.exp(s - m_new)                             # (1, block_k)
        corr = jnp.exp(m_prev - m_new)
        l_ref[0] = l_ref[0] * corr + p.sum()
        acc_ref[...] = acc_ref[...] * corr + p @ v
        m_ref[0] = m_new

    @pl.when(ki == num_kv - 1)
    def _finish():
        o_ref[...] = (acc_ref[...]
                      / jnp.maximum(l_ref[0], 1e-20)).astype(o_ref.dtype)


def flash_decode_pallas(q, k, v, filled, *, block_k: int = 512,
                        interpret: bool = False):
    """q: (B, H, 1, D); k/v: (B, H, S, D) GQA-expanded cache;
    filled: scalar int32 — number of valid cache slots. Returns (B,H,1,D)."""
    B, H, _, D = q.shape
    S = k.shape[2]
    block_k = min(block_k, S)
    pad = (-S) % block_k
    if pad:
        padw = ((0, 0), (0, 0), (0, pad), (0, 0))
        k = jnp.pad(k, padw)
        v = jnp.pad(v, padw)
    Sp = k.shape[2]
    num_kv = Sp // block_k
    qf = q.reshape(B * H, 1, D)
    kf = k.reshape(B * H, Sp, D)
    vf = v.reshape(B * H, Sp, D)
    filled_arr = jnp.full((1, 1), filled, jnp.int32)
    scale = 1.0 / float(D) ** 0.5
    out = pl.pallas_call(
        functools.partial(_decode_kernel, block_k=block_k, scale=scale,
                          num_kv=num_kv),
        grid=(B * H, num_kv),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, i: (0, 0)),
            pl.BlockSpec((None, 1, D), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((None, block_k, D), lambda b, i: (b, i, 0)),
            pl.BlockSpec((None, block_k, D), lambda b, i: (b, i, 0)),
        ],
        out_specs=pl.BlockSpec((None, 1, D), lambda b, i: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, 1, D), q.dtype),
        scratch_shapes=_scratch(D),
        interpret=interpret,
    )(filled_arr, qf, kf, vf)
    return out.reshape(B, H, 1, D)
