"""Single-token flash-decode — Pallas TPU kernel (§Perf/P2's hot loop).

One query token attends over a long KV cache: the cache streams through
VMEM in ``block_k`` tiles with online-softmax running statistics in
scratch, so HBM traffic is exactly one read of the valid cache prefix.
Blocks entirely past ``filled`` (the number of valid cache slots) are
skipped via ``pl.when`` — for a ring buffer that's a no-op (all slots
valid), for a growing cache it prunes the tail without re-compiling.

GQA-native and cache-layout-native: K/V arrive exactly as the model
stores them — ``(B, S, Hkv, D)``, un-expanded — and the BlockSpec
index_map slices the sequence dim in place, so no transposed or
hq-expanded copy of the cache is ever materialized in HBM. The grid
runs one program row per *KV* head; all ``group = Hq/Hkv`` query heads
of that head ride in the q block together, so each cache tile is
fetched once and serves the whole group — HBM reads per step shrink by
the group factor versus the expanded layout.

Grid = (batch, kv_heads, num_kv_blocks); the kv-block dim iterates
sequentially on TPU so scratch carries per-group-row (m, l, acc).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU memory spaces; absent on CPU-only installs (interpret mode)
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    _VMEM = None

NEG_INF = -1e30


def _scratch(group: int, d: int):
    if _VMEM is not None:
        return [_VMEM((group,), jnp.float32), _VMEM((group,), jnp.float32),
                _VMEM((group, d), jnp.float32)]
    return [jax.ShapeDtypeStruct((group,), jnp.float32),
            jax.ShapeDtypeStruct((group,), jnp.float32),
            jax.ShapeDtypeStruct((group, d), jnp.float32)]


def _decode_kernel(filled_ref, q_ref, k_ref, v_ref, o_ref,
                   m_ref, l_ref, acc_ref, *,
                   block_k: int, scale: float, num_kv: int):
    ki = pl.program_id(2)
    filled = filled_ref[0, 0]

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    k_start = ki * block_k

    @pl.when(k_start < filled)
    def _block():
        q = q_ref[...].astype(jnp.float32)                 # (group, D)
        k = k_ref[...].astype(jnp.float32)                 # (block_k, D)
        v = v_ref[...].astype(jnp.float32)
        s = (q @ k.T) * scale                              # (group, block_k)
        pos = k_start + jax.lax.iota(jnp.int32, block_k)
        s = jnp.where((pos < filled)[None, :], s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])                    # (group, block_k)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + p @ v
        m_ref[...] = m_new

    @pl.when(ki == num_kv - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-20)
        o_ref[...] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


def flash_decode_pallas(q, k, v, filled, *, block_k: int = 512,
                        interpret: bool = False):
    """q: (B, Hq, 1, D); k/v: (B, S, Hkv, D) — the model's cache storage
    layout, un-expanded; filled: scalar int32 — number of valid cache
    slots. Returns (B, Hq, 1, D)."""
    B, Hq, _, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    if Hq % Hkv:
        raise ValueError(
            f"GQA head counts must divide: n_heads={Hq}, n_kv_heads={Hkv}")
    group = Hq // Hkv
    block_k = min(block_k, S)
    pad = (-S) % block_k
    if pad:
        padw = ((0, 0), (0, pad), (0, 0), (0, 0))
        k = jnp.pad(k, padw)
        v = jnp.pad(v, padw)
    Sp = k.shape[1]
    num_kv = Sp // block_k
    # q heads j*group .. (j+1)*group-1 share kv head j (repeat semantics);
    # this reshape of the contiguous head dim is free
    qf = q.reshape(B, Hkv, group, D)
    filled_arr = jnp.full((1, 1), filled, jnp.int32)
    scale = 1.0 / float(D) ** 0.5
    out = pl.pallas_call(
        functools.partial(_decode_kernel, block_k=block_k, scale=scale,
                          num_kv=num_kv),
        grid=(B, Hkv, num_kv),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, i: (0, 0)),
            pl.BlockSpec((None, None, group, D), lambda b, h, i: (b, h, 0, 0)),
            pl.BlockSpec((None, block_k, None, D),
                         lambda b, h, i: (b, i, h, 0)),
            pl.BlockSpec((None, block_k, None, D),
                         lambda b, h, i: (b, i, h, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, group, D),
                               lambda b, h, i: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, group, D), q.dtype),
        scratch_shapes=_scratch(group, D),
        interpret=interpret,
    )(filled_arr, qf, k, v)
    return out.reshape(B, Hq, 1, D)
