"""Paged flash-decode — Pallas TPU kernel over a page-table-indexed KV
cache (the serving engine's hot loop).

``flash_decode`` streams one request's *contiguous* cache; batching
requests of wildly different lengths through it means padding every
cache to the longest request and copying each ragged cache into that
contiguous layout. This kernel removes both costs: K/V live in a shared
pool of fixed-size pages ``(num_pages, page_size, Hkv, D)`` and each
request brings a row of page indices (its page table). The grid's
innermost dimension walks the request's pages; the K/V BlockSpec
``index_map`` reads the *scalar-prefetched* page table to fetch the
page each step actually needs — a hardware-level gather, no contiguous
copy, no padding to the batch's max length (tail pages past a request's
``length`` are skipped via ``pl.when``).

Grid = (batch, kv_heads, max_pages); scalar-prefetch args are the page
table ``(B, max_pages)`` and per-request ``lengths (B,)``. Everything
else is inherited from ``flash_decode``'s GQA-native layout: one
program row per *KV* head, the whole ``group = Hq/Hkv`` query-head
group riding each (page_size, D) cache tile, online-softmax running
statistics in scratch over the sequential page dimension.

Bit-parity contract: with ``page_size == block_k`` the tile boundaries
and the online-softmax update order match ``flash_decode`` exactly, so
on equivalent fills the two kernels are bit-identical
(tests/test_paged_decode.py pins this, GQA + ragged fills +
page-boundary cases included).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU memory spaces + scalar-prefetch grid; absent on CPU-only installs
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    pltpu = None
    _VMEM = None

NEG_INF = -1e30


def _scratch(group: int, d: int):
    if _VMEM is not None:
        return [_VMEM((group,), jnp.float32), _VMEM((group,), jnp.float32),
                _VMEM((group, d), jnp.float32)]
    return [jax.ShapeDtypeStruct((group,), jnp.float32),
            jax.ShapeDtypeStruct((group,), jnp.float32),
            jax.ShapeDtypeStruct((group, d), jnp.float32)]


def _paged_kernel(pt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                  m_ref, l_ref, acc_ref, *,
                  page_size: int, scale: float, max_pages: int):
    b = pl.program_id(0)
    pi = pl.program_id(2)
    length = len_ref[b]

    @pl.when(pi == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    start = pi * page_size

    @pl.when(start < length)
    def _page():
        q = q_ref[...].astype(jnp.float32)                 # (group, D)
        k = k_ref[...].astype(jnp.float32)                 # (page_size, D)
        v = v_ref[...].astype(jnp.float32)
        s = (q @ k.T) * scale                              # (group, page_size)
        pos = start + jax.lax.iota(jnp.int32, page_size)
        s = jnp.where((pos < length)[None, :], s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])                    # (group, page_size)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + p @ v
        m_ref[...] = m_new

    @pl.when(pi == max_pages - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-20)
        o_ref[...] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


def flash_decode_paged_pallas(q, k_pages, v_pages, page_table, lengths, *,
                              interpret: bool = False):
    """q: (B, Hq, 1, D); k_pages/v_pages: (num_pages, page_size, Hkv, D)
    — the pool's storage layout, un-expanded; page_table: (B, max_pages)
    int32 page indices per request (entries past a request's fill must
    still be *valid* pool indices — the engine pads with the reserved
    null page 0; their tiles are never read); lengths: (B,) int32 valid
    tokens per request. Returns (B, Hq, 1, D).

    A request whose ``length`` is 0 (a padded batch-bucket slot) returns
    zeros — no page of the pool is touched for it.
    """
    B, Hq, _, D = q.shape
    page_size, Hkv = k_pages.shape[1], k_pages.shape[2]
    if Hq % Hkv:
        raise ValueError(
            f"GQA head counts must divide: n_heads={Hq}, n_kv_heads={Hkv}")
    if page_table.shape[0] != B or lengths.shape != (B,):
        raise ValueError(
            f"page_table {page_table.shape} / lengths {lengths.shape} do "
            f"not match batch {B}")
    group = Hq // Hkv
    max_pages = page_table.shape[1]
    # q heads j*group .. (j+1)*group-1 share kv head j; contiguous-head
    # reshape is free (same trick as flash_decode)
    qf = q.reshape(B, Hkv, group, D)
    scale = 1.0 / float(D) ** 0.5
    kernel = functools.partial(_paged_kernel, page_size=page_size,
                               scale=scale, max_pages=max_pages)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,          # page_table, lengths
        grid=(B, Hkv, max_pages),
        in_specs=[
            pl.BlockSpec((None, None, group, D),
                         lambda b, h, i, pt, ln: (b, h, 0, 0)),
            # the gather: this page's pool row comes from the request's
            # scalar-prefetched page table, h slices the KV head in place
            pl.BlockSpec((None, page_size, None, D),
                         lambda b, h, i, pt, ln: (pt[b, i], 0, h, 0)),
            pl.BlockSpec((None, page_size, None, D),
                         lambda b, h, i, pt, ln: (pt[b, i], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, group, D),
                               lambda b, h, i, pt, ln: (b, h, 0, 0)),
        scratch_shapes=_scratch(group, D),
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, group, D), q.dtype),
        interpret=interpret,
    )(page_table.astype(jnp.int32), lengths.astype(jnp.int32),
      qf, k_pages, v_pages)
    return out.reshape(B, Hq, 1, D)
