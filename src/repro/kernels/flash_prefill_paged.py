"""Segment-masked paged prefill — one Pallas call over a packed buffer
of several requests' prompt chunks (the serving engine's prefill path).

``flash_decode_paged`` removed the B=1-per-request cost from decode;
this kernel removes it from prefill. The engine concatenates the
pending prompt chunks of up to ``G`` requests into one token bucket of
length ``T`` (segment ids 1..G in contiguous runs, 0 = bucket padding)
and hands each segment its own page table. The kernel computes, for
every packed token, causal attention over *its own segment's* paged
K/V — equivalent to G separate chunked-prefill calls, in one traced
shape.

Grid layout = ``(Hkv, G, max_pages)``:

- ``h`` (outermost) walks KV heads; the whole ``group = Hq/Hkv`` query
  head group rides each (page_size, D) cache tile, GQA-native;
- ``g`` walks segments; the q block is the *full* packed buffer every
  step — tokens outside segment ``g+1`` are masked, and the two-level
  mask constant (below) makes their updates exact floating-point
  no-ops, so one (group, T)-shaped scratch accumulates across the
  whole (g, page) sweep;
- ``i`` (innermost) walks segment ``g+1``'s pages; the K/V BlockSpec
  ``index_map`` reads the scalar-prefetched page table
  ``pt[g, i]`` — the page gather happens at the DMA level, exactly as
  in the decode kernel. Pages past a segment's ``seg_maxpos`` (and all
  pages of empty segments, ``seg_maxpos == -1``) are skipped with
  ``pl.when``.

Two-level masking: running maxima init to ``M_INIT = -1e30`` but
masked scores are ``MASKED = -2e30``, strictly below it. A token whose
segment is not the current ``g`` sees an all-masked page: the row max
stays at ``m_prev``, the correction factor is ``exp(0) = 1`` and every
probability is ``exp(-1e30) = 0`` — bitwise no change to (m, l, acc).
With a single shared constant the classic failure appears: an untouched
row (``m_prev == mask value``) would get ``p = exp(0) = 1`` and soak up
garbage V before its own segment arrives.

Bit-parity contract: per token this is the same online-softmax page
sweep as ``flash_decode_paged`` over that token's causal prefix, so
packed prefill + paged decode agree with the sequential chunked path
(tests/test_packed_prefill.py pins greedy token parity, GQA and
page-boundary cases included).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU memory spaces + scalar-prefetch grid; absent on CPU-only installs
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    pltpu = None
    _VMEM = None

M_INIT = -1e30    # running-max init
MASKED = -2e30    # masked score: strictly below M_INIT (see module doc)


def _scratch(group: int, t: int, d: int):
    if _VMEM is not None:
        return [_VMEM((group, t), jnp.float32),
                _VMEM((group, t), jnp.float32),
                _VMEM((group, t, d), jnp.float32)]
    return [jax.ShapeDtypeStruct((group, t), jnp.float32),
            jax.ShapeDtypeStruct((group, t), jnp.float32),
            jax.ShapeDtypeStruct((group, t, d), jnp.float32)]


def _packed_kernel(pt_ref, mp_ref, seg_ref, pos_ref, q_ref, k_ref, v_ref,
                   o_ref, m_ref, l_ref, acc_ref, *,
                   page_size: int, scale: float, num_segs: int,
                   max_pages: int):
    g = pl.program_id(1)
    pi = pl.program_id(2)

    @pl.when(jnp.logical_and(g == 0, pi == 0))
    def _init():  # fresh scratch at the top of each head's (g, i) sweep
        m_ref[...] = jnp.full_like(m_ref, M_INIT)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    start = pi * page_size

    @pl.when(start <= mp_ref[g])   # -1 for empty segments skips every page
    def _page():
        q = q_ref[...].astype(jnp.float32)                 # (group, T, D)
        k = k_ref[...].astype(jnp.float32)                 # (page_size, D)
        v = v_ref[...].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((2,), (1,)), ((), ()))) * scale        # (group, T, ps)
        t_len = q.shape[1]
        kpos = start + jax.lax.broadcasted_iota(
            jnp.int32, (t_len, page_size), 1)
        seg = jnp.swapaxes(seg_ref[...], 0, 1)             # (T, 1)
        pos = jnp.swapaxes(pos_ref[...], 0, 1)
        mask = jnp.logical_and(seg == g + 1, kpos <= pos)  # (T, page_size)
        s = jnp.where(mask[None], s, MASKED)
        m_prev = m_ref[...]                                # (group, T)
        m_new = jnp.maximum(m_prev, s.max(axis=2))
        p = jnp.exp(s - m_new[:, :, None])                 # (group, T, ps)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=2)
        acc_ref[...] = (acc_ref[...] * corr[:, :, None]
                        + jax.lax.dot_general(
                            p, v, (((2,), (0,)), ((), ()))))
        m_ref[...] = m_new

    @pl.when(jnp.logical_and(g == num_segs - 1, pi == max_pages - 1))
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-20)   # pad rows: acc 0 → out 0
        o_ref[...] = (acc_ref[...] / denom[:, :, None]).astype(o_ref.dtype)


def flash_prefill_paged_pallas(q, k_pages, v_pages, page_table, seg_maxpos,
                               seg_ids, positions, *,
                               interpret: bool = False):
    """q: (T, Hq, D) — the packed chunk buffer's queries, token-major;
    k_pages/v_pages: (num_pages, page_size, Hkv, D) pool layout (the new
    chunk's K/V already scattered in by the caller); page_table:
    (G, max_pages) int32, null-page padded; seg_maxpos: (G,) int32 max
    absolute position per segment (-1 for unused rows); seg_ids (T,) /
    positions (T,) int32 per packed token. Returns (T, Hq, D).

    Bucket-pad tokens (segment id 0) return zeros; the caller never
    reads them.
    """
    T, Hq, D = q.shape
    page_size, Hkv = k_pages.shape[1], k_pages.shape[2]
    if Hq % Hkv:
        raise ValueError(
            f"GQA head counts must divide: n_heads={Hq}, n_kv_heads={Hkv}")
    G, max_pages = page_table.shape
    if seg_maxpos.shape != (G,):
        raise ValueError(
            f"seg_maxpos {seg_maxpos.shape} does not match page_table "
            f"rows {G}")
    if seg_ids.shape != (T,) or positions.shape != (T,):
        raise ValueError(
            f"seg_ids {seg_ids.shape} / positions {positions.shape} do "
            f"not match token count {T}")
    group = Hq // Hkv
    # q heads j*group .. (j+1)*group-1 share kv head j (flash_decode trick)
    qf = jnp.swapaxes(q, 0, 1).reshape(Hkv, group, T, D)
    scale = 1.0 / float(D) ** 0.5
    kernel = functools.partial(_packed_kernel, page_size=page_size,
                               scale=scale, num_segs=G, max_pages=max_pages)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,          # page_table, seg_maxpos
        grid=(Hkv, G, max_pages),
        in_specs=[
            pl.BlockSpec((1, T), lambda h, g, i, pt, mp: (0, 0)),
            pl.BlockSpec((1, T), lambda h, g, i, pt, mp: (0, 0)),
            pl.BlockSpec((None, group, T, D),
                         lambda h, g, i, pt, mp: (h, 0, 0, 0)),
            # the gather: this step's pool row comes from segment g's
            # scalar-prefetched page table, h slices the KV head in place
            pl.BlockSpec((None, page_size, None, D),
                         lambda h, g, i, pt, mp: (pt[g, i], 0, h, 0)),
            pl.BlockSpec((None, page_size, None, D),
                         lambda h, g, i, pt, mp: (pt[g, i], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((None, group, T, D),
                               lambda h, g, i, pt, mp: (h, 0, 0, 0)),
        scratch_shapes=_scratch(group, T, D),
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Hkv, group, T, D), q.dtype),
        interpret=interpret,
    )(page_table.astype(jnp.int32), seg_maxpos.astype(jnp.int32),
      seg_ids.reshape(1, T).astype(jnp.int32),
      positions.reshape(1, T).astype(jnp.int32),
      qf, k_pages, v_pages)
    return jnp.swapaxes(out.reshape(Hq, T, D), 0, 1)
