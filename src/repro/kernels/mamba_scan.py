"""Mamba2 chunked selective-state scan (SSD) — Pallas TPU kernel.

Grid = (batch, ssm_heads, num_chunks); the innermost chunk dim iterates
sequentially on TPU, so the (head_dim x state) recurrent state lives in
VMEM scratch and never touches HBM between chunks. Each step computes the
within-chunk quadratic term (chunk x chunk decay-weighted scores on the
MXU) plus the inter-chunk contribution of the carried state — the SSD
blocked algorithm with the inter-chunk recurrence fused into the same
kernel instead of a separate associative scan pass (the GPU formulation's
separate state pass would round-trip states through HBM; on TPU the
sequential grid + VMEM scratch removes that traffic).

Oracle: repro.kernels.ref.ssd_reference (== models.ssm._ssd_chunked).

``mamba_scan_vjp`` is the training entry point: a ``jax.custom_vjp``
whose forward runs the Pallas kernel and whose backward *recomputes*
through the sequential reference scan (no saved chunk intermediates —
residuals are just the five inputs, mirroring the flash-attention
recomputation backward). A fused Pallas reverse-scan backward is the
promoted follow-up (see ROADMAP).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    _VMEM = None


def _scratch(P: int, N: int):
    if _VMEM is not None:
        return [_VMEM((P, N), jnp.float32)]
    return [jax.ShapeDtypeStruct((P, N), jnp.float32)]


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, o_ref, state_ref, *,
                chunk: int, seq_len: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0].astype(jnp.float32)                  # (chunk, P)
    dt = dt_ref[0, 0].astype(jnp.float32)                # (chunk,)
    a = a_ref[0]                                         # () decay rate (neg)
    bm = b_ref[0].astype(jnp.float32)                    # (chunk, N)
    cm = c_ref[0].astype(jnp.float32)                    # (chunk, N)

    pos = ci * chunk + jax.lax.broadcasted_iota(jnp.int32, (chunk,), 0)
    valid = (pos < seq_len).astype(jnp.float32)
    dt = dt * valid                                      # padded steps: no-op

    logdec = dt * a                                      # (chunk,) negative
    cum = jnp.cumsum(logdec)                             # within-chunk
    li = cum[:, None]
    lj = cum[None, :]
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    arg = jnp.where(tri, li - lj, -1e30)
    dmat = jnp.where(tri, jnp.exp(arg), 0.0)             # (chunk, chunk)
    sc = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    w = sc * dmat
    xdt = x * dt[:, None]                                # (chunk, P)
    y_intra = jax.lax.dot_general(w, xdt, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    # inter-chunk: y_i += exp(cum_i) * C_i . S_prev^T  -> (chunk, P)
    state = state_ref[...]                               # (P, N)
    y_inter = jax.lax.dot_general(cm, state, (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    y_inter = y_inter * jnp.exp(cum)[:, None]
    o_ref[0, 0] = (y_intra + y_inter).astype(o_ref.dtype)
    # state update: S' = S * exp(total) + sum_j exp(total - cum_j) xdt_j B_j^T
    total = cum[chunk - 1]
    decj = jnp.exp(total - cum)[:, None]                 # (chunk,1)
    s_new = jax.lax.dot_general(xdt * decj, bm, (((0,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    state_ref[...] = state * jnp.exp(total) + s_new


def mamba_scan_pallas(xh, dt, A, Bm, Cm, *, chunk: int = 128,
                      interpret: bool = False):
    """Chunked SSD scan.

    xh: (B, S, H, P); dt: (B, S, H) positive; A: (H,) negative rates;
    Bm/Cm: (B, S, N). Returns y: (B, S, H, P) (float32 accumulated,
    cast to xh.dtype).
    """
    B, S, H, P = xh.shape
    N = Bm.shape[-1]
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    nc = Sp // chunk
    # (B, H, S, P) layout so (chunk, P) blocks are contiguous
    xT = xh.transpose(0, 2, 1, 3)
    dtT = dt.transpose(0, 2, 1)
    kernel = functools.partial(_ssd_kernel, chunk=chunk, seq_len=S)
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda b, h, ci: (b, h, ci, 0)),
            pl.BlockSpec((1, 1, chunk), lambda b, h, ci: (b, h, ci)),
            pl.BlockSpec((1,), lambda b, h, ci: (h,)),
            pl.BlockSpec((1, chunk, N), lambda b, h, ci: (b, ci, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, ci: (b, ci, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, P), lambda b, h, ci: (b, h, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sp, P), xh.dtype),
        scratch_shapes=_scratch(P, N),
        interpret=interpret,
    )(xT, dtT, A.astype(jnp.float32), Bm, Cm)
    return out.transpose(0, 2, 1, 3)[:, :S]


# ---------------------------------------------------------------------------
# custom VJP (training path)
# ---------------------------------------------------------------------------

class ScanConfig(NamedTuple):
    """Hashable static configuration threaded through the custom_vjp."""
    chunk: int
    interpret: bool


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _mamba_scan(cfg: ScanConfig, xh, dt, A, Bm, Cm):
    return mamba_scan_pallas(xh, dt, A, Bm, Cm, chunk=cfg.chunk,
                             interpret=cfg.interpret)


def _mamba_scan_fwd(cfg: ScanConfig, xh, dt, A, Bm, Cm):
    out = mamba_scan_pallas(xh, dt, A, Bm, Cm, chunk=cfg.chunk,
                            interpret=cfg.interpret)
    return out, (xh, dt, A, Bm, Cm)


def _mamba_scan_bwd(cfg: ScanConfig, residuals, gy):
    # recomputation backward: differentiate the sequential reference scan
    # (the kernel's ground-truth oracle) from the saved inputs — nothing
    # chunk-internal is stored, matching the kernel's HBM-light forward
    from repro.kernels import ref
    xh, dt, A, Bm, Cm = residuals
    _, vjp = jax.vjp(ref.ssd_reference, xh, dt, A, Bm, Cm)
    return vjp(gy.astype(xh.dtype))


_mamba_scan.defvjp(_mamba_scan_fwd, _mamba_scan_bwd)


def mamba_scan_vjp(xh, dt, A, Bm, Cm, *, chunk: int = 128,
                   interpret: bool = False):
    """Differentiable chunked SSD scan (training entry point)."""
    cfg = ScanConfig(chunk=min(chunk, xh.shape[1]), interpret=interpret)
    return _mamba_scan(cfg, xh, dt, A, Bm, Cm)
