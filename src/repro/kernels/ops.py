"""Jit'd public wrappers for the Pallas kernels.

On this CPU container the kernels execute in ``interpret=True`` mode (the
kernel body runs as traced Python); on a real TPU backend set
``REPRO_PALLAS_INTERPRET=0`` (or rely on the auto-detect) to compile them
for the MXU.

``flash_attention``, ``rmsnorm`` and ``mamba_scan`` are the
*training-grade* entry points: each carries a ``jax.custom_vjp``
(flash-recomputation backward for attention, analytic fused backward for
rmsnorm, reference-recomputation backward for the SSD scan) so
``impl="pallas"`` works under ``jax.value_and_grad`` end to end. When block sizes are not given
explicitly they come from the autotune cache (``repro.kernels.autotune``),
falling back to a deterministic static table in interpret mode.
"""
from __future__ import annotations

import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import autotune
from repro.kernels.flash_attention import flash_attention_vjp
from repro.kernels.flash_decode import flash_decode_pallas
from repro.kernels.flash_decode_paged import flash_decode_paged_pallas
from repro.kernels.flash_prefill_paged import flash_prefill_paged_pallas
from repro.kernels.mamba_scan import mamba_scan_vjp
from repro.kernels.rmsnorm import rmsnorm_vjp


def _interpret_default() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


def recommended_impl() -> str:
    """The model ``impl`` the launchers should default to.

    ``pallas`` wherever the kernels compile natively (TPU backends, or an
    explicit ``REPRO_PALLAS_INTERPRET=0``); ``reference`` on CPU-only
    hosts where interpret-mode kernels would *slow down* training.
    Override with ``REPRO_TRAIN_IMPL``.
    """
    env = os.environ.get("REPRO_TRAIN_IMPL")
    if env:
        if env not in ("reference", "pallas", "naive"):
            raise ValueError(
                f"REPRO_TRAIN_IMPL={env!r}: expected one of "
                "'reference', 'pallas', 'naive'")
        return env
    return "reference" if _interpret_default() else "pallas"


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k"))
def flash_attention(q, k, v, segment_ids=None, *, causal: bool = True,
                    window: Optional[int] = None,
                    block_q: Optional[int] = None,
                    block_k: Optional[int] = None):
    """Differentiable flash attention; block sizes autotuned when None.

    GQA-native: ``k``/``v`` carry ``n_kv_heads`` heads (pass them
    un-expanded); the kernels map each query head onto its KV group in
    the grid. The autotune key includes the group size so tuned tiles
    don't alias between MHA and GQA shapes.

    ``segment_ids``: optional (B, S) int32 packed-document ids (0 = pad).
    Attention stays within equal nonzero ids; block pairs whose id
    ranges cannot intersect are skipped in forward and backward.
    """
    interpret = _interpret_default()
    if block_q is None or block_k is None:
        bq, bk = autotune.lookup(
            "flash_fwd", S=q.shape[2], D=q.shape[3], dtype=str(q.dtype),
            causal=causal, window=window, G=q.shape[1] // k.shape[1],
            interpret=interpret)
        block_q = block_q or bq
        block_k = block_k or bk
    return flash_attention_vjp(q, k, v, segment_ids, causal=causal,
                               window=window, block_q=block_q,
                               block_k=block_k, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows"))
def rmsnorm(x, scale, *, eps: float = 1e-5, block_rows: int = 128):
    return rmsnorm_vjp(x, scale, eps=eps, block_rows=block_rows,
                       interpret=_interpret_default())


@functools.partial(jax.jit, static_argnames=("chunk",))
def mamba_scan(xh, dt, A, Bm, Cm, *, chunk: int = 128):
    """Differentiable chunked SSD scan (custom-VJP recomputation
    backward), so ``impl="pallas"`` trains through Mamba2 blocks too."""
    return mamba_scan_vjp(xh, dt, A, Bm, Cm, chunk=chunk,
                          interpret=_interpret_default())


@functools.partial(jax.jit, static_argnames=("block_k",))
def flash_decode(q, k, v, filled, *, block_k: int = 512):
    """Single-token decode attention over the un-expanded GQA cache in
    its stored layout: q (B,Hq,1,D), k/v (B,S,Hkv,D) — each cache tile
    is read once, in place, and serves the whole query-head group."""
    return flash_decode_pallas(q, k, v, filled, block_k=block_k,
                               interpret=_interpret_default())


@jax.jit
def flash_decode_paged(q, k_pages, v_pages, page_table, lengths):
    """Single-token decode attention over a *paged* KV cache: q
    (B,Hq,1,D), k/v pools (num_pages, page_size, Hkv, D), page_table
    (B, max_pages) int32, lengths (B,) int32. Each request's ragged
    cache is gathered page-by-page through the scalar-prefetched page
    table — no contiguous copy, no padding to the batch's max length.
    Bit-identical to :func:`flash_decode` at ``block_k == page_size``
    on equivalent fills."""
    return flash_decode_paged_pallas(q, k_pages, v_pages, page_table,
                                     lengths,
                                     interpret=_interpret_default())


@jax.jit
def flash_prefill_paged(q, k_pages, v_pages, page_table, seg_maxpos,
                        seg_ids, positions):
    """Packed-prefill attention over a paged KV cache: q (T,Hq,D) — the
    concatenated prompt chunks of up to G requests (segment ids 1..G,
    0 = padding), k/v pools (num_pages, page_size, Hkv, D) with the
    chunk's K/V already scattered in, page_table (G, max_pages) int32,
    seg_maxpos (G,) int32, seg_ids/positions (T,) int32. Each token
    attends causally over its own segment's gathered pages; one call
    replaces G chunked-prefill calls."""
    return flash_prefill_paged_pallas(q, k_pages, v_pages, page_table,
                                      seg_maxpos, seg_ids, positions,
                                      interpret=_interpret_default())
