"""Jit'd public wrappers for the Pallas kernels.

On this CPU container the kernels execute in ``interpret=True`` mode (the
kernel body runs as traced Python); on a real TPU backend set
``REPRO_PALLAS_INTERPRET=0`` (or rely on the auto-detect) to compile them
for the MXU.
"""
from __future__ import annotations

import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.flash_decode import flash_decode_pallas
from repro.kernels.mamba_scan import mamba_scan_pallas
from repro.kernels.rmsnorm import rmsnorm_pallas


def _interpret_default() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    block_q: int = 128, block_k: int = 128):
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  block_q=block_q, block_k=block_k,
                                  interpret=_interpret_default())


@functools.partial(jax.jit, static_argnames=("eps", "block_rows"))
def rmsnorm(x, scale, *, eps: float = 1e-5, block_rows: int = 128):
    return rmsnorm_pallas(x, scale, eps=eps, block_rows=block_rows,
                          interpret=_interpret_default())


@functools.partial(jax.jit, static_argnames=("chunk",))
def mamba_scan(xh, dt, A, Bm, Cm, *, chunk: int = 128):
    return mamba_scan_pallas(xh, dt, A, Bm, Cm, chunk=chunk,
                             interpret=_interpret_default())


@functools.partial(jax.jit, static_argnames=("block_k",))
def flash_decode(q, k, v, filled, *, block_k: int = 512):
    """Single-token decode attention over a GQA-expanded cache."""
    return flash_decode_pallas(q, k, v, filled, block_k=block_k,
                               interpret=_interpret_default())
