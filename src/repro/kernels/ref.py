"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

``expand_kv`` lives here and ONLY here: the production kernels and model
paths are GQA-native (K/V keep ``n_kv_heads`` heads end to end), so the
physical head replication survives solely as the parity oracle's way of
reducing grouped attention to the plain MHA reference.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def expand_kv(k: jnp.ndarray, n_rep: int, head_axis: int) -> jnp.ndarray:
    """Replicate each KV head ``n_rep`` times along ``head_axis`` (oracle
    only — the fast paths never materialize this)."""
    if n_rep == 1:
        return k
    return jnp.repeat(k, n_rep, axis=head_axis)


def gqa_attention_reference(q, k, v, *, causal: bool = True,
                            window: Optional[int] = None):
    """Grouped-query oracle: q (B,Hq,S,D), k/v (B,Hkv,S,D) — expands K/V
    and defers to the MHA reference."""
    n_rep = q.shape[1] // k.shape[1]
    return attention_reference(q, expand_kv(k, n_rep, 1),
                               expand_kv(v, n_rep, 1),
                               causal=causal, window=window)


def gqa_decode_attention_reference(q, k, v, filled):
    """Grouped-query decode oracle: q (B,Hq,1,D), k/v (B,Hkv,S,D)."""
    n_rep = q.shape[1] // k.shape[1]
    return decode_attention_reference(q, expand_kv(k, n_rep, 1),
                                      expand_kv(v, n_rep, 1), filled)


def attention_reference(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None):
    """Naive O(S^2) softmax attention. q,k,v: (B,H,S,D)."""
    B, H, S, D = q.shape
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(D)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)
                      ).astype(q.dtype)


def decode_attention_reference(q, k, v, filled):
    """Single-query attention over a cache prefix. q: (B,H,1,D);
    k/v: (B,H,S,D); filled: scalar — valid slots."""
    D = q.shape[-1]
    S = k.shape[2]
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(D)
    valid = jnp.arange(S)[None, None, None, :] < filled
    s = jnp.where(valid, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)
                      ).astype(q.dtype)


def rmsnorm_reference(x, scale, *, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)
            * scale.astype(jnp.float32)).astype(x.dtype)


def ssd_reference(xh, dt, A, Bm, Cm):
    """Sequential (non-chunked) selective-state scan — the ground truth.

    xh: (B,S,H,P); dt: (B,S,H); A: (H,) negative; Bm/Cm: (B,S,N).
    y_t = C_t . S_t + 0,   S_t = exp(dt_t*A) S_{t-1} + dt_t * x_t B_t^T
    """
    B, S, H, P = xh.shape
    N = Bm.shape[-1]

    def step(state, inp):
        x_t, dt_t, b_t, c_t = inp
        a_t = jnp.exp(dt_t * A)                           # (B,H)
        upd = jnp.einsum("bhp,bn,bh->bhpn", x_t.astype(jnp.float32),
                         b_t.astype(jnp.float32), dt_t)
        state = state * a_t[:, :, None, None] + upd
        y = jnp.einsum("bn,bhpn->bhp", c_t.astype(jnp.float32), state)
        return state, y

    s0 = jnp.zeros((B, H, P, N), jnp.float32)
    xs = (xh.swapaxes(0, 1), dt.swapaxes(0, 1),
          Bm.swapaxes(0, 1), Cm.swapaxes(0, 1))
    _, ys = jax.lax.scan(step, s0, xs)
    return ys.swapaxes(0, 1).astype(xh.dtype)             # (B,S,H,P)
