"""Fused RMSNorm — Pallas TPU kernel, fwd + analytic custom VJP.

One pass over rows staged through VMEM: mean-of-squares, rsqrt, scale —
fused so the normalized tensor never round-trips to HBM in fp32. Grid
tiles the flattened row dimension; the feature dimension stays whole in
VMEM (d_model <= 8192 for every assigned arch => <= 32 KB fp32 per row).

The backward is a single fused kernel with the closed-form jacobian
(no recomputation tree, no saved normalized tensor):

  r   = rsqrt(mean(x^2) + eps)        xhat = x * r
  u   = g * scale
  dx  = r * (u - xhat * mean(u * xhat))
  dscale = sum_rows g * xhat           (accumulated across row blocks in
                                        the sequentially-revisited output
                                        block — TPU grids are sequential)
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, scale_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)                  # (block_rows, d)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * scale_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm_pallas(x, scale, *, eps: float = 1e-5, block_rows: int = 128,
                   interpret: bool = False):
    """x: (..., d); scale: (d,)."""
    orig_shape = x.shape
    d = x.shape[-1]
    rows = 1
    for s in x.shape[:-1]:
        rows *= s
    xf = x.reshape(rows, d)
    block_rows = min(block_rows, rows)
    pad = (-rows) % block_rows
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    nrows = xf.shape[0]
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(nrows // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nrows, d), x.dtype),
        interpret=interpret,
    )(xf, scale)
    return out[:rows].reshape(orig_shape)


# ---------------------------------------------------------------------------
# backward
# ---------------------------------------------------------------------------

def _rmsnorm_bwd_kernel(x_ref, scale_ref, g_ref, dx_ref, dscale_ref, *,
                        eps: float):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        dscale_ref[...] = jnp.zeros_like(dscale_ref)

    x = x_ref[...].astype(jnp.float32)                  # (block_rows, d)
    g = g_ref[...].astype(jnp.float32)
    sc = scale_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    r = jax.lax.rsqrt(var + eps)
    xhat = x * r
    u = g * sc
    dx = r * (u - xhat * jnp.mean(u * xhat, axis=-1, keepdims=True))
    dx_ref[...] = dx.astype(dx_ref.dtype)
    dscale_ref[...] += jnp.sum(g * xhat, axis=0)


def rmsnorm_bwd_pallas(x, scale, g, *, eps: float = 1e-5,
                       block_rows: int = 128, interpret: bool = False):
    """Returns (dx, dscale) with the primal dtypes."""
    orig_shape = x.shape
    d = x.shape[-1]
    rows = 1
    for s in x.shape[:-1]:
        rows *= s
    xf = x.reshape(rows, d)
    gf = g.reshape(rows, d)
    block_rows = min(block_rows, rows)
    pad = (-rows) % block_rows
    if pad:
        # zero-padded rows contribute exactly 0 to dscale (g = 0)
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
        gf = jnp.pad(gf, ((0, pad), (0, 0)))
    nrows = xf.shape[0]
    dx, dscale = pl.pallas_call(
        functools.partial(_rmsnorm_bwd_kernel, eps=eps),
        grid=(nrows // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nrows, d), x.dtype),
            jax.ShapeDtypeStruct((d,), jnp.float32),
        ],
        interpret=interpret,
    )(xf, scale, gf)
    return (dx[:rows].reshape(orig_shape), dscale.astype(scale.dtype))


class NormConfig(NamedTuple):
    eps: float
    block_rows: int
    interpret: bool


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _rmsnorm(cfg: NormConfig, x, scale):
    return rmsnorm_pallas(x, scale, eps=cfg.eps, block_rows=cfg.block_rows,
                          interpret=cfg.interpret)


def _rmsnorm_fwd(cfg: NormConfig, x, scale):
    return _rmsnorm(cfg, x, scale), (x, scale)


def _rmsnorm_bwd(cfg: NormConfig, residuals, g):
    x, scale = residuals
    return rmsnorm_bwd_pallas(x, scale, g, eps=cfg.eps,
                              block_rows=cfg.block_rows,
                              interpret=cfg.interpret)


_rmsnorm.defvjp(_rmsnorm_fwd, _rmsnorm_bwd)


def rmsnorm_vjp(x, scale, *, eps: float = 1e-5, block_rows: int = 128,
                interpret: bool = False):
    """Differentiable fused RMSNorm (training entry point)."""
    return _rmsnorm(NormConfig(eps, block_rows, interpret), x, scale)
