"""Fused RMSNorm — Pallas TPU kernel.

One pass over rows staged through VMEM: mean-of-squares, rsqrt, scale —
fused so the normalized tensor never round-trips to HBM in fp32. Grid
tiles the flattened row dimension; the feature dimension stays whole in
VMEM (d_model <= 8192 for every assigned arch => <= 32 KB fp32 per row).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, scale_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)                  # (block_rows, d)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * scale_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm_pallas(x, scale, *, eps: float = 1e-5, block_rows: int = 128,
                   interpret: bool = False):
    """x: (..., d); scale: (d,)."""
    orig_shape = x.shape
    d = x.shape[-1]
    rows = 1
    for s in x.shape[:-1]:
        rows *= s
    xf = x.reshape(rows, d)
    block_rows = min(block_rows, rows)
    pad = (-rows) % block_rows
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    nrows = xf.shape[0]
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(nrows // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nrows, d), x.dtype),
        interpret=interpret,
    )(xf, scale)
    return out[:rows].reshape(orig_shape)
