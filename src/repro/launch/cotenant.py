"""Cotenant launcher: a train Session and a serve Session sharing one
physical cluster under :class:`~repro.core.arbiter.ClusterArbiter`.

The realistic heavy-traffic deployment shape: training holds most of the
cluster, serving holds a slice sized by its predicted wave latency, and
every fault or drift event in *either* tenant re-runs the global
arbitration (train may shrink, serve may donate, the lowest-priority
tenant suspends behind a committed checkpoint when no partition fits).

``--fault-plan`` / ``--serve-fault-plan`` inject deterministic
FaultSchedules into the respective tenant — the same drill CI runs.

Usage:
  python -m repro.launch.cotenant --arch llama-0.5b --reduced \
      --steps 12 --serve-every 4 --fault-plan lose:6:T4-16G+T4-16G
"""
from __future__ import annotations

import argparse
import tempfile
from pathlib import Path

import numpy as np

from repro.api import Session
from repro.configs import get_config
from repro.core import cluster as CL
from repro.core.arbiter import ClusterArbiter, TenantSuspended
from repro.core.faults import FaultPolicy, FaultSchedule
from repro.core.telemetry import EventLog
from repro.launch.serve import run_engine_wave


def _cluster(name: str) -> CL.ClusterSpec:
    if name in CL.PAPER_CLUSTERS:
        return CL.PAPER_CLUSTERS[name]()
    # default skewed fixture: compute-rich + memory-poor halves, the
    # shape where arbiter-chosen partitions beat a naive even split
    return CL.make_cluster("c8", [("V100-16G", 4), ("T4-16G", 4)], 12.0)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama-0.5b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--cluster", default="c8",
                    help="PAPER_CLUSTERS key or 'c8' (default skewed "
                         "4xV100 + 4xT4 fixture)")
    ap.add_argument("--steps", type=int, default=12)
    ap.add_argument("--gbs", type=int, default=16)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--zero", type=int, default=None)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--serve-every", type=int, default=4,
                    help="run one serve wave every N train steps")
    ap.add_argument("--fault-plan", default=None,
                    help="FaultSchedule specs for the train tenant")
    ap.add_argument("--serve-fault-plan", default=None,
                    help="FaultSchedule specs for the serve tenant "
                         "(steps are decode ticks)")
    ap.add_argument("--max-retries", type=int, default=2)
    ap.add_argument("--save-every", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--train-priority", type=int, default=1)
    ap.add_argument("--serve-priority", type=int, default=0)
    ap.add_argument("--train-min", type=int, default=2)
    ap.add_argument("--serve-min", type=int, default=1)
    ap.add_argument("--impl", default="auto")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    cluster = _cluster(args.cluster)
    ckpt_root = Path(args.ckpt_dir or tempfile.mkdtemp(prefix="cotenant-"))
    policy = FaultPolicy(max_retries=args.max_retries,
                         min_devices=1)

    arb = ClusterArbiter(cluster, events=EventLog(verbose=True))
    arb.register_train("train", cfg, gbs=args.gbs, seq=args.seq,
                       zero=args.zero, priority=args.train_priority,
                       min_devices=args.train_min, policy=policy,
                       ckpt_path=str(ckpt_root / "train"))
    arb.register_serve("serve", cfg, requests=args.requests,
                       cache_len=args.prompt_len + args.gen,
                       priority=args.serve_priority,
                       min_devices=args.serve_min, policy=policy,
                       ckpt_path=str(ckpt_root / "serve"))
    rep = arb.arbitrate(trigger="initial")
    print(f"[arbiter] initial partition over {cluster.n} devices "
          f"(utility {rep.utility:.1f}, {rep.candidates} candidates):")
    for name, comp in rep.partition.items():
        print(f"  {name:8s} -> " + " ".join(f"{k}x{c}"
                                            for k, c in comp.items()))

    train_sess = Session.build(cfg, arb.leases["train"], gbs=args.gbs,
                               seq=args.seq, zero=args.zero,
                               impl=args.impl, lr=1e-3)
    serve_sess = Session.build(cfg, arb.leases["serve"], mode="serve",
                               impl=args.impl)
    train_sup = arb.attach(
        "train", train_sess,
        schedule=(FaultSchedule.parse(args.fault_plan)
                  if args.fault_plan else None),
        save_every=args.save_every)
    serve_sup = arb.attach(
        "serve", serve_sess,
        schedule=(FaultSchedule.parse(args.serve_fault_plan)
                  if args.serve_fault_plan else None))

    rng = np.random.default_rng(0)
    # ragged mixed-length prompts — the traffic shape the engine exists for
    lens = rng.integers(max(args.prompt_len // 2, 1), args.prompt_len + 1,
                        args.requests)
    prompts = [rng.integers(3, cfg.vocab_size, int(l)).tolist()
               for l in lens]
    losses = []
    for i in range(args.steps):
        try:
            m = train_sup.step()
            losses.append(float(m["loss"]))
        except TenantSuspended as e:
            print(f"[cotenant] train suspended: {e}")
            break
        if args.serve_every and (i + 1) % args.serve_every == 0:
            t = arb.tenants["serve"]
            if t.suspended:
                print("[cotenant] serve suspended — skipping wave")
            else:
                try:
                    # engine built inside the call: recovery rebinds
                    # serve_sup.session and the retry rebuilds from it
                    results, wall_s, eng = serve_sup.call(
                        lambda: run_engine_wave(serve_sup.session, prompts,
                                                args.gen))
                    n_tok = sum(len(t) for t in results.values())
                    snap = eng.telemetry.snapshot()
                    per_tok = (snap.get("tok_p50_s")
                               or wall_s / max(n_tok, 1))
                    arb.observe_wave("serve", per_tok)
                    print(f"[cotenant] wave after step {i + 1}: "
                          f"{eng.log_line()}")
                except TenantSuspended as e:
                    print(f"[cotenant] serve suspended: {e}")
            arb.maybe_rearbitrate()

    train_sup.flush()
    print(f"[cotenant] {len(losses)} train steps, final loss "
          f"{losses[-1]:.4f}" if losses else "[cotenant] no steps ran")
    print(f"[cotenant] arbitrations={arb.arbitrations} "
          f"recoveries={train_sup.recoveries + serve_sup.recoveries}")
    counts = arb.events.counts()
    print("events:", " ".join(f"{k}={v}" for k, v in sorted(counts.items())))
    for name, t in arb.tenants.items():
        dev = "+".join(t.lease_devices) if t.lease_devices else "none"
        state = "suspended" if t.suspended else "running"
        print(f"  {name:8s} [{state}] lease: {dev}")


if __name__ == "__main__":
    main()
