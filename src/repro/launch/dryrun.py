import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x input shape x mesh) combination this lowers and
compiles the real step function (train_step / prefill / serve_step) against
ShapeDtypeStruct inputs on 512 placeholder host devices, then records:

  - memory_analysis()  (per-device bytes: does it fit a v5e's 16 GB HBM?)
  - cost_analysis()    (HLO FLOPs / bytes for the roofline terms)
  - collective bytes   (parsed from the optimized HLO: all-gather,
    all-reduce, reduce-scatter, all-to-all, collective-permute)

Usage:
  python -m repro.launch.dryrun --arch starcoder2-15b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod-only] [--out DIR]
"""
import argparse
import json
import re
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.api import step_io
from repro.configs import ASSIGNED_ARCHS, applicable, get_config, get_shape, SHAPES
from repro.core.sharding import MeshRules
from repro.launch import specs as SP
from repro.launch.mesh import make_production_mesh

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _bytes_of_shape(txt: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(txt):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str):
    """Sum output-operand bytes of every collective op, by kind.

    These are per-participant shard sizes in the SPMD-partitioned module —
    i.e. bytes each device injects into the interconnect per step."""
    per_kind = {k: 0 for k in _COLLECTIVES}
    count = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        if "=" not in ls:
            continue
        lhs, rhs = ls.split("=", 1)
        rhs = rhs.strip()
        m = re.match(r"^([a-z0-9\[\],\{\}\s]+?)\s*([a-z\-]+)\(", rhs)
        if not m:
            continue
        op = m.group(2)
        for kind in _COLLECTIVES:
            if op == kind or op == kind + "-start":
                per_kind[kind] += _bytes_of_shape(m.group(1))
                count[kind] += 1
    return per_kind, count


def _cost_dict(ca):
    """cost_analysis() returns a one-dict list on older JAX releases."""
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}


def build_step(cfg, rules, shape, impl: str = "reference"):
    """Returns (fn, example_args, in_shardings) — the Session API's
    lowering-only assembly (`repro.api.step_io`); no axes registration,
    no device allocation."""
    return step_io(cfg, rules, shape, impl=impl)


_COST_CACHE = {}


def cost_pass(arch: str, shape_name: str, cfg_override=None, tag: str = ""):
    """Mesh-independent FLOP/byte counting on a single device.

    XLA's cost_analysis() counts while-loop bodies ONCE (not x trip count),
    so the production scan-over-layers module under-reports totals by
    ~n_layers. This pass lowers an *unrolled* variant instead:

      flops_unrolled — layers unrolled + one-shot einsum attention
        (mathematically the same FLOPs as the chunked path; never executed,
        only lowered) + no remat => true algorithmic FLOPs.
      bytes_unrolled — layers unrolled + chunked attention + the config's
        remat policy => HBM-traffic estimate (attention inner-loop bytes
        still counted once per layer; see EXPERIMENTS.md caveats).
    """
    from dataclasses import replace as _replace
    key = (arch, shape_name, tag)
    if key in _COST_CACHE:
        return _COST_CACHE[key]
    from repro.models import model as mm
    cfg = cfg_override if cfg_override is not None else get_config(arch)
    shape = get_shape(shape_name)
    window = SP.effective_window(cfg, shape)
    out = {}

    def _flops_of(fn, *args):
        return _cost_dict(jax.jit(fn).lower(*args).cost_analysis())

    # params shapes without any mesh
    def initv(k):
        p, a = mm.init_model(k, cfg)
        return p

    p_shapes = jax.eval_shape(initv, jax.random.PRNGKey(0))

    if shape.mode == "train":
        batch = SP.batch_specs(cfg, shape)
        cfg_nr = _replace(cfg, remat=False)

        def fwd_bwd_naive(params, batch):
            def loss(p):
                return mm.loss_fn(p, cfg_nr, batch, window=window,
                                  impl="naive", unroll=True)[0]
            return jax.value_and_grad(loss)(params)

        def fwd_bwd_chunk(params, batch):
            def loss(p):
                return mm.loss_fn(p, cfg, batch, window=window,
                                  unroll=True)[0]
            return jax.value_and_grad(loss)(params)

        ca_f = _flops_of(fwd_bwd_naive, p_shapes, batch)
        ca_b = _flops_of(fwd_bwd_chunk, p_shapes, batch)
    elif shape.mode == "prefill":
        batch = SP.batch_specs(cfg, shape)
        ca_f = _flops_of(
            lambda p, b: mm.prefill(p, _replace(cfg, remat=False), b,
                                    window=window, impl="naive", unroll=True),
            p_shapes, batch)
        ca_b = _flops_of(
            lambda p, b: mm.prefill(p, cfg, b, window=window, unroll=True),
            p_shapes, batch)
    else:  # decode: no inner chunk scans; one unrolled pass serves both
        from repro.core.sharding import MeshRules
        cache_len = min(shape.seq_len, window) if window else shape.seq_len

        def build_state():
            enc = None
            if cfg.encoder_layers:
                enc = jnp.zeros((shape.global_batch,
                                 shape.seq_len // cfg.encoder_frame_ratio,
                                 cfg.d_model), jnp.bfloat16)
            return mm.init_decode_state(cfg, shape.global_batch, cache_len,
                                        enc_out=enc)

        state_shapes = jax.eval_shape(build_state)
        toks = SP.SDS((shape.global_batch, 1), jnp.int32)
        ca_f = _flops_of(
            lambda p, t, s: mm.decode_step(p, cfg, t, s, window=window,
                                           unroll=True),
            p_shapes, toks, state_shapes)
        ca_b = ca_f
    out["flops_unrolled"] = ca_f.get("flops", 0.0)
    out["bytes_unrolled"] = ca_b.get("bytes accessed", 0.0)
    _COST_CACHE[key] = out
    return out


def dryrun_one(arch: str, shape_name: str, multi_pod: bool,
               zero_stage=None, hierarchical=False, verbose=True,
               variant: str = ""):
    from repro.launch.variants import get_variant
    var = get_variant(variant)
    cfg = var.cfg_fn(get_config(arch))
    shape = get_shape(shape_name)
    ok, why = applicable(cfg, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name, "skipped": why}
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules_kw = dict(var.rules_kw)
    if hierarchical:
        rules_kw["hierarchical_params"] = True
    stage = rules_kw.pop("zero_stage",
                         zero_stage if zero_stage is not None
                         else cfg.zero_stage)
    rules = MeshRules(mesh, zero_stage=stage, **rules_kw)
    t0 = time.time()
    with mesh:
        fn, args, in_sh = build_step(cfg, rules, shape,
                                     impl=var.impl or "reference")
        lowered = jax.jit(fn, in_shardings=in_sh).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = _cost_dict(compiled.cost_analysis())
        hlo = compiled.as_text()
    coll, coll_n = collective_bytes(hlo)
    try:
        unrolled = cost_pass(arch, shape_name, cfg_override=cfg, tag=variant)
    except Exception as e:  # noqa: BLE001 — cost pass is best-effort
        unrolled = {"cost_pass_error": f"{type(e).__name__}: {e}"}
    res = {
        "arch": arch, "shape": shape_name,
        "variant": variant or "base",
        # global algorithmic FLOPs/bytes (unrolled single-device lowering;
        # scan bodies fully counted) — the roofline's compute/memory inputs:
        **unrolled,
        "mesh": list(mesh.devices.shape), "axes": list(mesh.axis_names),
        "zero_stage": rules.zero_stage,
        "hierarchical_params": hierarchical,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        # per-device numbers from the compiled SPMD module. CAVEAT: XLA
        # counts while-loop (scan-over-layers) bodies ONCE, so these
        # under-report by ~n_layers; kept for reference only.
        "flops_per_device_compiled": (cost or {}).get("flops", 0.0),
        "bytes_per_device_compiled": (cost or {}).get("bytes accessed", 0.0),
        "collective_bytes": coll, "collective_counts": coll_n,
    }
    if mem is not None:
        for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                     "output_size_in_bytes", "generated_code_size_in_bytes",
                     "peak_memory_in_bytes"):
            v = getattr(mem, attr, None)
            if v is not None:
                res[attr] = int(v)
    if verbose:
        print(json.dumps(res, indent=None, default=str))
    return res


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--zero", type=int, default=None)
    ap.add_argument("--hierarchical", action="store_true",
                    help="hierarchical ZeRO: params shard within pod only")
    ap.add_argument("--variant", default="",
                    help="named optimization variant (see launch/variants.py)")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args(argv)

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    combos = []
    archs = ASSIGNED_ARCHS if args.all or args.arch is None else [args.arch]
    shapes = list(SHAPES) if args.all or args.shape is None else [args.shape]
    meshes = ([False, True] if args.both_meshes
              else [bool(args.multi_pod)])
    for a in archs:
        for s in shapes:
            for mp in meshes:
                combos.append((a, s, mp))

    failures = []
    for a, s, mp in combos:
        tag = f"{a}_{s}_{'pod2' if mp else 'pod1'}"
        if args.hierarchical:
            tag += "_hpz"
        if args.variant:
            tag += f"_{args.variant}"
        fp = outdir / f"{tag}.json"
        try:
            res = dryrun_one(a, s, mp, args.zero, args.hierarchical,
                             variant=args.variant)
        except Exception as e:  # noqa: BLE001 — record and continue
            res = {"arch": a, "shape": s, "multi_pod": mp,
                   "error": f"{type(e).__name__}: {e}"}
            failures.append(tag)
            print(f"FAIL {tag}: {res['error']}", file=sys.stderr)
        fp.write_text(json.dumps(res, indent=2, default=str))
    if failures:
        print(f"{len(failures)} failures: {failures}", file=sys.stderr)
        sys.exit(1)
    print(f"all {len(combos)} dry-runs OK -> {outdir}")


if __name__ == "__main__":
    main()
