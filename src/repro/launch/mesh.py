"""Production meshes.

Single pod: 16 x 16 = 256 chips, axes ("data", "model").
Multi-pod:  2 x 16 x 16 = 512 chips, axes ("pod", "data", "model") — the
"pod" axis is both the cross-pod data-parallel axis and Poplar's
heterogeneity unit (each pod may be a different TPU generation; the
planner assigns uneven per-pod batch shares, DESIGN.md §2).

Functions, not module constants: importing this module must never touch
jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_devices: int = 1, model: int = 1):
    """Tiny mesh over the locally available devices (tests/examples)."""
    data = max(n_devices // model, 1)
    return jax.make_mesh((data, model), ("data", "model"))


def data_axis_size(mesh) -> int:
    size = 1
    for a in ("pod", "data"):
        if a in mesh.shape:
            size *= mesh.shape[a]
    return size
