"""Serving launcher: the hetero-aware continuous-batching engine, with
the fixed decode wave kept as the baseline it replaced.

The engine path (default) is the full PR-9 stack:

  1. price each device class's prefill vs decode throughput
     (``serve/split.plan_traffic_split`` over ``core/planner.plan_serve``
     — Alg. 1 economics applied to the two serving phases);
  2. run requests through :class:`~repro.serve.engine.Engine`: paged KV
     cache with refcounted prefix sharing, per-tick admission/eviction,
     packed chunked prefill interleaved with bucketed decode
     (``--no-packed-prefill`` / ``--no-prefix-cache`` fall back to the
     PR-9 behaviour);
  3. report TTFT / per-token latency percentiles and tokens/sec from
     the engine's :class:`~repro.core.telemetry.ServeTelemetry`.

``--wave`` runs the pre-engine baseline instead: one fixed wave sized by
``allocate_stage01`` over ``core/profiler.decode_profiles`` curves, every
request padded to the longest horizon. ``benchmarks/perf_variants.py``
races the two; the engine must win on mixed-length traffic.

Fault-injection parity with ``launch/train.py``: ``--fault-plan`` arms a
deterministic :class:`~repro.core.faults.FaultSchedule` on the serve
session (each engine decode tick consumes one schedule tick) and a
serve-side :class:`~repro.core.faults.Supervisor` absorbs the injected
faults — the serve tenant is drivable in the same cotenant fault drills
as train.

Usage:
  python -m repro.launch.serve --arch llama-0.5b --reduced \
      --cluster C --requests 32 --prompt-len 16 --gen 24 \
      [--wave] [--fault-plan lose:8:T4-16G] [--max-retries 2]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import Session
from repro.configs import get_config
from repro.core import cluster as CL
from repro.core.allocation import allocate_stage01, fit_curve
from repro.core.faults import FaultPolicy, FaultSchedule, Supervisor
from repro.core.profiler import decode_profiles


def run_wave(sess: Session, prompts, gen_tokens: int):
    """Fixed-wave baseline: prefill everyone, decode everyone to the
    same horizon. Short requests pay for long ones at both ends — kept
    as the benchmark the engine has to beat."""
    B, prompt_len = prompts.shape
    state = sess.init_decode_state(B, prompt_len + gen_tokens)
    logits = None
    t0 = time.time()
    for t in range(prompt_len):
        logits, state = sess.decode(prompts[:, t:t + 1], state)
    prefill_s = time.time() - t0
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out = []
    t0 = time.time()
    for _ in range(gen_tokens):
        out.append(np.asarray(tok)[:, 0])
        logits, state = sess.decode(tok, state)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    jax.block_until_ready(logits)
    decode_s = time.time() - t0
    return np.stack(out, axis=1), prefill_s, decode_s


def run_engine_wave(sess: Session, prompts, gens, **engine_kw):
    """Run one batch of requests through a fresh engine built from the
    (possibly recovered) session; returns ``(results, wall_s, engine)``.

    ``prompts`` is a list of token lists (ragged — that's the point);
    ``gens`` an int or per-request list of generation lengths. Built
    fresh each call so ``Supervisor.call`` retries construct the engine
    from ``sup.session`` after a recovery rebound it.
    """
    n = len(prompts)
    if isinstance(gens, int):
        gens = [gens] * n
    cache_len = max(len(p) + g for p, g in zip(prompts, gens))
    engine_kw.setdefault("requests", n)
    engine_kw.setdefault("cache_len", cache_len)
    eng = sess.engine(**engine_kw)
    rids = [eng.submit(p, g) for p, g in zip(prompts, gens)]
    t0 = time.time()
    results = eng.run()
    wall_s = time.time() - t0
    return {r: results[r] for r in rids}, wall_s, eng


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama-0.5b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--cluster", default="C", choices=sorted(CL.PAPER_CLUSTERS))
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--wave", action="store_true",
                    help="run the fixed-wave baseline instead of the "
                         "continuous-batching engine")
    ap.add_argument("--num-pages", type=int, default=512)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--chunk", type=int, default=16)
    ap.add_argument("--no-packed-prefill", dest="packed_prefill",
                    action="store_false", default=True,
                    help="sequential one-chunk-per-call prefill (the "
                         "PR-9 baseline) instead of packed segment-"
                         "masked prefill")
    ap.add_argument("--no-prefix-cache", dest="prefix_cache",
                    action="store_false", default=True,
                    help="disable cross-request prefix-page sharing")
    ap.add_argument("--fault-plan", default=None,
                    help="comma-separated FaultSchedule specs (steps are "
                         "decode ticks), e.g. lose:8:T4-16G,step_fail:3")
    ap.add_argument("--max-retries", type=int, default=2)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    cluster = CL.PAPER_CLUSTERS[args.cluster]()
    cache_len = args.prompt_len + args.gen

    # the cluster rides along so a membership fault has survivors to
    # re-plan onto (serve replan = mesh + re-jit, no Poplar search)
    sess = Session.build(cfg, cluster, mode="serve")
    sup = None
    if args.fault_plan:
        sched = FaultSchedule.parse(args.fault_plan)
        sup = Supervisor(sess, FaultPolicy(max_retries=args.max_retries),
                         sched)
        sess.events.verbose = True
    rng = np.random.default_rng(0)

    if args.wave:
        # ---- fixed-wave baseline: Poplar allocation of one wave --------
        curves = {n: fit_curve(p)
                  for n, p in decode_profiles(cluster, cfg,
                                              cache_len).items()}
        plan = allocate_stage01(curves, args.requests)
        print(f"serving wave of {args.requests} requests over cluster "
              f"{args.cluster} ({cluster.n} devices):")
        for name, a in plan.assignments.items():
            print(f"  {name:16s} -> {a.gmbs:4d} requests "
                  f"(mbs {curves[name].mbs})")
        assert plan.total_batch == args.requests
        prompts = jnp.asarray(
            rng.integers(3, cfg.vocab_size,
                         (args.requests, args.prompt_len)), jnp.int32)
        if sup is not None:
            # the callable re-reads sup.session: recovery may rebind it
            gen, prefill_s, decode_s = sup.call(
                lambda: run_wave(sup.session, prompts, args.gen))
        else:
            gen, prefill_s, decode_s = run_wave(sess, prompts, args.gen)
        tps = args.requests * args.gen / decode_s
        print(f"arch={args.arch} reduced={args.reduced} "
              f"prefill {prefill_s*1e3:.1f}ms  decode "
              f"{decode_s / args.gen * 1e3:.2f}ms/tok  {tps:.0f} tok/s")
        print("sample:", gen[0][:10].tolist())
    else:
        # ---- engine path: mixed-length traffic, continuous batching ----
        lens = rng.integers(max(args.prompt_len // 2, 1),
                            args.prompt_len + 1, args.requests)
        prompts = [rng.integers(3, cfg.vocab_size, int(l)).tolist()
                   for l in lens]
        gens = rng.integers(max(args.gen // 2, 1), args.gen + 1,
                            args.requests).tolist()
        kw = dict(num_pages=args.num_pages, page_size=args.page_size,
                  chunk=args.chunk, packed_prefill=args.packed_prefill,
                  prefix_cache=args.prefix_cache)
        if sup is not None:
            results, wall_s, eng = sup.call(
                lambda: run_engine_wave(sup.session, prompts, gens, **kw))
        else:
            results, wall_s, eng = run_engine_wave(sess, prompts, gens,
                                                   **kw)
        if eng.split is not None:
            print(eng.split.describe())
        tokens = sum(len(t) for t in results.values())
        print(f"arch={args.arch} reduced={args.reduced} "
              f"{len(results)} requests, {tokens} tokens in "
              f"{wall_s:.2f}s ({tokens / wall_s:.0f} tok/s wall)")
        print(eng.log_line())
        print("sample:", results[min(results)][:10])
    if sup is not None and len(sess.events):
        counts = sess.events.counts()
        print("fault events:", " ".join(f"{k}={v}"
                                        for k, v in sorted(counts.items())))


if __name__ == "__main__":
    main()
