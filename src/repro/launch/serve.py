"""Serving launcher: batched generation with Poplar-style heterogeneity
awareness applied to the *serving* wave size.

The paper allocates training micro-batches per device from measured speed
curves; the same machinery sizes decode waves across heterogeneous
serving groups here:

  1. profile each device group's decode step time vs batch (Alg. 1 on the
     serve path — analytical device models on this CPU container);
  2. spline-fit the curves (Alg. 2 substrate);
  3. allocate each wave's requests so all groups finish together
     (allocate_stage01 — decode has no gradient sync, so the stage-0/1
     allocator is the right shape);
  4. run the wave through a serve-mode Session (jitted prefill/decode).

Fault-injection parity with ``launch/train.py``: ``--fault-plan`` arms a
deterministic :class:`~repro.core.faults.FaultSchedule` on the serve
session (each decode call consumes one schedule tick) and a serve-side
:class:`~repro.core.faults.Supervisor` absorbs the injected faults —
the serve tenant is drivable in the same cotenant fault drills as train.

Usage:
  python -m repro.launch.serve --arch llama-0.5b --reduced \
      --cluster C --requests 32 --prompt-len 16 --gen 24 \
      [--fault-plan lose:8:T4-16G] [--max-retries 2]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import Session
from repro.configs import get_config
from repro.core import cluster as CL
from repro.core.allocation import allocate_stage01, fit_curve
from repro.core.faults import FaultPolicy, FaultSchedule, Supervisor
from repro.core.profiler import decode_profiles


def profile_decode_groups(cluster: CL.ClusterSpec, cfg, cache_len: int):
    """Decode-speed curves per device: step time ~ param reads + cache
    reads at batch b (HBM-bound), measured against each device's specs
    (profiling lives in :func:`repro.core.profiler.decode_profiles` —
    shared with the serve planner and the multi-tenant arbiter)."""
    return {n: fit_curve(p)
            for n, p in decode_profiles(cluster, cfg, cache_len).items()}


def run_wave(sess: Session, prompts, gen_tokens: int):
    B, prompt_len = prompts.shape
    state = sess.init_decode_state(B, prompt_len + gen_tokens)
    logits = None
    t0 = time.time()
    for t in range(prompt_len):
        logits, state = sess.decode(prompts[:, t:t + 1], state)
    prefill_s = time.time() - t0
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out = []
    t0 = time.time()
    for _ in range(gen_tokens):
        out.append(np.asarray(tok)[:, 0])
        logits, state = sess.decode(tok, state)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    jax.block_until_ready(logits)
    decode_s = time.time() - t0
    return np.stack(out, axis=1), prefill_s, decode_s


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama-0.5b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--cluster", default="C", choices=sorted(CL.PAPER_CLUSTERS))
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--fault-plan", default=None,
                    help="comma-separated FaultSchedule specs (steps are "
                         "decode ticks), e.g. lose:8:T4-16G,step_fail:3")
    ap.add_argument("--max-retries", type=int, default=2)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    cluster = CL.PAPER_CLUSTERS[args.cluster]()
    cache_len = args.prompt_len + args.gen

    # ---- Poplar allocation of the wave across heterogeneous groups ----
    curves = profile_decode_groups(cluster, cfg, cache_len)
    plan = allocate_stage01(curves, args.requests)
    print(f"serving wave of {args.requests} requests over cluster "
          f"{args.cluster} ({cluster.n} devices):")
    for name, a in plan.assignments.items():
        print(f"  {name:16s} -> {a.gmbs:4d} requests "
              f"(mbs {curves[name].mbs})")
    assert plan.total_batch == args.requests

    # ---- execute locally (one wave; per-group waves on a real fleet) ----
    # the cluster rides along so a membership fault has survivors to
    # re-plan onto (serve replan = mesh + re-jit, no Poplar search)
    sess = Session.build(cfg, cluster, mode="serve")
    sup = None
    if args.fault_plan:
        sched = FaultSchedule.parse(args.fault_plan)
        sup = Supervisor(sess, FaultPolicy(max_retries=args.max_retries),
                         sched)
        sess.events.verbose = True
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(3, cfg.vocab_size, (args.requests, args.prompt_len)),
        jnp.int32)
    if sup is not None:
        # the callable re-reads sup.session: recovery may rebind it
        gen, prefill_s, decode_s = sup.call(
            lambda: run_wave(sup.session, prompts, args.gen))
    else:
        gen, prefill_s, decode_s = run_wave(sess, prompts, args.gen)
    tps = args.requests * args.gen / decode_s
    print(f"arch={args.arch} reduced={args.reduced} "
          f"prefill {prefill_s*1e3:.1f}ms  decode "
          f"{decode_s / args.gen * 1e3:.2f}ms/tok  {tps:.0f} tok/s")
    print("sample:", gen[0][:10].tolist())
    if sup is not None and len(sess.events):
        counts = sess.events.counts()
        print("fault events:", " ".join(f"{k}={v}"
                                        for k, v in sorted(counts.items())))


if __name__ == "__main__":
    main()
