"""ShapeDtypeStruct input specs + sharding trees for every
(architecture x input shape) combination — the shared substrate of the
dry-run, the benchmarks and the real launcher.

No device allocation happens here: params/opt/caches come from
``jax.eval_shape`` and inputs are ShapeDtypeStructs.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.configs.shapes import InputShape
from repro.core.sharding import MeshRules, use_rules
from repro.core.zero import model_shardings
from repro.models import model as mm

SDS = jax.ShapeDtypeStruct


def effective_window(cfg: ModelConfig, shape: InputShape) -> Optional[int]:
    """long_500k on attention archs runs the sliding-window variant."""
    if shape.name == "long_500k" and cfg.long_context_variant_window:
        return cfg.long_context_variant_window
    return cfg.sliding_window


def batch_specs(cfg: ModelConfig, shape: InputShape, *, accum: int = 0
                ) -> Dict[str, SDS]:
    """Training/prefill batch ShapeDtypeStructs. ``accum>0`` prepends the
    gradient-accumulation axis (Poplar gmbs/lbs layout)."""
    B, S = shape.global_batch, shape.seq_len
    lead = (accum,) if accum else ()
    out = {
        "tokens": SDS(lead + (B, S), jnp.int32),
        "labels": SDS(lead + (B, S), jnp.int32),
        "loss_mask": SDS(lead + (B, S), jnp.float32),
    }
    if cfg.encoder_layers:
        out["frames"] = SDS(lead + (B, S // cfg.encoder_frame_ratio,
                                    cfg.d_model), jnp.bfloat16)
    if cfg.num_image_tokens:
        out["image_embeds"] = SDS(lead + (B, cfg.num_image_tokens,
                                          cfg.frontend_dim), jnp.bfloat16)
    if shape.mode == "prefill":
        out.pop("labels")
        out.pop("loss_mask")
    return out


def batch_spec_tree(rules: MeshRules, batch: Dict[str, SDS], *,
                    accum: int = 0) -> Dict[str, P]:
    out = {}
    for k, v in batch.items():
        lead = (None,) if accum else ()
        logical = lead + ("batch",) + (None,) * (v.ndim - len(lead) - 1)
        out[k] = rules.activation_spec(logical, v.shape)
    return out


def params_and_shardings(cfg: ModelConfig, rules: MeshRules,
                         with_opt: bool = True):
    """eval_shape the params (+ opt state) and derive their spec trees."""
    axes_box = {}

    def init_values_only(key):
        params, axes = mm.init_model(key, cfg)
        axes_box["axes"] = axes   # static; captured during the single trace
        return params

    p_shapes = jax.eval_shape(init_values_only, jax.random.PRNGKey(0))
    axes = axes_box["axes"]
    p_specs, opt_specs, g_specs = model_shardings(rules, p_shapes, axes)
    if not with_opt:
        return p_shapes, axes, p_specs, None, None, g_specs
    from repro.optim.adamw import adamw_init
    o_shapes = jax.eval_shape(adamw_init, p_shapes)
    return p_shapes, axes, p_specs, o_shapes, opt_specs, g_specs


def decode_state_specs(cfg: ModelConfig, rules: MeshRules,
                       shape: InputShape):
    """(state ShapeDtypeStruct tree, spec tree) for serve_step."""
    window = effective_window(cfg, shape)
    cache_len = min(shape.seq_len, window) if window else shape.seq_len

    def build():
        enc = None
        if cfg.encoder_layers:
            enc = jnp.zeros((shape.global_batch,
                             shape.seq_len // cfg.encoder_frame_ratio,
                             cfg.d_model), jnp.bfloat16)
        return mm.init_decode_state(cfg, shape.global_batch, cache_len,
                                    enc_out=enc)

    with use_rules(rules):
        state_shapes = jax.eval_shape(build)
        axes = mm.decode_state_axes(cfg, state_shapes)

    def to_spec(leaf_shape, ax):
        return rules.activation_spec(ax, leaf_shape.shape)

    spec_tree = jax.tree.map(
        lambda v, ax: to_spec(v, ax), state_shapes, axes,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))
    return state_shapes, spec_tree
