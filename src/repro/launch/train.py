"""Training launcher: Poplar auto-configuration + hetero data layout +
pjit'd ZeRO train loop, end to end — one `Session.build` call.

  python -m repro.launch.train --arch llama-0.5b --steps 100 \
      --cluster B --gbs 64 --seq 128 [--zero N] [--resume CKPT]

On this CPU container the "cluster" is simulated by the analytical device
models (the planner's allocation is real; execution runs on the local
device with the padded hetero layout). On a real heterogeneous TPU fleet
the same code plans per pod group and the mesh spans the fleet.

The planner sees the *same* config that trains (including ``--reduced``)
— planning against the full model while training the smoke variant would
feed the batch allocator the wrong memory model. ``--plan-seq`` keeps
the option of planning at a production sequence length while the CPU
demo trains short ones.
"""
from __future__ import annotations

import argparse
import sys
import time

from repro.api import Session
from repro.configs import get_config
from repro.core import cluster as CL


def _explicit_dests(ap: argparse.ArgumentParser, argv) -> set:
    """Dests of options the user actually typed (``--lr 3e-4`` counts even
    when 3e-4 is the default — resume must treat it as an override)."""
    argv = sys.argv[1:] if argv is None else list(argv)
    given = set()
    for action in ap._actions:
        for opt in action.option_strings:
            if any(a == opt or a.startswith(opt + "=") for a in argv):
                given.add(action.dest)
    return given


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama-0.5b")
    ap.add_argument("--reduced", action="store_true",
                    help="use the 2-layer smoke variant (CPU-friendly)")
    ap.add_argument("--cluster", default="B", choices=list("ABC") + ["tpu"])
    ap.add_argument("--gbs", type=int, default=32)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--plan-seq", type=int, default=None,
                    help="sequence length for planning only (default: --seq)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--profile", default="analytical",
                    choices=["analytical", "measured"],
                    help="planner timing source: 'analytical' simulates "
                         "the cluster's DeviceSpec curves, 'measured' "
                         "times the real jitted step per device kind "
                         "(Algorithm 1 over a ProbeHarness) so the batch "
                         "allocation runs on observed wall time")
    ap.add_argument("--replan-every", type=int, default=0, metavar="N",
                    help="every N steps, compare observed step time "
                         "against the plan's prediction and re-plan + "
                         "reshard in place when drift is detected "
                         "(0 = never; see Session.maybe_replan)")
    ap.add_argument("--zero", type=int, default=None)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--impl", default="auto",
                    choices=["auto", "reference", "pallas", "naive"],
                    help="attention/norm implementation; 'auto' picks the "
                         "custom-VJP Pallas kernels when they compile "
                         "natively (TPU) and the jnp reference otherwise")
    ap.add_argument("--overlap", default="auto",
                    choices=["auto", "scheduled", "xla"],
                    help="ZeRO-3 collective scheduling: 'scheduled' runs "
                         "the explicit shard_map step (double-buffered "
                         "layer prefetch + per-layer grad reduce-scatter), "
                         "'xla' leaves collectives to auto-SPMD, 'auto' "
                         "picks scheduled when the mesh supports it")
    ap.add_argument("--comm-dtype", default=None, choices=[None, "int8"],
                    help="wire format for the scheduled path's sharded "
                         "collectives (int8 = qcomm quantized AG/RS)")
    ap.add_argument("--packing", action="store_true",
                    help="pack mixed-length documents into the batch rows "
                         "(segment-aware attention, non-pad loss "
                         "normalizer, effective-token planning) — the "
                         "padding-free hot path")
    ap.add_argument("--data", default=None, help="text file (byte-LM); "
                                                 "default synthetic")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--resume", default=None, metavar="CKPT",
                    help="resume params/opt/step from a Session checkpoint "
                         "directory (crash recovery)")
    ap.add_argument("--ckpt-every", type=int, default=0, metavar="N",
                    help="checkpoint to --ckpt every N applied steps "
                         "(0 = final save only)")
    ap.add_argument("--async-ckpt", action="store_true",
                    help="asynchronous checkpointing: the step loop pays "
                         "only for the device->host snapshot; "
                         "serialization, atomic commit and retention run "
                         "on a background thread")
    ap.add_argument("--keep-last", type=int, default=None, metavar="N",
                    help="retain only the newest N committed checkpoints")
    ap.add_argument("--fault-plan", default=None, metavar="SPEC",
                    help="deterministic fault injection, comma-separated "
                         "(see core.faults.FaultSchedule.parse): e.g. "
                         "'lose:40:T4-16G#3+T4-16G#4,ckpt_io:25:2,"
                         "slow:10-20:T4-16G#2:2.0'")
    ap.add_argument("--max-retries", type=int, default=2,
                    help="recovery attempts per step before giving up")
    ap.add_argument("--min-devices", type=int, default=1,
                    help="fewest survivors a device loss may leave before "
                         "the run is declared unrecoverable")
    ap.add_argument("--log-every", type=int, default=1)
    args = ap.parse_args(argv)

    def make_cfg():
        return get_config(args.arch, reduced=args.reduced)

    def make_cluster():
        return (CL.hetero_tpu_fleet() if args.cluster == "tpu"
                else CL.PAPER_CLUSTERS[args.cluster]())

    # ---- Poplar: fully automated configuration, one call ----
    build_kw = dict(gbs=args.gbs, seq=args.seq, zero=args.zero,
                    impl=args.impl, overlap=args.overlap,
                    comm_dtype=args.comm_dtype, lr=args.lr, data=args.data,
                    plan_seq=args.plan_seq, profile=args.profile,
                    packing=args.packing)
    if args.resume:
        # crash recovery must resume the *recorded* recipe: only flags the
        # user actually typed on this invocation override it — passing
        # every argparse default would silently clobber the original
        # lr/gbs/data/arch the checkpoint was trained with
        given = _explicit_dests(ap, argv)
        overrides = {k: v for k, v in build_kw.items() if k in given}
        cfg = make_cfg() if given & {"arch", "reduced"} else None
        cluster = make_cluster() if "cluster" in given else None
        sess = Session.restore(args.resume, cfg=cfg, cluster=cluster,
                               **overrides)
        print(f"[resume] {args.resume} @ step {int(sess.state.step)}"
              + (f" (overriding {sorted(overrides)})" if overrides else ""))
    else:
        sess = Session.build(make_cfg(), make_cluster(), mode="train",
                             **build_kw)
    desc = sess.describe()
    print(f"[impl] {desc['impl']}"
          + (" (auto)" if args.impl == "auto" else ""))
    plan = desc.get("plan")   # absent when resuming an unplanned checkpoint
    if plan is not None:
        packed = sess._packed
        print(f"[poplar] stage={plan['zero_stage']} "
              f"probes={plan['profiling_probes']} "
              f"(+{plan['profiling_probes_saved']} deduped) "
              f"source={plan['profile_source']} "
              f"predicted {plan['predicted']['cluster_tflops']:.1f} TFLOPs "
              f"util={plan['predicted']['utilization']:.3f} "
              + (f"packed(fill={packed.token_fraction:.3f} "
                 f"seg~{packed.mean_segment_len:.0f}) "
                 if packed is not None else "")
              + f"({plan['plan_seconds']:.2f}s planning, "
              f"{desc['build_seconds']:.2f}s build)")
        for n, a in plan["assignments"].items():
            print(f"  {n:14s} gmbs={a['gmbs']:4d} micro={a['micro_batch']:3d} "
                  f"gas={a['gas']:3d} lbs={a['lbs']:3d}")
    else:
        print(f"[unplanned] stage={desc['zero_stage']} "
              f"({desc['build_seconds']:.2f}s build)")
    lay = desc["layout"]
    print(f"[layout] groups={len(lay['groups'])} "
          f"padded/group={lay['padded_group_batch']} gas={lay['gas']}")

    # ---- train loop: supervised steps over the Session's hetero loader.
    # The Supervisor absorbs faults per the policy (transient retry,
    # device-loss re-plan over survivors, restore-from-checkpoint
    # fallback) and drives the periodic async checkpoints; on a
    # fault-free run it is a plain pass-through around sess.step().
    from repro.api import FaultPolicy, FaultSchedule, Supervisor
    sess.events.verbose = True            # [fault] transition lines
    schedule = (FaultSchedule.parse(args.fault_plan)
                if args.fault_plan else None)
    policy = FaultPolicy(max_retries=args.max_retries,
                         min_devices=args.min_devices)
    sup = Supervisor(sess, policy, schedule, ckpt_path=args.ckpt,
                     save_every=args.ckpt_every,
                     async_save=args.async_ckpt,
                     keep_last=args.keep_last)

    tokens_seen = 0
    start = int(sess.state.step)
    steps_run = 0
    t_start = time.time()
    while int(sup.session.state.step) < args.steps:
        step = int(sup.session.state.step)
        met = sup.step()
        sess = sup.session                # recovery may rebind the session
        steps_run += 1
        tokens_seen += int(met["tokens"])
        if step % args.log_every == 0:
            tps = sess.telemetry.tokens_per_sec
            print(f"step {step:4d} loss={float(met['loss']):.4f} "
                  f"gnorm={float(met['grad_norm']):.3f} "
                  f"tokens={tokens_seen}"
                  + (f" tok/s={tps:.0f}" if tps else ""))
        if args.replan_every and step and step % args.replan_every == 0:
            rep = sess.maybe_replan()
            if rep is not None:
                imb = (f", imb={rep.drift.observed_imbalance:.2f}x"
                       if rep.drift is not None else "")
                print(f"[replan] step {step}: {rep.drift.reason} -> "
                      f"re-planned ({rep.plan_seconds:.2f}s plan + "
                      f"{rep.reshard_seconds:.2f}s reshard, "
                      f"stage={rep.zero_stage}, "
                      f"source={rep.profile_source}{imb})")
            else:
                d = sess.drift()
                if d is not None:
                    imb = (f" imb={d.observed_imbalance:.2f}x"
                           + (f" ({d.slowest_device})"
                              if d.slowest_device else ""))
                    print(f"[drift] step {step}: {d.reason}{imb}")
    dt = time.time() - t_start
    print(f"[done] {steps_run} steps, {tokens_seen} tokens, "
          f"{tokens_seen/dt:.0f} tok/s (wall, this host)")
    if args.ckpt:
        out = sess.save(args.ckpt, async_=args.async_ckpt,
                        keep_last=args.keep_last)
        if args.async_ckpt:
            errs = sess.flush_saves()
            print(f"[ckpt] committed step {out.step} async"
                  + (f" ({len(errs)} failed saves)" if errs else ""))
        else:
            print(f"[ckpt] saved {out}")
    counts = sess.events.counts()
    if counts:
        print("[events] " + " ".join(f"{k}={v}"
                                     for k, v in sorted(counts.items())))


if __name__ == "__main__":
    main()
