"""Training launcher: Poplar auto-configuration + hetero data layout +
pjit'd ZeRO train loop, end to end.

  python -m repro.launch.train --arch llama-0.5b --steps 100 \
      --cluster B --gbs 64 --seq 128 [--zero N] [--measured]

On this CPU container the "cluster" is simulated by the analytical device
models (the planner's allocation is real; execution runs on the local
device with the padded hetero layout). On a real heterogeneous TPU fleet
the same code plans per pod group and the mesh spans the fleet.
"""
from __future__ import annotations

import argparse
import time
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.configs import get_config
from repro.core import cluster as CL
from repro.core.hetero import layout_from_plan
from repro.core.planner import plan as poplar_plan
from repro.core.sharding import MeshRules
from repro.core.zero import make_train_step, model_shardings, register_axes
from repro.data.pipeline import HeteroDataLoader, SyntheticTokens, TextFileTokens
from repro.launch.mesh import data_axis_size, make_debug_mesh
from repro.models import model as mm
from repro.optim.adamw import adamw_init
from repro.optim.schedule import cosine_schedule


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama-0.5b")
    ap.add_argument("--reduced", action="store_true",
                    help="use the 2-layer smoke variant (CPU-friendly)")
    ap.add_argument("--cluster", default="B", choices=list("ABC") + ["tpu"])
    ap.add_argument("--gbs", type=int, default=32)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--zero", type=int, default=None)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--impl", default="auto",
                    choices=["auto", "reference", "pallas", "naive"],
                    help="attention/norm implementation; 'auto' picks the "
                         "custom-VJP Pallas kernels when they compile "
                         "natively (TPU) and the jnp reference otherwise")
    ap.add_argument("--overlap", default="auto",
                    choices=["auto", "scheduled", "xla"],
                    help="ZeRO-3 collective scheduling: 'scheduled' runs "
                         "the explicit shard_map step (double-buffered "
                         "layer prefetch + per-layer grad reduce-scatter), "
                         "'xla' leaves collectives to auto-SPMD, 'auto' "
                         "picks scheduled when the mesh supports it")
    ap.add_argument("--comm-dtype", default=None, choices=[None, "int8"],
                    help="wire format for the scheduled path's sharded "
                         "collectives (int8 = qcomm quantized AG/RS)")
    ap.add_argument("--data", default=None, help="text file (byte-LM); "
                                                 "default synthetic")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--log-every", type=int, default=1)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    cluster = (CL.hetero_tpu_fleet() if args.cluster == "tpu"
               else CL.PAPER_CLUSTERS[args.cluster]())

    from repro.kernels.ops import recommended_impl
    impl = recommended_impl() if args.impl == "auto" else args.impl
    print(f"[impl] {impl}" + (" (auto)" if args.impl == "auto" else ""))

    # ---- Poplar: fully automated configuration ----
    from repro.core.overlap import SCHEDULED_OVERLAP_FACTOR
    overlap_factor = (SCHEDULED_OVERLAP_FACTOR if args.overlap != "xla"
                      else 0.0)
    t0 = time.time()
    pplan = poplar_plan(cluster, get_config(args.arch), args.gbs,
                        seq_len=max(args.seq, 512), zero_stage=args.zero,
                        overlap_factor=overlap_factor)
    print(f"[poplar] stage={pplan.zero_stage} "
          f"probes={pplan.profiling_probes} "
          f"predicted {pplan.predicted.cluster_tflops:.1f} TFLOPs "
          f"util={pplan.predicted.utilization:.3f} "
          f"({time.time()-t0:.2f}s planning)")
    for n, a in pplan.allocation.assignments.items():
        print(f"  {n:14s} gmbs={a.gmbs:4d} micro={a.micro_batch:3d} "
              f"gas={a.gas:3d} lbs={a.lbs:3d}")

    # ---- hetero batch layout + loader ----
    mesh = make_debug_mesh(jax.device_count())
    layout = layout_from_plan(pplan.allocation,
                              group_multiple=data_axis_size(mesh))
    # cap padded batch for the CPU demo
    print(f"[layout] groups={len(layout.group_names)} "
          f"padded/group={layout.padded_group_batch} gas={layout.gas}")
    if args.data:
        src = TextFileTokens(args.data, args.seq)
        cfg = replace(cfg, vocab_size=max(cfg.vocab_size, src.vocab_size))
    else:
        src = SyntheticTokens(cfg.vocab_size, args.seq)
    loader = HeteroDataLoader(src, layout, args.seq)

    # ---- model + ZeRO shardings ----
    rules = MeshRules(mesh, zero_stage=pplan.zero_stage,
                      overlap=args.overlap, comm_dtype=args.comm_dtype)
    params, axes = mm.init_model(jax.random.PRNGKey(0), cfg)
    register_axes(rules, axes)
    p_specs, o_specs, _ = model_shardings(rules, params, axes)
    opt = adamw_init(params)
    with mesh:
        params = jax.device_put(params, jax.tree.map(rules.sharding, p_specs))
        opt = jax.device_put(opt, jax.tree.map(rules.sharding, o_specs))
        step_fn = jax.jit(make_train_step(
            cfg, rules, lr=args.lr, impl=impl, accum_steps=layout.gas))

        tokens_seen = 0
        t_start = time.time()
        for step in range(args.steps):
            batch = loader.next_batch()
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            if layout.gas == 1:
                batch = {k: v[0] for k, v in batch.items()}
            params, opt, met = step_fn(params, opt, batch)
            tokens_seen += int(met["tokens"])
            if step % args.log_every == 0:
                print(f"step {step:4d} loss={float(met['loss']):.4f} "
                      f"gnorm={float(met['grad_norm']):.3f} "
                      f"tokens={tokens_seen}")
        dt = time.time() - t_start
        print(f"[done] {args.steps} steps, {tokens_seen} tokens, "
              f"{tokens_seen/dt:.0f} tok/s (wall, this host)")
    if args.ckpt:
        fn = save_checkpoint(args.ckpt, args.steps, params, opt)
        print(f"[ckpt] saved {fn}")


if __name__ == "__main__":
    main()
