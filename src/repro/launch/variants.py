"""Named optimization variants for the dry-run / §Perf hillclimb.

A variant is (config transform, MeshRules overrides). The empty variant
is the paper-faithful baseline; every other entry is a beyond-paper
optimization recorded separately in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

from dataclasses import replace
from typing import Callable, Dict, Optional

from repro.configs.base import ModelConfig


def _moe_group(g: int) -> Callable[[ModelConfig], ModelConfig]:
    def f(cfg: ModelConfig) -> ModelConfig:
        if cfg.moe is None:
            return cfg
        return replace(cfg, moe=replace(cfg.moe, group_size=g))
    return f


def _remat(on: bool) -> Callable[[ModelConfig], ModelConfig]:
    return lambda cfg: replace(cfg, remat=on)


class Variant:
    def __init__(self, cfg_fn: Optional[Callable] = None,
                 rules_kw: Optional[Dict] = None, note: str = "",
                 impl: Optional[str] = None):
        self.cfg_fn = cfg_fn or (lambda c: c)
        self.rules_kw = rules_kw or {}
        self.note = note
        self.impl = impl  # model impl override ("pallas"/"reference"/None)


def _moe_impl(impl: str) -> Callable[[ModelConfig], ModelConfig]:
    def f(cfg: ModelConfig) -> ModelConfig:
        if cfg.moe is None:
            return cfg
        return replace(cfg, moe=replace(cfg.moe, impl=impl))
    return f


VARIANTS: Dict[str, Variant] = {
    # §Perf/P1 — grouped MoE routing: dispatch capacity per g-token group
    "moe_g4096": Variant(_moe_group(4096), note="grouped MoE dispatch, g=4096"),
    "moe_g1024": Variant(_moe_group(1024), note="grouped MoE dispatch, g=1024"),
    "moe_g256": Variant(_moe_group(256), note="grouped MoE dispatch, g=256"),
    # §Perf/P1 iter 2 — dropless sorted dispatch via lax.ragged_dot
    "moe_ragged": Variant(_moe_impl("ragged"),
                          note="dropless ragged_dot dispatch"),
    # §Perf/P1 iter 4 — explicit all_to_all expert parallelism. The
    # shard_map path derives capacity from the per-shard token count, so
    # it inherits the grouped-capacity win; group_size=2048 makes the
    # mesh-less cost-pass proxy match the per-shard capacity at S=32k.
    "moe_a2a": Variant(
        lambda c: (c if c.moe is None else replace(
            c, moe=replace(c.moe, impl="a2a", group_size=2048))),
        note="shard_map all_to_all expert parallelism"),
    # §Perf/P1 iter 3 — grouped dispatch + bf16 combine tensor
    "moe_g1024_bf16": Variant(
        lambda c: (c if c.moe is None else replace(
            c, moe=replace(c.moe, group_size=1024,
                           combine_dtype="bfloat16"))),
        note="g=1024 + bf16 combine"),
    # §Perf/P4 — training-grade Pallas kernels: custom-VJP flash attention
    # (recomputation backward, causal/window block skipping) + fused
    # rmsnorm VJP, with autotuned (block_q, block_k) tiles. The default
    # train path on TPU backends; as a named variant it lets the dry-run
    # compare kernel vs reference lowering on any backend.
    "pallas": Variant(impl="pallas",
                      note="custom-VJP flash-attention + rmsnorm kernels"),
    # §Perf/P3 — hierarchical ZeRO (ZeRO++ hpZ): params shard within pod
    "hpz": Variant(rules_kw=dict(hierarchical_params=True),
                   note="pod-local param shards; cross-pod grads only"),
    # §Perf/P2 follow-up — fp8 KV cache: halves decode cache reads
    "kv_fp8": Variant(lambda c: replace(c, kv_cache_dtype="float8_e4m3fn"),
                      note="fp8 KV cache storage"),
    # §Perf/P2 — serving sharding: params replicated over the data axis
    # (TP only). ZeRO-3's data-axis param shards force a full param
    # all-gather per decoded token; inference has no optimizer so the
    # shards buy nothing. zero_stage=0 at serve time removes the gather.
    "serve_z0": Variant(rules_kw=dict(zero_stage=0),
                        note="decode/prefill with data-replicated params"),
    # remat policy sweep (memory-term lever)
    "remat_off": Variant(_remat(False), note="no activation checkpointing"),
    # §Perf/P3 — mLSTM chunk sweep: (B,Q,Q,H) intermediates scale ~S*Q
    "mlstm_c128": Variant(lambda c: replace(c, mlstm_chunk=128),
                          note="mLSTM chunk 256 -> 128"),
    "mlstm_c64": Variant(lambda c: replace(c, mlstm_chunk=64),
                         note="mLSTM chunk 256 -> 64"),
    # §Perf/P3 — pure data parallelism: no TP, ZeRO over data x model.
    # For attention-free archs (xLSTM) whose small head count wastes the
    # model axis and forces per-chunk cotangent all-gathers.
    "dp_only": Variant(rules_kw=dict(dp_only=True),
                       note="no TP; batch and ZeRO over (data, model)"),
    # §Perf/P3 — combined best-known xLSTM config
    "xlstm_opt": Variant(lambda c: replace(c, mlstm_chunk=128),
                         rules_kw=dict(dp_only=True),
                         note="dp_only + mLSTM chunk 128"),
}


def get_variant(name: Optional[str]) -> Variant:
    if not name:
        return Variant()
    if name not in VARIANTS:
        raise KeyError(f"unknown variant {name!r}; known: {sorted(VARIANTS)}")
    return VARIANTS[name]
