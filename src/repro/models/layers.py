"""Core transformer layers: RMSNorm, RoPE, GQA attention (chunked
online-softmax for long sequences, KV-cache decode, optional sliding
window), SwiGLU MLP, embeddings.

Everything is pure jnp + logical-axis sharding constraints. The chunked
attention here is the *reference* implementation (linear memory, flash-style
two-level scan); the Pallas TPU kernel in ``repro.kernels`` is numerically
checked against it.

All attention paths are GQA-native: K/V keep ``n_kv_heads`` heads from
projection through the kernels (grouped einsums on the jnp paths, grid
index maps in Pallas) — the ``n_heads/n_kv_heads`` head replication
exists only in the parity oracle ``repro.kernels.ref.expand_kv``.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.sharding import constrain, current_rules
from repro.models.param import Annotated, dense_init, ones_init

# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d_model: int, dtype=jnp.bfloat16):
    return {"scale": ones_init((d_model,), ("embed",), dtype)}


def rmsnorm(params, x, eps: float = 1e-5, impl: str = "reference"):
    if impl == "pallas":
        from repro.kernels import ops as kops
        return kops.rmsnorm(x, params["scale"], eps=eps)
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, D); positions: (S,), or (B, S) per-row positions
    (packed sequences restart each document at 0), or broadcastable to
    x[..., :, 0]."""
    freqs = rope_frequencies(x.shape[-1], theta)           # (D/2,)
    pos = positions.astype(jnp.float32)
    if pos.ndim == 2 and x.ndim == 4:  # (B, S) against (B, H, S, D)
        pos = pos[:, None, :]
    angles = pos[..., :, None] * freqs                     # (..., S, D/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def attention_init(key, cfg, dtype=jnp.bfloat16) -> Dict:
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], (d, hq, hd), ("embed", "heads", None), dtype),
        "wk": dense_init(ks[1], (d, hkv, hd), ("embed", "kv_heads", None), dtype),
        "wv": dense_init(ks[2], (d, hkv, hd), ("embed", "kv_heads", None), dtype),
        "wo": dense_init(ks[3], (hq, hd, d), ("heads", None, "embed"), dtype),
    }


def _chunk_attn_flash(q, k, v, *, causal: bool, window: Optional[int],
                      q_offset: int = 0, q_chunk: int = 1024,
                      kv_chunk: int = 1024, segment_ids=None):
    """Two-level online-softmax attention, GQA-native.
    q: (B,Hq,Sq,D); k/v: (B,Hkv,Skv,D) with Hq % Hkv == 0 — each group of
    Hq//Hkv query heads reads its KV head through a grouped einsum, so
    K/V are never replicated to Hq heads. ``segment_ids``: optional
    (B, S) int32 packed-document ids (0 = pad) masking attention to
    within equal nonzero ids.

    Linear memory in sequence length; computes the full rectangle of blocks
    (masked) — block skipping is the Pallas kernel's win.
    """
    B, Hq, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Skv)
    # pad to multiples
    pad_q = (-Sq) % q_chunk
    pad_kv = (-Skv) % kv_chunk
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0))) if pad_q else q
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad_kv), (0, 0))) if pad_kv else k
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad_kv), (0, 0))) if pad_kv else v
    nq, nkv = qp.shape[2] // q_chunk, kp.shape[2] // kv_chunk
    scale = 1.0 / jnp.sqrt(D).astype(jnp.float32)

    # q heads g*G..(g+1)*G-1 share kv head g (repeat semantics)
    qb = qp.reshape(B, Hkv, G, nq, q_chunk, D).transpose(3, 0, 1, 2, 4, 5)
    #                                          (nq, B, Hkv, G, qc, D)
    kb = kp.reshape(B, Hkv, nkv, kv_chunk, D).transpose(2, 0, 1, 3, 4)
    vb = vp.reshape(B, Hkv, nkv, kv_chunk, D).transpose(2, 0, 1, 3, 4)
    qsegb = ksegb = None
    if segment_ids is not None:
        seg = jnp.asarray(segment_ids, jnp.int32)
        qseg_p = jnp.pad(seg, ((0, 0), (0, pad_q))) if pad_q else seg
        kseg_p = jnp.pad(seg, ((0, 0), (0, pad_kv))) if pad_kv else seg
        qsegb = qseg_p.reshape(B, nq, q_chunk).transpose(1, 0, 2)   # (nq,B,qc)
        ksegb = kseg_p.reshape(B, nkv, kv_chunk).transpose(1, 0, 2)  # (nkv,B,kc)

    q_pos_base = jnp.arange(q_chunk)
    kv_pos_base = jnp.arange(kv_chunk)

    def q_step(_, qi_q):
        if segment_ids is not None:
            qi, qblk, qsegblk = qi_q
        else:
            qi, qblk = qi_q
            qsegblk = None
        qpos = q_offset + qi * q_chunk + q_pos_base          # (qc,)

        def kv_step(carry, ki_kv):
            m, l, acc = carry
            if segment_ids is not None:
                ki, kblk, vblk, ksegblk = ki_kv
            else:
                ki, kblk, vblk = ki_kv
                ksegblk = None
            kpos = ki * kv_chunk + kv_pos_base               # (kc,)
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qblk, kblk,
                           preferred_element_type=jnp.float32) * scale
            mask = (kpos[None, :] <= Skv - 1)                # valid (unpadded) keys
            mask = mask & (qpos[:, None] >= 0)
            if causal:
                mask = mask & (qpos[:, None] >= kpos[None, :])
            if window is not None:
                mask = mask & (qpos[:, None] - kpos[None, :] < window)
            mask_b = mask[None]                              # (1|B, qc, kc)
            if ksegblk is not None:
                mask_b = mask_b & (qsegblk[:, :, None] == ksegblk[:, None, :])
                mask_b = mask_b & (ksegblk[:, None, :] > 0)
            s = jnp.where(mask_b[:, None, None], s, -jnp.inf)
            m_new = jnp.maximum(m, s.max(axis=-1))
            # guard fully-masked rows
            m_safe = jnp.where(jnp.isinf(m_new), 0.0, m_new)
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where(mask_b[:, None, None], p, 0.0)
            corr = jnp.exp(jnp.where(jnp.isinf(m), 0.0, m) - m_safe)
            corr = jnp.where(jnp.isinf(m), 0.0, corr)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p.astype(vblk.dtype), vblk,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        init = (jnp.full((B, Hkv, G, q_chunk), -jnp.inf, jnp.float32),
                jnp.zeros((B, Hkv, G, q_chunk), jnp.float32),
                jnp.zeros((B, Hkv, G, q_chunk, D), jnp.float32))
        kv_xs = ((jnp.arange(nkv), kb, vb, ksegb)
                 if segment_ids is not None else (jnp.arange(nkv), kb, vb))
        (m, l, acc), _ = jax.lax.scan(kv_step, init, kv_xs)
        out = acc / jnp.maximum(l, 1e-20)[..., None]
        return None, out.astype(q.dtype)

    q_xs = ((jnp.arange(nq), qb, qsegb)
            if segment_ids is not None else (jnp.arange(nq), qb))
    _, outs = jax.lax.scan(q_step, None, q_xs)
    # (nq, B, Hkv, G, qc, D) -> (B, Hq, Sq, D)
    out = outs.transpose(1, 2, 3, 0, 4, 5).reshape(B, Hq, nq * q_chunk, D)
    return out[:, :, :Sq]


def attention_apply(params, x, cfg, *, positions=None, mask_mode="causal",
                    window: Optional[int] = None, impl: str = "reference",
                    kv_override: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
                    segment_ids=None):
    """Full-sequence attention (train / prefill).

    x: (B, S, d_model). ``kv_override`` supplies external K/V inputs
    (cross-attention): tuple of (B, S_kv, d_model) source hidden states is
    projected by wk/wv. ``segment_ids``: optional (B, S) int32
    packed-document ids (0 = pad) — self-attention is confined within
    equal nonzero ids on every impl (ignored for cross-attention).
    """
    B, S, _ = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = jnp.einsum("bsd,dhk->bhsk", x, params["wq"].astype(x.dtype))
    src = kv_override[0] if kv_override is not None else x
    k = jnp.einsum("bsd,dhk->bhsk", src, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bhsk", src, params["wv"].astype(x.dtype))
    if positions is None:
        positions = jnp.arange(S)
    if kv_override is None:  # self-attention: rotate both
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = constrain(q, ("batch", "heads", None, None))
    # K/V stay at hkv heads end to end — every impl below is GQA-native,
    # so the (B, Hq, S, D) expansion is never materialized.
    k = constrain(k, ("batch", "kv_heads", None, None))
    v = constrain(v, ("batch", "kv_heads", None, None))
    causal = (mask_mode == "causal") and kv_override is None
    if kv_override is not None:
        segment_ids = None  # cross-attention: sources are not packed
    if impl == "pallas" and kv_override is None:
        # differentiable Pallas kernel (custom_vjp) — safe under
        # jax.value_and_grad and gradient accumulation
        from repro.kernels import ops as kops
        out = kops.flash_attention(q, k, v, segment_ids, causal=causal,
                                   window=window)
    elif impl == "naive":
        # one-shot einsum attention: used ONLY by the dry-run cost pass
        # (XLA cost_analysis does not multiply loop bodies by trip count,
        # so the chunked-scan path under-reports FLOPs). O(S^2) memory —
        # never executed, only lowered for counting.
        B_, Hq_, Sq_, D_ = q.shape
        Hkv_ = k.shape[1]
        qg = q.reshape(B_, Hkv_, Hq_ // Hkv_, Sq_, D_)
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k,
                       preferred_element_type=jnp.float32) / jnp.sqrt(
                           q.shape[-1]).astype(jnp.float32)
        qpos = jnp.arange(q.shape[2])[:, None]
        kpos = jnp.arange(k.shape[2])[None, :]
        mask = jnp.ones(s.shape[-2:], bool)
        if causal:
            mask &= qpos >= kpos
        if window is not None:
            mask &= (qpos - kpos) < window
        mask_b = mask[None]                                  # (1|B, Sq, Skv)
        if segment_ids is not None:
            seg = segment_ids
            mask_b = mask_b & (seg[:, :, None] == seg[:, None, :])
            mask_b = mask_b & (seg[:, None, :] > 0)
        s = jnp.where(mask_b[:, None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bhgqk,bhkd->bhgqd", p.astype(v.dtype), v
                         ).reshape(B_, Hq_, Sq_, D_)
    else:
        out = _chunk_attn_flash(q, k, v, causal=causal, window=window,
                                segment_ids=segment_ids)
    out = constrain(out, ("batch", "heads", None, None))
    y = jnp.einsum("bhsk,hkd->bsd", out, params["wo"].astype(x.dtype))
    return constrain(y, ("batch", None, "embed"))


# ----------------------------- decode path --------------------------------

def kv_cache_axes(cfg) -> Tuple:
    """Cache layout (B, S, Hkv, D): shard heads on 'model' when divisible,
    otherwise shard the cache sequence dim (context-parallel decode)."""
    rules = current_rules()
    if rules is not None and "model" in rules.mesh.shape:
        if cfg.n_kv_heads % rules.mesh.shape["model"] == 0:
            return ("batch", None, "kv_heads", None)
        return ("batch", "kv_seq", None, None)
    return ("batch", None, "kv_heads", None)


def attention_init_cache(cfg, batch: int, max_len: int, dtype=None):
    if dtype is None:
        dtype = jnp.dtype(getattr(cfg, "kv_cache_dtype", None) or cfg.dtype)
    hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, max_len, hkv, hd), dtype),
        "v": jnp.zeros((batch, max_len, hkv, hd), dtype),
    }


def _decode_attn_kvseq_sharded(rules, q, k_tok, v_tok, cache, slot, filled,
                               n_rep: int):
    """Distributed flash-decode over a sequence-sharded KV cache (§Perf/P2).

    Each `model`-axis shard holds S/n contiguous cache slots. The new
    token is written into whichever shard owns `slot`; every shard then
    computes partial attention over its local slice and the shards
    combine with a max-stabilized log-sum-exp psum. Per-layer collective
    traffic becomes O(B*Hq*D) f32 (the numerator/denominator psum)
    instead of the O(B*S*Hkv*D) cache all-gather XLA emits for a plain
    softmax over a sharded axis.
    """
    from jax.sharding import PartitionSpec as P

    from repro.core.sharding import shard_map_compat
    mesh = rules.mesh
    B, Hq, _, D = q.shape
    S = cache["k"].shape[1]
    n = mesh.shape["model"]
    S_loc = S // n
    scale = 1.0 / jnp.sqrt(D).astype(jnp.float32)
    bspec = rules.activation_spec(
        ("batch", None, None, None), cache["k"].shape)[0]

    def local_fn(qb, kt, vt, kc, vc, slot_, filled_):
        idx = jax.lax.axis_index("model")
        off = idx * S_loc
        lslot = slot_ - off
        in_range = (lslot >= 0) & (lslot < S_loc)
        lclamp = jnp.clip(lslot, 0, S_loc - 1)
        kc2 = jax.lax.dynamic_update_slice(
            kc, kt.astype(kc.dtype), (0, lclamp, 0, 0))
        vc2 = jax.lax.dynamic_update_slice(
            vc, vt.astype(vc.dtype), (0, lclamp, 0, 0))
        kc2 = jnp.where(in_range, kc2, kc)
        vc2 = jnp.where(in_range, vc2, vc)
        # grouped attention over the local un-expanded cache slice: the
        # q heads fold to (Hkv, n_rep) so K/V are read at Hkv heads
        Bl, Hq_, one, D_ = qb.shape
        Hkv_ = kc2.shape[2]
        kk = kc2.astype(qb.dtype)                             # (B,S_loc,Hkv,D)
        vv = vc2.astype(qb.dtype)
        qg = qb.reshape(Bl, Hkv_, n_rep, one, D_)
        s = jnp.einsum("bhgqd,bshd->bhgqs", qg, kk,
                       preferred_element_type=jnp.float32) * scale
        valid = (off + jnp.arange(S_loc))[None, None, None, None, :] < filled_
        s = jnp.where(valid, s, -jnp.inf)
        m_loc = s.max(axis=-1)                                # (B,Hkv,G,1)
        m_glob = jax.lax.pmax(m_loc, "model")
        m_safe = jnp.where(jnp.isinf(m_glob), 0.0, m_glob)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(valid, p, 0.0)
        l_glob = jax.lax.psum(p.sum(axis=-1), "model")
        acc = jnp.einsum("bhgqs,bshd->bhgqd", p.astype(jnp.float32),
                         vv.astype(jnp.float32))
        acc = jax.lax.psum(acc, "model")
        out = (acc / jnp.maximum(l_glob, 1e-20)[..., None]).astype(qb.dtype)
        return out.reshape(Bl, Hq_, one, D_), kc2, vc2

    qspec = P(bspec, None, None, None)
    cspec = P(bspec, "model", None, None)
    out, k_new, v_new = shard_map_compat(
        local_fn, mesh=mesh,
        in_specs=(qspec, qspec, qspec, cspec, cspec, P(), P()),
        out_specs=(qspec, cspec, cspec))(
        q, k_tok, v_tok, cache["k"], cache["v"], slot, filled)
    return out, {"k": k_new, "v": v_new}


def attention_decode(params, x, cache, index, cfg, *,
                     window: Optional[int] = None, impl: str = "reference"):
    """One-token decode. x: (B, 1, d). cache: {'k','v'} (B, S, Hkv, D).
    ``index``: scalar int32 — number of tokens already in the cache.
    Returns (y, new_cache). With a sliding window the cache is a ring buffer
    of size min(window, S). ``impl="pallas"`` streams the un-expanded GQA
    cache through the flash-decode kernel (one read serves each query
    group); the jnp path uses the same grouped layout via einsum."""
    B = x.shape[0]
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    S = cache["k"].shape[1]
    q = jnp.einsum("bsd,dhk->bhsk", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bhsk", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bhsk", x, params["wv"].astype(x.dtype))
    pos = jnp.full((1,), index, jnp.int32)
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    slot = index % S if window is not None else index
    filled = jnp.minimum(index + 1, S)
    axes = kv_cache_axes(cfg)
    rules = current_rules()
    if (axes[1] == "kv_seq" and rules is not None
            and getattr(rules, "kv_seq_shard", False)
            and "model" in rules.mesh.shape
            and S % rules.mesh.shape["model"] == 0
            and not isinstance(rules.mesh, jax.sharding.AbstractMesh)):
        # sequence-sharded cache: distributed flash-decode (§Perf/P2)
        out, new_cache = _decode_attn_kvseq_sharded(
            rules, q, k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
            cache, slot, filled, hq // hkv)
        # re-shard the (tiny) attention output on heads so the wo einsum
        # stays local to the model axis instead of gathering wo itself
        out = constrain(out, ("batch", "heads", None, None))
        y = jnp.einsum("bhsk,hkd->bsd", out, params["wo"].astype(x.dtype))
        return constrain(y, ("batch", None, "embed")), new_cache
    k_new = jax.lax.dynamic_update_slice(
        cache["k"], k.transpose(0, 2, 1, 3).astype(cache["k"].dtype), (0, slot, 0, 0))
    v_new = jax.lax.dynamic_update_slice(
        cache["v"], v.transpose(0, 2, 1, 3).astype(cache["v"].dtype), (0, slot, 0, 0))
    k_new = constrain(k_new, axes)
    v_new = constrain(v_new, axes)
    if impl == "pallas":
        # GQA-native flash-decode kernel streaming the cache in its
        # stored (B, S, Hkv, D) layout — no transposed copy is built
        from repro.kernels import ops as kops
        out = kops.flash_decode(q, k_new.astype(x.dtype),
                                v_new.astype(x.dtype), filled)
    else:
        # grouped attention over the un-expanded cache
        qg = q.reshape(B, hkv, hq // hkv, 1, hd)
        s = jnp.einsum("bhgqd,bshd->bhgqs", qg, k_new.astype(x.dtype),
                       preferred_element_type=jnp.float32) / jnp.sqrt(hd)
        valid = jnp.arange(S)[None, None, None, None, :] < filled
        s = jnp.where(valid, s, -jnp.inf)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bhgqs,bshd->bhgqd", p.astype(x.dtype),
                         v_new.astype(x.dtype)).reshape(B, hq, 1, hd)
    y = jnp.einsum("bhsk,hkd->bsd", out, params["wo"].astype(x.dtype))
    return constrain(y, ("batch", None, "embed")), {"k": k_new, "v": v_new}


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp_init(key, d_model: int, d_ff: int, dtype=jnp.bfloat16) -> Dict:
    ks = jax.random.split(key, 3)
    return {
        "wi_gate": dense_init(ks[0], (d_model, d_ff), ("embed", "ffn"), dtype),
        "wi_up": dense_init(ks[1], (d_model, d_ff), ("embed", "ffn"), dtype),
        "wo": dense_init(ks[2], (d_ff, d_model), ("ffn", "embed"), dtype),
    }


def mlp_apply(params, x):
    g = jnp.einsum("bsd,df->bsf", x, params["wi_gate"].astype(x.dtype))
    u = jnp.einsum("bsd,df->bsf", x, params["wi_up"].astype(x.dtype))
    h = jax.nn.silu(g) * u
    h = constrain(h, ("batch", None, "ffn"))
    y = jnp.einsum("bsf,fd->bsd", h, params["wo"].astype(x.dtype))
    return constrain(y, ("batch", None, "embed"))


# ---------------------------------------------------------------------------
# embeddings / head
# ---------------------------------------------------------------------------

def embedding_init(key, vocab: int, d_model: int, dtype=jnp.bfloat16):
    return {"table": dense_init(key, (vocab, d_model), ("vocab", "embed"),
                                dtype, scale=1.0)}


def embed(params, tokens):
    y = jnp.take(params["table"], tokens, axis=0)
    return constrain(y, ("batch", None, "embed"))


def logits(params, x):
    out = jnp.einsum("bsd,vd->bsv", x, params["table"].astype(x.dtype))
    return constrain(out, ("batch", None, "vocab"))
