"""Composable model builder: one entry point for all ten assigned
architectures plus the paper's own Llama/BERT evaluation models.

A config's resolved block list is factored into its repeating *pattern
unit*; parameters for each unit position are stacked across repeats and the
unit is applied under ``jax.lax.scan`` (MaxText-style) so 512-way dry-run
compiles stay small. Weight-shared blocks (zamba2) live outside the scan
and close over the unit body.

Public API:
  init_model(key, cfg)                  -> (params, logical_axes)
  forward(params, cfg, batch, ...)      -> (hidden, aux_loss)
  loss_fn(params, cfg, batch, ...)      -> (loss, metrics)
  init_decode_state(params, cfg, batch, max_len) -> cache
  prefill(params, cfg, batch, ...)      -> (last_logits, cache)
  decode_step(params, cfg, tokens, cache, ...) -> (logits, cache)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import (BLOCK_ATTN, BLOCK_MAMBA2, BLOCK_MLSTM,
                                BLOCK_MOE, BLOCK_SHARED_ATTN, BLOCK_SLSTM,
                                ModelConfig)
from repro.core.sharding import constrain
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S
from repro.models.param import dense_init, split, stack_layers

# ---------------------------------------------------------------------------
# pattern factoring
# ---------------------------------------------------------------------------

def pattern_unit(cfg: ModelConfig) -> Tuple[Tuple[str, ...], int]:
    kinds = cfg.blocks()
    n = len(kinds)
    for ulen in range(1, n + 1):
        if n % ulen:
            continue
        unit = kinds[:ulen]
        if unit * (n // ulen) == kinds:
            return unit, n // ulen
    return kinds, 1


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _block_init(key, kind: str, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 3)
    if kind == BLOCK_ATTN:
        return {"norm1": L.rmsnorm_init(cfg.d_model, dtype),
                "attn": L.attention_init(ks[0], cfg, dtype),
                "norm2": L.rmsnorm_init(cfg.d_model, dtype),
                "mlp": L.mlp_init(ks[1], cfg.d_model, cfg.d_ff, dtype)}
    if kind == BLOCK_MOE:
        return {"norm1": L.rmsnorm_init(cfg.d_model, dtype),
                "attn": L.attention_init(ks[0], cfg, dtype),
                "norm2": L.rmsnorm_init(cfg.d_model, dtype),
                "moe": M.moe_init(ks[1], cfg, dtype)}
    if kind == BLOCK_MLSTM:
        return {"norm": L.rmsnorm_init(cfg.d_model, dtype),
                "cell": S.mlstm_init(ks[0], cfg, dtype)}
    if kind == BLOCK_SLSTM:
        return {"norm": L.rmsnorm_init(cfg.d_model, dtype),
                "cell": S.slstm_init(ks[0], cfg, dtype)}
    if kind == BLOCK_MAMBA2:
        return {"norm": L.rmsnorm_init(cfg.d_model, dtype),
                "cell": S.mamba2_init(ks[0], cfg, dtype)}
    raise ValueError(kind)


def _encdec_extra_init(key, cfg: ModelConfig, dtype):
    """Encoder stack + cross-attention params for enc-dec (audio) archs."""
    ks = jax.random.split(key, 2 + cfg.encoder_layers)
    enc_layers = [_block_init(ks[2 + i], BLOCK_ATTN, cfg, dtype)
                  for i in range(cfg.encoder_layers)]
    return {
        "adapter": dense_init(ks[0], (cfg.d_model, cfg.d_model),
                              ("embed", "embed"), dtype),
        "enc": stack_layers(enc_layers),
        "enc_norm": L.rmsnorm_init(cfg.d_model, dtype),
    }


def init_model(key, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.param_dtype)
    unit, n_rep = pattern_unit(cfg)
    keys = jax.random.split(key, 8 + len(unit) * n_rep + cfg.n_layers)
    tree: Dict[str, Any] = {}
    tree["embed"] = L.embedding_init(keys[0], cfg.vocab_size, cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        tree["lm_head"] = L.embedding_init(keys[1], cfg.vocab_size, cfg.d_model, dtype)
    tree["final_norm"] = L.rmsnorm_init(cfg.d_model, dtype)

    kidx = 8
    stack: Dict[str, Any] = {}
    for pos, kind in enumerate(unit):
        if kind == BLOCK_SHARED_ATTN:
            continue
        per_rep = []
        for r in range(n_rep):
            per_rep.append(_block_init(keys[kidx], kind, cfg, dtype))
            kidx += 1
        stack[f"pos{pos}"] = stack_layers(per_rep)
    tree["stack"] = stack
    if BLOCK_SHARED_ATTN in unit:
        tree["shared"] = _block_init(keys[2], BLOCK_ATTN, cfg, dtype)
    if cfg.encoder_layers:
        tree["encdec"] = _encdec_extra_init(keys[3], cfg, dtype)
        # cross-attention per decoder unit position (decoder is uniform attn)
        cross = []
        for r in range(cfg.n_layers):
            cross.append({"norm": L.rmsnorm_init(cfg.d_model, dtype),
                          "attn": L.attention_init(keys[kidx], cfg, dtype)})
            kidx += 1
        tree["cross"] = stack_layers(cross)
    if cfg.num_image_tokens:
        tree["projector"] = {
            "w1": dense_init(keys[4], (cfg.frontend_dim, cfg.d_model),
                             (None, "embed"), dtype),
            "w2": dense_init(keys[5], (cfg.d_model, cfg.d_model),
                             ("embed", "embed"), dtype),
        }
    return split(tree)


# ---------------------------------------------------------------------------
# block application (full sequence)
# ---------------------------------------------------------------------------

def _apply_block(kind: str, bp, x, cfg, *, window, impl, enc_out=None,
                 cross_p=None, positions=None, segment_ids=None):
    """Returns (x, aux)."""
    aux = jnp.zeros((), jnp.float32)
    if kind in (BLOCK_ATTN, BLOCK_SHARED_ATTN, BLOCK_MOE):
        h = L.rmsnorm(bp["norm1"], x, cfg.norm_eps, impl=impl)
        mode = "causal" if cfg.causal else "full"
        x = x + L.attention_apply(bp["attn"], h, cfg, positions=positions,
                                  mask_mode=mode, window=window, impl=impl,
                                  segment_ids=segment_ids)
        if cross_p is not None:
            h = L.rmsnorm(cross_p["norm"], x, cfg.norm_eps, impl=impl)
            x = x + L.attention_apply(cross_p["attn"], h, cfg,
                                      mask_mode="full", impl=impl,
                                      kv_override=(enc_out,))
        h = L.rmsnorm(bp["norm2"], x, cfg.norm_eps, impl=impl)
        if kind == BLOCK_MOE:
            y, aux = M.moe_apply(bp["moe"], h, cfg)
            x = x + y
        else:
            x = x + L.mlp_apply(bp["mlp"], h)
        return x, aux
    h = L.rmsnorm(bp["norm"], x, cfg.norm_eps, impl=impl)
    if kind == BLOCK_MLSTM:
        x = x + S.mlstm_apply(bp["cell"], h, cfg)
    elif kind == BLOCK_SLSTM:
        x = x + S.slstm_apply(bp["cell"], h, cfg)
    elif kind == BLOCK_MAMBA2:
        x = x + S.mamba2_apply(bp["cell"], h, cfg, impl=impl)
    else:
        raise ValueError(kind)
    return x, aux


def _run_stack(params, x, cfg, *, window, impl, enc_out=None,
               unroll: bool = False, stream=None, positions=None,
               segment_ids=None):
    unit, n_rep = pattern_unit(cfg)
    shared = params.get("shared")
    cross = params.get("cross")  # (layers,...) stacked — only for uniform attn decoders

    def unit_body(carry, xs):
        x, aux = carry
        stack_slice, cross_slice = xs
        for pos, kind in enumerate(unit):
            bp = shared if kind == BLOCK_SHARED_ATTN else stack_slice[f"pos{pos}"]
            cp = None
            if cross_slice is not None and kind in (BLOCK_ATTN, BLOCK_MOE):
                cp = cross_slice
            x, a = _apply_block(kind, bp, x, cfg, window=window, impl=impl,
                                enc_out=enc_out, cross_p=cp,
                                positions=positions, segment_ids=segment_ids)
            aux = aux + a
        return (x, aux), None

    body = unit_body
    if cfg.remat:
        body = jax.checkpoint(unit_body, prevent_cse=False)

    if cross is not None:
        # decoder with cross attention: unit length is 1 (uniform attn)
        n_scan = cfg.n_layers // len(unit)
        xs = (params["stack"], cross)
    else:
        n_scan = n_rep
        xs = (params["stack"], None)
    if stream is not None:
        # Scheduled ZeRO-3 (core/overlap.py): `xs` leaves are this
        # device's parameter *shards*; each scan step consumes the full
        # layer params from `stream.gather` (all-gather fwd, per-layer
        # reduce-scatter bwd via its custom VJP).
        return _run_stack_streamed(unit_body, xs, x, cfg, n_scan, stream)
    if unroll:
        # python loop (dry-run cost pass: XLA cost_analysis does not
        # multiply while-loop bodies by trip count)
        carry = (x, jnp.zeros((), jnp.float32))
        for i in range(n_scan):
            xs_i = jax.tree.map(lambda v: v[i], xs)
            carry, _ = unit_body(carry, xs_i)
        return carry
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), xs,
                               length=n_scan)
    return x, aux


def _run_stack_streamed(unit_body, xs, x, cfg, n_scan: int, stream):
    """Layer scan over *sharded* stacked params, gathered layer-by-layer.

    ``stream.prefetch``: two-deep software pipeline — the carry holds the
    gathered params of the layer being computed while the next layer's
    all-gather is already issued (layer ``l+1`` prefetched under layer
    ``l``'s compute; remat wraps only the compute, so the backward reuses
    the saved gather). Without prefetch the gather sits *inside* the
    remat region: residuals stay sharded and the backward re-gathers
    (AG-fwd + AG-bwd + RS, the memory-light classic ZeRO-3 schedule).
    """
    def take(i):
        return jax.tree.map(
            lambda v: jax.lax.dynamic_index_in_dim(v, i, 0, keepdims=False),
            xs)

    aux0 = jnp.zeros((), jnp.float32)
    if stream.prefetch:
        compute = unit_body
        if cfg.remat:
            compute = jax.checkpoint(unit_body, prevent_cse=False)

        def body(carry, i):
            (x, aux), cur = carry
            nxt = stream.gather(take(i + 1))
            (x, aux), _ = compute((x, aux), cur)
            return ((x, aux), nxt), None

        # final iteration peeled: its params were prefetched by step
        # n_scan-2, and no step issues a gather past the last layer —
        # exactly n_scan all-gathers per sweep
        first = stream.gather(take(0))
        ((x, aux), last), _ = jax.lax.scan(body, ((x, aux0), first),
                                           jnp.arange(n_scan - 1))
        (x, aux), _ = compute((x, aux), last)
        return x, aux

    def gathered_body(carry, shard_slice):
        return unit_body(carry, stream.gather(shard_slice))

    inner = gathered_body
    if cfg.remat:
        inner = jax.checkpoint(gathered_body, prevent_cse=False)

    def body(carry, i):
        carry, _ = inner(carry, take(i))
        return carry, None

    (x, aux), _ = jax.lax.scan(body, (x, aux0), jnp.arange(n_scan))
    return x, aux


# ---------------------------------------------------------------------------
# forward / loss
# ---------------------------------------------------------------------------

def _embed_inputs(params, cfg, batch, impl):
    tokens = batch["tokens"]
    x = L.embed(params["embed"], tokens)
    if cfg.num_image_tokens:
        img = batch["image_embeds"].astype(x.dtype)         # (B,Nimg,frontend)
        p = jnp.einsum("bnf,fd->bnd", img, params["projector"]["w1"].astype(x.dtype))
        p = jax.nn.gelu(p)
        p = jnp.einsum("bnd,de->bne", p, params["projector"]["w2"].astype(x.dtype))
        n = cfg.num_image_tokens
        x = jnp.concatenate([p.astype(x.dtype), x[:, n:]], axis=1)
    return x


def _encode(params, cfg, batch, impl, unroll: bool = False):
    frames = batch["frames"].astype(jnp.dtype(cfg.dtype))   # (B,S_enc,d)
    x = jnp.einsum("bsd,de->bse", frames, params["encdec"]["adapter"].astype(frames.dtype))
    enc_cfg = cfg

    def enc_body(carry, bp):
        h, _ = carry
        h, _ = _apply_block(BLOCK_ATTN, bp, h, enc_cfg, window=None, impl=impl)
        return (h, jnp.zeros((), jnp.float32)), None

    if unroll:
        carry = (x, jnp.zeros((), jnp.float32))
        for i in range(cfg.encoder_layers):
            bp = jax.tree.map(lambda v: v[i], params["encdec"]["enc"])
            carry, _ = enc_body(carry, bp)
        x = carry[0]
        return L.rmsnorm(params["encdec"]["enc_norm"], x, cfg.norm_eps,
                         impl=impl)
    body = jax.checkpoint(enc_body, prevent_cse=False) if cfg.remat else enc_body
    (x, _), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                             params["encdec"]["enc"])
    return L.rmsnorm(params["encdec"]["enc_norm"], x, cfg.norm_eps, impl=impl)


def forward(params, cfg: ModelConfig, batch: Dict, *, window=None,
            impl: str = "reference", unroll: bool = False, stream=None):
    """Returns (final hidden states (B,S,d), aux_loss). ``stream`` (a
    core/overlap.LayerStream) switches the layer scan to gathered-from-
    shards streaming for the scheduled ZeRO-3 path. Packed batches carry
    ``segment_ids`` (B,S) int32 (0 = pad) and per-document-reset
    ``positions`` (B,S) int32; both thread into every attention block."""
    enc_out = (_encode(params, cfg, batch, impl, unroll=unroll)
               if cfg.encoder_layers else None)
    x = _embed_inputs(params, cfg, batch, impl)
    x, aux = _run_stack(params, x, cfg, window=window, impl=impl,
                        enc_out=enc_out, unroll=unroll, stream=stream,
                        positions=batch.get("positions"),
                        segment_ids=batch.get("segment_ids"))
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps, impl=impl)
    return x, aux


def lm_logits(params, cfg, hidden):
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    return L.logits(head, hidden)


def loss_terms(params, cfg: ModelConfig, batch: Dict, *, window=None,
               impl: str = "reference", unroll: bool = False, stream=None):
    """Unnormalized loss pieces: ``{"nll": Σ masked nll, "tokens": Σ mask,
    "aux": aux loss}``. The scheduled ZeRO-3 step consumes these raw sums
    so the cross-device token normalization can happen outside the
    differentiated region (see core/overlap.py)."""
    hidden, aux = forward(params, cfg, batch, window=window, impl=impl,
                          unroll=unroll, stream=stream)
    logits = lm_logits(params, cfg, hidden)
    labels = batch["labels"]
    mask = batch.get("loss_mask")
    if mask is None:
        mask = jnp.ones(labels.shape, jnp.float32)
    logits_f = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits_f, axis=-1)
    gold = jnp.take_along_axis(logits_f, labels[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    return {"nll": nll.sum(), "tokens": mask.sum(), "aux": aux}


def loss_fn(params, cfg: ModelConfig, batch: Dict, *, window=None,
            impl: str = "reference", unroll: bool = False):
    """Masked token cross-entropy. batch: tokens, labels, loss_mask."""
    t = loss_terms(params, cfg, batch, window=window, impl=impl,
                   unroll=unroll)
    denom = jnp.maximum(t["tokens"], 1.0)
    loss = t["nll"] / denom
    return loss + t["aux"], {"loss": loss, "aux": t["aux"],
                             "tokens": t["tokens"]}


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------

def _cache_for(kind: str, cfg, batch: int, max_len: int):
    if kind in (BLOCK_ATTN, BLOCK_SHARED_ATTN, BLOCK_MOE):
        w = cfg.sliding_window
        size = min(max_len, w) if w else max_len
        return L.attention_init_cache(cfg, batch, size)
    if kind == BLOCK_MLSTM:
        return S.mlstm_init_state(cfg, batch)
    if kind == BLOCK_SLSTM:
        return S.slstm_init_state(cfg, batch)
    if kind == BLOCK_MAMBA2:
        return S.mamba2_init_state(cfg, batch)
    raise ValueError(kind)


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int,
                      enc_out: Optional[jnp.ndarray] = None) -> Dict:
    unit, n_rep = pattern_unit(cfg)
    caches: Dict[str, Any] = {}
    for pos, kind in enumerate(unit):
        if kind == BLOCK_SHARED_ATTN:
            # one cache per occurrence, stacked over repeats
            caches[f"pos{pos}"] = jax.tree.map(
                lambda c: jnp.stack([c] * n_rep), _cache_for(kind, cfg, batch, max_len))
        else:
            caches[f"pos{pos}"] = jax.tree.map(
                lambda c: jnp.stack([c] * n_rep), _cache_for(kind, cfg, batch, max_len))
    state = {"layers": caches, "index": jnp.zeros((), jnp.int32)}
    if enc_out is not None:
        state["enc_out"] = enc_out
    return state


def _apply_block_decode(kind, bp, x, cache, index, cfg, *, window, enc_out=None,
                        cross_p=None, impl: str = "reference"):
    if kind in (BLOCK_ATTN, BLOCK_SHARED_ATTN, BLOCK_MOE):
        h = L.rmsnorm(bp["norm1"], x, cfg.norm_eps)
        y, cache = L.attention_decode(bp["attn"], h, cache, index, cfg,
                                      window=window, impl=impl)
        x = x + y
        if cross_p is not None:
            h = L.rmsnorm(cross_p["norm"], x, cfg.norm_eps)
            x = x + L.attention_apply(cross_p["attn"], h, cfg, mask_mode="full",
                                      kv_override=(enc_out,))
        h = L.rmsnorm(bp["norm2"], x, cfg.norm_eps)
        if kind == BLOCK_MOE:
            y, _ = M.moe_apply(bp["moe"], h, cfg)
            x = x + y
        else:
            x = x + L.mlp_apply(bp["mlp"], h)
        return x, cache
    h = L.rmsnorm(bp["norm"], x, cfg.norm_eps)
    if kind == BLOCK_MLSTM:
        y, cache = S.mlstm_decode(bp["cell"], h, cache, cfg)
    elif kind == BLOCK_SLSTM:
        y, cache = S.slstm_decode(bp["cell"], h, cache, cfg)
    elif kind == BLOCK_MAMBA2:
        y, cache = S.mamba2_decode(bp["cell"], h, cache, cfg)
    else:
        raise ValueError(kind)
    return x + y, cache


def decode_step(params, cfg: ModelConfig, tokens, state, *, window=None,
                unroll: bool = False, impl: str = "reference"):
    """tokens: (B,1) int32. Returns (logits (B,1,V), new state).
    ``impl="pallas"`` routes dense attention decode through the GQA-native
    flash-decode kernel."""
    unit, n_rep = pattern_unit(cfg)
    x = L.embed(params["embed"], tokens)
    index = state["index"]
    enc_out = state.get("enc_out")
    shared = params.get("shared")
    cross = params.get("cross")

    def unit_body(carry, xs):
        x = carry
        stack_slice, cache_slice, cross_slice, shared_cache = xs
        new_caches = {}
        for pos, kind in enumerate(unit):
            bp = shared if kind == BLOCK_SHARED_ATTN else stack_slice[f"pos{pos}"]
            cache = cache_slice[f"pos{pos}"]
            cp = cross_slice if (cross_slice is not None and
                                 kind in (BLOCK_ATTN, BLOCK_MOE)) else None
            x, cache = _apply_block_decode(kind, bp, x, cache, index, cfg,
                                           window=window, enc_out=enc_out,
                                           cross_p=cp, impl=impl)
            new_caches[f"pos{pos}"] = cache
        return x, new_caches

    xs = (params["stack"], state["layers"], cross, None)
    if unroll:
        n_scan = jax.tree.leaves(params["stack"])[0].shape[0]
        caches_out = []
        for i in range(n_scan):
            xs_i = jax.tree.map(lambda v: v[i], xs)
            x, nc = unit_body(x, xs_i)
            caches_out.append(nc)
        new_caches = jax.tree.map(lambda *ls: jnp.stack(ls), *caches_out)
    else:
        x, new_caches = jax.lax.scan(unit_body, x, xs)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = lm_logits(params, cfg, x)
    new_state = dict(state)
    new_state["layers"] = new_caches
    new_state["index"] = index + 1
    return logits, new_state


def decode_state_axes(cfg: ModelConfig, state) -> Dict:
    """Logical-axis tree matching init_decode_state's structure (used by the
    launcher to build decode-cache shardings)."""
    unit, n_rep = pattern_unit(cfg)

    def attn_axes(leaf_ndim):
        base = L.kv_cache_axes(cfg)
        return ("layers",) + base

    caches = {}
    for pos, kind in enumerate(unit):
        if kind in (BLOCK_ATTN, BLOCK_SHARED_ATTN, BLOCK_MOE):
            caches[f"pos{pos}"] = {"k": attn_axes(5), "v": attn_axes(5)}
        elif kind == BLOCK_MAMBA2:
            caches[f"pos{pos}"] = {
                "ssm": ("layers", "batch", "ssm_heads", None, None),
                "conv": ("layers", "batch", None, "ffn")}
        elif kind == BLOCK_MLSTM:
            caches[f"pos{pos}"] = {
                "mlstm": (("layers", "batch", "heads", None, None),
                          ("layers", "batch", "heads", None),
                          ("layers", "batch", "heads")),
                "conv": ("layers", "batch", None, "ffn")}
        elif kind == BLOCK_SLSTM:
            caches[f"pos{pos}"] = tuple(
                ("layers", "batch", "heads", None) for _ in range(4))
    axes = {"layers": caches, "index": ()}
    if "enc_out" in state:
        axes["enc_out"] = ("batch", None, "embed")
    return axes


def prefill(params, cfg: ModelConfig, batch: Dict, *, window=None,
            impl: str = "reference", unroll: bool = False):
    """Compute hidden states over the prompt; return last-token logits.

    (The production serve path would also populate the KV cache during
    prefill; the dry-run prefill measures the forward compute, and decode
    shapes measure steady-state token generation.)"""
    hidden, _ = forward(params, cfg, batch, window=window, impl=impl,
                        unroll=unroll)
    last = hidden[:, -1:]
    return lm_logits(params, cfg, last)
