"""Top-k Mixture-of-Experts FFN with GShard-style dispatch/combine einsums.

TPU-native expert parallelism: experts shard over the ``model`` mesh axis;
the dispatch one-hot einsum becomes an all-to-all under SPMD partitioning.
Capacity-factor based (tokens over capacity are dropped, their residual
passes through — standard GShard/Switch semantics). Aux load-balance loss
is returned so the trainer can add it.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.sharding import constrain
from repro.models.param import dense_init


def moe_init(key, cfg, dtype=jnp.bfloat16) -> Dict:
    d, m = cfg.d_model, cfg.moe
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], (d, m.num_experts), ("embed", "experts"),
                             jnp.float32),
        "wi_gate": dense_init(ks[1], (m.num_experts, d, m.d_expert),
                              ("experts", "embed", "ffn"), dtype),
        "wi_up": dense_init(ks[2], (m.num_experts, d, m.d_expert),
                            ("experts", "embed", "ffn"), dtype),
        "wo": dense_init(ks[3], (m.num_experts, m.d_expert, d),
                         ("experts", "ffn", "embed"), dtype),
    }


def _capacity(tokens_per_group: int, num_experts: int, top_k: int,
              capacity_factor: float) -> int:
    c = int(math.ceil(tokens_per_group * top_k / num_experts * capacity_factor))
    return max(c, 1)


def _router(params, x, cfg):
    """Shared routing: (gate_vals, gate_idx, aux). gate renormalized."""
    m = cfg.moe
    E, K = m.num_experts, m.top_k
    router_logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                               params["router"])
    probs = jax.nn.softmax(router_logits, axis=-1)            # (B,S,E)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)             # (B,S,K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)
    me = probs.mean(axis=(0, 1))
    one_hot_top1 = jax.nn.one_hot(gate_idx[..., 0], E)
    ce = one_hot_top1.mean(axis=(0, 1))
    aux = E * jnp.sum(me * ce) * m.aux_loss_weight
    return gate_vals, gate_idx, aux


def moe_apply_ragged(params, x, cfg) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Dropless sorted dispatch via lax.ragged_dot (§Perf/P1 iter 2).

    Per batch row: sort the (S*K) expert assignments, gather tokens into
    expert-contiguous order, run the three FFN matmuls as ragged group
    matmuls, and scatter-add the gated results back. No capacity buffers,
    no one-hot einsums — bytes scale with S*K*d instead of S*E*C*d, and
    FLOPs are exactly tokens*K*(FFN flops). Stays local to each batch
    row, so the data-axis sharding is preserved (no cross-device gather).
    """
    m = cfg.moe
    B, S, d = x.shape
    E, K = m.num_experts, m.top_k
    gate_vals, gate_idx, aux = _router(params, x, cfg)

    NK = S * K
    flat_e = gate_idx.reshape(B, NK)
    sort_i = jnp.argsort(flat_e, axis=1)                      # (B,NK)
    tok_i = sort_i // K                                       # (B,NK)
    xs = jnp.take_along_axis(x, tok_i[..., None], axis=1)     # (B,NK,d)
    gs = jax.vmap(lambda fe: jnp.bincount(fe, length=E))(flat_e)

    def rd(lhs, w):
        wb = jnp.broadcast_to(w.astype(lhs.dtype), (B,) + w.shape)
        return jax.vmap(jax.lax.ragged_dot)(lhs, wb, gs)

    g = rd(xs, params["wi_gate"])
    u = rd(xs, params["wi_up"])
    h = jax.nn.silu(g) * u
    h = constrain(h, ("batch", None, "ffn"))
    ys = rd(h, params["wo"])                                  # (B,NK,d)
    w = jnp.take_along_axis(gate_vals.reshape(B, NK), sort_i, axis=1)
    ys = (ys.astype(jnp.float32) * w[..., None]).astype(x.dtype)

    def scatter_add(tok, val):
        return jnp.zeros((S, d), val.dtype).at[tok].add(val)

    y = jax.vmap(scatter_add)(tok_i, ys)
    return constrain(y, ("batch", None, "embed")), aux


def _onehot_dispatch(gate_vals, gate_idx, E, C, ddtype, cdtype):
    """(dispatch, combine) one-hots for capacity-C buffers.
    gate_vals/gate_idx: (B, S, K)."""
    B, S, K = gate_idx.shape
    dispatch = jnp.zeros((B, S, E, C), ddtype)
    combine = jnp.zeros((B, S, E, C), jnp.dtype(cdtype))
    counts = jnp.zeros((B, E), jnp.int32)
    for j in range(K):
        sel = jax.nn.one_hot(gate_idx[..., j], E, dtype=jnp.int32)
        pos = jnp.cumsum(sel, axis=1) - 1 + counts[:, None, :]
        keep = (pos < C) & (sel > 0)
        pos_oh = jax.nn.one_hot(jnp.where(keep, pos, -1), C, dtype=ddtype)
        slot = sel.astype(ddtype)[..., None] * pos_oh
        dispatch = dispatch + slot
        combine = combine + (gate_vals[..., j][..., None, None]
                             * slot.astype(jnp.float32)).astype(combine.dtype)
        counts = counts + sel.sum(axis=1)
    return dispatch, combine


def moe_apply_a2a(params, x, cfg) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Explicit expert parallelism with all_to_all (§Perf/P1 iter 4).

    Tokens shard over (batch x sequence); experts shard over `model`.
    Each model-shard routes its local tokens into per-expert capacity
    buffers, an all_to_all swaps the (dest-shard, ...) blocks so every
    shard receives exactly the tokens its local experts must compute,
    and a second all_to_all returns the results — the production EP
    schedule (GShard/MaxText) instead of letting SPMD rewrite the
    dispatch einsums into all-gather + all-reduce.

    Falls back to the gshard path when no mesh is active (CPU tests),
    when S doesn't divide the model axis, or when E doesn't.
    """
    import math as _math
    from repro.core.sharding import current_rules
    from jax.sharding import PartitionSpec as P

    rules = current_rules()
    m = cfg.moe
    B, S, d = x.shape
    E, K = m.num_experts, m.top_k
    if (rules is None or "model" not in rules.mesh.shape
            or isinstance(rules.mesh, jax.sharding.AbstractMesh)):
        return _moe_apply_gshard(params, x, cfg)
    n = rules.mesh.shape["model"]
    if S % n or E % n or n == 1:
        return _moe_apply_gshard(params, x, cfg)
    E_loc, S_loc = E // n, S // n
    C = max(int(_math.ceil(S_loc * K / E * m.capacity_factor)), 1)

    gate_vals, gate_idx, aux = _router(params, x, cfg)
    f = params["wi_gate"].shape[-1]
    bspec = rules.activation_spec(("batch", None, None), x.shape)[0]

    def local_fn(xl, gv, gi, wg, wu, wo):
        # xl: (B_l, S_loc, d); gv/gi: (B_l, S_loc, K);
        # wg/wu: (E_loc, d, f); wo: (E_loc, f, d)
        Bl = xl.shape[0]
        dispatch, combine = _onehot_dispatch(gv, gi, E, C, xl.dtype,
                                             m.combine_dtype)
        xe = jnp.einsum("bsec,bsd->ebcd", dispatch, xl)       # (E,B_l,C,d)
        xe = xe.reshape(n, E_loc * Bl * C, d)
        # swap (dest-shard) blocks: afterwards dim0 = source shard and
        # the E_loc experts are THIS shard's experts
        xr = jax.lax.all_to_all(xe, "model", split_axis=0, concat_axis=0)
        xr = xr.reshape(n, E_loc, Bl, C, d)
        g = jnp.einsum("nebcd,edf->nebcf", xr, wg.astype(xr.dtype))
        u = jnp.einsum("nebcd,edf->nebcf", xr, wu.astype(xr.dtype))
        h = jax.nn.silu(g) * u
        ye = jnp.einsum("nebcf,efd->nebcd", h, wo.astype(xr.dtype))
        ye = ye.reshape(n, E_loc * Bl * C, d)
        yb = jax.lax.all_to_all(ye, "model", split_axis=0, concat_axis=0)
        yb = yb.reshape(E, Bl, C, d)
        y = jnp.einsum("bsec,ebcd->bsd", combine.astype(xl.dtype), yb)
        return y

    xspec = P(bspec, "model", None)
    gspec = P(bspec, "model", None)
    wspec = P("model", None, None)
    from repro.core.sharding import shard_map_compat
    y = shard_map_compat(
        local_fn, mesh=rules.mesh,
        in_specs=(xspec, gspec, gspec, wspec, wspec, wspec),
        out_specs=xspec)(
        x, gate_vals, gate_idx, params["wi_gate"], params["wi_up"],
        params["wo"])
    return constrain(y, ("batch", None, "embed")), aux


def moe_apply(params, x, cfg) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Dispatch on cfg.moe.impl: gshard (default), ragged, a2a."""
    if cfg.moe.impl == "ragged":
        return moe_apply_ragged(params, x, cfg)
    if cfg.moe.impl == "a2a":
        return moe_apply_a2a(params, x, cfg)
    return _moe_apply_gshard(params, x, cfg)


def _moe_apply_gshard(params, x, cfg) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d). Returns (y, aux_loss).

    With ``cfg.moe.group_size = g`` set (and g < S, g | S) the sequence is
    re-grouped to (B*S/g, g, d) before dispatch so the capacity-buffer
    tensors scale with g, not S — identical routing semantics per token
    (router is pointwise; groups are equal-sized so the aux loss mean is
    unchanged), but the (tokens, E, C) dispatch/combine footprint drops
    by ~S/g. §Perf/P1."""
    m = cfg.moe
    B0, S0, d = x.shape
    g = m.group_size
    if g and g < S0 and S0 % g == 0:
        x = x.reshape(B0 * (S0 // g), g, d)
    B, S, _ = x.shape
    E, K = m.num_experts, m.top_k
    C = _capacity(S, E, K, m.capacity_factor)

    gate_vals, gate_idx, aux = _router(params, x, cfg)

    # --- positions within expert buffers, per sequence group ---
    dispatch, combine = _onehot_dispatch(gate_vals, gate_idx, E, C, x.dtype,
                                         m.combine_dtype)
    dispatch = constrain(dispatch, ("batch", None, "experts", None))
    combine = constrain(combine, ("batch", None, "experts", None))

    # --- dispatch -> batched expert FFN -> combine ---
    xe = jnp.einsum("bsec,bsd->ebcd", dispatch, x)            # (E,B,C,d)
    xe = constrain(xe, ("experts", "batch", None, None))
    g = jnp.einsum("ebcd,edf->ebcf", xe, params["wi_gate"].astype(x.dtype))
    u = jnp.einsum("ebcd,edf->ebcf", xe, params["wi_up"].astype(x.dtype))
    h = jax.nn.silu(g) * u
    h = constrain(h, ("experts", "batch", None, "ffn"))
    ye = jnp.einsum("ebcf,efd->ebcd", h, params["wo"].astype(x.dtype))
    y = jnp.einsum("bsec,ebcd->bsd", combine.astype(x.dtype), ye)
    y = y.reshape(B0, S0, d)
    return constrain(y, ("batch", None, "embed")), aux
