"""Parameter trees with logical-axis annotations.

Model ``init`` functions build nested dicts whose leaves are
``Annotated(value, axes)``; :func:`split` separates them into a value tree
(what jax transforms see) and an axes tree (what the sharding planner sees).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class Annotated:
    value: Any
    axes: Tuple[Optional[str], ...]

    def __post_init__(self):
        if hasattr(self.value, "shape"):
            assert len(self.axes) == len(self.value.shape), (
                f"axes {self.axes} do not match shape {self.value.shape}")


def is_annotated(x) -> bool:
    return isinstance(x, Annotated)


def split(tree):
    """(annotated tree) -> (values, axes) as parallel pytrees."""
    values = jax.tree.map(lambda a: a.value, tree, is_leaf=is_annotated)
    axes = jax.tree.map(lambda a: a.axes, tree, is_leaf=is_annotated)
    return values, axes


def dense_init(key, shape, axes, dtype=jnp.bfloat16, scale: Optional[float] = None) -> Annotated:
    fan_in = shape[0] if len(shape) > 1 else max(shape[0], 1)
    if scale is None:
        scale = 1.0 / np.sqrt(fan_in)
    v = (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)
    return Annotated(v, tuple(axes))


def zeros_init(shape, axes, dtype=jnp.bfloat16) -> Annotated:
    return Annotated(jnp.zeros(shape, dtype), tuple(axes))


def ones_init(shape, axes, dtype=jnp.bfloat16) -> Annotated:
    return Annotated(jnp.ones(shape, dtype), tuple(axes))


def const_init(value, axes) -> Annotated:
    return Annotated(value, tuple(axes))


def stack_layers(trees):
    """Stack per-layer param trees along a new leading 'layers' axis."""
    def _stack(*leaves):
        vals = [l.value for l in leaves]
        return Annotated(jnp.stack(vals, axis=0), ("layers",) + leaves[0].axes)
    return jax.tree.map(_stack, *trees, is_leaf=is_annotated)


def param_count(values_tree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(values_tree))
