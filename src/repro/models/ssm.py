"""Sequence-mixing blocks that are sub-quadratic in sequence length:

- Mamba2 (SSD, chunked scan) — zamba2 backbone;
- mLSTM (xLSTM matrix memory, chunkwise-parallel log-space form);
- sLSTM (xLSTM scalar memory, true recurrence via lax.scan).

All three expose a full-sequence ``*_apply`` (train/prefill) and a
single-token ``*_decode`` that carries a constant-size recurrent state —
this is what makes long_500k decode feasible for the ssm/hybrid archs.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.sharding import constrain
from repro.models.param import Annotated, const_init, dense_init, ones_init, zeros_init

# ===========================================================================
# Mamba2 (SSD)
# ===========================================================================

def mamba2_dims(cfg):
    d_inner = cfg.ssm.expand * cfg.d_model
    n_heads = d_inner // cfg.ssm.head_dim
    return d_inner, n_heads


def mamba2_init(key, cfg, dtype=jnp.bfloat16) -> Dict:
    d = cfg.d_model
    s = cfg.ssm
    d_inner, H = mamba2_dims(cfg)
    N = s.state_dim
    conv_ch = d_inner + 2 * N  # xc + B + C (ngroups = 1)
    ks = jax.random.split(key, 4)
    in_dim = 2 * d_inner + 2 * N + H  # z, xc, B, C, dt
    return {
        "w_in": dense_init(ks[0], (d, in_dim), ("embed", "ffn"), dtype),
        "conv_w": dense_init(ks[1], (s.conv_width, conv_ch), ("conv", "ffn"), dtype,
                             scale=0.5),
        "A_log": const_init(jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
                            ("ssm_heads",)),
        "D": ones_init((H,), ("ssm_heads",), jnp.float32),
        "dt_bias": zeros_init((H,), ("ssm_heads",), jnp.float32),
        "norm_scale": ones_init((d_inner,), ("ffn",), dtype),
        "w_out": dense_init(ks[2], (d_inner, d), ("ffn", "embed"), dtype),
    }


def _split_zxbcdt(z_all, cfg):
    d_inner, H = mamba2_dims(cfg)
    N = cfg.ssm.state_dim
    z, xc, Bm, Cm, dt = jnp.split(
        z_all, [d_inner, 2 * d_inner, 2 * d_inner + N, 2 * d_inner + 2 * N], axis=-1)
    return z, xc, Bm, Cm, dt


def _causal_conv(x, w, state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv. x: (B,S,Ch), w: (W,Ch). state: (B,W-1,Ch)."""
    W = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(W))
    new_state = xp[:, -(W - 1):] if W > 1 else pad
    return out, new_state


def _ssd_chunked(xh, dt, A, Bm, Cm, chunk: int, init_state=None):
    """Chunked selective-state-space scan (SSD).

    xh: (B,S,H,P) inputs; dt: (B,S,H) positive step sizes; A: (H,) negative;
    Bm/Cm: (B,S,N) input/output projections (ngroups=1).
    Returns (y: (B,S,H,P), final_state: (B,H,P,N)).
    """
    B_, S, H, P = xh.shape
    N = Bm.shape[-1]
    pad = (-S) % chunk
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    S_p = S + pad
    nc = S_p // chunk
    # chunked views: (nc, B, chunk, ...)
    def chunked(t):
        return t.reshape(B_, nc, chunk, *t.shape[2:]).swapaxes(0, 1)
    xc_, dtc, Bc, Cc = chunked(xh), chunked(dt), chunked(Bm), chunked(Cm)

    logdec = dtc * (-jnp.exp(A))[None, None, None, :]     # (nc,B,Q,H) negative
    cums = jnp.cumsum(logdec, axis=2)                      # within-chunk cumulative

    if init_state is None:
        init_state = jnp.zeros((B_, H, P, N), jnp.float32)

    def step(state, inp):
        xcb, dtb, Bb, Cb, cum, ld = inp                    # (B,Q,H,P) etc.
        # intra-chunk (quadratic within chunk)
        # decay from j to i: exp(cum_i - cum_j) for i>=j
        li = cum[:, :, None, :]                            # (B,Q,1,H)
        lj = cum[:, None, :, :]                            # (B,1,Q,H)
        mask = jnp.tril(jnp.ones((cum.shape[1], cum.shape[1]), bool))[None, :, :, None]
        # mask the *argument* before exp (double-where) so the cotangent of
        # masked entries is exactly zero rather than inf * 0 = NaN.
        arg = jnp.where(mask, li - lj, -1e30)
        dmat = jnp.where(mask, jnp.exp(arg), 0.0)          # (B,Q,Q,H)
        sc = jnp.einsum("bin,bjn->bij", Cb, Bb)            # (B,Q,Q)
        w = sc[..., None] * dmat                            # (B,Q,Q,H)
        xdt = xcb * dtb[..., None]                          # (B,Q,H,P)
        y_intra = jnp.einsum("bijh,bjhp->bihp", w, xdt.astype(jnp.float32))
        # inter-chunk: contribution of carried state
        dec_to_i = jnp.exp(cum)                             # (B,Q,H)
        y_inter = jnp.einsum("bin,bhpn,bih->bihp", Cb, state, dec_to_i)
        # state update: S' = S * exp(total) + sum_j exp(total - cum_j) B_j xdt_j
        total = cum[:, -1]                                  # (B,H)
        dec_from_j = jnp.exp(total[:, None] - cum)          # (B,Q,H)
        s_new = jnp.einsum("bjn,bjhp,bjh->bhpn", Bb, xdt.astype(jnp.float32),
                           dec_from_j)
        state = state * jnp.exp(total)[:, :, None, None] + s_new
        return state, (y_intra + y_inter)

    final_state, ys = jax.lax.scan(step, init_state, (xc_, dtc, Bc, Cc, cums, logdec))
    y = ys.swapaxes(0, 1).reshape(B_, S_p, H, P)[:, :S]
    return y, final_state


def mamba2_apply(params, x, cfg, init_state=None, conv_state=None,
                 return_state: bool = False, impl: str = "reference"):
    """Full-sequence Mamba2. x: (B,S,d). ``impl="pallas"`` routes the
    chunked SSD scan through the custom-VJP Pallas kernel on the
    stateless train path (stateful prefill/decode keeps the jnp scan,
    which threads the carried state)."""
    d_inner, H = mamba2_dims(cfg)
    N, P = cfg.ssm.state_dim, cfg.ssm.head_dim
    z_all = jnp.einsum("bsd,di->bsi", x, params["w_in"].astype(x.dtype))
    z, xc, Bm, Cm, dt = _split_zxbcdt(z_all, cfg)
    conv_in = jnp.concatenate([xc, Bm, Cm], axis=-1)
    conv_out, new_conv_state = _causal_conv(conv_in, params["conv_w"].astype(x.dtype),
                                            conv_state)
    conv_out = jax.nn.silu(conv_out)
    xc, Bm, Cm = jnp.split(conv_out, [d_inner, d_inner + N], axis=-1)
    xh = xc.reshape(*xc.shape[:2], H, P)
    xh = constrain(xh, ("batch", None, "ssm_heads", None))
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    if impl == "pallas" and init_state is None and not return_state:
        from repro.kernels import ops as kops
        y = kops.mamba_scan(xh, dtv, -jnp.exp(params["A_log"]),
                            Bm.astype(jnp.float32), Cm.astype(jnp.float32),
                            chunk=cfg.ssm.chunk_size).astype(jnp.float32)
        state = None
    else:
        y, state = _ssd_chunked(xh, dtv, params["A_log"],
                                Bm.astype(jnp.float32),
                                Cm.astype(jnp.float32), cfg.ssm.chunk_size,
                                init_state)
    y = y + xh.astype(jnp.float32) * params["D"][None, None, :, None]
    y = y.reshape(*x.shape[:2], d_inner).astype(x.dtype)
    # gated RMSNorm
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    yf = yf * jax.lax.rsqrt(var + 1e-5) * params["norm_scale"].astype(jnp.float32)
    out = jnp.einsum("bsi,id->bsd", yf.astype(x.dtype),
                     params["w_out"].astype(x.dtype))
    out = constrain(out, ("batch", None, "embed"))
    if return_state:
        return out, (state, new_conv_state)
    return out


def mamba2_init_state(cfg, batch: int):
    d_inner, H = mamba2_dims(cfg)
    N = cfg.ssm.state_dim
    conv_ch = d_inner + 2 * N
    return {
        "ssm": jnp.zeros((batch, H, cfg.ssm.head_dim, N), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm.conv_width - 1, conv_ch),
                          jnp.dtype(cfg.dtype)),
    }


def mamba2_decode(params, x, state, cfg):
    """One-token step. x: (B,1,d); state: {'ssm','conv'}."""
    d_inner, H = mamba2_dims(cfg)
    N, P = cfg.ssm.state_dim, cfg.ssm.head_dim
    z_all = jnp.einsum("bsd,di->bsi", x, params["w_in"].astype(x.dtype))
    z, xc, Bm, Cm, dt = _split_zxbcdt(z_all, cfg)
    conv_in = jnp.concatenate([xc, Bm, Cm], axis=-1)
    conv_out, conv_state = _causal_conv(conv_in, params["conv_w"].astype(x.dtype),
                                        state["conv"])
    conv_out = jax.nn.silu(conv_out)
    xc, Bm, Cm = jnp.split(conv_out, [d_inner, d_inner + N], axis=-1)
    xh = xc[:, 0].reshape(-1, H, P).astype(jnp.float32)       # (B,H,P)
    dtv = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"])  # (B,H)
    a = jnp.exp(dtv * (-jnp.exp(params["A_log"]))[None, :])   # (B,H)
    Bv, Cv = Bm[:, 0].astype(jnp.float32), Cm[:, 0].astype(jnp.float32)  # (B,N)
    s = state["ssm"] * a[:, :, None, None] + jnp.einsum(
        "bn,bhp,bh->bhpn", Bv, xh, dtv)
    y = jnp.einsum("bn,bhpn->bhp", Cv, s) + xh * params["D"][None, :, None]
    y = y.reshape(x.shape[0], 1, d_inner)
    yf = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    yf = yf * jax.lax.rsqrt(var + 1e-5) * params["norm_scale"].astype(jnp.float32)
    out = jnp.einsum("bsi,id->bsd", yf.astype(x.dtype), params["w_out"].astype(x.dtype))
    return out, {"ssm": s, "conv": conv_state}


# ===========================================================================
# mLSTM (xLSTM matrix memory) — chunkwise-parallel, log-space gates
# ===========================================================================

def mlstm_dims(cfg):
    d_inner = 2 * cfg.d_model
    dh = d_inner // cfg.n_heads
    return d_inner, dh


def mlstm_init(key, cfg, dtype=jnp.bfloat16) -> Dict:
    d = cfg.d_model
    d_inner, dh = mlstm_dims(cfg)
    H = cfg.n_heads
    ks = jax.random.split(key, 7)
    return {
        "w_up": dense_init(ks[0], (d, 2 * d_inner), ("embed", "ffn"), dtype),
        "conv_w": dense_init(ks[1], (4, d_inner), ("conv", "ffn"), dtype, scale=0.5),
        "wq": dense_init(ks[2], (d_inner, H, dh), ("ffn", "heads", None), dtype),
        "wk": dense_init(ks[3], (d_inner, H, dh), ("ffn", "heads", None), dtype),
        "wv": dense_init(ks[4], (d_inner, H, dh), ("ffn", "heads", None), dtype),
        "w_if": dense_init(ks[5], (d_inner, 2 * H), ("ffn", "heads"), jnp.float32),
        "if_bias": const_init(jnp.concatenate([
            jnp.zeros((H,), jnp.float32), 3.0 * jnp.ones((H,), jnp.float32)]),
            ("heads",)),
        "skip_scale": ones_init((d_inner,), ("ffn",), dtype),
        "norm_scale": ones_init((d_inner,), ("ffn",), dtype),
        "w_down": dense_init(ks[6], (d_inner, d), ("ffn", "embed"), dtype),
    }


def _mlstm_chunked(q, k, v, log_i, log_f, chunk: int, init_state=None):
    """Chunkwise mLSTM. q,k,v: (B,S,H,Dh); log_i/log_f: (B,S,H).

    Carries (C: (B,H,Dh,Dh), n: (B,H,Dh), m: (B,H)) across chunks — the
    running stabilizer m follows the xLSTM paper.
    """
    B, S, H, Dh = q.shape
    pad = (-S) % chunk
    if pad:
        pad4 = ((0, 0), (0, pad), (0, 0), (0, 0))
        q, k, v = (jnp.pad(t, pad4) for t in (q, k, v))
        log_i = jnp.pad(log_i, ((0, 0), (0, pad), (0, 0)), constant_values=-1e9)
        log_f = jnp.pad(log_f, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    nc = Sp // chunk

    def chunked(t):
        return t.reshape(B, nc, chunk, *t.shape[2:]).swapaxes(0, 1)
    qc, kc, vc, lic, lfc = map(chunked, (q, k, v, log_i, log_f))
    cumf = jnp.cumsum(lfc, axis=2)                           # (nc,B,Q,H)
    scale = 1.0 / jnp.sqrt(Dh)

    if init_state is None:
        C0 = jnp.zeros((B, H, Dh, Dh), jnp.float32)
        n0 = jnp.zeros((B, H, Dh), jnp.float32)
        m0 = jnp.full((B, H), -1e9, jnp.float32)
    else:
        C0, n0, m0 = init_state

    def step(carry, inp):
        C, n, m = carry
        qb, kb, vb, li, cf = inp                              # (B,Q,H,*) / (B,Q,H)
        # log weights: intra d[i,j] = cf_i - cf_j + li_j (j<=i); inter: cf_i + m
        dlog = cf[:, :, None, :] - cf[:, None, :, :] + li[:, None, :, :]
        mask = jnp.tril(jnp.ones((cf.shape[1], cf.shape[1]), bool))
        dlog = jnp.where(mask[None, :, :, None], dlog, -1e30)
        inter_log = cf + m[:, None, :]                        # (B,Q,H)
        m_new = jnp.maximum(dlog.max(axis=2), inter_log)      # (B,Q,H) per-row stab
        d = jnp.exp(dlog - m_new[:, :, None, :])              # (B,Q,Q,H)
        inter_w = jnp.exp(inter_log - m_new)                  # (B,Q,H)
        s = jnp.einsum("bihd,bjhd->bijh", qb.astype(jnp.float32),
                       kb.astype(jnp.float32)) * scale
        w = s * d
        h_intra = jnp.einsum("bijh,bjhd->bihd", w, vb.astype(jnp.float32))
        h_inter = jnp.einsum("bihd,bhde,bih->bihe", qb.astype(jnp.float32), C,
                             inter_w) * scale
        # normalizer n_t = sum_j decay_ij i_j k_j (vector, carried as `n`);
        # denom = max(|q . n|, exp(-m)). Linear in j *before* the abs, so
        # the result is invariant to the chunking (decode chunk=1 must equal
        # the train-time chunk=256 path exactly).
        qn_intra = w.sum(axis=2)                              # (B,Q,H)
        qn_inter = jnp.einsum("bihd,bhd,bih->bih", qb.astype(jnp.float32),
                              n, inter_w) * scale
        denom = jnp.maximum(jnp.abs(qn_intra + qn_inter), jnp.exp(-m_new))
        h = (h_intra + h_inter) / denom[..., None]
        # ---- state update to end of chunk ----
        total = cf[:, -1]                                     # (B,H)
        m_next = jnp.maximum(total + m, (total[:, None] - cf + li).max(axis=1))
        dec_j = jnp.exp(total[:, None] - cf + li - m_next[:, None])  # (B,Q,H)
        C_new = C * jnp.exp(total + m - m_next)[:, :, None, None] + jnp.einsum(
            "bjhd,bjhe,bjh->bhde", kb.astype(jnp.float32),
            vb.astype(jnp.float32), dec_j)
        n_new = n * jnp.exp(total + m - m_next)[:, :, None] + jnp.einsum(
            "bjhd,bjh->bhd", kb.astype(jnp.float32), dec_j)
        return (C_new, n_new, m_next), h

    (C, n, m), hs = jax.lax.scan(step, (C0, n0, m0), (qc, kc, vc, lic, cumf))
    h = hs.swapaxes(0, 1).reshape(B, Sp, H, Dh)[:, :S]
    return h, (C, n, m)


def mlstm_apply(params, x, cfg, init_state=None, return_state: bool = False):
    B, S, d = x.shape
    d_inner, dh = mlstm_dims(cfg)
    H = cfg.n_heads
    up = jnp.einsum("bsd,di->bsi", x, params["w_up"].astype(x.dtype))
    xi, zg = jnp.split(up, 2, axis=-1)
    conv_state = None if init_state is None else init_state.get("conv")
    xconv, new_conv = _causal_conv(xi, params["conv_w"].astype(x.dtype), conv_state)
    xconv = jax.nn.silu(xconv)
    q = jnp.einsum("bsi,ihd->bshd", xconv, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsi,ihd->bshd", xconv, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsi,ihd->bshd", xi, params["wv"].astype(x.dtype))
    q = constrain(q, ("batch", None, "heads", None))
    gates = jnp.einsum("bsi,ig->bsg", xconv.astype(jnp.float32), params["w_if"])
    gates = gates + params["if_bias"][None, None, :]
    log_i, log_f = jnp.split(gates, 2, axis=-1)               # (B,S,H)
    log_f = jax.nn.log_sigmoid(log_f)
    mstate = None if init_state is None else init_state.get("mlstm")
    h, new_m = _mlstm_chunked(q, k, v, log_i, log_f,
                              chunk=min(getattr(cfg, "mlstm_chunk", 256),
                                        max(S, 1)), init_state=mstate)
    h = h.reshape(B, S, d_inner).astype(x.dtype)
    h = h + params["skip_scale"].astype(x.dtype) * xconv
    hf = h.astype(jnp.float32)
    var = jnp.mean(hf * hf, axis=-1, keepdims=True)
    hf = hf * jax.lax.rsqrt(var + 1e-5) * params["norm_scale"].astype(jnp.float32)
    hf = hf * jax.nn.silu(zg.astype(jnp.float32))
    out = jnp.einsum("bsi,id->bsd", hf.astype(x.dtype), params["w_down"].astype(x.dtype))
    out = constrain(out, ("batch", None, "embed"))
    if return_state:
        return out, {"mlstm": new_m, "conv": new_conv}
    return out


def mlstm_init_state(cfg, batch: int):
    d_inner, dh = mlstm_dims(cfg)
    H = cfg.n_heads
    return {
        "mlstm": (jnp.zeros((batch, H, dh, dh), jnp.float32),
                  jnp.zeros((batch, H, dh), jnp.float32),
                  jnp.full((batch, H), -1e9, jnp.float32)),
        "conv": jnp.zeros((batch, 3, d_inner), jnp.dtype(cfg.dtype)),
    }


def mlstm_decode(params, x, state, cfg):
    out, new_state = mlstm_apply(params, x, cfg, init_state=state,
                                 return_state=True)
    return out, new_state


# ===========================================================================
# sLSTM (xLSTM scalar memory) — sequential scan
# ===========================================================================

def slstm_init(key, cfg, dtype=jnp.bfloat16) -> Dict:
    d = cfg.d_model
    H = cfg.n_heads
    dh = d // H
    ks = jax.random.split(key, 4)
    pf = 4 / 3
    d_ff = int(pf * d) // 64 * 64 or 64
    return {
        # input weights for z,i,f,o stacked: (d, 4, H, dh)
        "w_x": dense_init(ks[0], (d, 4, H, dh), ("embed", None, "heads", None), dtype),
        # block-diagonal recurrent weights per head: (4, H, dh, dh)
        "w_h": dense_init(ks[1], (4, H, dh, dh), (None, "heads", None, None), dtype,
                          scale=0.3),
        "bias": const_init(jnp.concatenate([
            jnp.zeros((3, H, dh), jnp.float32),
            jnp.ones((1, H, dh), jnp.float32)], axis=0), (None, "heads", None)),
        "norm_scale": ones_init((d,), ("embed",), dtype),
        "ffn_up": dense_init(ks[2], (d, 2 * d_ff), ("embed", "ffn"), dtype),
        "ffn_down": dense_init(ks[3], (d_ff, d), ("ffn", "embed"), dtype),
    }


def _slstm_scan(wx_terms, w_h, bias, h0, c0, n0, m0):
    """wx_terms: (B,S,4,H,dh) precomputed input contributions."""
    B, S = wx_terms.shape[:2]

    def step(carry, xt):
        h, c, n, m = carry                                    # (B,H,dh) each
        rec = jnp.einsum("bhd,ghde->bghe", h, w_h.astype(jnp.float32))
        pre = xt.astype(jnp.float32) + rec + bias[None]       # (B,4,H,dh)
        z = jnp.tanh(pre[:, 0])
        i_t = pre[:, 1]
        f_t = jax.nn.log_sigmoid(pre[:, 2])
        o = jax.nn.sigmoid(pre[:, 3])
        m_new = jnp.maximum(f_t + m, i_t)
        i_p = jnp.exp(i_t - m_new)
        f_p = jnp.exp(f_t + m - m_new)
        c_new = f_p * c + i_p * z
        n_new = jnp.maximum(f_p * n + i_p, 1e-6)
        h_new = o * c_new / n_new
        return (h_new, c_new, n_new, m_new), h_new

    (h, c, n, m), hs = jax.lax.scan(step, (h0, c0, n0, m0),
                                    wx_terms.swapaxes(0, 1))
    return hs.swapaxes(0, 1), (h, c, n, m)                    # (B,S,H,dh)


def slstm_apply(params, x, cfg, init_state=None, return_state: bool = False):
    B, S, d = x.shape
    H = cfg.n_heads
    dh = d // H
    wx = jnp.einsum("bsd,dghe->bsghe", x, params["w_x"].astype(x.dtype))
    if init_state is None:
        zer = jnp.zeros((B, H, dh), jnp.float32)
        init_state = (zer, zer, zer + 1e-6, zer - 1e9)
    hs, state = _slstm_scan(wx, params["w_h"], params["bias"], *init_state)
    y = hs.reshape(B, S, d).astype(x.dtype)
    yf = y.astype(jnp.float32)
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    y = (yf * jax.lax.rsqrt(var + 1e-5) * params["norm_scale"].astype(jnp.float32)
         ).astype(x.dtype)
    # gated FFN (proj factor 4/3 per xLSTM paper)
    up = jnp.einsum("bsd,df->bsf", y, params["ffn_up"].astype(x.dtype))
    a, b = jnp.split(up, 2, axis=-1)
    y = jnp.einsum("bsf,fd->bsd", jax.nn.silu(a) * b, params["ffn_down"].astype(x.dtype))
    y = constrain(y, ("batch", None, "embed"))
    if return_state:
        return y, state
    return y


def slstm_init_state(cfg, batch: int):
    H = cfg.n_heads
    dh = cfg.d_model // H
    zer = jnp.zeros((batch, H, dh), jnp.float32)
    return (zer, zer, zer + 1e-6, zer - 1e9)


def slstm_decode(params, x, state, cfg):
    return slstm_apply(params, x, cfg, init_state=state, return_state=True)
