"""AdamW with fp32 master weights — the optimizer whose state ZeRO
partitions. State layout (mu, nu, master) mirrors the parameter tree, so
the ZeRO sharding rules for a parameter apply leaf-wise to its state.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def adamw_init(params) -> Dict[str, Any]:
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(f32, params),
        "nu": jax.tree.map(f32, params),
        "master": jax.tree.map(lambda p: p.astype(jnp.float32), params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(grads, state, params, lr, cfg: AdamWConfig = AdamWConfig(),
                 gnorm: Optional[jnp.ndarray] = None
                 ) -> Tuple[Any, Dict[str, Any], Dict[str, jnp.ndarray]]:
    """One AdamW step. Returns (new_params, new_state, metrics).

    ``gnorm`` overrides the gradient-norm computation — the scheduled
    ZeRO-3 step passes the cross-device norm of its *sharded* grad tree
    (a local ``global_norm`` would miss the other shards).
    """
    if gnorm is None:
        gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    count = state["count"] + 1
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(g, mu, nu, master):
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * g * g
        mhat = mu / b1c
        nhat = nu / b2c
        step = mhat / (jnp.sqrt(nhat) + cfg.eps) + cfg.weight_decay * master
        master = master - lr * step
        return mu, nu, master

    flat_g, treedef = jax.tree.flatten(grads)
    flat_mu = jax.tree.leaves(state["mu"])
    flat_nu = jax.tree.leaves(state["nu"])
    flat_ma = jax.tree.leaves(state["master"])
    out = [upd(g, m, n, ma) for g, m, n, ma in
           zip(flat_g, flat_mu, flat_nu, flat_ma)]
    new_mu = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_nu = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_master = jax.tree.unflatten(treedef, [o[2] for o in out])
    new_params = jax.tree.map(
        lambda ma, p: ma.astype(p.dtype), new_master, params)
    new_state = {"mu": new_mu, "nu": new_nu, "master": new_master,
                 "count": count}
    return new_params, new_state, {"grad_norm": gnorm}
