"""Learning-rate schedules."""
from __future__ import annotations

import jax.numpy as jnp


def linear_warmup(step, warmup_steps: int, peak_lr: float):
    return peak_lr * jnp.minimum(1.0, (step + 1) / max(warmup_steps, 1))


def cosine_schedule(step, warmup_steps: int, total_steps: int,
                    peak_lr: float, min_lr_ratio: float = 0.1):
    warm = linear_warmup(step, warmup_steps, peak_lr)
    frac = jnp.clip((step - warmup_steps) /
                    jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
    cos = min_lr_ratio + (1 - min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return jnp.where(step < warmup_steps, warm, peak_lr * cos)
