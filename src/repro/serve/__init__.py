"""Hetero-aware serving engine: paged KV cache + continuous batching
over Poplar-planned device classes.

Layers (bottom-up):
  paged_cache — host-side page allocator (page tables, free list,
                refcounted prefix sharing)
  runtime     — paged decode / chunked + packed prefill jitted steps
  split       — per-device-class prefill/decode traffic pricing
  engine      — request queue, admission/eviction, bucketed batching
"""
from repro.serve.engine import Engine, Request
from repro.serve.paged_cache import PagedCacheOOM, PagedKVCache
from repro.serve.runtime import (PagedRuntime, init_pools,
                                 kv_bytes_per_token, next_pow2,
                                 trace_counts)
from repro.serve.split import (ClassLane, TrafficSplit, drift_report,
                               plan_traffic_split, uniform_split)

__all__ = [
    "ClassLane", "Engine", "PagedCacheOOM", "PagedKVCache",
    "PagedRuntime", "Request", "TrafficSplit", "drift_report",
    "init_pools", "kv_bytes_per_token", "next_pow2",
    "plan_traffic_split", "trace_counts", "uniform_split",
]
