"""Continuous-batching engine over the paged runtime.

The fixed-wave loop (``launch/serve.py::run_wave``) admits a batch,
prefills it token by token, decodes everyone to the longest request's
horizon, and only then admits more — short requests pay for long ones at
both ends. This engine replaces the wave with a per-step scheduler:

  queued ── admit (pages + slot free) ──> prefilling ── chunks done ──>
  decoding ── max tokens reached ──> done (pages freed THAT tick)

Each :meth:`step` is one scheduler tick:
  1. **admit** queued requests while their context fits the page pool
     and a decode slot is free (slots come from the hetero split's
     per-class sizing, so admission control *is* the Poplar allocation);
  2. **prefill** up to ``prefill_budget`` prompt tokens. By default the
     pending chunks of several requests *pack* into one segment-masked
     ``PagedRuntime.prefill_packed`` call (one traced shape per token
     bucket instead of one B=1 call per request); lanes drain in
     prefill-share order with age-based priority (``age_priority`` per
     bypassed tick) so packing many short prompts cannot starve a long
     one. A request whose prompt completes samples its first token
     (that's its TTFT) and joins the decode batch.
     ``packed_prefill=False`` keeps the sequential one-chunk-per-call
     path — the measured baseline in perf/serving/packed_prefill.
     Admission additionally consults the cache's *prefix index*
     (``prefix_cache=True``): a request whose context shares a
     page-aligned prefix with pages already written adopts them
     read-only (refcount + 1) and prefills only the tail;
  3. **decode** one token for every decoding request in a single
     bucketed batch (B and the page-table width both padded to powers of
     two) so the jit cache stays O(log) in both axes. A request whose
     next token needs a page the pool can't give preempts the *youngest*
     decoding request (pages released, context re-prefilled later —
     greedy decoding makes the recompute bit-exact);
  4. **retire** finished requests and release their pages immediately —
     the freed pages are what lets step 1 admit on the very next tick.

Drift: every decode step feeds the ``ServeTelemetry`` tokens/sec EMA;
:meth:`maybe_resplit` calibrates a baseline against the split's
predicted wave latency, and ``resplit_after`` consecutive drifted
reports re-run :func:`~repro.serve.split.plan_traffic_split` (and fire
``on_resplit`` — the cotenant launcher wires that to the arbiter's
re-arbitration).

Faults: ``tick_hook`` runs before every decode step; a Session-attached
engine consumes one serve tick per call there, so deterministic
FaultSchedules and ``Supervisor.call`` recovery drive the engine exactly
like the wave path did.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.telemetry import DriftConfig, ServeTelemetry
from repro.serve import split as SP
from repro.serve.paged_cache import PagedCacheOOM, PagedKVCache
from repro.serve.runtime import PagedRuntime, next_pow2


@dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int
    lane: str = ""                    # device class the router picked
    prefill_pos: int = 0              # context tokens already prefilled
    generated: List[int] = field(default_factory=list)
    pending_token: Optional[int] = None   # sampled, not yet fed to decode
    submit_t: float = 0.0
    first_token_t: Optional[float] = None
    preemptions: int = 0
    wait_ticks: int = 0               # prefill ticks spent bypassed

    @property
    def context(self) -> List[int]:
        """Tokens that must be in the KV cache before decode can resume:
        the prompt plus everything generated so far. For a fresh request
        this is just the prompt; after a preemption the generated suffix
        is re-prefilled too (greedy decode makes that recompute exact)."""
        return self.prompt + self.generated

    @property
    def ttft(self) -> Optional[float]:
        if self.first_token_t is None:
            return None
        return self.first_token_t - self.submit_t

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new_tokens


class Engine:
    """Continuous-batching scheduler over one paged runtime.

    ``split`` sizes admission (total decode slots) and orders prefill
    (lane shares); without one, ``max_batch`` alone caps concurrency.
    """

    def __init__(self, params, cfg, *, num_pages: int = 256,
                 page_size: int = 16, chunk: int = 32,
                 max_batch: int = 64, prefill_budget: Optional[int] = None,
                 impl: str = "reference",
                 split: Optional[SP.TrafficSplit] = None,
                 cluster=None, mesh=None,
                 tick_hook: Optional[Callable[[], None]] = None,
                 on_resplit: Optional[Callable[[SP.TrafficSplit], None]] = None,
                 drift_config: Optional[DriftConfig] = None,
                 resplit_after: int = 2,
                 telemetry: Optional[ServeTelemetry] = None,
                 packed_prefill: bool = True,
                 prefix_cache: bool = True,
                 age_priority: float = 0.25):
        self.cfg = cfg
        self.num_pages = num_pages
        self.page_size = page_size
        self.chunk = chunk
        self.max_batch = max_batch
        self.packed_prefill = packed_prefill
        self.prefix_cache = prefix_cache
        self.age_priority = age_priority
        # default budget: one chunk per device class per tick — enough to
        # keep prefill flowing without starving decode
        n_lanes = len(split.lanes) if split is not None else 1
        self.prefill_budget = (prefill_budget if prefill_budget is not None
                               else chunk * max(n_lanes, 1))
        self.split = split
        self.cluster = cluster
        self.tick_hook = tick_hook
        self.on_resplit = on_resplit
        self.drift_config = drift_config or DriftConfig()
        self.resplit_after = resplit_after
        self.telemetry = telemetry or ServeTelemetry()

        self.kv = PagedKVCache(num_pages=num_pages, page_size=page_size)
        self.runtime = PagedRuntime(params, cfg, num_pages=num_pages,
                                    page_size=page_size, impl=impl,
                                    mesh=mesh)
        self.queued: deque = deque()
        self.prefilling: List[Request] = []
        self.decoding: List[Request] = []
        self.done: Dict[int, Request] = {}
        self._next_rid = 0
        self._drift_baseline: Optional[float] = None
        self._drift_streak = 0
        self.resplits = 0
        self.preemptions = 0
        self.steps = 0
        self.ticks = 0

    # --------------------------------------------------------- intake ----
    @property
    def decode_slots(self) -> int:
        if self.split is not None and self.split.decode_slots_total > 0:
            return min(self.split.decode_slots_total, self.max_batch)
        return self.max_batch

    def _route(self) -> str:
        """Pick a lane for a new request: the class whose decode share is
        most under-served by assignments so far (deterministic weighted
        round-robin; '' without a split)."""
        if self.split is None or not self.split.lanes:
            return ""
        kinds = sorted(self.split.lanes)
        counts = {k: 0 for k in kinds}
        assigned = 0
        for r in (*self.queued, *self.prefilling, *self.decoding,
                  *self.done.values()):
            if r.lane in counts:
                counts[r.lane] += 1
                assigned += 1
        total = max(assigned, 1)
        return max(kinds, key=lambda k: (
            self.split.decode_share.get(k, 0.0) - counts[k] / total,
            self.split.decode_share.get(k, 0.0)))

    def submit(self, prompt, max_new_tokens: int) -> int:
        prompt = [int(t) for t in np.asarray(prompt).reshape(-1)]
        if not prompt:
            raise ValueError("empty prompt")
        worst = self.kv.pages_for(len(prompt) + int(max_new_tokens) + 1)
        if worst > self.num_pages - 1:
            raise PagedCacheOOM(
                f"request needs {worst} pages at its longest; the pool "
                f"has {self.num_pages - 1}")
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid=rid, prompt=prompt,
                      max_new_tokens=int(max_new_tokens),
                      lane=self._route(), submit_t=time.perf_counter())
        self.queued.append(req)
        return rid

    # ------------------------------------------------------ scheduling ---
    def _admit(self) -> None:
        live = len(self.prefilling) + len(self.decoding)
        while self.queued and live < self.decode_slots:
            req = self.queued[0]
            ctx = req.context
            hit = 0
            if self.prefix_cache:
                # cap so >= 1 real token remains to prefill — the final
                # context token must run through the model to produce the
                # next-token logits (the shared page's K/V alone can't)
                hit = self.kv.probe_prefix(ctx[:len(ctx) - 1])
            # the context plus one decode token must fit right now
            # (adopted prefix pages don't come from the free list);
            # otherwise wait for retirements to free pages
            need = self.kv.pages_for(len(ctx) + 1) - hit // self.page_size
            if need > self.kv.free_pages:
                break
            self.queued.popleft()
            self.kv.alloc(req.rid)
            if hit:
                adopted = self.kv.adopt_prefix(req.rid, ctx[:len(ctx) - 1])
                req.prefill_pos = adopted
                self.telemetry.record_prefix_hit(adopted)
            self.kv.reserve(req.rid, len(ctx) - req.prefill_pos)
            self.prefilling.append(req)
            live += 1

    def _prefill_order(self) -> List[Request]:
        """Drain order for the prompt backlog: lanes sorted by prefill
        share (compute-rich classes first), FIFO within a lane — but a
        request bypassed for ``wait_ticks`` ticks gains ``age_priority``
        per tick, so once packing favors a high-share lane's many short
        chunks a low-share lane's long prompt still rises to the front
        in bounded time (the starvation pin in
        tests/test_packed_prefill.py)."""
        share = (self.split.prefill_share if self.split is not None
                 else {})
        return sorted(
            self.prefilling,
            key=lambda r: (-(share.get(r.lane, 0.0)
                             + self.age_priority * r.wait_ticks), r.rid))

    def _age_prefill(self, served: List[Request]) -> None:
        """Reset the age of requests that advanced this tick; age the
        pending ones that lost the budget to a *different* lane.
        Within one lane order is FIFO by rid, so a request behind its
        own lane's siblings is queued, not starved — aging it too would
        turn single-lane FIFO into round-robin and inflate the decode
        batch-size buckets for nothing. Starvation is the cross-lane
        case: a share-poor lane outranked tick after tick."""
        served_rids = {r.rid for r in served}
        other_lane_served = {r.lane for r in served}
        for r in self.prefilling:
            if r.rid in served_rids:
                r.wait_ticks = 0
            elif (r.prefill_pos < len(r.context)
                  and other_lane_served - {r.lane}):
                r.wait_ticks += 1

    def _finish_prefill(self, req: Request, next_token: int) -> None:
        req.pending_token = next_token
        if req.first_token_t is None:
            req.first_token_t = time.perf_counter()
            self.telemetry.record_ttft(req.ttft)

    def _prefill_tick(self) -> None:
        if self.packed_prefill:
            self._prefill_tick_packed()
        else:
            self._prefill_tick_sequential()

    # -- sequential baseline (PR-9 behaviour): one B=1 call per chunk ----
    def _prefill_tick_sequential(self) -> None:
        budget = self.prefill_budget
        finished: List[Request] = []
        served: List[Request] = []
        for req in self._prefill_order():
            ctx = req.context
            while budget > 0 and req.prefill_pos < len(ctx):
                n_valid = min(self.chunk, len(ctx) - req.prefill_pos, budget)
                chunk = ctx[req.prefill_pos:req.prefill_pos + n_valid]
                chunk = chunk + [0] * (self.chunk - n_valid)
                max_pages = next_pow2(len(self.kv.tables[req.rid]))
                pt, _ = self.kv.gather([req.rid], 1, max_pages)
                logits = self.runtime.prefill_chunk(
                    np.asarray([chunk], np.int32), pt,
                    req.prefill_pos, n_valid)
                req.prefill_pos += n_valid
                self.kv.advance(req.rid, n_valid)
                budget -= n_valid
                if req not in served:
                    served.append(req)
                self.telemetry.record_prefill(n_valid)
                self.telemetry.record_prefill_call(n_valid, self.chunk)
                if self.prefix_cache:
                    self.kv.register_prefix(
                        req.rid, req.prompt,
                        min(req.prefill_pos, len(req.prompt)))
                if req.prefill_pos == len(ctx):
                    self._finish_prefill(req, int(jnp.argmax(logits[0, -1])))
                    finished.append(req)
            if budget <= 0:
                break
        self._age_prefill(served)
        for req in finished:
            self.prefilling.remove(req)
            self.decoding.append(req)

    # -- packed fast path: one segment-masked call per tick --------------
    def _fill_prefill_budget(self) -> List[List]:
        """Walk the backlog in priority order handing out the tick's
        token budget: first each lane's share of it, then a second pass
        gives any leftover to whoever still has pending tokens — the
        budget is spent whenever there is work, regardless of lane mix.
        Returns ``[request, n_tokens]`` picks (n_tokens > 0)."""
        order = [r for r in self._prefill_order()
                 if r.prefill_pos < len(r.context)]
        if not order:
            return []
        remaining = self.prefill_budget
        share = (self.split.prefill_share if self.split is not None
                 else {})
        lane_budget = {k: max(int(round(remaining * s)), 1)
                       for k, s in share.items()}
        picks: List[List] = []
        slot = {}
        for r in order:
            if remaining <= 0:
                break
            lb = lane_budget.get(r.lane, remaining)
            n = min(len(r.context) - r.prefill_pos, lb, remaining)
            if n <= 0:
                continue
            slot[r.rid] = len(picks)
            picks.append([r, n])
            if r.lane in lane_budget:
                lane_budget[r.lane] -= n
            remaining -= n
        for r in order:                       # leftover, ignore lane caps
            if remaining <= 0:
                break
            got = picks[slot[r.rid]][1] if r.rid in slot else 0
            n = min(len(r.context) - r.prefill_pos - got, remaining)
            if n <= 0:
                continue
            if r.rid in slot:
                picks[slot[r.rid]][1] += n
            else:
                slot[r.rid] = len(picks)
                picks.append([r, n])
            remaining -= n
        return picks

    def _prefill_tick_packed(self) -> None:
        picks = self._fill_prefill_budget()
        self._age_prefill([r for r, _ in picks])
        if not picks:
            return
        # pack every pick's chunk into one bucket-padded buffer: token
        # count, segment count and page-table width each round up to a
        # power of two so the packed jit cache stays O(log^3)
        total = sum(n for _, n in picks)
        T = next_pow2(total)
        G = next_pow2(len(picks))
        P = next_pow2(max(len(self.kv.tables[r.rid]) for r, _ in picks))
        tokens = np.zeros((1, T), np.int32)
        seg = np.zeros(T, np.int32)
        pos = np.zeros(T, np.int32)
        pages = np.zeros(T, np.int32)         # pads scatter to null page 0
        slots = np.zeros(T, np.int32)
        pt = np.zeros((G, P), np.int32)
        maxpos = np.full(G, -1, np.int32)     # -1: kernel skips the row
        last_idx = np.zeros(G, np.int32)
        off = 0
        for gi, (req, n) in enumerate(picks):
            ctx = req.context
            table = self.kv.tables[req.rid]
            tokens[0, off:off + n] = ctx[req.prefill_pos:req.prefill_pos + n]
            seg[off:off + n] = gi + 1
            abspos = np.arange(req.prefill_pos, req.prefill_pos + n)
            pos[off:off + n] = abspos
            pages[off:off + n] = [table[p] for p in abspos // self.page_size]
            slots[off:off + n] = abspos % self.page_size
            pt[gi, :len(table)] = table
            maxpos[gi] = req.prefill_pos + n - 1
            last_idx[gi] = off + n - 1
            off += n
        logits = self.runtime.prefill_packed(tokens, seg, pos, pages,
                                             slots, pt, maxpos, last_idx)
        self.telemetry.record_prefill_call(total, T)
        finished: List[Request] = []
        nxt = None
        for gi, (req, n) in enumerate(picks):
            req.prefill_pos += n
            self.kv.advance(req.rid, n)
            self.telemetry.record_prefill(n)
            if self.prefix_cache:
                # publish fully-written *prompt* pages; admission (which
                # runs before prefill each tick) only ever adopts pages
                # committed by a previous tick's call
                self.kv.register_prefix(
                    req.rid, req.prompt,
                    min(req.prefill_pos, len(req.prompt)))
            if req.prefill_pos == len(req.context):
                if nxt is None:
                    nxt = np.asarray(jnp.argmax(logits[0], axis=-1))
                self._finish_prefill(req, int(nxt[gi]))
                finished.append(req)
        for req in finished:
            self.prefilling.remove(req)
            self.decoding.append(req)

    def _preempt(self, victim: Request) -> None:
        """Evict a decoding request: release every page and requeue it at
        the front. Its generated prefix is re-prefilled on re-admission
        (recompute-style preemption — greedy decode reproduces the same
        tokens, pinned by tests)."""
        self.decoding.remove(victim)
        self.kv.release(victim.rid)
        victim.prefill_pos = 0
        victim.pending_token = None
        victim.preemptions += 1
        self.preemptions += 1
        self.queued.appendleft(victim)

    def _reserve_batch(self) -> List[Request]:
        """Reserve one decode token per decoding request, preempting the
        youngest requests when the pool runs dry (oldest first keeps
        head-of-line latency bounded)."""
        reserved: List[Request] = []
        for req in list(self.decoding[:self.max_batch]):
            if req not in self.decoding:
                continue                      # preempted by an earlier pass
            while True:
                try:
                    self.kv.reserve(req.rid, 1)
                    reserved.append(req)
                    break
                except PagedCacheOOM:
                    keep = {r.rid for r in reserved} | {req.rid}
                    victims = [r for r in self.decoding
                               if r.rid not in keep]
                    if victims:
                        self._preempt(max(victims, key=lambda r: r.rid))
                        continue
                    self._preempt(req)        # last resort: itself
                    break
        return reserved

    def _decode_tick(self) -> None:
        if not self.decoding:
            return
        if self.tick_hook is not None:
            self.tick_hook()
        batch = self._reserve_batch()
        if not batch:
            return
        B = next_pow2(len(batch))
        max_pages = next_pow2(max(len(self.kv.tables[r.rid])
                                  for r in batch))
        pt, ln = self.kv.gather([r.rid for r in batch], B, max_pages)
        toks = np.zeros((B, 1), np.int32)
        for i, req in enumerate(batch):
            toks[i, 0] = req.pending_token
        t0 = time.perf_counter()
        logits = self.runtime.decode(toks, pt, ln)
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        dt = time.perf_counter() - t0
        self.telemetry.record_decode(dt, live=len(batch))
        self.steps += 1
        for i, req in enumerate(batch):
            self.kv.advance(req.rid, 1)
            req.generated.append(int(toks[i, 0]))
            req.pending_token = int(nxt[i])
        # retire: pages free the same tick so admission sees them next tick
        for req in [r for r in batch if r.done]:
            self.decoding.remove(req)
            self.kv.release(req.rid)
            self.done[req.rid] = req
            self.telemetry.record_finished()
        self.maybe_resplit()

    def step(self) -> None:
        """One scheduler tick: admit → prefill (budgeted) → decode."""
        self.ticks += 1
        self._admit()
        self._prefill_tick()
        self._decode_tick()

    def run(self, max_ticks: int = 100_000) -> Dict[int, List[int]]:
        """Drive ticks until every submitted request is done; returns
        {rid: generated tokens}."""
        ticks = 0
        while self.queued or self.prefilling or self.decoding:
            before = self._progress_marker()
            self.step()
            ticks += 1
            if ticks >= max_ticks:
                raise RuntimeError(f"engine stalled after {ticks} ticks")
            if self._progress_marker() == before:
                raise RuntimeError(
                    "engine made no progress in a tick: "
                    f"queued={len(self.queued)} "
                    f"free_pages={self.kv.free_pages}")
        return {rid: r.generated for rid, r in self.done.items()}

    def _progress_marker(self):
        return (len(self.queued), len(self.prefilling), len(self.decoding),
                self.steps, self.preemptions,
                sum(r.prefill_pos for r in self.prefilling))

    # ----------------------------------------------------------- drift ---
    def maybe_resplit(self) -> Optional[SP.TrafficSplit]:
        """Re-split on sustained drift. The first qualifying sample
        calibrates the substrate baseline (analytical seconds are not
        wall seconds), then ``resplit_after`` consecutive drifted reports
        re-run the split pricing and notify ``on_resplit`` (the arbiter
        re-arbitration hook)."""
        if self.split is None or self.split.plan is None:
            return None
        win = self.telemetry.throughput
        if self._drift_baseline is None:
            if (win.value is not None
                    and win.count >= self.drift_config.min_samples
                    and self.split.wave_latency > 0):
                self._drift_baseline = win.value / self.split.wave_latency
            return None
        rep = SP.drift_report(self.split, win, self.drift_config,
                              baseline=self._drift_baseline)
        if rep is None or not rep.drifted:
            self._drift_streak = 0
            return None
        self._drift_streak += 1
        if self._drift_streak < self.resplit_after:
            return None
        self._drift_streak = 0
        if self.cluster is None:
            return None
        new = SP.plan_traffic_split(
            self.cluster, self.cfg,
            requests=max(self.split.decode_slots_total, 1),
            cache_len=self.split.cache_len, page_size=self.page_size)
        self.split = new
        self.resplits += 1
        self._drift_baseline = None     # recalibrate against the new plan
        win.reset()
        if self.on_resplit is not None:
            self.on_resplit(new)
        return new

    # -------------------------------------------------------- reporting --
    def describe(self) -> Dict[str, Any]:
        out = {
            "queued": len(self.queued),
            "prefilling": len(self.prefilling),
            "decoding": len(self.decoding),
            "done": len(self.done),
            "decode_slots": self.decode_slots,
            "pages": {"free": self.kv.free_pages,
                      "used": self.kv.used_pages,
                      "peak": self.kv.peak_in_use,
                      "page_size": self.page_size},
            "steps": self.steps,
            "ticks": self.ticks,
            "preemptions": self.preemptions,
            "resplits": self.resplits,
            "prefill": {
                "packed": self.packed_prefill,
                "calls": self.telemetry.prefill_calls,
                "calls_per_tick": (self.telemetry.prefill_calls
                                   / max(self.ticks, 1)),
                "fill_frac": self.telemetry.prefill_fill_frac,
                "prefix_hit_tokens": self.telemetry.prefix_hit_tokens,
                "prefix_hit_pages": self.kv.prefix_hits,
            },
            "telemetry": self.telemetry.snapshot(),
        }
        if self.split is not None:
            out["split"] = {
                "strategy": self.split.strategy,
                "decode_share": dict(self.split.decode_share),
                "prefill_share": dict(self.split.prefill_share),
                "wave_latency": self.split.wave_latency,
            }
        return out

    def log_line(self) -> str:
        d = self.describe()
        total = d["pages"]["used"] + d["pages"]["free"]
        pf = d["prefill"]
        fill = (f"{pf['fill_frac']:.0%}" if pf["fill_frac"] is not None
                else "-")
        line = (f"[engine] {self.telemetry.describe()} · "
                f"q{d['queued']}/p{d['prefilling']}/d{d['decoding']} · "
                f"pages {d['pages']['used']}/{total} · "
                f"pf {pf['calls']}c "
                f"({pf['calls_per_tick']:.2f}/tick, fill {fill}, "
                f"hit {pf['prefix_hit_tokens']}t)")
        if self.split is not None:
            line += f" · {self.split.describe()}"
        return line
