"""Paged KV cache — fixed-size pages, per-request page tables.

The serving engine's memory substrate: instead of one contiguous
``(B, max_len, Hkv, D)`` cache sized to the longest request, K/V live in
a shared pool of ``num_pages`` fixed-size pages and each request holds
an ordered list of page indices (its *page table*). Requests of wildly
different lengths then pack into one decode batch with zero cache copy
and zero padding-to-max-length; a finished request returns its pages to
the free list immediately, which is what makes per-decode-step
admission/eviction (continuous batching) possible at all.

Two halves, deliberately separated:

- :class:`PagedKVCache` — the *host-side allocator*: pure bookkeeping
  (free list, per-request tables, lengths), no arrays. Every mutation
  maintains the no-leak invariant ``free + allocated == num_pages - 1``
  (page 0 is the reserved *null page*: padded batch-bucket slots point
  their tables at it so scatter writes for dead rows land harmlessly;
  it is never handed to a request).
- the *device pools* — ``init_pools`` builds the model-shaped pytree of
  K/V pools (one ``(n_rep, num_pages, page_size, Hkv, D)`` pair per
  attention position of the pattern unit, GQA-native at ``n_kv_heads``),
  owned and threaded functionally by ``serve.runtime``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Tuple

import numpy as np


class PagedCacheOOM(Exception):
    """Raised when an allocation cannot be served from the free list."""


@dataclass
class PagedKVCache:
    """Host-side page allocator. Page 0 is reserved (the null page)."""
    num_pages: int
    page_size: int
    free: List[int] = field(init=False)
    tables: Dict[int, List[int]] = field(init=False)   # rid -> page ids
    lengths: Dict[int, int] = field(init=False)        # rid -> tokens held
    peak_in_use: int = field(init=False, default=0)

    def __post_init__(self):
        if self.num_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is reserved)")
        if self.page_size < 1:
            raise ValueError("page_size must be >= 1")
        # LIFO free list: recently-freed pages are re-used first (warm)
        self.free = list(range(self.num_pages - 1, 0, -1))
        self.tables = {}
        self.lengths = {}

    # ---------------------------------------------------------- queries --
    @property
    def free_pages(self) -> int:
        return len(self.free)

    @property
    def used_pages(self) -> int:
        return sum(len(t) for t in self.tables.values())

    def pages_for(self, n_tokens: int) -> int:
        return -(-max(n_tokens, 0) // self.page_size)

    def can_fit(self, n_tokens: int) -> bool:
        return self.pages_for(n_tokens) <= len(self.free)

    def length(self, rid: int) -> int:
        return self.lengths[rid]

    def table(self, rid: int) -> Tuple[int, ...]:
        return tuple(self.tables[rid])

    # -------------------------------------------------------- lifecycle --
    def alloc(self, rid: int) -> None:
        """Register an empty request (no pages yet; ``reserve`` grows it)."""
        if rid in self.tables:
            raise ValueError(f"request {rid} already allocated")
        self.tables[rid] = []
        self.lengths[rid] = 0

    def reserve(self, rid: int, n_tokens: int) -> None:
        """Ensure capacity for ``length + n_tokens`` more tokens,
        growing the request's page table from the free list. Raises
        :class:`PagedCacheOOM` (state unchanged) when the pool is out —
        the engine's signal to stop admitting."""
        t = self.tables[rid]
        need = self.pages_for(self.lengths[rid] + n_tokens) - len(t)
        if need <= 0:
            return
        if need > len(self.free):
            raise PagedCacheOOM(
                f"request {rid}: need {need} pages, {len(self.free)} free")
        for _ in range(need):
            t.append(self.free.pop())
        self.peak_in_use = max(self.peak_in_use, self.used_pages)

    def advance(self, rid: int, n_tokens: int = 1) -> None:
        """Commit ``n_tokens`` written tokens. Capacity must have been
        reserved — advancing past the table is a bug, not an OOM."""
        new_len = self.lengths[rid] + n_tokens
        if new_len > len(self.tables[rid]) * self.page_size:
            raise ValueError(
                f"request {rid}: advance to {new_len} tokens exceeds "
                f"{len(self.tables[rid])} reserved pages")
        self.lengths[rid] = new_len

    def release(self, rid: int) -> int:
        """Free all of a finished request's pages; returns how many."""
        pages = self.tables.pop(rid)
        del self.lengths[rid]
        self.free.extend(reversed(pages))
        return len(pages)

    # ------------------------------------------------- batch assembly ----
    def gather(self, rids: List[int], batch: int, max_pages: int
               ) -> Tuple[np.ndarray, np.ndarray]:
        """(page_table, lengths) arrays for one bucketed decode batch:
        shape ``(batch, max_pages)`` / ``(batch,)`` with rows past
        ``len(rids)`` padded to the null page / length 0 (the kernel
        returns zeros for them and their scatter writes hit page 0)."""
        if len(rids) > batch:
            raise ValueError(f"{len(rids)} requests > batch bucket {batch}")
        pt = np.zeros((batch, max_pages), np.int32)
        ln = np.zeros((batch,), np.int32)
        for i, rid in enumerate(rids):
            t = self.tables[rid]
            if len(t) > max_pages:
                raise ValueError(
                    f"request {rid}: {len(t)} pages > bucket {max_pages}")
            pt[i, :len(t)] = t
            ln[i] = self.lengths[rid]
        return pt, ln

    # ------------------------------------------------------ invariants ---
    def check(self) -> None:
        """No-leak/no-alias invariants (tests call this after every op):
        free + allocated covers pages 1..num_pages-1 exactly once, page 0
        is never allocated, and every length fits its table."""
        allocated = [p for t in self.tables.values() for p in t]
        assert 0 not in allocated, "null page leaked into a request"
        assert 0 not in self.free, "null page leaked into the free list"
        seen = sorted(allocated + self.free)
        assert seen == list(range(1, self.num_pages)), (
            f"page leak/alias: {len(allocated)} allocated + "
            f"{len(self.free)} free != {self.num_pages - 1}")
        for rid, t in self.tables.items():
            assert self.lengths[rid] <= len(t) * self.page_size
