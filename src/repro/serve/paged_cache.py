"""Paged KV cache — fixed-size pages, per-request page tables, refcounted
cross-request prefix sharing.

The serving engine's memory substrate: instead of one contiguous
``(B, max_len, Hkv, D)`` cache sized to the longest request, K/V live in
a shared pool of ``num_pages`` fixed-size pages and each request holds
an ordered list of page indices (its *page table*). Requests of wildly
different lengths then pack into one decode batch with zero cache copy
and zero padding-to-max-length; a finished request returns its pages to
the free list immediately, which is what makes per-decode-step
admission/eviction (continuous batching) possible at all.

Pages are *refcounted*: requests whose prompts share a page-aligned
prefix hold the same physical pages (see "prefix sharing" below), so
``release`` decrements and frees only at zero — a preempted or retired
request never yanks K/V out from under a sibling still decoding.

Two halves, deliberately separated:

- :class:`PagedKVCache` — the *host-side allocator*: pure bookkeeping
  (free list, per-request tables, lengths, refcounts, prefix index), no
  arrays. Every mutation maintains the no-leak invariant
  ``free + unique(allocated) == num_pages - 1`` (page 0 is the reserved
  *null page*: padded batch-bucket slots point their tables at it so
  scatter writes for dead rows land harmlessly; it is never handed to a
  request).
- the *device pools* — ``init_pools`` builds the model-shaped pytree of
  K/V pools (one ``(n_rep, num_pages, page_size, Hkv, D)`` pair per
  attention position of the pattern unit, GQA-native at ``n_kv_heads``),
  owned and threaded functionally by ``serve.runtime``.

Prefix sharing
--------------

A page whose ``page_size`` slots are all filled with *prompt* tokens is
immutable for the rest of its life (decode and later prefill chunks
write into later pages), and its K/V depend only on the token prefix up
to its end — RoPE positions are absolute from 0 in every request, so two
requests with the same prompt prefix compute bit-identical K/V for it.
The allocator therefore keeps a *prefix index* keyed by
``(parent_page_id, tokens_of_this_page)``: chaining on the physical
parent page id makes the key collision-free (two requests can only agree
on page k's key if they already share pages 0..k-1) and O(page_size) to
build. ``adopt_prefix`` walks the chain for a new prompt, adopting each
matching page read-only (refcount + 1) so only the unmatched tail is
ever prefilled; the partial tail page is never shared — a request whose
prompt ends mid-page re-prefills those tokens into its own fresh page
(copy-on-write by re-prefill). ``register_prefix`` is the write side,
called by the engine as prefill advances past page boundaries.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Sequence, Tuple

import numpy as np

# prefix-index key: (physical id of the parent page — 0 roots the chain
# at the null page — and this page's exact token contents)
PrefixKey = Tuple[int, Tuple[int, ...]]


class PagedCacheOOM(Exception):
    """Raised when an allocation cannot be served from the free list."""


@dataclass
class PagedKVCache:
    """Host-side page allocator. Page 0 is reserved (the null page)."""
    num_pages: int
    page_size: int
    free: List[int] = field(init=False)
    tables: Dict[int, List[int]] = field(init=False)   # rid -> page ids
    lengths: Dict[int, int] = field(init=False)        # rid -> tokens held
    refcounts: Dict[int, int] = field(init=False)      # page -> holders
    prefix_index: Dict[PrefixKey, int] = field(init=False)
    page_key: Dict[int, PrefixKey] = field(init=False)  # registered pages
    peak_in_use: int = field(init=False, default=0)
    prefix_hits: int = field(init=False, default=0)    # pages adopted
    prefix_hit_tokens: int = field(init=False, default=0)

    def __post_init__(self):
        if self.num_pages < 2:
            raise ValueError("need >= 2 pages (page 0 is reserved)")
        if self.page_size < 1:
            raise ValueError("page_size must be >= 1")
        # LIFO free list: recently-freed pages are re-used first (warm)
        self.free = list(range(self.num_pages - 1, 0, -1))
        self.tables = {}
        self.lengths = {}
        self.refcounts = {}
        self.prefix_index = {}
        self.page_key = {}

    # ---------------------------------------------------------- queries --
    @property
    def free_pages(self) -> int:
        return len(self.free)

    @property
    def used_pages(self) -> int:
        """Distinct physical pages held by live requests (a page shared
        by n requests counts once — it occupies one pool slot)."""
        return len({p for t in self.tables.values() for p in t})

    def pages_for(self, n_tokens: int) -> int:
        return -(-max(n_tokens, 0) // self.page_size)

    def can_fit(self, n_tokens: int) -> bool:
        return self.pages_for(n_tokens) <= len(self.free)

    def length(self, rid: int) -> int:
        return self.lengths[rid]

    def table(self, rid: int) -> Tuple[int, ...]:
        return tuple(self.tables[rid])

    # -------------------------------------------------------- lifecycle --
    def alloc(self, rid: int) -> None:
        """Register an empty request (no pages yet; ``reserve`` grows it,
        ``adopt_prefix`` may seed it with shared prefix pages)."""
        if rid in self.tables:
            raise ValueError(f"request {rid} already allocated")
        self.tables[rid] = []
        self.lengths[rid] = 0

    def reserve(self, rid: int, n_tokens: int) -> None:
        """Ensure capacity for ``length + n_tokens`` more tokens,
        growing the request's page table from the free list. Raises
        :class:`PagedCacheOOM` (state unchanged) when the pool is out —
        the engine's signal to stop admitting."""
        t = self.tables[rid]
        need = self.pages_for(self.lengths[rid] + n_tokens) - len(t)
        if need <= 0:
            return
        if need > len(self.free):
            raise PagedCacheOOM(
                f"request {rid}: need {need} pages, {len(self.free)} free")
        for _ in range(need):
            p = self.free.pop()
            self.refcounts[p] = 1
            t.append(p)
        self.peak_in_use = max(self.peak_in_use, self.used_pages)

    def advance(self, rid: int, n_tokens: int = 1) -> None:
        """Commit ``n_tokens`` written tokens. Capacity must have been
        reserved — advancing past the table is a bug, not an OOM."""
        new_len = self.lengths[rid] + n_tokens
        if new_len > len(self.tables[rid]) * self.page_size:
            raise ValueError(
                f"request {rid}: advance to {new_len} tokens exceeds "
                f"{len(self.tables[rid])} reserved pages")
        self.lengths[rid] = new_len

    def release(self, rid: int) -> int:
        """Drop a finished request's hold on its pages; each page's
        refcount decrements and the page returns to the free list only
        at zero (a prefix page shared with a live sibling survives).
        Returns how many pages were actually freed."""
        pages = self.tables.pop(rid)
        del self.lengths[rid]
        freed = 0
        for p in reversed(pages):
            self.refcounts[p] -= 1
            if self.refcounts[p] == 0:
                del self.refcounts[p]
                key = self.page_key.pop(p, None)
                if key is not None:
                    self.prefix_index.pop(key, None)
                self.free.append(p)
                freed += 1
        return freed

    # --------------------------------------------------- prefix sharing --
    def _prefix_chain(self, tokens: Sequence[int]) -> List[int]:
        """Longest chain of already-registered pages matching ``tokens``
        from position 0 (full pages only — the tail is never shared)."""
        chain: List[int] = []
        parent = 0
        ps = self.page_size
        for start in range(0, (len(tokens) // ps) * ps, ps):
            key = (parent, tuple(int(t) for t in tokens[start:start + ps]))
            page = self.prefix_index.get(key)
            if page is None:
                break
            chain.append(page)
            parent = page
        return chain

    def probe_prefix(self, tokens: Sequence[int]) -> int:
        """Tokens a fresh request over ``tokens`` could adopt, without
        mutating — the engine's admission check subtracts this from the
        pages a request needs before consulting the free list."""
        return len(self._prefix_chain(tokens)) * self.page_size

    def adopt_prefix(self, rid: int, tokens: Sequence[int]) -> int:
        """Seed a freshly-``alloc``ed request with the longest registered
        page-aligned prefix of ``tokens``: each matched page is appended
        to the request's table read-only (refcount + 1) and its tokens
        count as already written. Returns the tokens adopted.

        Callers cap ``tokens`` so at least one real token remains to
        prefill (the last prompt token must run through the model to
        produce first-token logits)."""
        if self.tables[rid]:
            raise ValueError(
                f"request {rid}: adopt_prefix needs an empty table")
        chain = self._prefix_chain(tokens)
        for p in chain:
            self.refcounts[p] += 1
            self.tables[rid].append(p)
        n = len(chain) * self.page_size
        self.lengths[rid] = n
        self.prefix_hits += len(chain)
        self.prefix_hit_tokens += n
        return n

    def register_prefix(self, rid: int, tokens: Sequence[int],
                        n_written: int) -> int:
        """Publish the request's fully-written prompt pages into the
        prefix index. ``tokens`` is the immutable prompt; ``n_written``
        how many of its tokens are committed in the cache. Only pages
        *entirely* covered by written prompt tokens register (the
        partial tail page is never shared), and a page already published
        under its key — by this request (idempotent re-call) or by a
        sibling that prefilled the same prefix first — is skipped.
        Returns how many pages were newly registered."""
        t = self.tables[rid]
        ps = self.page_size
        upto = min(len(tokens), n_written, self.lengths[rid])
        added = 0
        for i in range(upto // ps):
            page = t[i]
            # parent = our own physical predecessor: for adopted pages
            # that IS the index's chain page, and keeping every parent
            # pointer inside one table means a registered page can never
            # outlive its parent (release frees chains bottom-up), so the
            # index never holds a key whose parent id was recycled
            key = (t[i - 1] if i else 0,
                   tuple(int(x) for x in tokens[i * ps:(i + 1) * ps]))
            existing = self.prefix_index.get(key)
            if existing == page:
                continue                    # already published (adopted)
            if existing is not None or page in self.page_key:
                # a sibling that prefilled the same prefix concurrently
                # published first — stop rather than splice chains
                break
            self.prefix_index[key] = page
            self.page_key[page] = key
            added += 1
        return added

    # ------------------------------------------------- batch assembly ----
    def gather(self, rids: List[int], batch: int, max_pages: int
               ) -> Tuple[np.ndarray, np.ndarray]:
        """(page_table, lengths) arrays for one bucketed decode batch:
        shape ``(batch, max_pages)`` / ``(batch,)`` with rows past
        ``len(rids)`` padded to the null page / length 0 (the kernel
        returns zeros for them and their scatter writes hit page 0)."""
        if len(rids) > batch:
            raise ValueError(f"{len(rids)} requests > batch bucket {batch}")
        pt = np.zeros((batch, max_pages), np.int32)
        ln = np.zeros((batch,), np.int32)
        for i, rid in enumerate(rids):
            t = self.tables[rid]
            if len(t) > max_pages:
                raise ValueError(
                    f"request {rid}: {len(t)} pages > bucket {max_pages}")
            pt[i, :len(t)] = t
            ln[i] = self.lengths[rid]
        return pt, ln

    # ------------------------------------------------------ invariants ---
    def check(self) -> None:
        """No-leak/no-alias invariants (tests call this after every op):
        free + distinct allocated covers pages 1..num_pages-1 exactly
        once, page 0 is never allocated, every refcount equals the number
        of tables holding that page, every length fits its table, and the
        prefix index points only at live registered pages."""
        multiplicity: Dict[int, int] = {}
        for t in self.tables.values():
            for p in t:
                multiplicity[p] = multiplicity.get(p, 0) + 1
        assert 0 not in multiplicity, "null page leaked into a request"
        assert 0 not in self.free, "null page leaked into the free list"
        seen = sorted(list(multiplicity) + self.free)
        assert seen == list(range(1, self.num_pages)), (
            f"page leak/alias: {len(multiplicity)} allocated + "
            f"{len(self.free)} free != {self.num_pages - 1} "
            f"(double-free or shared page freed early)")
        assert self.refcounts == multiplicity, (
            f"refcount drift: {self.refcounts} vs table multiplicity "
            f"{multiplicity}")
        for rid, t in self.tables.items():
            assert self.lengths[rid] <= len(t) * self.page_size
        for key, page in self.prefix_index.items():
            assert page in multiplicity, (
                f"prefix index points at freed page {page}")
            assert self.page_key.get(page) == key, (
                f"page {page} key mismatch in prefix index")
            parent = key[0]
            assert parent == 0 or parent in multiplicity, (
                f"registered page {page} outlived its chain parent "
                f"{parent}")
        assert set(self.page_key) == {p for p in self.prefix_index.values()}
