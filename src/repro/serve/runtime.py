"""Paged model runtime: decode + chunked prefill over paged KV pools.

The device-side half of the paged cache. ``models.decode_step`` scans the
pattern unit over per-position contiguous caches ``(n_rep, B, S, Hkv, D)``;
this module keeps the exact same scan structure but swaps the cache leaves
for shared page pools ``(n_rep, num_pages, page_size, Hkv, D)`` indexed
through per-request page tables. Three consequences:

- decode batches are ragged for free: each request's K/V live wherever its
  pages are, attention gathers them through the table (Pallas kernel
  ``kernels.flash_decode_paged`` or a jnp gather+grouped-einsum reference);
- the new token's K/V is a *scatter* — ``pool.at[page, slot].set(...)`` at
  ``page = table[length // page_size]``, ``slot = length % page_size`` —
  instead of a ``dynamic_update_slice`` into a per-request buffer;
- prefill runs in fixed-size chunks that write then attend causally, so
  a long prompt never forces a max-length-shaped compile and can be
  interleaved with decode steps. Chunks of *several* requests pack into
  one segment-id-masked call (``prefill_packed``): tokens concatenate
  into a single budget-sized buffer, per-token destination pages route
  each segment's K/V scatter into its own page table, and attention is
  confined within equal segment ids — one traced shape serves however
  many requests the engine's token budget covers this tick.

All jitted entry points go through a module-level cache keyed on the
config fingerprint and static shapes, so fresh ``PagedRuntime`` instances
(and fresh Engines) reuse compiles, and ``trace_counts()`` exposes how
many distinct shapes actually traced — the engine's bucketing test pins
this against the number of buckets.

Only attention-cache block kinds (dense attn / MoE-attn) are paged;
SSM/shared-attn/enc-dec configs raise ``NotImplementedError`` up front.
"""
from __future__ import annotations

import collections
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import BLOCK_ATTN, BLOCK_MOE, ModelConfig
from repro.core.bucketing import next_pow2  # noqa: F401  (re-exported)
from repro.models import layers as L
from repro.models import moe as M
from repro.models.model import lm_logits, pattern_unit

_PAGED_KINDS = (BLOCK_ATTN, BLOCK_MOE)

# trace-time counters: the body of a jitted function runs once per compile,
# so bumping here counts compiles. Tests pin boundedness under bucketing.
TRACE_COUNTS: collections.Counter = collections.Counter()

_JIT_CACHE: Dict[Tuple, Any] = {}


def check_paged_support(cfg: ModelConfig) -> None:
    unit, _ = pattern_unit(cfg)
    bad = [k for k in unit if k not in _PAGED_KINDS]
    if bad:
        raise NotImplementedError(
            f"paged serving supports attention-cache blocks only; "
            f"{cfg.name} has {bad}")
    if cfg.sliding_window:
        raise NotImplementedError(
            "paged serving does not implement sliding-window eviction yet")
    if cfg.encoder_layers:
        raise NotImplementedError("paged serving is decoder-only")


def _kv_dtype(cfg: ModelConfig):
    return jnp.dtype(getattr(cfg, "kv_cache_dtype", None) or cfg.dtype)


def kv_bytes_per_token(cfg: ModelConfig) -> int:
    """K+V bytes one token occupies across every attention layer — the
    unit the hetero split uses to turn a device's memory budget into a
    page count."""
    unit, n_rep = pattern_unit(cfg)
    n_attn = sum(1 for k in unit if k in _PAGED_KINDS) * n_rep
    return (2 * cfg.n_kv_heads * cfg.resolved_head_dim
            * _kv_dtype(cfg).itemsize * n_attn)


def init_pools(cfg: ModelConfig, num_pages: int, page_size: int) -> Dict:
    """Page pools shaped like ``init_decode_state``'s cache tree: one
    ``{"k","v"}`` pair per attention position of the pattern unit, each
    ``(n_rep, num_pages, page_size, Hkv, D)`` so the decode scan slices
    repeats exactly like the contiguous path. Page index 0 is the null
    page (never allocated to a request)."""
    check_paged_support(cfg)
    unit, n_rep = pattern_unit(cfg)
    dtype = _kv_dtype(cfg)
    hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    shape = (n_rep, num_pages, page_size, hkv, hd)
    return {f"pos{p}": {"k": jnp.zeros(shape, dtype),
                        "v": jnp.zeros(shape, dtype)}
            for p, kind in enumerate(unit) if kind in _PAGED_KINDS}


# ---------------------------------------------------------------------------
# decode: one token for a bucketed batch of ragged requests
# ---------------------------------------------------------------------------

def _paged_attn_decode(ap, h, pool, page_table, lengths, cfg, impl):
    """h: (B,1,d); pool: {"k","v"} (num_pages, page_size, Hkv, D).
    Writes the new token at (table[len // ps], len % ps), then attends
    over ``lengths + 1`` tokens. Padded batch slots (length 0, table all
    null-page) scatter into page 0 and read garbage — their logits are
    discarded by the engine."""
    B = h.shape[0]
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = jnp.einsum("bsd,dhk->bhsk", h, ap["wq"].astype(h.dtype))
    k = jnp.einsum("bsd,dhk->bhsk", h, ap["wk"].astype(h.dtype))
    v = jnp.einsum("bsd,dhk->bhsk", h, ap["wv"].astype(h.dtype))
    pos = lengths[:, None]                                   # (B,1) per-row
    q = L.apply_rope(q, pos, cfg.rope_theta)
    k = L.apply_rope(k, pos, cfg.rope_theta)

    page_size = pool["k"].shape[1]
    max_pages = page_table.shape[1]
    page = page_table[jnp.arange(B),
                      jnp.clip(lengths // page_size, 0, max_pages - 1)]
    slot = lengths % page_size
    kd = _kv_dtype(cfg)
    k_new = pool["k"].at[page, slot].set(k[:, :, 0, :].astype(kd))
    v_new = pool["v"].at[page, slot].set(v[:, :, 0, :].astype(kd))
    filled = lengths + 1

    if impl == "pallas":
        from repro.kernels import ops as kops
        out = kops.flash_decode_paged(q, k_new.astype(h.dtype),
                                      v_new.astype(h.dtype),
                                      page_table, filled)
    else:
        S_tot = max_pages * page_size
        keys = k_new[page_table].reshape(B, S_tot, hkv, hd).astype(h.dtype)
        vals = v_new[page_table].reshape(B, S_tot, hkv, hd).astype(h.dtype)
        qg = q.reshape(B, hkv, hq // hkv, 1, hd)
        s = jnp.einsum("bhgqd,bshd->bhgqs", qg, keys,
                       preferred_element_type=jnp.float32) / jnp.sqrt(hd)
        valid = jnp.arange(S_tot)[None, :] < filled[:, None]     # (B,S_tot)
        s = jnp.where(valid[:, None, None, None, :], s, -jnp.inf)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bhgqs,bshd->bhgqd", p.astype(h.dtype),
                         vals).reshape(B, hq, 1, hd)
    y = jnp.einsum("bhsk,hkd->bsd", out, ap["wo"].astype(h.dtype))
    return y, {"k": k_new, "v": v_new}


def _paged_decode(params, pools, tokens, page_table, lengths, *,
                  cfg: ModelConfig, impl: str):
    """tokens (B,1) int32 → (logits (B,1,V), new pools)."""
    TRACE_COUNTS["decode"] += 1
    unit, _ = pattern_unit(cfg)
    x = L.embed(params["embed"], tokens)

    def unit_body(x, xs):
        stack_slice, pool_slice = xs
        new_pools = {}
        for p, kind in enumerate(unit):
            bp = stack_slice[f"pos{p}"]
            h = L.rmsnorm(bp["norm1"], x, cfg.norm_eps)
            y, new_pool = _paged_attn_decode(bp["attn"], h,
                                             pool_slice[f"pos{p}"],
                                             page_table, lengths, cfg, impl)
            x = x + y
            h = L.rmsnorm(bp["norm2"], x, cfg.norm_eps)
            if kind == BLOCK_MOE:
                y, _ = M.moe_apply(bp["moe"], h, cfg)
                x = x + y
            else:
                x = x + L.mlp_apply(bp["mlp"], h)
            new_pools[f"pos{p}"] = new_pool
        return x, new_pools

    x, new_pools = jax.lax.scan(unit_body, x, (params["stack"], pools))
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return lm_logits(params, cfg, x), new_pools


# ---------------------------------------------------------------------------
# prefill: one chunk of one request's prompt (write K/V, attend causally)
# ---------------------------------------------------------------------------

def _paged_attn_prefill(ap, h, pool, page_table, offset, n_valid, cfg):
    """h: (1,T,d). Writes the chunk's K/V into the request's pages at
    absolute positions ``offset + t``, then attends each chunk token over
    the full gathered cache with a causal mask. Tokens past ``n_valid``
    (bucket padding of the final chunk) are redirected to the null page
    and their outputs are garbage the caller never reads."""
    T = h.shape[1]
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = jnp.einsum("bsd,dhk->bhsk", h, ap["wq"].astype(h.dtype))
    k = jnp.einsum("bsd,dhk->bhsk", h, ap["wk"].astype(h.dtype))
    v = jnp.einsum("bsd,dhk->bhsk", h, ap["wv"].astype(h.dtype))
    t_idx = jnp.arange(T)
    abs_pos = offset + t_idx                                 # (T,)
    q = L.apply_rope(q, abs_pos, cfg.rope_theta)
    k = L.apply_rope(k, abs_pos, cfg.rope_theta)

    page_size = pool["k"].shape[1]
    max_pages = page_table.shape[1]
    pages = page_table[0, jnp.clip(abs_pos // page_size, 0, max_pages - 1)]
    pages = jnp.where(t_idx < n_valid, pages, 0)             # pad → null page
    slots = abs_pos % page_size
    kd = _kv_dtype(cfg)
    k_new = pool["k"].at[pages, slots].set(k[0].swapaxes(0, 1).astype(kd))
    v_new = pool["v"].at[pages, slots].set(v[0].swapaxes(0, 1).astype(kd))

    S_tot = max_pages * page_size
    keys = k_new[page_table[0]].reshape(1, S_tot, hkv, hd).astype(h.dtype)
    vals = v_new[page_table[0]].reshape(1, S_tot, hkv, hd).astype(h.dtype)
    qg = q.reshape(1, hkv, hq // hkv, T, hd)
    s = jnp.einsum("bhgqd,bshd->bhgqs", qg, keys,
                   preferred_element_type=jnp.float32) / jnp.sqrt(hd)
    causal = jnp.arange(S_tot)[None, :] <= abs_pos[:, None]  # (T,S_tot)
    s = jnp.where(causal[None, None, None, :, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqs,bshd->bhgqd", p.astype(h.dtype),
                     vals).reshape(1, hq, T, hd)
    y = jnp.einsum("bhsk,hkd->bsd", out, ap["wo"].astype(h.dtype))
    return y, {"k": k_new, "v": v_new}


def _paged_prefill(params, pools, tokens, page_table, offset, n_valid, *,
                   cfg: ModelConfig):
    """tokens (1,T) int32, one chunk of one request. Returns
    (last-valid-token logits (1,1,V), new pools)."""
    TRACE_COUNTS["prefill"] += 1
    unit, _ = pattern_unit(cfg)
    x = L.embed(params["embed"], tokens)

    def unit_body(x, xs):
        stack_slice, pool_slice = xs
        new_pools = {}
        for p, kind in enumerate(unit):
            bp = stack_slice[f"pos{p}"]
            h = L.rmsnorm(bp["norm1"], x, cfg.norm_eps)
            y, new_pool = _paged_attn_prefill(bp["attn"], h,
                                              pool_slice[f"pos{p}"],
                                              page_table, offset, n_valid,
                                              cfg)
            x = x + y
            h = L.rmsnorm(bp["norm2"], x, cfg.norm_eps)
            if kind == BLOCK_MOE:
                y, _ = M.moe_apply(bp["moe"], h, cfg)
                x = x + y
            else:
                x = x + L.mlp_apply(bp["mlp"], h)
            new_pools[f"pos{p}"] = new_pool
        return x, new_pools

    x, new_pools = jax.lax.scan(unit_body, x, (params["stack"], pools))
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    last = jax.lax.dynamic_slice_in_dim(x, n_valid - 1, 1, axis=1)
    return lm_logits(params, cfg, last), new_pools


# ---------------------------------------------------------------------------
# packed prefill: chunks of several requests in one segment-masked call
# ---------------------------------------------------------------------------

def _paged_attn_prefill_packed(ap, h, pool, seg_ids, positions, pages,
                               slots, page_table, seg_maxpos, cfg, impl):
    """h: (1,T,d) — the packed chunk buffer: several requests' pending
    prompt chunks concatenated, segment ids 1..G in contiguous runs
    (0 = bucket padding). Writes every token's K/V at its per-token
    destination ``(pages[t], slots[t])`` — each segment's scatter lands
    in its own page table; pads land on the null page — then attends
    each token over its OWN segment's gathered cache with a causal mask
    on absolute positions. Exactly the sequential ``_paged_attn_prefill``
    math applied per segment: one call instead of G."""
    T = h.shape[1]
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = jnp.einsum("bsd,dhk->bhsk", h, ap["wq"].astype(h.dtype))
    k = jnp.einsum("bsd,dhk->bhsk", h, ap["wk"].astype(h.dtype))
    v = jnp.einsum("bsd,dhk->bhsk", h, ap["wv"].astype(h.dtype))
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)

    kd = _kv_dtype(cfg)
    k_new = pool["k"].at[pages, slots].set(k[0].swapaxes(0, 1).astype(kd))
    v_new = pool["v"].at[pages, slots].set(v[0].swapaxes(0, 1).astype(kd))

    if impl == "pallas":
        from repro.kernels import ops as kops
        out = kops.flash_prefill_paged(
            q[0].swapaxes(0, 1), k_new.astype(h.dtype),
            v_new.astype(h.dtype), page_table, seg_maxpos, seg_ids,
            positions)                                   # (T, Hq, hd)
        out = out.swapaxes(0, 1)[None]                   # (1, Hq, T, hd)
    else:
        G = page_table.shape[0]
        page_size = pool["k"].shape[1]
        S_tot = page_table.shape[1] * page_size
        keys = k_new[page_table].reshape(G, S_tot, hkv, hd).astype(h.dtype)
        vals = v_new[page_table].reshape(G, S_tot, hkv, hd).astype(h.dtype)
        seg_row = jnp.clip(seg_ids - 1, 0, G - 1)        # pad -> row 0
        keys_t = keys[seg_row]                           # (T, S_tot, hkv, hd)
        vals_t = vals[seg_row]
        qg = q[0].reshape(hkv, hq // hkv, T, hd)
        s = jnp.einsum("hgtd,tshd->hgts", qg, keys_t,
                       preferred_element_type=jnp.float32) / jnp.sqrt(hd)
        kpos = jnp.arange(S_tot)
        # causal over absolute positions within the token's own segment;
        # pad tokens (seg 0) mask everything — finite NEG_INF keeps their
        # garbage rows NaN-free (the caller never reads them)
        mask = jnp.logical_and(kpos[None, :] <= positions[:, None],
                               (seg_ids > 0)[:, None])   # (T, S_tot)
        s = jnp.where(mask[None, None, :, :], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("hgts,tshd->hgtd", p.astype(h.dtype),
                         vals_t).reshape(1, hq, T, hd)
    y = jnp.einsum("bhsk,hkd->bsd", out, ap["wo"].astype(h.dtype))
    return y, {"k": k_new, "v": v_new}


def _paged_prefill_packed(params, pools, tokens, seg_ids, positions, pages,
                          slots, page_table, seg_maxpos, last_idx, *,
                          cfg: ModelConfig, impl: str):
    """tokens (1,T) int32 packed chunk buffer. Returns (per-segment
    last-valid-token logits (1,G,V), new pools) — row g is only
    meaningful when segment g+1 finished its context this call."""
    TRACE_COUNTS["prefill_packed"] += 1
    unit, _ = pattern_unit(cfg)
    x = L.embed(params["embed"], tokens)

    def unit_body(x, xs):
        stack_slice, pool_slice = xs
        new_pools = {}
        for p, kind in enumerate(unit):
            bp = stack_slice[f"pos{p}"]
            h = L.rmsnorm(bp["norm1"], x, cfg.norm_eps)
            y, new_pool = _paged_attn_prefill_packed(
                bp["attn"], h, pool_slice[f"pos{p}"], seg_ids, positions,
                pages, slots, page_table, seg_maxpos, cfg, impl)
            x = x + y
            h = L.rmsnorm(bp["norm2"], x, cfg.norm_eps)
            if kind == BLOCK_MOE:
                y, _ = M.moe_apply(bp["moe"], h, cfg)
                x = x + y
            else:
                x = x + L.mlp_apply(bp["mlp"], h)
            new_pools[f"pos{p}"] = new_pool
        return x, new_pools

    x, new_pools = jax.lax.scan(unit_body, x, (params["stack"], pools))
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    xg = jnp.take(x[0], last_idx, axis=0)[None]          # (1, G, d)
    return lm_logits(params, cfg, xg), new_pools


# ---------------------------------------------------------------------------
# jit cache (module-level: fresh runtimes/engines reuse compiles)
# ---------------------------------------------------------------------------

def _cfg_key(cfg: ModelConfig) -> Tuple:
    return (cfg.name, cfg.n_layers, cfg.d_model, cfg.n_heads,
            cfg.n_kv_heads, cfg.resolved_head_dim, cfg.vocab_size,
            str(cfg.dtype), str(getattr(cfg, "kv_cache_dtype", None)),
            float(cfg.rope_theta), float(cfg.norm_eps))


def _decode_fn(cfg: ModelConfig, impl: str):
    key = ("decode", _cfg_key(cfg), impl)
    if key not in _JIT_CACHE:
        _JIT_CACHE[key] = jax.jit(
            functools.partial(_paged_decode, cfg=cfg, impl=impl))
    return _JIT_CACHE[key]


def _prefill_fn(cfg: ModelConfig):
    key = ("prefill", _cfg_key(cfg))
    if key not in _JIT_CACHE:
        _JIT_CACHE[key] = jax.jit(
            functools.partial(_paged_prefill, cfg=cfg))
    return _JIT_CACHE[key]


def _prefill_packed_fn(cfg: ModelConfig, impl: str):
    key = ("prefill_packed", _cfg_key(cfg), impl)
    if key not in _JIT_CACHE:
        _JIT_CACHE[key] = jax.jit(
            functools.partial(_paged_prefill_packed, cfg=cfg, impl=impl))
    return _JIT_CACHE[key]


def trace_counts() -> Dict[str, int]:
    """Compiles observed so far per entry point (trace-time counters)."""
    return dict(TRACE_COUNTS)


# ---------------------------------------------------------------------------
# runtime object
# ---------------------------------------------------------------------------

class PagedRuntime:
    """Owns the device pools and threads them functionally through the
    jitted paged decode / prefill steps. Host-side page accounting lives
    in ``PagedKVCache`` (the engine owns that); this class only trusts
    the page tables it is handed."""

    def __init__(self, params, cfg: ModelConfig, *, num_pages: int,
                 page_size: int, impl: str = "reference", mesh=None):
        check_paged_support(cfg)
        self.params = params
        self.cfg = cfg
        self.num_pages = num_pages
        self.page_size = page_size
        self.impl = impl
        self.mesh = mesh
        self.pools = init_pools(cfg, num_pages, page_size)

    def _ctx(self):
        if self.mesh is not None:
            return self.mesh
        import contextlib
        return contextlib.nullcontext()

    def decode(self, tokens, page_table, lengths):
        """tokens (B,1), page_table (B,P), lengths (B,) → logits (B,1,V).
        Each request's new token is written at position ``lengths[b]``;
        callers advance their length bookkeeping by 1 afterwards."""
        fn = _decode_fn(self.cfg, self.impl)
        with self._ctx():
            logits, self.pools = fn(self.params, self.pools,
                                    jnp.asarray(tokens, jnp.int32),
                                    jnp.asarray(page_table, jnp.int32),
                                    jnp.asarray(lengths, jnp.int32))
        return logits

    def prefill_chunk(self, tokens, page_table, offset: int, n_valid: int):
        """tokens (1,T) one bucket-padded chunk of one request's prompt;
        ``offset`` tokens already written, ``n_valid`` real tokens in this
        chunk. Returns last-valid-token logits (1,1,V)."""
        fn = _prefill_fn(self.cfg)
        with self._ctx():
            logits, self.pools = fn(self.params, self.pools,
                                    jnp.asarray(tokens, jnp.int32),
                                    jnp.asarray(page_table, jnp.int32),
                                    jnp.asarray(offset, jnp.int32),
                                    jnp.asarray(n_valid, jnp.int32))
        return logits

    def prefill_packed(self, tokens, seg_ids, positions, pages, slots,
                       page_table, seg_maxpos, last_idx):
        """One packed call over several requests' prompt chunks.

        - ``tokens`` (1,T): concatenated chunks, bucket-padded with 0s;
        - ``seg_ids`` (T,): 1..G in contiguous runs, 0 for padding;
        - ``positions`` (T,): each token's absolute prompt position
          (0 on pads — pad rows are fully masked regardless);
        - ``pages``/``slots`` (T,): per-token K/V scatter destination
          (null page 0 for pads);
        - ``page_table`` (G,P): segment g+1's pages, null-padded;
        - ``seg_maxpos`` (G,): max absolute position per segment
          (unused rows may repeat a live row — logits are gathered);
        - ``last_idx`` (G,): packed index of each segment's final valid
          token (0 for unused rows).

        Returns per-segment logits (1,G,V) at ``last_idx`` — row g is
        the next-token distribution only for segments that completed
        their context in this call.
        """
        fn = _prefill_packed_fn(self.cfg, self.impl)
        with self._ctx():
            logits, self.pools = fn(self.params, self.pools,
                                    jnp.asarray(tokens, jnp.int32),
                                    jnp.asarray(seg_ids, jnp.int32),
                                    jnp.asarray(positions, jnp.int32),
                                    jnp.asarray(pages, jnp.int32),
                                    jnp.asarray(slots, jnp.int32),
                                    jnp.asarray(page_table, jnp.int32),
                                    jnp.asarray(seg_maxpos, jnp.int32),
                                    jnp.asarray(last_idx, jnp.int32))
        return logits
