"""Hetero-aware traffic splitting — Poplar's Algorithm 1 economics
applied to the two serving phases.

Training has one currency (samples/sec); serving has two, and they price
differently per device class:

- **decode** is HBM-bandwidth-bound (each step re-reads the parameters
  plus every live request's KV pages), so decode capacity follows
  ``core/profiler.decode_profiles``'s analytical model through
  ``core/planner.plan_serve`` — the finish-together wave allocator sizes
  each class's decode slots;
- **prefill** is compute-bound (a full forward over the prompt), so
  prefill capacity follows ``peak_tflops · mfu / (2 · active_params)``
  tokens/sec — the same arithmetic-intensity split vLLM-class engines
  exploit when they separate prefill and decode scheduling.

On a skewed cluster the two rankings disagree (a V100 beats a T4 by ~4x
on HBM but ~2x on compute), so the resulting shares are *not* uniform
and not even proportional to each other — that divergence is what the
engine's router consumes and what the tests pin against
:func:`uniform_split`.

The split is a plan, and plans drift: :func:`drift_report` compares the
engine's observed decode-step EMA against the plan's wave latency
through the PR-5 ``detect_drift`` machinery (baseline-calibrated, so
"CPU container is not the analytical simulator" doesn't read as drift);
the Engine re-splits on sustained drift and, under an arbiter lease,
asks for re-arbitration.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

from repro.core.planner import ServePlan, plan_serve
from repro.core.telemetry import DriftConfig, DriftReport, EMAWindow, detect_drift
from repro.serve.runtime import kv_bytes_per_token


def prefill_tokens_per_sec(dev, cfg) -> float:
    """Compute-bound prefill rate of one device: FLOPs budget over the
    ~2·params FLOPs each prompt token costs in the forward pass."""
    return dev.peak_tflops * 1e12 * dev.mfu / max(2.0 * cfg.active_params, 1.0)


@dataclass
class ClassLane:
    """One device class's serving capacity under the current split."""
    kind: str
    count: int
    decode_slots: int        # concurrent decode requests the class is sized for
    decode_tps: float        # aggregate decode tokens/sec at those slots
    prefill_tps: float       # aggregate compute-bound prefill tokens/sec
    num_pages: int           # KV page budget from the class's memory


@dataclass
class TrafficSplit:
    """Per-device-class shares of the two serving phases."""
    lanes: Dict[str, ClassLane]
    decode_share: Dict[str, float]   # fraction of decode slots per class
    prefill_share: Dict[str, float]  # fraction of prefill tokens per class
    plan: Optional[ServePlan]        # underlying Poplar serve plan (None = uniform)
    cache_len: int
    page_size: int
    strategy: str = "hetero"

    @property
    def decode_slots_total(self) -> int:
        return sum(l.decode_slots for l in self.lanes.values())

    @property
    def num_pages_total(self) -> int:
        return sum(l.num_pages for l in self.lanes.values())

    @property
    def wave_latency(self) -> float:
        return self.plan.wave_latency if self.plan is not None else 0.0

    def describe(self) -> str:
        parts = []
        for kind in sorted(self.lanes):
            l = self.lanes[kind]
            parts.append(
                f"{kind}x{l.count}: decode {self.decode_share[kind]:.0%}"
                f"/{l.decode_slots} slots, prefill "
                f"{self.prefill_share[kind]:.0%}")
        return f"split[{self.strategy}] " + " · ".join(parts)


def _lane_pages(dev, cfg, page_size: int, count: int,
                mem_fraction: float) -> int:
    """Page budget: the class's pooled memory headroom after parameters,
    in units of one page's K+V bytes (floored at one page per device)."""
    per_dev = dev.mem_gb * 1e9 * mem_fraction - cfg.active_params * 2
    page_bytes = kv_bytes_per_token(cfg) * page_size
    return max(int(per_dev // max(page_bytes, 1)), 1) * count


def plan_traffic_split(cluster, cfg, *, requests: int, cache_len: int,
                       page_size: int = 16, mem_fraction: float = 0.6,
                       profile_cache: Optional[Dict] = None) -> TrafficSplit:
    """Price both phases per device class and derive the shares.

    ``requests`` sizes the decode wave the Poplar allocator splits
    (finish-together over the per-class HBM-bound curves); prefill shares
    come straight from the compute rates. Identical devices collapse into
    one lane."""
    plan = plan_serve(cluster, cfg, requests, cache_len,
                      profile_cache=profile_cache)
    by_kind: Dict[str, Dict] = {}
    counts: Dict[str, int] = {}
    for dev in cluster.devices:
        counts[dev.name] = counts.get(dev.name, 0) + 1
        inst = f"{dev.name}#{counts[dev.name]}"
        lane = by_kind.setdefault(dev.name, {"dev": dev, "count": 0,
                                             "slots": 0})
        lane["count"] += 1
        a = plan.allocation.assignments.get(inst)
        lane["slots"] += a.gmbs if a is not None else 0

    lanes: Dict[str, ClassLane] = {}
    for kind, agg in by_kind.items():
        dev, count, slots = agg["dev"], agg["count"], agg["slots"]
        decode_tps = (slots / plan.wave_latency
                      if plan.wave_latency > 0 else 0.0)
        lanes[kind] = ClassLane(
            kind=kind, count=count, decode_slots=slots,
            decode_tps=decode_tps,
            prefill_tps=prefill_tokens_per_sec(dev, cfg) * count,
            num_pages=_lane_pages(dev, cfg, page_size, count, mem_fraction))

    tot_slots = max(sum(l.decode_slots for l in lanes.values()), 1)
    tot_pf = max(sum(l.prefill_tps for l in lanes.values()), 1e-12)
    return TrafficSplit(
        lanes=lanes,
        decode_share={k: l.decode_slots / tot_slots for k, l in lanes.items()},
        prefill_share={k: l.prefill_tps / tot_pf for k, l in lanes.items()},
        plan=plan, cache_len=cache_len, page_size=page_size,
        strategy="hetero")


def uniform_split(cluster, cfg, *, requests: int, cache_len: int,
                  page_size: int = 16,
                  mem_fraction: float = 0.6) -> TrafficSplit:
    """Heterogeneity-blind baseline: every device gets the same share of
    both phases regardless of its specs — what a homogeneous-cluster
    engine would do, and what the skewed-cluster tests beat."""
    by_kind: Dict[str, Dict] = {}
    for dev in cluster.devices:
        lane = by_kind.setdefault(dev.name, {"dev": dev, "count": 0})
        lane["count"] += 1
    n = max(cluster.n, 1)
    lanes: Dict[str, ClassLane] = {}
    for kind, agg in by_kind.items():
        dev, count = agg["dev"], agg["count"]
        slots = max(round(requests * count / n), 1)
        lanes[kind] = ClassLane(
            kind=kind, count=count, decode_slots=slots,
            decode_tps=0.0,
            prefill_tps=prefill_tokens_per_sec(dev, cfg) * count,
            num_pages=_lane_pages(dev, cfg, page_size, count, mem_fraction))
    return TrafficSplit(
        lanes=lanes,
        decode_share={k: l.count / n for k, l in lanes.items()},
        prefill_share={k: l.count / n for k, l in lanes.items()},
        plan=None, cache_len=cache_len, page_size=page_size,
        strategy="uniform")


def drift_report(split: TrafficSplit, window: EMAWindow,
                 config: DriftConfig = DriftConfig(),
                 baseline: float = 1.0) -> Optional[DriftReport]:
    """Judge the engine's observed decode-step EMA against the split's
    predicted wave latency. Same contract as ``Session.drift``: None
    until there is a prediction and enough samples; ``baseline`` is the
    observed/predicted ratio calibrated right after the split was made
    (analytical seconds are not container seconds)."""
    if split.plan is None:
        return None
    return detect_drift(window, split.plan.wave_latency, config,
                        baseline=baseline)
