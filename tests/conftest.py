import os
import sys
from pathlib import Path

# NOTE: do NOT set --xla_force_host_platform_device_count here — smoke tests
# and benches must see the real single device; only launch/dryrun.py (and the
# dedicated subprocess tests) use placeholder devices.
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
