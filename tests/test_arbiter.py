"""Multi-tenant ClusterArbiter: partition invariants, priority-ordered
degradation, fault convergence, and the 8-device cotenant acceptance.

The fast tests run the arbiter session-less (registered tenants price
candidate partitions through their planners directly — no jit, no real
Sessions), plus one in-process suspend/resume round trip with live
train + serve Sessions. The slow subprocess test is the full drill from
the issue: train and serve cotenants on the 8-device placeholder mesh,
both tenants report the same 2-device loss (exactly one global
re-arbitration), training continues bit-identically vs a fresh build on
the new lease, and a forced degradation suspends the serve tenant behind
a committed checkpoint that auto-resumes on device return.
"""
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.api import (ClusterArbiter, DeviceLossError,
                       FaultToleranceExhausted, Session, TenantSuspended)
from repro.checkpoint import committed_steps
from repro.configs import get_config
from repro.core.cluster import make_cluster

CFG = get_config("llama-0.5b", reduced=True)


def _c8():
    return make_cluster("c8", [("V100-16G", 4), ("T4-16G", 4)], 12.0)


def _arb(*, train_min=2, serve_min=1, serve_weight=1.0, requests=8,
         max_candidates=4096):
    arb = ClusterArbiter(_c8(), max_candidates=max_candidates)
    arb.register_train("train", CFG, gbs=16, seq=32, zero=3, priority=1,
                       min_devices=train_min)
    arb.register_serve("serve", CFG, requests=requests, cache_len=16,
                       priority=0, min_devices=serve_min,
                       weight=serve_weight)
    return arb


def _check_partition_invariants(arb, rep):
    """Leases are pairwise disjoint and exhaustive over healthy devices,
    device counts match the abstract partition, floors hold for every
    kept tenant."""
    all_devs = [d for devs in rep.devices.values() for d in devs]
    assert len(all_devs) == len(set(all_devs)), "leases overlap"
    assert set(all_devs) == arb.healthy, "leases not exhaustive"
    for name, comp in rep.partition.items():
        t = arb.tenants[name]
        assert sum(comp.values()) >= t.min_devices
        got = {}
        for d in rep.devices[name]:
            got[d.split("#")[0]] = got.get(d.split("#")[0], 0) + 1
        assert got == comp
        assert t.lease is not None and t.lease.n == sum(comp.values())
    for name in rep.suspended:
        t = arb.tenants[name]
        assert t.suspended and t.lease is None and t.lease_devices == ()


# ------------------------------------------------ partition invariants --

def test_leases_disjoint_exhaustive_across_memberships():
    """Property-style sweep: after the initial arbitration and after
    every loss in a shrinking-membership sequence, leases stay disjoint
    and exhaustive over the healthy set with floors honored."""
    arb = _arb(train_min=2, serve_min=1)
    rep = arb.arbitrate(trigger="initial")
    _check_partition_invariants(arb, rep)
    for lost in (["T4-16G#4"], ["V100-16G#4", "T4-16G#3"], ["V100-16G#3"],
                 ["T4-16G#2", "V100-16G#2"]):
        rep = arb.handle_fault("train", DeviceLossError(lost))
        assert rep is not None
        _check_partition_invariants(arb, rep)
    assert len(arb.healthy) == 2               # 8 - 6 lost
    assert arb.arbitrations == 5


def test_even_partition_is_candidate_and_arbiter_beats_it():
    """The naive even split is in the candidate set, so the arbiter's
    pick is >= it structurally — and strictly better on the skewed
    compute-rich/memory-poor fixture (the CI bench gate)."""
    arb = _arb()
    rep = arb.arbitrate(trigger="initial")
    even = arb.evaluate_partition(arb.even_partition())
    assert even is not None
    assert rep.utility >= even
    assert rep.utility > even * 1.05           # skew is real, not noise
    assert rep.candidates > 1
    assert rep.healthy == 8


def test_bare_kind_loss_resolves_to_concrete_instance():
    arb = _arb()
    arb.arbitrate(trigger="initial")
    rep = arb.handle_fault("serve", DeviceLossError(["T4-16G"]))
    assert rep is not None
    assert "T4-16G#4" in arb.lost               # highest-numbered healthy
    assert "T4-16G#4" not in arb.healthy
    _check_partition_invariants(arb, rep)


def test_repeated_bare_kind_loss_resolves_to_distinct_instances():
    """``lose:N:V100+V100`` (the CLI grammar) must take TWO devices: each
    bare kind in one report claims a distinct instance, matching
    ``drop_devices``'s per-name counting."""
    arb = _arb()
    arb.arbitrate(trigger="initial")
    rep = arb.handle_fault("train",
                           DeviceLossError(["V100-16G", "V100-16G"]))
    assert rep is not None
    assert arb.lost == {"V100-16G#4", "V100-16G#3"}
    assert len(arb.healthy) == 6
    _check_partition_invariants(arb, rep)
    # mixed explicit + bare: the bare name skips the explicitly named one
    rep = arb.handle_fault("serve",
                           DeviceLossError(["V100-16G#2", "V100-16G"]))
    assert rep is not None
    assert {"V100-16G#2", "V100-16G#1"} <= arb.lost
    assert len(arb.healthy) == 4
    _check_partition_invariants(arb, rep)


# ------------------------------------- priority-ordered degradation -----

def test_floor_pressure_suspends_lowest_priority_tenant():
    """Floors 4+4 fit 8 devices; losing one leaves 7 < 8, so the
    lower-priority serve tenant is suspended and train keeps its floor."""
    arb = _arb(train_min=4, serve_min=4)
    rep = arb.arbitrate(trigger="initial")
    assert rep.suspended == []
    rep = arb.handle_fault("train", DeviceLossError(["V100-16G#4"]))
    assert rep.suspended == ["serve"]
    assert arb.tenants["serve"].suspended
    assert not arb.tenants["train"].suspended
    assert sum(rep.partition["train"].values()) == 7   # exhaustive: all 7
    _check_partition_invariants(arb, rep)
    kinds = [e.kind for e in arb.events]
    assert "tenant_suspended" in kinds
    # no feasible partition at all -> exhausted, not silent
    for d in ("V100-16G#3", "V100-16G#2", "V100-16G#1", "T4-16G#4"):
        arb.healthy.discard(d)
        arb.lost.add(d)
    with pytest.raises(FaultToleranceExhausted, match="no feasible"):
        arb.arbitrate(trigger="fault")


def test_device_return_resumes_suspended_tenant():
    arb = _arb(train_min=4, serve_min=4)
    arb.arbitrate(trigger="initial")
    arb.handle_fault("train", DeviceLossError(["V100-16G#4"]))
    assert arb.tenants["serve"].suspended
    rep = arb.restore_devices("V100-16G#4")
    assert rep is not None and rep.trigger == "return"
    assert rep.suspended == []
    assert not arb.tenants["serve"].suspended
    _check_partition_invariants(arb, rep)
    # returning a device that was never lost is a no-op
    assert arb.restore_devices("V100-16G#4") is None


# ----------------------------------------------- fault convergence ------

def test_simultaneous_faults_converge_to_one_rearbitration():
    """Both tenants report the same physical 2-device loss; the second
    report finds nothing fresh and converges without a second
    arbitration — no replan storm."""
    arb = _arb()
    arb.arbitrate(trigger="initial")
    assert arb.arbitrations == 1
    lost = ["T4-16G#3", "T4-16G#4"]
    rep = arb.handle_fault("train", DeviceLossError(lost), step_idx=3)
    assert rep is not None and arb.arbitrations == 2
    assert arb.handle_fault("serve", DeviceLossError(lost)) is None
    assert arb.arbitrations == 2
    counts = arb.events.counts()
    assert counts["fault_converged"] == 1
    assert counts["device_loss"] == 1          # one physical event
    # partial overlap: only the fresh instance triggers a new round
    rep = arb.handle_fault("serve", DeviceLossError(["T4-16G#4",
                                                     "T4-16G#2"]))
    assert rep is not None and arb.arbitrations == 3
    assert "T4-16G#2" in arb.lost


# ------------------------------------------- load-driven reallocation ---

def test_serve_load_shift_claims_devices_from_train():
    """With a tiny serve weight, train keeps a share of the fast V100s;
    declaring a load spike (wave size + weight up) re-prices every
    candidate and the next arbitration hands the entire fast tier to
    serve — the serve tenant claims devices from train under load."""
    arb = _arb(serve_weight=1e-3, requests=4)
    rep0 = arb.arbitrate(trigger="initial")
    assert rep0.partition["train"].get("V100-16G", 0) > 0
    arb.update_serve_load("serve", requests=64, weight=1e3)
    rep1 = arb.arbitrate(trigger="drift")
    assert rep1.partition["serve"].get("V100-16G", 0) == 4
    assert rep1.partition["train"].get("V100-16G", 0) == 0
    _check_partition_invariants(arb, rep1)


def test_utility_cache_survives_fault_but_not_drift():
    arb = _arb(max_candidates=64)
    arb.arbitrate(trigger="initial")
    assert len(arb._utility_cache) > 0
    n = len(arb._utility_cache)
    arb.handle_fault("train", DeviceLossError(["T4-16G#4"]))
    assert len(arb._utility_cache) >= n        # kept across membership
    arb.arbitrate(trigger="drift")
    # cleared then repopulated only with the current round's candidates
    assert all(k[0] in arb.tenants for k in arb._utility_cache)


def test_register_validation():
    arb = _arb()
    with pytest.raises(ValueError, match="already registered"):
        arb.register_train("train", CFG, gbs=8, seq=16)
    with pytest.raises(ValueError, match="min_devices"):
        arb.register_train("t2", CFG, gbs=8, seq=16, min_devices=0)


# -------------------------------- live suspend/resume round trip --------

def test_inprocess_suspend_resume_round_trip(tmp_path):
    """Live Sessions on a 4-device cluster: floor pressure suspends the
    serve tenant behind a committed checkpoint, the train tenant replans
    onto the survivors and keeps stepping, and device return resumes
    serve through the checkpoint with working decode."""
    import jax.numpy as jnp
    cluster = make_cluster("c4", [("V100-16G", 2), ("T4-16G", 2)], 12.0)
    arb = ClusterArbiter(cluster)
    arb.register_train("train", CFG, gbs=4, seq=8, priority=1,
                       min_devices=2, ckpt_path=str(tmp_path / "train"))
    arb.register_serve("serve", CFG, requests=4, cache_len=8, priority=0,
                       min_devices=2, ckpt_path=str(tmp_path / "serve"))
    arb.arbitrate(trigger="initial")
    train = Session.build(CFG, arb.leases["train"], gbs=4, seq=8,
                          plan_seq=8, impl="reference")
    serve = Session.build(CFG, arb.leases["serve"], mode="serve",
                          impl="reference")
    arb.attach("train", train, supervised=False)
    arb.attach("serve", serve, supervised=False)
    train.step()

    rep = arb.handle_fault("train", DeviceLossError(["T4-16G#2"]))
    assert rep.suspended == ["serve"]
    assert committed_steps(str(tmp_path / "serve"))    # durable before yield
    with pytest.raises(RuntimeError, match="suspended"):
        serve.init_decode_state(2, 8)
        serve.decode(jnp.zeros((2, 1), jnp.int32),
                     serve.init_decode_state(2, 8))
    assert train.cluster.n == 3                 # replanned onto survivors
    assert np.isfinite(float(train.step()["loss"]))

    rep = arb.restore_devices("T4-16G#2")
    assert not arb.tenants["serve"].suspended
    assert arb.tenants["serve"].lease is not None
    logits, _ = serve.decode(jnp.zeros((2, 1), jnp.int32),
                             serve.init_decode_state(2, 8))
    assert np.isfinite(np.asarray(logits)).all()
    kinds = [e.kind for e in arb.events]
    assert kinds.index("tenant_suspended") < kinds.index("tenant_resumed")


# --------------------------------------- 8-device acceptance (slow) -----

ARB_SUBPROC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import tempfile
from dataclasses import replace
import jax, numpy as np
import jax.numpy as jnp
from repro.api import (ClusterArbiter, DeviceLossError, FaultPolicy,
                       FaultSchedule, Session, TenantSuspended)
from repro.checkpoint import committed_steps, latest_verified_step
from repro.configs import get_config
from repro.core.cluster import make_cluster
from repro.launch.serve import run_wave

cfg = get_config("llama-0.5b", reduced=True)
cfg = replace(cfg, dtype="float32", param_dtype="float32")
root = tempfile.mkdtemp()
kw = dict(gbs=16, seq=16, zero=3, impl="reference", lr=1e-3)

arb = ClusterArbiter(make_cluster("c8", [("V100-16G", 4),
                                         ("T4-16G", 4)], 12.0))
arb.register_train("train", cfg, gbs=16, seq=16, zero=3, priority=1,
                   min_devices=4, ckpt_path=root + "/train")
arb.register_serve("serve", cfg, requests=8, cache_len=12, priority=0,
                   min_devices=2, ckpt_path=root + "/serve")
rep = arb.arbitrate(trigger="initial")
assert not rep.suspended

train = Session.build(cfg, arb.leases["train"], **kw)
serve = Session.build(cfg, arb.leases["serve"], mode="serve",
                      impl="reference")
assert train.mesh.devices.size + serve.mesh.devices.size == 8

# both tenants' schedules report the SAME physical 2-device loss: the
# train step hits it first (step 3), the serve wave's report must
# converge into that round — exactly one re-arbitration for one event
lost = ("T4-16G#3", "T4-16G#4")
tsup = arb.attach("train", train,
                  schedule=FaultSchedule().lose(3, *lost),
                  save_every=2)
ssup = arb.attach("serve", serve,
                  schedule=FaultSchedule().lose(0, *lost))

rng = np.random.default_rng(0)
prompts = jnp.asarray(rng.integers(3, cfg.vocab_size, (8, 8)), jnp.int32)
losses = []
for i in range(6):
    losses.append(float(tsup.step()["loss"]))
    if i == 3:   # first wave after the loss: serve's own schedule fires
        gen, _, _ = ssup.call(lambda: run_wave(ssup.session, prompts, 4))
        assert gen.shape == (8, 4)
tsup.flush()
assert all(np.isfinite(l) for l in losses)
assert int(train.state.step) == 6
assert arb.arbitrations == 2                   # initial + ONE fault round
counts = arb.events.counts()
assert counts["fault_converged"] == 1
assert counts["arbitrated"] == 2
assert counts["arbiter_recovered"] == 2        # each tenant recovered once
# exactly one *physical* loss record (the arbiter's tenant-tagged one);
# the per-tenant supervisor reports fold into that single round
assert len([e for e in arb.events
            if e.kind == "device_loss" and e.tenant]) == 1
assert len(arb.healthy) == 6
held = [d for t in arb.tenants.values() for d in t.lease_devices]
assert sorted(held) == sorted(arb.healthy)
assert not any(d in held for d in lost)
assert committed_steps(root + "/train") == [2, 4, 6]
print("ARB_ONE_REARBITRATION_OK")

# bit-identical continuation: a FRESH session built on the post-fault
# train lease, restored from the step-4 autosave, must replay steps 5-6
# with exactly the losses the supervised run produced
control = Session.build(cfg, arb.tenants["train"].lease, **kw)
control.load(root + "/train", 4)
replay = [float(control.step()["loss"]) for _ in range(2)]
assert replay == losses[4:6], (replay, losses[4:6])
print("ARB_TRAJECTORY_OK")

# forced degradation: two more devices go; floors (4+2) exceed the 4
# survivors, so the serve tenant suspends behind a committed checkpoint
rep = arb.handle_fault("train", DeviceLossError(["V100-16G#3",
                                                 "V100-16G#4"]))
assert rep.suspended == ["serve"]
assert arb.tenants["serve"].suspended
assert latest_verified_step(root + "/serve") is not None
assert train.cluster.n == 4
losses.append(float(tsup.step()["loss"]))      # train survives on 4
assert np.isfinite(losses[-1])
try:
    run_wave(serve, prompts, 2)
    raise SystemExit("suspended serve session must refuse decode")
except RuntimeError as e:
    assert "suspended" in str(e)
print("ARB_DEGRADE_OK")

# device return: one global re-arbitration auto-resumes serve through
# its committed checkpoint; decode works on the new lease
rep = arb.restore_devices("T4-16G#3", "T4-16G#4", "V100-16G#3",
                          "V100-16G#4")
assert rep.trigger == "return" and not rep.suspended
assert not arb.tenants["serve"].suspended
gen, _, _ = ssup.call(lambda: run_wave(ssup.session, prompts, 4))
assert gen.shape == (8, 4)
kinds = [e.kind for e in arb.events]
assert kinds.index("device_loss") < kinds.index("fault_converged")
assert kinds.index("tenant_suspended") < kinds.index("device_return")
assert kinds.index("device_return") < kinds.index("tenant_resumed")
print("ARB_RESUME_OK")
print("ARB_ALL_OK")
"""


@pytest.mark.slow
def test_arbiter_8dev_cotenant_subprocess():
    """Acceptance on the 8-device CPU mesh: train + serve cotenants
    under one arbiter, a shared 2-device loss absorbed by exactly one
    re-arbitration, bit-identical training continuation vs a fresh build
    on the new lease, priority-ordered suspension behind a committed
    checkpoint, and auto-resume on device return."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(Path(__file__).resolve().parents[1] / "src")
    out = subprocess.run([sys.executable, "-c", ARB_SUBPROC_SCRIPT],
                         env=env, capture_output=True, text=True,
                         timeout=900)
    assert "ARB_ALL_OK" in out.stdout, out.stdout + out.stderr
