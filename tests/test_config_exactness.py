"""The assigned-architecture configs must match the assignment table
EXACTLY (layers, d_model, heads, kv heads, d_ff, vocab, MoE/SSM specifics).
Guards against drift while refactoring config machinery."""
import pytest

from repro.configs import get_config

# (arch id, L, d_model, H, kv, d_ff, vocab, extras)
TABLE = [
    ("granite-moe-1b-a400m", 24, 1024, 16, 8, None, 49155,
     dict(moe=(32, 8, 512))),
    ("moonshot-v1-16b-a3b", 48, 2048, 16, 16, None, 163840,
     dict(moe=(64, 6, 1408))),
    ("xlstm-1.3b", 48, 2048, 4, 4, 0, 50304, dict(family="ssm")),
    ("phi3.5-moe-42b-a6.6b", 32, 4096, 32, 8, None, 32064,
     dict(moe=(16, 2, 6400))),
    ("seamless-m4t-medium", 12, 1024, 16, 16, 4096, 256206,
     dict(encdec=True)),
    ("llava-next-34b", 60, 7168, 56, 8, 20480, 64000, dict(vlm=True)),
    ("starcoder2-15b", 40, 6144, 48, 4, 24576, 49152, {}),
    ("internlm2-20b", 48, 6144, 48, 8, 16384, 92544, {}),
    ("minitron-4b", 32, 3072, 24, 8, 9216, 256000, {}),
    ("zamba2-2.7b", 54, 2560, 32, 32, 10240, 32000,
     dict(family="hybrid", ssm_state=64)),
]


@pytest.mark.parametrize("arch,L,d,H,kv,dff,V,extras",
                         TABLE, ids=[t[0] for t in TABLE])
def test_config_matches_assignment(arch, L, d, H, kv, dff, V, extras):
    cfg = get_config(arch)
    assert cfg.n_layers == L
    assert cfg.d_model == d
    assert cfg.n_heads == H
    assert cfg.n_kv_heads == kv
    assert cfg.vocab_size == V
    if dff is not None:
        assert cfg.d_ff == dff
    if "moe" in extras:
        E, K, de = extras["moe"]
        assert cfg.moe is not None
        assert cfg.moe.num_experts == E
        assert cfg.moe.top_k == K
        assert cfg.moe.d_expert == de
    if extras.get("family"):
        assert cfg.family == extras["family"]
    if extras.get("ssm_state"):
        assert cfg.ssm is not None
        assert cfg.ssm.state_dim == extras["ssm_state"]
    if extras.get("encdec"):
        assert cfg.encoder_layers > 0
    if extras.get("vlm"):
        assert cfg.num_image_tokens > 0 and cfg.frontend_dim > 0


def test_reduced_variants_are_smoke_sized():
    for t in TABLE:
        cfg = get_config(t[0], reduced=True)
        assert cfg.n_layers <= 4
        assert cfg.d_model <= 512
        if cfg.moe is not None:
            assert cfg.moe.num_experts <= 4
