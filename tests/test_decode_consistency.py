"""Decode-path correctness: sequential one-token decode must reproduce the
full-sequence forward pass (KV cache / recurrent states are exact)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from dataclasses import replace

from repro.configs import get_config
from repro.models import model as mm


def _tokens(cfg, B, S):
    rng = np.random.default_rng(7)
    return jnp.asarray(rng.integers(3, cfg.vocab_size, (B, S)), jnp.int32)


@pytest.mark.parametrize("arch", ["llama-0.5b", "starcoder2-15b"])
def test_dense_decode_matches_forward(arch):
    cfg = replace(get_config(arch, reduced=True), param_dtype="float32",
                  dtype="float32")
    params, _ = mm.init_model(jax.random.PRNGKey(1), cfg)
    B, S = 2, 12
    toks = _tokens(cfg, B, S)
    hidden, _ = mm.forward(params, cfg, {"tokens": toks})
    full_logits = mm.lm_logits(params, cfg, hidden)        # (B,S,V)

    state = mm.init_decode_state(cfg, B, S)
    outs = []
    for t in range(S):
        lg, state = mm.decode_step(params, cfg, toks[:, t:t + 1], state)
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec_logits, np.float32),
                               np.asarray(full_logits, np.float32),
                               rtol=2e-3, atol=2e-3)


def test_mamba_decode_matches_forward():
    cfg = replace(get_config("zamba2-2.7b", reduced=True),
                  param_dtype="float32", dtype="float32")
    params, _ = mm.init_model(jax.random.PRNGKey(1), cfg)
    B, S = 1, 10
    toks = _tokens(cfg, B, S)
    hidden, _ = mm.forward(params, cfg, {"tokens": toks})
    full_logits = mm.lm_logits(params, cfg, hidden)
    state = mm.init_decode_state(cfg, B, S)
    outs = []
    for t in range(S):
        lg, state = mm.decode_step(params, cfg, toks[:, t:t + 1], state)
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec_logits, np.float32),
                               np.asarray(full_logits, np.float32),
                               rtol=5e-3, atol=5e-3)


def test_slstm_decode_matches_forward():
    cfg = replace(get_config("xlstm-1.3b", reduced=True),
                  param_dtype="float32", dtype="float32")
    params, _ = mm.init_model(jax.random.PRNGKey(1), cfg)
    B, S = 1, 8
    toks = _tokens(cfg, B, S)
    hidden, _ = mm.forward(params, cfg, {"tokens": toks})
    full_logits = mm.lm_logits(params, cfg, hidden)
    state = mm.init_decode_state(cfg, B, S)
    outs = []
    for t in range(S):
        lg, state = mm.decode_step(params, cfg, toks[:, t:t + 1], state)
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec_logits, np.float32),
                               np.asarray(full_logits, np.float32),
                               rtol=5e-3, atol=5e-3)


def test_fp8_kv_cache_decode_close_to_bf16():
    """fp8 cache storage (§Perf/P2 follow-up) must track the full-precision
    decode within fp8 quantization error."""
    cfg = replace(get_config("llama-0.5b", reduced=True),
                  param_dtype="float32", dtype="float32")
    cfg8 = replace(cfg, kv_cache_dtype="float8_e4m3fn")
    params, _ = mm.init_model(jax.random.PRNGKey(1), cfg)
    B, S = 2, 10
    toks = _tokens(cfg, B, S)

    def run(c):
        state = mm.init_decode_state(c, B, S)
        outs = []
        for t in range(S):
            lg, state = mm.decode_step(params, c, toks[:, t:t + 1], state)
            outs.append(lg[:, 0])
        return jnp.stack(outs, axis=1)

    ref = np.asarray(run(cfg), np.float32)
    q8 = np.asarray(run(cfg8), np.float32)
    assert mm.init_decode_state(cfg8, B, S)["layers"]["pos0"]["k"].dtype == \
        jnp.float8_e4m3fn
    # fp8 e4m3 has ~2 decimal digits; logits should stay close in rank
    err = np.abs(ref - q8) / (np.abs(ref) + 1.0)
    assert np.median(err) < 0.05, float(np.median(err))
    assert np.isfinite(q8).all()


def test_sliding_window_decode_ring_buffer():
    """With window W, decode beyond W must equal a forward pass that masks
    tokens older than W (ring-buffer cache correctness)."""
    cfg = replace(get_config("llama-0.5b", reduced=True),
                  param_dtype="float32", dtype="float32")
    W = 4
    B, S = 1, 10
    toks = _tokens(cfg, B, S)
    params, _ = mm.init_model(jax.random.PRNGKey(1), cfg)
    hidden, _ = mm.forward(params, cfg, {"tokens": toks}, window=W)
    full_logits = mm.lm_logits(params, cfg, hidden)
    state = mm.init_decode_state(cfg, B, W)  # cache only W slots
    outs = []
    for t in range(S):
        lg, state = mm.decode_step(params, cfg, toks[:, t:t + 1], state,
                                   window=W)
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec_logits, np.float32),
                               np.asarray(full_logits, np.float32),
                               rtol=2e-3, atol=2e-3)
