"""Distributed flash-decode (§Perf/P2) correctness: the sequence-sharded
shard_map path must match the single-device reference decode exactly."""
import os
import subprocess
import sys
from pathlib import Path

import pytest

SUBPROC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
import numpy as np
from repro.configs.base import ModelConfig
from repro.core.sharding import MeshRules, use_rules
from repro.models import layers as L
from repro.models.param import split

cfg = ModelConfig(name="t", family="dense", n_layers=1, d_model=64,
                  n_heads=8, n_kv_heads=2, d_ff=128, vocab_size=128)
params, _ = split(L.attention_init(jax.random.PRNGKey(0), cfg,
                                   dtype=jnp.float32))
B, S = 4, 32
rng = np.random.default_rng(0)
cache = {
    "k": jnp.asarray(rng.normal(size=(B, S, 2, 8)), jnp.float32),
    "v": jnp.asarray(rng.normal(size=(B, S, 2, 8)), jnp.float32),
}
x = jnp.asarray(rng.normal(size=(B, 1, 64)), jnp.float32)

# reference: no rules -> plain softmax path
for index in (0, 5, 17, 31):
    y_ref, c_ref = L.attention_decode(params, x, cache,
                                      jnp.int32(index), cfg)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    rules = MeshRules(mesh, zero_stage=0)
    # kv_heads=2 % model=4 != 0 -> kv_seq sharding -> shard_map path
    assert L.kv_cache_axes.__call__ is not None
    with mesh, use_rules(rules):
        axes = L.kv_cache_axes(cfg)
        assert axes[1] == "kv_seq", axes
        y_sh, c_sh = jax.jit(
            lambda p, xv, c, i: L.attention_decode(p, xv, c, i, cfg)
        )(params, x, cache, jnp.int32(index))
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_sh),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(c_ref["k"]), np.asarray(c_sh["k"]),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(c_ref["v"]), np.asarray(c_sh["v"]),
                               rtol=1e-6, atol=1e-6)
    print("index", index, "OK")

# ring-buffer (windowed) slots
for index in (3, 40, 63):
    y_ref, c_ref = L.attention_decode(params, x, cache, jnp.int32(index),
                                      cfg, window=S)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    rules = MeshRules(mesh, zero_stage=0)
    with mesh, use_rules(rules):
        y_sh, c_sh = jax.jit(
            lambda p, xv, c, i: L.attention_decode(p, xv, c, i, cfg,
                                                   window=S)
        )(params, x, cache, jnp.int32(index))
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_sh),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(c_ref["k"]), np.asarray(c_sh["k"]),
                               rtol=1e-6, atol=1e-6)
    print("window index", index, "OK")
print("SHARDED_DECODE_OK")
"""


@pytest.mark.slow
def test_sharded_flash_decode_matches_reference_8dev():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(Path(__file__).resolve().parents[1] / "src")
    out = subprocess.run([sys.executable, "-c", SUBPROC_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert "SHARDED_DECODE_OK" in out.stdout, out.stdout + out.stderr
