"""Elastic Session runtime: measured profiling feeding the planner,
step-time telemetry + drift detection, live re-planning with cross-mesh
state resharding, and checkpoint restore onto a different cluster than
the one that wrote it.

The 8-device acceptance paths (measured-profile provenance on the 8-dev
CPU mesh, drop-two-devices replan, 8-dev stage-3 checkpoint -> 4-dev
restore with bit-identical params/opt) run in a subprocess with
placeholder XLA host devices; everything else runs in-process on the
real single device.
"""
import os
import subprocess
import sys
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.api import DriftConfig, EMAWindow, ProbeHarness, Session
from repro.configs import get_config
from repro.core.cluster import make_cluster
from repro.core.telemetry import detect_drift
from repro.launch.mesh import make_debug_mesh


# ----------------------------------------------------------- telemetry --

def test_ema_window_discards_warmup_then_smooths():
    w = EMAWindow(alpha=0.5, warmup=2)
    w.record(100.0)                       # compile step: discarded
    w.record(90.0)                        # discarded
    assert w.value is None and w.skipped == 2
    w.record(1.0)
    assert w.value == 1.0 and w.count == 1
    w.record(3.0)
    assert w.value == pytest.approx(2.0)  # 0.5*3 + 0.5*1
    w.reset()
    assert w.value is None and w.count == 0 and w.skipped == 0


def test_drift_detector_bands():
    w = EMAWindow(warmup=0)
    for _ in range(3):
        w.record(1.0)
    cfg = DriftConfig(threshold=0.5, min_samples=3)
    # in band
    rep = detect_drift(w, 0.8, cfg)
    assert rep is not None and not rep.drifted
    # too slow
    rep = detect_drift(w, 0.5, cfg)
    assert rep.drifted and rep.ratio == pytest.approx(2.0)
    assert "slower" in rep.reason
    # too fast (plan underuses the cluster)
    rep = detect_drift(w, 4.0, cfg)
    assert rep.drifted and rep.ratio == pytest.approx(0.25)
    # imbalance context from the plan's predicted busy times
    rep = detect_drift(w, 1.0, cfg, {"a": 1.0, "b": 2.0})
    assert rep.predicted_imbalance == pytest.approx(2.0)
    # substrate calibration: a 100x structural observed/predicted constant
    # is nominal, not drift; a further 2x slowdown on top of it is
    rep = detect_drift(w, 0.01, cfg, baseline=100.0)
    assert not rep.drifted and rep.ratio == pytest.approx(1.0)
    rep = detect_drift(w, 0.01, cfg, baseline=50.0)
    assert rep.drifted and rep.ratio == pytest.approx(2.0)


def test_drift_detector_withholds_judgement():
    w = EMAWindow(warmup=0)
    cfg = DriftConfig(min_samples=3)
    assert detect_drift(w, 1.0, cfg) is None          # no samples
    w.record(5.0)
    assert detect_drift(w, 1.0, cfg) is None          # too few samples
    w.record(5.0)
    w.record(5.0)
    assert detect_drift(w, None, cfg) is None         # unplanned session
    assert detect_drift(w, 1.0, cfg).drifted


# ---------------------------------------- profiler satellites (no jax) --

def _analytical_runner(dev="V100-16G", stage=0, n=4, noise=0.0):
    from repro.core.cluster import CATALOG
    from repro.core.profiler import AnalyticalRunner
    from repro.core.workload import MemoryModel, train_flops_per_token

    cfg = get_config("llama-0.5b")
    fps = train_flops_per_token(cfg, 4096) * 4096
    return AnalyticalRunner(CATALOG[dev], MemoryModel(cfg, 4096, stage, n),
                            fps, stage, noise=noise)


def test_noisy_profiles_reproduce_across_processes():
    """Satellite: the noise rng must be seeded from a *stable* hash of the
    spec name (zlib.crc32), not hash(str) which varies with
    PYTHONHASHSEED — a re-plan in a fresh process must reproduce the same
    noisy profile."""
    r = _analytical_runner(noise=0.05)
    times = [r.compute_time(b) for b in (1, 2, 4)]
    # fresh runner instance: same draw sequence (rng reseeds per instance)
    r2 = _analytical_runner(noise=0.05)
    assert [r2.compute_time(b) for b in (1, 2, 4)] == times

    script = (
        "from repro.configs import get_config\n"
        "from repro.core.cluster import CATALOG\n"
        "from repro.core.profiler import AnalyticalRunner\n"
        "from repro.core.workload import MemoryModel, train_flops_per_token\n"
        "cfg = get_config('llama-0.5b')\n"
        "r = AnalyticalRunner(CATALOG['V100-16G'], "
        "MemoryModel(cfg, 4096, 0, 4), "
        "train_flops_per_token(cfg, 4096) * 4096, 0, noise=0.05)\n"
        "print(repr([r.compute_time(b) for b in (1, 2, 4)]))\n")
    env = dict(os.environ)
    env["PYTHONPATH"] = str(Path(__file__).resolve().parents[1] / "src")
    env["PYTHONHASHSEED"] = "12345"     # a different str-hash universe
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=120)
    assert out.returncode == 0, out.stderr
    assert eval(out.stdout.strip()) == times


def test_profile_cluster_dedupes_identical_devices():
    """Satellite: N identical devices run Algorithm 1 once, share the
    profile, and the saved probes are reported."""
    from repro.core.profiler import probes_saved, profile_cluster

    runners = {f"V100-16G#{i}": _analytical_runner() for i in range(1, 5)}
    profs = profile_cluster(runners, 0)
    reps = [p for p in profs.values() if p.shared_from is None]
    shared = [p for p in profs.values() if p.shared_from is not None]
    assert len(reps) == 1 and len(shared) == 3
    rep = reps[0]
    for p in shared:
        assert p.shared_from == rep.name
        assert p.probes == 0                      # no re-execution
        assert p.points == rep.points and p.mbs == rep.mbs
    assert probes_saved(profs) == 3 * rep.probes
    # opting out reproduces the undeduped cost
    full = profile_cluster(runners, 0, dedupe=False)
    assert sum(p.probes for p in full.values()) == 4 * rep.probes
    assert probes_saved(full) == 0


def test_plan_reports_dedupe_savings():
    from repro.core.planner import plan

    c = make_cluster("t", [("V100-16G", 2), ("T4-16G", 2)], 12.0)
    p = plan(c, get_config("llama-0.5b"), gbs=64, seq_len=4096,
             zero_stage=0)
    # 2 kinds profiled, 2 duplicates shared
    assert p.profiling_probes_saved > 0
    assert p.profiling_probes_saved == sum(
        prof.probes for prof in p.profiles.values()
        if prof.shared_from is None)
    assert p.profile_source == "analytical"
    assert all(pr.source == "analytical" for pr in p.profiles.values())


# -------------------------------------------------- measured profiling --

def test_probe_harness_times_real_steps_and_models_memory():
    cfg = get_config("llama-0.5b", reduced=True)
    h = ProbeHarness(cfg, seq_len=8, zero_stage=0)
    h.step(1)                                  # must execute, not raise
    h.step(2)
    assert h.compiles == 2
    h.step(2)                                  # cached: no new compile
    assert h.compiles == 2
    m0, m1, m4 = h.memory_bytes(0), h.memory_bytes(1), h.memory_bytes(4)
    assert m0 < m1 < m4                        # linear in batch
    assert m4 - m1 == pytest.approx(3 * (m1 - m0), rel=1e-6)


def test_measured_profile_feeds_allocation():
    """Session.build(profile='measured'): the plan's timings must come
    from MeasuredRunner wall time (provenance), dedupe must collapse
    Algorithm 1 to one run per device kind, and the allocation must
    still account for every sample."""
    cfg = get_config("llama-0.5b", reduced=True)
    cluster = make_cluster("m", [("T4-16G", 2)], 12.0)
    sess = Session.build(cfg, cluster, gbs=4, seq=8, zero=0,
                         impl="reference", profile="measured", probe_cap=2)
    assert sess.plan.profile_source == "measured"
    assert all(p.source == "measured" for p in sess.plan.profiles.values())
    assert sess.plan.profiling_probes_saved > 0        # 2nd T4 shared
    assert sess.plan.allocation.total_batch == 4
    assert sess.describe()["plan"]["profile_source"] == "measured"
    m = sess.step()
    assert np.isfinite(float(m["loss"]))


def test_probe_harness_memory_base_is_stage_sharded():
    """The OOM oracle's model-state base must honour the ZeRO stage: the
    probe compiles an unsharded 1-device step, so taking its resident
    bytes verbatim would overcount a stage>=1 deployment ~n_workers-fold
    and reject configurations that actually fit. Only the per-sample
    slope is measured; the base comes from the stage-aware MemoryModel."""
    cfg = get_config("llama-0.5b", reduced=True)
    h0 = ProbeHarness(cfg, seq_len=8, zero_stage=0, n_workers=8)
    h3 = ProbeHarness(cfg, seq_len=8, zero_stage=3, n_workers=8)
    base0, base3 = h0.memory_bytes(0), h3.memory_bytes(0)
    assert base3 < base0                       # 16P replicated vs ~16P/8
    from repro.core.workload import MemoryModel
    assert base3 == pytest.approx(
        MemoryModel(cfg, 8, 3, 8, cfg.remat).bytes_at_batch(0))


def test_build_rejects_unknown_profile():
    cfg = get_config("llama-0.5b", reduced=True)
    with pytest.raises(ValueError, match="profile"):
        Session.build(cfg, None, profile="psychic", mesh=make_debug_mesh(1))


# ------------------------------------------------------------- replan --

def test_replan_unchanged_cluster_preserves_trajectory():
    """replan() on an unchanged cluster must be a no-op for training
    semantics: same plan, same layout, same batches, bit-identical loss
    sequence vs an unperturbed control run."""
    cfg = get_config("llama-0.5b", reduced=True)
    kw = dict(gbs=8, seq=16, zero=1, impl="reference", lr=1e-3)

    control = Session.build(cfg, make_cluster("t", [("V100-16G", 2),
                                                    ("T4-16G", 2)], 12.0),
                            **kw)
    losses_control = [float(control.step()["loss"]) for _ in range(6)]

    elastic = Session.build(cfg, make_cluster("t", [("V100-16G", 2),
                                                    ("T4-16G", 2)], 12.0),
                            **kw)
    losses = [float(elastic.step()["loss"]) for _ in range(3)]
    rep = elastic.replan()
    assert rep.trigger == "explicit" and rep.new_devices == 4
    assert rep.plan_seconds >= 0 and rep.reshard_seconds > 0
    assert elastic.replans == 1
    losses += [float(elastic.step()["loss"]) for _ in range(3)]
    assert losses == losses_control


def test_replan_cluster_membership_change():
    """Dropping devices re-plans the allocation over the survivors and
    reshards the live state — training continues finite, same params."""
    cfg = get_config("llama-0.5b", reduced=True)
    sess = Session.build(cfg, make_cluster("t", [("V100-16G", 2),
                                                 ("T4-16G", 2)], 12.0),
                         gbs=8, seq=16, zero=1, impl="reference")
    for _ in range(2):
        sess.step()
    before = jax.tree.map(np.asarray, sess.state.params)
    rep = sess.replan(cluster=make_cluster("t2", [("V100-16G", 2)], 12.0))
    assert rep.trigger == "cluster"
    assert rep.old_devices == 4 and rep.new_devices == 2
    assert sess.cluster.n == 2
    assert len(sess.layout.group_names) == 2
    assert sum(a.gmbs for a in sess.plan.allocation.assignments.values()) == 8
    # the reshard moved, not mutated, the state
    for a, b in zip(jax.tree.leaves(before),
                    jax.tree.leaves(sess.state.params)):
        np.testing.assert_array_equal(a, np.asarray(b))
    assert int(sess.state.step) == 2
    assert np.isfinite(float(sess.step()["loss"]))


def test_maybe_replan_fires_only_on_drift():
    cfg = get_config("llama-0.5b", reduced=True)
    # probe_cap bounds the measured re-profiling a drift-triggered
    # replan performs (each probed batch size is one jit compile)
    sess = Session.build(cfg, make_cluster("t", [("T4-16G", 2)], 12.0),
                         gbs=4, seq=8, zero=0, impl="reference",
                         probe_cap=2)
    for _ in range(5):
        sess.step()
    # step() calibrated the substrate constant (simulated V100 clock vs
    # this host's wall clock) as soon as the window was judgeable
    assert sess._drift_baseline is not None
    # deterministic re-calibration: constant synthetic step times make
    # the EMA (and hence the baseline ratio) exact, so steady state is
    # NOT drift under the default band regardless of host noise...
    sess.telemetry.reset()
    sess._drift_baseline = None
    for _ in range(4):                         # 1 warmup + min_samples
        sess.telemetry.record(0.123)
    rep = sess.drift()                         # calibrates, then judges
    assert rep is not None and not rep.drifted
    assert rep.ratio == pytest.approx(1.0)
    assert sess.maybe_replan() is None
    assert sess.replans == 0
    # ...but a genuine slowdown relative to that baseline is: simulate
    # steps suddenly taking 10x the calibrated time
    for _ in range(4):
        sess.telemetry.record(1.23)
    rep = sess.maybe_replan()
    assert rep is not None and rep.trigger == "drift"
    assert rep.drift is not None and rep.drift.drifted
    assert "slower" in rep.drift.reason
    assert sess.replans == 1
    assert sess.telemetry.count == 0           # window reset after replan
    assert sess._drift_baseline is None        # new plan recalibrates
    # drift means the old timings mispredicted: the re-plan consumed live
    # measurements, not the analytical curves that just failed
    assert rep.profile_source == "measured"
    assert sess.profile == "measured"


def test_replan_failure_leaves_session_untouched(monkeypatch):
    """A planner failure mid-replan (e.g. SimOOM on a shrunken cluster)
    must not half-update the session: gbs/profile/plan/layout keep their
    pre-call values and training continues on the old configuration."""
    from repro.core.profiler import SimOOM

    cfg = get_config("llama-0.5b", reduced=True)
    sess = Session.build(cfg, make_cluster("t", [("T4-16G", 2)], 12.0),
                         gbs=4, seq=8, zero=0, impl="reference")
    sess.step()
    old_plan, old_layout = sess.plan, sess.layout

    def boom(*a, **k):
        raise SimOOM("no feasible stage")

    monkeypatch.setattr(sess, "_run_planner", boom)
    with pytest.raises(SimOOM):
        sess.replan(cluster=make_cluster("t1", [("T4-16G", 1)], 12.0),
                    gbs=32, profile="measured")
    assert sess.gbs == 4 and sess.profile == "analytical"
    assert sess.plan is old_plan and sess.layout is old_layout
    assert sess.cluster.n == 2 and sess.replans == 0
    assert np.isfinite(float(sess.step()["loss"]))   # still trains


def test_telemetry_sample_every_keeps_async_steps():
    """DriftConfig(sample_every=k): only every k-th step pays the
    telemetry sync; the rest keep JAX async dispatch."""
    cfg = get_config("llama-0.5b", reduced=True)
    sess = Session.build(cfg, make_cluster("t", [("T4-16G", 2)], 12.0),
                         gbs=4, seq=8, zero=0, impl="reference",
                         drift=DriftConfig(sample_every=3))
    for _ in range(6):
        sess.step()
    # steps 0 and 3 observed: one warmup-discarded, one in the EMA
    assert sess.telemetry.skipped + sess.telemetry.count == 2


def test_replan_commit_failure_rolls_back(monkeypatch):
    """A failure *after* planning (re-jit, device_put, ...) must roll the
    session back onto the old mesh/rules/layout with the state re-placed
    on the old shardings — half-migrated is worse than failed."""
    cfg = get_config("llama-0.5b", reduced=True)
    sess = Session.build(cfg, make_cluster("t", [("T4-16G", 2)], 12.0),
                         gbs=4, seq=8, zero=0, impl="reference")
    sess.step()
    old_mesh, old_rules, old_layout = sess.mesh, sess.rules, sess.layout
    before = jax.tree.map(np.asarray, sess.state.params)

    def boom():
        raise RuntimeError("jit exploded")

    monkeypatch.setattr(sess, "_build_step_fns", boom)
    with pytest.raises(RuntimeError, match="jit exploded"):
        sess.replan(cluster=make_cluster("t1", [("T4-16G", 1)], 12.0))
    assert sess.mesh is old_mesh and sess.rules is old_rules
    assert sess.layout is old_layout and sess.cluster.n == 2
    assert sess.replans == 0
    for a, b in zip(jax.tree.leaves(before),
                    jax.tree.leaves(sess.state.params)):
        np.testing.assert_array_equal(a, np.asarray(b))
    # the old jitted step still drives the old configuration
    assert np.isfinite(float(sess.step()["loss"]))


def test_failed_replan_resets_drift_state(monkeypatch):
    """Satellite (bugfix): a rolled-back replan must also reset the
    telemetry EMA, per-device timers and the drift baseline. Keeping the
    drifted window meant the very next maybe_replan() re-fired on the
    same stale evidence — a failed-replan loop that never gathers a
    fresh sample of reality."""
    cfg = get_config("llama-0.5b", reduced=True)
    sess = Session.build(cfg, make_cluster("t", [("T4-16G", 2)], 12.0),
                         gbs=4, seq=8, plan_seq=8, impl="reference")
    for _ in range(4):
        sess.step()
    # manufacture drift: pretend observed steps are far off the plan
    sess._drift_baseline = 1.0
    for _ in range(4):
        sess.telemetry.record(sess.plan.predicted.iter_time * 10)
    for _ in range(3):
        sess.device_timers.record({"T4-16G#1": 1.0, "T4-16G#2": 4.0})
    rep = sess.drift()
    assert rep.drifted and rep.observed_imbalance > 1.0

    def boom():
        raise RuntimeError("jit exploded")

    monkeypatch.setattr(sess, "_build_step_fns", boom)
    with pytest.raises(RuntimeError, match="jit exploded"):
        sess.replan(trigger="drift")
    # the stale evidence is gone: nothing to re-fire on until fresh
    # samples re-establish drift under the (unchanged) old plan
    assert sess.telemetry.count == 0
    assert sess._drift_baseline is None
    assert sess.device_timers.imbalance() == 1.0
    assert sess.maybe_replan() is None
    assert sess.replans == 0


def test_adhoc_drift_probe_does_not_poison_calibration():
    """drift(config=) with a permissive ad-hoc config may judge however
    it likes, but the *persistent* baseline only calibrates once the
    session's own min_samples is met — one noisy probe must not pin the
    substrate constant for every later maybe_replan()."""
    cfg = get_config("llama-0.5b", reduced=True)
    sess = Session.build(cfg, make_cluster("t", [("T4-16G", 2)], 12.0),
                         gbs=4, seq=8, zero=0, impl="reference")
    sess.telemetry.reset()
    sess._drift_baseline = None
    for _ in range(2):                         # 1 warmup + 1 sample
        sess.telemetry.record(0.5)
    rep = sess.drift(DriftConfig(min_samples=1))
    assert rep is not None                     # the probe judged...
    assert sess._drift_baseline is None        # ...but did not calibrate
    for _ in range(2):                         # reach the session's gate
        sess.telemetry.record(0.5)
    sess.drift()
    assert sess._drift_baseline is not None


def test_replan_mode_guard():
    # serve sessions replan since the multi-tenant arbiter (lease
    # migration = mesh + re-jit, no Poplar search); dryrun still refuses
    cfg = get_config("llama-0.5b", reduced=True)
    sess = Session.build(cfg, mode="dryrun")
    with pytest.raises(RuntimeError, match="train/serve"):
        sess.replan()

    serve = Session.build(cfg, mode="serve", impl="reference")
    rep = serve.replan()                    # no cluster: re-jit in place
    assert rep.trigger == "explicit"
    import jax.numpy as jnp
    tokens = jnp.zeros((1, 1), jnp.int32)
    state = serve.init_decode_state(1, 4)
    logits, _ = serve.decode(tokens, state)
    assert np.all(np.isfinite(np.asarray(logits)))


# ------------------------------------------- 8-device elastic (slow) ----

SUBPROC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
from dataclasses import replace
import jax, jax.numpy as jnp
import numpy as np
from repro.api import Session
from repro.configs import get_config
from repro.core.cluster import make_cluster

cfg = get_config("llama-0.5b", reduced=True)
cfg = replace(cfg, dtype="float32", param_dtype="float32")
C8 = lambda: make_cluster("c8", [("V100-16G", 4), ("T4-16G", 4)], 12.0)
C6 = lambda: make_cluster("c6", [("V100-16G", 4), ("T4-16G", 2)], 12.0)
C4 = lambda: make_cluster("c4", [("V100-16G", 2), ("T4-16G", 2)], 12.0)

# ---- acceptance: measured-profile plan on the 8-dev mesh ----
sess = Session.build(cfg, C8(), gbs=16, seq=16, zero=3, impl="reference",
                     profile="measured", probe_cap=2, lr=1e-3)
assert sess.mesh.devices.size == 8, sess.mesh
assert sess.plan.profile_source == "measured"
assert all(p.source == "measured" for p in sess.plan.profiles.values())
assert sess.plan.profiling_probes_saved > 0
assert sess.plan.allocation.total_batch == 16
m = sess.step()
assert np.isfinite(float(m["loss"]))
print("ELASTIC_MEASURED_OK")

# ---- acceptance: unchanged-cluster replan preserves the trajectory ----
kw = dict(gbs=16, seq=16, zero=3, impl="reference", lr=1e-3)
control = Session.build(cfg, C8(), **kw)
ctl = [float(control.step()["loss"]) for _ in range(6)]
elastic = Session.build(cfg, C8(), **kw)
obs = [float(elastic.step()["loss"]) for _ in range(3)]
rep = elastic.replan()
obs += [float(elastic.step()["loss"]) for _ in range(3)]
assert obs == ctl, (obs, ctl)
print("ELASTIC_TRAJECTORY_OK")

# ---- drop two devices mid-run: replan succeeds, loss stays finite ----
rep = elastic.replan(cluster=C6())
assert rep.old_devices == 8 and rep.new_devices == 6
assert elastic.mesh.devices.size == 6, elastic.mesh
assert sum(a.gmbs for a in
           elastic.plan.allocation.assignments.values()) == 16
tail = [float(elastic.step()["loss"]) for _ in range(3)]
assert all(np.isfinite(l) for l in tail), tail
assert int(elastic.state.step) == 9
print("ELASTIC_DROP2_OK")

# ---- acceptance: 8-dev stage-3 checkpoint -> 4-dev cross-mesh restore --
import tempfile
ckpt = tempfile.mkdtemp()
donor = Session.build(cfg, C8(), **kw)
for _ in range(2):
    donor.step()
donor.save(ckpt)
want_p = jax.tree.map(np.asarray, donor.state.params)
want_o = jax.tree.map(np.asarray, donor.state.opt)

resumed = Session.restore(ckpt, cfg=cfg, cluster=C4())
assert resumed.mesh.devices.size == 4, resumed.mesh
assert resumed.rules.zero_stage == 3
assert int(resumed.state.step) == 2
for a, b in zip(jax.tree.leaves(want_p),
                jax.tree.leaves(resumed.state.params)):
    np.testing.assert_array_equal(a, np.asarray(b))
for a, b in zip(jax.tree.leaves(want_o),
                jax.tree.leaves(resumed.state.opt)):
    np.testing.assert_array_equal(a, np.asarray(b))
# the restored params are really sharded over the 4-device mesh
leaf = jax.tree.leaves(resumed.state.params)[0]
assert len(leaf.sharding.mesh.devices.flatten()) == 4
assert np.isfinite(float(resumed.step()["loss"]))
print("ELASTIC_RESHARD_RESTORE_OK")
print("ELASTIC_ALL_OK")
"""


@pytest.mark.slow
def test_elastic_8dev_subprocess():
    """The acceptance paths on the 8-device CPU mesh: measured-profile
    provenance, trajectory-preserving replan, drop-two-devices elastic
    continuation, and 8-dev stage-3 -> 4-dev cross-mesh restore with
    bit-identical params/opt."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(Path(__file__).resolve().parents[1] / "src")
    out = subprocess.run([sys.executable, "-c", SUBPROC_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert "ELASTIC_ALL_OK" in out.stdout, out.stdout + out.stderr
