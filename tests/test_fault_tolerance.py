"""Fault-tolerant Session runtime: atomic/async checkpointing with
crash-consistent recovery, the deterministic fault-injection harness,
and the supervised step loop (retry / re-plan over survivors / restore
fallback).

The checkpoint protocol tests exercise the exact crash points SIGKILL
could hit (between temp-write and rename, before the manifest merge) via
``SimulatedCrash`` injection and assert that readers only ever observe
fully committed, digest-verified checkpoints. The 8-device acceptance
path (lose two devices mid-run, continue on six, crash-mid-save then
bit-identical restore) runs in a subprocess with placeholder XLA host
devices.
"""
import os
import subprocess
import sys
import threading
import time
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.api import Session
from repro.checkpoint import (AsyncCheckpointWriter, SimulatedCrash,
                              committed_steps, latest_step,
                              latest_verified_step, restore_checkpoint,
                              save_checkpoint, sweep_retention,
                              verify_checkpoint)
from repro.configs import get_config
from repro.core.cluster import make_cluster
from repro.core.faults import (DeviceLossError, FaultPolicy, FaultSchedule,
                               FaultToleranceExhausted, Supervisor,
                               TransientStepError, classify_fault,
                               drop_devices)
from repro.core.telemetry import DeviceTimers


def _params(seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    return {"w": rng.normal(size=(4, 3)).astype(np.float32) * scale,
            "b": np.arange(3, dtype=np.float32) * scale}


# ------------------------------------------------ atomic commit protocol --

def test_sync_save_is_atomic_and_committed(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 0, _params())
    save_checkpoint(d, 5, _params(1))
    assert committed_steps(d) == [0, 5]
    assert latest_step(d) == 5
    assert latest_verified_step(d) == 5
    assert verify_checkpoint(d, 5)
    # no temp residue after a clean commit
    assert not list(tmp_path.glob("*.tmp.*"))


def test_crash_between_temp_write_and_rename_leaves_no_torn_state(tmp_path):
    """SimulatedCrash at payload_rename: the payload temp file exists but
    was never renamed — the directory's committed set is unchanged and
    latest_step still resolves to the previous good checkpoint."""
    d = str(tmp_path)
    save_checkpoint(d, 3, _params())

    def crash_hook(event, step):
        if event == "payload_rename":
            raise SimulatedCrash(f"killed during {event}")

    with pytest.raises(SimulatedCrash):
        save_checkpoint(d, 7, _params(1), io_hook=crash_hook)
    assert committed_steps(d) == [3]
    assert latest_step(d) == 3
    assert latest_verified_step(d) == 3
    # the torn write is invisible to the glob (ckpt_*.npz never matches
    # the .tmp suffix) but its residue is on disk for the retention sweep
    assert list(tmp_path.glob("*.tmp.*"))
    sweep_retention(d, keep_last=5)
    assert not list(tmp_path.glob("*.tmp.*"))
    assert committed_steps(d) == [3]


def test_crash_before_manifest_merge_is_not_committed(tmp_path):
    """The manifest merge is the commit point: a crash after the payload
    and meta renames but before the manifest write leaves files on disk
    that no reader treats as committed."""
    d = str(tmp_path)
    save_checkpoint(d, 1, _params())

    def crash_hook(event, step):
        if event == "manifest_write":
            raise SimulatedCrash("killed before commit point")

    with pytest.raises(SimulatedCrash):
        save_checkpoint(d, 2, _params(1), io_hook=crash_hook)
    assert (tmp_path / "ckpt_00000002.npz").exists()   # orphaned payload
    assert committed_steps(d) == [1]
    assert latest_verified_step(d) == 1


def test_corrupt_payload_falls_back_to_previous_checkpoint(tmp_path):
    """Digest mismatch on the newest checkpoint: restore (step=None)
    skips it and loads the previous committed one bit-identically."""
    d = str(tmp_path)
    good = _params(seed=0)
    save_checkpoint(d, 1, good)
    save_checkpoint(d, 2, _params(seed=1))
    (tmp_path / "ckpt_00000002.npz").write_bytes(b"garbage not a zipfile")
    assert not verify_checkpoint(d, 2)
    assert latest_verified_step(d) == 1

    step, params, _ = restore_checkpoint(d, None, _params())
    assert step == 1
    for k in good:
        np.testing.assert_array_equal(params[k], good[k])
    # asking for the corrupt step explicitly is an error, not silence
    with pytest.raises(ValueError, match="verif"):
        restore_checkpoint(d, 2, _params())


def test_keep_last_retention(tmp_path):
    d = str(tmp_path)
    for s in range(5):
        save_checkpoint(d, s, _params(s), keep_last=2)
    assert committed_steps(d) == [3, 4]
    assert not (tmp_path / "ckpt_00000000.npz").exists()
    assert not (tmp_path / "ckpt_00000000.json").exists()
    assert latest_verified_step(d) == 4


# ------------------------------------------------------- async writer ----

def test_async_save_returns_before_write_completes(tmp_path):
    """The deterministic stall test: the io_hook blocks the background
    write on an Event, proving submit() returned while the commit was
    still in flight — the critical path paid only for the snapshot."""
    d = str(tmp_path)
    gate = threading.Event()
    entered = threading.Event()

    def hook(event, step):
        if event == "payload_write":
            entered.set()
            assert gate.wait(30)

    w = AsyncCheckpointWriter(d, io_hook=hook)
    pending = w.submit(4, _params())
    assert not pending.done                    # write gated, submit returned
    assert entered.wait(30)                    # background thread is inside
    assert latest_step(d) is None              # nothing committed yet
    gate.set()
    assert pending.result(30).endswith("ckpt_00000004.npz")
    assert latest_verified_step(d) == 4
    w.close()


def test_async_writer_retries_io_errors_with_backoff(tmp_path):
    d = str(tmp_path)
    sched = FaultSchedule().fail_ckpt_io(0, times=2)
    events = []
    w = AsyncCheckpointWriter(
        d, io_hook=sched.checkpoint_io, backoff_s=0.01,
        on_event=lambda kind, **kw: events.append(kind))
    pending = w.submit(0, _params())
    assert pending.result(30)
    assert pending.retries == 2
    assert latest_verified_step(d) == 0
    assert events.count("ckpt_io_retry") == 2
    assert events[-1] == "ckpt_committed"
    w.close()


def test_async_writer_exhausts_retries_then_fails(tmp_path):
    d = str(tmp_path)
    sched = FaultSchedule().fail_ckpt_io(0, times=99)
    w = AsyncCheckpointWriter(d, io_hook=sched.checkpoint_io,
                              max_retries=2, backoff_s=0.01)
    pending = w.submit(0, _params())
    with pytest.raises(OSError):
        pending.result(30)
    assert w.errors and latest_step(d) is None
    w.close()


def test_async_crash_mid_save_restores_previous_bit_identically(tmp_path):
    """Crash in the background writer between temp-write and rename: the
    PendingSave surfaces the crash, and restore falls back to the last
    committed checkpoint with bit-identical arrays."""
    d = str(tmp_path)
    good = _params(seed=7)
    save_checkpoint(d, 10, good)
    sched = FaultSchedule().crash_ckpt(11, at="payload_rename")
    w = AsyncCheckpointWriter(d, io_hook=sched.checkpoint_io)
    pending = w.submit(11, _params(seed=8))
    with pytest.raises(SimulatedCrash):
        pending.result(30)
    assert latest_verified_step(d) == 10
    step, params, _ = restore_checkpoint(d, None, _params())
    assert step == 10
    for k in good:
        np.testing.assert_array_equal(params[k], good[k])
    w.close()


# ------------------------------------------------ incremental saves -----

def test_incremental_save_skips_unchanged_leaves(tmp_path):
    """Unchanged arrays are not rewritten: the new manifest entry's
    sources table points them at the prior payload, the new npz holds
    only the changed leaves, and verify/restore follow the indirection
    bit-identically."""
    import json
    d = str(tmp_path)
    p1 = _params()
    save_checkpoint(d, 1, p1, incremental=True)    # no prior: full write
    p2 = dict(p1)
    p2["w"] = p1["w"] * 2.0                        # "b" unchanged
    save_checkpoint(d, 2, p2, incremental=True)

    man = json.loads((tmp_path / "MANIFEST.json").read_text())
    rec = man["steps"]["2"]
    assert rec["sources"] == {"params/b": "ckpt_00000001.npz"}
    with np.load(tmp_path / "ckpt_00000002.npz") as npz:
        assert "params/w" in npz.files and "params/b" not in npz.files
    assert "sources" not in man["steps"]["1"]      # the base is full

    assert verify_checkpoint(d, 1) and verify_checkpoint(d, 2)
    step, params, _ = restore_checkpoint(d, 2, _params())
    assert step == 2
    np.testing.assert_array_equal(params["w"], p2["w"])
    np.testing.assert_array_equal(params["b"], p1["b"])


def test_incremental_chains_collapse_to_origin_file(tmp_path):
    """A leaf unchanged across many saves always sources from the file
    that actually holds its bytes — not a chain of hops through every
    intermediate step."""
    import json
    d = str(tmp_path)
    p = _params()
    save_checkpoint(d, 1, p, incremental=True)
    for s in (2, 3, 4):
        p = dict(p)
        p["w"] = p["w"] + 1.0                      # "b" never changes
        save_checkpoint(d, s, p, incremental=True)
    man = json.loads((tmp_path / "MANIFEST.json").read_text())
    for s in ("2", "3", "4"):
        assert man["steps"][s]["sources"]["params/b"] == "ckpt_00000001.npz"
    step, params, _ = restore_checkpoint(d, 4, _params())
    np.testing.assert_array_equal(params["w"], p["w"])
    np.testing.assert_array_equal(params["b"], _params()["b"])


def test_incremental_resave_of_same_step_is_full(tmp_path):
    """Re-saving step N compares only against steps strictly below N, so
    restore-to-earlier-then-save never self-references."""
    import json
    d = str(tmp_path)
    save_checkpoint(d, 3, _params(), incremental=True)
    save_checkpoint(d, 3, _params(), incremental=True)
    man = json.loads((tmp_path / "MANIFEST.json").read_text())
    assert "sources" not in man["steps"]["3"]
    assert verify_checkpoint(d, 3)


def test_retention_sweep_keeps_referenced_base_payloads(tmp_path):
    """keep_last drops old manifest entries but must not unlink a base
    payload that surviving incremental entries still source from."""
    d = str(tmp_path)
    p = _params()
    save_checkpoint(d, 1, p, incremental=True)
    for s in (2, 3):
        p = dict(p)
        p["w"] = p["w"] + 1.0
        save_checkpoint(d, s, p, incremental=True)
    assert sweep_retention(d, keep_last=2) == [1]      # dropped steps
    assert committed_steps(d) == [2, 3]
    assert (tmp_path / "ckpt_00000001.npz").exists()   # still referenced
    assert not (tmp_path / "ckpt_00000001.json").exists()
    assert verify_checkpoint(d, 3)
    step, params, _ = restore_checkpoint(d, 3, _params())
    np.testing.assert_array_equal(params["b"], _params()["b"])
    np.testing.assert_array_equal(params["w"], p["w"])


def test_async_writer_incremental_mode(tmp_path):
    import json
    w = AsyncCheckpointWriter(str(tmp_path), incremental=True)
    p = _params()
    w.submit(1, p).result(30)
    p2 = dict(p)
    p2["b"] = p["b"] + 1.0
    w.submit(2, p2).result(30)
    w.close()
    man = json.loads((tmp_path / "MANIFEST.json").read_text())
    assert man["steps"]["2"]["sources"] == {"params/w": "ckpt_00000001.npz"}
    step, params, _ = restore_checkpoint(str(tmp_path), 2, _params())
    np.testing.assert_array_equal(params["w"], p["w"])
    np.testing.assert_array_equal(params["b"], p2["b"])


# ------------------------------------------------- fault schedule units --

def test_fault_schedule_parse_grammar():
    s = FaultSchedule.parse(
        "lose:40:T4-16G#3+T4-16G#4,step_fail:5:2,ckpt_io:25:2,"
        "ckpt_crash:30:payload_rename,slow:10-20:T4-16G#2:2.0")
    kinds = [e.kind for e in s.entries]
    assert kinds == ["lose", "step_fail", "ckpt_io", "ckpt_crash", "slow"]
    assert s.entries[0].devices == ["T4-16G#3", "T4-16G#4"]
    assert s.entries[1].count == 2
    assert s.entries[3].at == "payload_rename"
    assert s.slow_factor(15, device="T4-16G#2") == 2.0
    assert s.slow_factor(15, device="V100-16G#1") == 1.0
    assert s.slow_factor(25, device="T4-16G#2") == 1.0
    with pytest.raises(ValueError, match="unknown fault spec"):
        FaultSchedule.parse("meteor:1")


def test_fault_schedule_entries_are_consumed():
    s = FaultSchedule().fail_step(3, times=2)
    s.check_step(1)                            # before the step: nothing
    for _ in range(2):
        with pytest.raises(TransientStepError):
            s.check_step(3)
    s.check_step(3)                            # budget consumed: clean
    assert s.fired == ["step_fail@3", "step_fail@3"]

    s = FaultSchedule().lose(4, "T4-16G#2")
    with pytest.raises(DeviceLossError) as ei:
        s.check_step(7)                        # >= step still fires (late)
    assert ei.value.lost == ["T4-16G#2"]
    s.check_step(7)                            # once only


def test_classify_fault():
    assert classify_fault(DeviceLossError(["a"])) == "membership"
    assert classify_fault(TransientStepError("x")) == "transient"
    assert classify_fault(OSError("disk")) == "transient"
    assert classify_fault(ValueError("bug")) == "fatal"
    assert classify_fault(TypeError("bug")) == "fatal"


def test_drop_devices():
    c = make_cluster("c8", [("V100-16G", 4), ("T4-16G", 4)], 12.0)
    s = drop_devices(c, ["T4-16G#3", "T4-16G#4"])
    assert s.n == 6
    names = [d.name for d in s.devices]
    assert names.count("V100-16G") == 4 and names.count("T4-16G") == 2
    assert s.inter_link_gbps == c.inter_link_gbps
    with pytest.raises(ValueError, match="no 'H100-80G' left"):
        drop_devices(c, ["H100-80G#1"])
    with pytest.raises(ValueError, match="empty cluster"):
        drop_devices(make_cluster("c1", [("T4-16G", 1)], 12.0), ["T4-16G#1"])


def test_device_timers_imbalance():
    t = DeviceTimers(warmup=0)
    for _ in range(3):
        t.record({"a": 1.0, "b": 3.0})
    assert t.imbalance() == pytest.approx(3.0)
    assert t.slowest() == "b"
    t.reset()
    assert t.imbalance() == 1.0 and t.slowest() is None


# ------------------------------------------------- supervised step loop --

def _small_session(**kw):
    cfg = get_config("llama-0.5b", reduced=True)
    kw.setdefault("zero", 0)
    return Session.build(cfg, None, gbs=4, seq=8, impl="reference", **kw)


def test_supervisor_transient_retry_loses_no_microsteps():
    """A transient step failure retries in place and the loss trajectory
    is identical to a fault-free control run — the interrupted
    accumulation batch replayed in full, nothing lost or double-fed."""
    control = _small_session(accum_steps=2)
    want = [float(control.step()["loss"]) for _ in range(4)]

    sess = _small_session(accum_steps=2)
    sched = FaultSchedule().fail_step(1, times=1).fail_step(3, times=2)
    sup = Supervisor(sess, FaultPolicy(max_retries=2, backoff_s=0.001),
                     sched)
    got = [float(sup.step()["loss"]) for _ in range(4)]
    assert got == want
    assert len(sched.fired) == 3
    assert sup.events.counts()["transient"] == 3


def test_supervisor_exhausts_retry_budget():
    sess = _small_session()
    sched = FaultSchedule().fail_step(0, times=99)
    sup = Supervisor(sess, FaultPolicy(max_retries=1, backoff_s=0.001),
                     sched)
    with pytest.raises(FaultToleranceExhausted):
        sup.step()
    assert sup.events.counts()["gave_up"] == 1


def test_supervisor_fatal_faults_are_not_retried():
    sess = _small_session()
    sup = Supervisor(sess, FaultPolicy(backoff_s=0.001))

    calls = []
    real_step = sess.step

    def bad_step(*a, **k):
        calls.append(1)
        raise ValueError("programming error")

    sess.step = bad_step
    with pytest.raises(ValueError, match="programming error"):
        sup.step()
    assert len(calls) == 1                     # exactly one attempt
    sess.step = real_step


def test_supervisor_min_devices_gate():
    """Device loss leaving fewer survivors than the policy's floor is
    unrecoverable — and the session is untouched by the attempt."""
    cfg = get_config("llama-0.5b", reduced=True)
    sess = Session.build(cfg, make_cluster("t", [("T4-16G", 2)], 12.0),
                         gbs=4, seq=8, plan_seq=8, impl="reference")
    sched = FaultSchedule().lose(0, "T4-16G#2")
    sup = Supervisor(sess, FaultPolicy(min_devices=2), sched)
    with pytest.raises(FaultToleranceExhausted, match="surviving"):
        sup.step()
    assert sess.cluster.n == 2                 # no partial recovery


def test_supervisor_autosave_and_flush(tmp_path):
    d = str(tmp_path)
    sess = _small_session()
    sup = Supervisor(sess, ckpt_path=d, save_every=2, async_save=True,
                     keep_last=2)
    sup.run(4)
    assert sess.flush_saves() == []
    assert committed_steps(d) == [2, 4]


def test_membership_recovery_flushes_pending_async_save_first(tmp_path):
    """Bugfix pin: an async autosave still in flight when a device loss
    hits must commit *before* the membership change — replan re-shards
    the live state, and racing the background writer could gather
    half-resharded arrays into the "pre-fault" checkpoint.

    The io_hook holds the step-2 background commit open until the loss
    has actually fired, so the only way the commit can precede the
    recovery in the event log is the supervisor's explicit flush."""
    d = str(tmp_path)
    cfg = get_config("llama-0.5b", reduced=True)
    sess = Session.build(cfg, make_cluster("t", [("T4-16G", 2)], 12.0),
                         gbs=4, seq=8, plan_seq=8, impl="reference")
    sched = FaultSchedule().lose(3, "T4-16G#2")
    sup = Supervisor(sess, FaultPolicy(min_devices=1), sched,
                     ckpt_path=d, save_every=2, async_save=True)
    loss_fired = threading.Event()
    real_hook = sess._ckpt_io_hook

    def gated_hook(event, step):
        if event == "payload_write" and step == 2:
            assert loss_fired.wait(60), "loss never fired while save pending"
        real_hook(event, step)

    sess._writer_for(d, None).io_hook = gated_hook
    real_emit = sup.events.emit

    def emit(kind, **kw):
        if kind == "device_loss":
            loss_fired.set()           # loss observed: release the writer
        return real_emit(kind, **kw)

    sup.events.emit = emit
    for _ in range(4):
        sup.step()
    assert sess.flush_saves() == []
    kinds = [e.kind for e in sup.events]
    assert kinds.index("ckpt_committed") < kinds.index("replan_recovered")
    assert committed_steps(d) == [2, 4]
    assert int(sup.session.state.step) == 4


def test_slow_host_shows_in_observed_imbalance():
    """An injected straggler must surface in DriftReport
    .observed_imbalance via the per-device timing proxy."""
    cfg = get_config("llama-0.5b", reduced=True)
    sess = Session.build(cfg, make_cluster("t", [("T4-16G", 2)], 12.0),
                         gbs=4, seq=8, plan_seq=8, impl="reference")
    sess.attach_faults(FaultSchedule().slow(0, 99, 3.0, device="T4-16G#2"))
    for _ in range(6):
        sess.step()
    rep = sess.drift()
    assert rep is not None
    assert rep.observed_imbalance == pytest.approx(3.0, rel=0.2)
    assert rep.slowest_device == "T4-16G#2"


def test_session_drain_rewinds_loader_to_applied_step():
    sess = _small_session()
    for _ in range(3):
        sess.step()
    loader = sess.loader()
    loader.next_batch()                        # in-flight batch pulled...
    sess.drain()                               # ...fault: drain discards it
    assert loader._epoch == int(sess.state.step)
    # the replayed batch is the one the interrupted step consumed
    b1 = loader.next_batch()
    loader.seek(3)
    b2 = loader.next_batch()
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])


# --------------------------------------- 8-device acceptance (slow) -----

FT_SUBPROC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import tempfile
from dataclasses import replace
import jax, numpy as np
from repro.api import (FaultPolicy, FaultSchedule, Session, SimulatedCrash,
                       Supervisor)
from repro.checkpoint import committed_steps, latest_verified_step
from repro.configs import get_config
from repro.core.cluster import make_cluster

cfg = get_config("llama-0.5b", reduced=True)
cfg = replace(cfg, dtype="float32", param_dtype="float32")
C8 = lambda: make_cluster("c8", [("V100-16G", 4), ("T4-16G", 4)], 12.0)
kw = dict(gbs=16, seq=16, zero=3, impl="reference", lr=1e-3)

ckpt = tempfile.mkdtemp()
sess = Session.build(cfg, C8(), **kw)
assert sess.mesh.devices.size == 8

# lose two devices at step 3, fail checkpoint IO once at the step-2
# autosave: the supervisor must retry the save, re-plan onto the six
# survivors, and keep training with finite loss
sched = (FaultSchedule().lose(3, "T4-16G#3", "T4-16G#4")
                        .fail_ckpt_io(2, times=1))
sup = Supervisor(sess, FaultPolicy(min_devices=4), sched,
                 ckpt_path=ckpt, save_every=2, async_save=True)
m = sup.run(6)
assert np.isfinite(float(m["loss"])), m
assert sup.session.cluster.n == 6
assert sup.session.mesh.devices.size == 6
assert int(sup.session.state.step) == 6
counts = sup.events.counts()
assert counts["device_loss"] == 1 and counts["replan_recovered"] == 1
assert counts["ckpt_io_retry"] == 1            # the injected IO fault
assert sup.session.last_replan.trigger == "fault"
assert sup.session.flush_saves() == []         # every save committed
assert committed_steps(ckpt) == [2, 4, 6]
print("FT_DEVICE_LOSS_OK")

# trajectory check: the post-loss continuation consumed the full global
# batch (total_batch preserved over survivors)
assert sum(a.gmbs for a in
           sup.session.plan.allocation.assignments.values()) == 16
print("FT_BATCH_PRESERVED_OK")

# crash mid-save (between temp write and rename), then restore: the torn
# write is invisible and restore lands on the last committed step with
# bit-identical params
want = jax.tree.map(np.asarray, sup.session.state.params)
crash = FaultSchedule().crash_ckpt(6, at="payload_rename")
sup.session.attach_faults(crash)
pend = sup.session.save(ckpt, async_=True)
try:
    pend.result(60)
    raise SystemExit("expected SimulatedCrash")
except SimulatedCrash:
    pass
assert latest_verified_step(ckpt) == 6         # prior commit, untouched
resumed = Session.restore(ckpt, cfg=cfg)
assert int(resumed.state.step) == 6
for a, b in zip(jax.tree.leaves(want),
                jax.tree.leaves(resumed.state.params)):
    np.testing.assert_array_equal(a, np.asarray(b))
assert np.isfinite(float(resumed.step()["loss"]))
print("FT_CRASH_RESTORE_OK")
print("FT_ALL_OK")
"""


@pytest.mark.slow
def test_fault_tolerance_8dev_subprocess():
    """Acceptance on the 8-device CPU mesh: lose two devices mid-run
    (supervised re-plan onto six survivors, finite loss, async saves
    committed through an injected IO fault), then crash-mid-save and
    bit-identical restore from the last committed checkpoint."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(Path(__file__).resolve().parents[1] / "src")
    out = subprocess.run([sys.executable, "-c", FT_SUBPROC_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert "FT_ALL_OK" in out.stdout, out.stdout + out.stderr
