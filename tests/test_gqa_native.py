"""GQA-native attention: structural guarantees that the compiled pallas
paths never materialize an hq-expanded K/V tensor, plus decode parity
over a partially-filled cache, the zero axes-registration lifetime fix,
and the MemoryModel's kv-heads accounting."""
from dataclasses import replace

import jax
import jax.core as jcore
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import layers as L
from repro.models import model as mm
from repro.models.param import split

RNG = np.random.default_rng(11)


def _rand(shape, dtype=jnp.float32):
    return jnp.asarray(RNG.standard_normal(shape), dtype)


def _gqa_cfg():
    # reduced llama-0.5b is already grouped: 4 q heads over 2 kv heads
    cfg = replace(get_config("llama-0.5b", reduced=True),
                  dtype="float32", param_dtype="float32")
    assert cfg.n_heads != cfg.n_kv_heads
    return cfg


# ------------------------------------------------------------ jaxpr walk --

def _iter_eqns(jaxpr):
    """Every eqn in ``jaxpr`` including nested call/scan/custom_vjp/pallas
    sub-jaxprs."""
    for eqn in jaxpr.eqns:
        yield eqn
        for val in eqn.params.values():
            items = val if isinstance(val, (list, tuple)) else (val,)
            for item in items:
                if isinstance(item, jcore.ClosedJaxpr):
                    yield from _iter_eqns(item.jaxpr)
                elif isinstance(item, jcore.Jaxpr):
                    yield from _iter_eqns(item)


def _all_shapes(jaxpr):
    shapes = set()
    for eqn in _iter_eqns(jaxpr):
        for var in list(eqn.invars) + list(eqn.outvars):
            aval = getattr(var, "aval", None)
            shape = getattr(aval, "shape", None)
            if shape is not None:
                shapes.add(tuple(shape))
    return shapes


def test_gqa_train_step_has_no_expanded_kv_intermediate():
    """The acceptance gate: tracing value_and_grad(loss_fn, impl=pallas)
    for a GQA config must show (a) no jnp.repeat-style broadcast
    intermediate that an hq-expansion would create, and (b) the flash
    pallas_calls receiving K/V at B*Hkv leading dim (un-expanded)."""
    cfg = _gqa_cfg()
    # B=3 keeps the banned (B, Hkv, G, S, hd) signature distinct from the
    # (n_layers=2)-leading stacked scan residuals of the 2-layer config
    B, S = 3, 16
    Hq, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    G = Hq // Hkv
    params, _ = mm.init_model(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray(RNG.integers(3, cfg.vocab_size, (B, S + 1)),
                       jnp.int32)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:],
             "loss_mask": jnp.ones((B, S), jnp.float32)}

    def loss(p):
        return mm.loss_fn(p, cfg, batch, impl="pallas")[0]

    jaxpr = jax.make_jaxpr(jax.value_and_grad(loss))(params)
    shapes = _all_shapes(jaxpr.jaxpr)
    # jnp.repeat(k, G, axis=1) lowers through a (B, Hkv, G, S, hd)
    # broadcast before reshaping to (B, Hq, S, hd) — its absence means no
    # K/V expansion anywhere in the step (fwd, custom-VJP bwd included)
    assert (B, Hkv, G, S, hd) not in shapes
    assert (B, Hkv, 1, S, hd) not in shapes

    kv_lead = set()
    for eqn in _iter_eqns(jaxpr.jaxpr):
        if eqn.primitive.name != "pallas_call":
            continue
        for var in eqn.invars:
            shape = tuple(var.aval.shape)
            if len(shape) == 3 and shape[2] == hd and shape[1] >= S:
                kv_lead.add(shape[0])
    # flash kernels see q at B*Hq and K/V at B*Hkv — both leading dims
    # must appear among the attention pallas_call operands
    assert B * Hkv in kv_lead, kv_lead
    assert B * Hq in kv_lead, kv_lead


def test_gqa_decode_step_has_no_expanded_cache():
    cfg = _gqa_cfg()
    B, S = 2, 24
    Hq, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    params, _ = split(L.attention_init(jax.random.PRNGKey(0), cfg,
                                       dtype=jnp.float32))
    cache = {"k": _rand((B, S, Hkv, hd)), "v": _rand((B, S, Hkv, hd))}
    x = _rand((B, 1, cfg.d_model))

    def step(p, xv, c, i):
        return L.attention_decode(p, xv, c, i, cfg, impl="pallas")

    jaxpr = jax.make_jaxpr(step)(params, x, cache, jnp.int32(7))
    shapes = _all_shapes(jaxpr.jaxpr)
    # an expanded cache would appear as (B, S, Hq, hd) (jnp.repeat on
    # axis 2) or as a (B, Hq, S, hd) kernel operand; only Hkv may occur
    assert (B, S, Hq, hd) not in shapes
    assert (B, S, Hkv, Hq // Hkv, hd) not in shapes
    assert (B, Hq, S, hd) not in shapes


# ------------------------------------------------------- decode parity ---

@pytest.mark.parametrize("index", [0, 3, 22])
def test_decode_pallas_matches_reference_partial_cache(index):
    """Pallas decode vs the jnp reference with a partially-filled cache:
    identical outputs AND identical cache updates at every fill level."""
    cfg = _gqa_cfg()
    B, S = 2, 24
    Hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    params, _ = split(L.attention_init(jax.random.PRNGKey(1), cfg,
                                       dtype=jnp.float32))
    cache = {"k": _rand((B, S, Hkv, hd)), "v": _rand((B, S, Hkv, hd))}
    x = _rand((B, 1, cfg.d_model))
    y_ref, c_ref = L.attention_decode(params, x, cache, jnp.int32(index),
                                      cfg, impl="reference")
    y_pal, c_pal = L.attention_decode(params, x, cache, jnp.int32(index),
                                      cfg, impl="pallas")
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_pal),
                               rtol=2e-5, atol=2e-5)
    for key in ("k", "v"):
        np.testing.assert_allclose(np.asarray(c_ref[key]),
                                   np.asarray(c_pal[key]), atol=0)


def test_full_model_decode_pallas_matches_forward():
    """Sequential pallas decode reproduces the full-sequence forward on a
    GQA model (cache exactness through the flash-decode kernel)."""
    cfg = _gqa_cfg()
    B, S = 2, 10
    toks = jnp.asarray(RNG.integers(3, cfg.vocab_size, (B, S)), jnp.int32)
    params, _ = mm.init_model(jax.random.PRNGKey(1), cfg)
    hidden, _ = mm.forward(params, cfg, {"tokens": toks})
    full_logits = mm.lm_logits(params, cfg, hidden)
    state = mm.init_decode_state(cfg, B, S)
    outs = []
    for t in range(S):
        lg, state = mm.decode_step(params, cfg, toks[:, t:t + 1], state,
                                   impl="pallas")
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec_logits, np.float32),
                               np.asarray(full_logits, np.float32),
                               rtol=2e-3, atol=2e-3)


# --------------------------------------------- zero axes registration ----

def test_register_axes_lives_and_dies_with_the_rules_instance():
    """Regression for the id(rules)-keyed cache: axes must be stored on
    the instance (so a recycled id can never serve a stale tree) and two
    live instances must never share a registration."""
    from repro.core.sharding import MeshRules
    from repro.core.zero import _AXES_ATTR, _axes_of, register_axes

    mesh = jax.make_mesh((1,), ("data",))
    r1 = MeshRules(mesh, zero_stage=0)
    r2 = MeshRules(mesh, zero_stage=0)
    axes1, axes2 = {"a": ("embed",)}, {"a": ("vocab",)}
    register_axes(r1, axes1)
    register_axes(r2, axes2)
    assert _axes_of(None, r1) is axes1
    assert _axes_of(None, r2) is axes2
    assert getattr(r1, _AXES_ATTR) is axes1  # instance-held, not global
    r3 = MeshRules(mesh, zero_stage=0)
    with pytest.raises(RuntimeError):
        _axes_of(None, r3)


# -------------------------------------------------- MemoryModel satellite -

def test_memory_model_counts_kv_at_n_kv_heads():
    from repro.core.workload import MemoryModel

    cfg = get_config("llama-1.1b")          # 32 q heads over 4 kv heads
    assert cfg.n_kv_heads < cfg.n_heads
    hd = cfg.resolved_head_dim
    kv_gap = 2 * 4096 * (cfg.n_heads - cfg.n_kv_heads) * hd * 2

    # remat: the live (re)computed layer's K/V (x2) is counted at the
    # width the kernels allocate
    native = MemoryModel(cfg, 4096, 0, 4)
    legacy = MemoryModel(cfg, 4096, 0, 4, gqa_native_attn=False)
    a_native = native.activation_bytes_per_sample()
    a_legacy = legacy.activation_bytes_per_sample()
    assert a_legacy - a_native == pytest.approx(kv_gap * 2)
    # wider feasible micro-batch on the same device — the Poplar payoff
    assert native.max_batch(16.0) >= legacy.max_batch(16.0)

    # no remat: every saved attention layer's K/V shrinks; the legacy
    # estimate is byte-identical to the pre-GQA accounting (the 14x
    # catch-all already included expanded K/V — no double count)
    nat_nr = MemoryModel(cfg, 4096, 0, 4, remat=False)
    leg_nr = MemoryModel(cfg, 4096, 0, 4, remat=False,
                         gqa_native_attn=False)
    base_nr = 14 * 4096 * cfg.d_model * 2 * cfg.n_layers
    assert leg_nr.activation_bytes_per_sample() >= base_nr
    assert (leg_nr.activation_bytes_per_sample()
            - nat_nr.activation_bytes_per_sample()
            == pytest.approx(kv_gap * cfg.n_layers))

    mha = get_config("llama-0.5b")          # 16/16: no GQA, no change
    assert (MemoryModel(mha, 4096, 0, 4).activation_bytes_per_sample()
            == MemoryModel(mha, 4096, 0, 4, gqa_native_attn=False
                           ).activation_bytes_per_sample())
