"""Hetero batch layout + data pipeline + simulator + checkpoint tests."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: skip, never error
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.core.allocation import (AllocationPlan, DeviceAssignment,
                                   allocate_stage01, fit_curve)
from repro.core.cluster import make_cluster
from repro.core.hetero import (HeteroBatchLayout, build_masks,
                               layout_from_plan, pack_batch)
from repro.core.planner import make_runners
from repro.core.profiler import profile_cluster
from repro.core.simulator import simulate_plan
from repro.core.workload import train_flops_per_token
from repro.data.pipeline import (ByteTokenizer, HeteroDataLoader,
                                 SyntheticTokens)

CFG = get_config("llama-0.5b")


def _plan(gbs=64):
    cluster = make_cluster("t", [("V100-16G", 2), ("T4-16G", 2)])
    runners = make_runners(cluster, CFG, 512, 0)
    profs = profile_cluster(runners, 0)
    curves = {n: fit_curve(p) for n, p in profs.items()}
    return allocate_stage01(curves, gbs), curves, cluster


def test_layout_covers_plan_batch():
    plan, _, _ = _plan(64)
    layout = layout_from_plan(plan, group_multiple=2)
    assert layout.total_real() == plan.total_batch
    assert layout.padded_group_batch % 2 == 0


def test_masks_match_layout():
    plan, _, _ = _plan(96)
    layout = layout_from_plan(plan)
    masks = build_masks(layout)
    assert masks.shape == (layout.gas, layout.padded_global_batch)
    assert int(masks.sum()) == layout.total_real()


@given(st.integers(8, 512))
@settings(max_examples=10, deadline=None)
def test_pack_batch_exact_token_accounting(gbs):
    plan, _, _ = _plan(gbs)
    layout = layout_from_plan(plan)
    seq = 16
    rows = SyntheticTokens(1000, seq).rows(layout.total_real())
    packed = pack_batch(rows, layout, seq)
    # every real row appears exactly once; mask counts the real rows
    n_real = int(packed["loss_mask"][:, :, 0].sum())
    assert n_real == min(layout.total_real(), len(rows)) == gbs
    # labels are the shifted tokens
    got = packed["tokens"][packed["loss_mask"][:, :, 0] > 0]
    assert got.shape[0] == gbs


def test_hetero_loader_stream():
    plan, _, _ = _plan(32)
    layout = layout_from_plan(plan)
    src = SyntheticTokens(1000, 16)
    loader = HeteroDataLoader(src, layout, 16)
    b1 = loader.next_batch()
    b2 = loader.next_batch()
    assert b1["tokens"].shape == b2["tokens"].shape
    assert not np.array_equal(b1["tokens"], b2["tokens"])  # new epoch data


def test_simulator_invariants():
    plan, curves, cluster = _plan(128)
    fps = train_flops_per_token(CFG, 512) * 512
    res = simulate_plan(plan, curves, CFG, 512, cluster, fps)
    assert res.iter_time >= max(res.device_busy.values())
    assert 0 < res.utilization <= 1.0
    assert res.samples == 128
    assert res.cluster_tflops > 0


def test_byte_tokenizer_roundtrip():
    t = ByteTokenizer()
    s = "Poplar: heterogeneity-aware ZeRO."
    assert t.decode(t.encode(s)) == s


def test_checkpoint_roundtrip(tmp_path):
    import jax
    import jax.numpy as jnp
    from repro.checkpoint import restore_checkpoint, save_checkpoint
    from repro.models import model as mm
    from repro.optim.adamw import adamw_init
    cfg = get_config("llama-0.5b", reduced=True)
    params, _ = mm.init_model(jax.random.PRNGKey(0), cfg)
    opt = adamw_init(params)
    save_checkpoint(str(tmp_path), 7, params, opt)
    step, p2, o2 = restore_checkpoint(str(tmp_path), None, params, opt)
    assert step == 7
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    assert int(o2["count"]) == 0
