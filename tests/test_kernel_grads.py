"""Training-path parity for the custom-VJP Pallas kernels (interpret mode):
`impl="pallas"` under jax.value_and_grad must match `impl="reference"`
exactly (<=1e-4 max-abs), plus autotune cache round-trip invariants."""
import json
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels import autotune, ref
from repro.kernels.flash_attention import flash_attention_vjp
from repro.kernels.rmsnorm import rmsnorm_vjp
from repro.models import model as mm

RNG = np.random.default_rng(7)


def _rand(shape, dtype=jnp.float32):
    return jnp.asarray(RNG.standard_normal(shape), dtype)


# ------------------------------------------------- kernel-level gradients --

@pytest.mark.parametrize("B,H,S,D,causal,window,blk", [
    (1, 2, 128, 32, True, None, 64),
    (2, 2, 200, 64, True, None, 64),     # S not a multiple of the block
    (1, 2, 160, 32, True, 48, 32),       # sliding window
    (1, 2, 50, 32, True, 16, 32),        # odd S + window
    (1, 1, 96, 32, False, None, 32),     # non-causal
])
def test_flash_attention_grad_matches_reference(B, H, S, D, causal, window,
                                                blk):
    q, k, v = (_rand((B, H, S, D)) for _ in range(3))
    co = _rand((B, H, S, D))

    def loss_pallas(q, k, v):
        o = flash_attention_vjp(q, k, v, causal=causal, window=window,
                                block_q=blk, block_k=blk, interpret=True)
        return (o.astype(jnp.float32) * co).sum()

    def loss_ref(q, k, v):
        o = ref.attention_reference(q, k, v, causal=causal, window=window)
        return (o.astype(jnp.float32) * co).sum()

    gp = jax.grad(loss_pallas, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gp, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


@pytest.mark.parametrize("shape", [(4, 128), (2, 37, 256), (3, 130, 64)])
def test_rmsnorm_grad_matches_reference(shape):
    x = _rand(shape)
    s = _rand(shape[-1:])
    co = _rand(shape)

    def loss_pallas(x, s):
        return (rmsnorm_vjp(x, s, interpret=True, block_rows=32
                            ).astype(jnp.float32) * co).sum()

    def loss_ref(x, s):
        return (ref.rmsnorm_reference(x, s).astype(jnp.float32) * co).sum()

    gp = jax.grad(loss_pallas, argnums=(0, 1))(x, s)
    gr = jax.grad(loss_ref, argnums=(0, 1))(x, s)
    for a, b in zip(gp, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


@pytest.mark.parametrize("Hq,Hkv,S,causal,window,dtype", [
    (2, 2, 96, True, None, jnp.float32),    # G=1 (MHA degenerate case)
    (6, 1, 80, True, None, jnp.float32),    # G=6 (internlm2-like ratio)
    (8, 1, 50, True, None, jnp.float32),    # G=8, odd seq len
    (8, 2, 70, True, 24, jnp.float32),      # G=4 + sliding window + odd S
    (6, 2, 33, False, None, jnp.float32),   # G=3, non-causal, odd S
    (4, 2, 64, True, None, jnp.bfloat16),   # G=2, bf16
])
def test_flash_attention_gqa_grads_match_expanded_reference(
        Hq, Hkv, S, causal, window, dtype):
    """hq != hkv gradients: the fused dKV group accumulation must equal
    differentiating through the oracle's physical expansion (which sums
    the expanded dK/dV over each group via the repeat's transpose)."""
    D = 32
    q = _rand((1, Hq, S, D), dtype)
    k = _rand((1, Hkv, S, D), dtype)
    v = _rand((1, Hkv, S, D), dtype)
    co = _rand((1, Hq, S, D))

    def loss_pallas(q, k, v):
        o = flash_attention_vjp(q, k, v, causal=causal, window=window,
                                block_q=32, block_k=32, interpret=True)
        return (o.astype(jnp.float32) * co).sum()

    def loss_ref(q, k, v):
        o = ref.gqa_attention_reference(q, k, v, causal=causal,
                                        window=window)
        return (o.astype(jnp.float32) * co).sum()

    gp = jax.grad(loss_pallas, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
    for a, b in zip(gp, gr):
        assert a.shape == b.shape  # dK/dV stay at Hkv heads
        assert a.dtype == b.dtype
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=tol)


def test_flash_attention_bf16_grads_keep_dtype():
    q, k, v = (_rand((1, 2, 64, 32), jnp.bfloat16) for _ in range(3))

    def loss(q, k, v):
        return flash_attention_vjp(q, k, v, causal=True, block_q=32,
                                   block_k=32, interpret=True
                                   ).astype(jnp.float32).sum()

    gq, gk, gv = jax.grad(loss, argnums=(0, 1, 2))(q, k, v)
    assert gq.dtype == gk.dtype == gv.dtype == jnp.bfloat16


# ------------------------------------------------- mamba2 scan grads -----

@pytest.mark.parametrize("B,S,H,P,N,chunk", [
    (1, 48, 2, 8, 8, 16),
    (2, 50, 1, 16, 8, 16),      # S not a multiple of the chunk
    (1, 16, 2, 8, 4, 64),       # chunk > S (clamped)
])
def test_mamba_scan_grad_matches_chunked_reference(B, S, H, P, N, chunk):
    """mamba_scan_vjp (Pallas fwd + recomputation bwd) vs differentiating
    the *chunked* model formulation — two independent algorithms for the
    same scan, so matching gradients are a real parity check."""
    from repro.kernels.mamba_scan import mamba_scan_vjp
    from repro.models.ssm import _ssd_chunked

    xh = _rand((B, S, H, P))
    dt = jnp.abs(_rand((B, S, H))) * 0.5 + 0.01
    A_log = _rand((H,)) * 0.1
    Bm, Cm = _rand((B, S, N)), _rand((B, S, N))
    co = _rand((B, S, H, P))

    def loss_pallas(xh, dt, A_log, Bm, Cm):
        y = mamba_scan_vjp(xh, dt, -jnp.exp(A_log), Bm, Cm, chunk=chunk,
                           interpret=True)
        return (y.astype(jnp.float32) * co).sum()

    def loss_chunked(xh, dt, A_log, Bm, Cm):
        y, _ = _ssd_chunked(xh, dt, A_log, Bm, Cm, chunk=chunk)
        return (y.astype(jnp.float32) * co).sum()

    gp = jax.grad(loss_pallas, argnums=(0, 1, 2, 3, 4))(xh, dt, A_log, Bm, Cm)
    gr = jax.grad(loss_chunked, argnums=(0, 1, 2, 3, 4))(xh, dt, A_log, Bm, Cm)
    for a, b in zip(gp, gr):
        assert a.shape == b.shape
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4)


def test_mamba2_apply_pallas_grads_match_reference():
    """Block-level gate for the zamba2/granite-ssm train path: mamba2
    blocks under impl='pallas' must train identically to the reference."""
    from dataclasses import replace

    from repro.models import ssm as S
    from repro.models.param import split

    cfg = get_config("zamba2-2.7b", reduced=True)
    cfg = replace(cfg, dtype="float32", param_dtype="float32")
    params, _ = split(S.mamba2_init(jax.random.PRNGKey(1), cfg, jnp.float32))
    x = _rand((2, 24, cfg.d_model)) * 0.1

    def loss(p, x, impl):
        y = S.mamba2_apply(p, x, cfg, impl=impl)
        return (y.astype(jnp.float32) ** 2).sum()

    lr_, gr = jax.value_and_grad(loss, argnums=(0, 1))(params, x, "reference")
    lp_, gp = jax.value_and_grad(loss, argnums=(0, 1))(params, x, "pallas")
    assert abs(float(lr_) - float(lp_)) < 1e-3
    for a, b in zip(jax.tree.leaves(gr), jax.tree.leaves(gp)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=2e-3)


# ------------------------------------------------- loss_fn-level parity ---

@pytest.mark.parametrize("S,window", [(50, None), (48, 16)])
def test_loss_fn_grad_parity_pallas_vs_reference(S, window):
    """jax.value_and_grad(loss_fn) end to end: the acceptance gate for the
    training-grade kernel path (causal + sliding window, odd seq lens)."""
    cfg = get_config("llama-0.5b", reduced=True)
    cfg = replace(cfg, dtype="float32", param_dtype="float32")
    params, _ = mm.init_model(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray(RNG.integers(3, cfg.vocab_size, (2, S + 1)),
                       jnp.int32)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:],
             "loss_mask": jnp.ones((2, S), jnp.float32)}

    def loss(p, impl):
        return mm.loss_fn(p, cfg, batch, window=window, impl=impl)[0]

    lr, gr = jax.value_and_grad(lambda p: loss(p, "reference"))(params)
    lp, gp = jax.value_and_grad(lambda p: loss(p, "pallas"))(params)
    assert abs(float(lr) - float(lp)) < 1e-4
    for a, b in zip(jax.tree.leaves(gr), jax.tree.leaves(gp)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-4)


# ------------------------------------------------------- autotune cache ---

def test_autotune_cache_round_trip(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "at.json"))
    autotune.clear_memory_cache()
    kw = dict(S=333, D=64, dtype="float32", causal=True, window=None)
    first = autotune.lookup("flash_fwd", interpret=True, **kw)
    # identical key -> identical blocks, from memory and from disk
    assert autotune.lookup("flash_fwd", interpret=True, **kw) == first
    autotune.clear_memory_cache()
    assert autotune.lookup("flash_fwd", interpret=True, **kw) == first
    # the disk file documents the key with a well-formed entry
    data = json.loads((tmp_path / "at.json").read_text())
    key = autotune.key_of("flash_fwd", **kw)
    assert data[key]["blocks"] == list(first)
    assert data[key]["source"].startswith("static")


def test_autotune_gqa_group_size_does_not_alias(tmp_path, monkeypatch):
    """MHA and GQA shapes must resolve to distinct cache keys, so a tile
    measured for G=1 never answers a G=6 lookup (and vice versa)."""
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "at.json"))
    autotune.clear_memory_cache()
    kw = dict(S=256, D=64, dtype="float32", causal=True, window=None)
    k1 = autotune.key_of("flash_fwd", **kw)          # default G=1
    k6 = autotune.key_of("flash_fwd", G=6, **kw)
    assert k1 != k6
    autotune.record(k1, (128, 128))
    autotune.record(k6, (64, 128))
    assert autotune.lookup("flash_fwd", G=1, **kw) == (128, 128)
    assert autotune.lookup("flash_fwd", G=6, **kw) == (64, 128)


def test_autotune_measured_sweep_writes_cache(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "at.json"))
    autotune.clear_memory_cache()
    calls = []

    def make_fn(bq, bk):
        calls.append((bq, bk))
        return lambda: jnp.zeros(())

    best = autotune.tune("flash_fwd", make_fn, S=64, D=32, dtype="float32",
                         candidates=((32, 32), (32, 64), (128, 128)),
                         iters=1)
    assert calls == [(32, 32), (32, 64), (64, 64)]  # clamped to S + deduped
    assert best in calls
    data = json.loads((tmp_path / "at.json").read_text())
    key = autotune.key_of("flash_fwd", S=64, D=32, dtype="float32",
                          causal=True, window=None)
    assert data[key]["source"] == "measured"
    assert "ms" in data[key]
    # second tune for the same key is a pure cache hit (no new sweeps)
    n = len(calls)
    assert autotune.tune("flash_fwd", make_fn, S=64, D=32,
                         dtype="float32") == best
    assert len(calls) == n
