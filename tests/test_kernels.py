"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.mamba_scan import mamba_scan_pallas
from repro.kernels.rmsnorm import rmsnorm_pallas

RNG = np.random.default_rng(42)


def _rand(shape, dtype):
    x = RNG.standard_normal(shape).astype(np.float32)
    return jnp.asarray(x, dtype)


TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


# ------------------------------------------------------- flash attention --

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,S,D", [
    (1, 1, 64, 32), (2, 3, 128, 64), (1, 2, 200, 64),  # non-multiple S
    (1, 1, 256, 128),
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(B, H, S, D, dtype, causal):
    q, k, v = (_rand((B, H, S, D), dtype) for _ in range(3))
    got = flash_attention_pallas(q, k, v, causal=causal, block_q=64,
                                 block_k=64, interpret=True)
    want = ref.attention_reference(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               **TOL[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("Hq,Hkv,S", [
    (6, 1, 96), (8, 2, 128), (4, 2, 200),  # non-multiple S
    (56, 8, 64),                           # llava-next-34b head ratio
])
def test_flash_attention_gqa_sweep(Hq, Hkv, S, dtype):
    """Un-expanded K/V through the grid index_map vs the expanding oracle."""
    D = 32
    q = _rand((1, Hq, S, D), dtype)
    k = _rand((1, Hkv, S, D), dtype)
    v = _rand((1, Hkv, S, D), dtype)
    got = flash_attention_pallas(q, k, v, causal=True, block_q=32,
                                 block_k=32, interpret=True)
    want = ref.gqa_attention_reference(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


# --------------------------------------------------------- flash decode --

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,S,D", [
    (1, 2, 64, 32), (2, 4, 256, 64), (1, 2, 200, 64),  # non-multiple S
])
@pytest.mark.parametrize("filled_frac", [0.01, 0.4, 1.0])
def test_flash_decode_sweep(B, H, S, D, dtype, filled_frac):
    from repro.kernels.flash_decode import flash_decode_pallas
    filled = max(int(S * filled_frac), 1)
    q = _rand((B, H, 1, D), dtype)
    k = _rand((B, H, S, D), dtype)
    v = _rand((B, H, S, D), dtype)
    # the kernel takes the cache's stored (B, S, H, D) layout
    got = flash_decode_pallas(q, k.swapaxes(1, 2), v.swapaxes(1, 2),
                              jnp.int32(filled), block_k=64, interpret=True)
    want = ref.decode_attention_reference(q, k, v, filled)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


@pytest.mark.parametrize("Hq,Hkv", [(6, 1), (8, 2), (16, 2)])
@pytest.mark.parametrize("filled_frac", [0.05, 0.6, 1.0])
def test_flash_decode_gqa_sweep(Hq, Hkv, filled_frac):
    """GQA decode over a partially-filled un-expanded cache: the grouped
    q block must see exactly the valid prefix of its KV head."""
    from repro.kernels.flash_decode import flash_decode_pallas
    B, S, D = 2, 96, 32
    filled = max(int(S * filled_frac), 1)
    q = _rand((B, Hq, 1, D), jnp.float32)
    k = _rand((B, Hkv, S, D), jnp.float32)
    v = _rand((B, Hkv, S, D), jnp.float32)
    got = flash_decode_pallas(q, k.swapaxes(1, 2), v.swapaxes(1, 2),
                              jnp.int32(filled), block_k=32, interpret=True)
    want = ref.gqa_decode_attention_reference(q, k, v, filled)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_decode_matches_model_decode_softmax():
    """Kernel vs the exact masked softmax the model decode path computes."""
    from repro.kernels.flash_decode import flash_decode_pallas
    B, H, S, D = 2, 4, 96, 32
    q = _rand((B, H, 1, D), jnp.float32)
    kc = _rand((B, H, S, D), jnp.float32)
    vc = _rand((B, H, S, D), jnp.float32)
    filled = 40
    got = flash_decode_pallas(q, kc.swapaxes(1, 2), vc.swapaxes(1, 2),
                              jnp.int32(filled), block_k=32, interpret=True)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, kc) / jnp.sqrt(D)
    valid = jnp.arange(S)[None, None, None, :] < filled
    s = jnp.where(valid, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    want = jnp.einsum("bhqk,bhkd->bhqd", p, vc)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("window", [16, 64, 1000])
def test_flash_attention_sliding_window(window):
    q, k, v = (_rand((1, 2, 160, 32), jnp.float32) for _ in range(3))
    got = flash_attention_pallas(q, k, v, causal=True, window=window,
                                 block_q=32, block_k=32, interpret=True)
    want = ref.attention_reference(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_matches_model_reference_path():
    """Kernel vs the model's chunked jnp attention (the path the dry-run
    compiles) — the two long-seq implementations must agree."""
    from repro.models.layers import _chunk_attn_flash
    q, k, v = (_rand((2, 2, 192, 64), jnp.float32) for _ in range(3))
    got = flash_attention_pallas(q, k, v, causal=True, interpret=True,
                                 block_q=64, block_k=64)
    want = _chunk_attn_flash(q, k, v, causal=True, window=None,
                             q_chunk=64, kv_chunk=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# --------------------------------------------------------------- rmsnorm --

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(4, 128), (2, 37, 256), (1, 1, 512),
                                   (3, 130, 64)])
def test_rmsnorm_sweep(shape, dtype):
    x = _rand(shape, dtype)
    s = _rand(shape[-1:], dtype)
    got = rmsnorm_pallas(x, s, interpret=True, block_rows=32)
    want = ref.rmsnorm_reference(x, s)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


# ------------------------------------------------------------ mamba scan --

@pytest.mark.parametrize("B,S,H,P,N,chunk", [
    (1, 64, 2, 8, 4, 16), (2, 96, 4, 16, 8, 32),
    (1, 100, 1, 32, 16, 32),  # S not a multiple of chunk
    (1, 128, 2, 64, 64, 64),  # zamba2-like head_dim/state
])
def test_mamba_scan_sweep(B, S, H, P, N, chunk):
    xh = _rand((B, S, H, P), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, (B, S, H)), jnp.float32)
    A = jnp.asarray(-RNG.uniform(0.5, 2.0, (H,)), jnp.float32)
    Bm = _rand((B, S, N), jnp.float32)
    Cm = _rand((B, S, N), jnp.float32)
    got = mamba_scan_pallas(xh, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    want = ref.ssd_reference(xh, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_mamba_scan_matches_model_chunked_path():
    from repro.models.ssm import _ssd_chunked
    B, S, H, P, N = 2, 80, 2, 16, 8
    xh = _rand((B, S, H, P), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, (B, S, H)), jnp.float32)
    A = jnp.asarray(np.log(RNG.uniform(0.5, 2.0, (H,))), jnp.float32)
    Bm = _rand((B, S, N), jnp.float32)
    Cm = _rand((B, S, N), jnp.float32)
    want, _ = _ssd_chunked(xh, dt, A, Bm, Cm, chunk=16)
    got = mamba_scan_pallas(xh, dt, -jnp.exp(A), Bm, Cm, chunk=16,
                            interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-4, atol=2e-4)
