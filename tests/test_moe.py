"""MoE dispatch correctness: grouped routing (§Perf/P1) vs the
paper-faithful per-sequence-capacity baseline, plus invariants."""
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: skip, never error
from hypothesis import given, settings, strategies as st

from repro.configs.base import MoEConfig, ModelConfig
from repro.models import moe as M


def _cfg(E=4, K=2, d=32, d_expert=64, cf=4.0, g=None):
    return ModelConfig(
        name="t", family="moe", n_layers=1, d_model=d, n_heads=2,
        n_kv_heads=2, d_ff=0, vocab_size=64,
        moe=MoEConfig(num_experts=E, top_k=K, d_expert=d_expert,
                      capacity_factor=cf, group_size=g))


def _params(cfg, key=0):
    from repro.models.param import split
    values, _ = split(M.moe_init(jax.random.PRNGKey(key), cfg))
    return values


def test_grouped_matches_ungrouped_when_no_drops():
    """With capacity_factor high enough that nothing drops, grouping the
    sequence must not change any token's output (router is pointwise)."""
    cfg0 = _cfg(cf=8.0, g=None)
    cfg_g = replace(cfg0, moe=replace(cfg0.moe, group_size=8))
    params = _params(cfg0)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32), jnp.float32)
    y0, aux0 = M.moe_apply(params, x, cfg0)
    y1, aux1 = M.moe_apply(params, x, cfg_g)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(float(aux0), float(aux1), rtol=1e-5)


@pytest.mark.parametrize("g", [None, 8, 16])
def test_moe_output_shape_and_finite(g):
    cfg = _cfg(g=g)
    params = _params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 32, 32), jnp.float32)
    y, aux = M.moe_apply(params, x, cfg)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    assert np.isfinite(float(aux))


def test_group_size_ignored_when_not_divisible_or_larger():
    cfg = _cfg(g=1000)   # does not divide S=32 -> falls back to baseline
    params = _params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 32, 32), jnp.float32)
    y_g, _ = M.moe_apply(params, x, cfg)
    y_b, _ = M.moe_apply(params, x, _cfg(g=None))
    np.testing.assert_allclose(np.asarray(y_g), np.asarray(y_b), rtol=1e-5)


def test_capacity_drops_passthrough_residual():
    """Tokens over capacity contribute zero from the MoE (their residual
    passes through at the block level); output stays finite and bounded."""
    cfg = _cfg(cf=0.25, g=None)      # brutal capacity squeeze
    params = _params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 64, 32), jnp.float32)
    y, _ = M.moe_apply(params, x, cfg)
    # dropped tokens give exactly 0 rows; kept rows finite
    assert np.isfinite(np.asarray(y)).all()
    # at cf=0.25 with top2-of-4 at least half the slots are gone
    zero_rows = (np.abs(np.asarray(y)).sum(-1) == 0).mean()
    assert zero_rows > 0.1


@given(E=st.sampled_from([2, 4, 8]), K=st.integers(1, 2),
       g=st.sampled_from([4, 8, 16]), seed=st.integers(0, 10**6))
@settings(max_examples=12, deadline=None)
def test_grouping_invariance_property(E, K, g, seed):
    """Property: for any (E, K, g) with capacity high enough that no
    token drops, grouped and ungrouped dispatch agree — routing is
    pointwise, so the group boundaries must be unobservable."""
    K = min(K, E)
    cfg0 = _cfg(E=E, K=K, cf=float(2 * E), g=None)
    cfg_g = replace(cfg0, moe=replace(cfg0.moe, group_size=g))
    params = _params(cfg0, key=seed % 97)
    x = jax.random.normal(jax.random.PRNGKey(seed), (2, 32, 32), jnp.float32)
    y0, _ = M.moe_apply(params, x, cfg0)
    y1, _ = M.moe_apply(params, x, cfg_g)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               rtol=3e-2, atol=3e-2)


def _dense_oracle(params, x, cfg):
    """Exact dropless reference: every expert computes every token; gates
    mask the combination. O(E*tokens) compute — tests only."""
    m = cfg.moe
    B, S, d = x.shape
    E, K = m.num_experts, m.top_k
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, K)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    w = jnp.zeros((B, S, E), jnp.float32)
    for j in range(K):
        w = w + gate[..., j, None] * jax.nn.one_hot(idx[..., j], E)
    g = jnp.einsum("bsd,edf->bsef", x, params["wi_gate"].astype(x.dtype))
    u = jnp.einsum("bsd,edf->bsef", x, params["wi_up"].astype(x.dtype))
    h = jax.nn.silu(g) * u
    ye = jnp.einsum("bsef,efd->bsed", h, params["wo"].astype(x.dtype))
    return jnp.einsum("bse,bsed->bsd", w.astype(x.dtype), ye)


def test_ragged_matches_dense_oracle_exactly():
    """The ragged_dot path is dropless: it must equal the exact
    every-expert oracle (no capacity approximation at all)."""
    cfg = _cfg(cf=1.0)
    cfg = replace(cfg, moe=replace(cfg.moe, impl="ragged"))
    params = _params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 48, 32), jnp.float32)
    y, _ = M.moe_apply(params, x, cfg)
    y_ref = _dense_oracle(params, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-2, atol=2e-2)


def test_ragged_grad_finite():
    cfg = replace(_cfg(), moe=replace(_cfg().moe, impl="ragged"))
    params = _params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(6), (1, 16, 32), jnp.float32)

    def loss(p):
        y, aux = M.moe_apply(p, x, cfg)
        return (y ** 2).mean() + aux

    g = jax.grad(loss)(params)
    for leaf in jax.tree.leaves(g):
        assert np.isfinite(np.asarray(leaf, np.float32)).all()


def test_grouped_reduces_dispatch_footprint():
    """The lowered HLO bytes of the grouped variant must be well below the
    ungrouped baseline at long sequence (the §Perf/P1 claim, in miniature)."""
    cfg0 = _cfg(cf=1.25, g=None)
    cfg_g = replace(cfg0, moe=replace(cfg0.moe, group_size=32))
    params = _params(cfg0)
    x = jax.ShapeDtypeStruct((1, 1024, 32), jnp.float32)

    def bytes_of(cfg):
        ca = jax.jit(lambda p, xv: M.moe_apply(p, xv, cfg)[0]).lower(
            params, x).cost_analysis()
        return ca.get("bytes accessed", 0.0)

    assert bytes_of(cfg_g) < 0.25 * bytes_of(cfg0)
