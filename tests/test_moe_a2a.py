"""Expert-parallel all_to_all MoE (§Perf/P1 iter 4): the shard_map path
must match the single-device gshard reference when capacity is ample."""
import os
import subprocess
import sys
from pathlib import Path

import pytest

SUBPROC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
from dataclasses import replace
import jax, jax.numpy as jnp
import numpy as np
from repro.configs.base import ModelConfig, MoEConfig
from repro.core.sharding import MeshRules, use_rules
from repro.models import moe as M
from repro.models.param import split

def cfgs(cf):
    base = ModelConfig(
        name="t", family="moe", n_layers=1, d_model=32, n_heads=2,
        n_kv_heads=2, d_ff=0, vocab_size=64,
        moe=MoEConfig(num_experts=4, top_k=2, d_expert=64,
                      capacity_factor=cf))
    a2a = replace(base, moe=replace(base.moe, impl="a2a"))
    return base, a2a

base, a2a = cfgs(cf=8.0)   # ample capacity: no drops anywhere
params, _ = split(M.moe_init(jax.random.PRNGKey(0), base))
params = jax.tree.map(lambda p: p.astype(jnp.float32)
                      if p.dtype == jnp.bfloat16 else p, params)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32), jnp.float32)

y_ref, aux_ref = M.moe_apply(params, x, base)

mesh = jax.make_mesh((2, 4), ("data", "model"))   # E=4 over model=4
rules = MeshRules(mesh, zero_stage=0)
with mesh, use_rules(rules):
    y_sh, aux_sh = jax.jit(
        lambda p, xv: M.moe_apply(p, xv, a2a))(params, x)

np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_sh),
                           rtol=2e-4, atol=2e-4)
np.testing.assert_allclose(float(aux_ref), float(aux_sh), rtol=1e-4)
print("forward OK")

# gradients flow through the all_to_all pair
def loss(p, c):
    with use_rules(rules) if c is a2a else __import__("contextlib").nullcontext():
        y, aux = M.moe_apply(p, x, c)
    return (y ** 2).mean() + aux

with mesh, use_rules(rules):
    g_sh = jax.jit(jax.grad(lambda p: loss(p, a2a)))(params)
g_ref = jax.grad(lambda p: loss(p, base))(params)
for k in ("wi_gate", "wi_up", "wo", "router"):
    np.testing.assert_allclose(np.asarray(g_ref[k], np.float32),
                               np.asarray(g_sh[k], np.float32),
                               rtol=5e-3, atol=5e-4)
print("grad OK")
print("A2A_OK")
"""


@pytest.mark.slow
def test_a2a_matches_gshard_reference_8dev():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(Path(__file__).resolve().parents[1] / "src")
    out = subprocess.run([sys.executable, "-c", SUBPROC_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert "A2A_OK" in out.stdout, out.stdout + out.stderr
