"""Scheduled ZeRO-3 (core/overlap.py): parity with the XLA-auto oracle on
a multi-device CPU mesh (stage 3; accum_steps 1 and 2; fp32 and int8
wire), comm planning/eligibility, exposed-byte accounting, and the
simulator/planner overlap term."""
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import get_config
from repro.core import overlap
from repro.core.sharding import MeshRules
from repro.core.workload import exposed_comm_time
from repro.models import model as mm


def _abstract_mesh(sizes, names):
    try:
        return AbstractMesh(tuple(zip(names, sizes)))
    except TypeError:
        return AbstractMesh(sizes, names)


def _plan_for(rules, cfg, batch_rows=16, seq=16, accum=1):
    params, axes = mm.init_model(jax.random.PRNGKey(0), cfg)
    shape = ((accum, batch_rows, seq) if accum > 1
             else (batch_rows, seq))
    toks = jnp.zeros(shape, jnp.int32)
    batch = {"tokens": toks, "labels": toks,
             "loss_mask": jnp.ones(shape, jnp.float32)}
    return overlap.plan_comm(rules, params, axes, batch, accum), params


# ------------------------------------------------------- comm planning ----

def test_plan_comm_metadata_on_abstract_mesh():
    cfg = get_config("llama-0.5b", reduced=True)
    mesh = _abstract_mesh((2, 4), ("pod", "data"))
    rules = MeshRules(mesh, zero_stage=3, overlap="scheduled")
    plan, params = _plan_for(rules, cfg)
    assert not isinstance(plan, str), plan
    assert plan.dp_axes == ("pod", "data")
    assert plan.n_dp == 8
    assert "stack" in plan.stream_keys
    comms = jax.tree.leaves(
        plan.comm, is_leaf=lambda x: isinstance(x, overlap.LeafComm))
    sharded = [c for c in comms if c.shard_dim is not None]
    assert sharded, "no leaf picked up ZeRO sharding"
    for c in sharded:
        assert set(c.shard_axes) <= {"pod", "data"}
        assert c.nshard in (2, 4, 8)


def test_plan_comm_rejects_tensor_parallel_mesh():
    cfg = get_config("llama-0.5b", reduced=True)
    mesh = _abstract_mesh((2, 4), ("data", "model"))
    rules = MeshRules(mesh, zero_stage=3, overlap="scheduled")
    plan, _ = _plan_for(rules, cfg)
    assert isinstance(plan, str)
    assert "tensor-parallel" in plan


def test_plan_comm_rejects_indivisible_batch():
    cfg = get_config("llama-0.5b", reduced=True)
    mesh = _abstract_mesh((8,), ("data",))
    rules = MeshRules(mesh, zero_stage=3, overlap="scheduled")
    plan, _ = _plan_for(rules, cfg, batch_rows=3)
    assert isinstance(plan, str)
    assert "divide" in plan


def test_plan_comm_rejects_lower_stages():
    cfg = get_config("llama-0.5b", reduced=True)
    mesh = _abstract_mesh((8,), ("data",))
    rules = MeshRules(mesh, zero_stage=2, overlap="scheduled")
    plan, _ = _plan_for(rules, cfg)
    assert isinstance(plan, str)
    assert "stage" in plan


def test_plan_comm_hierarchical_pod_goes_to_psum_axes():
    cfg = get_config("llama-0.5b", reduced=True)
    mesh = _abstract_mesh((2, 4), ("pod", "data"))
    rules = MeshRules(mesh, zero_stage=3, hierarchical_params=True,
                      overlap="scheduled")
    plan, _ = _plan_for(rules, cfg)
    assert not isinstance(plan, str), plan
    comms = jax.tree.leaves(
        plan.comm, is_leaf=lambda x: isinstance(x, overlap.LeafComm))
    for c in comms:
        if c.shard_dim is not None:
            assert c.shard_axes == ("data",)   # params never cross pods
            assert c.psum_axes == ("pod",)     # grads still reduce over pods


def test_int8_wire_falls_back_on_compound_axes():
    cfg = get_config("llama-0.5b", reduced=True)
    mesh = _abstract_mesh((2, 4), ("pod", "data"))
    rules = MeshRules(mesh, zero_stage=3, overlap="scheduled",
                      comm_dtype="int8")
    plan, _ = _plan_for(rules, cfg)
    assert not isinstance(plan, str), plan
    comms = jax.tree.leaves(
        plan.comm, is_leaf=lambda x: isinstance(x, overlap.LeafComm))
    for c in comms:
        if c.shard_dim is not None and len(c.shard_axes) > 1:
            assert c.comm_dtype is None   # quantized path rides one axis


# -------------------------------------------------- exposed-byte model ----

def test_comm_report_scheduled_exposes_strictly_less():
    cfg = get_config("llama-0.5b", reduced=True)
    mesh = _abstract_mesh((8,), ("data",))
    rules = MeshRules(mesh, zero_stage=3, overlap="scheduled")
    plan, params = _plan_for(rules, cfg)
    rep = overlap.comm_report(plan, params, remat=cfg.remat)
    assert rep["exposed_bytes_scheduled"] < rep["exposed_bytes_auto"]
    assert rep["hidden_bytes_scheduled"] > 0
    assert rep["exposed_bytes_scheduled"] + rep["hidden_bytes_scheduled"] \
        == pytest.approx(rep["wire_bytes_scheduled"])


def test_comm_report_int8_cuts_wire_bytes():
    cfg = get_config("llama-0.5b", reduced=True)
    mesh = _abstract_mesh((8,), ("data",))
    f32 = MeshRules(mesh, zero_stage=3, overlap="scheduled")
    q = MeshRules(mesh, zero_stage=3, overlap="scheduled", comm_dtype="int8")
    plan_f, params = _plan_for(f32, cfg)
    plan_q, _ = _plan_for(q, cfg)
    rf = overlap.comm_report(plan_f, params, remat=cfg.remat)
    rq = overlap.comm_report(plan_q, params, remat=cfg.remat)
    # reduced config keeps f32 params: int8+scales is ~4x fewer bytes
    assert rq["wire_bytes_scheduled"] < rf["wire_bytes_scheduled"]


# ------------------------------------------- simulator/planner overlap ----

def test_exposed_comm_time_properties():
    assert exposed_comm_time(1.0, 10.0, 0.0) == 1.0          # serial model
    assert exposed_comm_time(1.0, 10.0, 0.9) == pytest.approx(0.1)  # floor
    partial = exposed_comm_time(1.0, 0.5, 0.8)               # compute-bound
    assert partial == pytest.approx(1.0 - 0.4)
    # monotone: more hiding capacity never increases exposure
    for f in (0.0, 0.3, 0.6, 0.9):
        assert exposed_comm_time(1.0, 0.5, f) >= exposed_comm_time(
            1.0, 0.5, f + 0.1) - 1e-12


def test_overlap_term_changes_hetero_allocation():
    """Acceptance gate: with comm hidden under compute, Algorithm 2's
    sweep can afford more accumulation micro-steps, which re-balances the
    hetero split — and predicts a strictly faster iteration."""
    from repro.core.cluster import make_cluster
    from repro.core.planner import plan

    cfg = get_config("llama-0.5b")
    cluster = make_cluster("t", [("V100-16G", 2), ("T4-16G", 2)], 2.0)
    p0 = plan(cluster, cfg, gbs=128, seq_len=2048, zero_stage=3,
              overlap_factor=0.0)
    p1 = plan(cluster, cfg, gbs=128, seq_len=2048, zero_stage=3,
              overlap_factor=overlap.SCHEDULED_OVERLAP_FACTOR)
    assert p0.allocation.total_batch == p1.allocation.total_batch == 128
    a0 = {n: (a.gmbs, a.micro_batch, a.gas)
          for n, a in p0.allocation.assignments.items()}
    a1 = {n: (a.gmbs, a.micro_batch, a.gas)
          for n, a in p1.allocation.assignments.items()}
    assert a0 != a1, "overlap term did not move the allocation"
    assert p1.predicted.iter_time < p0.predicted.iter_time
    assert p1.predicted.comm_hidden > 0


def test_simulator_overlap_never_slower():
    from repro.core.cluster import make_cluster
    from repro.core.planner import make_runners, plan
    from repro.core.simulator import simulate_plan
    from repro.core.workload import train_flops_per_token

    cfg = get_config("llama-0.5b")
    cluster = make_cluster("t", [("A800-80G", 2), ("V100S-32G", 2)], 4.0)
    p = plan(cluster, cfg, gbs=256, seq_len=2048, zero_stage=3)
    fps = train_flops_per_token(cfg, 2048) * 2048
    s0 = simulate_plan(p.allocation, p.curves, cfg, 2048, cluster, fps,
                       overlap_factor=0.0)
    s1 = simulate_plan(p.allocation, p.curves, cfg, 2048, cluster, fps,
                       overlap_factor=0.7)
    assert s1.iter_time < s0.iter_time
    assert s1.comm_time + s1.comm_hidden == pytest.approx(s0.comm_time)


# ------------------------------------------------ multi-device parity ----

SUBPROC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
from dataclasses import replace
import jax, jax.numpy as jnp
import numpy as np
from repro.configs import get_config
from repro.core.sharding import MeshRules
from repro.core.zero import make_train_step, model_shardings, register_axes
from repro.models import model as mm
from repro.optim.adamw import adamw_init

cfg = get_config("llama-0.5b", reduced=True)
cfg = replace(cfg, dtype="float32", param_dtype="float32")
params, axes = mm.init_model(jax.random.PRNGKey(0), cfg)
opt = adamw_init(params)
rng = np.random.default_rng(0)
toks = jnp.asarray(rng.integers(3, cfg.vocab_size, (16, 16)), jnp.int32)
batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1),
         "loss_mask": jnp.ones((16, 16), jnp.float32)}
stacked = jax.tree.map(lambda x: x.reshape((2, 8) + x.shape[1:]), batch)


def run(mesh, mode, accum=1, comm_dtype=None, prefetch=True):
    rules = MeshRules(mesh, zero_stage=3, overlap=mode,
                      comm_dtype=comm_dtype, overlap_prefetch=prefetch)
    register_axes(rules, axes)
    p_specs, o_specs, _ = model_shardings(rules, params, axes)
    b = stacked if accum > 1 else batch
    with mesh:
        pp = jax.device_put(params, jax.tree.map(rules.sharding, p_specs))
        oo = jax.device_put(opt, jax.tree.map(rules.sharding, o_specs))
        step = jax.jit(make_train_step(cfg, rules, lr=1e-3,
                                       accum_steps=accum))
        for _ in range(2):
            pp, oo, met = step(pp, oo, b)
    return (jax.tree.map(np.asarray, pp),
            {k: float(v) for k, v in met.items()})


def close(a, b, what, rtol=1e-4, atol=1e-5):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(x, y, rtol=rtol, atol=atol, err_msg=what)


mesh1d = jax.make_mesh((8,), ("data",))
mesh2d = jax.make_mesh((2, 4), ("pod", "data"))

p_auto, m_auto = run(mesh1d, "xla")
p_sched, m_sched = run(mesh1d, "scheduled")
close(p_auto, p_sched, "scheduled fp32")
assert abs(m_auto["loss"] - m_sched["loss"]) < 1e-4, (m_auto, m_sched)
assert abs(m_auto["grad_norm"] - m_sched["grad_norm"]) < 1e-3
print("PARITY_F32_OK")

p_auto2, m_auto2 = run(mesh1d, "xla", accum=2)
p_sched2, m_sched2 = run(mesh1d, "scheduled", accum=2)
# accum stacks two micro grads before the update: reduction-order noise
# compounds over the two optimizer steps, hence the slightly wider rtol
close(p_auto2, p_sched2, "scheduled accum", rtol=5e-4, atol=5e-5)
assert abs(m_auto2["loss"] - m_sched2["loss"]) < 1e-4
print("PARITY_ACCUM_OK")

p_re, _ = run(mesh1d, "scheduled", prefetch=False)
close(p_auto, p_re, "scheduled regather")
print("PARITY_REGATHER_OK")

p_pod, m_pod = run(mesh2d, "xla")
p_pods, m_pods = run(mesh2d, "scheduled")
close(p_pod, p_pods, "scheduled pod-data mesh")
print("PARITY_POD_OK")

# int8 wire: quantization perturbs weights/grads within the qcomm bound;
# training must stay close and finite, not bitwise
p_q, m_q = run(mesh1d, "scheduled", comm_dtype="int8")
assert np.isfinite(m_q["loss"])
assert abs(m_q["loss"] - m_auto["loss"]) / abs(m_auto["loss"]) < 0.05, \
    (m_q["loss"], m_auto["loss"])
for x, y in zip(jax.tree.leaves(p_q), jax.tree.leaves(p_auto)):
    assert np.isfinite(x).all()
    np.testing.assert_allclose(x, y, rtol=0.5, atol=0.05)
print("PARITY_INT8_OK")
print("SCHED_PARITY_OK")
"""


@pytest.mark.slow
def test_scheduled_matches_xla_auto_8dev_subprocess():
    """Scheduled ZeRO-3 must produce the same training trajectory as the
    XLA-auto oracle — the schedule changes *when collectives run*, never
    the math. Covers fp32/accum/regather/pod-mesh exactly and int8 wire
    within quantization tolerance."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(Path(__file__).resolve().parents[1] / "src")
    out = subprocess.run([sys.executable, "-c", SUBPROC_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert "SCHED_PARITY_OK" in out.stdout, out.stdout + out.stderr


def test_scheduled_mode_raises_when_unsupported():
    """Explicit overlap='scheduled' on an unsupported combination is an
    error at trace time, not a silent fallback."""
    from repro.core.zero import make_train_step, register_axes
    from repro.optim.adamw import adamw_init

    cfg = get_config("llama-0.5b", reduced=True)
    mesh = jax.make_mesh((1,), ("data",))
    rules = MeshRules(mesh, zero_stage=2, overlap="scheduled")
    params, axes = mm.init_model(jax.random.PRNGKey(0), cfg)
    register_axes(rules, axes)
    opt = adamw_init(params)
    toks = jnp.zeros((2, 8), jnp.int32)
    batch = {"tokens": toks, "labels": toks,
             "loss_mask": jnp.ones((2, 8), jnp.float32)}
    step = make_train_step(cfg, rules, lr=1e-3)
    with pytest.raises(ValueError, match="scheduled"):
        step(params, opt, batch)


def test_auto_mode_falls_back_on_single_device():
    """overlap='auto' on a 1-device mesh silently uses the XLA path (and
    matches overlap='xla' exactly)."""
    from repro.core.zero import make_train_step, register_axes
    from repro.launch.mesh import make_debug_mesh
    from repro.optim.adamw import adamw_init

    cfg = get_config("llama-0.5b", reduced=True)
    params, axes = mm.init_model(jax.random.PRNGKey(0), cfg)
    opt = adamw_init(params)
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(3, cfg.vocab_size, (2, 17)), jnp.int32)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:],
             "loss_mask": jnp.ones((2, 16), jnp.float32)}
    outs = {}
    for mode in ("xla", "auto"):
        rules = MeshRules(make_debug_mesh(1), zero_stage=3, overlap=mode)
        register_axes(rules, axes)
        step = jax.jit(make_train_step(cfg, rules, lr=1e-3))
        p, _, met = step(params, opt, batch)
        outs[mode] = (p, float(met["loss"]))
    assert outs["xla"][1] == outs["auto"][1]
    for a, b in zip(jax.tree.leaves(outs["xla"][0]),
                    jax.tree.leaves(outs["auto"][0])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
