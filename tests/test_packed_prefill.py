"""Packed chunked prefill + refcounted prefix caching.

Four pin groups:
  1. bucketing helpers — boundary behaviour of the now-shared
     ``next_pow2`` / ``pow2_floor`` pair;
  2. allocator — prefix index semantics plus a randomized fuzz proving
     refcounted pages never leak or double-free under admission,
     preemption and retirement of prefix-sharing requests;
  3. engine parity — packed-vs-sequential greedy token parity (GQA and
     page-boundary cases included), the prefix-heavy drill decoding
     bit-identical tokens to the no-sharing path while computing
     strictly fewer prefill tokens, and the cross-lane starvation fix;
  4. kernel — the segment-masked paged-prefill Pallas kernel against a
     per-token numpy oracle, and packed compile-count boundedness.
"""
import os
import sys
from dataclasses import replace

import numpy as np
import pytest

import jax.numpy as jnp

from repro.api import Session
from repro.configs import get_config
from repro.core.bucketing import next_pow2, pow2_floor
from repro.core.cluster import make_cluster
from repro.serve import trace_counts
from repro.serve.paged_cache import PagedCacheOOM, PagedKVCache
from repro.serve.split import plan_traffic_split


def _cfg():
    cfg = get_config("llama-0.5b", reduced=True)
    return replace(cfg, dtype="float32", param_dtype="float32")


@pytest.fixture(scope="module")
def sess():
    return Session.build(_cfg(), mode="serve", impl="reference")


# ------------------------------------------------- bucketing helpers --


def test_next_pow2_boundaries():
    assert [next_pow2(n) for n in (0, 1, 2, 3, 4, 5, 7, 8, 9)] == \
        [1, 1, 2, 4, 4, 8, 8, 8, 16]
    assert next_pow2(1023) == 1024
    assert next_pow2(1024) == 1024
    assert next_pow2(1025) == 2048


def test_pow2_floor_boundaries():
    assert [pow2_floor(n) for n in (1, 2, 3, 4, 5, 7, 8, 9)] == \
        [1, 2, 2, 4, 4, 4, 8, 8]
    assert pow2_floor(1024) == 1024
    assert pow2_floor(1025) == 1024
    with pytest.raises(ValueError):
        pow2_floor(0)


def test_pow2_duality():
    for n in range(1, 300):
        assert pow2_floor(next_pow2(n)) == next_pow2(n)
        assert next_pow2(pow2_floor(n)) == pow2_floor(n)
        assert pow2_floor(n) <= n <= next_pow2(n)


# ------------------------------------------------------- allocator ----


def test_prefix_adopt_page_aligned_only():
    """Only full pages share; the partial tail is re-prefilled (CoW)."""
    kv = PagedKVCache(num_pages=32, page_size=4)
    prompt = list(range(10, 20))                    # 10 tokens: 2.5 pages
    kv.alloc(0)
    kv.reserve(0, len(prompt))
    kv.advance(0, len(prompt))
    kv.register_prefix(0, prompt, len(prompt))
    # 2 full pages registered, the 2-token tail page is not
    assert len(kv.prefix_index) == 2
    assert kv.probe_prefix(prompt) == 8
    assert kv.probe_prefix(prompt[:7]) == 4         # only page 0 whole
    assert kv.probe_prefix([99] + prompt[1:]) == 0  # first page differs
    kv.alloc(1)
    adopted = kv.adopt_prefix(1, prompt)
    assert adopted == 8
    assert kv.tables[1] == kv.tables[0][:2]         # same physical pages
    assert kv.refcounts[kv.tables[0][0]] == 2
    assert kv.prefix_hit_tokens == 8
    kv.check()
    # retire the original: shared pages survive for request 1
    freed = kv.release(0)
    assert freed == 1                               # only the tail page
    kv.check()
    assert kv.probe_prefix(prompt) == 8             # index entries live
    kv.release(1)
    kv.check()
    assert kv.used_pages == 0
    assert not kv.prefix_index and not kv.page_key


def test_prefix_chain_needs_shared_parent():
    """Page k only matches when pages 0..k-1 already matched — an equal
    second page behind a different first page is a different key."""
    kv = PagedKVCache(num_pages=32, page_size=2)
    a, b = [1, 2, 7, 8], [3, 4, 7, 8]               # same second page
    for rid, toks in ((0, a), (1, b)):
        kv.alloc(rid)
        kv.reserve(rid, 4)
        kv.advance(rid, 4)
        kv.register_prefix(rid, toks, 4)
    kv.check()
    assert len(kv.prefix_index) == 4                # no aliasing
    assert kv.probe_prefix(a) == 4
    assert kv.probe_prefix(b) == 4
    assert kv.probe_prefix([1, 2, 9, 9]) == 2


def test_register_prefix_sibling_conflict_keeps_one_chain():
    """Two requests that prefilled the same prompt independently (both
    admitted before either registered): the second publisher must not
    splice its pages into the first one's chain."""
    kv = PagedKVCache(num_pages=32, page_size=2)
    toks = [5, 6, 7, 8]
    for rid in (0, 1):
        kv.alloc(rid)
        kv.reserve(rid, 4)
        kv.advance(rid, 4)
    assert kv.register_prefix(0, toks, 4) == 2
    assert kv.register_prefix(1, toks, 4) == 0      # key taken — no splice
    kv.check()
    kv.release(0)                                   # chain owner retires
    kv.check()
    assert kv.probe_prefix(toks) == 0               # chain gone with it
    kv.release(1)
    kv.check()


def test_allocator_fuzz_refcounted_lifecycle():
    """Randomized admission / prefill / preemption / retirement of
    prefix-sharing requests; ``check()`` after every operation proves
    pages never leak, double-free, or outlive their chain parents."""
    rng = np.random.default_rng(7)
    kv = PagedKVCache(num_pages=24, page_size=4)
    # a small pool of prompt families so prefixes actually collide
    bases = [list(rng.integers(3, 50, 12)) for _ in range(3)]
    live = {}                                       # rid -> (tokens, written)
    next_rid = 0
    for _ in range(400):
        op = rng.random()
        if op < 0.4 and len(live) < 8:              # admit (maybe adopt)
            base = bases[rng.integers(len(bases))]
            cut = int(rng.integers(4, len(base) + 1))
            extra = list(rng.integers(3, 50, rng.integers(0, 4)))
            toks = base[:cut] + extra
            rid = next_rid
            next_rid += 1
            kv.alloc(rid)
            kv.check()
            adopted = kv.adopt_prefix(rid, toks[:len(toks) - 1])
            kv.check()
            try:
                kv.reserve(rid, len(toks) - adopted)
            except PagedCacheOOM:
                kv.release(rid)                     # admission rollback
                kv.check()
                continue
            kv.check()
            live[rid] = (toks, adopted)
        elif op < 0.75 and live:                    # prefill a few tokens
            rid = list(live)[rng.integers(len(live))]
            toks, written = live[rid]
            n = min(int(rng.integers(1, 6)), len(toks) - written)
            if n > 0:
                kv.advance(rid, n)
                written += n
                kv.register_prefix(rid, toks, written)
                live[rid] = (toks, written)
                kv.check()
        elif live:                                  # preempt or retire
            rid = list(live)[rng.integers(len(live))]
            del live[rid]
            kv.release(rid)
            kv.check()
    for rid in list(live):
        kv.release(rid)
        kv.check()
    assert kv.used_pages == 0
    assert kv.free_pages == kv.num_pages - 1
    assert not kv.prefix_index and not kv.page_key and not kv.refcounts


# --------------------------------------------------- engine parity ----


def _run_engine(sess, prompts, gens, **kw):
    eng = sess.engine(**kw)
    rids = [eng.submit(p, g) for p, g in zip(prompts, gens)]
    results = eng.run()
    eng.kv.check()
    assert eng.kv.used_pages == 0
    return [results[r] for r in rids], eng


def test_packed_matches_sequential_tokens(sess):
    """The tentpole parity pin: packed prefill decodes exactly the
    tokens the sequential chunked path decodes, across ragged lengths
    that straddle page boundaries (page_size 4: prompts end mid-page,
    on-boundary, and one token past it) under the GQA config."""
    rng = np.random.default_rng(3)
    lens = (5, 16, 11, 3, 8, 9)                     # 16, 8 on-boundary
    prompts = [rng.integers(3, sess.cfg.vocab_size, int(n)).tolist()
               for n in lens]
    gens = [6, 3, 8, 5, 4, 7]
    kw = dict(num_pages=128, page_size=4, chunk=4)
    seq, _ = _run_engine(sess, prompts, gens, packed_prefill=False,
                         prefix_cache=False, **kw)
    packed, eng = _run_engine(sess, prompts, gens, packed_prefill=True,
                              prefix_cache=False, **kw)
    assert packed == seq
    # the whole point: strictly fewer prefill model invocations
    assert eng.telemetry.prefill_calls < sum(
        -(-n // 4) for n in lens)


def test_packed_parity_under_preemption(sess):
    """Packed prefill + a pool tight enough to force preemption still
    reproduces the uncontended tokens (recompute stays exact)."""
    rng = np.random.default_rng(4)
    prompts = [rng.integers(3, sess.cfg.vocab_size, int(n)).tolist()
               for n in (9, 7, 12, 8)]
    gens = [8, 8, 8, 8]
    roomy, _ = _run_engine(sess, prompts, gens, num_pages=128,
                           page_size=4, chunk=4)
    tight, eng = _run_engine(sess, prompts, gens, num_pages=14,
                             page_size=4, chunk=4)
    assert eng.preemptions > 0, "pool was large enough — test is vacuous"
    assert tight == roomy


def _run_staggered(sess, prompts, gens, **kw):
    """Submit one request every other tick — arrivals must be staggered
    for prefix sharing to ever trigger: adoption happens at admission,
    against pages an *earlier* request already wrote and registered."""
    eng = sess.engine(**kw)
    rids = []
    for p, g in zip(prompts, gens):
        rids.append(eng.submit(p, g))
        eng.step()
        eng.step()
        eng.kv.check()
    results = eng.run()
    eng.kv.check()
    assert eng.kv.used_pages == 0
    return [results[r] for r in rids], eng


def test_prefix_sharing_parity_and_fewer_tokens(sess):
    """The acceptance drill: staggered prompts sharing a long
    page-aligned prefix decode bit-identical tokens with prefix caching
    on vs off, while computing strictly fewer prefill tokens (adopted
    pages skip the model)."""
    rng = np.random.default_rng(5)
    system = rng.integers(3, sess.cfg.vocab_size, 12).tolist()  # 3 pages
    prompts = [system + rng.integers(3, sess.cfg.vocab_size,
                                     int(n)).tolist()
               for n in (3, 5, 2, 6, 4)]
    gens = [5, 4, 6, 3, 5]
    kw = dict(num_pages=128, page_size=4, chunk=4, prefill_budget=16)
    plain, eng_off = _run_staggered(sess, prompts, gens,
                                    prefix_cache=False, **kw)
    shared, eng_on = _run_staggered(sess, prompts, gens,
                                    prefix_cache=True, **kw)
    assert shared == plain
    submitted = sum(len(p) for p in prompts)
    assert eng_on.telemetry.prefill_tokens < submitted
    assert (eng_on.telemetry.prefill_tokens
            < eng_off.telemetry.prefill_tokens)
    assert eng_on.telemetry.prefix_hit_tokens >= 12 * (len(prompts) - 1)
    assert eng_on.kv.prefix_hits > 0


def test_prefix_sharing_preemption_respects_siblings(sess):
    """A tight pool with prefix sharing: preempting/retiring one sharer
    must not free pages a sibling still reads. Token parity against the
    roomy no-sharing run covers correctness; check() covers the
    allocator invariants after every tick."""
    rng = np.random.default_rng(6)
    system = rng.integers(3, sess.cfg.vocab_size, 8).tolist()
    prompts = [system + rng.integers(3, sess.cfg.vocab_size,
                                     int(n)).tolist()
               for n in (4, 3, 5, 2)]
    gens = [8, 8, 8, 8]
    want, _ = _run_engine(sess, prompts, gens, num_pages=128,
                          page_size=4, chunk=4, prefix_cache=False)
    eng = sess.engine(num_pages=16, page_size=4, chunk=4)
    rids = [eng.submit(p, g) for p, g in zip(prompts, gens)]
    while eng.queued or eng.prefilling or eng.decoding:
        eng.step()
        eng.kv.check()
    got = [eng.done[r].generated for r in rids]
    assert got == want
    assert eng.kv.used_pages == 0
    assert eng.kv.prefix_hits > 0, "no page was ever shared — vacuous"
    assert eng.preemptions > 0, "pool never pressured — vacuous"


def _starvation_drive(sess, split, rng, *, age_priority, packed,
                      max_ticks=40):
    """One long low-share-lane prompt against a continuous stream of
    short high-share-lane prompts. Returns the tick its prefill
    completed (None = starved past ``max_ticks``)."""
    eng = sess.engine(num_pages=256, page_size=4, chunk=4,
                      prefill_budget=4, split=split,
                      age_priority=age_priority,
                      packed_prefill=packed, prefix_cache=False)
    lanes = sorted(split.prefill_share, key=split.prefill_share.get)
    victim_rid = eng.submit(
        rng.integers(3, sess.cfg.vocab_size, 24).tolist(), 2)
    vreq = eng.queued[-1]
    vreq.lane = lanes[0]
    for tick in range(max_ticks):
        while len(eng.queued) < 4:          # saturate the fast lane
            eng.submit(rng.integers(3, sess.cfg.vocab_size, 4).tolist(),
                       1)
            eng.queued[-1].lane = lanes[-1]
        eng.step()
        if vreq.prefill_pos >= len(vreq.prompt):
            return tick
    assert victim_rid not in eng.done
    return None


def test_prefill_starvation_age_priority(sess):
    """The satellite bugfix pin, both prefill paths:

    - sequential walk: the budget is handed out purely in
      ``_prefill_order`` order, so without aging a low-share lane's
      long prompt is bypassed for as long as the high-share lane has
      pending chunks — with ``age_priority`` its accumulated wait
      eventually outranks the share gap and it finishes;
    - packed walk: each lane's budget share is floored at one token, so
      the victim drains even at ``age_priority=0`` — packing never
      reintroduces the starvation the sequential path exhibits.
    """
    cluster = make_cluster("c8", [("V100-16G", 4), ("T4-16G", 4)], 12.0)
    split = plan_traffic_split(cluster, sess.cfg, requests=8,
                               cache_len=64)

    def rng():
        return np.random.default_rng(8)

    starved = _starvation_drive(sess, split, rng(), age_priority=0.0,
                                packed=False)
    assert starved is None, (
        f"un-aged sequential victim finished at tick {starved} — "
        "scenario no longer starves, strengthen it")
    aged = _starvation_drive(sess, split, rng(), age_priority=0.25,
                             packed=False)
    assert aged is not None, "aged victim still starved"
    packed_flat = _starvation_drive(sess, split, rng(), age_priority=0.0,
                                    packed=True)
    assert packed_flat is not None, "packed lane floor failed to drain"


# ------------------------------------------------ kernel + compiles ----


def test_flash_prefill_paged_kernel_vs_oracle():
    """Interpret-mode kernel against a per-token numpy softmax oracle:
    multiple segments, a mid-prompt chunk (nonzero offset), an empty
    segment row, GQA grouping, and bucket padding."""
    from repro.kernels.flash_prefill_paged import flash_prefill_paged_pallas
    rng = np.random.default_rng(0)
    ps, npages, Hkv, D, Hq = 4, 16, 2, 8, 4
    T, G, P = 16, 4, 4
    k_pages = jnp.asarray(rng.normal(size=(npages, ps, Hkv, D)),
                          jnp.float32)
    v_pages = jnp.asarray(rng.normal(size=(npages, ps, Hkv, D)),
                          jnp.float32)
    q = jnp.asarray(rng.normal(size=(T, Hq, D)), jnp.float32)
    seg_ids = np.zeros(T, np.int32)
    positions = np.zeros(T, np.int32)
    seg_ids[:6] = 1
    positions[:6] = np.arange(6)                    # fresh chunk
    seg_ids[6:11] = 2
    positions[6:11] = np.arange(8, 13)              # later chunk, offset 8
    page_table = np.zeros((G, P), np.int32)
    page_table[0, :2] = [1, 2]
    page_table[1, :4] = [3, 4, 5, 6]
    seg_maxpos = np.array([5, 12, -1, -1], np.int32)
    out = np.asarray(flash_prefill_paged_pallas(
        q, k_pages, v_pages, jnp.asarray(page_table),
        jnp.asarray(seg_maxpos), jnp.asarray(seg_ids),
        jnp.asarray(positions), interpret=True))
    S_tot = P * ps
    keys = np.asarray(k_pages)[page_table].reshape(G, S_tot, Hkv, D)
    vals = np.asarray(v_pages)[page_table].reshape(G, S_tot, Hkv, D)
    group = Hq // Hkv
    for t in range(T):
        g = seg_ids[t] - 1
        if g < 0:
            assert np.all(out[t] == 0.0), f"pad token {t} not zeroed"
            continue
        for h in range(Hq):
            kh = keys[g, :, h // group, :]
            vh = vals[g, :, h // group, :]
            s = kh @ np.asarray(q)[t, h] / np.sqrt(D)
            s = np.where(np.arange(S_tot) <= positions[t], s, -np.inf)
            p = np.exp(s - s.max())
            p /= p.sum()
            np.testing.assert_allclose(out[t, h], p @ vh, rtol=2e-5,
                                       atol=2e-5, err_msg=f"t={t} h={h}")


def test_packed_prefill_compile_counts_bounded(sess):
    """Packed prefill compiles are bounded by the (T, G, P) power-of-two
    buckets actually visited, not by ticks — and a second engine over
    the same config adds zero."""
    eng = sess.engine(num_pages=256, page_size=4, chunk=4)
    rng = np.random.default_rng(9)
    for n in (3, 5, 7, 9, 11, 13, 4, 6):
        eng.submit(rng.integers(3, sess.cfg.vocab_size, n).tolist(),
                   int(rng.integers(2, 7)))
    before = trace_counts()
    eng.run()
    mid = trace_counts()
    assert mid.get("prefill_packed", 0) - before.get("prefill_packed",
                                                     0) <= 6
    assert mid.get("prefill", 0) == before.get("prefill", 0)

    eng2 = sess.engine(num_pages=256, page_size=4, chunk=4)
    for n in (3, 5, 7, 9):
        eng2.submit(rng.integers(3, sess.cfg.vocab_size, n).tolist(), 3)
    eng2.run()
    after = trace_counts()
    assert after == mid, "second engine re-compiled despite shared cache"


def test_engine_surfaces_prefill_telemetry(sess):
    eng = sess.engine(num_pages=64, page_size=4, chunk=4,
                      prefill_budget=8)
    eng.submit([4, 5, 6, 7, 8, 9, 10, 11], 3)
    eng.step()                                     # register before the
    eng.step()                                     # sharer arrives
    eng.submit([4, 5, 6, 7, 8, 9, 12, 13], 2)     # shares one page
    eng.run()
    d = eng.describe()
    assert d["prefill"]["calls"] > 0
    assert 0 < d["prefill"]["fill_frac"] <= 1.0
    assert d["prefill"]["calls_per_tick"] > 0
    assert d["prefill"]["prefix_hit_tokens"] >= 4
    snap = eng.telemetry.snapshot()
    assert snap["prefill_calls"] == d["prefill"]["calls"]
    assert snap["prefix_hit_tokens"] >= 4
    assert snap["prefill_fill_frac"] == d["prefill"]["fill_frac"]
    line = eng.log_line()
    assert "fill" in line and "hit" in line
