"""Paged KV cache substrate: kernel bit-parity vs the contiguous
flash-decode path, and the host-side allocator's no-leak invariants.

The parity contract is exact: at ``page_size == block_k`` the paged
kernel visits the same KV tiles at the same boundaries in the same
order, so its online-softmax accumulation is *bit-identical* to the
contiguous kernel on an equivalent fill — asserted with array_equal,
not allclose.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels.flash_decode import flash_decode_pallas
from repro.kernels.flash_decode_paged import flash_decode_paged_pallas
from repro.serve.paged_cache import PagedCacheOOM, PagedKVCache

PAGE = 8


def _pool(rng, num_pages, hkv, d):
    k = rng.standard_normal((num_pages, PAGE, hkv, d)).astype(np.float32)
    v = rng.standard_normal((num_pages, PAGE, hkv, d)).astype(np.float32)
    return jnp.asarray(k), jnp.asarray(v)


def _paged_case(rng, lengths, hq, hkv, d, max_pages=None):
    """Build a ragged paged batch with shuffled (non-contiguous) page
    assignments plus the per-request contiguous equivalents."""
    B = len(lengths)
    need = [-(-l // PAGE) if l else 0 for l in lengths]
    if max_pages is None:
        max_pages = max(max(need), 1)
    pool_pages = 1 + sum(need) + 3          # null page + slack
    k_pages, v_pages = _pool(rng, pool_pages, hkv, d)
    ids = list(rng.permutation(np.arange(1, pool_pages)))
    pt = np.zeros((B, max_pages), np.int32)
    for b, n in enumerate(need):
        for i in range(n):
            pt[b, i] = ids.pop()
    q = jnp.asarray(
        rng.standard_normal((B, hq, 1, d)).astype(np.float32))
    ln = np.asarray(lengths, np.int32)
    return q, k_pages, v_pages, jnp.asarray(pt), jnp.asarray(ln)


def _contiguous_row(q, k_pages, v_pages, pt_row, length):
    """Oracle: gather request b's pages into a contiguous (1,S,Hkv,D)
    cache and run the contiguous kernel at block_k == page size."""
    n = -(-int(length) // PAGE)
    k = k_pages[np.asarray(pt_row[:n])].reshape(1, n * PAGE, *k_pages.shape[2:])
    v = v_pages[np.asarray(pt_row[:n])].reshape(1, n * PAGE, *v_pages.shape[2:])
    return flash_decode_pallas(q, k, v, jnp.int32(length), block_k=PAGE,
                               interpret=True)


@pytest.mark.parametrize("hq,hkv", [(4, 2), (4, 4), (8, 1)])
def test_paged_bitwise_matches_contiguous(hq, hkv):
    """GQA / MHA / MQA, ragged lengths straddling every page-boundary
    case: mid-page, exactly-full page, one-past-boundary, multi-page."""
    rng = np.random.default_rng(0)
    lengths = [3, PAGE, PAGE + 1, 3 * PAGE - 2]
    q, kp, vp, pt, ln = _paged_case(rng, lengths, hq, hkv, 16)
    out = flash_decode_paged_pallas(q, kp, vp, pt, ln, interpret=True)
    assert out.shape == (len(lengths), hq, 1, 16)
    for b, l in enumerate(lengths):
        ref = _contiguous_row(q[b:b + 1], kp, vp, pt[b], l)
        assert np.array_equal(np.asarray(out[b:b + 1]), np.asarray(ref)), \
            f"row {b} (len {l}) diverged from contiguous"


def test_paged_zero_length_rows_return_zeros():
    """Padded batch-bucket slots (length 0, table all null page) must
    come back as exact zeros without touching the pool."""
    rng = np.random.default_rng(1)
    q, kp, vp, pt, ln = _paged_case(rng, [PAGE + 3, 5], 4, 2, 16,
                                    max_pages=4)
    pt = jnp.asarray(np.vstack([np.asarray(pt),
                                np.zeros((2, 4), np.int32)]))
    ln = jnp.asarray(np.concatenate([np.asarray(ln), [0, 0]]))
    q = jnp.concatenate([q, jnp.asarray(
        rng.standard_normal((2, 4, 1, 16)).astype(np.float32))])
    out = flash_decode_paged_pallas(q, kp, vp, pt, ln, interpret=True)
    assert np.array_equal(np.asarray(out[2:]), np.zeros((2, 4, 1, 16)))
    # live rows unaffected by the dead ones riding along
    solo = flash_decode_paged_pallas(q[:2], kp, vp, pt[:2], ln[:2],
                                     interpret=True)
    assert np.array_equal(np.asarray(out[:2]), np.asarray(solo))


def test_paged_table_padding_is_inert():
    """Entries past a request's fill must not affect its output even
    when they point at real (allocated, garbage-filled) pages."""
    rng = np.random.default_rng(2)
    q, kp, vp, pt, ln = _paged_case(rng, [PAGE + 2], 4, 2, 16,
                                    max_pages=6)
    pt2 = np.asarray(pt).copy()
    pt2[0, 2:] = 1                      # a live page, beyond the fill
    out1 = flash_decode_paged_pallas(q, kp, vp, pt, ln, interpret=True)
    out2 = flash_decode_paged_pallas(q, kp, vp, jnp.asarray(pt2), ln,
                                     interpret=True)
    assert np.array_equal(np.asarray(out1), np.asarray(out2))


# ------------------------------------------------------- allocator -----


def test_cache_lifecycle_and_no_leak():
    kv = PagedKVCache(num_pages=16, page_size=4)
    assert kv.free_pages == 15          # page 0 reserved
    kv.alloc(0)
    kv.reserve(0, 10)                   # 3 pages
    assert len(kv.table(0)) == 3 and kv.used_pages == 3
    kv.check()
    kv.advance(0, 10)
    assert kv.length(0) == 10
    kv.reserve(0, 1)                    # slot 10 fits page 3 — no growth
    assert len(kv.table(0)) == 3
    kv.advance(0, 1)
    kv.reserve(0, 2)                    # crosses into page 4
    assert len(kv.table(0)) == 4
    kv.check()
    with pytest.raises(ValueError):
        kv.advance(0, 99)               # past reservation = bug, not OOM
    assert kv.release(0) == 4
    assert kv.free_pages == 15 and kv.used_pages == 0
    assert kv.peak_in_use == 4
    kv.check()


def test_cache_oom_leaves_state_unchanged():
    kv = PagedKVCache(num_pages=4, page_size=4)   # 3 usable pages
    kv.alloc(0)
    kv.reserve(0, 8)                    # 2 pages
    kv.alloc(1)
    before = (kv.table(0), kv.free_pages, kv.length(0))
    with pytest.raises(PagedCacheOOM):
        kv.reserve(1, 9)                # needs 3, only 1 free
    assert (kv.table(0), kv.free_pages, kv.length(0)) == before
    assert kv.table(1) == ()
    kv.check()
    assert not kv.can_fit(9) and kv.can_fit(4)


def test_cache_gather_pads_to_null_page():
    kv = PagedKVCache(num_pages=8, page_size=4)
    kv.alloc(5)
    kv.reserve(5, 6)
    kv.advance(5, 6)
    pt, ln = kv.gather([5], batch=4, max_pages=4)
    assert pt.shape == (4, 4) and ln.shape == (4,)
    assert list(pt[0][:2]) == list(kv.table(5))
    assert pt[0][2] == 0 and pt[0][3] == 0      # past-fill -> null page
    assert (pt[1:] == 0).all() and (ln[1:] == 0).all()
    assert ln[0] == 6
    with pytest.raises(ValueError):
        kv.gather([5], batch=4, max_pages=1)    # table wider than bucket


def test_cache_random_workload_never_leaks():
    rng = np.random.default_rng(3)
    kv = PagedKVCache(num_pages=32, page_size=4)
    live = {}
    for i in range(300):
        op = rng.integers(0, 3)
        if op == 0 or not live:
            rid = 1000 + i
            kv.alloc(rid)
            live[rid] = 0
        elif op == 1:
            rid = int(rng.choice(list(live)))
            n = int(rng.integers(1, 9))
            try:
                kv.reserve(rid, n)
                kv.advance(rid, n)
                live[rid] += n
            except PagedCacheOOM:
                pass                     # state must survive unchanged
        else:
            rid = int(rng.choice(list(live)))
            kv.release(rid)
            del live[rid]
        kv.check()
        assert kv.used_pages + kv.free_pages == 31
    for rid in list(live):
        kv.release(rid)
    kv.check()
    assert kv.free_pages == 31
