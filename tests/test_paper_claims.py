"""Regression tests pinning the paper's headline claims (EXPERIMENTS.md
§Paper-validation) so refactors can't silently break the reproduction."""
import pytest

from benchmarks.common import evaluate_cluster
from repro.core.cluster import cluster_A, cluster_B, cluster_C

GBS = 256


@pytest.mark.parametrize("cluster_fn,name", [
    (cluster_A, "A"), (cluster_B, "B"), (cluster_C, "C")])
@pytest.mark.parametrize("stage", [0, 1, 2, 3])
def test_poplar_geq_all_baselines(cluster_fn, name, stage):
    """Claim 1: Poplar >= every baseline on every (cluster x stage)."""
    res = evaluate_cluster(cluster_fn(), "llama-0.5b", GBS, stage)
    assert res, f"cluster {name} z{stage} infeasible"
    pop = res["poplar"].cluster_tflops
    for strat, r in res.items():
        assert pop >= r.cluster_tflops * 0.999, (
            f"poplar {pop:.1f} < {strat} {r.cluster_tflops:.1f} "
            f"on cluster {name} z{stage}")


def test_cluster_A_z0_parity_with_whale():
    """Claim 2 (Fig. 3a): equal compute capability -> Whale can't see the
    memory heterogeneity; Poplar ~ DeepSpeed ~ Whale at z0/z1."""
    res = evaluate_cluster(cluster_A(), "llama-0.5b", GBS, 0)
    pop = res["poplar"].cluster_tflops
    ds = res["deepspeed"].cluster_tflops
    assert pop / ds < 1.10      # parity, not a big win


def test_cluster_B_walltime_beats_flops_metric():
    """Claim 3 (Fig. 3b): measured wall time allocates better than spec
    FLOPs when turbo/sustained behaviour diverges (V100 vs T4)."""
    res = evaluate_cluster(cluster_B(), "llama-0.5b", GBS, 0)
    pop = res["poplar"].cluster_tflops
    whale = res["whale"].cluster_tflops
    assert pop / whale > 1.05


def test_z23_beats_z01_margin_vs_whale_on_B():
    """Claim 4: Poplar's advantage over Whale grows at z2/z3 (fewer
    accumulation steps -> less communication)."""
    r01 = evaluate_cluster(cluster_B(), "llama-0.5b", GBS, 1)
    r23 = evaluate_cluster(cluster_B(), "llama-0.5b", GBS, 3)
    m01 = r01["poplar"].cluster_tflops / r01["whale"].cluster_tflops
    m23 = r23["poplar"].cluster_tflops / r23["whale"].cluster_tflops
    assert m23 > m01


def test_hetero_beats_strong_homog_on_all_clusters():
    """Using both device kinds must beat the strong sub-cluster alone."""
    for fn in (cluster_A, cluster_B, cluster_C):
        res = evaluate_cluster(fn(), "llama-0.5b", GBS, 1)
        assert (res["poplar"].cluster_tflops
                > res["homog-strong"].cluster_tflops)
