"""Algorithm 1 (mbs search) + Algorithm 2 (batch allocation) properties."""
import math

import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: skip, never error
from hypothesis import assume, given, settings, strategies as st

from repro.configs import get_config
from repro.core.allocation import (allocate_flops_proportional,
                                   allocate_stage01, allocate_stage23,
                                   allocate_uniform, fit_curve)
from repro.core.cluster import CATALOG, ClusterSpec, make_cluster
from repro.core.planner import make_runners, plan
from repro.core.profiler import (AnalyticalRunner, SimOOM, probes_saved,
                                 profile_cluster, profile_device,
                                 time_consumed_during_step, StepSegments)
from repro.core.workload import MemoryModel, train_flops_per_token

CFG = get_config("llama-0.5b")
SEQ = 4096


def _runner(dev="V100-16G", stage=0, n=4):
    spec = CATALOG[dev]
    mem = MemoryModel(CFG, SEQ, stage, n)
    fps = train_flops_per_token(CFG, SEQ) * SEQ
    return AnalyticalRunner(spec, mem, fps, stage)


# ---------------------------------------------------------------- Alg. 1 --

@pytest.mark.parametrize("dev", ["A100-80G", "V100-16G", "T4-16G"])
@pytest.mark.parametrize("stage", [0, 1, 2, 3])
def test_mbs_search_exact(dev, stage):
    r = _runner(dev, stage)
    prof = profile_device(r, dev, stage)
    truth = r.memory.max_batch(r.spec.mem_gb)
    assert prof.mbs == truth
    # probing at mbs must not OOM; at mbs+1 it must
    assert r.memory_bytes_at(prof.mbs) <= r.memory_capacity_bytes()
    assert r.memory_bytes_at(prof.mbs + 1) > r.memory_capacity_bytes()


def test_mbs_search_cost_logarithmic():
    r = _runner("A100-80G", 3, 8)
    prof = profile_device(r, "a", 3)
    # exponential + binary search: O(2 log mbs) probes, not O(mbs)
    assert prof.probes <= 2 * math.ceil(math.log2(max(prof.mbs, 2))) + 6


def test_stage_escalation_when_model_too_big():
    big = get_config("phi3.5-moe-42b-a6.6b")  # 42B params: z0 needs 670 GB
    mem0 = MemoryModel(big, SEQ, 0, 8)
    assert mem0.max_batch(80.0) == 0
    mem3 = MemoryModel(big, SEQ, 3, 64)
    assert mem3.max_batch(80.0) > 0  # sharded across 64 x 80GB it fits


def test_time_consumed_subtracts_collectives():
    seg = StepSegments(fwd=1.0, bwd=2.0, optim=0.1, ag_fwd=0.3, ag_bwd=0.3,
                       rs_bwd=0.2)
    assert time_consumed_during_step(seg, 0) == pytest.approx(3.0)
    assert time_consumed_during_step(seg, 3) == pytest.approx(3.0)


# ---------------------------------------------------------------- Alg. 2 --

def _curves(cluster: ClusterSpec, stage=0):
    runners = make_runners(cluster, CFG, SEQ, stage)
    from repro.core.profiler import profile_cluster
    profs = profile_cluster(runners, stage)
    return {n: fit_curve(p) for n, p in profs.items()}


@given(st.integers(8, 2048))
@settings(max_examples=25, deadline=None)
def test_stage01_allocation_sums_to_gbs(gbs):
    curves = _curves(make_cluster("t", [("V100-16G", 2), ("T4-16G", 2)]))
    plan_ = allocate_stage01(curves, gbs)
    assert plan_.total_batch == gbs
    for a in plan_.assignments.values():
        assert a.gmbs >= 0
        assert a.micro_batch <= curves[a.name].mbs


@given(st.integers(64, 4096))
@settings(max_examples=15, deadline=None)
def test_stage23_allocation_sums_to_gbs(gbs):
    curves = _curves(make_cluster("t", [("A800-80G", 2), ("V100S-32G", 2)]), 3)
    plan_ = allocate_stage23(curves, gbs, comm_time_per_step=0.02,
                             zero_stage=3)
    assert plan_.total_batch == gbs
    for a in plan_.assignments.values():
        assert 0 <= a.micro_batch <= curves[a.name].mbs
        if a.gmbs:
            full = a.gas - (1 if a.lbs else 0)
            assert full * a.micro_batch + a.lbs == a.gmbs


def test_faster_devices_get_more_batch():
    curves = _curves(make_cluster("t", [("A800-80G", 1), ("T4-16G", 1)]))
    plan_ = allocate_stage01(curves, 256)
    a800 = next(v for k, v in plan_.assignments.items() if "A800" in k)
    t4 = next(v for k, v in plan_.assignments.items() if "T4" in k)
    assert a800.gmbs > 2 * t4.gmbs


def test_poplar_beats_uniform_on_hetero_cluster():
    from repro.core.simulator import simulate_plan
    cluster = make_cluster("t", [("V100-16G", 2), ("T4-16G", 2)], 12.0)
    curves = _curves(cluster)
    fps = train_flops_per_token(CFG, SEQ) * SEQ
    p = allocate_stage01(curves, 512)
    u = allocate_uniform(curves, 512, 1)
    sp = simulate_plan(p, curves, CFG, SEQ, cluster, fps)
    su = simulate_plan(u, curves, CFG, SEQ, cluster, fps)
    assert sp.cluster_tflops >= su.cluster_tflops


def test_whale_flops_misallocates_vs_poplar():
    """Paper Fig. 8: spec-sheet FLOPs mispredicts real performance; Poplar's
    wall-time measurement allocates better (or equal)."""
    from repro.core.simulator import simulate_plan
    cluster = make_cluster("t", [("V100-16G", 2), ("T4-16G", 2)], 12.0)
    curves = _curves(cluster)
    fps = train_flops_per_token(CFG, SEQ) * SEQ
    rating = {n: CATALOG[n.split("#")[0]].peak_tflops for n in curves}
    w = allocate_flops_proportional(curves, 512, 1, rating)
    p = allocate_stage01(curves, 512)
    sw = simulate_plan(w, curves, CFG, SEQ, cluster, fps)
    sp = simulate_plan(p, curves, CFG, SEQ, cluster, fps)
    assert sp.cluster_tflops >= sw.cluster_tflops * 0.999


@given(n_strong=st.integers(1, 4), n_weak=st.integers(1, 4),
       gbs=st.sampled_from([128, 256, 512]), stage=st.sampled_from([0, 3]))
@settings(max_examples=10, deadline=None)
def test_poplar_dominates_baselines_property(n_strong, n_weak, gbs, stage):
    """Property (the paper's core claim): on any 2-type composition,
    Poplar's allocation never loses to uniform or FLOPs-proportional."""
    from repro.core.simulator import simulate_plan
    from repro.core.workload import comm_time_per_microstep
    cluster = make_cluster("t", [("A800-80G", n_strong),
                                 ("V100S-32G", n_weak)], 12.0)
    curves = _curves(cluster, stage)
    fps = train_flops_per_token(CFG, SEQ) * SEQ
    rating = {n: CATALOG[n.split("#")[0]].peak_tflops for n in curves}
    if stage <= 1:
        p = allocate_stage01(curves, gbs)
    else:
        comm = comm_time_per_microstep(CFG, stage, cluster.n,
                                       cluster.effective_link_gbps(cluster.n))
        p = allocate_stage23(curves, gbs, comm, stage)
    u = allocate_uniform(curves, gbs, stage)
    w = allocate_flops_proportional(curves, gbs, stage, rating)
    for pl in (p, u, w):
        pl.zero_stage = stage
    sp = simulate_plan(p, curves, CFG, SEQ, cluster, fps)
    su = simulate_plan(u, curves, CFG, SEQ, cluster, fps)
    sw = simulate_plan(w, curves, CFG, SEQ, cluster, fps)
    assert sp.cluster_tflops >= su.cluster_tflops * 0.999
    assert sp.cluster_tflops >= sw.cluster_tflops * 0.999


# ------------------------------------------------------------- planner ----

@pytest.mark.parametrize("stage", [0, 1, 2, 3])
def test_planner_end_to_end_paper_clusters(stage):
    from repro.core.cluster import PAPER_CLUSTERS
    for make in PAPER_CLUSTERS.values():
        c = make()
        p = plan(c, CFG, gbs=256, seq_len=SEQ, zero_stage=stage)
        assert p.allocation.total_batch == 256
        assert p.predicted.iter_time > 0
        assert 0.5 < p.predicted.utilization <= 1.0


def test_planner_auto_stage():
    # 1.1B model: ZeRO-0 needs 16P = 17.6 GB > 16 GB, so the paper's
    # automatic escalation must kick in and land on stage >= 1.
    mid = get_config("llama-1.1b")
    c = make_cluster("t", [("V100-16G", 4)])
    p = plan(c, mid, gbs=16, seq_len=512, zero_stage=None)
    assert 1 <= p.zero_stage <= 3
