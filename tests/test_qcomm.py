"""Quantized collectives (ZeRO++ qwZ/qgZ building blocks): numerics vs
the unquantized reference, error bounds, and wire-byte accounting."""
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dep: skip, never error
from hypothesis import given, settings, strategies as st

from repro.core.qcomm import (dequantize_blocks, quantize_blocks,
                              wire_bytes)


@given(st.integers(1, 2000), st.integers(0, 10**6))
@settings(max_examples=25, deadline=None)
def test_quantize_roundtrip_error_bound(n, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n,)) * rng.uniform(0.01, 100),
                    jnp.float32)
    q, s = quantize_blocks(x, block=256)
    y = dequantize_blocks(q, s, n)
    # int8 block quantization: error <= amax_block / 127 / 2 per element
    amax = float(jnp.abs(x).max())
    assert float(jnp.abs(y - x).max()) <= amax / 127.0 + 1e-6


def test_quantize_exact_zeros_and_scale_safety():
    x = jnp.zeros((512,), jnp.float32)
    q, s = quantize_blocks(x)
    y = dequantize_blocks(q, s, 512)
    np.testing.assert_array_equal(np.asarray(y), 0.0)


def test_wire_bytes_accounting():
    q, u = wire_bytes(1 << 20, block=256, unquantized_dtype=jnp.float32)
    assert u == 4 << 20
    assert q == (1 << 20) + (4096 * 4)      # payload + scales
    assert u / q > 3.9                       # ~4x reduction vs f32


SUBPROC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from repro.core.sharding import shard_map_compat
from repro.core.qcomm import quantized_reduce_scatter, quantized_all_gather

mesh = jax.make_mesh((8,), ("data",))
rng = np.random.default_rng(0)

# N = 8*1024 is block-aligned; N = 8*100 exercises the shard-aligned
# partitioning (per-partition block padding, boundaries at N/8 — the
# layout the scheduled ZeRO-3 reduce-scatter relies on)
for N in (8 * 1024, 8 * 100):
    # per-device distinct gradients (replicated shape, different values)
    gs = jnp.asarray(rng.normal(size=(8, N)), jnp.float32)

    def rs_local(g):
        return quantized_reduce_scatter(g[0], "data")

    out = jax.jit(shard_map_compat(rs_local, mesh=mesh,
                                   in_specs=P("data", None),
                                   out_specs=P("data")))(gs)
    got = np.asarray(out)                       # (N,) concatenated partitions
    assert got.shape == (N,), got.shape         # shard-aligned: no padding out
    want = np.asarray(gs.sum(axis=0))           # full reduction
    err = np.abs(got - want)
    tol = np.abs(gs).max() / 127.0 * 8 + 1e-5   # 8 devices' quant errors add
    assert err.max() <= tol, (N, err.max(), tol)
    print("RS_OK", N, float(err.max()))

# all_gather: every device contributes its partition, result replicated
parts = jnp.asarray(rng.normal(size=(8, 128)), jnp.float32)
def ag_local(p):
    return quantized_all_gather(p[0], "data")
outg = jax.jit(shard_map_compat(ag_local, mesh=mesh,
                                in_specs=P("data", None),
                                out_specs=P()))(parts)
wantg = np.asarray(parts).reshape(-1)
errg = np.abs(np.asarray(outg) - wantg)
assert errg.max() <= np.abs(parts).max() / 127.0 + 1e-6
print("AG_OK", float(errg.max()))
print("QCOMM_OK")
"""


@pytest.mark.slow
def test_quantized_collectives_8dev_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = str(Path(__file__).resolve().parents[1] / "src")
    out = subprocess.run([sys.executable, "-c", SUBPROC_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert "QCOMM_OK" in out.stdout, out.stdout + out.stderr
