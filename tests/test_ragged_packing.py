"""Ragged-throughput tests: sequence packing, segment-aware attention,
effective-token planning, and the satellite plumbing (tokens/sec EMA,
profile cache, overlap calibration).

The NaN-probe test is the load-bearing one for the kernels: it proves the
``pl.when`` segment block-skip really never *reads* a fully-disjoint K/V
tile (masking alone would still read it, and 0 * NaN = NaN would leak).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.allocation import allocate_stage01, fit_curve
from repro.core.cluster import cluster_B, make_cluster
from repro.core.hetero import layout_from_plan
from repro.core.overlap import SCHEDULED_OVERLAP_FACTOR, calibrate_overlap_factor
from repro.core.planner import make_runners, plan
from repro.core.profiler import StepSegments, profile_cluster
from repro.core.telemetry import EMAWindow
from repro.core.workload import (PackedWorkload, train_flops_per_row,
                                 train_flops_per_token)
from repro.data.pipeline import (HeteroDataLoader, MixedLengthDocs,
                                 pack_documents)
from repro.kernels.flash_attention import flash_attention_vjp
from repro.models import model as mm

RNG = np.random.default_rng(7)


def _rand(*shape):
    return jnp.asarray(RNG.standard_normal(shape), jnp.float32)


def _seg_row(seq, seg_lens):
    """Contiguous segments 1..n then pad 0 — the pack_documents layout."""
    row = np.zeros(seq, np.int32)
    off = 0
    for sid, L in enumerate(seg_lens, start=1):
        row[off:off + L] = sid
        off += L
    assert off <= seq
    return row


# ---------------------------------------------------------------------------
# packer: fill efficiency + emitted layout invariants
# ---------------------------------------------------------------------------

def test_pack_documents_layout_and_fill():
    seq, rows = 64, 8
    src = MixedLengthDocs(1000, seq, min_len=8, seed=3)
    budget = int(round(rows * seq * HeteroDataLoader.PACK_OVERDRAW
                       / src.mean_doc_len))
    fields, stats = pack_documents(src.documents(budget, 0), rows, seq)
    # FFD reaches single-digit-ish pad fractions; the padded baseline
    # (one doc per row) wastes >= 40% of the slots on the same stream
    assert stats.pad_fraction < 0.15
    padded = src.rows(rows, 0)
    padded_fill = float((padded[:, 1:] != 0).mean())
    assert 1.0 - padded_fill >= 0.40
    seg, pos, lm = (fields["segment_ids"], fields["positions"],
                    fields["loss_mask"])
    # loss mask == real-token indicator; positions restart per document;
    # segment ids are contiguous runs 1..n per row
    np.testing.assert_array_equal(lm, (seg > 0).astype(np.float32))
    for r in range(rows):
        ids = seg[r][seg[r] > 0]
        if ids.size == 0:
            continue
        uniq = np.unique(ids)
        np.testing.assert_array_equal(uniq, np.arange(1, uniq.size + 1))
        # contiguous: sorted run order (FFD appends left to right)
        assert np.all(np.diff(ids) >= 0)
        for sid in uniq:
            np.testing.assert_array_equal(
                pos[r][seg[r] == sid], np.arange(int((seg[r] == sid).sum())))


# ---------------------------------------------------------------------------
# kernel: packed parity + the NaN block-skip probe
# ---------------------------------------------------------------------------

def _ref_attention(q, k, v, seg, causal, window):
    """Dense jnp oracle with the same (q_seg == k_seg) & (k_seg != 0) mask.

    Finite -1e9 masking keeps fully-masked pad rows NaN-free; only
    non-pad positions are ever compared.
    """
    Hq, Hkv = q.shape[1], k.shape[1]
    kx = jnp.repeat(k, Hq // Hkv, axis=1)
    vx = jnp.repeat(v, Hq // Hkv, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, kx) / jnp.sqrt(q.shape[-1])
    m = (seg[:, None, :, None] == seg[:, None, None, :]) \
        & (seg[:, None, None, :] != 0)
    idx = jnp.arange(q.shape[2])
    if causal:
        m = m & (idx[:, None] >= idx[None, :])
    if window is not None:
        m = m & (idx[:, None] - idx[None, :] < window)
    p = jax.nn.softmax(jnp.where(m, s, -1e9), axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vx)


@pytest.mark.parametrize("window", [None, 48])
def test_flash_segment_parity_fwd_and_grads(window):
    B, Hq, Hkv, S, D = 2, 4, 2, 128, 32
    q, k, v = _rand(B, Hq, S, D), _rand(B, Hkv, S, D), _rand(B, Hkv, S, D)
    seg = jnp.asarray(np.stack([_seg_row(S, [40, 50, 30]),     # 8 pad slots
                                _seg_row(S, [60, 68])]))       # full row
    real = (np.asarray(seg) > 0)[:, None, :, None]             # (B,1,S,1)
    cot = _rand(B, Hq, S, D) * real                            # 0 at pads

    def f_kernel(q, k, v):
        out = flash_attention_vjp(q, k, v, seg, causal=True, window=window,
                                  block_q=32, block_k=32, interpret=True)
        return jnp.sum(out * cot), out

    def f_ref(q, k, v):
        out = _ref_attention(q, k, v, seg, causal=True, window=window)
        return jnp.sum(out * cot), out

    (_, out_k), grads_k = jax.value_and_grad(f_kernel, (0, 1, 2),
                                             has_aux=True)(q, k, v)
    (_, out_r), grads_r = jax.value_and_grad(f_ref, (0, 1, 2),
                                             has_aux=True)(q, k, v)
    np.testing.assert_allclose(np.asarray(out_k * real),
                               np.asarray(out_r * real),
                               rtol=2e-3, atol=2e-3)
    for gk, gr, name in zip(grads_k, grads_r, "qkv"):
        np.testing.assert_allclose(np.asarray(gk * (real if name == "q"
                                                    else 1.0)),
                                   np.asarray(gr * (real if name == "q"
                                                    else 1.0)),
                                   rtol=2e-3, atol=2e-3, err_msg=f"d{name}")


def test_segment_block_skip_never_reads_disjoint_tiles():
    """Poison V rows of a K tile fully inside an *earlier* segment: if the
    pl.when skip works, later segments' outputs stay finite (the tile is
    never read); with masking alone, 0 * NaN = NaN would leak through."""
    B, H, S, D, blk = 1, 2, 128, 32, 32
    q, k = _rand(B, H, S, D), _rand(B, H, S, D)
    v = np.asarray(_rand(B, H, S, D)).copy()
    # seg1 rows 0..49, seg2 rows 50..99, pad 100..127; K tile [0, 32) is
    # fully seg1 and fully disjoint from every q tile at rows >= 64
    seg = jnp.asarray(_seg_row(S, [50, 50])[None])
    v[:, :, :blk, :] = np.nan
    v = jnp.asarray(v)
    out = flash_attention_vjp(q, k, v, seg, causal=True,
                              block_q=blk, block_k=blk, interpret=True)
    # q tiles [64,96) and [96,128) have nonzero-seg range {2} — disjoint
    # from the poisoned tile's {1}, so rows 64..99 must be finite
    assert bool(jnp.all(jnp.isfinite(out[:, :, 64:100, :])))
    # seg1's own rows legitimately read the poisoned values
    assert not bool(jnp.all(jnp.isfinite(out[:, :, :50, :])))
    # sanity: without segment ids the causal mask alone reads the tile
    out_noseg = flash_attention_vjp(q, k, v, None, causal=True,
                                    block_q=blk, block_k=blk, interpret=True)
    assert not bool(jnp.all(jnp.isfinite(out_noseg[:, :, 64:100, :])))


# ---------------------------------------------------------------------------
# model: packed loss == padded per-document loss (same documents)
# ---------------------------------------------------------------------------

def test_model_packed_loss_matches_padded():
    cfg = get_config("llama-0.5b", reduced=True)
    seq, rows = 64, 2
    src = MixedLengthDocs(cfg.vocab_size, seq, min_len=8, max_len=30, seed=5)
    docs = src.documents(6, 0)
    fields, stats = pack_documents(docs, rows, seq)
    assert stats.n_dropped == 0 and stats.n_packed == len(docs)
    params, _ = mm.init_model(jax.random.PRNGKey(0), cfg)

    packed = {k: jnp.asarray(v) for k, v in fields.items()}
    loss_p, met_p = mm.loss_fn(params, cfg, packed, impl="reference")

    # padded baseline: one doc per row, default positions, no segments
    pad = np.zeros((len(docs), seq + 1), np.int32)
    for i, d in enumerate(docs):
        pad[i, :len(d)] = d[:seq + 1]
    batch = {"tokens": jnp.asarray(pad[:, :-1]),
             "labels": jnp.asarray(pad[:, 1:]),
             "loss_mask": jnp.asarray((pad[:, 1:] != 0).astype(np.float32))}
    loss_d, met_d = mm.loss_fn(params, cfg, batch, impl="reference")

    assert int(met_p["tokens"]) == int(met_d["tokens"]) == stats.real_tokens
    np.testing.assert_allclose(float(loss_p), float(loss_d), rtol=2e-4)


# ---------------------------------------------------------------------------
# loader: packed stream through the hetero layout, seek/relayout purity
# ---------------------------------------------------------------------------

def _layout(gbs=16, seq=64):
    cfg = get_config("llama-0.5b")
    cluster = make_cluster("t", [("V100-16G", 2), ("T4-16G", 2)])
    runners = make_runners(cluster, cfg, seq, 0)
    curves = {n: fit_curve(p)
              for n, p in profile_cluster(runners, 0).items()}
    return layout_from_plan(allocate_stage01(curves, gbs))


def test_packed_loader_fill_and_fields():
    seq = 64
    layout = _layout(16, seq)
    src = MixedLengthDocs(1000, seq, min_len=8, seed=1)
    packed = HeteroDataLoader(src, layout, seq, packing=True).next_batch()
    padded = HeteroDataLoader(src, layout, seq).next_batch()
    for name in ("tokens", "labels", "segment_ids", "positions",
                 "loss_mask"):
        assert name in packed, name
    cap = layout.total_real() * seq
    frac_packed = 1.0 - float(packed["loss_mask"].sum()) / cap
    frac_padded = 1.0 - float(padded["loss_mask"].sum()) / cap
    assert frac_packed < 0.15
    assert frac_padded >= 0.40
    # labels are next-token shifted within every segment
    seg, tok, lab = (packed[k] for k in ("segment_ids", "tokens", "labels"))
    inner = (seg[:, :, 1:] == seg[:, :, :-1]) & (seg[:, :, 1:] > 0)
    np.testing.assert_array_equal(tok[:, :, 1:][inner], lab[:, :, :-1][inner])


def test_packed_loader_seek_and_relayout_are_pure():
    seq = 64
    layout = _layout(16, seq)
    src = MixedLengthDocs(1000, seq, min_len=8, seed=2)
    a = HeteroDataLoader(src, layout, seq, packing=True)
    batches = [a.next_batch() for _ in range(3)]
    b = HeteroDataLoader(src, layout, seq, packing=True)
    b.seek(2)
    replay = b.next_batch()
    for name, arr in batches[2].items():
        np.testing.assert_array_equal(arr, replay[name], err_msg=name)
    # relayout with seek: same stream position, new layout — stats agree
    c = HeteroDataLoader(src, layout, seq, packing=True)
    c.relayout(_layout(24, seq), seek=2)
    c.next_batch()
    assert c.last_pack_stats.pad_fraction < 0.15


# ---------------------------------------------------------------------------
# planner: effective-token pricing moves the hetero allocation
# ---------------------------------------------------------------------------

def test_train_flops_per_row_effective_tokens():
    cfg = get_config("llama-0.5b")
    seq = 4096
    base = train_flops_per_row(cfg, seq)
    assert base == pytest.approx(train_flops_per_token(cfg, seq) * seq)
    # pure fill discount: linear in token_fraction at unchanged span
    half = train_flops_per_row(cfg, seq,
                               PackedWorkload(0.5, mean_segment_len=seq))
    assert half == pytest.approx(0.5 * base)
    # shorter segments shrink the quadratic attention term too
    short = train_flops_per_row(cfg, seq, PackedWorkload(1.0, 128.0))
    assert short < base
    assert short == pytest.approx(train_flops_per_token(cfg, 128) * seq)
    # stats clamp into [0, 1]
    stats = dataclasses.make_dataclass(
        "S", ["pad_fraction", "mean_segment_len"])(-0.2, 64.0)
    pw = PackedWorkload.from_stats(stats)
    assert pw.token_fraction == 1.0 and pw.mean_segment_len == 64.0


def test_planner_allocation_shifts_under_packed_pricing():
    """The acceptance scenario: pricing the packed workload changes the
    hetero batch split (pad-heavy compute overweights the fast devices;
    the effective workload hands rows back to the slow ones)."""
    cfg = get_config("llama-0.5b")
    pw = PackedWorkload(token_fraction=0.6, mean_segment_len=128.0)
    p0 = plan(cluster_B(), cfg, 128, 4096, zero_stage=3)
    p1 = plan(cluster_B(), cfg, 128, 4096, zero_stage=3, packed=pw)
    a0 = {n: a.gmbs for n, a in p0.allocation.assignments.items()}
    a1 = {n: a.gmbs for n, a in p1.allocation.assignments.items()}
    assert sum(a0.values()) == sum(a1.values()) == 128
    assert a0 != a1
    # the packed plan shifts rows toward the slower T4s: with the
    # compute-per-row discounted, the comm/compute balance at stage 3
    # lets them carry more of the batch
    t4 = [n for n in a0 if n.startswith("T4")]
    assert sum(a1[n] for n in t4) > sum(a0[n] for n in t4)
    # both plans still simulate
    assert p0.predicted.iter_time > 0 and p1.predicted.iter_time > 0


# ---------------------------------------------------------------------------
# satellites: tokens/sec EMA, profile cache, overlap calibration
# ---------------------------------------------------------------------------

def test_ema_window_tokens_per_sec():
    w = EMAWindow(alpha=0.5)
    w.record(9.0, tokens=1.0)          # warmup: timed the jit compile
    assert w.tokens_per_sec is None
    w.record(0.5, tokens=100.0)
    assert w.tokens_per_sec == pytest.approx(200.0)
    w.record(0.5, tokens=50.0)
    assert w.tokens_per_sec == pytest.approx(0.5 * 100.0 + 0.5 * 200.0)
    # tokens-less records (padded callers) leave the EMA untouched
    w.record(0.5)
    assert w.tokens_per_sec == pytest.approx(150.0)
    w.reset()
    assert w.tokens_per_sec is None and w.value is None


class _CountingRunner:
    """Minimal DeviceRunner that counts real executions."""
    source = "measured"
    dedupe_key = None

    def __init__(self, cache_key):
        self.cache_key = cache_key
        self.calls = 0

    def memory_capacity_bytes(self):
        return 16e9

    def memory_bytes_at(self, batch):
        return 1e9 + batch * 2e9

    def run_step(self, batch):
        self.calls += 1
        if self.memory_bytes_at(batch) > self.memory_capacity_bytes():
            from repro.core.profiler import SimOOM
            raise SimOOM("oom")
        return StepSegments(fwd=1e-3 * batch, bwd=2e-3 * batch)


def test_profile_cache_skips_reprofiling():
    cache = {}
    r1 = _CountingRunner(("cfg", 64, 0, "kind"))
    first = profile_cluster({"d#1": r1}, 0, cache=cache)
    assert r1.calls > 0 and first["d#1"].probes == r1.calls
    assert ("cfg", 64, 0, "kind") in cache
    # fresh runner, same persistent identity: served from cache, zero runs
    r2 = _CountingRunner(("cfg", 64, 0, "kind"))
    second = profile_cluster({"d#1": r2}, 0, cache=cache)
    assert r2.calls == 0
    assert second["d#1"].probes == 0
    assert second["d#1"].shared_from is None  # hit lives in a prior call
    assert second["d#1"].mbs == first["d#1"].mbs
    assert second["d#1"].source == "measured"
    # different workload identity misses
    r3 = _CountingRunner(("cfg", 128, 0, "kind"))
    profile_cluster({"d#1": r3}, 0, cache=cache)
    assert r3.calls > 0


def test_calibrate_overlap_factor():
    # scheduled hid 0.7s of 1.0s comm
    assert calibrate_overlap_factor(2.0, 1.3, 1.0) == pytest.approx(0.7)
    # never credits full hiding: clamped at 0.95
    assert calibrate_overlap_factor(2.0, 0.9, 1.0) == 0.95
    # degenerate probes fall back to the static default
    fb = SCHEDULED_OVERLAP_FACTOR
    assert calibrate_overlap_factor(0.0, 1.0, 1.0) == fb
    assert calibrate_overlap_factor(2.0, 1.3, 0.0) == fb
    assert calibrate_overlap_factor(1.0, 1.2, 0.5) == fb  # sched slower
    assert calibrate_overlap_factor(2.0, 1.3, 1.0, fallback=0.5) != 0.5
