"""Continuous-batching engine: scheduler invariants, token parity with
the contiguous decode path, compile-count boundedness under shape
bucketing, hetero traffic splitting, drift-triggered re-splits, and the
8-device engine-vs-fixed-wave drill.
"""
import os
import subprocess
import sys
from dataclasses import replace
from pathlib import Path

import numpy as np
import pytest

import jax.numpy as jnp

from repro.api import Session
from repro.configs import get_config
from repro.core.cluster import make_cluster
from repro.core.telemetry import DriftConfig, ServeTelemetry
from repro.serve import trace_counts
from repro.serve.engine import Engine
from repro.serve.paged_cache import PagedCacheOOM
from repro.serve.split import plan_traffic_split, uniform_split


def _cfg():
    cfg = get_config("llama-0.5b", reduced=True)
    return replace(cfg, dtype="float32", param_dtype="float32")


def _skewed_cluster():
    return make_cluster("c8", [("V100-16G", 4), ("T4-16G", 4)], 12.0)


@pytest.fixture(scope="module")
def sess():
    return Session.build(_cfg(), mode="serve", impl="reference")


def _oracle(sess, prompt, gen):
    """Per-request contiguous decode: the pre-engine token sequence the
    paged path must reproduce exactly (greedy, same params)."""
    state = sess.init_decode_state(1, len(prompt) + gen)
    logits = None
    for t in prompt:
        logits, state = sess.decode(jnp.asarray([[t]], jnp.int32), state)
    out = []
    tok = int(jnp.argmax(logits[0, -1]))
    for _ in range(gen):
        out.append(tok)
        logits, state = sess.decode(jnp.asarray([[tok]], jnp.int32), state)
        tok = int(jnp.argmax(logits[0, -1]))
    return out


def test_engine_tokens_match_contiguous_decode(sess):
    """Mixed-length requests through chunked prefill + bucketed paged
    decode produce exactly the tokens the contiguous path produces."""
    rng = np.random.default_rng(0)
    prompts = [rng.integers(3, sess.cfg.vocab_size, int(n)).tolist()
               for n in (5, 16, 11, 3)]
    gens = [6, 3, 8, 5]
    eng = sess.engine(num_pages=64, page_size=4, chunk=4)
    rids = [eng.submit(p, g) for p, g in zip(prompts, gens)]
    results = eng.run()
    for rid, p, g in zip(rids, prompts, gens):
        assert results[rid] == _oracle(sess, p, g), f"request {rid}"
    assert eng.kv.used_pages == 0
    eng.kv.check()


def test_engine_preemption_parity(sess):
    """A pool too small for the whole batch forces recompute-style
    preemption; greedy decode makes the preempted requests' tokens
    identical to an uncontended run."""
    rng = np.random.default_rng(1)
    prompts = [rng.integers(3, sess.cfg.vocab_size, int(n)).tolist()
               for n in (9, 7, 12, 8)]
    gens = [8, 8, 8, 8]
    roomy = sess.engine(num_pages=128, page_size=4, chunk=4)
    tight = sess.engine(num_pages=14, page_size=4, chunk=4)
    rids = [roomy.submit(p, g) for p, g in zip(prompts, gens)]
    want = roomy.run()
    tids = [tight.submit(p, g) for p, g in zip(prompts, gens)]
    got = tight.run()
    assert tight.preemptions > 0, "pool was large enough — test is vacuous"
    for a, b in zip(rids, tids):
        assert want[a] == got[b]
    assert tight.kv.used_pages == 0
    tight.kv.check()


def test_engine_admission_respects_slots_and_pages(sess):
    eng = sess.engine(num_pages=32, page_size=4, chunk=4, max_batch=2)
    for _ in range(5):
        eng.submit([5, 6, 7], 2)
    eng._admit()
    assert len(eng.prefilling) + len(eng.decoding) <= 2
    assert len(eng.queued) == 3
    while eng.queued or eng.prefilling or eng.decoding:
        live = len(eng.prefilling) + len(eng.decoding)
        assert live <= 2
        eng.step()
        eng.kv.check()
    assert len(eng.done) == 5
    assert eng.kv.used_pages == 0


def test_engine_chunked_prefill_budget(sess):
    """Prefill advances at most ``prefill_budget`` tokens per tick, in
    ``chunk``-sized slices — decode is never starved by a long prompt."""
    eng = sess.engine(num_pages=64, page_size=4, chunk=4,
                      prefill_budget=4)
    long_prompt = list(range(3, 3 + 19))
    eng.submit(long_prompt, 2)
    positions = []
    for _ in range(6):
        eng.step()
        r = (eng.prefilling + eng.decoding)
        positions.append(r[0].prefill_pos if r else len(long_prompt))
        if not (eng.prefilling or eng.decoding or eng.queued):
            break
    deltas = [b - a for a, b in zip([0] + positions, positions)]
    assert all(d <= 4 for d in deltas), deltas
    assert max(positions) == len(long_prompt)


def test_engine_submit_rejects_impossible_request(sess):
    eng = sess.engine(num_pages=8, page_size=4, chunk=4)
    with pytest.raises(PagedCacheOOM):
        eng.submit(list(range(3, 40)), 64)     # can never fit
    with pytest.raises(ValueError):
        eng.submit([], 4)


def test_engine_compile_counts_bounded(sess):
    """The satellite bugfix pin: B and page-table width are bucketed to
    powers of two and jitted fns are cached at module level, so compile
    counts stay O(log) in batch/length — and a second engine over the
    same config adds zero new compiles."""
    eng = sess.engine(num_pages=256, page_size=4, chunk=4)
    rng = np.random.default_rng(2)
    for n in (3, 5, 7, 9, 11, 13, 4, 6):
        eng.submit(rng.integers(3, sess.cfg.vocab_size, n).tolist(),
                   int(rng.integers(2, 7)))
    before = trace_counts()
    eng.run()
    mid = trace_counts()
    # 8 ragged requests, dozens of prefill chunks and decode ticks:
    # compiles bounded by the handful of power-of-two (B, table-width)
    # buckets actually visited, not by ticks or token counts
    assert mid.get("decode", 0) - before.get("decode", 0) <= 6
    assert mid.get("prefill", 0) - before.get("prefill", 0) <= 4

    eng2 = sess.engine(num_pages=256, page_size=4, chunk=4)
    for n in (3, 5, 7, 9):
        eng2.submit(rng.integers(3, sess.cfg.vocab_size, n).tolist(), 3)
    eng2.run()
    after = trace_counts()
    assert after == mid, "second engine re-compiled despite shared cache"


def test_engine_telemetry_populated(sess):
    eng = sess.engine(num_pages=64, page_size=4, chunk=4)
    eng.submit([4, 5, 6, 7], 3)
    eng.submit([8, 9], 2)
    eng.run()
    snap = eng.telemetry.snapshot()
    assert snap["requests_done"] == 2
    assert snap["tokens_generated"] == 5
    assert snap["prefill_tokens"] >= 6
    assert snap["ttft_p50_s"] is not None and snap["ttft_p50_s"] > 0
    assert snap["tok_p50_s"] is not None
    assert "serve:" in eng.telemetry.describe()
    line = eng.log_line()
    assert "pages" in line and "q0/p0/d0" in line


# ------------------------------------------------------ traffic split --


def test_hetero_split_differs_from_uniform():
    """On the skewed 4xV100 + 4xT4 fixture the HBM-bound decode pricing
    and the compute-bound prefill pricing must both leave the uniform
    50/50 point, and not by the same amount (two different currencies)."""
    cfg = _cfg()
    cl = _skewed_cluster()
    het = plan_traffic_split(cl, cfg, requests=16, cache_len=64)
    uni = uniform_split(cl, cfg, requests=16, cache_len=64)
    assert uni.decode_share["V100-16G"] == pytest.approx(0.5)
    assert het.decode_share["V100-16G"] > 0.6       # fast HBM pulls decode
    assert het.prefill_share["V100-16G"] > 0.5      # fast compute too
    assert (het.decode_share["V100-16G"]
            != pytest.approx(het.prefill_share["V100-16G"]))
    assert het.decode_slots_total == 16
    assert het.wave_latency > 0
    assert "hetero" in het.describe() and "uniform" in uni.describe()


def test_split_sizes_engine_admission(sess):
    cl = _skewed_cluster()
    split = plan_traffic_split(cl, _cfg(), requests=4, cache_len=32)
    eng = Engine(sess.state.params, sess.cfg, num_pages=64, page_size=4,
                 chunk=4, split=split, impl="reference")
    assert eng.decode_slots == 4
    for i in range(6):
        eng.submit([3 + i, 4 + i, 5 + i], 2)
    eng._admit()
    assert len(eng.prefilling) + len(eng.decoding) <= 4
    lanes = {r.lane for r in (*eng.queued, *eng.prefilling)}
    assert lanes <= set(split.lanes)
    eng.run()
    assert len(eng.done) == 6


def test_engine_resplit_on_sustained_drift(sess):
    """Decode-step EMA drifting far from the split's predicted wave
    latency re-runs the pricing after ``resplit_after`` consecutive
    drifted reports and fires the arbiter hook."""
    cl = _skewed_cluster()
    split = plan_traffic_split(cl, _cfg(), requests=4, cache_len=32)
    fired = []
    eng = Engine(sess.state.params, sess.cfg, num_pages=64, page_size=4,
                 chunk=4, split=split, cluster=cl, impl="reference",
                 drift_config=DriftConfig(threshold=0.5, min_samples=2),
                 resplit_after=2, on_resplit=fired.append)
    win = eng.telemetry.throughput
    # calibration: nominal samples establish observed/predicted baseline
    for _ in range(4):
        win.record(0.01, tokens=4)
        eng.maybe_resplit()
    assert eng._drift_baseline is not None and eng.resplits == 0
    # sustained 4x slowdown: first drifted report arms the streak, the
    # second crosses resplit_after and re-prices the split
    for _ in range(8):
        win.record(0.04, tokens=4)
        eng.maybe_resplit()
        if eng.resplits:
            break
    assert eng.resplits == 1
    assert len(fired) == 1 and fired[0] is eng.split
    assert eng._drift_baseline is None          # recalibrating vs new plan
    assert eng.describe()["resplits"] == 1


# --------------------------------------- 8-device acceptance (slow) -----

ENGINE_SUBPROC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import time
from dataclasses import replace
import numpy as np
import jax.numpy as jnp
from repro.api import Session
from repro.configs import get_config
from repro.core.cluster import make_cluster
from repro.launch.serve import run_engine_wave, run_wave

cfg = replace(get_config("llama-0.5b", reduced=True),
              dtype="float32", param_dtype="float32")
cl = make_cluster("c8", [("V100-16G", 4), ("T4-16G", 4)], 12.0)
sess = Session.build(cfg, cl, mode="serve", impl="reference")

# skewed mixed-length traffic — mostly short chats plus a couple of
# long documents. The wave pads *everyone* to the longest prompt and
# the longest horizon, so the longs tax every short request twice;
# the engine retires shorts as they finish and back-fills.
rng = np.random.default_rng(0)
plens = [int(n) for n in rng.integers(4, 9, 8)] + [56, 48]
gens = [int(g) for g in rng.integers(2, 5, 8)] + [40, 48]
prompts = [rng.integers(3, cfg.vocab_size, n).tolist() for n in plens]
useful = sum(gens)
pmax, gmax = max(plens), max(gens)

kw = dict(num_pages=256, page_size=8, chunk=32)
# correctness on the cold run (hetero split sizes admission off the
# lease cluster), then best-of-2 warm timings for both paths
results, _, eng = run_engine_wave(sess, prompts, gens, **kw)
assert sorted(len(v) for v in results.values()) == sorted(gens)
assert eng.split is not None and eng.split.strategy == "hetero"
assert eng.kv.used_pages == 0
engine_s = min(run_engine_wave(sess, prompts, gens, **kw)[1]
               for _ in range(2))

wave = jnp.asarray(np.stack([
    np.pad(p, (0, pmax - len(p)), constant_values=3) for p in prompts]),
    jnp.int32)
run_wave(sess, wave, gmax)                       # warmup
wave_s = []
for _ in range(2):
    t0 = time.time()
    run_wave(sess, wave, gmax)
    wave_s.append(time.time() - t0)
wave_s = min(wave_s)

engine_tps = useful / engine_s
wave_tps = useful / wave_s
print(f"engine {engine_tps:.1f} tok/s vs wave {wave_tps:.1f} tok/s")
assert engine_tps > wave_tps, (engine_tps, wave_tps)
print("ENGINE_BEATS_WAVE_OK")
"""


@pytest.mark.slow
def test_engine_beats_fixed_wave_8dev_subprocess():
    """Acceptance on the 8-device CPU mesh: on mixed-length traffic the
    continuous-batching engine's useful tokens/sec beats the fixed-wave
    baseline that pads every request to the longest prompt + horizon."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(Path(__file__).resolve().parents[1] / "src")
    out = subprocess.run([sys.executable, "-c", ENGINE_SUBPROC_SCRIPT],
                         env=env, capture_output=True, text=True,
                         timeout=900)
    assert "ENGINE_BEATS_WAVE_OK" in out.stdout, out.stdout + out.stderr
