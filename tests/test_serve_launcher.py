"""Serving launcher: Poplar allocation applied to decode waves."""
import numpy as np

from repro.configs import get_config
from repro.core.allocation import allocate_stage01, fit_curve
from repro.core.cluster import cluster_B, cluster_C
from repro.core.profiler import decode_profiles
from repro.launch.serve import run_engine_wave, run_wave

import jax
import jax.numpy as jnp


def _decode_curves(cluster, cfg, cache_len):
    # launch/serve used to wrap this one-liner; the profiling itself is
    # core/profiler.decode_profiles, shared with planner and arbiter
    return {n: fit_curve(p)
            for n, p in decode_profiles(cluster, cfg, cache_len).items()}


def test_decode_wave_allocation_sums_and_favors_fast():
    cfg = get_config("llama-0.5b")
    curves = _decode_curves(cluster_C(), cfg, cache_len=4096)
    plan = allocate_stage01(curves, 64)
    assert plan.total_batch == 64
    a800 = sum(a.gmbs for n, a in plan.assignments.items() if "A800" in n)
    v100 = sum(a.gmbs for n, a in plan.assignments.items() if "V100S" in n)
    # same count of each device type; faster HBM must take more requests
    assert a800 > v100


def test_decode_wave_respects_memory_limits():
    cfg = get_config("llama-1.1b")
    # tiny 16GB parts at a huge cache length -> small mbs
    curves = _decode_curves(cluster_B(), cfg, cache_len=262144)
    for c in curves.values():
        assert c.mbs >= 1
    plan = allocate_stage01(curves, 8)
    for name, a in plan.assignments.items():
        assert a.micro_batch <= curves[name].mbs


def test_run_wave_generates_tokens():
    from repro.api import Session

    cfg = get_config("llama-0.5b", reduced=True)
    sess = Session.build(cfg, mode="serve", impl="reference")
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(3, cfg.vocab_size, (2, 4)), jnp.int32)
    gen, prefill_s, decode_s = run_wave(sess, prompts, gen_tokens=3)
    assert gen.shape == (2, 3)
    assert (gen >= 0).all() and (gen < cfg.vocab_size).all()


def test_run_engine_wave_matches_request_count():
    from repro.api import Session

    cfg = get_config("llama-0.5b", reduced=True)
    sess = Session.build(cfg, mode="serve", impl="reference")
    prompts = [[5, 6, 7], [9, 10, 11, 12, 13]]
    results, wall_s, eng = run_engine_wave(sess, prompts, [3, 2],
                                           num_pages=64, page_size=4,
                                           chunk=4)
    assert sorted(results) == [0, 1]
    assert len(results[0]) == 3 and len(results[1]) == 2
    assert wall_s > 0
    assert eng.kv.used_pages == 0          # everything retired and freed
    assert eng.telemetry.requests_done == 2
