"""Serving launcher: Poplar allocation applied to decode waves."""
import numpy as np

from repro.configs import get_config
from repro.core.allocation import allocate_stage01
from repro.core.cluster import cluster_B, cluster_C
from repro.launch.serve import profile_decode_groups, run_wave

import jax
import jax.numpy as jnp


def test_decode_wave_allocation_sums_and_favors_fast():
    cfg = get_config("llama-0.5b")
    curves = profile_decode_groups(cluster_C(), cfg, cache_len=4096)
    plan = allocate_stage01(curves, 64)
    assert plan.total_batch == 64
    a800 = sum(a.gmbs for n, a in plan.assignments.items() if "A800" in n)
    v100 = sum(a.gmbs for n, a in plan.assignments.items() if "V100S" in n)
    # same count of each device type; faster HBM must take more requests
    assert a800 > v100


def test_decode_wave_respects_memory_limits():
    cfg = get_config("llama-1.1b")
    # tiny 16GB parts at a huge cache length -> small mbs
    curves = profile_decode_groups(cluster_B(), cfg, cache_len=262144)
    for c in curves.values():
        assert c.mbs >= 1
    plan = allocate_stage01(curves, 8)
    for name, a in plan.assignments.items():
        assert a.micro_batch <= curves[name].mbs


def test_run_wave_generates_tokens():
    from repro.api import Session

    cfg = get_config("llama-0.5b", reduced=True)
    sess = Session.build(cfg, mode="serve", impl="reference")
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(3, cfg.vocab_size, (2, 4)), jnp.int32)
    gen, prefill_s, decode_s = run_wave(sess, prompts, gen_tokens=3)
    assert gen.shape == (2, 3)
    assert (gen >= 0).all() and (gen < cfg.vocab_size).all()
