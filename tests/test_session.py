"""Session API: the one-call facade must be a *refactor*, not a new code
path — its jitted step is the same computation the hand-wired ceremony
built (bit-identical params across ZeRO stages 0–3, accum>1 and the
scheduled-overlap path on an 8-device mesh), its checkpoints resume the
exact trajectory, and TrainState carries the logical axes as static
pytree metadata (no register_axes side channel)."""
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import Session, TrainState, build_step, new_train_state
from repro.configs import get_config
from repro.core.sharding import MeshRules
from repro.launch.mesh import make_debug_mesh
from repro.models import model as mm


# ------------------------------------------------------------ TrainState --

def test_train_state_roundtrips_axes_through_tree_ops():
    cfg = get_config("llama-0.5b", reduced=True)
    params, axes = mm.init_model(jax.random.PRNGKey(0), cfg)
    state = new_train_state(params, axes)
    doubled = jax.tree.map(lambda x: x * 2, state)
    assert doubled.axes == axes                     # aux data survives
    assert int(doubled.step) == 0
    leaves, treedef = jax.tree.flatten(state)
    rebuilt = jax.tree.unflatten(treedef, leaves)
    assert rebuilt.axes == axes


def test_train_state_axes_are_static_under_jit():
    cfg = get_config("llama-0.5b", reduced=True)
    params, axes = mm.init_model(jax.random.PRNGKey(0), cfg)
    state = new_train_state(params, axes)
    seen = {}

    @jax.jit
    def f(st: TrainState):
        seen["axes"] = st.axes         # trace time: plain Python data
        assert not isinstance(st.axes, jax.core.Tracer)
        return st.step + 1

    assert int(f(state)) == 1
    assert seen["axes"] == axes


def test_build_step_rejects_unknown_kind_and_missing_axes():
    cfg = get_config("llama-0.5b", reduced=True)
    rules = MeshRules(make_debug_mesh(1), zero_stage=0)
    with pytest.raises(ValueError, match="kind"):
        build_step(cfg, rules, kind="evaluate")
    with pytest.raises(ValueError, match="axes"):
        build_step(cfg, rules, kind="train")


# ------------------------------------------------- facade basics (1 dev) --

def test_session_equals_handwired_shim_single_device():
    """In-process spot check of the parity the 8-dev subprocess pins."""
    from repro.core.zero import make_train_step, register_axes
    from repro.optim.adamw import adamw_init

    cfg = get_config("llama-0.5b", reduced=True)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(3, cfg.vocab_size, (4, 16)), jnp.int32)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1),
             "loss_mask": jnp.ones((4, 16), jnp.float32)}

    params, axes = mm.init_model(jax.random.PRNGKey(0), cfg)
    rules = MeshRules(make_debug_mesh(1), zero_stage=0)
    register_axes(rules, axes)
    step = jax.jit(make_train_step(cfg, rules, lr=1e-3, impl="reference"))
    opt = adamw_init(params)
    p_ref, _, met_ref = step(params, opt, batch)

    sess = Session.build(cfg, None, gbs=4, seq=16, zero=0, impl="reference",
                         lr=1e-3, mesh=make_debug_mesh(1))
    met = sess.step(batch)
    assert float(met["loss"]) == float(met_ref["loss"])
    for a, b in zip(jax.tree.leaves(p_ref),
                    jax.tree.leaves(sess.state.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(sess.state.step) == 1


def test_describe_reports_plan_memory_and_overlap():
    from repro.core.cluster import cluster_B

    cfg = get_config("llama-0.5b", reduced=True)
    sess = Session.build(cfg, cluster_B(), gbs=8, seq=16, zero=1,
                         impl="reference")
    d = sess.describe()
    assert d["zero_stage"] == 1 and d["mode"] == "train"
    assert d["plan"]["profiling_probes"] > 0
    assert set(d["plan"]["assignments"]) == {
        "V100-16G#1", "V100-16G#2", "T4-16G#1", "T4-16G#2"}
    assert 0 < d["plan"]["predicted"]["utilization"] <= 1.0
    assert d["memory"]["model_state_gb"] > 0
    # stage 1 is not schedulable: the report is the reason string
    assert isinstance(d["overlap_report"], str)
    assert sum(a["gmbs"] for a in d["plan"]["assignments"].values()) == 8


def test_describe_overlap_report_on_stage3():
    cfg = get_config("llama-0.5b", reduced=True)
    sess = Session.build(cfg, None, gbs=8, seq=16, zero=3,
                         impl="reference", mesh=make_debug_mesh(1))
    rep = sess.describe()["overlap_report"]
    # 1-device mesh: nothing is sharded, so the report is a dict with
    # zero wire bytes (or an eligibility string on exotic meshes)
    if not isinstance(rep, str):
        assert rep["wire_bytes_scheduled"] == 0.0


def test_build_auto_stage_escalation_zero_none():
    """zero=None through Session.build: the paper's automatic ZeRO-stage
    escalation must run inside the facade (previously only exercised at
    the profiler.auto_stage unit level). The 1.1B model cannot fit
    ZeRO-0 on a 16 GB V100 (16P ≈ 17.6 GB), so the planner must settle
    on stage >= 1 and the session must adopt exactly that stage."""
    from repro.core.cluster import make_cluster

    mid = get_config("llama-1.1b")
    cluster = make_cluster("t", [("V100-16G", 4)])
    sess = Session.build(mid, cluster, gbs=16, seq=512, mode="dryrun",
                         zero=None)
    assert sess.plan is not None
    assert 1 <= sess.plan.zero_stage <= 3
    assert sess.rules.zero_stage == sess.plan.zero_stage
    # the escalation probed the infeasible stage(s) too: some profile of
    # a rejected stage had mbs=0, and the final one fits at least batch 1
    assert all(p.mbs >= 1 for p in sess.plan.profiles.values())
    assert sess.describe()["plan"]["zero_stage"] == sess.plan.zero_stage


def test_dryrun_mode_lowers_without_allocating():
    cfg = get_config("llama-0.5b", reduced=True)
    sess = Session.build(cfg, None, gbs=4, seq=16, mode="dryrun", zero=3,
                         mesh=make_debug_mesh(1))
    assert isinstance(jax.tree.leaves(sess.state.params)[0],
                      jax.ShapeDtypeStruct)
    lowered = sess.lower()
    assert "all-gather" in lowered.as_text() or lowered is not None


def test_serve_mode_decodes():
    cfg = get_config("llama-0.5b", reduced=True)
    sess = Session.build(cfg, mode="serve", impl="reference")
    state = sess.init_decode_state(2, 8)
    tok = jnp.zeros((2, 1), jnp.int32)
    for _ in range(3):
        logits, state = sess.decode(tok, state)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert int(state["index"]) == 3


def test_step_rejects_stacked_batch_when_accum_is_one():
    cfg = get_config("llama-0.5b", reduced=True)
    sess = Session.build(cfg, None, gbs=4, seq=16, zero=0, impl="reference",
                         mesh=make_debug_mesh(1))
    stacked = {"tokens": jnp.zeros((2, 4, 16), jnp.int32),
               "labels": jnp.zeros((2, 4, 16), jnp.int32),
               "loss_mask": jnp.ones((2, 4, 16), jnp.float32)}
    with pytest.raises(ValueError, match="accum"):
        sess.step(stacked)          # would silently drop micro-batches


def test_seed_reaches_the_data_source():
    cfg = get_config("llama-0.5b", reduced=True)
    kw = dict(gbs=2, seq=8, zero=0, impl="reference",
              mesh=make_debug_mesh(1))
    b0 = Session.build(cfg, None, seed=0, **kw).loader().next_batch()
    b1 = Session.build(cfg, None, seed=1, **kw).loader().next_batch()
    assert not np.array_equal(b0["tokens"], b1["tokens"])


# ------------------------------------------------------- save / restore --

def test_save_restore_resumes_identical_trajectory(tmp_path):
    cfg = get_config("llama-0.5b", reduced=True)
    kw = dict(gbs=4, seq=16, zero=0, impl="reference", lr=1e-3,
              mesh=make_debug_mesh(1))
    sess = Session.build(cfg, None, **kw)
    for _ in range(3):
        sess.step()                       # loader-fed deterministic batches
    sess.save(str(tmp_path))
    ahead = [float(sess.step()["loss"]) for _ in range(2)]

    resumed = Session.restore(str(tmp_path), cfg=cfg,
                              mesh=make_debug_mesh(1))
    assert int(resumed.state.step) == 3
    replay = [float(resumed.step()["loss"]) for _ in range(2)]
    assert replay == ahead                # bit-identical resume
    for a, b in zip(jax.tree.leaves(sess.state.params),
                    jax.tree.leaves(resumed.state.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restore_preserves_adamw_cfg(tmp_path):
    from repro.optim.adamw import AdamWConfig

    cfg = get_config("llama-0.5b", reduced=True)
    sess = Session.build(cfg, None, gbs=2, seq=8, zero=0, impl="reference",
                         mesh=make_debug_mesh(1),
                         adamw_cfg=AdamWConfig(weight_decay=0.0, b2=0.99))
    sess.step()
    sess.save(str(tmp_path))
    resumed = Session.restore(str(tmp_path), cfg=cfg,
                              mesh=make_debug_mesh(1))
    assert resumed.adamw_cfg == AdamWConfig(weight_decay=0.0, b2=0.99)


def test_restore_replays_data_recipe_without_explicit_cfg(tmp_path):
    corpus = tmp_path / "corpus.txt"
    corpus.write_text("the quick brown fox jumps over the lazy dog " * 40)
    ckpt = tmp_path / "ckpt"
    cfg = get_config("llama-0.5b", reduced=True)
    sess = Session.build(cfg, None, gbs=2, seq=8, zero=0, impl="reference",
                         mesh=make_debug_mesh(1), data=str(corpus))
    sess.step()
    sess.save(str(ckpt))
    # fingerprint is recorded against the *input* cfg, and the data=
    # recipe re-derives any vocab widening inside build
    resumed = Session.restore(str(ckpt), mesh=make_debug_mesh(1))
    assert int(resumed.state.step) == 1
    assert resumed.data == str(corpus)
    loss = float(resumed.step()["loss"])
    assert np.isfinite(loss)


def test_restore_recovers_reduced_cfg_from_metadata(tmp_path):
    cfg = get_config("llama-0.5b", reduced=True)
    sess = Session.build(cfg, None, gbs=2, seq=8, zero=0, impl="reference",
                         mesh=make_debug_mesh(1))
    sess.step()
    sess.save(str(tmp_path))
    resumed = Session.restore(str(tmp_path))   # no cfg: fingerprint match
    assert resumed.cfg.total_params == cfg.total_params
    assert int(resumed.state.step) == 1


# ---------------------------------------------- 8-device parity (slow) ----

SUBPROC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
from dataclasses import replace
import jax, jax.numpy as jnp
import numpy as np
from repro.api import Session
from repro.configs import get_config
from repro.core.sharding import MeshRules
from repro.core.zero import make_train_step, model_shardings, register_axes
from repro.models import model as mm
from repro.optim.adamw import adamw_init

cfg = get_config("llama-0.5b", reduced=True)
cfg = replace(cfg, dtype="float32", param_dtype="float32")
params, axes = mm.init_model(jax.random.PRNGKey(0), cfg)
opt = adamw_init(params)
rng = np.random.default_rng(0)
toks = jnp.asarray(rng.integers(3, cfg.vocab_size, (16, 16)), jnp.int32)
batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1),
         "loss_mask": jnp.ones((16, 16), jnp.float32)}
stacked = jax.tree.map(lambda x: x.reshape((2, 8) + x.shape[1:]), batch)
mesh = jax.make_mesh((8,), ("data",))


def handwired(stage, overlap="xla", accum=1):
    rules = MeshRules(mesh, zero_stage=stage, overlap=overlap)
    register_axes(rules, axes)
    p_specs, o_specs, _ = model_shardings(rules, params, axes)
    b = stacked if accum > 1 else batch
    with mesh:
        pp = jax.device_put(params, jax.tree.map(rules.sharding, p_specs))
        oo = jax.device_put(opt, jax.tree.map(rules.sharding, o_specs))
        step = jax.jit(make_train_step(cfg, rules, lr=1e-3,
                                       impl="reference", accum_steps=accum))
        for _ in range(2):
            pp, oo, met = step(pp, oo, b)
    return jax.tree.map(np.asarray, pp), {k: float(v) for k, v in met.items()}


def via_session(stage, overlap="xla", accum=1):
    sess = Session.build(cfg, None, gbs=16, seq=16, zero=stage,
                         overlap=overlap, impl="reference", lr=1e-3,
                         mesh=mesh, accum_steps=accum)
    b = stacked if accum > 1 else batch
    for _ in range(2):
        met = sess.step(b)
    assert int(sess.state.step) == 2
    return (jax.tree.map(np.asarray, sess.state.params),
            {k: float(v) for k, v in met.items()})


for stage in (0, 1, 2, 3):
    p_ref, m_ref = handwired(stage)
    p_s, m_s = via_session(stage)
    assert m_ref["loss"] == m_s["loss"], (stage, m_ref, m_s)
    for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_s)):
        np.testing.assert_array_equal(a, b, err_msg=f"stage {stage}")
    print(f"SESSION_STAGE{stage}_OK")

p_ref, m_ref = handwired(0, accum=2)
p_s, m_s = via_session(0, accum=2)
assert m_ref["loss"] == m_s["loss"]
for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_s)):
    np.testing.assert_array_equal(a, b, err_msg="accum")
print("SESSION_ACCUM_OK")

p_ref, m_ref = handwired(3, overlap="scheduled")
p_s, m_s = via_session(3, overlap="scheduled")
assert m_ref["loss"] == m_s["loss"]
for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_s)):
    np.testing.assert_array_equal(a, b, err_msg="scheduled")
print("SESSION_SCHEDULED_OK")
print("SESSION_PARITY_OK")
"""


@pytest.mark.slow
def test_session_matches_handwired_8dev_subprocess():
    """Session.build(...).step(batch) is bit-identical to the pre-refactor
    register_axes + model_shardings + device_put + make_train_step path:
    stages 0-3, accum_steps>1, and the scheduled-overlap shard_map step."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(Path(__file__).resolve().parents[1] / "src")
    out = subprocess.run([sys.executable, "-c", SUBPROC_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert "SESSION_PARITY_OK" in out.stdout, out.stdout + out.stderr
