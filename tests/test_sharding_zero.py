"""MeshRules / ZeRO sharding unit tests (AbstractMesh — no devices needed)
+ an 8-device subprocess integration test of the multi-pod path."""
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.core.sharding import MeshRules


def _abstract_mesh(sizes, names):
    """AbstractMesh across JAX versions: 0.4.x wants ((name, size), ...);
    newer releases want (sizes, names). Either way: no devices needed."""
    try:
        return AbstractMesh(tuple(zip(names, sizes)))
    except TypeError:
        return AbstractMesh(sizes, names)


MESH1 = _abstract_mesh((16, 16), ("data", "model"))
MESH2 = _abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def test_tp_axis_divisibility():
    r = MeshRules(MESH1, zero_stage=0)
    # heads divisible by 16 -> sharded on model
    assert r.param_spec((1024, 48, 128), ("embed", "heads", None)) == \
        P(None, "model", None)
    # heads NOT divisible (llava 56) -> replicated
    assert r.param_spec((7168, 56, 128), ("embed", "heads", None)) == \
        P(None, None, None)


def test_zero3_shards_largest_free_dim():
    r = MeshRules(MESH1, zero_stage=3)
    spec = r.param_spec((1024, 48, 128), ("embed", "heads", None))
    assert spec == P("data", "model", None)
    # vocab 49155 not divisible by 16: embedding shards d_model on data
    spec = r.param_spec((49155, 1024), ("vocab", "embed"))
    assert spec == P(None, "data")


def test_zero_stage_gates_param_sharding():
    r1 = MeshRules(MESH1, zero_stage=1)
    spec = r1.param_spec((4096, 6400), ("embed", "ffn"), zero_sharded=False)
    assert spec == P(None, "model")
    spec_opt = r1.param_spec((4096, 6400), ("embed", "ffn"), zero_sharded=True)
    assert spec_opt == P("data", "model")


def test_multipod_param_spec_uses_pod_axis():
    r = MeshRules(MESH2, zero_stage=3)
    spec = r.param_spec((2048, 1408), ("embed", "ffn"))
    # zero axes = (pod, data) jointly 32-way on the largest free dim
    assert spec == P(("pod", "data"), "model")


def test_hierarchical_zero_excludes_pod():
    r = MeshRules(MESH2, zero_stage=3, hierarchical_params=True)
    spec = r.param_spec((2048, 1408), ("embed", "ffn"))
    assert spec == P("data", "model")


def test_activation_batch_spec():
    r = MeshRules(MESH2, zero_stage=3)
    assert r.activation_spec(("batch", None), (256, 4096)) == \
        P(("pod", "data"), None)
    # batch=1 (long_500k): not divisible -> replicated
    assert r.activation_spec(("batch", None), (1, 524288)) == P(None, None)


def test_expert_axis():
    r = MeshRules(MESH1, zero_stage=3)
    spec = r.param_spec((32, 1024, 512), ("experts", "embed", "ffn"))
    assert spec[0] == "model"           # 32 experts over 16-way model axis
    assert spec[1] == "data"            # FSDP on d_model


def test_dp_only_disables_tp_and_widens_zero():
    r = MeshRules(MESH1, zero_stage=3, dp_only=True)
    # no TP: heads/ffn stay unsharded; ZeRO shards over data AND model
    spec = r.param_spec((2048, 4, 1024), ("embed", "heads", None))
    assert "model" not in str(spec[1])
    assert spec == P(("data", "model"), None, None) or \
        spec == P(None, None, ("data", "model"))
    # batch maps over both axes jointly
    assert r.activation_spec(("batch", None), (256, 4096)) == \
        P(("data", "model"), None)


def test_dp_only_batch_fallback_when_indivisible():
    r = MeshRules(MESH1, zero_stage=3, dp_only=True)
    # 64 % 256 != 0 -> falls back to data-only (64 % 16 == 0)
    assert r.activation_spec(("batch", None), (64, 4096)) == P("data", None)


SUBPROC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
import numpy as np
from repro.configs import get_config
from repro.core.sharding import MeshRules
from repro.core.zero import make_train_step, model_shardings, register_axes
from repro.models import model as mm
from repro.optim.adamw import adamw_init

cfg = get_config("llama-0.5b", reduced=True)
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
params, axes = mm.init_model(jax.random.PRNGKey(0), cfg)
opt = adamw_init(params)
rng = np.random.default_rng(0)
toks = jnp.asarray(rng.integers(3, cfg.vocab_size, (8, 16)), jnp.int32)
batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1),
         "loss_mask": jnp.ones((8, 16), jnp.float32)}

losses = {}
for stage in (0, 3):
    rules = MeshRules(mesh, zero_stage=stage)
    register_axes(rules, axes)
    p_specs, o_specs, _ = model_shardings(rules, params, axes)
    with mesh:
        pp = jax.device_put(params, jax.tree.map(rules.sharding, p_specs))
        oo = jax.device_put(opt, jax.tree.map(rules.sharding, o_specs))
        step = jax.jit(make_train_step(cfg, rules, lr=1e-3))
        l = None
        for _ in range(2):
            pp, oo, met = step(pp, oo, batch)
            l = float(met["loss"])
        losses[stage] = l
print("LOSS0", losses[0])
print("LOSS3", losses[3])
assert abs(losses[0] - losses[3]) / abs(losses[0]) < 2e-2, losses
print("ZERO_EQUIV_OK")
"""


@pytest.mark.slow
def test_zero_stage_equivalence_8dev_subprocess():
    """ZeRO-0 and ZeRO-3 must produce the same training trajectory — the
    stages change *where* state lives, never the math. Runs on 8 placeholder
    devices in a subprocess so the main process keeps 1 device."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(Path(__file__).resolve().parents[1] / "src")
    out = subprocess.run([sys.executable, "-c", SUBPROC_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert "ZERO_EQUIV_OK" in out.stdout, out.stdout + out.stderr
