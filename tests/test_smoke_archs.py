"""Per-architecture smoke tests: REDUCED variant of each assigned family,
one forward + one train step on CPU, asserting output shapes + no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.core.sharding import MeshRules
from repro.core.zero import make_train_step, register_axes
from repro.launch.mesh import make_debug_mesh
from repro.models import model as mm
from repro.optim.adamw import adamw_init

B, S = 2, 32


def _batch(cfg):
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, (B, S)).astype(np.int32)
    batch = {"tokens": jnp.asarray(toks),
             "labels": jnp.asarray(np.roll(toks, -1, axis=1)),
             "loss_mask": jnp.ones((B, S), jnp.float32)}
    if cfg.encoder_layers:
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, S // cfg.encoder_frame_ratio, cfg.d_model)),
            jnp.float32)
    if cfg.num_image_tokens:
        batch["image_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.num_image_tokens, cfg.frontend_dim)),
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS + ("llama-0.5b", "bert-1.1b"))
def test_forward_shapes_no_nans(arch):
    cfg = get_config(arch, reduced=True)
    assert cfg.n_layers == 2 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    params, axes = mm.init_model(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    hidden, aux = mm.forward(params, cfg, batch)
    assert hidden.shape == (B, S, cfg.d_model)
    assert bool(jnp.isfinite(hidden.astype(jnp.float32)).all())
    logits = mm.lm_logits(params, cfg, hidden)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_one_train_step(arch):
    cfg = get_config(arch, reduced=True)
    params, axes = mm.init_model(jax.random.PRNGKey(0), cfg)
    mesh = make_debug_mesh(1)
    rules = MeshRules(mesh, zero_stage=0)
    register_axes(rules, axes)
    step = make_train_step(cfg, rules, lr=1e-3)
    opt = adamw_init(params)
    batch = _batch(cfg)
    new_params, new_opt, metrics = jax.jit(step)(params, opt, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually changed
    delta = sum(float(jnp.abs(a.astype(jnp.float32)
                              - b.astype(jnp.float32)).sum())
                for a, b in zip(jax.tree.leaves(params),
                                jax.tree.leaves(new_params)))
    assert delta > 0


@pytest.mark.parametrize("arch", ["starcoder2-15b", "xlstm-1.3b",
                                  "zamba2-2.7b", "granite-moe-1b-a400m",
                                  "seamless-m4t-medium"])
def test_serve_decode_shapes(arch):
    cfg = get_config(arch, reduced=True)
    params, _ = mm.init_model(jax.random.PRNGKey(0), cfg)
    enc = None
    if cfg.encoder_layers:
        enc = jnp.zeros((B, 8, cfg.d_model), jnp.bfloat16)
    state = mm.init_decode_state(cfg, B, 64, enc_out=enc)
    toks = jnp.ones((B, 1), jnp.int32)
    for _ in range(3):
        logits, state = mm.decode_step(params, cfg, toks, state)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    assert int(state["index"]) == 3
